"""Calibration benchmark: kernel speedups + fitted-model quality.

Pins three things into ``BENCH_calibrate.json``:

1. **Kernel speedups** — the current kernel wall times (via
   ``kernels_bench.bench_kernels``, median-of-reps) against the pinned
   pre-optimization timings (the ``PRE_OPT_US`` table below, recorded
   on this container before the fused-GQA / batched-GEMV /
   batched-SSM-scan / rectangular-block work landed).
2. **Fit quality** — a full ``kind='calibrate'`` study (default shape
   grid): fitted-model median relative error on held-out shapes next
   to the uncalibrated nominal-constants error.
3. **Artifact round-trip** — the fitted ``CalibratedBandwidth`` is
   saved to JSON, reloaded, fed to a ``kind='roofline'`` study via
   ``bandwidth=``, and the artifact of that study is required to be
   *bit-identical* to the same study run with the in-memory object.

Run:  PYTHONPATH=src python -m benchmarks.calibrate_bench [--smoke]
(``--smoke``: smoke-preset grid + single-rep kernel rows, same checks,
separate ``BENCH_calibrate_smoke.json`` — the CI step.)

All wall times are CPU numbers for this container; the harness
calibrates whatever backend it runs on.
"""

from __future__ import annotations

import argparse
import json
import pathlib

import jax

from repro.core.calibrate import CalibrateSpec
from repro.core.study import (
    AnalysisSpec,
    CalibratedBandwidth,
    Study,
    WorkloadSpec,
)

from .kernels_bench import bench_kernels

HERE = pathlib.Path(__file__).resolve().parent

#: median us per kernel row *before* this round of optimizations
#: (same shapes/reps as ``kernels_bench``, same container class).
PRE_OPT_US = {
    "kernels/dos_matmul_512x2048x512_bf16": 10055.46,
    "kernels/flash_chunked_1k_gqa": 39038.31,
    "kernels/flash_chunked_1k_bwd": 110089.68,
    "kernels/ssd_scan_1k_8h": 23307.15,
    "kernels/decode_attn_b8_4k_cache": 64082.75,
    "kernels/systolic_sim_16x96x16_l4": 356529.02,
}


def bench_speedups(reps: int = 3) -> list[dict]:
    rows = []
    for name, us, note, spread in bench_kernels(reps=reps):
        pre = PRE_OPT_US.get(name)
        rows.append({
            "name": name,
            "us": us,
            "spread_us": spread,
            "pre_opt_us": pre,
            "speedup_vs_pre_opt": (pre / us) if pre else None,
            "note": note,
        })
    return rows


def bench_calibration(smoke: bool) -> dict:
    spec = (
        CalibrateSpec(preset="smoke", reps=2, warmup=1)
        if smoke
        else CalibrateSpec(preset="default", reps=5, warmup=2)
    )
    study = Study(
        name="bench-calibrate",
        workload=WorkloadSpec(kind="gemms", gemms=((64, 64, 64),)),
        analysis=AnalysisSpec(kind="calibrate", calibrate=spec),
    )
    result = study.run()
    p = result.payload
    return {
        "preset": spec.preset,
        "errors": p["errors"],
        "dram_gbs_fitted": p["dram_gbs_fitted"],
        "efficiency": p["efficiency"],
        "artifact": p["artifact"].to_dict(),
    }


def bench_artifact_roundtrip(artifact_dict: dict) -> bool:
    """Reload the artifact from its JSON form, run the same roofline
    study with the reloaded and the original bandwidth, and require
    bit-identical result JSON."""
    art = CalibratedBandwidth.from_dict(json.loads(json.dumps(artifact_dict)))
    workload = WorkloadSpec(kind="gemms",
                            gemms=((64, 12100, 147), (512, 784, 128)))

    def roof(bw):
        return Study(
            name="bench-calibrate-roofline",
            workload=workload,
            analysis=AnalysisSpec(kind="roofline", bandwidth=bw),
        ).run().to_json()

    return roof(art) == roof(artifact_dict)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="smoke grid + single-rep kernel rows — the CI step")
    args = ap.parse_args()

    kernels = bench_speedups(reps=1 if args.smoke else 3)
    cal = bench_calibration(args.smoke)
    identical = bench_artifact_roundtrip(cal["artifact"])
    fast_rows = [
        r["name"] for r in kernels
        if r["speedup_vs_pre_opt"] and r["speedup_vs_pre_opt"] >= 1.3
    ]
    out = {
        "smoke": args.smoke,
        "backend": jax.default_backend(),
        "kernels": kernels,
        "n_rows_speedup_ge_1p3": len(fast_rows),
        "rows_speedup_ge_1p3": fast_rows,
        "calibration": cal,
        "artifact_roundtrip_bit_identical": identical,
    }
    name = "BENCH_calibrate_smoke.json" if args.smoke else "BENCH_calibrate.json"
    (HERE / name).write_text(json.dumps(out, indent=1))
    print(json.dumps(out, indent=1))
    for r in kernels:
        s = (f"{r['speedup_vs_pre_opt']:.2f}x" if r["speedup_vs_pre_opt"]
             else "  -  ")
        print(f"{r['name']:<45} {r['us']:>12.1f} us  {s:>7} vs pre-opt")
    e = cal["errors"]
    print(
        f"fit: holdout err {e['holdout_median_rel_err']:.1%} "
        f"(uncalibrated {e['uncalibrated_holdout_median_rel_err']:.1%}); "
        f"roundtrip bit-identical: {identical}"
    )


ALL = [bench_speedups, bench_calibration]


if __name__ == "__main__":
    main()
