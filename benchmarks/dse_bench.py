"""Benchmark the batched DSE engine against the legacy per-point loop.

Times the full Fig-7-style sweep — N random workloads x 3 MAC budgets x
16 tier counts, each point requiring a full (R, C) shape search — two
ways:

  - legacy: the pre-engine per-point Python loop (scalar
    ``analytical.optimal_tiers`` per workload x budget), and
  - engine: one declarative Fig-7 Study (``core.dse.fig7_study``) whose
    ``run()`` is a single ``optimal_tiers_batched`` engine call
    (optionally with the jitted JAX search backend).

Asserts both agree exactly, prints the speedup, and writes
``BENCH_dse.json`` next to this file.

Run:  PYTHONPATH=src python -m benchmarks.dse_bench [--n 300] [--jax]
"""

from __future__ import annotations

import argparse
import json
import pathlib
import time

import numpy as np

from repro.core.analytical import optimal_tiers
from repro.core.dse import fig7_study, random_workloads
from repro.core.engine import optimal_tiers_batched

HERE = pathlib.Path(__file__).resolve().parent
BUDGETS = (2**14, 2**16, 2**18)
MAX_TIERS = 16


def run(n_workloads: int = 300, seed: int = 0, jax_backend: bool = False):
    wl = random_workloads(n_workloads, seed)

    t0 = time.perf_counter()
    legacy = np.array(
        [
            [optimal_tiers(m, k, n, b, MAX_TIERS)[0] for b in BUDGETS]
            for m, k, n in wl
        ]
    )
    legacy_s = time.perf_counter() - t0

    backends = ["numpy"] + (["jax"] if jax_backend else [])
    out = {
        "sweep": f"{n_workloads} workloads x {len(BUDGETS)} budgets x {MAX_TIERS} tiers",
        "points": n_workloads * len(BUDGETS) * MAX_TIERS,
        "legacy_s": legacy_s,
    }
    for backend in backends:
        if backend == "jax":  # warm the jit cache outside the timed region
            optimal_tiers_batched(wl[:8], BUDGETS, MAX_TIERS, backend="jax")
        study = fig7_study(BUDGETS, n_workloads, seed, MAX_TIERS, backend=backend)
        t0 = time.perf_counter()
        res = study.run()
        dt = time.perf_counter() - t0
        best = np.asarray(res.payload["optimal_tiers"], dtype=np.int64)
        assert np.array_equal(best, legacy), "engine disagrees with legacy loop"
        out[f"engine_{backend}_s"] = dt
        out[f"speedup_{backend}"] = legacy_s / dt
    out["match"] = True
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=300, help="number of workloads")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--jax", action="store_true", help="also time the JAX backend")
    ap.add_argument("--smoke", action="store_true",
                    help="small sweep (40 workloads) — the CI smoke step")
    args = ap.parse_args()
    out = run(40 if args.smoke else args.n, args.seed, args.jax)
    # smoke runs get their own artifact so the canonical full-sweep
    # numbers (committed + uploaded by CI) are never clobbered
    name = "BENCH_dse_smoke.json" if args.smoke else "BENCH_dse.json"
    (HERE / name).write_text(json.dumps(out, indent=1))
    print(json.dumps(out, indent=1))
    for k in out:
        if k.startswith("speedup"):
            print(f"{k}: {out[k]:.1f}x  (target >= 10x)")


if __name__ == "__main__":
    main()
