"""Tier-folded scheduling benchmark: per-layer vs fixed vs tier_fold.

Pins the fine-grain 3D-mapping story of the ``tier_fold`` policy (the
ISSUE-10 acceptance artifact): every decode-shaped zoo network is
scheduled three ways over the same budget-matched design grid under the
paper-default memory system —

1. ``per_layer``: each layer picks its own (R, C, L) — the upper bound
   that needs per-layer reconfiguration;
2. ``fixed``: one array, whole layers mapped natively — the paper's
   baseline;
3. ``tier_fold``: the same fixed array, but each layer may fold its
   M / K / N extent across the stack's tiers, with the fold-created
   traffic (partial-sum planes, operand multicast) priced on the
   vertical links.

The headline row asserts the acceptance criterion: on at least one
mainstream workload (smollm-135m decode) tier_fold beats the
fixed-array policy by >= 1.1x total cycles. Fold-type residency
(cycle-weighted share of k/m/n folds) is reported per network.

Writes ``BENCH_fold.json`` (or ``BENCH_fold_smoke.json`` with
``--smoke``, the CI-sized run) next to this file.

Run:  PYTHONPATH=src python -m benchmarks.fold_bench [--smoke]
"""

from __future__ import annotations

import argparse
import json
import pathlib
import time

from repro.core.bandwidth import BandwidthSpec
from repro.core.engine import schedule
from repro.core.network import lower_zoo

HERE = pathlib.Path(__file__).resolve().parent

HEADLINE_ARCH = "smollm-135m"
SMOKE_ARCHS = ("smollm-135m", "gemma3-1b", "whisper-medium")
POLICIES = ("per_layer", "fixed", "tier_fold")


def run(smoke: bool = False):
    bw = BandwidthSpec.paper_default()
    streams = lower_zoo(shapes=("decode_32k",))
    if smoke:
        streams = [s for s in streams if s.arch in SMOKE_ARCHS]

    rows = []
    t0 = time.perf_counter()
    for stream in streams:
        rep = schedule(stream, mac_budgets=(2**14,), tiers=range(1, 9),
                       bandwidth=bw, policies=POLICIES)
        fx, tf, pl = rep.fixed, rep.tier_fold, rep.per_layer
        rows.append({
            "arch": stream.arch,
            "shape": stream.shape,
            "layers": len(stream.layer_names),
            "cycles": {"per_layer": pl.total_cycles,
                       "fixed": fx.total_cycles,
                       "tier_fold": tf.total_cycles},
            "tier_fold_vs_fixed": fx.total_cycles / tf.total_cycles,
            "per_layer_vs_fixed": fx.total_cycles / pl.total_cycles,
            "fold_residency": rep.fold["residency"],
            "design": list(int(x) for x in tf.design),
        })
    wall_s = time.perf_counter() - t0

    by_arch = {r["arch"]: r for r in rows}
    head = by_arch[HEADLINE_ARCH]
    assert head["tier_fold_vs_fixed"] >= 1.1, (
        f"acceptance: tier_fold must beat fixed by >=1.1x on "
        f"{HEADLINE_ARCH}, got {head['tier_fold_vs_fixed']:.3f}x")
    # tier_fold can never lose to fixed (native mapping is a candidate)
    for r in rows:
        assert r["tier_fold_vs_fixed"] >= 1.0, r["arch"]

    return {
        "sweep": f"{len(rows)} decode_32k networks x budget 2^14 x "
                 f"tiers 1..8, paper-default memory",
        "bandwidth": bw.to_dict(),
        "wall_s": wall_s,
        "headline": {
            "arch": HEADLINE_ARCH,
            "tier_fold_vs_fixed": head["tier_fold_vs_fixed"],
            "fold_residency": head["fold_residency"],
        },
        "networks": rows,
    }


def bench_fold():
    """benchmarks.run entry: one summary row per policy comparison."""
    out = run(smoke=True)
    h = out["headline"]
    return [
        ("fold/tier_fold_vs_fixed", out["wall_s"] * 1e6,
         f"{h['arch']}: {h['tier_fold_vs_fixed']:.2f}x; "
         f"residency {h['fold_residency']}"),
    ]


ALL = [bench_fold]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="3-network sweep — the CI smoke step")
    args = ap.parse_args()
    out = run(smoke=args.smoke)
    name = "BENCH_fold_smoke.json" if args.smoke else "BENCH_fold.json"
    (HERE / name).write_text(json.dumps(out, indent=1))
    print(json.dumps(out["headline"], indent=1))
    gains = ", ".join(f"{r['arch']} {r['tier_fold_vs_fixed']:.2f}x"
                      for r in out["networks"])
    print(f"tier_fold vs fixed: {gains}")


if __name__ == "__main__":
    main()
