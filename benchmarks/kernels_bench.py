"""Kernel microbenchmarks (CPU jnp paths; Pallas validated separately).

Times the layer-facing ops that the models hot-path through, plus the
cycle-level systolic simulator. Wall times here are CPU numbers — the
TPU story lives in the roofline benchmark — but they track relative
regressions and prove the ops run.

Run:  PYTHONPATH=src python -m benchmarks.kernels_bench [--smoke]
writes ``BENCH_kernels.json`` (``BENCH_kernels_smoke.json`` with
``--smoke``: single-rep timings, same ops) next to this file.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.systolic import simulate_dos_3d
from repro.kernels.dos_matmul import dos_matmul
from repro.kernels.flash_attention import decode_attention
from repro.kernels.flash_attention.ops import flash_attention_jnp
from repro.kernels.ssm_scan import ssm_scan

HERE = pathlib.Path(__file__).resolve().parent


def _timeit(fn, *args, reps=3, warmup=2):
    """Median-of-reps wall time in us, plus dispersion (max - min).

    Each rep is individually timed after ``warmup`` untimed calls; the
    median is robust to the scheduler hiccups that a mean-of-3 on a
    1-CPU CI box folds straight into the pin.
    """
    for _ in range(max(warmup, 1)):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts)), float(max(ts) - min(ts))


def bench_kernels(reps: int = 3):
    rng = np.random.default_rng(0)
    rows = []

    a = jnp.asarray(rng.normal(size=(512, 2048)), jnp.bfloat16)
    b = jnp.asarray(rng.normal(size=(2048, 512)), jnp.bfloat16)
    f = jax.jit(lambda a, b: dos_matmul(a, b))
    us, spread = _timeit(f, a, b, reps=reps)
    gf = 2 * 512 * 2048 * 512 / (us / 1e6) / 1e9
    rows.append(("kernels/dos_matmul_512x2048x512_bf16", us, f"{gf:.1f} GFLOP/s cpu", spread))

    q = jnp.asarray(rng.normal(size=(1, 1024, 8, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 1024, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 1024, 2, 64)), jnp.float32)
    f = jax.jit(lambda q, k, v: flash_attention_jnp(q, k, v, causal=True))
    us, spread = _timeit(f, q, k, v, reps=reps)
    rows.append(("kernels/flash_chunked_1k_gqa", us, "fwd, fused GQA", spread))

    f = jax.jit(jax.grad(lambda q, k, v: jnp.sum(flash_attention_jnp(q, k, v) ** 2)))
    us, spread = _timeit(f, q, k, v, reps=reps)
    rows.append(("kernels/flash_chunked_1k_bwd", us, "custom-vjp", spread))

    u = jnp.asarray(rng.normal(size=(2, 1024, 8, 64)), jnp.float32)
    ld = jnp.asarray(-rng.uniform(0.01, 0.2, size=(2, 1024, 8)), jnp.float32)
    B = jnp.asarray(rng.normal(size=(2, 1024, 8, 64)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(2, 1024, 8, 64)), jnp.float32)
    f = jax.jit(lambda *x: ssm_scan(*x)[0])
    us, spread = _timeit(f, u, ld, B, C, reps=reps)
    rows.append(("kernels/ssd_scan_1k_8h", us, "chunk=auto (32 on cpu)", spread))

    qd = jnp.asarray(rng.normal(size=(8, 1, 16, 64)), jnp.float32)
    kc = jnp.asarray(rng.normal(size=(8, 4096, 4, 64)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(8, 4096, 4, 64)), jnp.float32)
    f = jax.jit(lambda q, k, v: decode_attention(q, k, v, length=4000))
    us, spread = _timeit(f, qd, kc, vc, reps=reps)
    rows.append(("kernels/decode_attn_b8_4k_cache", us, "batched-GEMV path", spread))

    A = jnp.asarray(rng.normal(size=(16, 96)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(96, 16)), jnp.float32)
    # cold time on purpose: this row tracks trace+compile+run of the
    # cycle simulator, which is how Study sweeps hit it (once per shape).
    t0 = time.perf_counter()
    r = simulate_dos_3d(A, Bm, 8, 8, 4)
    us = (time.perf_counter() - t0) * 1e6
    rows.append(("kernels/systolic_sim_16x96x16_l4", us, f"{r.cycles} cycles (cold)", 0.0))
    return rows


ALL = [bench_kernels]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="single-rep timings — the CI smoke step")
    args = ap.parse_args()
    rows = bench_kernels(reps=1 if args.smoke else 3)
    out = {
        "smoke": args.smoke,
        "backend": jax.default_backend(),
        "rows": [
            {"name": n, "us": us, "note": note, "spread_us": spread}
            for n, us, note, spread in rows
        ],
    }
    name = "BENCH_kernels_smoke.json" if args.smoke else "BENCH_kernels.json"
    (HERE / name).write_text(json.dumps(out, indent=1))
    print(json.dumps(out, indent=1))
    for n, us, note, spread in rows:
        print(f"{n:<45} {us:>12.1f} us (±{spread:.0f})  {note}")


if __name__ == "__main__":
    main()
