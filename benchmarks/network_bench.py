"""Benchmark the network-level mapping path (zoo -> lowering -> schedule).

Lowers every live (arch, shape) cell of the model zoo to its GEMM
stream and schedules it end-to-end — each cell is one declarative
``schedule`` Study (``core.study``) compiled into
``core.engine.schedule`` — timing the lowering and the scheduling
separately. Sanity checks ride along: every stream is non-empty, every
report is finite, and the fixed-design policy is never faster than
per-layer-optimal.

Writes ``BENCH_network.json`` next to this file.

Run:  PYTHONPATH=src python -m benchmarks.network_bench [--smoke] [--jax]
``--smoke`` runs a 2-arch x 2-shape subset on a reduced grid — the CI
regression-visibility step.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import time

import numpy as np

from repro.core.network import lower_zoo
from repro.core.study import AnalysisSpec, SpaceSpec, Study, WorkloadSpec

HERE = pathlib.Path(__file__).resolve().parent

SMOKE_ARCHS = ("smollm-135m", "deepseek-moe-16b")
SMOKE_SHAPES = ("train_4k", "decode_32k")


def run(smoke: bool = False, backend: str = "numpy"):
    from repro.configs import cells as zoo_cells

    space = SpaceSpec()
    archs = shapes = None
    if smoke:
        archs, shapes = set(SMOKE_ARCHS), set(SMOKE_SHAPES)
        space = SpaceSpec(mac_budgets=(2**14, 2**16), tiers=tuple(range(1, 9)))
    # lowering-only timing (the Study runs below re-lower their own
    # cell as part of workload resolution; that cost — ~0.5 ms/cell vs
    # ~0.7 s of scheduling — rides inside schedule_s)
    t0 = time.perf_counter()
    lower_zoo(shapes=shapes, archs=archs)
    lower_s = time.perf_counter() - t0

    live, _ = zoo_cells()
    cells = []
    t0 = time.perf_counter()
    for arch, shape in live:
        if archs is not None and arch not in archs:
            continue
        if shapes is not None and shape not in shapes:
            continue
        rep = Study(
            workload=WorkloadSpec(kind="network", arch=arch, shape=shape),
            space=space,
            analysis=AnalysisSpec(kind="schedule", backend=backend),
        ).run().report
        pl, fx = rep.per_layer, rep.fixed
        assert rep.n_gemms > 0, (arch, shape)
        assert np.isfinite(pl.total_cycles) and np.isfinite(fx.total_cycles), (
            arch, shape)
        assert fx.total_cycles >= pl.total_cycles, (arch, shape)
        cells.append({
            "arch": rep.arch, "shape": rep.shape, "mode": rep.mode,
            "n_gemms": rep.n_gemms,
            "n_gemm_invocations": rep.n_gemm_invocations,
            "total_macs": rep.total_macs,
            "per_layer_cycles": pl.total_cycles,
            "fixed_cycles": fx.total_cycles,
            "fixed_over_opt": fx.total_cycles / pl.total_cycles,
            "fixed_speedup_vs_2d": fx.speedup_vs_2d,
            "fixed_energy_j": fx.energy_j,
            "fixed_edp_js": fx.edp_js,
            "fixed_t_max_c": fx.t_max_c,
            "fixed_design_rcl": [int(x) for x in np.asarray(fx.design)],
            "n_candidates": rep.n_candidates,
            "n_thermally_masked": rep.n_thermally_masked,
        })
    sched_s = time.perf_counter() - t0

    points = sum(c["n_gemms"] * c["n_candidates"] for c in cells)
    return {
        "smoke": smoke,
        "backend": backend,
        "n_cells": len(cells),
        "design_points_evaluated": points,
        "lower_s": lower_s,
        "schedule_s": sched_s,
        "points_per_s": points / sched_s if sched_s else float("nan"),
        "all_fixed_ge_per_layer": True,
        "cells": cells,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small subset + reduced grid (CI smoke step)")
    ap.add_argument("--jax", action="store_true",
                    help="use the jitted JAX search backend")
    args = ap.parse_args()
    t0 = time.perf_counter()
    out = run(smoke=args.smoke, backend="jax" if args.jax else "numpy")
    out["total_s"] = time.perf_counter() - t0
    # smoke runs get their own artifact so the canonical full-sweep
    # numbers (committed + uploaded by CI) are never clobbered
    name = "BENCH_network_smoke.json" if args.smoke else "BENCH_network.json"
    (HERE / name).write_text(json.dumps(out, indent=1))
    print(json.dumps({k: v for k, v in out.items() if k != "cells"}, indent=1))
    worst = max(out["cells"], key=lambda c: c["fixed_over_opt"])
    print(f"worst fixed/per-layer gap: {worst['fixed_over_opt']:.3f}x "
          f"({worst['arch']}/{worst['shape']})")


if __name__ == "__main__":
    main()
