"""Paper-figure reproductions (Figs. 5-9, Tables I-II) as benchmarks.

Each ``bench_*`` returns (name, us_per_call, derived) rows where
``derived`` is the reproduced headline number next to the paper's claim.
The DSE figures (5-7, Table I) route through the batched evaluation
engine — each is a single ``DesignGrid`` evaluation.
"""

from __future__ import annotations

import time
import warnings

import numpy as np

from repro.core.analytical import tau_2d, tau_3d
from repro.core.dse import PAPER_WORKLOADS, fig5_sweep, fig6_sweep, fig7_scatter
from repro.core.engine import DesignGrid, evaluate, optimal_tiers_batched
from repro.core.ppa import (
    area_normalized_speedup, array_power, table2_setup, thermal_report,
)


def _timed(fn, reps=1):
    t0 = time.perf_counter()
    # These benchmarks deliberately reproduce the figures through the
    # historical call-style entry points (now Study-backed shims).
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        for _ in range(reps):
            out = fn()
    us = (time.perf_counter() - t0) / reps * 1e6
    return out, us


def bench_fig5():
    """Speedup vs tier count / MAC budget / K. Paper: up to 9.16x at 12
    tiers, 1.93x at 2 tiers (K=12100, 2^18 MACs); losses for small K."""
    (tiers, out), us = _timed(lambda: fig5_sweep())
    s12 = out[(2**18, 12100)][tiers.index(12)]
    s2 = out[(2**18, 12100)][tiers.index(2)]
    worst = out[(2**12, 255)][tiers.index(12)]
    rows = [
        ("fig5/speedup_12tier_2^18_K12100", us, f"{s12:.2f}x (paper 9.16x)"),
        ("fig5/speedup_2tier", us, f"{s2:.2f}x (paper 1.93x)"),
        ("fig5/small_K_loss", us, f"{(1-worst)*100:.0f}% loss (paper 51%)"),
    ]
    return rows


def bench_fig6():
    """Speedup vs MAC budget at 4 tiers; threshold N_min = M*N."""
    (budgets, out, thr), us = _timed(lambda: fig6_sweep())
    best = max(max(v) for v in out.values())
    # below N_min = M*N no meaningful 3D speedup should exist (paper's
    # empirical threshold; our optimizer finds marginal ~1.0x points)
    below = [
        s
        for (n_dim, k), curve in out.items()
        for b, s in zip(budgets, curve)
        if b < thr[n_dim]
    ]
    return [
        ("fig6/max_speedup_4tier", us, f"{best:.2f}x (paper 3.13x)"),
        ("fig6/max_speedup_below_Nmin", us,
         f"{max(below):.2f}x (~1 => threshold holds)"),
    ]


def bench_fig7():
    """Optimal-tier scatter over 300 random workloads x 3 MAC budgets;
    the median optimal tier count shifts right with budget."""
    res, us = _timed(lambda: fig7_scatter())
    medians = [r.median for r in res]
    shift = medians[-1] >= medians[0]
    return [
        ("fig7/median_optimal_tiers", us,
         "/".join(f"{m:.0f}" for m in medians) + f" (rightshift={shift})"),
    ]


def bench_tab1():
    """Table I workloads: 3D-vs-2D speedup at 2^16 MACs, best tier<=16 —
    one batched tier search plus one 2D-baseline evaluation."""
    t0 = time.perf_counter()
    wl = list(PAPER_WORKLOADS.values())
    best, best_cycles = optimal_tiers_batched(wl, [2**16])
    base = evaluate(DesignGrid.product(wl, [2**16], [1]), metrics=("perf",))
    speedup = base.cycles[:, 0] / best_cycles[:, 0]
    rows = [
        (f"tab1/{name}", 0.0,
         f"l*={int(best[i, 0])} speedup={speedup[i]:.2f}x")
        for i, name in enumerate(PAPER_WORKLOADS)
    ]
    us = (time.perf_counter() - t0) / len(rows) * 1e6
    return [(n, us, d) for n, _, d in rows]


def bench_tab2():
    """Power: 2D 6.61W / 3D-TSV 6.39W / 3D-MIV 6.26W (+peaks)."""
    paper = {"2d": (6.61, 14.99), "tsv": (6.39, 14.41), "miv": (6.26, 14.14)}
    rows = []
    for name, kw in table2_setup().items():
        r, us = _timed(lambda kw=kw: array_power(**kw))
        pt, pp = paper[name]
        rows.append(
            (f"tab2/power_{name}", us,
             f"{r.total_w:.2f}W/{r.peak_w:.2f}W (paper {pt}/{pp})")
        )
    return rows


def bench_fig8():
    """Thermal: 2D < 3D-TSV < 3D-MIV, all under the 105C budget."""
    rows = []
    for macs in (4096, 16384, 65536):
        out, us = _timed(
            lambda m=macs: (
                thermal_report(m, 1, "2d"),
                thermal_report(m, 3, "tsv"),
                thermal_report(m, 3, "miv"),
            )
        )
        t2, tt, tm = out
        rows.append(
            (f"fig8/thermal_{macs}mac", us,
             f"2d={t2.t_max_c:.0f}C tsv={tt.t_max_c:.0f}C miv={tm.t_max_c:.0f}C "
             f"budget_ok={all(r.within_budget for r in out)}")
        )
    return rows


def bench_fig9():
    """Area-normalized performance. Paper: 2-tier 1.19-1.97x; >=4 tiers
    at 2^18 MACs 1.27-2.83x (TSV) / up to 7.9x (MIV); TSV loses at 4096."""
    rows = []
    t0 = time.perf_counter()
    t2 = area_normalized_speedup(64, 12100, 147, 2**18, 2, "tsv")
    m2 = area_normalized_speedup(64, 12100, 147, 2**18, 2, "miv")
    t8 = area_normalized_speedup(64, 12100, 147, 2**18, 8, "tsv")
    m12 = area_normalized_speedup(64, 12100, 147, 2**18, 12, "miv")
    small = area_normalized_speedup(64, 12100, 147, 4096, 4, "tsv")
    us = (time.perf_counter() - t0) / 5 * 1e6
    rows.append(("fig9/2tier_band", us, f"tsv={t2:.2f} miv={m2:.2f} (paper 1.19-1.97)"))
    rows.append(("fig9/8tier_tsv", us, f"{t8:.2f}x (paper band 1.27-2.83)"))
    rows.append(("fig9/12tier_miv", us, f"{m12:.2f}x (paper up to 7.9x)"))
    rows.append(("fig9/4096mac_tsv_loses", us, f"{small:.2f}x (<1: paper 'up to 75% worse')"))
    return rows


def bench_eqs():
    """Eq. 1/2 evaluation latency (vectorized over 1e5 workloads)."""
    M = np.random.default_rng(0).integers(1, 1024, size=100_000)
    _, us = _timed(lambda: tau_3d(M, 4096, 512, 32, 32, 4))
    return [("eqs/tau3d_vectorized_100k", us, "cycles/UDF-free")]


ALL = [bench_eqs, bench_fig5, bench_fig6, bench_fig7, bench_tab1, bench_tab2,
       bench_fig8, bench_fig9]
