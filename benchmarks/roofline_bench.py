"""Benchmark the engine-backed bandwidth/roofline model (§Roofline).

Runs one declarative ``roofline`` Study — N random Fig-7-style
workloads x 3 MAC budgets x 16 tier counts under
``BandwidthSpec.paper_default()`` — and checks it against two
independent references:

  - scalar identity: for a sample of design points, the batched
    ``gemm_traffic_batched`` + ``roofline_cycles`` pipeline is
    recomputed point-by-point (batch of one) and must agree exactly;
  - uncapped identity: the same study with an unbounded spec must be
    bit-for-bit equal to the plain compute-bound ``evaluate`` — the
    contract that keeps every pre-bandwidth result valid.

Prints the points/s throughput and bound histogram, and writes
``BENCH_roofline.json`` next to this file. The TPU dry-run artifact
table this benchmark used to print now lives in
``experiments/make_report.py`` (``python -m repro report``).

Run:  PYTHONPATH=src python -m benchmarks.roofline_bench [--n 300] [--smoke]
"""

from __future__ import annotations

import argparse
import json
import pathlib
import time

import numpy as np

from repro.core.bandwidth import BandwidthSpec, gemm_traffic_batched, roofline_cycles
from repro.core.dse import random_workloads
from repro.core.engine import DesignGrid, evaluate
from repro.core.study import AnalysisSpec, SpaceSpec, Study, WorkloadSpec

HERE = pathlib.Path(__file__).resolve().parent
BUDGETS = (2**14, 2**16, 2**18)
MAX_TIERS = 16


def _scalar_check(res, grid, spec: BandwidthSpec, n_sample: int = 64) -> None:
    """Recompute a sample of points one at a time; must match exactly."""
    rng = np.random.default_rng(0)
    W, P = res.valid.shape
    for _ in range(n_sample):
        w, p = int(rng.integers(W)), int(rng.integers(P))
        if not res.valid[w, p]:
            continue
        M, K, N = (int(x) for x in grid.workloads[w])
        tr = gemm_traffic_batched(
            "dos", [M], [K], [N], [int(res.rows[w, p])], [int(res.cols[w, p])],
            [int(grid.tiers[p])], np.asarray(["tsv"]), spec,
        )
        assert tr["dram_bytes"][0] == res.dram_bytes[w, p], (w, p)
        compute = res.cycles[w, p] - res.stall_cycles[w, p]
        total, stall, _ = roofline_cycles(
            [compute], tr["dram_bytes"] / spec.dram_bytes_per_cycle,
            tr["vlink_cycles"],
        )
        assert total[0] == res.cycles[w, p], (w, p)
        assert stall[0] == res.stall_cycles[w, p], (w, p)


def vlink_scenario():
    """A sweep where the vertical-link bound actually binds.

    The headline sweep's Fig-7-style workloads have K large enough that
    every fold carries ~``ceil(K/L)`` compute cycles against ~15 cycles
    of shared-TSV partial-sum drain, so ``bound_counts.vlink`` stays 0
    there. Short-contraction (decode-like) GEMMs under tiny MAC budgets
    at high tier counts flip that: the array comes out narrow, each
    fold carries just a few MAC cycles, and the shared TSV bus drains
    partial sums slower than the pile makes them. This study pins that
    regime — the row asserts ``vlink > 0``.
    """
    study = Study(
        name="roofline-bench-vlink",
        workload=WorkloadSpec(
            kind="gemms",
            gemms=((64, 8, 64), (128, 16, 128), (256, 32, 256)),
        ),
        space=SpaceSpec(
            mac_budgets=(64, 256),
            tiers=(8, 16),
            dataflow=("dos",),
            tech=("tsv",),
        ),
        analysis=AnalysisSpec(kind="roofline", bandwidth=BandwidthSpec.paper_default()),
    )
    out = study.run()
    counts = out.payload["bound_counts"]
    assert counts["vlink"] > 0, f"vlink never binds: {counts}"
    return {
        "sweep": "3 short-K gemms x budgets (64,256) x tiers (8,16), dos/tsv",
        "points": int(np.sum(out.result.valid)),
        "bound_counts": counts,
        "stall_frac": out.payload["stall_frac"],
    }


def fold_scenario():
    """The vlink technology decides the best intra-layer fold.

    ``(M, K, N) = (12, 7000, 12)`` on a 4x4 array across 3 tiers: the
    contraction is deep but the array is tiny, so folding the output
    rows (fold-m) saves ~0.4% of compute cycles over the native fold-K
    — but only if the L-1 partial-sum planes it creates drain fast
    enough. MIVs (17 bits/MAC) swallow them; the shared TSV bus
    (17/16 bits/MAC) turns the same mapping vlink-bound at ~1.9x the
    cycles. One workload, one array — two technologies, two best
    folds. The row asserts the flip so the regression is pinned here
    as well as in ``tests/test_bandwidth.py``.
    """
    from repro.core.pricing import price_steps

    spec = BandwidthSpec.paper_default()
    out = {}
    for tech in ("tsv", "miv"):
        cyc = {}
        for fold in (None, "m"):
            pr = price_steps(
                "os", np.array([12]), np.array([7000]), np.array([12]),
                np.array([4]), np.array([4]), np.array([3]),
                np.array([tech]), spec, fold=fold,
            )
            cyc["native_k" if fold is None else "fold_m"] = float(
                pr["total_cycles"][0])
        out[tech] = cyc
    assert out["miv"]["fold_m"] < out["miv"]["native_k"], out
    assert out["tsv"]["fold_m"] > out["tsv"]["native_k"], out
    return {
        "workload": [12, 7000, 12],
        "design": "os, 4x4 array, 3 tiers, paper-default memory",
        "cycles": out,
        "flip": "miv -> fold_m wins; tsv -> native fold-k wins",
    }


def run(n_workloads: int = 300, seed: int = 0):
    spec = BandwidthSpec.paper_default()
    study = Study(
        name=f"roofline-bench-{n_workloads}",
        workload=WorkloadSpec(kind="random", n=n_workloads, seed=seed),
        space=SpaceSpec(mac_budgets=BUDGETS, tiers=tuple(range(1, MAX_TIERS + 1))),
        analysis=AnalysisSpec(kind="roofline", bandwidth=spec),
    )
    t0 = time.perf_counter()
    out_study = study.run()
    bw_s = time.perf_counter() - t0
    res = out_study.result
    grid = res.grid

    _scalar_check(res, grid, spec)

    # Uncapped bit-identity vs the plain compute-bound evaluate.
    wl = random_workloads(n_workloads, seed)
    plain = evaluate(DesignGrid.product(wl, BUDGETS, range(1, MAX_TIERS + 1)))
    unb = evaluate(
        DesignGrid.product(wl, BUDGETS, range(1, MAX_TIERS + 1)),
        bandwidth=BandwidthSpec(),
    )
    assert np.array_equal(plain.cycles, unb.cycles)
    assert np.array_equal(plain.speedup, unb.speedup, equal_nan=True)
    assert float(np.nansum(unb.stall_cycles)) == 0.0

    points = n_workloads * len(BUDGETS) * MAX_TIERS
    return {
        "sweep": f"{n_workloads} workloads x {len(BUDGETS)} budgets x {MAX_TIERS} tiers",
        "points": points,
        "bandwidth": spec.to_dict(),
        "roofline_s": bw_s,
        "points_per_s": points / bw_s,
        "bound_counts": out_study.payload["bound_counts"],
        "stall_frac": out_study.payload["stall_frac"],
        "speedup_max_compute": float(np.nanmax(plain.speedup)),
        "speedup_max_bw": float(np.nanmax(res.speedup)),
        "scalar_match": True,
        "uncapped_identity": True,
        "vlink_scenario": vlink_scenario(),
        "fold_scenario": fold_scenario(),
    }


def bench_roofline():
    """benchmarks.run entry: small engine-backed roofline summary rows."""
    out = run(40)
    us = out["roofline_s"] * 1e6
    vl = out["vlink_scenario"]
    return [
        ("roofline/engine_sweep", us,
         f"{out['points']} pts; bounds {out['bound_counts']}; "
         f"stall {out['stall_frac']:.2f}"),
        ("roofline/speedup_collapse", 0.0,
         f"compute-bound {out['speedup_max_compute']:.2f}x -> "
         f"bw-aware {out['speedup_max_bw']:.2f}x"),
        ("roofline/vlink_binds", 0.0,
         f"short-K dos/tsv: bounds {vl['bound_counts']}"),
        ("roofline/fold_flip", 0.0, out["fold_scenario"]["flip"]),
    ]


ALL = [bench_roofline]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=300, help="number of workloads")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="small sweep (40 workloads) — the CI smoke step")
    args = ap.parse_args()
    out = run(40 if args.smoke else args.n, args.seed)
    name = "BENCH_roofline_smoke.json" if args.smoke else "BENCH_roofline.json"
    (HERE / name).write_text(json.dumps(out, indent=1))
    print(json.dumps(out, indent=1))
    print(f"points/s: {out['points_per_s']:.0f}  "
          f"speedup collapse: {out['speedup_max_compute']:.2f}x -> "
          f"{out['speedup_max_bw']:.2f}x")


if __name__ == "__main__":
    main()
