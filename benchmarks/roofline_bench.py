"""Roofline table from the dry-run artifacts (§Roofline source of truth).

Reads experiments/dryrun/*.json (written by repro.launch.dryrun), emits
one row per (arch x shape) single-pod cell with the three terms, the
dominant bottleneck, MODEL_FLOPS/HLO_FLOPs and MFU — and writes the
markdown table EXPERIMENTS.md embeds.
"""

from __future__ import annotations

import json
import pathlib

ART_DIR = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "dryrun"
OUT_MD = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "roofline_table.md"


def load_artifacts(mesh="pod16x16", strategy=None):
    rows = []
    for p in sorted(ART_DIR.glob("*.json")):
        a = json.loads(p.read_text())
        if a.get("mesh") != mesh or "error" in a:
            continue
        if strategy and a.get("strategy") != strategy:
            continue
        rows.append(a)
    return rows


def table_rows(arts):
    out = []
    for a in arts:
        r = a["roofline"]
        out.append({
            "arch": a["arch"], "shape": a["shape"], "strategy": a["strategy"],
            "mem_gb": a["memory"]["peak_per_device_gb"],
            "compute_ms": r["compute_s"] * 1e3,
            "memory_ms": (r.get("memory_s_kernel") or r["memory_s"]) * 1e3,
            "hlo_memory_ms": r["memory_s"] * 1e3,
            "collective_ms": r["collective_s"] * 1e3,
            "dominant": r["dominant"],
            "step_ms": r["step_s"] * 1e3,
            "useful": r["useful_ratio"],
            "mfu": r["mfu"],
        })
    return out


def bench_roofline():
    arts = load_artifacts()
    if not arts:
        return [("roofline/no_artifacts", 0.0,
                 "run: python -m repro.launch.dryrun --both-meshes")]
    rows = table_rows(arts)
    md = [
        "| arch | shape | strat | GB/dev | compute ms | memory ms (kernel) | collective ms | dominant | step ms | MODEL/HLO | MFU |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    out = []
    for r in rows:
        md.append(
            f"| {r['arch']} | {r['shape']} | {r['strategy']} | {r['mem_gb']:.1f} "
            f"| {r['compute_ms']:.2f} | {r['memory_ms']:.2f} | {r['collective_ms']:.2f} "
            f"| {r['dominant']} | {r['step_ms']:.2f} | {r['useful']:.2f} | {r['mfu']*100:.1f}% |"
        )
        out.append((
            f"roofline/{r['arch']}/{r['shape']}/{r['strategy']}",
            r["step_ms"] * 1e3,
            f"{r['dominant']}-bound mfu={r['mfu']*100:.1f}%",
        ))
    OUT_MD.write_text("\n".join(md) + "\n")
    dom = {}
    for r in rows:
        dom[r["dominant"]] = dom.get(r["dominant"], 0) + 1
    out.append(("roofline/summary", 0.0,
                f"{len(rows)} cells; bottlenecks: {dom}; table -> {OUT_MD.name}"))
    return out


ALL = [bench_roofline]
