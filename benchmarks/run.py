"""Benchmark entry point: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Sections:
  - eqs/fig5/fig6/fig7/tab1: analytical model + DSE reproductions
  - tab2/fig8/fig9: PPA model reproductions
  - kernels/*: op microbenchmarks (CPU wall time)
  - roofline/*: the engine-backed bandwidth/roofline sweep
    (benchmarks.roofline_bench; the dry-run artifact table moved to
    ``python -m repro report``)
"""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from . import kernels_bench, paper_figs, roofline_bench

    benches = paper_figs.ALL + kernels_bench.ALL + roofline_bench.ALL
    print("name,us_per_call,derived")
    failures = 0
    for b in benches:
        try:
            for name, us, derived in b():
                print(f"{name},{us:.1f},{derived}")
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{b.__name__},0,ERROR {type(e).__name__}: {e}", file=sys.stderr)
            traceback.print_exc()
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
