"""Million-point sweep benchmark: throughput, bounded RSS, resumability.

Pins the production-scale story of the Study/engine stack on a
Fig-7-style sweep (random workloads x 3 MAC budgets x 16 tier counts,
every point a full (R, C) shape search):

1. **cold**: run the whole sweep chunk-cached into a fresh directory —
   reports wall time, points/s, and the process peak RSS (the streamed
   chunk execution keeps it bounded at any grid size);
2. **resume**: delete half the cached chunks and re-run via the same
   cache — asserts (via the artifact's hit/miss counters) that exactly
   the missing half is recomputed and that the stitched result is
   bit-for-bit identical to the cold run;
3. **warm**: run again fully cached — asserts zero recomputation.

Writes ``BENCH_scale.json`` (or ``BENCH_scale_smoke.json`` with
``--smoke``, the CI-sized run) next to this file.

Run:  PYTHONPATH=src python -m benchmarks.scale_bench [--points 1000000]
"""

from __future__ import annotations

import argparse
import json
import pathlib
import resource
import shutil
import sys
import tempfile
import time

import numpy as np

from repro.core.cache import ResultCache
from repro.core.dse import fig7_study

HERE = pathlib.Path(__file__).resolve().parent
BUDGETS = (2**14, 2**16, 2**18)
MAX_TIERS = 16
POINTS_PER_WORKLOAD = len(BUDGETS) * MAX_TIERS


def _peak_rss_mb() -> float:
    ru = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    # linux reports KiB, macOS bytes
    return ru / 1024.0 if sys.platform != "darwin" else ru / 2**20

def run(points: int, seed: int = 0, shard=None, keep_cache: str | None = None):
    n_workloads = max(1, points // POINTS_PER_WORKLOAD)
    # only the jax backend has a device axis — an explicit shard request
    # on the numpy default would error (and 'auto' would measure nothing)
    study = fig7_study(BUDGETS, n_workloads, seed, MAX_TIERS,
                       backend="jax" if shard else "numpy")
    if shard:
        import dataclasses

        study = dataclasses.replace(
            study, analysis=dataclasses.replace(study.analysis, shard=shard)
        )
    root = pathlib.Path(keep_cache) if keep_cache else pathlib.Path(
        tempfile.mkdtemp(prefix="repro-scale-")
    )
    out = {
        "sweep": f"{n_workloads} workloads x {len(BUDGETS)} budgets x {MAX_TIERS} tiers",
        "points": n_workloads * POINTS_PER_WORKLOAD,
    }
    # ~16 chunks at any sweep size, so the half-populated resume below
    # exercises real chunk granularity (same block size for every run:
    # chunk keys embed the exact index range).
    block_cells = max(POINTS_PER_WORKLOAD, out["points"] // 16)
    stale = ResultCache(root).study_dir(study) / "chunks"
    if stale.is_dir() and any(stale.iterdir()):
        raise SystemExit(
            f"error: {stale.parent} already holds chunks for this sweep — the "
            "benchmark measures a cold run; point --keep-cache at a fresh "
            "directory (or delete the old one)"
        )
    try:
        # 1. cold cached run
        cache = ResultCache(root, block_cells=block_cells)
        t0 = time.perf_counter()
        cold = study.run(cache=cache)
        out["cold_s"] = time.perf_counter() - t0
        out["points_per_s"] = out["points"] / out["cold_s"]
        out["chunks"] = cold.cache["misses"]
        assert cold.cache["hits"] == 0
        ref = np.asarray(cold.payload["optimal_tiers"], dtype=np.int64)

        # 2. kill half the chunks, resume: only the missing half recomputes
        files = sorted((cache.study_dir(study) / "chunks").glob("*.json"))
        for p in files[::2]:
            p.unlink()
        deleted = len(files[::2])
        t0 = time.perf_counter()
        resumed = study.run(cache=ResultCache(root, block_cells=block_cells))
        out["resume_s"] = time.perf_counter() - t0
        assert resumed.cache["misses"] == deleted, resumed.cache
        assert resumed.cache["hits"] == len(files) - deleted, resumed.cache
        assert np.array_equal(
            ref, np.asarray(resumed.payload["optimal_tiers"], dtype=np.int64)
        ), "resumed sweep diverged from the cold run"

        # 3. fully warm: nothing recomputes
        t0 = time.perf_counter()
        warm = study.run(cache=ResultCache(root, block_cells=block_cells))
        out["warm_s"] = time.perf_counter() - t0
        assert warm.cache["misses"] == 0 and warm.cache["hits"] == len(files)
        assert np.array_equal(
            ref, np.asarray(warm.payload["optimal_tiers"], dtype=np.int64)
        )
    finally:
        if not keep_cache:
            shutil.rmtree(root, ignore_errors=True)
    out["peak_rss_mb"] = _peak_rss_mb()
    out["match"] = True
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--points", type=int, default=1_000_000,
                    help="~design points in the sweep (workloads = points/48)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--shard", default=None,
                    help="engine device-shard setting ('auto' | int); "
                         "switches the search to the jax backend")
    ap.add_argument("--keep-cache", default=None, metavar="DIR",
                    help="persist the chunk cache here (default: temp dir)")
    ap.add_argument("--smoke", action="store_true",
                    help="~20k-point sweep — the CI smoke step")
    args = ap.parse_args()
    out = run(20_000 if args.smoke else args.points, args.seed, args.shard,
              args.keep_cache)
    name = "BENCH_scale_smoke.json" if args.smoke else "BENCH_scale.json"
    (HERE / name).write_text(json.dumps(out, indent=1))
    print(json.dumps(out, indent=1))
    print(f"{out['points']} points: cold {out['cold_s']:.1f}s "
          f"({out['points_per_s']:,.0f} points/s), resume {out['resume_s']:.1f}s, "
          f"warm {out['warm_s']:.2f}s, peak RSS {out['peak_rss_mb']:.0f} MB")


if __name__ == "__main__":
    main()
