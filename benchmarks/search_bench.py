"""Guided Pareto search benchmark: a ~1e9-point space to a stable
frontier in seconds, validated against an exhaustive reference.

Pins the PR-6 guided-search story (``core.search``) in three acts:

1. **validation** (~1M-point subspace, exhaustively tractable): run the
   exhaustive reference, then the guided search with a <1% evaluation
   budget — asserts the guided feasible frontier reaches >= 0.99 of the
   exhaustive hypervolume (common reference point), and that identical
   seeds give bit-identical ``StudyResult`` JSON.
2. **resume**: the same guided study chunk-cached cold, then re-run
   warm — asserts the warm run replays every generation from cache with
   **0 recomputed chunks** and an identical payload.
3. **full space** (~1e9 effective points: 2560 MAC budgets x 16 tiers x
   3 dataflows x 2 vlink techs x 64 DRAM x 64 SRAM values): the guided
   search prices a few 10^4 points of it — wall clock and points/s
   reported for 1 worker vs N ``parallel.work_queue`` processes, with
   payload bit-identity asserted across worker counts. The >= 2x
   multi-worker speedup assertion is gated on ``os.cpu_count() >= 4``
   (on fewer cores the honest numbers are still recorded).

Writes ``BENCH_search.json`` (``BENCH_search_smoke.json`` with
``--smoke``, the CI-sized run) next to this file.

Run:  PYTHONPATH=src python -m benchmarks.search_bench [--smoke]
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import pathlib
import shutil
import tempfile
import time

import numpy as np

from repro.core.cache import ResultCache
from repro.core.search import exhaustive_frontier, hypervolume
from repro.core.study import (
    AnalysisSpec,
    BandwidthSpec,
    SearchSpec,
    SpaceSpec,
    Study,
    WorkloadSpec,
)

HERE = pathlib.Path(__file__).resolve().parent
GEMMS = ((64, 12100, 147), (512, 784, 128))


def _budgets(n: int) -> tuple[int, ...]:
    return tuple(
        int(x) for x in np.unique(np.round(np.geomspace(2**10, 2**20, n)))
    )


def _study(name, budgets, tiers, dataflow, tech, dram, sram, search: SearchSpec,
           workers=None) -> Study:
    return Study(
        name=name,
        workload=WorkloadSpec(kind="gemms", gemms=GEMMS),
        space=SpaceSpec(mac_budgets=budgets, tiers=tiers, dataflow=dataflow,
                        tech=tech),
        analysis=AnalysisSpec(
            kind="search",
            bandwidth=BandwidthSpec.paper_default(),
            search=dataclasses.replace(
                search,
                dram_gbs=tuple(float(x) for x in dram),
                sram_kib=tuple(float(x) for x in sram),
            ),
            workers=workers,
        ),
    )


def _validation_study(smoke: bool) -> Study:
    if smoke:
        return _study(
            "search-bench-validation-smoke",
            _budgets(24), tuple(range(1, 9)), ("dos", "ws"), ("tsv", "miv"),
            np.geomspace(8, 1024, 4), np.geomspace(32, 4096, 4),
            SearchSpec(objectives=("cycles", "energy_j"), generations=4,
                       population=96, refine=(4, 2, 1, 1)),
        )
    return _study(
        "search-bench-validation",
        _budgets(128), tuple(range(1, 17)), ("dos", "ws", "is"), ("tsv", "miv"),
        np.geomspace(8, 1024, 9), np.geomspace(32, 4096, 9),
        SearchSpec(objectives=("cycles", "energy_j"), generations=10,
                   population=960, refine=(16, 8, 8, 4, 4, 2, 2, 1, 1, 1)),
    )


def _full_study(smoke: bool, workers=None) -> Study:
    if smoke:
        return _study(
            "search-bench-full-smoke",
            _budgets(96), tuple(range(1, 17)), ("dos", "ws", "is"),
            ("tsv", "miv"),
            np.geomspace(8, 1024, 16), np.geomspace(32, 4096, 16),
            SearchSpec(objectives=("cycles", "energy_j"), generations=4,
                       population=512, refine=(8, 4, 2, 1)),
            workers=workers,
        )
    return _study(
        "search-bench-full",
        _budgets(2560), tuple(range(1, 17)), ("dos", "ws", "is"), ("tsv", "miv"),
        np.geomspace(8, 1024, 64), np.geomspace(32, 4096, 64),
        SearchSpec(objectives=("cycles", "energy_j"), generations=12,
                   population=4096, refine=(64, 32, 16, 16, 8, 8, 4, 4, 2, 2, 1, 1)),
        workers=workers,
    )


def _run_full(study: Study, block_cells: int) -> tuple[float, dict]:
    """One cold cached full-space run in a scratch dir; (wall_s, payload)."""
    root = tempfile.mkdtemp(prefix="repro-searchbench-")
    try:
        t0 = time.perf_counter()
        res = study.run(cache=ResultCache(root, block_cells=block_cells))
        dt = time.perf_counter() - t0
        assert res.cache["hits"] == 0, res.cache
        return dt, res.to_dict()["payload"]
    finally:
        shutil.rmtree(root, ignore_errors=True)


def run(smoke: bool = False) -> dict:
    out: dict = {"smoke": smoke, "workloads": [list(g) for g in GEMMS]}

    # -- 1. validation: guided vs exhaustive on a tractable subspace --------
    val = _validation_study(smoke)
    t0 = time.perf_counter()
    ex = exhaustive_frontier(val)
    t_ex = time.perf_counter() - t0
    exF = ex["frontier_objectives"]
    ref = exF.max(axis=0) * 1.1  # common reference: both hv use it
    hv_ex = hypervolume(exF, ref)

    t0 = time.perf_counter()
    guided = val.run()
    t_g = time.perf_counter() - t0
    p = guided.payload
    hv_g = hypervolume(p["frontier_objectives"], ref)
    ratio = hv_g / hv_ex
    min_ratio = 0.95 if smoke else 0.99
    assert ratio >= min_ratio, f"hv ratio {ratio:.5f} < {min_ratio}"
    if not smoke:
        assert p["frac_evaluated"] < 0.01, p["frac_evaluated"]
    deterministic = val.run().to_json() == guided.to_json()
    assert deterministic, "same-seed runs are not bit-identical"
    out["validation"] = {
        "space_size": ex["space_size"],
        "exhaustive_s": t_ex,
        "exhaustive_points_per_s": ex["space_size"] / t_ex,
        "exhaustive_frontier": int(len(exF)),
        "hypervolume_exhaustive": hv_ex,
        "guided_s": t_g,
        "n_evaluated": p["n_evaluated"],
        "frac_evaluated": p["frac_evaluated"],
        "guided_frontier": int(len(p["frontier_objectives"])),
        "hypervolume_guided": hv_g,
        "hypervolume_ratio": ratio,
        "same_seed_bit_identical": deterministic,
    }

    # -- 2. resume: warm cache replays every generation, 0 recomputed ------
    root = tempfile.mkdtemp(prefix="repro-searchbench-")
    try:
        t0 = time.perf_counter()
        cold = val.run(cache=ResultCache(root))
        cold_s = time.perf_counter() - t0
        assert cold.cache["hits"] == 0
        t0 = time.perf_counter()
        warm = val.run(cache=ResultCache(root))
        warm_s = time.perf_counter() - t0
        assert warm.cache["misses"] == 0, warm.cache
        assert warm.to_dict()["payload"] == cold.to_dict()["payload"]
        out["resume"] = {
            "cold_s": cold_s,
            "warm_s": warm_s,
            "chunks": cold.cache["misses"],
            "recomputed_chunks_on_resume": warm.cache["misses"],
            "payload_identical": True,
        }
    finally:
        shutil.rmtree(root, ignore_errors=True)

    # -- 3. full space: 1 worker vs N over the work queue ------------------
    cpus = os.cpu_count() or 1
    n_workers = 2 if smoke else min(4, max(2, cpus))
    full1 = _full_study(smoke, workers=1)
    pop = full1.analysis.search.population
    # split each generation into ~2 blocks per worker so the queue has
    # real parallel grain (chunk keys embed the range: identical layout
    # for both runs, so the N-worker run could even resume the 1-worker
    # cache — here both start cold in scratch dirs)
    block_cells = max(1, pop * len(GEMMS) // (2 * n_workers))
    t_1w, payload_1w = _run_full(full1, block_cells)
    fullN = _full_study(smoke, workers=n_workers)
    t_nw, payload_nw = _run_full(fullN, block_cells)
    assert payload_1w == payload_nw, "worker count changed the payload"
    pf = payload_1w
    speedup = t_1w / t_nw if t_nw else float("inf")
    if not smoke:
        assert pf["space_size"] >= 950_000_000, pf["space_size"]
        if cpus >= 4:
            assert speedup >= 2.0, (
                f"{n_workers}-worker speedup {speedup:.2f}x < 2x on {cpus} cpus"
            )
    out["full_space"] = {
        "space_size": pf["space_size"],
        "n_evaluated": pf["n_evaluated"],
        "frac_evaluated": pf["frac_evaluated"],
        "frontier_size": len(pf["frontier_objectives"]),
        "hypervolume": pf["hypervolume"],
        "cpus": cpus,
        "workers": n_workers,
        "wall_s_1_worker": t_1w,
        "points_per_s_1_worker": pf["n_evaluated"] / t_1w,
        f"wall_s_{n_workers}_workers": t_nw,
        f"points_per_s_{n_workers}_workers": pf["n_evaluated"] / t_nw,
        "speedup_vs_1_worker": speedup,
        "speedup_asserted": (not smoke) and cpus >= 4,
        "payload_identical_across_workers": True,
    }
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small spaces, light budgets — the CI smoke step")
    args = ap.parse_args()
    out = run(smoke=args.smoke)
    name = "BENCH_search_smoke.json" if args.smoke else "BENCH_search.json"
    (HERE / name).write_text(json.dumps(out, indent=1))
    print(json.dumps(out, indent=1))
    v, f = out["validation"], out["full_space"]
    t_nw = f[f"wall_s_{f['workers']}_workers"]
    print(
        f"validation: hv ratio {v['hypervolume_ratio']:.4f} at "
        f"{v['frac_evaluated']:.3%} of {v['space_size']:,} points "
        f"(exhaustive {v['exhaustive_s']:.1f}s vs guided {v['guided_s']:.1f}s); "
        f"full space {f['space_size']:,} points: {f['n_evaluated']:,} evals, "
        f"1w {f['wall_s_1_worker']:.1f}s vs {f['workers']}w {t_nw:.1f}s "
        f"({f['speedup_vs_1_worker']:.2f}x)"
    )


if __name__ == "__main__":
    main()
