"""Serving-traffic benchmark: sustained 3D-vs-2D on a mixed trace.

Pins the production-serving story of ``core.serve`` (the ISSUE-8
acceptance artifact): a seeded mixed prefill/decode trace on a zoo
model, priced per design point through the bandwidth-aware engine under
the paper-default memory system, where

1. a **feasible 3D design beats the 2D baseline on tokens/s/W** (the
   single-tier die must over-provision one big array that stalls on
   DRAM and burns static power; the stack spends the same MAC budget at
   a higher sustained efficiency) — asserted, with p50/p99 TTFT and
   per-output-token latency reported per point;
2. a **half-populated cache resumes bit-identically**: delete half the
   per-point chunk files, re-run via ``--resume`` semantics, assert
   exactly the missing design points recompute and the stitched payload
   matches the cold run bit for bit (then a warm run recomputes
   nothing).

Writes ``BENCH_serve.json`` (or ``BENCH_serve_smoke.json`` with
``--smoke``, the CI-sized run) next to this file.

Run:  PYTHONPATH=src python -m benchmarks.serve_bench [--smoke]
"""

from __future__ import annotations

import argparse
import json
import pathlib
import shutil
import tempfile
import time

import numpy as np

from repro.core.cache import ResultCache
from repro.core.study import (
    AnalysisSpec,
    BandwidthSpec,
    ConstraintSpec,
    ServeSpec,
    SpaceSpec,
    Study,
    TrafficSpec,
    WorkloadSpec,
)

HERE = pathlib.Path(__file__).resolve().parent


def serve_study(smoke: bool = False) -> Study:
    """The pinned serving study: qwen2.5-3b decode under the
    paper-default memory system. Budget-matched tier counts 1..8 — the
    2D baseline is the tiers=1 column of the same grid."""
    traffic = TrafficSpec(
        arrival_rps=2048.0,
        n_requests=8 if smoke else 24,
        prompt_dist="lognormal",
        prompt_mean=128,
        prompt_max=512,
        output_dist="lognormal",
        output_mean=24,
        output_max=96,
        sigma=0.6,
        max_batch=4,
        policy="continuous",
        chunk_prefill=64,
        seed=0,
    )
    return Study(
        name="bench-serve-smoke" if smoke else "bench-serve",
        workload=WorkloadSpec(kind="network", arch="qwen2.5-3b",
                              shape="decode_32k"),
        space=SpaceSpec(
            mac_budgets=(2**16,) if smoke else (2**14, 2**16, 2**18),
            tiers=(1, 2, 4) if smoke else (1, 2, 4, 8),
        ),
        constraints=ConstraintSpec(),
        analysis=AnalysisSpec(
            kind="serve",
            bandwidth=BandwidthSpec.paper_default(),
            serve=ServeSpec(traffic=traffic),
        ),
    )


def _point_rows(p: dict) -> list[dict]:
    pts = p["points"]
    return [
        {
            "design": f"{pts['rows'][i]}x{pts['cols'][i]}x{pts['tiers'][i]}",
            "tech": str(pts["tech"][i]),
            "feasible": bool(pts["feasible"][i]),
            "gen_tok_s": float(pts["gen_tok_s"][i]),
            "ttft_p50_s": float(pts["ttft_p50_s"][i]),
            "ttft_p99_s": float(pts["ttft_p99_s"][i]),
            "tpot_p50_s": float(pts["tpot_p50_s"][i]),
            "tpot_p99_s": float(pts["tpot_p99_s"][i]),
            "energy_per_token_j": float(pts["energy_per_token_j"][i]),
            "tokens_per_s_per_w": float(pts["tokens_per_s_per_w"][i]),
            "stall_frac": float(pts["stall_frac"][i]),
        }
        for i in range(p["n_points"])
    ]


def run(smoke: bool = False, keep_cache: str | None = None) -> dict:
    study = serve_study(smoke)
    tr = study.analysis.serve.traffic
    root = pathlib.Path(keep_cache) if keep_cache else pathlib.Path(
        tempfile.mkdtemp(prefix="repro-serve-")
    )
    # one design point per chunk, so the half-populated resume below
    # exercises per-point granularity (chunk keys embed the index range)
    block_cells = tr.n_requests
    out: dict = {}
    try:
        # 1. cold cached run
        cache = ResultCache(root, block_cells=block_cells)
        t0 = time.perf_counter()
        cold = study.run(cache=cache)
        out["cold_s"] = time.perf_counter() - t0
        assert cold.cache["hits"] == 0
        p = cold.payload
        ref_json = json.dumps(cold.to_dict()["payload"], sort_keys=True)

        s = p["summary"]
        assert s["best_3d"] is not None, "no feasible 3D design"
        assert s["best_2d"] is not None, "no feasible 2D design"
        assert s["win_3d_vs_2d"] > 1.0, (
            f"3D does not beat 2D on tokens/s/W: {s['win_3d_vs_2d']}"
        )
        pts = p["points"]
        assert np.isfinite(pts["ttft_p50_s"]).all()
        assert np.isfinite(pts["ttft_p99_s"]).all()
        assert np.isfinite(pts["tpot_p50_s"]).all()
        # conservation: every admitted token was served
        assert int(pts["tokens_prefilled"][0]) == p["trace"]["tokens_in"]
        assert int(pts["tokens_decoded"][0]) == p["trace"]["tokens_out"]

        # 2. kill half the chunks, resume: exactly the missing design
        # points recompute; stitched payload is bit-identical
        files = sorted((cache.study_dir(study) / "chunks").glob("points-*.json"))
        out["chunks"] = len(files)
        for f in files[::2]:
            f.unlink()
        deleted = len(files[::2])
        t0 = time.perf_counter()
        resumed = study.run(cache=ResultCache(root, block_cells=block_cells))
        out["resume_s"] = time.perf_counter() - t0
        assert resumed.cache["misses"] == deleted, resumed.cache
        assert resumed.cache["hits"] == len(files) - deleted, resumed.cache
        assert json.dumps(resumed.to_dict()["payload"], sort_keys=True) == ref_json, (
            "resumed serve payload diverged from the cold run"
        )

        # 3. fully warm: nothing recomputes
        warm = study.run(cache=ResultCache(root, block_cells=block_cells))
        assert warm.cache["misses"] == 0 and warm.cache["hits"] == len(files)
        assert json.dumps(warm.to_dict()["payload"], sort_keys=True) == ref_json
    finally:
        if not keep_cache:
            shutil.rmtree(root, ignore_errors=True)

    out.update({
        "study": study.name,
        "arch": p["arch"],
        "shape": p["shape"],
        "n_points": p["n_points"],
        "trace": p["trace"],
        "traffic": tr.to_dict(),
        "points": _point_rows(p),
        "summary": s,
        "resume_bit_identical": True,
    })
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized trace/grid — BENCH_serve_smoke.json")
    ap.add_argument("--keep-cache", default=None, metavar="DIR",
                    help="persist the chunk cache here (default: temp dir)")
    args = ap.parse_args()
    out = run(smoke=args.smoke, keep_cache=args.keep_cache)
    name = "BENCH_serve_smoke.json" if args.smoke else "BENCH_serve.json"
    (HERE / name).write_text(json.dumps(out, indent=1))
    print(json.dumps(out, indent=1))
    s = out["summary"]
    print(
        f"{out['arch']}/{out['shape']}: {out['n_points']} design points, "
        f"best 3D {s['best_3d']['tokens_per_s_per_w']:.1f} tok/s/W vs 2D "
        f"{s['best_2d']['tokens_per_s_per_w']:.1f} ({s['win_3d_vs_2d']:.2f}x); "
        f"cold {out['cold_s']:.2f}s, resume {out['resume_s']:.2f}s"
    )


if __name__ == "__main__":
    main()
