"""Benchmark the Study facade against direct engine calls.

The declarative ``core.study.Study`` layer is the repo's one front
door; this benchmark proves the door is free. It times the same work
twice —

  - evaluate: Table-I workloads x (budget x tier) grid, all metric
    groups, ``engine.evaluate(grid)`` vs the equivalent
    ``Study(...).run()``;
  - schedule: one model-zoo cell lowered + scheduled,
    ``lower_network + engine.schedule`` vs the equivalent ``schedule``
    Study (which resolves the workload itself);

— asserts the results are bit-for-bit identical, and reports the
facade overhead, which must stay **< 5%** (min-of-reps timing; the
facade adds only spec validation and payload wrapping, no array
conversion). Writes ``BENCH_study.json`` next to this file.

Run:  PYTHONPATH=src python -m benchmarks.study_bench [--smoke] [--reps 5]
"""

from __future__ import annotations

import argparse
import json
import pathlib
import time

import numpy as np

from repro.configs import REGISTRY, SHAPES
from repro.core.dse import PAPER_WORKLOADS
from repro.core.engine import DesignGrid, evaluate, schedule
from repro.core.network import lower_network
from repro.core.study import AnalysisSpec, SpaceSpec, Study, WorkloadSpec

HERE = pathlib.Path(__file__).resolve().parent
OVERHEAD_TARGET_PCT = 5.0


def _paired(fn_a, fn_b, reps: int):
    """Time two implementations of the same work in alternating reps.

    Returns ``(out_a, out_b, best_a_s, best_b_s, overhead_pct)`` where
    ``overhead_pct`` is the **median of per-rep paired differences**
    (b - a) over the best a-time. Pairing cancels machine drift
    (frequency scaling, background load) that a min-over-independent-
    runs ratio picks up as fake +/- several percent; the median drops
    rep-level outliers (GC, interrupts)."""
    ta, tb = [], []
    out = [None, None]
    for _ in range(reps):
        for i, (fn, acc) in enumerate(((fn_a, ta), (fn_b, tb))):
            t0 = time.perf_counter()
            out[i] = fn()
            acc.append(time.perf_counter() - t0)
    diffs = np.asarray(tb) - np.asarray(ta)
    best_a = float(np.min(ta))
    overhead_pct = float(np.median(diffs)) / best_a * 100.0
    return out[0], out[1], best_a, float(np.min(tb)), overhead_pct


def bench_evaluate(reps: int, smoke: bool):
    wl = list(PAPER_WORKLOADS.values())
    budgets = (2**14, 2**16) if smoke else (2**14, 2**16, 2**18)
    tiers = tuple(range(1, 9)) if smoke else tuple(range(1, 17))

    def direct():
        return evaluate(DesignGrid.product(wl, budgets, tiers))

    study = Study(
        name="study-bench-evaluate",
        workload=WorkloadSpec(kind="gemms", gemms=wl),
        space=SpaceSpec(mac_budgets=budgets, tiers=tiers),
    )
    res_d, res_s, t_d, t_s, overhead = _paired(direct, lambda: study.run(), reps)
    for f in ("rows", "cols", "cycles", "speedup", "power_w", "t_max_c"):
        a, b = getattr(res_d, f), getattr(res_s.result, f)
        assert np.array_equal(a, b, equal_nan=True), f"evaluate mismatch in {f}"
    return {
        "grid": f"{len(wl)} workloads x {len(budgets) * len(tiers)} points",
        "direct_s": t_d,
        "study_s": t_s,
        "overhead_pct": overhead,
    }


def bench_schedule(reps: int, smoke: bool):
    # train_4k keeps the engine work in the hundreds of ms, so the
    # fixed facade cost (spec resolve + wrap) is measurable against it
    # rather than drowned in ms-scale timer jitter.
    arch, shape = "smollm-135m", "train_4k"
    # no reduced smoke grid here: the full cell is already ~0.2s, and a
    # smaller one would push the arms into ms-scale timer jitter where
    # the overhead ratio is meaningless.
    budgets = (2**14, 2**16, 2**18)
    tiers = tuple(range(1, 17))

    def direct():
        # the Study resolves its own workload, so the fair direct
        # baseline includes the lowering too
        stream = lower_network(REGISTRY[arch], SHAPES[shape])
        return schedule(stream, mac_budgets=budgets, tiers=tiers)

    study = Study(
        name="study-bench-schedule",
        workload=WorkloadSpec(kind="network", arch=arch, shape=shape),
        space=SpaceSpec(mac_budgets=budgets, tiers=tiers),
        analysis=AnalysisSpec(kind="schedule"),
    )
    rep_d, rep_s, t_d, t_s, overhead = _paired(direct, lambda: study.run(), reps)
    assert rep_d.to_dict() == rep_s.report.to_dict(), "schedule mismatch"
    return {
        "cell": f"{arch}/{shape}",
        "direct_s": t_d,
        "study_s": t_s,
        "overhead_pct": overhead,
    }


def run(smoke: bool = False, reps: int = 5):
    out = {
        "smoke": smoke,
        "reps": reps,
        "target_pct": OVERHEAD_TARGET_PCT,
        "evaluate": bench_evaluate(reps, smoke),
        "schedule": bench_schedule(reps, smoke),
        "match": True,
    }
    out["max_overhead_pct"] = max(
        out["evaluate"]["overhead_pct"], out["schedule"]["overhead_pct"]
    )
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced grid (the CI smoke step)")
    ap.add_argument("--reps", type=int, default=5)
    args = ap.parse_args()
    out = run(smoke=args.smoke, reps=args.reps)
    # smoke runs get their own artifact so the canonical full-run
    # numbers are never clobbered
    name = "BENCH_study_smoke.json" if args.smoke else "BENCH_study.json"
    (HERE / name).write_text(json.dumps(out, indent=1))
    print(json.dumps(out, indent=1))
    worst = out["max_overhead_pct"]
    print(f"facade overhead: {worst:.2f}% (target < {OVERHEAD_TARGET_PCT}%)")
    assert worst < OVERHEAD_TARGET_PCT, (
        f"Study facade overhead {worst:.2f}% exceeds {OVERHEAD_TARGET_PCT}%"
    )


if __name__ == "__main__":
    main()
