"""Transient thermal/DVFS benchmark: sustained vs peak, pinned flip.

Pins the ISSUE-9 acceptance story for the transient thermal model
(``core.ppa.thermal.ThermalState`` + ``core.pricing.DvfsSpec``):

1. **Steady-vs-transient agreement**: stepping the lumped RC stack
   under constant power converges to ``lumped_tier_temps``'s steady
   state — the fixed-point residual is reported and asserted below
   1e-9 relative (backward Euler shares the steady assembly, so the
   agreement is exact up to float64 roundoff).
2. **Sustained <= peak**: a governed serving run never reports more
   sustained tokens/s than the ungoverned steady pricing advertises
   as peak (asserted per design point).
3. **The feasibility flip**: under a junction limit between the 2D
   baseline's and the stacked design's *steady* temperatures, the
   3D point is steady-infeasible — the worst-case gate strikes it —
   yet transient-feasible: the governed excursion over the whole trace
   stays under the limit, and its sustained tokens/s beats the
   steady-feasible 2D baseline's. The steady model throws away the
   faster design; the transient model prices and keeps it.

Writes ``BENCH_thermal.json`` (or ``BENCH_thermal_smoke.json`` with
``--smoke``, the CI-sized run) next to this file.

Run:  PYTHONPATH=src python -m benchmarks.thermal_bench [--smoke]
"""

from __future__ import annotations

import argparse
import json
import pathlib
import time

import numpy as np

from repro.core.ppa.thermal import ThermalState, lumped_tier_temps, step_temps
from repro.core.study import (
    AnalysisSpec,
    BandwidthSpec,
    ConstraintSpec,
    ServeSpec,
    SpaceSpec,
    Study,
    TrafficSpec,
    WorkloadSpec,
)

HERE = pathlib.Path(__file__).resolve().parent

#: junction limit pinned between the steady temperatures of the 2D
#: baseline (68x240x1, ~54.1 degC) and the per-tier-budget-matched
#: stack (68x256x8, ~54.7 degC) of the study below.
FLIP_LIMIT_C = 54.4


def flip_study(smoke: bool = False, thermal: str = "steady") -> Study:
    """qwen2.5-3b decode serving on a per-tier-matched grid: the
    2**18-MAC 8-tier stack carries the same per-tier array as the
    2**14-MAC 2D die — the paper's Fig. 8 setup, where stacking the
    same tier is what concentrates the heat."""
    traffic = TrafficSpec(
        arrival_rps=2048.0,
        n_requests=8 if smoke else 24,
        prompt_dist="lognormal",
        prompt_mean=128,
        prompt_max=512,
        output_dist="lognormal",
        output_mean=24,
        output_max=96,
        sigma=0.6,
        max_batch=4,
        policy="continuous",
        chunk_prefill=64,
        seed=0,
    )
    return Study(
        name=f"bench-thermal-{thermal}" + ("-smoke" if smoke else ""),
        workload=WorkloadSpec(kind="network", arch="qwen2.5-3b",
                              shape="decode_32k"),
        space=SpaceSpec(mac_budgets=(2**14, 2**18), tiers=(1, 8)),
        constraints=ConstraintSpec(thermal_limit_c=FLIP_LIMIT_C),
        analysis=AnalysisSpec(
            kind="serve",
            thermal=thermal,
            bandwidth=BandwidthSpec.paper_default(),
            serve=ServeSpec(traffic=traffic),
        ),
    )


def fixed_point_residual() -> dict:
    """Step the RC stack under constant power until the transient
    temperatures converge; compare against the one-shot steady solve."""
    fp = np.array([4.2, 4.2, 30.0])
    tiers = np.array([4, 8, 1])
    tech = np.array(["tsv", "miv", "2d"])
    macs = np.array([4096.0, 4096.0, 65536.0])
    q_tier = np.array([1.5, 0.8, 6.0])
    q = np.where(
        np.arange(tiers.max())[None, :] < tiers[:, None],
        q_tier[:, None], 0.0,
    )
    steady = lumped_tier_temps(q, fp, tiers, tech, macs)
    state = ThermalState.init(fp, tiers, tech, macs)
    t0 = time.perf_counter()
    n_steps = 400
    for _ in range(n_steps):
        state = step_temps(state, q, np.full(3, 0.05))
    elapsed = time.perf_counter() - t0
    alive = state.alive
    rel = np.abs(state.temps_c - steady)[alive] / np.abs(steady[alive])
    return {
        "n_steps": n_steps,
        "dt_s": 0.05,
        "step_s": elapsed / n_steps,
        "max_rel_err": float(rel.max()),
    }


def _point_rows(p: dict) -> list[dict]:
    pts = p["points"]
    return [
        {
            "design": f"{pts['rows'][i]}x{pts['cols'][i]}x{pts['tiers'][i]}",
            "tech": str(pts["tech"][i]),
            "feasible_steady": bool(pts["feasible_steady"][i]),
            "feasible_transient": bool(pts["feasible"][i]),
            "t_max_steady_c": float(pts["t_max_c"][i]),
            "t_max_governed_c": float(pts["t_max_transient_c"][i]),
            "peak_tok_s": float(pts["peak_tok_s"][i]),
            "sustained_tok_s": float(pts["gen_tok_s"][i]),
            "peak_vs_sustained": float(pts["peak_vs_sustained"][i]),
            "residency": [float(x) for x in pts["dvfs_residency"][i]],
        }
        for i in range(p["n_points"])
    ]


def run(smoke: bool = False) -> dict:
    out: dict = {"thermal_limit_c": FLIP_LIMIT_C}

    # 1. transient stepping agrees with the steady solver
    out["fixed_point"] = fixed_point_residual()
    assert out["fixed_point"]["max_rel_err"] < 1e-9, out["fixed_point"]

    # 2+3. steady gate vs governed transient on the same grid
    steady = flip_study(smoke, "steady").run()
    t0 = time.perf_counter()
    trans = flip_study(smoke, "transient").run()
    out["transient_s"] = time.perf_counter() - t0
    p = trans.payload
    pts = p["points"]
    out["dvfs"] = p["dvfs"]
    out["points"] = _point_rows(p)

    # the steady study's verdicts match the transient study's
    # feasible_steady column (same designs, same gate)
    assert (steady.payload["points"]["feasible"] == pts["feasible_steady"]).all()

    # sustained never exceeds peak; residency is a distribution
    ok = pts["valid"]
    assert (pts["peak_vs_sustained"][ok] >= 1.0 - 1e-12).all()
    assert np.allclose(pts["dvfs_residency"][ok].sum(axis=1), 1.0)
    # governed excursion under the limit wherever transient-feasible
    feas = pts["feasible"]
    assert (pts["t_max_transient_c"][feas] < FLIP_LIMIT_C).all()

    # the pinned flip: a 3D point the steady gate strikes, serving
    # faster than the steady-feasible 2D baseline under the governor
    flip = feas & ~pts["feasible_steady"] & (pts["tiers"] > 1)
    assert flip.any(), "no steady-infeasible 3D point became feasible"
    base2d = pts["feasible_steady"] & (pts["tiers"] == 1)
    assert base2d.any(), "no steady-feasible 2D baseline"
    i3 = int(np.argmax(np.where(flip, pts["gen_tok_s"], -np.inf)))
    i2 = int(np.argmax(np.where(base2d, pts["gen_tok_s"], -np.inf)))
    win = float(pts["gen_tok_s"][i3] / pts["gen_tok_s"][i2])
    out["flip"] = {
        "design_3d": f"{pts['rows'][i3]}x{pts['cols'][i3]}x{pts['tiers'][i3]}",
        "design_2d": f"{pts['rows'][i2]}x{pts['cols'][i2]}x{pts['tiers'][i2]}",
        "t_steady_3d_c": float(pts["t_max_c"][i3]),
        "t_governed_3d_c": float(pts["t_max_transient_c"][i3]),
        "sustained_3d_tok_s": float(pts["gen_tok_s"][i3]),
        "sustained_2d_tok_s": float(pts["gen_tok_s"][i2]),
        "win_3d_vs_2d_sustained": win,
    }
    assert pts["t_max_c"][i3] > FLIP_LIMIT_C  # steady gate really struck it
    assert win > 1.0, f"throttled 3D does not beat 2D sustained: {win}"

    out["study"] = trans.study.name
    out["arch"] = p["arch"]
    out["n_points"] = p["n_points"]
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized trace — BENCH_thermal_smoke.json")
    args = ap.parse_args()
    out = run(smoke=args.smoke)
    name = "BENCH_thermal_smoke.json" if args.smoke else "BENCH_thermal.json"
    (HERE / name).write_text(json.dumps(out, indent=1))
    print(json.dumps(out, indent=1))
    f = out["flip"]
    print(
        f"{out['arch']}: steady gate at {out['thermal_limit_c']} degC strikes "
        f"{f['design_3d']} (steady {f['t_steady_3d_c']:.1f} degC); governed it "
        f"stays at {f['t_governed_3d_c']:.1f} degC and sustains "
        f"{f['sustained_3d_tok_s']:.0f} tok/s vs the 2D baseline's "
        f"{f['sustained_2d_tok_s']:.0f} ({f['win_3d_vs_2d_sustained']:.2f}x); "
        f"fixed-point residual {out['fixed_point']['max_rel_err']:.1e}"
    )


if __name__ == "__main__":
    main()
