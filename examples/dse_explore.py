"""The paper's design-space exploration, interactive.

Given a GEMM workload and a MAC budget, reports: the optimal 2D array,
the optimal tier count, speedup, power/area/thermal for the chosen
config, and how the same decision maps onto a TPU mesh axis (advisor).

Run:  PYTHONPATH=src python examples/dse_explore.py --m 128 --k 8192 --n 512
"""

import argparse

from repro.core.advisor import GemmShard, score_strategies
from repro.core.analytical import optimal_tiers, optimize_array_2d, optimize_array_3d, speedup_3d
from repro.core.ppa import area_normalized_speedup, array_power, thermal_report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--m", type=int, default=64)
    ap.add_argument("--k", type=int, default=12100)
    ap.add_argument("--n", type=int, default=147)
    ap.add_argument("--macs", type=int, default=2**16)
    ap.add_argument("--mesh-axis", type=int, default=16)
    args = ap.parse_args()
    M, K, N, budget = args.m, args.k, args.n, args.macs

    p2 = optimize_array_2d(M, K, N, budget)
    print(f"2D optimum:  {p2.rows}x{p2.cols} -> {p2.cycles:.0f} cycles")
    l, _ = optimal_tiers(M, K, N, budget)
    p3 = optimize_array_3d(M, K, N, budget, l)
    print(f"3D optimum:  {l} tiers of {p3.rows}x{p3.cols} -> {p3.cycles:.0f} cycles "
          f"({speedup_3d(M, K, N, budget, l):.2f}x)")

    for tech in ("tsv", "miv"):
        ans = area_normalized_speedup(M, K, N, budget, l, tech)
        pw = array_power(M, K, N, p3.rows, p3.cols, l, tech)
        th = thermal_report(p3.rows * p3.cols, min(l, 4), tech, M=M, K=K, N=N)
        print(f"  {tech.upper()}: perf/area {ans:.2f}x vs 2D | {pw.total_w:.2f} W "
              f"| T_max {th.t_max_c:.0f} C (budget_ok={th.within_budget})")

    print(f"\nTPU mesh axis of {args.mesh_axis} (the 'tiers'):")
    for s in score_strategies(GemmShard(M=M, K=K, N=N, axis=args.mesh_axis)):
        print(f"  {s.name:10s} compute {s.compute_s*1e6:9.2f}us "
              f"coll {s.collective_s*1e6:9.2f}us total {s.total_s*1e6:9.2f}us")


if __name__ == "__main__":
    main()
