"""The paper's design-space exploration, interactive.

Given a GEMM workload and a MAC budget, reports: the optimal 2D array,
the optimal tier count, speedup, power/area/thermal for the chosen
config, and how the same decision maps onto a TPU mesh axis (advisor).

Run:  PYTHONPATH=src python examples/dse_explore.py --m 128 --k 8192 --n 512
Add --pareto to print the latency/area/power Pareto frontier over the
whole (budget x tier) grid via one batched engine call.
"""

import argparse

from repro.core.advisor import GemmShard, score_strategies
from repro.core.analytical import optimal_tiers, optimize_array_2d, optimize_array_3d, speedup_3d
from repro.core.ppa import area_normalized_speedup, array_power, thermal_report


def pareto_study(M, K, N):
    """Latency-area-power frontier over budgets x tiers (Sec. IV-C/D)."""
    from repro.core.engine import DesignGrid, evaluate

    budgets = [2**p for p in range(12, 19)]
    tiers = range(1, 17)
    grid = DesignGrid.product([(M, K, N)], budgets, tiers)
    res = evaluate(grid)
    mask = res.pareto_mask(("cycles", "area_um2", "power_w"))[0]
    print(f"\nPareto frontier ({mask.sum()}/{mask.size} points survive):")
    print("  macs     tiers  RxC        cycles      area mm2  power W  T_max C")
    for p in mask.nonzero()[0]:
        b = grid.mac_budgets[p]
        print(
            f"  2^{int(b).bit_length()-1:<6} {grid.tiers[p]:<6} "
            f"{res.rows[0, p]}x{res.cols[0, p]:<8} {res.cycles[0, p]:<11.0f} "
            f"{res.area_um2[0, p]*1e-6:<9.2f} {res.power_w[0, p]:<8.2f} "
            f"{res.t_max_c[0, p]:.0f}"
        )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--m", type=int, default=64)
    ap.add_argument("--k", type=int, default=12100)
    ap.add_argument("--n", type=int, default=147)
    ap.add_argument("--macs", type=int, default=2**16)
    ap.add_argument("--mesh-axis", type=int, default=16)
    ap.add_argument("--pareto", action="store_true",
                    help="engine-backed latency/area/power Pareto frontier")
    args = ap.parse_args()
    M, K, N, budget = args.m, args.k, args.n, args.macs

    p2 = optimize_array_2d(M, K, N, budget)
    print(f"2D optimum:  {p2.rows}x{p2.cols} -> {p2.cycles:.0f} cycles")
    l, _ = optimal_tiers(M, K, N, budget)
    p3 = optimize_array_3d(M, K, N, budget, l)
    print(f"3D optimum:  {l} tiers of {p3.rows}x{p3.cols} -> {p3.cycles:.0f} cycles "
          f"({speedup_3d(M, K, N, budget, l):.2f}x)")

    for tech in ("tsv", "miv"):
        ans = area_normalized_speedup(M, K, N, budget, l, tech)
        pw = array_power(M, K, N, p3.rows, p3.cols, l, tech)
        th = thermal_report(p3.rows * p3.cols, min(l, 4), tech, M=M, K=K, N=N)
        print(f"  {tech.upper()}: perf/area {ans:.2f}x vs 2D | {pw.total_w:.2f} W "
              f"| T_max {th.t_max_c:.0f} C (budget_ok={th.within_budget})")

    print(f"\nTPU mesh axis of {args.mesh_axis} (the 'tiers'):")
    for s in score_strategies(GemmShard(M=M, K=K, N=N, axis=args.mesh_axis)):
        print(f"  {s.name:10s} compute {s.compute_s*1e6:9.2f}us "
              f"coll {s.collective_s*1e6:9.2f}us total {s.total_s*1e6:9.2f}us")

    if args.pareto:
        pareto_study(M, K, N)


if __name__ == "__main__":
    main()
