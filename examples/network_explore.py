"""Network-level design-space exploration, interactive.

Lower any model-zoo architecture to its GEMM workload stream and
schedule it end-to-end on the 3D-array design grid: per-layer-optimal
vs one fixed array design, with thermal feasibility masking. Each run
is a declarative ``core.study.Study`` — add ``--spec`` to print the
spec JSON instead of running it (feed it to ``python -m repro run``).

Run:  PYTHONPATH=src python examples/network_explore.py --arch qwen2.5-3b
      PYTHONPATH=src python examples/network_explore.py \\
          --arch deepseek-moe-16b --shape decode_32k --tech miv
Add --stream to print the lowered per-layer GEMM stream, and
--thermal-limit to tighten the junction budget and watch designs drop
off the feasible set.
"""

import argparse

from repro.configs import REGISTRY, SHAPES
from repro.core.study import AnalysisSpec, ConstraintSpec, SpaceSpec, Study, WorkloadSpec


def build_study(arch, shape, dataflow, tech, thermal_limit):
    kw = {}
    if thermal_limit is not None:
        kw["constraints"] = ConstraintSpec(thermal_limit_c=thermal_limit)
    return Study(
        name=f"network-explore-{arch}-{shape}",
        workload=WorkloadSpec(kind="network", arch=arch, shape=shape),
        space=SpaceSpec(dataflow=dataflow, tech=tech),
        analysis=AnalysisSpec(kind="schedule"),
        **kw,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=sorted(REGISTRY))
    ap.add_argument("--shape", default=None, choices=sorted(SHAPES),
                    help="default: train_4k, prefill_32k and decode_32k")
    ap.add_argument("--tech", default="tsv", choices=["tsv", "miv"])
    ap.add_argument("--dataflow", default="dos", choices=["dos", "ws", "is"])
    ap.add_argument("--thermal-limit", type=float, default=None,
                    help="junction limit [C]; default: the 105C budget")
    ap.add_argument("--stream", action="store_true",
                    help="print the lowered GEMM stream per shape")
    ap.add_argument("--spec", action="store_true",
                    help="print the Study spec JSON instead of running")
    args = ap.parse_args()

    cfg = REGISTRY[args.arch]
    shapes = [args.shape] if args.shape else ["train_4k", "prefill_32k", "decode_32k"]

    for shape_name in shapes:
        if shape_name == "long_500k" and not cfg.is_subquadratic:
            print(f"\n== {shape_name}: skipped (full attention at 500k)")
            continue
        study = build_study(args.arch, shape_name, args.dataflow, args.tech,
                            args.thermal_limit)
        if args.spec:
            print(study.to_json())
            continue
        stream = study.workload.resolve()
        print(f"\n== {cfg.name} / {shape_name} ({stream.mode}) — "
              f"{stream.workloads.shape[0]} unique GEMMs, "
              f"{stream.n_gemm_invocations} invocations, "
              f"{stream.total_macs:.3e} MACs, "
              f"{stream.arithmetic_intensity():.1f} MACs/DRAM-byte")
        if args.stream:
            for g in stream.gemms:
                print(f"   {g.name:16s} M={g.M:<7d} K={g.K:<7d} N={g.N:<7d} "
                      f"x{g.count}")
        rep = study.run().report
        for pol in (rep.per_layer, rep.fixed):
            if not pol.feasible:
                print(f"   {pol.policy:9s}: NO feasible design under the "
                      f"thermal limit ({rep.thermal_limit:.0f} C)")
                continue
            d = pol.design if pol.policy == "fixed" else pol.design[0]
            tag = (f"{int(d[0])}x{int(d[1])}x{int(d[2])}"
                   + ("" if pol.policy == "fixed" else " (first layer)"))
            print(f"   {pol.policy:9s}: {pol.total_cycles:.3e} cycles "
                  f"({pol.time_s*1e3:.2f} ms) | {pol.speedup_vs_2d:.2f}x vs 2D "
                  f"| {pol.energy_j:.2e} J | EDP {pol.edp_js:.2e} Js "
                  f"| util {pol.utilization:.2f} | T_max {pol.t_max_c:.0f} C "
                  f"| {tag}")
        if rep.n_thermally_masked:
            print(f"   {rep.n_thermally_masked}/{rep.n_candidates} candidate "
                  f"designs thermally masked at {rep.thermal_limit:.0f} C")


if __name__ == "__main__":
    main()
