"""Quickstart: the paper's model, the simulator, and a tiny training run.

Run:  PYTHONPATH=src python examples/quickstart.py [--smoke]
(--smoke trims the training section to a few steps — the CI fast path.)
"""

import sys

import numpy as np

SMOKE = "--smoke" in sys.argv

# --- 1. The paper's analytical model (Eqs. 1-2) -----------------------------
from repro.core.analytical import optimal_tiers, speedup_3d, tau_2d, tau_3d

M, K, N = 64, 12100, 147  # ResNet50's RN0 layer as a GEMM (Table I)
print("tau_2d(64x64 array)      :", int(tau_2d(M, K, N, 64, 64)), "cycles")
print("tau_3d(8 tiers of 64x64) :", int(tau_3d(M, K, N, 64, 64, 8)), "cycles")
l, cyc = optimal_tiers(M, K, N, n_macs=2**18)
print(f"optimal tiers @ 2^18 MACs: l*={l}  speedup={speedup_3d(M,K,N,2**18,l):.2f}x")

# --- 2. The cycle-level 3D systolic array actually computing a GEMM ---------
from repro.core.systolic import simulate_dos_3d

A = np.random.default_rng(0).normal(size=(8, 64)).astype(np.float32)
B = np.random.default_rng(1).normal(size=(64, 8)).astype(np.float32)
r = simulate_dos_3d(A, B, 8, 8, tiers=4)
print("dOS simulator exact:", np.allclose(np.asarray(r.out), A @ B, atol=1e-4),
      f"({r.cycles} cycles, {r.tiers} tiers)")

# --- 3. The same idea as a mesh sharding choice (the advisor) ----------------
from repro.core.advisor import GemmShard, choose_sharding

for name, g in [
    ("train GEMM (1M tokens)", GemmShard(M=1 << 20, K=4096, N=4096, axis=16)),
    ("decode GEMM (8 tokens)", GemmShard(M=8, K=8192, N=8192, axis=16)),
]:
    print(f"advisor[{name}] -> {choose_sharding(g).name}")

# --- 4. The same question, bandwidth-aware (one declarative Study) ----------
from repro.core.study import AnalysisSpec, BandwidthSpec, Study, WorkloadSpec, SpaceSpec

res = Study(
    workload=WorkloadSpec(kind="gemms", gemms=[(M, K, N)]),
    space=SpaceSpec(mac_budgets=[2**18], tiers=range(1, 17)),
    analysis=AnalysisSpec(kind="roofline", bandwidth=BandwidthSpec.paper_default()),
).run()
r = res.result
best = int(np.nanargmax(np.where(r.feasible[0], r.speedup[0], np.nan)))
print(f"bandwidth-aware: best feasible tier count {int(r.grid.tiers[best])}, "
      f"{r.speedup[0, best]:.2f}x vs 2D ({r.bound[0, best]}-bound — the "
      f"compute-bound {speedup_3d(M, K, N, 2**18, l):.2f}x needs infinite DRAM)")

# --- 5. Train a tiny model end to end ------------------------------------------
from repro.configs import REGISTRY, reduced
from repro.launch.train import train_loop

cfg = reduced(REGISTRY["smollm-135m"])
steps = 5 if SMOKE else 20
_, losses, _ = train_loop(cfg, steps=steps, global_batch=4, seq_len=64, log_every=5)
print(f"tiny LM loss: {losses[0]:.3f} -> {losses[-1]:.3f}")
