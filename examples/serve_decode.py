"""Serve a small model with batched requests: prefill + decode loop.

Run:  PYTHONPATH=src python examples/serve_decode.py --arch gemma3-1b --smoke
"""

import argparse

from repro.configs import get_config, reduced
from repro.launch.serve import serve_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen-tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    r = serve_loop(cfg, batch=args.batch, prompt_len=args.prompt_len,
                   gen_tokens=args.gen_tokens)
    print(f"{cfg.name}: prefill {r['prefill_s']*1e3:.1f} ms | "
          f"decode {r['decode_tok_s']:.1f} tok/s (batch {args.batch})")
    print("sample tokens:", r["generated"][0, :16].tolist())


if __name__ == "__main__":
    main()
