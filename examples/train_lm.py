"""End-to-end driver: train SmolLM-135M (the real config) on the
synthetic pipeline for a few hundred steps with checkpointing.

Run:  PYTHONPATH=src python examples/train_lm.py --steps 200
(CPU-sized batch/seq; on a TPU pod the same driver takes the production
mesh + the full shapes. --smoke uses the reduced config for CI.)
"""

import argparse

from repro.configs import REGISTRY, reduced
from repro.launch.train import train_loop
from repro.optim import OptConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--strategy", default="dos")
    args = ap.parse_args()

    cfg = REGISTRY["smollm-135m"]
    if args.smoke:
        cfg = reduced(cfg)
    print(f"training {cfg.name} ({cfg.n_layers}L d{cfg.d_model}) "
          f"for {args.steps} steps")
    _, losses, wd = train_loop(
        cfg, steps=args.steps, global_batch=args.batch, seq_len=args.seq,
        strategy=args.strategy, ckpt_dir=args.ckpt_dir, ckpt_every=50,
        log_every=10,
        opt_cfg=OptConfig(lr=6e-4, warmup_steps=30, total_steps=args.steps),
    )
    k = max(len(losses) // 10, 1)
    first, last = sum(losses[:k]) / k, sum(losses[-k:]) / k
    print(f"loss: {first:.3f} -> {last:.3f} over {len(losses)} steps "
          f"({len(wd.slow_steps)} straggler steps)")


if __name__ == "__main__":
    main()
