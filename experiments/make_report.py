"""Generate the §Dry-run, §Roofline, §DSE, §Network, §Search and
§Calibrate sections.

Usage: PYTHONPATH=src python -m repro report            (the front door)
   or: PYTHONPATH=src python experiments/make_report.py [--sections ...]
Writes experiments/dryrun_section.md, experiments/roofline_section.md,
experiments/dse_section.md and experiments/network_section.md. The
roofline, DSE and network tables are recomputed live through
declarative ``core.study.Study`` specs — one ``roofline`` study (plus
its compute-bound ``evaluate`` twin) over every Table-I workload x
budget x tier under ``BandwidthSpec.paper_default()``, one ``evaluate``
study for the DSE table (optima restricted to thermally feasible
points), and one ``schedule`` study per model-zoo cell
(per-layer-optimal vs fixed-design policies). The TPU dry-run
artifact tables (experiments/dryrun/) are appended when artifacts
exist. EXPERIMENTS.md includes their content verbatim.
"""

from __future__ import annotations

import json
import pathlib

import numpy as np

HERE = pathlib.Path(__file__).resolve().parent
ART = HERE / "dryrun"

ARCH_ORDER = [
    "llama-3.2-vision-11b", "smollm-135m", "qwen2.5-3b", "qwen2-72b",
    "gemma3-1b", "whisper-medium", "zamba2-2.7b", "deepseek-moe-16b",
    "llama4-scout-17b-a16e", "xlstm-125m",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(strategy=None):
    arts = {}
    for p in sorted(ART.glob("*.json")):
        a = json.loads(p.read_text())
        if strategy and a.get("strategy") != strategy:
            continue
        arts[(a["arch"], a["shape"], a["mesh"], a.get("strategy", "dos"))] = a
    return arts


def fmt_bytes(b):
    return f"{b/2**30:.2f} GiB"


def dryrun_section(arts):
    lines = [
        "### Per-cell dry-run results (strategy: dos = paper-faithful baseline)",
        "",
        "| arch | shape | mesh | compile | GB/dev | HLO GFLOPs/dev | collectives (counts) |",
        "|---|---|---|---|---|---|---|",
    ]
    ok_single = ok_multi = fail = 0
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            for mesh in ("pod16x16", "pod2x16x16"):
                a = arts.get((arch, shape, mesh, "dos"))
                if a is None:
                    continue
                if "error" in a:
                    fail += 1
                    lines.append(f"| {arch} | {shape} | {mesh} | **FAIL** | | | {a['error'][:60]} |")
                    continue
                if mesh == "pod16x16":
                    ok_single += 1
                else:
                    ok_multi += 1
                cost = a.get("cost_corrected", a["cost"])
                cc = a.get("collectives_corrected", a["collectives"])["counts"]
                cstr = " ".join(f"{k.split('-')[-1][:4]}:{v}" for k, v in sorted(cc.items()))
                lines.append(
                    f"| {arch} | {shape} | {mesh} | {a['compile_s']:.0f}s "
                    f"| {a['memory']['peak_per_device_gb']:.1f} "
                    f"| {cost.get('flops',0)/1e9:,.0f} | {cstr} |"
                )
    lines.insert(0, f"**{ok_single} single-pod + {ok_multi} multi-pod cells compiled OK; {fail} failures.**\n")
    return "\n".join(lines) + "\n"


def roofline_section(arts, mac_budgets=(2**14, 2**16, 2**18), max_tiers=16,
                     cache=None):
    """Engine-backed roofline: the paper's Table-I workloads under a
    finite memory system (``BandwidthSpec.paper_default()``), next to
    the compute-bound prediction.

    Two declarative studies over the same (budget x tier) grid — one
    plain ``evaluate`` (the paper's peak-compute optimism) and one
    ``roofline`` (DRAM + SRAM reuse + TSV vertical links) — so the
    table shows, per (workload, budget): the compute-optimal tier
    count and speedup, the bandwidth-aware winner (which can differ),
    its bound class, and the stall share. The TPU dry-run artifact
    table (when artifacts exist) follows as the scale-out counterpart.
    """
    from repro.core.bandwidth import BandwidthSpec
    from repro.core.dse import PAPER_WORKLOADS
    from repro.core.study import AnalysisSpec, SpaceSpec, Study, WorkloadSpec

    bw = BandwidthSpec.paper_default()
    names = list(PAPER_WORKLOADS)
    wl = [PAPER_WORKLOADS[n] for n in names]
    space = SpaceSpec(mac_budgets=mac_budgets, tiers=tuple(range(1, max_tiers + 1)))
    workload = WorkloadSpec(kind="gemms", gemms=wl)
    comp = Study(
        name="report-roofline-compute", workload=workload, space=space,
    ).run(cache=cache).result
    res = Study(
        name="report-roofline-bw", workload=workload, space=space,
        analysis=AnalysisSpec(kind="roofline", bandwidth=bw),
    ).run(cache=cache).result

    W, B, T = len(wl), len(mac_budgets), max_tiers
    lines = [
        "### Engine roofline (Table-I workloads, dOS, TSV, "
        f"{bw.dram_gbs:.0f} GB/s DRAM, {bw.sram_kib_per_tier:.0f} KiB "
        "SRAM/tier)",
        "",
        "Compute-bound columns are the paper's model (Eqs. 1/2); the",
        "bandwidth-aware columns charge DRAM traffic under the SRAM reuse",
        "model and TSV vertical-link service time, and take the roofline",
        "`max(compute, memory, vlink)` per design point. The 2D baseline",
        "pays the same memory system, so `speedup` is honest on both sides.",
        "",
        "| workload | MACs | l* (compute) | speedup (compute) "
        "| l* (bw-aware) | speedup (bw-aware) | bound | stall % |",
        "|---|---|---|---|---|---|---|---|",
    ]

    def best_per(res_):
        cyc = np.where(res_.feasible, res_.cycles, np.inf).reshape(W, B, T)
        return np.argmin(cyc, axis=2)

    bc, bb = best_per(comp), best_per(res)
    for wi, nm in enumerate(names):
        for bi, b in enumerate(mac_budgets):
            pc, pb = bi * T + bc[wi, bi], bi * T + bb[wi, bi]
            stall = res.stall_cycles[wi, pb] / res.cycles[wi, pb]
            lines.append(
                f"| {nm} | 2^{int(np.log2(b))} | {bc[wi, bi] + 1} "
                f"| {comp.speedup[wi, pc]:.2f}x | {bb[wi, bi] + 1} "
                f"| {res.speedup[wi, pb]:.2f}x | **{res.bound[wi, pb]}** "
                f"| {100 * stall:.0f} |"
            )
    v = res.valid
    hist = {n: int(np.sum(v & (res.bound == n)))
            for n in ("compute", "memory", "vlink")}
    flips = int(np.sum(bc != bb))
    lines.append(
        f"\nBound mix over the {v.sum()}-point grid: {hist}; the "
        f"bandwidth-aware tier optimum differs from the compute-bound one "
        f"in {flips}/{W * B} (workload, budget) cells."
    )
    if arts:
        lines += ["", "### TPU dry-run roofline (scale-out counterpart)", ""]
        lines += _artifact_roofline_table(arts)
    return "\n".join(lines) + "\n"


def _artifact_roofline_table(arts):
    lines = [
        "| arch | shape | GB/dev | compute s | memory s (hlo / kernel) | collective s | dominant | MODEL/HLO | MFU | what would move the dominant term |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            a = arts.get((arch, shape, "pod16x16", "dos"))
            if a is None or "error" in a:
                continue
            r = a["roofline"]
            note = _note(a)
            lines.append(
                f"| {arch} | {shape} | {a['memory']['peak_per_device_gb']:.1f} "
                f"| {r['compute_s']:.3f} | {r['memory_s']:.3f} / {r['memory_s_kernel']:.3f} "
                f"| {r['collective_s']:.3f} | **{r['dominant']}** "
                f"| {r['useful_ratio']:.2f} | {r['mfu']*100:.2f}% | {note} |"
            )
    return lines


def _note(a):
    r = a["roofline"]
    d = r["dominant"]
    if d == "collective":
        return ("drop pure-dOS K-sharding where M*N/device is large "
                "(advisor: megatron/DP mix); reduce-scatter chaining")
    if d == "memory":
        if a["mode"] == "decode":
            return "cache layout/quantization; batch more requests per step"
        return "fuse optimizer+grad traffic; larger microbatches"
    return "near roofline: block-size/layout tuning only"


def dse_section(mac_budgets=(2**14, 2**16, 2**18), max_tiers=16, cache=None):
    """Study-backed DSE summary: per Table-I workload x MAC budget, the
    optimal tier count with its speedup, power, perf/area and T_max —
    one declarative ``evaluate`` study over the full grid (a single
    batched engine pass). Optima are restricted to the thermally
    feasible points (``res.feasible``); at the paper's scales nothing
    is masked (its Fig. 8 finding), but the constraint is structural,
    not assumed."""
    from repro.core.dse import PAPER_WORKLOADS
    from repro.core.study import SpaceSpec, Study, WorkloadSpec

    names = list(PAPER_WORKLOADS)
    wl = [PAPER_WORKLOADS[n] for n in names]
    res = Study(
        name="report-dse",
        workload=WorkloadSpec(kind="gemms", gemms=wl),
        space=SpaceSpec(mac_budgets=mac_budgets,
                        tiers=tuple(range(1, max_tiers + 1))),
    ).run(cache=cache).result
    W, B, T = len(wl), len(mac_budgets), max_tiers
    cyc = np.where(res.feasible, res.cycles, np.inf).reshape(W, B, T)
    best = np.argmin(cyc, axis=2)  # optimal feasible tier per (workload, budget)

    def pick(arr):
        return np.take_along_axis(arr.reshape(W, B, T), best[:, :, None], 2)[:, :, 0]

    speed = pick(res.speedup)
    power = pick(res.power_w)
    ans = pick(res.area_norm_speedup)
    tmax = pick(res.t_max_c)
    lines = [
        "### Engine DSE summary (Table-I workloads, dOS, TSV)",
        "",
        "| workload | MACs | l* | speedup | power W | perf/area | T_max C |",
        "|---|---|---|---|---|---|---|",
    ]
    for wi, name in enumerate(names):
        for bi, b in enumerate(mac_budgets):
            lines.append(
                f"| {name} | 2^{int(np.log2(b))} | {best[wi, bi] + 1} "
                f"| {speed[wi, bi]:.2f}x | {power[wi, bi]:.2f} "
                f"| {ans[wi, bi]:.2f}x | {tmax[wi, bi]:.0f} |"
            )
    masked = int(np.sum(res.valid & ~res.feasible))
    lines.append(
        f"\n{masked} of {res.valid.sum()} valid design points thermally "
        f"masked at the {res.grid.n_points}-point grid (junction limit)."
    )
    return "\n".join(lines) + "\n"


def network_section(shapes=("train_4k", "prefill_32k", "decode_32k"), cache=None):
    """Network-level results: one declarative ``schedule`` study per
    model-zoo cell — lowered to its GEMM stream and scheduled through
    the engine, per-layer-optimal vs one fixed array design, end-to-end
    cycles/energy/EDP and 3D-vs-2D speedup."""
    from repro.configs import cells
    from repro.core.study import AnalysisSpec, Study, WorkloadSpec

    lines = [
        "### Network-level schedule (zoo -> lowering -> engine.schedule)",
        "",
        "Two mapping policies per network: `per-layer` (every GEMM on its",
        "own best feasible array — the DSE upper bound) and `fixed` (one",
        "rows x cols x tiers design serves all layers — the buildable",
        "accelerator). Speedup is vs the budget-matched optimized 2D",
        "baseline; designs over the junction limit are excluded.",
        "",
        "| network | shape | gemms (inv) | fixed design RxCxL | fixed cycles "
        "| fixed/opt | 3D-vs-2D | energy J | EDP Js | T_max C |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    live, _ = cells()
    for arch, shape in live:
        if shape not in shapes:
            continue
        rep = Study(
            name=f"report-network-{arch}-{shape}",
            workload=WorkloadSpec(kind="network", arch=arch, shape=shape),
            analysis=AnalysisSpec(kind="schedule"),
        ).run(cache=cache).report
        fx, pl = rep.fixed, rep.per_layer
        r, c, l = (int(x) for x in np.asarray(fx.design).reshape(-1)[:3])
        lines.append(
            f"| {rep.arch} | {rep.shape} | {rep.n_gemms} ({rep.n_gemm_invocations}) "
            f"| {r}x{c}x{l} | {fx.total_cycles:.3e} "
            f"| {fx.total_cycles / pl.total_cycles:.3f} "
            f"| {fx.speedup_vs_2d:.2f}x | {fx.energy_j:.2e} "
            f"| {fx.edp_js:.2e} | {fx.t_max_c:.0f} |"
        )
    return "\n".join(lines) + "\n"


def search_section(cache=None):
    """Guided Pareto search demo: the example ``kind='search'`` study
    (budgets x tiers x dataflow x tech x DRAM x SRAM grades) priced to
    its cycles/energy frontier at a few-percent evaluated fraction —
    the machinery `benchmarks/search_bench.py` scales to ~1e9 points."""
    from repro.core.study import Study

    out = Study.example("search").run(cache=cache)
    p = out.payload
    names = p["axis_names"]
    axes = " x ".join(f"{n}({len(p['axes'][n])})" for n in names)
    F = np.asarray(p["frontier_objectives"])
    idx = np.unique(np.linspace(0, len(F) - 1, 10).astype(int))
    lines = [
        "### Guided Pareto search (kind='search')",
        "",
        out.describe(),
        "",
        f"Space: {axes}; deterministic for the spec's seed, resumable "
        "per generation (`--cache`), multi-process (`--workers N`).",
        "",
        "| " + " | ".join(names) + " | " + " | ".join(p["objectives"]) + " |",
        "|" + "---|" * (len(names) + len(p["objectives"])),
    ]
    for i in idx:
        design = [f"{p['frontier_designs'][n][i]}" for n in names]
        objs = [f"{v:.3e}" for v in F[i]]
        lines.append("| " + " | ".join(design + objs) + " |")
    lines.append(
        f"\n{len(F)} frontier points; {len(idx)} shown (evenly sampled "
        f"along the cycles-sorted frontier); hypervolume "
        f"{p['hypervolume']:.4e} against ref {p['ref_point']}."
    )
    return "\n".join(lines) + "\n"


def calibrate_section(cache=None):
    """Measured-model calibration: the example ``kind='calibrate'``
    study (smoke grid — the full grid is ``preset='default'`` via
    ``benchmarks/calibrate_bench.py``) measured on this machine's
    backend and fitted to the roofline; the table is per-shape
    measured vs modeled time. Wall times are backend-local, so this
    section is honest about *where* it ran."""
    import jax

    from repro.core.study import Study

    out = Study.example("calibrate").run(cache=cache)
    p = out.payload
    e = p["errors"]
    lines = [
        "### Calibrated roofline (kind='calibrate')",
        "",
        out.describe(),
        "",
        f"Backend: `{jax.default_backend()}`. Fitted DRAM "
        f"{p['dram_gbs_fitted']:.2f} GB/s; holdout median relative error "
        f"{e['holdout_median_rel_err']:.1%} vs "
        f"{e['uncalibrated_holdout_median_rel_err']:.1%} for the "
        "uncalibrated nominal constants. The `artifact` in the study "
        "payload is a `CalibratedBandwidth` any other study accepts via "
        "`bandwidth=`.",
        "",
        "| shape | t measured | t model | rel err | GFLOP/s | GB/s |",
        "|---|---|---|---|---|---|",
    ]
    for r in p["rows"]:
        lines.append(
            f"| {r['label']} | {r['t_s']*1e3:.2f} ms | {r['pred_s']*1e3:.2f} ms "
            f"| {r['rel_err']:.1%} | {r['achieved_gflops']:.1f} "
            f"| {r['achieved_gbs']:.2f} |"
        )
    return "\n".join(lines) + "\n"


def serve_section(cache=None):
    """Serving-traffic study: the example ``kind='serve'`` study (a
    seeded mixed prefill/decode trace on a zoo model, priced per design
    point through the bandwidth-aware engine) reduced to the sustained
    serving metrics — the production-facing counterpart of the
    single-GEMM speedup tables. The full 3D-vs-2D comparison on a
    larger model is ``benchmarks/serve_bench.py`` / ``BENCH_serve.json``."""
    from repro.core.study import Study

    out = Study.example("serve").run(cache=cache)
    p = out.payload
    pts = p["points"]
    t = out.study.analysis.serve.traffic
    lines = [
        "### Serving traffic (kind='serve')",
        "",
        out.describe(),
        "",
        f"Trace: {t.n_requests} requests at {t.arrival_rps:g} req/s "
        f"({p['trace']['tokens_in']} prompt + {p['trace']['tokens_out']} "
        f"generated tokens), max batch {t.max_batch}, {t.policy} batching, "
        f"chunked prefill at {t.chunk_prefill} tokens/step; each queue step "
        "is one vectorized engine call over all design points (seeded — "
        "re-runs and `--cache`/`--resume` are bit-identical).",
        "",
        "| design (RxCxL) | tech | feas | tok/s | TTFT p50/p99 [ms] "
        "| TPOT p50/p99 [ms] | E/token [mJ] | tok/s/W | stall |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for i in range(p["n_points"]):
        lines.append(
            f"| {pts['rows'][i]}x{pts['cols'][i]}x{pts['tiers'][i]} "
            f"| {pts['tech'][i]} | {'yes' if pts['feasible'][i] else 'no'} "
            f"| {pts['gen_tok_s'][i]:.0f} "
            f"| {pts['ttft_p50_s'][i]*1e3:.2f}/{pts['ttft_p99_s'][i]*1e3:.2f} "
            f"| {pts['tpot_p50_s'][i]*1e3:.2f}/{pts['tpot_p99_s'][i]*1e3:.2f} "
            f"| {pts['energy_per_token_j'][i]*1e3:.2f} "
            f"| {pts['tokens_per_s_per_w'][i]:.0f} "
            f"| {pts['stall_frac'][i]:.0%} |"
        )
    s = p["summary"]
    if s["win_3d_vs_2d"] is not None:
        lines.append(
            f"\nBest feasible 3D vs best feasible 2D on tokens/s/W: "
            f"{s['win_3d_vs_2d']:.2f}x."
        )
    return "\n".join(lines) + "\n"


def thermal_section(cache=None):
    """Transient thermal/DVFS: the example serve study re-run with
    ``thermal='transient'`` under a junction limit tightened to just
    above the coolest point's steady-state temperature, so every design
    throttles — the table shows what the worst-case steady gate hides:
    sustained tokens/s under the governor next to the peak the steady
    model advertises, with the governed temperature excursion and the
    throttled-state residency. The pinned feasibility-flip benchmark is
    ``benchmarks/thermal_bench.py`` / ``BENCH_thermal.json``."""
    import dataclasses

    from repro.core.study import Study

    base = Study.example("serve")
    steady = base.run(cache=cache)
    t_hot = steady.payload["points"]["t_max_c"]
    limit = float(np.round(np.nanmin(t_hot) + 2.0, 1))
    tight = dataclasses.replace(
        base,
        name=base.name + "-transient",
        constraints=dataclasses.replace(
            base.constraints, thermal_limit_c=limit
        ),
        analysis=dataclasses.replace(base.analysis, thermal="transient"),
    )
    out = tight.run(cache=cache)
    p = out.payload
    pts = p["points"]
    dv = p["dvfs"]
    states = "/".join(f"{f:g}" for f in dv["freqs_ghz"])
    lines = [
        "### Transient thermal / DVFS (thermal='transient')",
        "",
        out.describe(),
        "",
        f"Junction limit tightened to {limit:.1f} degC (steady-state "
        f"coolest point + 2); governor states {states} GHz, throttle "
        f"margin {dv['throttle_margin_c']:g} degC, hysteresis "
        f"{dv['hysteresis_c']:g} degC. 'steady' marks the worst-case "
        "steady-state verdict at the fixed 1 GHz clock; every struck "
        "design still serves at the governed sustained rate.",
        "",
        "| design (RxCxL) | tech | steady | transient | peak tok/s "
        "| sustained tok/s | peak/sustained | T_max gov [degC] "
        "| top-state residency |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for i in range(p["n_points"]):
        resid_top = pts["dvfs_residency"][i][-1]
        lines.append(
            f"| {pts['rows'][i]}x{pts['cols'][i]}x{pts['tiers'][i]} "
            f"| {pts['tech'][i]} "
            f"| {'yes' if pts['feasible_steady'][i] else 'no'} "
            f"| {'yes' if pts['feasible'][i] else 'no'} "
            f"| {pts['peak_tok_s'][i]:.0f} "
            f"| {pts['gen_tok_s'][i]:.0f} "
            f"| {pts['peak_vs_sustained'][i]:.2f}x "
            f"| {pts['t_max_transient_c'][i]:.1f} "
            f"| {resid_top:.0%} |"
        )
    n_flip = int(np.sum(pts["feasible"] & ~pts["feasible_steady"]))
    lines.append(
        f"\n{n_flip} of {p['n_points']} designs are steady-infeasible at "
        "this limit yet serve within it under the governor — the "
        "peak-vs-sustained gap is the number the steady gate cannot see."
    )
    return "\n".join(lines) + "\n"


def main(sections=None, cache=None):
    """Regenerate the requested sections (None = all). This is what
    ``python -m repro report`` drives. ``cache`` (a directory path)
    makes the live DSE/network studies chunk-cached: re-generating the
    report recomputes nothing that already ran — the sections come out
    bit-identical either way (chunking never changes results)."""
    sections = (
        set(sections)
        if sections
        else {"dryrun", "roofline", "dse", "network", "search", "calibrate",
              "serve", "thermal"}
    )
    if cache is not None:
        from repro.core.cache import ResultCache

        cache = cache if isinstance(cache, ResultCache) else ResultCache(cache)
    arts = load() if sections & {"dryrun", "roofline"} else {}
    if "dryrun" in sections:
        (HERE / "dryrun_section.md").write_text(dryrun_section(arts))
    if "roofline" in sections:
        (HERE / "roofline_section.md").write_text(roofline_section(arts, cache=cache))
    if "dse" in sections:
        (HERE / "dse_section.md").write_text(dse_section(cache=cache))
    if "network" in sections:
        (HERE / "network_section.md").write_text(network_section(cache=cache))
    if "search" in sections:
        (HERE / "search_section.md").write_text(search_section(cache=cache))
    if "calibrate" in sections:
        (HERE / "calibrate_section.md").write_text(calibrate_section(cache=cache))
    if "serve" in sections:
        (HERE / "serve_section.md").write_text(serve_section(cache=cache))
    if "thermal" in sections:
        (HERE / "thermal_section.md").write_text(thermal_section(cache=cache))
    if "roofline" not in sections:
        return
    # machine-readable summary for the hillclimb
    rows = []
    for (arch, shape, mesh, strat), a in arts.items():
        if mesh != "pod16x16" or "error" in a:
            continue
        r = a["roofline"]
        rows.append({
            "arch": arch, "shape": shape, "strategy": strat,
            "dominant": r["dominant"], "step_s": r["step_s"],
            "mfu": r["mfu"], "collective_s": r["collective_s"],
            "compute_s": r["compute_s"],
            "mem_gb": a["memory"]["peak_per_device_gb"],
        })
    rows.sort(key=lambda x: x["mfu"])
    (HERE / "summary.json").write_text(json.dumps(rows, indent=1))
    print(f"{len(rows)} single-pod cells summarized; worst MFU:")
    for r in rows[:6]:
        print(f"  {r['arch']}/{r['shape']}/{r['strategy']}: mfu={r['mfu']*100:.2f}% "
              f"dom={r['dominant']} step={r['step_s']*1e3:.1f}ms mem={r['mem_gb']}GB")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--sections", nargs="*", default=None,
                    choices=["dryrun", "roofline", "dse", "network", "search",
                             "calibrate", "serve", "thermal"])
    main(sections=ap.parse_args().sections)
