"""Shims for jax API drift (0.4.x image vs >= 0.5/0.7 APIs).

Every version-dependent lookup lives here so a future jax bump is a
one-file change: `shard_map`, Pallas `CompilerParams`,
`make_mesh(axis_types=...)`, `lax.pcast`, and the `cost_analysis()`
return shape.
"""

from __future__ import annotations

import jax

__all__ = [
    "shard_map",
    "pallas_tpu_compiler_params",
    "make_mesh",
    "pcast",
    "unwrap_cost_analysis",
]

# shard_map: top-level `jax.shard_map` since ~0.6; experimental before,
# where it also lacks replication rules for checkpoint_name etc. — so
# the fallback skips the (new-jax-only) replication check.
shard_map = getattr(jax, "shard_map", None)
if shard_map is None:

    from jax.experimental.shard_map import shard_map as _experimental_shard_map

    def shard_map(f, **kw):
        kw.setdefault("check_rep", False)
        return _experimental_shard_map(f, **kw)


def pallas_tpu_compiler_params():
    """`pltpu.CompilerParams`, named `TPUCompilerParams` before jax 0.5."""
    from jax.experimental.pallas import tpu as pltpu

    return getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def make_mesh(shape, axes):
    """`jax.make_mesh` with Auto axis types where supported.

    jax < 0.5 has no AxisType / axis_types kwarg; Auto is the default
    behavior there, so omitting it is equivalent.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def pcast(x, axes, to):
    """`jax.lax.pcast`, identity on jax < 0.7 (no varying-type system)."""
    fn = getattr(jax.lax, "pcast", None)
    return x if fn is None else fn(x, axes, to=to)


def unwrap_cost_analysis(cost):
    """jax < 0.5 wraps the compiled cost dict in a single-element list."""
    if isinstance(cost, (list, tuple)):
        return cost[0]
    return cost
