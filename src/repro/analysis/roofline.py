"""Three-term roofline from a compiled dry-run artifact.

    compute term    = HLO_FLOPs / peak_FLOP/s            (per chip)
    memory term     = HLO_bytes / HBM_bw                 (per chip)
    collective term = collective_wire_bytes / link_bw    (per chip)

``cost_analysis()`` on the SPMD-compiled module is already per-device
(flops / bytes of one chip's program). Collective bytes are NOT in
cost_analysis: we parse the compiled HLO text, take every
all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute result shape (per-device under SPMD) and convert to
ring wire-bytes with the op-specific factor:

    all-reduce        2 (g-1)/g * bytes      (reduce-scatter + all-gather ring)
    all-gather          (g-1)/g * bytes      (result bytes = full buffer)
    reduce-scatter      (g-1)   * bytes      (result bytes = one shard)
    all-to-all          (g-1)/g * bytes
    collective-permute  1       * bytes

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link
ICI (one link per axis direction assumed busy — the pessimistic single-
link model; overlap across axes is an optimization the §Perf loop can
claim explicitly).
"""

from __future__ import annotations

import dataclasses
import re

import numpy as np

from ..core.ppa import constants as HW

__all__ = [
    "CollectiveStats",
    "parse_collectives",
    "Roofline",
    "roofline_from_artifact",
    "roofline_terms_batched",
]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s+(?:\(([^)]*)\)|(\w+)\[([\d,]*)\][^ ]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_TUPLE_PART = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> float:
    bpe = _DTYPE_BYTES.get(dtype)
    if bpe is None:
        return 0.0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return float(n * bpe)


@dataclasses.dataclass
class CollectiveStats:
    wire_bytes: float  # ring wire bytes per device (factor-adjusted)
    result_bytes: float  # raw result bytes
    counts: dict  # op -> count
    by_op_bytes: dict  # op -> wire bytes


def parse_collectives(hlo_text: str) -> CollectiveStats:
    counts: dict = {}
    by_op: dict = {}
    wire = 0.0
    raw = 0.0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        tuple_body, dtype, dims, op = m.groups()
        if tuple_body is not None:
            rb = sum(
                _shape_bytes(d, s) for d, s in _TUPLE_PART.findall(tuple_body)
            )
        else:
            rb = _shape_bytes(dtype, dims)
        g = _group_size(line)
        if op == "all-reduce":
            factor = 2.0 * (g - 1) / g
        elif op == "all-gather":
            factor = (g - 1) / g
        elif op == "reduce-scatter":
            factor = float(g - 1)
        elif op == "all-to-all":
            factor = (g - 1) / g
        else:  # collective-permute
            factor = 1.0
        counts[op] = counts.get(op, 0) + 1
        by_op[op] = by_op.get(op, 0.0) + rb * factor
        wire += rb * factor
        raw += rb
    return CollectiveStats(wire_bytes=wire, result_bytes=raw, counts=counts, by_op_bytes=by_op)


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    return 2  # unknown grouping: assume a pair (conservative-low)


def roofline_terms_batched(
    compute_s,
    memory_s,
    collective_s,
    memory_s_kernel=0.0,
):
    """Batched three-term artifact roofline — the one combiner.

    Vectorized over broadcastable per-cell term arrays [seconds].
    Returns a dict of arrays: ``step_s`` (max(compute, effective
    memory) + collective — compute/memory overlap on TPU, the
    collective is the paper-faithful serialized adder pile),
    ``stall_s`` (step minus compute: time the MXUs are not the
    bottleneck), and ``dominant`` ('compute' | 'memory' | 'collective',
    ties toward the earlier name). ``memory_s_kernel`` > 0 overrides
    ``memory_s`` per cell (Pallas kernels keep flash/SSD blocks in
    VMEM; the jnp-fallback HLO overstates those bytes).

    The scalar ``Roofline`` properties are batch-of-one wrappers over
    this function, so per-artifact and batched tables can never drift
    (regression-pinned on the parse fixtures) — the same
    scalar-wraps-batched contract as ``core.engine`` /
    ``core.bandwidth.roofline_cycles``, which applies the overlapped
    max to engine cycles instead of artifact seconds.
    """
    compute, mem, mem_k, coll = np.broadcast_arrays(
        *(np.asarray(x, dtype=np.float64)
          for x in (compute_s, memory_s, memory_s_kernel, collective_s))
    )
    mem_eff = np.where(mem_k > 0, mem_k, mem)
    step = np.maximum(compute, mem_eff) + coll
    names = np.asarray(("compute", "memory", "collective"))
    dominant = names[
        np.where(
            coll > np.maximum(compute, mem_eff),
            2,
            np.where(mem_eff > compute, 1, 0),
        )
    ]
    return {
        "memory_s_effective": mem_eff,
        "step_s": step,
        "stall_s": step - compute,
        "dominant": dominant,
    }


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    n_chips: int
    hlo_flops: float  # per device
    hlo_bytes: float  # per device
    wire_bytes: float  # per device
    model_flops: float  # 6*N*D useful flops, global
    compute_s: float
    memory_s: float
    collective_s: float
    collective_counts: dict
    # kernel-aware analytic HBM traffic (Pallas kernels keep flash/SSD
    # blocks in VMEM; the jnp-fallback HLO overstates those bytes).
    memory_s_kernel: float = 0.0

    def _terms(self) -> dict:
        """Batch-of-one delegation to ``roofline_terms_batched``."""
        return roofline_terms_batched(
            self.compute_s, self.memory_s, self.collective_s,
            self.memory_s_kernel,
        )

    @property
    def dominant(self) -> str:
        return str(np.asarray(self._terms()["dominant"]).reshape(-1)[0])

    @property
    def step_s(self) -> float:
        """Pessimistic step estimate: max(compute, kernel-true memory)
        + collective (the paper-faithful sequential adder pile)."""
        return float(np.asarray(self._terms()["step_s"]).reshape(-1)[0])

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs * chips): remat/dispatch overhead."""
        total_hlo = self.hlo_flops * self.n_chips
        return self.model_flops / total_hlo if total_hlo else 0.0

    @property
    def mfu(self) -> float:
        """Model-flops utilization at the roofline step estimate."""
        denom = self.step_s * self.n_chips * HW.TPU_PEAK_FLOPS_BF16
        return self.model_flops / denom if denom else 0.0

    @property
    def roofline_fraction(self) -> float:
        """max-term / step: 1.0 = the dominant term is the whole step."""
        m = max(self.compute_s, self.memory_s, self.collective_s)
        return m / self.step_s if self.step_s else 0.0

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(
            dominant=self.dominant,
            step_s=self.step_s,
            useful_ratio=self.useful_ratio,
            mfu=self.mfu,
        )
        return d


def roofline_from_artifact(
    *,
    arch: str,
    shape: str,
    mesh_name: str,
    n_chips: int,
    cost: dict,
    coll: CollectiveStats,
    model_flops: float,
    kernel_bytes: float = 0.0,
) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    return Roofline(
        arch=arch,
        shape=shape,
        mesh=mesh_name,
        n_chips=n_chips,
        hlo_flops=flops,
        hlo_bytes=byts,
        wire_bytes=coll.wire_bytes,
        model_flops=model_flops,
        compute_s=flops / HW.TPU_PEAK_FLOPS_BF16,
        memory_s=byts / HW.TPU_HBM_BW,
        collective_s=coll.wire_bytes / HW.TPU_ICI_BW_PER_LINK,
        collective_counts=coll.counts,
        memory_s_kernel=kernel_bytes / HW.TPU_HBM_BW,
    )
