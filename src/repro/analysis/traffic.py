"""Kernel-aware analytic HBM traffic model (per device, per step).

The dry-run's HLO ``bytes accessed`` is exact for the *CPU fallback*
graph — but the fallback materializes flash/SSD probability blocks that
the Pallas kernels keep in VMEM on the TPU target. This module computes
the TPU-kernel-true HBM traffic from the model structure; the roofline
reports both (HLO per spec, kernel-adjusted for optimization decisions).

Accounting (2-byte activations/weights unless stated):

weights  train: mb grad-accum passes read the device's weight shard
         twice (fwd+bwd) in bf16; gradients accumulate in f32 (r+w per
         microbatch); AdamW reads/writes p, m, v in f32 once per step.
         serve: one bf16 read of the weight shard per step.
activations  per layer per local token: residual stream r/w + block
         in/out traffic (q/k/v/o, MLP hidden r+w, SSD inner), x3 for
         backward (recompute read + grad traffic) under remat.
attention kernel: reads q, k, v once, writes o (no S^2 traffic);
         backward ~2x forward reads + dq/dk/dv writes.
kv cache decode: full cache shard read per step + one slot written.
logits:  bf16 write + f32 softmax r/w on the vocab shard.
"""

from __future__ import annotations

from ..config import ArchConfig, ShapeConfig
from ..core.ppa import constants as HW

__all__ = [
    "attn_ssm_layer_split",
    "hbm_seconds_per_device",
    "kv_bytes_per_context_token",
    "state_bytes_per_request",
    "traffic_bytes_per_device",
]

_B2, _B4 = 2, 4


def attn_ssm_layer_split(cfg: ArchConfig) -> tuple[int, int]:
    """(n_attention_layers, n_ssm_layers) of one forward pass.

    Hybrids (zamba2) run an SSM backbone of ``n_layers`` blocks PLUS a
    weight-shared attention+MLP block applied every ``attn_every``
    layers (``core.network._lower_hybrid``); pure-attention families
    have ``n_attn = n_layers``, pure SSM ``n_ssm = n_layers``. The one
    split every per-layer accounting in this module (and the serving
    simulator's kv-cache pricing) agrees on.
    """
    if cfg.family == "hybrid":
        n_attn = cfg.n_layers // cfg.attn_every if cfg.attn_every else 0
        return n_attn, cfg.n_layers
    if cfg.family == "ssm":
        return 0, cfg.n_layers
    return cfg.n_layers, 0


def kv_bytes_per_context_token(cfg: ArchConfig, bytes_kv: int = _B2) -> float:
    """kv-cache footprint [bytes] of ONE context token across all
    attention layers (K + V, ``n_kv_heads x head_dim`` each).

    A decode step reads ``context_len *`` this per request (the full
    cache shard read of ``traffic_bytes_per_device``) and writes one
    new slot; the serving simulator (``core.serve``) prices both
    against the DRAM interface.
    """
    n_attn, _ = attn_ssm_layer_split(cfg)
    return float(n_attn * 2 * cfg.n_kv_heads * cfg.head_dim_ * bytes_kv)


def state_bytes_per_request(cfg: ArchConfig) -> float:
    """SSM recurrent-state traffic [bytes] of one decode step for one
    request: the f32 state read + written once per SSM layer (the
    context-length-independent analogue of the kv cache)."""
    _, n_ssm = attn_ssm_layer_split(cfg)
    if not n_ssm:
        return 0.0
    di = cfg.ssm_expand * cfg.d_model
    nst = (di // cfg.ssm_head_dim) * cfg.ssm_state * cfg.ssm_head_dim
    return float(n_ssm * nst * _B4 * 2)


def hbm_seconds_per_device(
    cfg: ArchConfig,
    shape: ShapeConfig,
    n_params: int,
    *,
    hbm_bw: float = HW.TPU_HBM_BW,
    **kw,
) -> float:
    """Kernel-true HBM service time [s] of one step on one device.

    ``traffic_bytes_per_device(...) / hbm_bw`` — the memory term the
    roofline combiner (``analysis.roofline.roofline_terms_batched``)
    consumes as ``memory_s_kernel``; ``hbm_bw`` is bytes/s (default:
    the v5e HBM model). Keyword args pass through to
    ``traffic_bytes_per_device``.
    """
    return traffic_bytes_per_device(cfg, shape, n_params, **kw) / hbm_bw


def traffic_bytes_per_device(
    cfg: ArchConfig,
    shape: ShapeConfig,
    n_params: int,
    *,
    n_chips: int,
    model_ax: int = 16,
    microbatches: int = 1,
) -> float:
    mode = shape.mode
    tokens_local = shape.global_batch * shape.seq_len / max(n_chips / model_ax, 1)
    if mode == "decode":
        tokens_local = shape.global_batch / max(n_chips / model_ax, 1)
        tokens_local = max(tokens_local, 1.0)

    e = cfg.d_model
    hd = cfg.head_dim_
    h, kvh = cfg.n_heads, cfg.n_kv_heads
    f = cfg.expert_d_ff * (cfg.top_k + cfg.n_shared_experts) if cfg.family == "moe" else cfg.d_ff

    # --- weights + optimizer ---------------------------------------------
    w_shard = n_params / model_ax  # elements read per device per pass
    w_all_shard = n_params / n_chips  # FSDP storage shard (opt state)
    if mode == "train":
        w_traffic = microbatches * 2 * w_shard * _B2  # fwd + bwd bf16 reads
        w_traffic += microbatches * 2 * w_all_shard * _B4  # grad accum r+w f32
        w_traffic += 6 * w_all_shard * _B4  # adam p,m,v read+write
    else:
        w_traffic = w_shard * _B2

    # --- per-layer activation traffic (per local token) ---------------------
    # residual r/w (~6E), qkv out, attn o in/out, mlp hidden r+w (~3F incl
    # gate/up write + read), norms (~2E). Heads dims sharded over model.
    # Mixed-family layer split (see attn_ssm_layer_split) — attention
    # accounting scales with n_attn_layers, SSM accounting with
    # n_ssm_layers, so neither component is double- or zero-counted.
    n_attn_layers, n_ssm_layers = attn_ssm_layer_split(cfg)
    attn_io = (h * hd + 2 * kvh * hd + 2 * h * hd) / model_ax
    attn_blk = 8 * e / model_ax + attn_io + 3 * f / model_ax
    di = cfg.ssm_expand * e
    ssm_blk = 8 * e + (4 * di + 2 * cfg.ssm_state) / model_ax + 2 * di / model_ax
    fwd_act = (
        tokens_local
        * (n_attn_layers * attn_blk + n_ssm_layers * ssm_blk)
        * _B2
    )
    act_traffic = fwd_act * (3.0 if mode == "train" else 1.0)

    # --- attention kernel HBM traffic ----------------------------------------
    if n_attn_layers:
        qkv = tokens_local * (h + 2 * kvh) * hd / model_ax
        o = tokens_local * h * hd / model_ax
        per_layer = (qkv + o) * _B2
        if mode == "train":
            per_layer *= 3.0  # bwd rereads qkv/o/do + writes dq/dk/dv
        act_traffic += n_attn_layers * per_layer

    # --- kv cache / state (decode) ---------------------------------------------
    if mode == "decode":
        if n_attn_layers:
            # read the full local cache shard once
            act_traffic += (
                shape.global_batch * shape.seq_len
                * kv_bytes_per_context_token(cfg) / n_chips
            )
        if n_ssm_layers:
            act_traffic += (
                shape.global_batch * state_bytes_per_request(cfg) / n_chips
            )

    # --- logits ----------------------------------------------------------------
    v_shard = cfg.vocab / model_ax
    logit_traffic = tokens_local * v_shard * (_B2 + 2 * _B4)
    if mode == "train":
        logit_traffic *= 2.0

    return float(w_traffic + act_traffic + logit_traffic)
