from . import checkpointer
from .checkpointer import latest_step, restore, save, save_async, wait_for_saves

__all__ = ["checkpointer", "latest_step", "restore", "save", "save_async", "wait_for_saves"]
