"""Sharded checkpointing with async writes and elastic restore.

Layout: ``<dir>/step_<N>/`` holding one ``.npy`` per pytree leaf (path-
keyed flat names) plus ``meta.json`` (step, tree structure, completion
marker). Writes happen on a background thread after ``device_get`` (the
training loop keeps stepping — async checkpointing overlaps I/O with
compute). Restores return numpy trees that the caller ``device_put``s
with *current* shardings — which is exactly what makes restarts elastic:
a checkpoint taken on a (2,16,16) mesh restores onto any mesh whose
shardings divide the shapes, because leaves are stored as full arrays.

(On a real multi-host pod each host would write only its addressable
shards; single-process here writes full arrays — noted in DESIGN.md.)
"""

from __future__ import annotations

import json
import pathlib
import shutil
import threading

import jax
import numpy as np

__all__ = ["save", "save_async", "restore", "latest_step", "wait_for_saves"]

_FLAT_SEP = "__"
_pending: list[threading.Thread] = []


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _FLAT_SEP.join(_path_str(p) for p in path)
        out[key] = leaf
    return out, treedef


def _path_str(p):
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save(ckpt_dir, step: int, tree, keep: int = 3):
    """Synchronous checkpoint write."""
    host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
    _write(pathlib.Path(ckpt_dir), step, host_tree, keep)


def save_async(ckpt_dir, step: int, tree, keep: int = 3):
    """Device->host copy happens now; disk I/O on a background thread."""
    host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
    t = threading.Thread(
        target=_write, args=(pathlib.Path(ckpt_dir), step, host_tree, keep),
        daemon=True,
    )
    t.start()
    _pending.append(t)
    return t


def wait_for_saves():
    for t in _pending:
        t.join()
    _pending.clear()


def _write(root: pathlib.Path, step: int, host_tree, keep: int):
    flat, _ = _flatten(host_tree)
    tmp = root / f"step_{step}.tmp"
    final = root / f"step_{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    for key, leaf in flat.items():
        np.save(tmp / f"{key}.npy", leaf)
    (tmp / "meta.json").write_text(json.dumps({"step": step, "keys": sorted(flat)}))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)  # atomic completion marker
    # retention
    steps = sorted(
        int(p.name.split("_")[1]) for p in root.glob("step_*") if p.is_dir()
        and not p.name.endswith(".tmp")
    )
    for s in steps[:-keep]:
        shutil.rmtree(root / f"step_{s}", ignore_errors=True)


def latest_step(ckpt_dir) -> int | None:
    root = pathlib.Path(ckpt_dir)
    if not root.exists():
        return None
    steps = [
        int(p.name.split("_")[1])
        for p in root.glob("step_*")
        if p.is_dir() and (p / "meta.json").exists()
    ]
    return max(steps) if steps else None


def restore(ckpt_dir, step: int, like):
    """Load into the structure of ``like`` (a pytree or ParamDef tree of
    arrays / ShapeDtypeStructs). Returns a numpy pytree."""
    root = pathlib.Path(ckpt_dir) / f"step_{step}"
    flat_like, treedef = _flatten(like)
    leaves = []
    for key in flat_like:
        leaves.append(np.load(root / f"{key}.npy"))
    # tree_unflatten wants leaves in treedef order == flat_like order
    return jax.tree_util.tree_unflatten(treedef, leaves)
