"""``python -m repro`` — the shell front door over the DSE stack.

Subcommands:

- ``run <spec.json>``: load a declarative ``Study`` spec, compile it
  through the batched engine, and write the versioned ``StudyResult``
  artifact (JSON). ``-`` reads the spec from stdin. ``--cache DIR``
  stores every evaluated sub-grid chunk content-addressed under DIR
  (spec-hash keyed; see ``core.cache``); ``--resume DIR`` re-runs the
  spec persisted inside an existing cache directory, loading finished
  chunks and computing only the missing ones — the recovery path for
  interrupted large-scale sweeps.
- ``example-spec <kind>``: print a small runnable template spec for any
  analysis kind (evaluate | schedule | pareto | advise | sweep |
  roofline | search | calibrate | serve) — ``python -m repro example-spec
  evaluate > spec.json`` then ``run`` it. ``run --workers N`` farms a
  ``kind='search'`` study's generation blocks to N worker processes.
- ``report``: regenerate the ``experiments/`` report sections (the DSE
  and network tables are recomputed live through Study specs).
- ``bench``: run the repo benchmarks (``--smoke`` for the CI subset);
  each emits its ``BENCH_*.json`` next to ``benchmarks/``.

``report`` and ``bench`` drive files that live in the repository
checkout (``experiments/``, ``benchmarks/``), so they locate the repo
root from the current directory; ``run``/``example-spec`` work
anywhere the package is importable.
"""

from __future__ import annotations

import argparse
import dataclasses
import importlib.util
import json
import os
import pathlib
import subprocess
import sys

from .core.cache import DEFAULT_CACHE_DIR, ResultCache
from .core.study import ANALYSIS_KINDS, Study

_BENCHES = (
    "dse", "network", "study", "scale", "roofline", "kernels", "search",
    "calibrate", "serve", "thermal",
)


def _find_repo_root() -> pathlib.Path:
    """Walk up from cwd to the checkout holding benchmarks/experiments."""
    here = pathlib.Path.cwd().resolve()
    for cand in (here, *here.parents):
        if (cand / "benchmarks").is_dir() and (cand / "experiments").is_dir():
            return cand
    raise SystemExit(
        "error: could not find the repo checkout (benchmarks/ + experiments/) "
        "from the current directory — run from inside the repository"
    )


def _find_resume_spec(resume: pathlib.Path) -> pathlib.Path:
    """Locate spec.json inside a cache directory (study dir or root)."""
    if (resume / "spec.json").is_file():
        return resume / "spec.json"
    specs = sorted(resume.glob("*/spec.json"))
    if len(specs) == 1:
        return specs[0]
    if not specs:
        raise SystemExit(
            f"error: no spec.json under {resume} — point --resume at a cache "
            "directory written by `repro run --cache`"
        )
    raise SystemExit(
        f"error: {resume} holds {len(specs)} cached studies; point --resume "
        "at one study directory: " + ", ".join(str(s.parent) for s in specs)
    )


def _cmd_run(args) -> int:
    cache = None
    if args.resume:
        if args.spec:
            raise SystemExit("error: give either a spec file or --resume, not both")
        if args.cache is not None:
            raise SystemExit(
                "error: --resume already names the cache directory; drop --cache"
            )
        spec_path = _find_resume_spec(pathlib.Path(args.resume))
        text = spec_path.read_text()
        src = str(spec_path)
        cache = ResultCache(spec_path.parent.parent)
    elif args.spec == "-":
        text = sys.stdin.read()
        src = "<stdin>"
    elif args.spec:
        path = pathlib.Path(args.spec)
        if not path.exists():
            raise SystemExit(f"error: spec file {path} does not exist")
        text = path.read_text()
        src = str(path)
    else:
        raise SystemExit("error: need a spec file ('-' for stdin) or --resume DIR")
    try:
        study = Study.from_json(text)
    except (ValueError, KeyError, TypeError, json.JSONDecodeError) as e:
        # TypeError covers misspelled spec fields (unexpected kwargs)
        raise SystemExit(f"error: invalid study spec {src}: {e}") from None
    if args.workers is not None:
        # an execution knob (never part of the cache key): override in
        # place so --resume composes across worker counts
        study = dataclasses.replace(
            study,
            analysis=dataclasses.replace(study.analysis, workers=args.workers),
        )
    if cache is None and args.cache is not None:
        cache = ResultCache(args.cache or DEFAULT_CACHE_DIR)
    result = study.run(cache=cache)
    if args.out:
        out = result.save(args.out)
        print(f"wrote {out}")
    else:
        print(result.to_json())
    print(result.describe(), file=sys.stderr)
    if cache is not None:
        st = result.cache
        print(
            f"cache {cache.study_dir(study)}: {st['hits']} chunk(s) reused, "
            f"{st['misses']} computed",
            file=sys.stderr,
        )
    return 0


def _cmd_example_spec(args) -> int:
    study = Study.example(args.kind)
    if args.transient:
        try:
            study = dataclasses.replace(
                study,
                name=study.name + "-transient",
                analysis=dataclasses.replace(
                    study.analysis, thermal="transient"
                ),
            )
        except ValueError as e:
            raise SystemExit(f"error: {e}") from None
    print(study.to_json())
    return 0


def _cmd_report(args) -> int:
    root = _find_repo_root()
    path = root / "experiments" / "make_report.py"
    spec = importlib.util.spec_from_file_location("repro_make_report", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    cache = None
    if args.cache is not None:
        cache = args.cache or str(root / DEFAULT_CACHE_DIR)
    mod.main(sections=args.sections, cache=cache)
    return 0


def _cmd_bench(args) -> int:
    root = _find_repo_root()
    env = dict(os.environ)
    src = str(pathlib.Path(__file__).resolve().parents[1])
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (src, env.get("PYTHONPATH")) if p
    )
    which = _BENCHES if args.which == "all" else (args.which,)
    for name in which:
        cmd = [sys.executable, "-m", f"benchmarks.{name}_bench"]
        if args.smoke:
            cmd.append("--smoke")
        print(f"$ {' '.join(cmd)}", file=sys.stderr)
        proc = subprocess.run(cmd, cwd=root, env=env)
        if proc.returncode:
            return proc.returncode
    return 0


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro",
        description="Declarative Study front door over the 3D-IC DSE stack.",
    )
    sub = ap.add_subparsers(dest="command", required=True)

    run = sub.add_parser("run", help="run a Study spec, write the artifact")
    run.add_argument("spec", nargs="?", default=None,
                     help="path to a Study spec JSON ('-' for stdin)")
    run.add_argument("--out", "-o", default=None,
                     help="artifact path (default: print JSON to stdout)")
    run.add_argument("--cache", nargs="?", const="", default=None, metavar="DIR",
                     help="content-addressed chunk cache directory "
                          f"(default when flag given: {DEFAULT_CACHE_DIR})")
    run.add_argument("--resume", default=None, metavar="DIR",
                     help="continue an interrupted cached run: DIR is the "
                          "cache root (single study) or one <spec-hash> "
                          "study directory; only missing chunks are computed")
    run.add_argument("--workers", type=int, default=None, metavar="N",
                     help="farm kind='search' generation blocks to N worker "
                          "processes (overrides the spec's analysis.workers; "
                          "results are bit-identical at any count)")
    run.set_defaults(fn=_cmd_run)

    ex = sub.add_parser("example-spec", help="print a runnable template spec")
    ex.add_argument("kind", nargs="?", default="evaluate",
                    choices=list(ANALYSIS_KINDS))
    ex.add_argument("--transient", action="store_true",
                    help="switch the template to the transient thermal/DVFS "
                         "model (thermal='transient' + a default DvfsSpec; "
                         "evaluate/pareto/roofline/schedule/serve kinds)")
    ex.set_defaults(fn=_cmd_example_spec)

    rep = sub.add_parser("report", help="regenerate the experiments/ sections")
    rep.add_argument("--sections", nargs="*", default=None,
                     choices=["dryrun", "roofline", "dse", "network", "search",
                              "calibrate", "serve", "thermal"],
                     help="subset to regenerate (default: all)")
    rep.add_argument("--cache", nargs="?", const="", default=None, metavar="DIR",
                     help="chunk-cache the live DSE/network studies "
                          f"(default when flag given: {DEFAULT_CACHE_DIR})")
    rep.set_defaults(fn=_cmd_report)

    be = sub.add_parser("bench", help="run the repo benchmarks")
    be.add_argument("--which", default="all", choices=["all", *_BENCHES])
    be.add_argument("--smoke", action="store_true",
                    help="small CI-sized runs (separate BENCH_*_smoke.json)")
    be.set_defaults(fn=_cmd_bench)
    return ap


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())
