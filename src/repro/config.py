"""Configuration system: architectures, input shapes, runs.

Every assigned architecture is an ``ArchConfig`` in ``repro.configs``;
every benchmark shape is a ``ShapeConfig``. ``RunConfig`` composes them
with a mesh/parallelism choice for the launcher and dry-run.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

__all__ = ["ArchConfig", "ShapeConfig", "RunConfig", "SHAPES", "reduced"]

Mode = Literal["train", "prefill", "decode"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    act: str = "silu"
    # --- attention pattern ---------------------------------------------
    sliding_window: int = 0  # 0 = all layers global
    global_every: int = 0  # every Nth layer global (gemma3: 6 -> 5:1)
    global_rope_theta: float = 0.0  # 0 -> rope_theta
    qk_norm: bool = False
    # --- MoE -------------------------------------------------------------
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    # --- SSM / hybrid -----------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    attn_every: int = 0  # zamba2: shared attention after every Nth block
    slstm_at: tuple = ()  # xlstm: block indices running sLSTM
    # --- encoder-decoder --------------------------------------------------
    n_enc_layers: int = 0
    enc_seq: int = 0  # stub-frontend sequence length (whisper frames)
    # --- VLM ---------------------------------------------------------------
    cross_every: int = 0  # every Nth decoder layer is vision cross-attn
    n_image_tokens: int = 0
    # --- numerics / compilation -------------------------------------------
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    scan_layers: bool = True
    # unroll inner chunk-scans (flash/SSD) so cost_analysis counts every
    # trip — used by the dry-run's small unrolled cost variants only.
    unroll_inner: bool = False
    # --- provenance ---------------------------------------------------------
    source: str = ""
    notes: str = ""

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_subquadratic(self) -> bool:
        """Can this arch run long_500k? SSM/hybrid/sliding-window only."""
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0

    @property
    def n_params(self) -> int:
        """Approximate parameter count (embeddings included)."""
        d, v = self.d_model, self.vocab
        hd = self.head_dim_
        emb = v * d * (1 if self.tie_embeddings else 2)
        att = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
        if self.family == "moe":
            ff_r = 3 * d * self.expert_d_ff * self.n_experts
            ff_s = 3 * d * self.expert_d_ff * self.n_shared_experts
            ff = ff_r + ff_s + d * self.n_experts  # + router
        elif self.family in ("ssm",):
            ff = 0
        else:
            ff = 3 * d * self.d_ff
        if self.family in ("ssm", "hybrid"):
            d_in = self.ssm_expand * d
            ssm = d * (2 * d_in + 2 * self.ssm_state + d_in // self.ssm_head_dim) + d_in * d
            per_layer = ssm if self.family == "ssm" else ssm  # hybrids: + shared attn once
        else:
            per_layer = att + ff
        if self.family == "hybrid":
            total = self.n_layers * per_layer + (att + 3 * d * self.d_ff)
        elif self.family == "ssm":
            # xlstm: qkv projections + gates per block
            total = self.n_layers * (4 * d * d + 2 * d)
        else:
            total = self.n_layers * per_layer
        if self.family == "encdec":
            total += self.n_enc_layers * (att + 3 * d * self.d_ff)
        return total + emb

    @property
    def n_active_params(self) -> int:
        """Active parameters per token (MoE-aware), for MODEL_FLOPS."""
        if self.family != "moe":
            return self.n_params
        d = self.d_model
        ff_active = 3 * d * self.expert_d_ff * (self.top_k + self.n_shared_experts)
        hd = self.head_dim_
        att = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * (att + ff_active) + emb


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: Mode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class RunConfig:
    arch: ArchConfig
    shape: ShapeConfig
    strategy: str = "dos"  # dos | megatron | auto
    fsdp: bool = True  # shard params/opt over data axis (train)
    multi_pod: bool = False
    pipeline: bool = False  # pipeline-parallel over the pod axis
    remat: str = "layer"  # none | layer | full
    microbatches: int = 1


def reduced(cfg: ArchConfig, seq: int = 128) -> ArchConfig:
    """A smoke-test-sized config of the same family: small dims, few
    layers, tiny vocab — but the same block structure and patterns."""
    kw: dict = dict(
        n_layers=min(cfg.n_layers, 4),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(4, max(1, cfg.n_kv_heads * 4 // max(cfg.n_heads, 1))),
        head_dim=32,
        d_ff=256 if cfg.d_ff else 0,
        vocab=256,
        scan_layers=cfg.scan_layers,
        param_dtype="float32",
        compute_dtype="float32",
    )
    if cfg.global_every:
        kw["global_every"] = 2
        kw["sliding_window"] = min(cfg.sliding_window, seq // 2) or 64
    elif cfg.sliding_window:
        kw["sliding_window"] = min(cfg.sliding_window, 64)
    if cfg.family == "moe":
        kw.update(n_experts=8, n_shared_experts=min(cfg.n_shared_experts, 1),
                  top_k=min(cfg.top_k, 2), expert_d_ff=64)
    if cfg.family in ("ssm", "hybrid"):
        kw.update(ssm_state=16, ssm_head_dim=16)
    if cfg.attn_every:
        kw["attn_every"] = 2
    if cfg.slstm_at:
        kw["slstm_at"] = (1,)
    if cfg.family == "encdec":
        kw.update(n_enc_layers=2, enc_seq=64)
    if cfg.family == "vlm":
        kw.update(cross_every=2, n_image_tokens=16)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **kw)
