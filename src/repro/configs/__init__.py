"""Architecture config registry: the 10 assigned archs + paper workloads.

``get_config(name)`` returns the full ArchConfig; ``reduced(cfg)``
(from repro.config) gives the smoke-test sizing.
"""

from __future__ import annotations

from ..config import ArchConfig, ShapeConfig, SHAPES, reduced  # noqa: F401
from .deepseek_moe_16b import CONFIG as deepseek_moe_16b
from .gemma3_1b import CONFIG as gemma3_1b
from .llama32_vision_11b import CONFIG as llama32_vision_11b
from .llama4_scout_17b import CONFIG as llama4_scout_17b
from .qwen25_3b import CONFIG as qwen25_3b
from .qwen2_72b import CONFIG as qwen2_72b
from .smollm_135m import CONFIG as smollm_135m
from .whisper_medium import CONFIG as whisper_medium
from .xlstm_125m import CONFIG as xlstm_125m
from .zamba2_27b import CONFIG as zamba2_27b

REGISTRY: dict[str, ArchConfig] = {
    c.name: c
    for c in [
        llama32_vision_11b,
        smollm_135m,
        qwen25_3b,
        qwen2_72b,
        gemma3_1b,
        whisper_medium,
        zamba2_27b,
        deepseek_moe_16b,
        llama4_scout_17b,
        xlstm_125m,
    ]
}


def get_config(name: str) -> ArchConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(REGISTRY)}")
    return REGISTRY[name]


def cells():
    """All live (arch, shape) dry-run cells + documented skips.

    long_500k needs sub-quadratic attention: it runs only for
    SSM/hybrid/sliding-window archs (see DESIGN.md §Arch-applicability).
    """
    live, skipped = [], []
    for arch in REGISTRY.values():
        for shape in SHAPES.values():
            if shape.name == "long_500k" and not arch.is_subquadratic:
                skipped.append((arch.name, shape.name, "full attention at 500k"))
                continue
            live.append((arch.name, shape.name))
    return live, skipped
