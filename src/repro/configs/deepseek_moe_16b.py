"""DeepSeekMoE-16B: fine-grained experts, 2 shared + 64 routed top-6.

[arXiv:2401.06066; hf]. All layers MoE (the real model's first dense
layer is simplified to MoE; see DESIGN.md). Fine-grained expert
d_ff=1408 gives a SMALL GEMM contraction dim per expert — the paper's
small-K regime where dOS loses (Fig. 5), which the advisor reproduces.
"""

from ..config import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=102400,
    head_dim=128,
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    expert_d_ff=1408,
    rope_theta=10_000.0,
    source="arXiv:2401.06066",
)
