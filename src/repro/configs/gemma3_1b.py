"""Gemma3-1B: 5:1 local:global attention, 1:4 GQA, huge vocab.

[hf:google/gemma-3-1b-pt; unverified]. Local layers use a 1024-token
sliding window with rope theta 10k; every 6th layer is global with
theta 1M. Sub-quadratic (sliding window) -> runs long_500k.
"""

from ..config import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    d_ff=6912,
    vocab=262144,
    head_dim=256,
    rope_theta=10_000.0,
    global_rope_theta=1_000_000.0,
    sliding_window=1024,
    global_every=6,
    qk_norm=True,
    tie_embeddings=True,
    source="hf:google/gemma-3-1b-pt",
)
