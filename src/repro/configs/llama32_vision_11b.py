"""Llama-3.2-Vision-11B text backbone + cross-attn image layers.

[hf:meta-llama/Llama-3.2-11B-Vision; unverified]. 40 layers total:
every 5th layer cross-attends to (stub) precomputed image patch
embeddings; the other 32 are standard GQA self-attention layers.
The vision tower is a stub per the assignment (input_specs supplies
patch embeddings).
"""

from ..config import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=128256,
    head_dim=128,
    rope_theta=500_000.0,
    cross_every=5,
    n_image_tokens=1600,
    source="hf:meta-llama/Llama-3.2-11B-Vision",
    notes="vision frontend stubbed: precomputed patch embeds",
)
