"""Llama-4-Scout-17B-16E: MoE with 16 large experts, top-1 routing.

[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]. Contrast case to
deepseek-moe: expert d_ff=8192 is a LARGE contraction dim, so dOS
sharding of expert FFNs is competitive (paper's large-K regime).
"""

from ..config import ArchConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab=202048,
    head_dim=128,
    n_experts=16,
    n_shared_experts=1,
    top_k=1,
    expert_d_ff=8192,
    rope_theta=500_000.0,
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)
