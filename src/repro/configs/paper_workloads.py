"""The paper's Table I: exemplary DNN layers as GEMM workloads."""

from ..core.analytical import GEMM

WORKLOADS = [
    GEMM(M=64, K=12100, N=147, name="RN0"),     # ResNet50
    GEMM(M=512, K=784, N=128, name="RN1"),
    GEMM(M=128, K=4096, N=2048, name="GNMT0"),  # Google NMT
    GEMM(M=320, K=4096, N=3072, name="GNMT1"),
    GEMM(M=1024, K=50000, N=16, name="DB0"),    # DeepBench
    GEMM(M=35, K=2560, N=4096, name="DB1"),
    GEMM(M=31999, K=84, N=1024, name="TF0"),    # Transformer
    GEMM(M=84, K=4096, N=1024, name="TF1"),
]
