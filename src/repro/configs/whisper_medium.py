"""Whisper-medium: encoder-decoder with stubbed conv frontend.

[arXiv:2212.04356; unverified]. 24 encoder + 24 decoder layers,
MHA (kv == q heads). input_specs supplies precomputed frame
embeddings (B, 1500, 1024); decode shapes exercise decoder self-cache
+ cross-attention; long_500k skipped (full attention).
"""

from ..config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium",
    family="encdec",
    n_layers=24,
    n_enc_layers=24,
    enc_seq=1500,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=51865,
    head_dim=64,
    act="gelu",
    tie_embeddings=True,
    source="arXiv:2212.04356",
    notes="conv/mel frontend stubbed per assignment",
)
