"""xLSTM-125M: mLSTM + sLSTM blocks. [arXiv:2405.04517; unverified]

12 blocks, d_model 768; sLSTM at blocks {3, 9}, mLSTM elsewhere
(d_ff=0: the xLSTM block IS the mixer, no separate MLP). The paper's
dOS applies to the q/k/v/out projections only — the recurrence itself
is outer-product (K=1); see DESIGN.md §Arch-applicability. SSM family
-> runs long_500k.
"""

from ..config import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    head_dim=192,
    ssm_state=96,      # mLSTM q/k dim per head
    ssm_head_dim=192,  # mLSTM value dim per head
    slstm_at=(3, 9),
    tie_embeddings=True,
    source="arXiv:2405.04517",
)
