"""Zamba2-2.7B: Mamba2 backbone + shared attention block.

[arXiv:2411.15242; hf]. 54 Mamba2 layers; ONE weight-shared
attention+MLP block applied after every 6th Mamba layer (the paper's
shared block; per-invocation LoRA omitted — see DESIGN.md). Hybrid ->
runs long_500k.
"""

from ..config import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab=32000,
    head_dim=80,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    attn_every=6,
    rope_theta=10_000.0,
    source="arXiv:2411.15242",
)
