"""Core library: the paper's contribution.

- ``analytical``: Eqs. 1-2 runtime model + array-shape/tier optimizers.
- ``dataflow``: OS/WS/IS/dOS descriptors + switching activities.
- ``systolic``: cycle-level functional simulator (validates dOS).
- ``dse``: the paper's design-space sweeps (Figs. 5-7).
- ``ppa``: power / area / thermal models (Table II, Figs. 8-9).
- ``advisor``: the DSE generalized to TPU-mesh sharding choices.
"""

from . import advisor, analytical, dataflow, dse, ppa, systolic
from .analytical import (
    GEMM,
    ArrayPlan,
    mac_threshold,
    optimal_tiers,
    optimize_array_2d,
    optimize_array_3d,
    speedup_3d,
    tau_2d,
    tau_3d,
)
from .advisor import GemmShard, choose_sharding, score_strategies
from .dataflow import DOS, IS, OS, WS, dos_activity
from .systolic import simulate_dos_3d, simulate_os_2d

__all__ = [
    "advisor",
    "analytical",
    "dataflow",
    "dse",
    "ppa",
    "systolic",
    "GEMM",
    "ArrayPlan",
    "mac_threshold",
    "optimal_tiers",
    "optimize_array_2d",
    "optimize_array_3d",
    "speedup_3d",
    "tau_2d",
    "tau_3d",
    "GemmShard",
    "choose_sharding",
    "score_strategies",
    "DOS",
    "IS",
    "OS",
    "WS",
    "dos_activity",
    "simulate_dos_3d",
    "simulate_os_2d",
]
