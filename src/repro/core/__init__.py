"""Core library: the paper's contribution.

- ``analytical``: Eqs. 1-2 runtime model + array-shape/tier optimizers.
- ``dataflow``: OS/WS/IS/dOS descriptors + switching activities.
- ``systolic``: cycle-level functional simulator (validates dOS).
- ``engine``: batched design-space evaluation engine (perf + PPA in one
  vectorized pass over whole workload x design grids).
- ``dse``: the paper's design-space sweeps (Figs. 5-7), thin wrappers
  over the engine.
- ``ppa``: power / area / thermal models (Table II, Figs. 8-9), with
  batched entry points the engine consumes.
- ``advisor``: the DSE generalized to TPU-mesh sharding choices, ranked
  through the engine.
- ``study``: the declarative front door — JSON-round-trippable
  ``Study`` specs compiled into the engine, returning versioned
  ``StudyResult`` artifacts (what ``python -m repro`` drives).
- ``params``: the shared option vocabularies + validators every API
  boundary uses.
"""

from . import advisor, analytical, dataflow, dse, engine, params, ppa, study, systolic
from .analytical import (
    GEMM,
    ArrayPlan,
    mac_threshold,
    optimal_tiers,
    optimize_array_2d,
    optimize_array_3d,
    optimize_rc_batched,
    speedup_3d,
    tau_2d,
    tau_3d,
    tau_is,
    tau_ws,
)
from .advisor import GemmShard, choose_sharding, rank_candidates, score_strategies
from .dataflow import DOS, IS, OS, WS, activity_batched, dos_activity
from .engine import (
    DesignGrid,
    EvalResult,
    evaluate,
    optimal_tiers_batched,
    pareto_frontier,
)
from .study import (
    AnalysisSpec,
    ConstraintSpec,
    SpaceSpec,
    Study,
    StudyResult,
    WorkloadSpec,
)
from .systolic import simulate_dos_3d, simulate_os_2d

__all__ = [
    "advisor",
    "analytical",
    "dataflow",
    "dse",
    "engine",
    "params",
    "ppa",
    "study",
    "systolic",
    "AnalysisSpec",
    "ConstraintSpec",
    "SpaceSpec",
    "Study",
    "StudyResult",
    "WorkloadSpec",
    "GEMM",
    "ArrayPlan",
    "mac_threshold",
    "optimal_tiers",
    "optimize_array_2d",
    "optimize_array_3d",
    "optimize_rc_batched",
    "speedup_3d",
    "tau_2d",
    "tau_3d",
    "tau_is",
    "tau_ws",
    "GemmShard",
    "choose_sharding",
    "rank_candidates",
    "score_strategies",
    "DOS",
    "IS",
    "OS",
    "WS",
    "activity_batched",
    "dos_activity",
    "DesignGrid",
    "EvalResult",
    "evaluate",
    "optimal_tiers_batched",
    "pareto_frontier",
    "simulate_dos_3d",
    "simulate_os_2d",
]
