"""Dataflow advisor: the paper's DSE, extended from tiers to TPU meshes.

The paper asks: *given a GEMM (M, K, N) and a MAC budget, how many tiers
ℓ should the 3D array have, and does the (ℓ-1)-cycle cross-tier
reduction pay for itself?* (Eq. 2, Figs. 5-7).

On a TPU mesh the same question becomes: *given a GEMM and a mesh axis
of size ℓ, which operand dimension do we shard over the axis — and is
the resulting collective worth it?* The mapping is exact:

  - sharding K over the axis == the paper's dOS: each device holds a
    K/ℓ slice, computes a partial M x N sum, and the cross-tier adder
    pile becomes an **all-reduce of the M x N output** (cost grows with
    ℓ like the paper's ℓ-1 term — same convexity, same optimum).
  - sharding N (or M) over the axis == WS/IS-in-3D == model/data
    parallelism: no partial sums, but each device must see the whole A
    (all-gather of the activations) — the paper's "scaled-out 2D".

The advisor scores each strategy with a roofline-style cost model
(compute + memory + collective terms, using the v5e constants) and
returns the winner. The paper's threshold ``N_macs > M*N`` reappears
naturally: K-sharding wins when the per-device output tile M*N is too
small to fill the device (e.g. decode GEMMs) and K is large.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from .ppa import constants as C

__all__ = ["GemmShard", "score_strategies", "choose_sharding", "Strategy"]

_BF16 = 2  # bytes
#: per-hop ICI latency. This is where the paper's (l-1) *serial* adder
#: term survives on a mesh: a ring collective over an axis of size l
#: costs ~2(l-1) latency hops regardless of payload, so the dOS total is
#: convex in l exactly like Eq. 2.
ICI_HOP_LATENCY_S = 1e-6


@dataclasses.dataclass(frozen=True)
class Strategy:
    name: str  # 'replicate' | 'shard_M' | 'shard_N' | 'shard_K' (dOS)
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def total_s(self) -> float:
        # Compute and memory overlap on TPU (different units); the
        # collective is serialized unless overlapped — we model the
        # pessimistic (paper-faithful: sequential adder pile) case.
        return max(self.compute_s, self.memory_s) + self.collective_s


@dataclasses.dataclass(frozen=True)
class GemmShard:
    M: int
    K: int
    N: int
    axis: int  # mesh axis size (the paper's tier count ℓ)
    bytes_per_el: int = _BF16

    def flops(self) -> float:
        return 2.0 * self.M * self.K * self.N


def _ring_allreduce_s(nbytes: float, axis: int, bw: float) -> float:
    """Ring all-reduce: 2(l-1)/l of the buffer over the slowest link,
    plus 2(l-1) serial latency hops (the paper's adder pile)."""
    return 2.0 * (axis - 1) / axis * nbytes / bw + 2 * (axis - 1) * ICI_HOP_LATENCY_S


def _ring_allgather_s(nbytes_shard: float, axis: int, bw: float) -> float:
    return (axis - 1) * nbytes_shard / bw + (axis - 1) * ICI_HOP_LATENCY_S


def score_strategies(
    g: GemmShard,
    flops_per_s: float = C.TPU_PEAK_FLOPS_BF16,
    hbm_bw: float = C.TPU_HBM_BW,
    ici_bw: float = C.TPU_ICI_BW_PER_LINK,
    mxu_tile: int = 128,
) -> list[Strategy]:
    """Cost each way of mapping the GEMM onto one mesh axis of size ℓ.

    The compute term includes the paper's *fill/quantization* effect:
    a per-device output tile smaller than the MXU tile (128x128) wastes
    the systolic array exactly like the paper's ceil(M/R)ceil(N/C)
    rounding — this is how N_macs > M*N re-emerges at chip level.
    """
    L = g.axis
    b = g.bytes_per_el
    out: list[Strategy] = []

    def eff(m, n, k):
        """MXU efficiency from tile quantization (ceil rounding)."""
        um = -(-m // mxu_tile) * mxu_tile
        un = -(-n // mxu_tile) * mxu_tile
        uk = -(-k // 8) * 8
        return (m * n * k) / (um * un * uk)

    def compute_t(m, n, k):
        e = max(eff(m, n, k), 1e-6)
        return 2.0 * m * n * k / (flops_per_s * e) / 1.0

    def memory_t(m, n, k):
        return b * (m * k + k * n + m * n) / hbm_bw

    # replicate: every device does the whole thing (no collective).
    out.append(Strategy("replicate", compute_t(g.M, g.N, g.K), memory_t(g.M, g.N, g.K), 0.0))
    # shard_M (IS-in-3D / data parallel): A row-sharded; B replicated.
    mL = -(-g.M // L)
    out.append(Strategy("shard_M", compute_t(mL, g.N, g.K), memory_t(mL, g.N, g.K), 0.0))
    # shard_N (WS-in-3D / megatron column-parallel): B col-sharded; the
    # next layer usually needs the full activation -> all-gather output.
    nL = -(-g.N // L)
    coll_n = _ring_allgather_s(b * g.M * nL, L, ici_bw)
    out.append(Strategy("shard_N", compute_t(g.M, nL, g.K), memory_t(g.M, nL, g.K), coll_n))
    # shard_K (dOS): partial sums all-reduced — the paper's adder pile.
    kL = -(-g.K // L)
    coll_k = _ring_allreduce_s(b * g.M * g.N, L, ici_bw)
    out.append(Strategy("shard_K", compute_t(g.M, g.N, kL), memory_t(g.M, g.N, kL), coll_k))
    return out


def choose_sharding(g: GemmShard, **kw) -> Strategy:
    """The advisor: minimum-total-time strategy for this GEMM."""
    return min(score_strategies(g, **kw), key=lambda s: s.total_s)


def advise_layer(M: int, K: int, N: int, axis: int, **kw) -> str:
    return choose_sharding(GemmShard(M=M, K=K, N=N, axis=axis), **kw).name


# ---------------------------------------------------------------------------
# Chain-aware scoring (§Perf B3 lesson)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ChainStrategy:
    name: str
    compute_s: float
    collective_s: float
    reshard_s: float

    @property
    def total_s(self) -> float:
        return self.compute_s + self.collective_s + self.reshard_s


def score_block_chain(
    tokens: int,
    d_model: int,
    d_ff: int,
    n_heads: int,
    head_dim: int,
    axis: int,
    flops_per_s: float = C.TPU_PEAK_FLOPS_BF16,
    ici_bw: float = C.TPU_ICI_BW_PER_LINK,
) -> list[ChainStrategy]:
    """Whole-transformer-block comparison of dOS vs megatron vs zero.

    The single-GEMM model (score_strategies) misses that a *chain* of
    GEMMs pays a resharding boundary wherever consecutive GEMMs want
    different input layouts. This is the §Perf B3 lesson: per-GEMM, dOS
    (shard_K) scores best for decode GEMMs, but megatron's col->row
    pairing runs the whole attention + MLP chain with ONE collective per
    pair, while pure dOS pays a reduce-scatter after EVERY GEMM plus
    latency hops. Counts per block (fwd):

      dOS:       6 GEMMs -> 6 reduce-scatters of each output + hops
      megatron:  2 collectives (attn out AR, mlp out AR)
      zero:      0 activation collectives; weight all-gathers instead
    """
    b = 2.0
    L = axis
    e, f, hd2 = d_model, d_ff, n_heads * head_dim
    gemm_flops = 2.0 * tokens * (e * hd2 * 2 + e * hd2 + hd2 * e) + 2.0 * tokens * (
        2 * e * f + f * e
    )
    compute = gemm_flops / (L * flops_per_s)
    hop = ICI_HOP_LATENCY_S

    def ar(nbytes):
        return 2.0 * (L - 1) / L * nbytes / ici_bw + 2 * (L - 1) * hop

    def rs(nbytes):
        return (L - 1) / L * nbytes / ici_bw + (L - 1) * hop

    out: list[ChainStrategy] = []
    # dOS: RS after each of ~6 GEMM outputs (sizes: qkv ~2*e+..., o, 2f, e)
    dos_coll = (
        rs(tokens * hd2 * 2 * b) + rs(tokens * e * b)  # qkv + o
        + 2 * rs(tokens * f * b) + rs(tokens * e * b)  # mlp up/gate + down
        + rs(tokens * e * b)  # attention-internal regroup
    )
    out.append(ChainStrategy("dos", compute, dos_coll, 0.0))
    # megatron: one AR per pair (attention out, mlp out)
    meg_coll = 2 * ar(tokens * e * b)
    out.append(ChainStrategy("megatron", compute, meg_coll, 0.0))
    # zero: weight all-gathers amortized across the batch's tokens
    w_bytes = (e * hd2 * 2 + hd2 * e + 3 * e * f) * b
    zero_coll = (L - 1) / L * w_bytes / ici_bw + (L - 1) * hop
    out.append(ChainStrategy("zero", gemm_flops / (L * flops_per_s), zero_coll, 0.0))
    return out


def choose_block_strategy(tokens, d_model, d_ff, n_heads, head_dim, axis, **kw):
    return min(
        score_block_chain(tokens, d_model, d_ff, n_heads, head_dim, axis, **kw),
        key=lambda s: s.total_s,
    )
