"""Dataflow advisor: the paper's DSE, extended from tiers to TPU meshes.

The paper asks: *given a GEMM (M, K, N) and a MAC budget, how many tiers
ℓ should the 3D array have, and does the (ℓ-1)-cycle cross-tier
reduction pay for itself?* (Eq. 2, Figs. 5-7).

On a TPU mesh the same question becomes: *given a GEMM and a mesh axis
of size ℓ, which operand dimension do we shard over the axis — and is
the resulting collective worth it?* The mapping is exact:

  - sharding K over the axis == the paper's dOS: each device holds a
    K/ℓ slice, computes a partial M x N sum, and the cross-tier adder
    pile becomes an **all-reduce of the M x N output** (cost grows with
    ℓ like the paper's ℓ-1 term — same convexity, same optimum).
  - sharding N (or M) over the axis == WS/IS-in-3D == model/data
    parallelism: no partial sums, but each device must see the whole A
    (all-gather of the activations) — the paper's "scaled-out 2D".

The advisor scores each strategy with a roofline-style cost model
(compute + memory + collective terms, using the v5e constants) and
returns the winner. The paper's threshold ``N_macs > M*N`` reappears
naturally: K-sharding wins when the per-device output tile M*N is too
small to fill the device (e.g. decode GEMMs) and K is large.

The scoring itself lives in the batched evaluation engine
(``core.engine.score_mesh_strategies``): ``rank_candidates`` costs a
whole batch of GEMMs x all four strategies in one vectorized engine
call, and the scalar ``score_strategies``/``choose_sharding`` are its
batch-of-one wrappers.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from .engine import ICI_HOP_LATENCY_S, MESH_STRATEGIES, score_mesh_strategies
from .ppa import constants as C

__all__ = [
    "GemmShard",
    "score_strategies",
    "choose_sharding",
    "rank_candidates",
    "Strategy",
]

_BF16 = 2  # bytes


@dataclasses.dataclass(frozen=True)
class Strategy:
    name: str  # 'replicate' | 'shard_M' | 'shard_N' | 'shard_K' (dOS)
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def total_s(self) -> float:
        # Compute and memory overlap on TPU (different units); the
        # collective is serialized unless overlapped — we model the
        # pessimistic (paper-faithful: sequential adder pile) case.
        return max(self.compute_s, self.memory_s) + self.collective_s


@dataclasses.dataclass(frozen=True)
class GemmShard:
    M: int
    K: int
    N: int
    axis: int  # mesh axis size (the paper's tier count ℓ)
    bytes_per_el: int = _BF16

    def flops(self) -> float:
        return 2.0 * self.M * self.K * self.N


def score_strategies(
    g: GemmShard,
    flops_per_s: float = C.TPU_PEAK_FLOPS_BF16,
    hbm_bw: float = C.TPU_HBM_BW,
    ici_bw: float = C.TPU_ICI_BW_PER_LINK,
    mxu_tile: int = 128,
) -> list[Strategy]:
    """Cost each way of mapping the GEMM onto one mesh axis of size ℓ.

    Batch-of-one wrapper over the engine's vectorized scoring
    (``core.engine.score_mesh_strategies``); see there for the model.
    """
    scores = score_mesh_strategies(
        g.M, g.K, g.N, g.axis,
        bytes_per_el=g.bytes_per_el,
        flops_per_s=flops_per_s,
        hbm_bw=hbm_bw,
        ici_bw=ici_bw,
        mxu_tile=mxu_tile,
    )
    return [
        Strategy(
            name,
            float(np.asarray(scores[name]["compute_s"]).reshape(-1)[0]),
            float(np.asarray(scores[name]["memory_s"]).reshape(-1)[0]),
            float(np.asarray(scores[name]["collective_s"]).reshape(-1)[0]),
        )
        for name in MESH_STRATEGIES
    ]


def _rank(
    workloads,
    axis,
    mac_budget: int | None = None,
    tech: str = "tsv",
    thermal_limit: float | None = None,
    **kw,
):
    """The ranking engine behind ``rank_candidates`` and the Study
    ``'advise'`` analysis — both route through this one implementation,
    so the shim and the spec path can never drift.

    ``workloads`` is an (n, 3) array-like of (M, K, N) rows; ``axis`` is
    the mesh-axis size (scalar or (n,)). Returns ``(names, totals)``:
    ``names`` — (n,) array of winning strategy names, ``totals`` — (n,
     4) float64 of total seconds per strategy, columns ordered as
    ``engine.MESH_STRATEGIES``.

    When ``mac_budget`` is given, thermal feasibility becomes a
    first-class constraint: ``shard_K`` is the paper's dOS — the
    physically 3D-stacked mapping with ``axis`` tiers — so workloads
    whose ``axis``-tier stack at that MAC budget would exceed
    ``thermal_limit`` (default: the junction budget) get ``shard_K``
    struck from the ranking (total = inf) and fall back to the best
    scaled-out-2D strategy. The other three strategies replicate or
    shard without stacking and are never thermally masked.
    """
    wl = np.atleast_2d(np.asarray(workloads, dtype=np.int64))
    scores = score_mesh_strategies(wl[:, 0], wl[:, 1], wl[:, 2], axis, **kw)
    totals = np.stack(
        [np.broadcast_to(scores[n]["total_s"], (wl.shape[0],)) for n in MESH_STRATEGIES],
        axis=1,
    )
    if mac_budget is not None:
        from .engine import thermal_feasible

        limit = C.THERMAL_BUDGET_C if thermal_limit is None else thermal_limit
        feas = thermal_feasible(
            wl, [int(mac_budget)], axis, tech=tech, thermal_limit=limit
        )[:, 0]
        totals = totals.copy()
        totals[~feas, MESH_STRATEGIES.index("shard_K")] = np.inf
    names = np.asarray(MESH_STRATEGIES)[np.argmin(totals, axis=1)]
    return names, totals


def rank_candidates(
    workloads,
    axis,
    mac_budget: int | None = None,
    tech: str = "tsv",
    thermal_limit: float | None = None,
    **kw,
):
    """DEPRECATED shim: rank all four mesh strategies for a batch of
    GEMMs. Build the declarative equivalent instead —

        Study(workload=WorkloadSpec(kind='gemms', gemms=...),
              space=SpaceSpec(tech=...),
              constraints=ConstraintSpec(thermal_limit_c=...),
              analysis=AnalysisSpec(kind='advise', axis=..., mac_budget=...))

    — whose ``run()`` payload carries the same ``names``/``totals``
    (see ``_rank`` for semantics; both paths share it bit-for-bit).
    """
    import warnings

    from .ppa import constants as _C
    from .study import AnalysisSpec, ConstraintSpec, SpaceSpec, Study, WorkloadSpec

    warnings.warn(
        "rank_candidates(...) is deprecated; use a core.study.Study with "
        "AnalysisSpec(kind='advise') — same engine, same bits, plus a "
        "serializable StudyResult artifact.",
        DeprecationWarning,
        stacklevel=2,
    )
    wl = np.atleast_2d(np.asarray(workloads, dtype=np.int64))
    axis_arr = np.atleast_1d(np.asarray(axis))
    if axis_arr.shape[0] != 1:
        # per-workload axis sizes never fit one scalar spec field; rank
        # directly (identical implementation, no artifact).
        return _rank(wl, axis, mac_budget=mac_budget, tech=tech,
                     thermal_limit=thermal_limit, **kw)
    res = Study(
        workload=WorkloadSpec(kind="gemms", gemms=tuple(map(tuple, wl.tolist()))),
        space=SpaceSpec(tech=tech),
        constraints=ConstraintSpec(
            thermal_limit_c=_C.THERMAL_BUDGET_C if thermal_limit is None
            else thermal_limit
        ),
        analysis=AnalysisSpec(kind="advise", axis=int(axis_arr[0]),
                              mac_budget=mac_budget, params=dict(kw)),
    ).run()
    return res.payload["names"], res.payload["totals"]


def choose_sharding(g: GemmShard, **kw) -> Strategy:
    """The advisor: minimum-total-time strategy for this GEMM."""
    return min(score_strategies(g, **kw), key=lambda s: s.total_s)


def advise_layer(M: int, K: int, N: int, axis: int, **kw) -> str:
    return choose_sharding(GemmShard(M=M, K=K, N=N, axis=axis), **kw).name


# ---------------------------------------------------------------------------
# Chain-aware scoring (§Perf B3 lesson)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ChainStrategy:
    name: str
    compute_s: float
    collective_s: float
    reshard_s: float

    @property
    def total_s(self) -> float:
        return self.compute_s + self.collective_s + self.reshard_s


def score_block_chain(
    tokens: int,
    d_model: int,
    d_ff: int,
    n_heads: int,
    head_dim: int,
    axis: int,
    flops_per_s: float = C.TPU_PEAK_FLOPS_BF16,
    ici_bw: float = C.TPU_ICI_BW_PER_LINK,
) -> list[ChainStrategy]:
    """Whole-transformer-block comparison of dOS vs megatron vs zero.

    The single-GEMM model (score_strategies) misses that a *chain* of
    GEMMs pays a resharding boundary wherever consecutive GEMMs want
    different input layouts. This is the §Perf B3 lesson: per-GEMM, dOS
    (shard_K) scores best for decode GEMMs, but megatron's col->row
    pairing runs the whole attention + MLP chain with ONE collective per
    pair, while pure dOS pays a reduce-scatter after EVERY GEMM plus
    latency hops. Counts per block (fwd):

      dOS:       6 GEMMs -> 6 reduce-scatters of each output + hops
      megatron:  2 collectives (attn out AR, mlp out AR)
      zero:      0 activation collectives; weight all-gathers instead
    """
    b = 2.0
    L = axis
    e, f, hd2 = d_model, d_ff, n_heads * head_dim
    gemm_flops = 2.0 * tokens * (e * hd2 * 2 + e * hd2 + hd2 * e) + 2.0 * tokens * (
        2 * e * f + f * e
    )
    compute = gemm_flops / (L * flops_per_s)
    hop = ICI_HOP_LATENCY_S

    def ar(nbytes):
        return 2.0 * (L - 1) / L * nbytes / ici_bw + 2 * (L - 1) * hop

    def rs(nbytes):
        return (L - 1) / L * nbytes / ici_bw + (L - 1) * hop

    out: list[ChainStrategy] = []
    # dOS: RS after each of ~6 GEMM outputs (sizes: qkv ~2*e+..., o, 2f, e)
    dos_coll = (
        rs(tokens * hd2 * 2 * b) + rs(tokens * e * b)  # qkv + o
        + 2 * rs(tokens * f * b) + rs(tokens * e * b)  # mlp up/gate + down
        + rs(tokens * e * b)  # attention-internal regroup
    )
    out.append(ChainStrategy("dos", compute, dos_coll, 0.0))
    # megatron: one AR per pair (attention out, mlp out)
    meg_coll = 2 * ar(tokens * e * b)
    out.append(ChainStrategy("megatron", compute, meg_coll, 0.0))
    # zero: weight all-gathers amortized across the batch's tokens
    w_bytes = (e * hd2 * 2 + hd2 * e + 3 * e * f) * b
    zero_coll = (L - 1) / L * w_bytes / ici_bw + (L - 1) * hop
    out.append(ChainStrategy("zero", gemm_flops / (L * flops_per_s), zero_coll, 0.0))
    return out


def choose_block_strategy(tokens, d_model, d_ff, n_heads, head_dim, axis, **kw):
    return min(
        score_block_chain(tokens, d_model, d_ff, n_heads, head_dim, axis, **kw),
        key=lambda s: s.total_s,
    )
