"""Analytical performance model for 2D and 3D systolic arrays.

Implements and extends the runtime model of the paper (Eqs. 1 and 2),
which itself extends SCALE-Sim's [13, Eq. (4)] output-stationary model.

A GEMM workload is ``A(M x K) @ B(K x N)``. For an output-stationary (OS)
2D array with R rows and C columns (``N_macs = R*C``):

    tau_2d = (2R + C + K - 2) * ceil(M/R) * ceil(N/C)          (Eq. 1)

For the distributed-output-stationary (dOS) 3D array with ``l`` tiers of
R' x C' each (``N_macs = l * R' * C'``), the contraction dim K is split
across tiers (each works on K/l) and the partial sums are accumulated
down the tier pile with ``l - 1`` sequential adds:

    tau_3d = (2R' + C' + (ceil(K/l) + l - 1) - 2)
             * ceil(M/R') * ceil(N/C')                          (Eq. 2)

All four dataflows of the paper (Sec. III-C) share the same structural
form: a per-fold latency ``2R + C + T - 2`` (array fill + drain + the
temporal dimension ``T``) times a fold count over the two spatially
mapped dimensions.  ``dataflow_dims`` maps each dataflow onto that
(D_rows, D_cols, T) triple, which is what lets a *single* batched search
kernel (``optimize_rc_batched`` / ``_search_rc``) serve OS, WS, IS and
dOS alike — the engine (``core.engine``) evaluates thousands of design
points through it in one vectorized pass.

The scalar optimizers (``optimize_array_2d`` / ``optimize_array_3d``)
delegate to the batched kernel with a batch of one, so the per-point and
batched paths are the same code and can never disagree.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

import numpy as np

__all__ = [
    "GEMM",
    "tau_2d",
    "tau_3d",
    "tau_ws",
    "tau_is",
    "dataflow_dims",
    "FOLD_NAMES",
    "native_fold",
    "fold_dims",
    "optimize_rc_batched",
    "optimize_array_2d",
    "optimize_array_3d",
    "speedup_3d",
    "optimal_tiers",
    "mac_threshold",
    "ArrayPlan",
]

OptMode = Literal["opt", "square"]

#: Sentinel runtime for invalid design points (e.g. per-tier budget < 1).
INVALID_CYCLES = np.iinfo(np.int64).max


@dataclasses.dataclass(frozen=True)
class GEMM:
    """A GEMM workload ``A(M x K) @ B(K x N)``."""

    M: int
    K: int
    N: int
    name: str = ""

    @property
    def flops(self) -> int:
        return 2 * self.M * self.K * self.N

    @property
    def macs(self) -> int:
        return self.M * self.K * self.N


@dataclasses.dataclass(frozen=True)
class ArrayPlan:
    """A chosen array configuration and its predicted runtime (cycles)."""

    rows: int
    cols: int
    tiers: int
    cycles: float
    n_macs_used: int
    #: useful MAC-ops of the workload (M*K*N); optimizers fill this in so
    #: ``utilization`` is defined. ``None`` for hand-built plans.
    workload_macs: int | None = None

    @property
    def utilization(self) -> float:
        """Useful MAC-ops per provisioned MAC-cycle (<= 1)."""
        if not self.workload_macs or not self.n_macs_used or not self.cycles:
            return np.nan
        return self.workload_macs / (self.n_macs_used * self.cycles)


def _ceil_div(a, b):
    return -(-np.asarray(a) // np.asarray(b))


def tau_2d(M, K, N, R, C):
    """Eq. 1 — runtime in cycles of an OS 2D array (vectorized)."""
    M, K, N, R, C = np.broadcast_arrays(
        *(np.asarray(x, dtype=np.int64) for x in (M, K, N, R, C))
    )
    return (2 * R + C + K - 2) * _ceil_div(M, R) * _ceil_div(N, C)


def tau_3d(M, K, N, R, C, tiers):
    """Eq. 2 — runtime in cycles of a dOS 3D array (vectorized).

    ``R, C`` are the *per-tier* dimensions. ``tiers == 1`` exactly
    recovers Eq. 1 (a property test asserts this).
    """
    M, K, N, R, C, L = np.broadcast_arrays(
        *(np.asarray(x, dtype=np.int64) for x in (M, K, N, R, C, tiers))
    )
    k_per_tier = _ceil_div(K, L)
    return (2 * R + C + (k_per_tier + L - 1) - 2) * _ceil_div(M, R) * _ceil_div(N, C)


def tau_ws(M, K, N, R, C, tiers=1):
    """Weight-stationary runtime (vectorized): N, K spatial; M temporal.

    B is pre-loaded (N mapped to rows, K to columns); A streams through
    for M cycles per fold. Extended to ``tiers`` > 1 the temporal dim M
    is split across tiers with **no** cross-tier traffic (WS-in-3D
    degenerates to model parallelism, paper Sec. III-C):

        tau_ws = (2R + C + ceil(M/l) - 2) * ceil(N/R) * ceil(K/C)
    """
    M, K, N, R, C, L = np.broadcast_arrays(
        *(np.asarray(x, dtype=np.int64) for x in (M, K, N, R, C, tiers))
    )
    return (2 * R + C + _ceil_div(M, L) - 2) * _ceil_div(N, R) * _ceil_div(K, C)


def tau_is(M, K, N, R, C, tiers=1):
    """Input-stationary runtime (vectorized): M, K spatial; N temporal.

    A is pre-loaded (M mapped to rows, K to columns); B streams through
    for N cycles per fold. Extended to ``tiers`` > 1 the temporal dim N
    is split across tiers with no cross-tier traffic:

        tau_is = (2R + C + ceil(N/l) - 2) * ceil(M/R) * ceil(K/C)
    """
    M, K, N, R, C, L = np.broadcast_arrays(
        *(np.asarray(x, dtype=np.int64) for x in (M, K, N, R, C, tiers))
    )
    return (2 * R + C + _ceil_div(N, L) - 2) * _ceil_div(M, R) * _ceil_div(K, C)


def dataflow_dims(dataflow: str, M, K, N, tiers):
    """Map a dataflow onto the generic (D_rows, D_cols, T_serial) triple.

    Every dataflow's runtime is ``(2R + C + T_serial - 2) * ceil(D_rows/R)
    * ceil(D_cols/C)``:

    - ``os`` / ``dos``: M, N spatial; T = ceil(K/l) + (l-1) cross-tier
      adds (l = 1 recovers plain OS / Eq. 1).
    - ``ws``: N, K spatial; T = ceil(M/l)  (M split across tiers, no
      vertical traffic).
    - ``is``: M, K spatial; T = ceil(N/l).
    """
    M, K, N, L = (np.asarray(x, dtype=np.int64) for x in (M, K, N, tiers))
    if dataflow in ("os", "dos"):
        return M, N, _ceil_div(K, L) + L - 1
    if dataflow == "ws":
        return N, K, _ceil_div(M, L)
    if dataflow == "is":
        return M, K, _ceil_div(N, L)
    raise ValueError(f"unknown dataflow {dataflow!r}")


#: the three tier folds: which GEMM dimension the stack of l tiers
#: partitions. Canonical candidate order for the ``tier_fold`` policy.
FOLD_NAMES = ("m", "k", "n")


def native_fold(dataflow: str) -> str:
    """The dataflow's *paper* tier split — the dimension its 3D
    extension already folds across tiers.

    os/dos fold the contraction dim K (Eq. 2's ``ceil(K/l) + l - 1``);
    ws folds the temporal M; is folds the temporal N. ``fold_dims``
    with the native fold is exactly ``dataflow_dims``.
    """
    if dataflow in ("os", "dos"):
        return "k"
    if dataflow == "ws":
        return "m"
    if dataflow == "is":
        return "n"
    raise ValueError(f"unknown dataflow {dataflow!r}")


def fold_dims(fold: str | None, dataflow: str, M, K, N, tiers):
    """(D_rows, D_cols, T_serial) of a dataflow under a chosen tier fold.

    A *fold* names which GEMM dimension the l tiers partition. The
    native fold (``native_fold(dataflow)``, or ``fold=None``) is the
    paper's 3D extension and returns ``dataflow_dims`` unchanged. The
    two non-native folds split a different dimension into balanced
    ``ceil``-sized per-tier slices; each tier then runs the dataflow's
    own 2D schedule on its slice:

    - splitting an output dim (m or n for os/dos; n for ws; m for is)
      yields l independent sub-GEMMs: the split dim shrinks to
      ``ceil(dim/l)`` and the serial/temporal term runs at full depth;
    - splitting the contraction dim K on ws/is mirrors dOS: the K
      extent of the spatial map shrinks to ``ceil(K/l)`` and the
      temporal term pays ``l - 1`` cross-tier partial-sum adds.

    All triples degenerate to the dataflow's 2D dims at ``tiers == 1``,
    so every fold is exactly the native mapping on a single tier.
    """
    if fold is None or fold == native_fold(dataflow):
        return dataflow_dims(dataflow, M, K, N, tiers)
    M, K, N, L = (np.asarray(x, dtype=np.int64) for x in (M, K, N, tiers))
    if dataflow in ("os", "dos"):
        if fold == "m":
            return _ceil_div(M, L), N, K
        if fold == "n":
            return M, _ceil_div(N, L), K
    elif dataflow == "ws":
        if fold == "k":
            return N, _ceil_div(K, L), M + L - 1
        if fold == "n":
            return _ceil_div(N, L), K, M
    elif dataflow == "is":
        if fold == "k":
            return M, _ceil_div(K, L), N + L - 1
        if fold == "m":
            return _ceil_div(M, L), K, N
    raise ValueError(f"unknown fold {fold!r} for dataflow {dataflow!r}")


def _search_rc(xp, D1, D2, Tser, budget, r_max_total: int):
    """Batched rectangular (R, C) search — the engine's hot kernel.

    ``xp`` is ``numpy`` or ``jax.numpy`` (the engine jits the latter).
    All of D1/D2/Tser/budget are int64 arrays of shape (B,); the search
    enumerates R in [1, r_max_total] for every batch element at once and
    masks candidates beyond each element's own ``min(D1, budget)``.

    Candidate enumeration, ordering and tie-breaking mirror the original
    three-variant scalar search exactly (ascending R, first minimum
    wins), so a batch of one reproduces it bit-for-bit — but only one
    tau per candidate is evaluated: of the original variants
    {(R, C_cap), (R, C2), (R2, C2)} the fold-tightened (R2, C2) always
    wins, since C2 = ceil(D2/ceil(D2/C_cap)) <= C_cap and
    R2 = ceil(D1/ceil(D1/R)) <= R leave both fold counts unchanged
    while shrinking the per-fold fill term 2R + C.
    """
    if xp is np and (
        max(int(D1.max(initial=0)), int(D2.max(initial=0)), int(budget.max(initial=0)))
        < 2**52
    ):
        # numpy's integer floordiv is a scalar loop while float64 math is
        # SIMD, and float64 is *exact* on integers < 2^53: every ceil-div
        # here has quotient*divisor <= dividend < 2^52, so
        # floor(fl((a+b-1)/b)) == ceil(a/b) holds exactly. tau products
        # are guarded below and fall back to int64 on overflow.
        out = _search_rc_f64(D1, D2, Tser, budget, r_max_total)
        if out is not None:
            return out
    D1 = D1[:, None]
    D2 = D2[:, None]
    Tser = Tser[:, None]
    budget = budget[:, None]
    R = xp.arange(1, r_max_total + 1, dtype=xp.int64)[None, :]
    valid = R <= xp.minimum(D1, budget)
    foldM = -(-D1 // R)
    C1 = xp.minimum(xp.maximum(budget // R, 1), D2)
    f = -(-D2 // C1)
    C2 = -(-D2 // f)  # tightened: same folds, smaller C
    R2 = -(-D1 // foldM)  # tightened: same folds, smaller R
    taus = (2 * R2 + C2 + Tser - 2) * (foldM * f)
    taus = xp.where(valid, taus, INVALID_CYCLES)
    i = xp.argmin(taus, axis=1)[:, None]

    def take(a):
        return xp.take_along_axis(xp.broadcast_to(a, taus.shape), i, axis=1)[:, 0]

    return take(R2), take(C2), take(taus)


def _search_rc_f64(D1, D2, Tser, budget, r_max_total: int):
    """All-float64 numpy fast path of ``_search_rc``.

    Identical results by construction (every intermediate is an exactly
    represented integer); returns None when a tau candidate reaches
    2^53, in which case the caller reruns the chunk in int64.
    """
    D1f = D1.astype(np.float64)[:, None]
    D2f = D2.astype(np.float64)[:, None]
    Tf = Tser.astype(np.float64)[:, None]
    bf = budget.astype(np.float64)[:, None]
    Rf = np.arange(1.0, r_max_total + 1.0)[None, :]
    foldM = np.floor((D1f + Rf - 1.0) / Rf)
    C1 = np.minimum(np.maximum(np.floor(bf / Rf), 1.0), D2f)
    f = np.floor((D2f + C1 - 1.0) / C1)
    C2 = np.floor((D2f + f - 1.0) / f)  # tightened: same folds, smaller C
    R2 = np.floor((D1f + foldM - 1.0) / foldM)  # tightened, same folds
    taus = (2.0 * R2 + C2 + Tf - 2.0) * (foldM * f)
    if np.max(taus, initial=0.0) >= 2.0**53:
        return None
    taus = np.where(Rf <= np.minimum(D1f, bf), taus, np.inf)
    i = np.argmin(taus, axis=1)[:, None]

    def take(a):
        sel = np.take_along_axis(np.broadcast_to(a, taus.shape), i, axis=1)[:, 0]
        return sel.astype(np.int64)

    r, c = take(R2), take(C2)
    t = np.take_along_axis(taus, i, axis=1)[:, 0]
    return r, c, np.where(np.isfinite(t), t, INVALID_CYCLES).astype(np.int64)


def _square_rc(xp, D1, D2, Tser, budget):
    """Batched 'square' mode: R = C = floor(sqrt(budget)), fold-tightened."""
    side = xp.maximum(xp.floor(xp.sqrt(budget)).astype(xp.int64), 1)
    r = xp.minimum(side, -(-D1 // (-(-D1 // side))))
    c = xp.minimum(side, -(-D2 // (-(-D2 // side))))
    t = (2 * r + c + Tser - 2) * (-(-D1 // r)) * (-(-D2 // c))
    return r, c, t


def optimize_rc_batched(
    M, K, N, n_macs, tiers, dataflow: str = "dos", mode: OptMode = "opt",
    backend: str = "numpy",
):
    """Batched array-shape optimizer over whole design grids.

    Broadcasts ``M, K, N, n_macs, tiers`` against each other, derives the
    per-tier budget ``n_macs // tiers`` (the paper rounds down "to avoid
    resource over-provision", Sec. IV-A), and returns ``(rows, cols,
    cycles)`` int64 arrays of the broadcast shape. Design points whose
    per-tier budget is < 1 get ``cycles == INVALID_CYCLES``.

    Delegates to the engine's chunked/table-factored search — the one
    implementation behind the scalar optimizers, ``evaluate()`` and
    this function alike. ``backend`` selects numpy or the jitted JAX
    search kernel.
    """
    from .engine import _DEFAULT_CHUNK, _optimize_flat  # lazy: engine imports us

    M, K, N, n_macs, L = np.broadcast_arrays(
        *(np.asarray(x, dtype=np.int64) for x in (M, K, N, n_macs, tiers))
    )
    shape = M.shape
    flat = [np.ascontiguousarray(x.reshape(-1)) for x in (M, K, N, n_macs, L)]
    r, c, t = _optimize_flat(*flat, dataflow, mode, backend, _DEFAULT_CHUNK)
    return r.reshape(shape), c.reshape(shape), t.reshape(shape)


def _best_rc(M, K, N, budget, tiers, mode: OptMode):
    """Find (R, C) minimizing Eq. 2 for a per-tier MAC budget.

    ``mode='square'`` reproduces the paper's plotted configurations
    (square tiers, R = C = floor(sqrt(budget))); ``mode='opt'`` searches
    all useful rectangular shapes with R*C <= budget. Rows beyond M and
    columns beyond N are never useful (they only add fill/drain time),
    so the search space is R in [1, min(M, budget)].

    Thin scalar wrapper over the batched kernel (batch of one) — the
    batched path IS the implementation.
    """
    budget = int(budget)
    if budget < 1:
        raise ValueError(f"per-tier MAC budget must be >= 1, got {budget}")
    D1, D2, Tser = dataflow_dims(
        "dos", np.array([M]), np.array([K]), np.array([N]), np.array([tiers])
    )
    b = np.array([budget], dtype=np.int64)
    if mode == "square":
        r, c, t = _square_rc(np, D1, D2, Tser, b)
    else:
        r, c, t = _search_rc(np, D1, D2, Tser, b, int(min(int(M), budget)))
    return int(r[0]), int(c[0]), float(t[0])


def optimize_array_2d(M, K, N, n_macs, mode: OptMode = "opt") -> ArrayPlan:
    """Paper's [13] methodology: best (R, C) for a 2D array budget."""
    r, c, t = _best_rc(M, K, N, n_macs, 1, mode)
    return ArrayPlan(
        rows=r, cols=c, tiers=1, cycles=t, n_macs_used=r * c,
        workload_macs=int(M) * int(K) * int(N),
    )


def optimize_array_3d(M, K, N, n_macs, tiers, mode: OptMode = "opt") -> ArrayPlan:
    """Best per-tier (R', C') for a 3D array: budget floor(n_macs/tiers).

    The paper rounds the per-tier budget down "to avoid resource
    over-provision" (Sec. IV-A).
    """
    tiers = int(tiers)
    per_tier = int(n_macs) // tiers
    r, c, t = _best_rc(M, K, N, per_tier, tiers, mode)
    return ArrayPlan(
        rows=r, cols=c, tiers=tiers, cycles=t, n_macs_used=tiers * r * c,
        workload_macs=int(M) * int(K) * int(N),
    )


def speedup_3d(M, K, N, n_macs, tiers, mode: OptMode = "opt") -> float:
    """Speedup of the optimized 3D array over the optimized 2D array
    with the same MAC budget (the y-axis of Figs. 5 and 6)."""
    t2 = optimize_array_2d(M, K, N, n_macs, mode).cycles
    t3 = optimize_array_3d(M, K, N, n_macs, tiers, mode).cycles
    return float(t2 / t3)


def optimal_tiers(M, K, N, n_macs, max_tiers: int = 16, mode: OptMode = "opt"):
    """argmin over tier count of the optimized 3D runtime (Fig. 7)."""
    best_l, best_t = 1, np.inf
    for l in range(1, int(max_tiers) + 1):
        if n_macs // l < 1:
            break
        t = optimize_array_3d(M, K, N, n_macs, l, mode).cycles
        if t < best_t:
            best_l, best_t = l, t
    return best_l, best_t


def mac_threshold(M, N) -> int:
    """N_min — minimum MAC budget for 3D to outperform 2D (Sec. IV-A.1).

    The paper finds 3D pays off only once the array can hold the whole
    M x N output spatially: ``N_macs > M*N``.
    """
    return int(M) * int(N)
