"""Analytical performance model for 2D and 3D systolic arrays.

Implements and extends the runtime model of the paper (Eqs. 1 and 2),
which itself extends SCALE-Sim's [13, Eq. (4)] output-stationary model.

A GEMM workload is ``A(M x K) @ B(K x N)``. For an output-stationary (OS)
2D array with R rows and C columns (``N_macs = R*C``):

    tau_2d = (2R + C + K - 2) * ceil(M/R) * ceil(N/C)          (Eq. 1)

For the distributed-output-stationary (dOS) 3D array with ``l`` tiers of
R' x C' each (``N_macs = l * R' * C'``), the contraction dim K is split
across tiers (each works on K/l) and the partial sums are accumulated
down the tier pile with ``l - 1`` sequential adds:

    tau_3d = (2R' + C' + (ceil(K/l) + l - 1) - 2)
             * ceil(M/R') * ceil(N/C')                          (Eq. 2)

All functions are vectorized over numpy arrays so the DSE sweeps
(Figs. 5-7, 9) run in milliseconds.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

import numpy as np

__all__ = [
    "GEMM",
    "tau_2d",
    "tau_3d",
    "optimize_array_2d",
    "optimize_array_3d",
    "speedup_3d",
    "optimal_tiers",
    "mac_threshold",
    "ArrayPlan",
]

OptMode = Literal["opt", "square"]


@dataclasses.dataclass(frozen=True)
class GEMM:
    """A GEMM workload ``A(M x K) @ B(K x N)``."""

    M: int
    K: int
    N: int
    name: str = ""

    @property
    def flops(self) -> int:
        return 2 * self.M * self.K * self.N

    @property
    def macs(self) -> int:
        return self.M * self.K * self.N


@dataclasses.dataclass(frozen=True)
class ArrayPlan:
    """A chosen array configuration and its predicted runtime (cycles)."""

    rows: int
    cols: int
    tiers: int
    cycles: float
    n_macs_used: int

    @property
    def utilization(self) -> float:
        """Useful MAC-ops per provisioned MAC-cycle (<= 1)."""
        return np.nan  # filled by callers that know the workload


def _ceil_div(a, b):
    return -(-np.asarray(a) // np.asarray(b))


def tau_2d(M, K, N, R, C):
    """Eq. 1 — runtime in cycles of an OS 2D array (vectorized)."""
    M, K, N, R, C = np.broadcast_arrays(
        *(np.asarray(x, dtype=np.int64) for x in (M, K, N, R, C))
    )
    return (2 * R + C + K - 2) * _ceil_div(M, R) * _ceil_div(N, C)


def tau_3d(M, K, N, R, C, tiers):
    """Eq. 2 — runtime in cycles of a dOS 3D array (vectorized).

    ``R, C`` are the *per-tier* dimensions. ``tiers == 1`` exactly
    recovers Eq. 1 (a property test asserts this).
    """
    M, K, N, R, C, L = np.broadcast_arrays(
        *(np.asarray(x, dtype=np.int64) for x in (M, K, N, R, C, tiers))
    )
    k_per_tier = _ceil_div(K, L)
    return (2 * R + C + (k_per_tier + L - 1) - 2) * _ceil_div(M, R) * _ceil_div(N, C)


def _best_rc(M, K, N, budget, tiers, mode: OptMode):
    """Find (R, C) minimizing Eq. 2 for a per-tier MAC budget.

    ``mode='square'`` reproduces the paper's plotted configurations
    (square tiers, R = C = floor(sqrt(budget))); ``mode='opt'`` searches
    all useful rectangular shapes with R*C <= budget. Rows beyond M and
    columns beyond N are never useful (they only add fill/drain time),
    so the search space is R in [1, min(M, budget)].
    """
    budget = int(budget)
    if budget < 1:
        raise ValueError(f"per-tier MAC budget must be >= 1, got {budget}")
    if mode == "square":
        side = max(int(np.floor(np.sqrt(budget))), 1)
        r = min(side, _round_up_to_fold(M, side))
        c = min(side, _round_up_to_fold(N, side))
        t = tau_3d(M, K, N, r, c, tiers)
        return int(r), int(c), float(t)
    # Full search. Candidate R values: 1..min(M, budget); for each, the
    # best C is min(budget // R, N') where N' rounds N up to its fold
    # boundary (larger C only adds +C to the fill term).
    r_max = int(min(M, budget))
    R = np.arange(1, r_max + 1, dtype=np.int64)
    C_cap = np.maximum(budget // R, 1)
    # Optimal C given a fold count f = ceil(N/C) is the smallest C with
    # that fold count, i.e. C = ceil(N/f). Enumerate both the capped C
    # and its fold-tightened version.
    C1 = np.minimum(C_cap, N)
    f = _ceil_div(N, C1)
    C2 = _ceil_div(N, f)  # tightened: same folds, smaller C
    taus1 = tau_3d(M, K, N, R, C1, tiers)
    taus2 = tau_3d(M, K, N, R, C2, tiers)
    taus = np.where(taus2 <= taus1, taus2, taus1)
    Cs = np.where(taus2 <= taus1, C2, C1)
    # Also tighten R to its fold boundary (same ceil(M/R), smaller R).
    fR = _ceil_div(M, R)
    R2 = _ceil_div(M, fR)
    taus_r = tau_3d(M, K, N, R2, Cs, tiers)
    taus = np.minimum(taus, taus_r)
    Rs = np.where(taus_r <= taus, R2, R)
    i = int(np.argmin(taus))
    return int(Rs[i]), int(Cs[i]), float(taus[i])


def _round_up_to_fold(dim, side):
    """Smallest R <= side with the same ceil(dim/R) as side (tighten)."""
    f = -(-int(dim) // int(side))
    return -(-int(dim) // f)


def optimize_array_2d(M, K, N, n_macs, mode: OptMode = "opt") -> ArrayPlan:
    """Paper's [13] methodology: best (R, C) for a 2D array budget."""
    r, c, t = _best_rc(M, K, N, n_macs, 1, mode)
    return ArrayPlan(rows=r, cols=c, tiers=1, cycles=t, n_macs_used=r * c)


def optimize_array_3d(M, K, N, n_macs, tiers, mode: OptMode = "opt") -> ArrayPlan:
    """Best per-tier (R', C') for a 3D array: budget floor(n_macs/tiers).

    The paper rounds the per-tier budget down "to avoid resource
    over-provision" (Sec. IV-A).
    """
    tiers = int(tiers)
    per_tier = int(n_macs) // tiers
    r, c, t = _best_rc(M, K, N, per_tier, tiers, mode)
    return ArrayPlan(rows=r, cols=c, tiers=tiers, cycles=t, n_macs_used=tiers * r * c)


def speedup_3d(M, K, N, n_macs, tiers, mode: OptMode = "opt") -> float:
    """Speedup of the optimized 3D array over the optimized 2D array
    with the same MAC budget (the y-axis of Figs. 5 and 6)."""
    t2 = optimize_array_2d(M, K, N, n_macs, mode).cycles
    t3 = optimize_array_3d(M, K, N, n_macs, tiers, mode).cycles
    return float(t2 / t3)


def optimal_tiers(M, K, N, n_macs, max_tiers: int = 16, mode: OptMode = "opt"):
    """argmin over tier count of the optimized 3D runtime (Fig. 7)."""
    best_l, best_t = 1, np.inf
    for l in range(1, int(max_tiers) + 1):
        if n_macs // l < 1:
            break
        t = optimize_array_3d(M, K, N, n_macs, l, mode).cycles
        if t < best_t:
            best_l, best_t = l, t
    return best_l, best_t


def mac_threshold(M, N) -> int:
    """N_min — minimum MAC budget for 3D to outperform 2D (Sec. IV-A.1).

    The paper finds 3D pays off only once the array can hold the whole
    M x N output spatially: ``N_macs > M*N``.
    """
    return int(M) * int(N)
