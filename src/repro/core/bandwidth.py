"""Bandwidth-aware runtime model: DRAM, SRAM and vertical-link limits.

The paper's 9.14x 3D-vs-2D speedup (Figs. 5-7) assumes every operand
is on-chip the cycle the array wants it — a *compute-bound* mapping.
Its own TSV/MIV discussion (Sec. III-B) and the memory-bandwidth
characterization in "Towards 3D AI Hardware" make clear that whether a
stacked design realizes that speedup is decided by three resources the
runtime model of Eqs. 1/2 does not see:

- **DRAM bandwidth** [GB/s]: operands that miss on-chip SRAM must
  stream from DRAM; a design whose traffic-per-cycle exceeds the DRAM
  interface stalls the array.
- **On-chip SRAM capacity per tier** [bytes]: decides *how much*
  DRAM traffic there is (operand reuse across array folds) and, below
  the minimal working set, whether the design can run at all — SRAM
  capacity joins thermal as a first-class feasibility mask.
- **Vertical-link bandwidth** [bytes/cycle per tier boundary]: the dOS
  dataflow pushes one partial-sum plane (R x C accumulator words) down
  every tier boundary per fold. MIVs are small enough ([21], ~0.05
  um^2) to afford one full 17-bit bus per MAC pile; TSVs (~30 um^2
  with keep-out [20]) force bus sharing — the technology choice
  becomes a *bandwidth* distinction, not just a capacitance one.

``gemm_traffic_batched`` computes, for a whole batch of (workload,
design) pairs at once, the DRAM bytes, vertical-link bytes and
minimum SRAM working set of a GEMM on an (R, C, L) array under a
``BandwidthSpec``; ``roofline_cycles`` combines the compute cycles of
Eqs. 1/2 with the resulting memory/vertical-link service times into

    total_cycles = max(compute, memory, vlink)        (overlapped roofline)
    stall_cycles = total - compute
    bound        = argmax term ('compute' | 'memory' | 'vlink')

Everything here is exact float64 on integer-valued inputs (< 2^53) and
**identity-preserving**: the default ``BandwidthSpec()`` is unbounded
in every resource, which makes ``stall_cycles == 0``, ``bound ==
'compute'`` and every engine output bit-for-bit identical to the
bandwidth-oblivious path (regression-tested in
``tests/test_bandwidth.py``).

Reuse model (documented, deterministic). Traffic is counted per
logical tensor — A (M x K), B (K x N), O (M x N) — with reuse decided
by which resident tiles fit in the per-tier SRAM, checked in a fixed
order (stationary plane + stream buffers first, then A's resident
tile, then B's):

- os/dos (outputs stationary, K split over L tiers, Kt = ceil(K/L)):
  O is written once (accumulation stays on-chip / down the pile). A is
  read once iff its per-tier fold-row slice (R * Kt bytes_in) stays
  resident across the ceil(N/C) column folds, else ceil(N/C) times. B
  is read once iff its full per-tier slice (Kt * N bytes_in) fits too,
  else ceil(M/R) times.
- ws (weights stationary; M split over L tiers, Mt = ceil(M/L)): B is
  read once. A is read once iff its per-tier resident slice (Mt * K)
  fits, else ceil(N/R) times. Partial outputs accumulate across the
  ceil(K/C) contraction folds: spilled ((2*ceil(K/C) - 1) * M * N
  accumulator words) unless the per-tier accumulator tile (Mt * R)
  fits.
- is (inputs stationary; N split over L tiers, Nt = ceil(N/L)):
  symmetric to ws with A and B swapped.

Vertical links carry cross-tier traffic only for dOS (WS/IS-in-3D
split a temporal dimension and exchange nothing — see
``analytical.dataflow_dims``): per fold, each of the L - 1 tier
boundaries moves the R x C partial-sum plane (bytes_acc per word). The
boundaries operate concurrently, so the vlink service time is one
boundary's traffic over one boundary's bandwidth.

Fold traffic model (``fold_traffic_batched``, the ``tier_fold``
policy's pricing). A non-native fold re-partitions the GEMM across
tiers (see ``analytical.fold_dims``); the traffic convention is the
one the native model already uses: each tier's *own* operand and
result slices ride the planar distribution network and are priced by
the DRAM term alone — vertical links carry only the traffic the fold
*creates* across tier boundaries:

- folding the contraction dim K on ws/is mirrors dOS: every fold
  pushes an R x C partial-sum plane down each of the L - 1 boundaries;
- folding an output dim (m/n) makes the l tiers independent sub-GEMMs
  that all consume the *same* copy of the non-split operand (fold-m
  shares B, fold-n shares A): that operand's DRAM stream is multicast
  down the pile, so each of the L - 1 boundaries carries one copy of
  the stream and the vlink service time is the stream over one
  boundary's bandwidth. Splitting an output dim also *cuts* the shared
  operand's re-stream count (the per-tier fold count over the split
  dim shrinks by ~l) — the fold's DRAM-side win.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from .ppa import constants as C

__all__ = [
    "BOUND_NAMES",
    "BandwidthSpec",
    "TSV_VLINK_SHARE",
    "bound_names",
    "fold_traffic_batched",
    "gemm_traffic_batched",
    "resolve_vlink_bits",
    "roofline_cycles",
]

#: bound classification order — ties break toward the earlier name, so
#: an exactly-balanced (or unbounded) design reports 'compute'.
BOUND_NAMES = ("compute", "memory", "vlink")

#: MAC piles per shared TSV bus. One 17-bit TSV bus per MAC pile would
#: cost VLINK_BITS * A_TSV_UM2 / A_MAC_UM2 ~ 128% area overhead — far
#: beyond the paper's "worst-case over-provisioning"; sharing one bus
#: among 16 piles brings the overhead to ~8% (the few-percent regime
#: the paper quotes for vias) at 1/16 the per-pile bandwidth. MIVs
#: (~0.05 um^2) afford a full bus per pile at < 0.3% overhead.
TSV_VLINK_SHARE = 16


@dataclasses.dataclass(frozen=True)
class BandwidthSpec:
    """Memory-system model for bandwidth-aware evaluation.

    Every default is *unbounded* — ``BandwidthSpec()`` produces zero
    stall cycles and leaves engine results bit-for-bit unchanged; cap
    a resource to make it bind.

    - ``dram_gbs``: DRAM/HBM interface bandwidth [GB/s; 1 GB = 1e9
      bytes]. At the paper's 1 GHz clock, ``dram_gbs`` is also the
      interface's bytes/cycle.
    - ``sram_kib_per_tier``: on-chip SRAM per tier [KiB]. Governs both
      operand reuse (how often A/B re-stream from DRAM) and the
      SRAM-capacity feasibility mask (designs whose minimal working
      set does not fit are infeasible).
    - ``vlink_bits_per_mac``: vertical bus width per MAC pile
      [bits/cycle], or ``'derived'`` to take the per-technology
      default (miv: the full ``VLINK_BITS``-bit bus; tsv: shared
      ``VLINK_BITS / TSV_VLINK_SHARE``; 2d: unbounded — no vertical
      links exist).
    - ``bytes_in``: operand word size [bytes] (paper: 8-bit operands).
    - ``bytes_acc``: partial-sum/accumulator word size [bytes]
      (paper: 16-bit accumulators).
    """

    dram_gbs: float = math.inf
    sram_kib_per_tier: float = math.inf
    vlink_bits_per_mac: float | str = math.inf
    bytes_in: int = 1
    bytes_acc: int = 2

    def __post_init__(self):
        for name in ("dram_gbs", "sram_kib_per_tier"):
            v = float(getattr(self, name))
            if not v > 0:
                raise ValueError(f"{name} must be > 0 (inf = unbounded), got {v}")
            object.__setattr__(self, name, v)
        v = self.vlink_bits_per_mac
        if isinstance(v, str):
            if v != "derived":
                raise ValueError(
                    f"vlink_bits_per_mac must be a positive width in bits or "
                    f"'derived', got {v!r}"
                )
        else:
            v = float(v)
            if not v > 0:
                raise ValueError(
                    f"vlink_bits_per_mac must be > 0 (inf = unbounded), got {v}"
                )
            object.__setattr__(self, "vlink_bits_per_mac", v)
        for name in ("bytes_in", "bytes_acc"):
            v = int(getattr(self, name))
            if v < 1:
                raise ValueError(f"{name} must be >= 1 byte, got {v}")
            object.__setattr__(self, name, v)

    @property
    def unbounded(self) -> bool:
        """True when no resource can bind (the identity spec)."""
        return (
            math.isinf(self.dram_gbs)
            and math.isinf(self.sram_kib_per_tier)
            and (
                not isinstance(self.vlink_bits_per_mac, str)
                and math.isinf(self.vlink_bits_per_mac)
            )
        )

    @property
    def sram_bytes(self) -> float:
        """Per-tier SRAM capacity [bytes]."""
        return self.sram_kib_per_tier * 1024.0

    @property
    def dram_bytes_per_cycle(self) -> float:
        """DRAM service rate [bytes/cycle] at the model's clock."""
        return self.dram_gbs * 1e9 / C.FREQ_HZ

    @classmethod
    def paper_default(cls) -> "BandwidthSpec":
        """A representative capped memory system for reports/benchmarks:
        HBM2-class 256 GB/s DRAM, 1 MiB SRAM per tier, per-technology
        derived vertical buses. On the Table-I workloads x the paper's
        budgets this splits the grid ~30/70 between compute- and
        memory-bound points (vlink binds only on short-fold decode-like
        shapes) and caps the headline 3D-vs-2D speedup well below the
        compute-bound prediction — the honest version of Fig. 5-7."""
        return cls(dram_gbs=256.0, sram_kib_per_tier=1024.0,
                   vlink_bits_per_mac="derived")

    def to_dict(self) -> dict:
        """JSON-compatible form (non-finite floats as strings — the
        study layer's strict-JSON convention); ``from_dict`` inverts."""
        out = dataclasses.asdict(self)
        for k, v in out.items():
            if isinstance(v, float) and math.isinf(v):
                out[k] = "Infinity"
        return out

    @classmethod
    def from_dict(cls, d: dict) -> "BandwidthSpec":
        kw = dict(d)
        for k in ("dram_gbs", "sram_kib_per_tier"):
            if k in kw:
                kw[k] = float(kw[k])
        v = kw.get("vlink_bits_per_mac")
        if v is not None and not isinstance(v, str):
            kw["vlink_bits_per_mac"] = float(v)
        elif v == "Infinity":
            kw["vlink_bits_per_mac"] = math.inf
        return cls(**kw)


def resolve_vlink_bits(spec: BandwidthSpec, tech) -> np.ndarray:
    """Per-point vertical bus width [bits/cycle per MAC pile].

    ``tech`` is a ('2d'|'tsv'|'miv') array; '2d' is always unbounded
    (there is no vertical link to saturate).
    """
    tech = np.asarray(tech)
    if spec.vlink_bits_per_mac == "derived":
        bits = np.where(
            tech == "miv",
            float(C.VLINK_BITS),
            np.where(tech == "tsv", C.VLINK_BITS / TSV_VLINK_SHARE, np.inf),
        )
    else:
        bits = np.full(tech.shape, float(spec.vlink_bits_per_mac))
    return np.where(tech == "2d", np.inf, bits)


def _ceil(a, b):
    return np.floor((a + b - 1.0) / b)


def gemm_traffic_batched(dataflow: str, M, K, N, R, Cc, L, tech, spec: BandwidthSpec,
                         sram_bytes=None):
    """Traffic + working set of a GEMM batch on (R, C, L) arrays.

    All array arguments are flat int arrays of one dataflow group (the
    engine splits per dataflow); ``tech`` is a parallel str array.
    ``sram_bytes`` (optional) overrides ``spec.sram_bytes`` with a
    parallel per-element capacity array [bytes] — the engine passes it
    when the grid carries per-point SRAM axes (guided search over
    memory systems); ``None`` keeps the spec's scalar capacity.
    Returns a dict of float64 arrays, per batch element:

    - ``dram_bytes``: total DRAM traffic [bytes] under the module's
      reuse model (A + B + O);
    - ``vlink_bytes``: total cross-tier traffic [bytes] (all L - 1
      boundaries summed; 0 for ws/is and for L == 1);
    - ``vlink_cycles``: vertical-link service time [cycles] — one
      boundary's traffic over one boundary's bandwidth (boundaries run
      concurrently);
    - ``sram_need_bytes``: minimal per-tier working set [bytes]
      (stationary plane + double-buffered edge streams) — the
      SRAM-capacity feasibility threshold.
    """
    M, K, N, R, Cc, L = (np.asarray(x, dtype=np.float64) for x in (M, K, N, R, Cc, L))
    bi, ba = float(spec.bytes_in), float(spec.bytes_acc)
    sram = (
        spec.sram_bytes
        if sram_bytes is None
        else np.asarray(sram_bytes, dtype=np.float64)
    )
    vbits = resolve_vlink_bits(spec, tech)
    zeros = np.zeros_like(M)

    if dataflow in ("os", "dos"):
        Kt = _ceil(K, L)
        foldM = _ceil(M, R)
        foldN = _ceil(N, Cc)
        base = R * Cc * ba + 2.0 * (R + Cc) * bi
        a_tile = R * Kt * bi
        b_slice = Kt * N * bi
        reuse_a = base + a_tile <= sram
        reuse_b = reuse_a & (base + a_tile + b_slice <= sram)
        a_bytes = np.where(reuse_a, 1.0, foldN) * M * K * bi
        b_bytes = np.where(reuse_b, 1.0, foldM) * K * N * bi
        o_bytes = M * N * ba
        folds = foldM * foldN
        vlink_bytes = np.where(L > 1.0, (L - 1.0) * folds * R * Cc * ba, 0.0)
        with np.errstate(divide="ignore"):
            per_boundary_bw = R * Cc * vbits / 8.0  # bytes/cycle
            vlink_cycles = np.where(
                L > 1.0, folds * R * Cc * ba / per_boundary_bw, 0.0
            )
        return dict(
            dram_bytes=a_bytes + b_bytes + o_bytes,
            vlink_bytes=vlink_bytes,
            vlink_cycles=vlink_cycles,
            sram_need_bytes=base,
        )

    if dataflow == "ws":
        # N, K spatial; M temporal, split across tiers (no vlink traffic).
        Mt = _ceil(M, L)
        foldN = _ceil(N, R)
        foldK = _ceil(K, Cc)
        base = R * Cc * bi + 2.0 * (R * ba + Cc * bi)
        stationary_bytes = K * N * bi  # weights, loaded once
        a_resident = Mt * K * bi
        reuse_a = base + a_resident <= sram
        a_bytes = np.where(reuse_a, 1.0, foldN) * M * K * bi
        o_tile = Mt * R * ba
        o_fits = base + np.where(reuse_a, a_resident, 0.0) + o_tile <= sram
        o_bytes = np.where(o_fits, 1.0, 2.0 * foldK - 1.0) * M * N * ba
        return dict(
            dram_bytes=stationary_bytes + a_bytes + o_bytes,
            vlink_bytes=zeros,
            vlink_cycles=zeros,
            sram_need_bytes=base,
        )

    if dataflow == "is":
        # M, K spatial; N temporal, split across tiers (no vlink traffic).
        Nt = _ceil(N, L)
        foldM = _ceil(M, R)
        foldK = _ceil(K, Cc)
        base = R * Cc * bi + 2.0 * (R * ba + Cc * bi)
        stationary_bytes = M * K * bi  # inputs, loaded once
        b_resident = Nt * K * bi
        reuse_b = base + b_resident <= sram
        b_bytes = np.where(reuse_b, 1.0, foldM) * K * N * bi
        o_tile = Nt * R * ba
        o_fits = base + np.where(reuse_b, b_resident, 0.0) + o_tile <= sram
        o_bytes = np.where(o_fits, 1.0, 2.0 * foldK - 1.0) * M * N * ba
        return dict(
            dram_bytes=stationary_bytes + b_bytes + o_bytes,
            vlink_bytes=zeros,
            vlink_cycles=zeros,
            sram_need_bytes=base,
        )

    raise ValueError(f"unknown dataflow {dataflow!r}")


def fold_traffic_batched(fold, dataflow: str, M, K, N, R, Cc, L, tech,
                         spec: BandwidthSpec, sram_bytes=None):
    """Traffic + working set of a GEMM batch under a chosen tier fold.

    Same contract as ``gemm_traffic_batched`` (which it returns
    verbatim for the dataflow's native fold or ``fold=None`` — the
    identity that keeps the fixed/per_layer policies bit-stable), plus
    the two non-native folds per dataflow, priced under the module's
    fold traffic convention (module docstring): per-tier slices ride
    the planar network (DRAM term), vertical links carry only
    fold-created traffic — dOS-style partial-sum planes for a
    non-native fold-k, the shared operand's multicast stream for an
    output-dim fold. ``tests/oracle_fold.py`` reprices every branch
    with explicit per-tier/per-boundary loops; the differential tests
    assert bit-for-bit agreement.
    """
    from .analytical import native_fold

    if fold is None or fold == native_fold(dataflow):
        return gemm_traffic_batched(dataflow, M, K, N, R, Cc, L, tech, spec,
                                    sram_bytes=sram_bytes)
    M, K, N, R, Cc, L = (np.asarray(x, dtype=np.float64) for x in (M, K, N, R, Cc, L))
    bi, ba = float(spec.bytes_in), float(spec.bytes_acc)
    sram = (
        spec.sram_bytes
        if sram_bytes is None
        else np.asarray(sram_bytes, dtype=np.float64)
    )
    vbits = resolve_vlink_bits(spec, tech)

    def _stream_vlink(stream_bytes):
        # The shared operand's DRAM stream is multicast down the pile:
        # all L - 1 boundaries carry one copy each; service time is the
        # stream over one boundary's concurrent bandwidth.
        with np.errstate(divide="ignore"):
            per_boundary_bw = R * Cc * vbits / 8.0
            cycles = np.where(L > 1.0, stream_bytes / per_boundary_bw, 0.0)
        return np.where(L > 1.0, (L - 1.0) * stream_bytes, 0.0), cycles

    if dataflow in ("os", "dos"):
        base = R * Cc * ba + 2.0 * (R + Cc) * bi
        a_tile = R * K * bi  # full-K row tile: the fold keeps K whole
        if fold == "m":
            Mt = _ceil(M, L)
            foldMt = _ceil(Mt, R)
            foldN = _ceil(N, Cc)
            b_slice = K * N * bi  # B is shared whole across tiers
            reuse_a = base + a_tile <= sram
            reuse_b = reuse_a & (base + a_tile + b_slice <= sram)
            a_bytes = np.where(reuse_a, 1.0, foldN) * M * K * bi
            b_stream = np.where(reuse_b, 1.0, foldMt) * K * N * bi
            o_bytes = M * N * ba
            vlink_bytes, vlink_cycles = _stream_vlink(b_stream)
            return dict(
                dram_bytes=a_bytes + b_stream + o_bytes,
                vlink_bytes=vlink_bytes,
                vlink_cycles=vlink_cycles,
                sram_need_bytes=base,
            )
        if fold == "n":
            Nt = _ceil(N, L)
            foldM = _ceil(M, R)
            foldNt = _ceil(Nt, Cc)
            b_slice = K * Nt * bi  # per-tier column slice of B
            reuse_a = base + a_tile <= sram
            reuse_b = reuse_a & (base + a_tile + b_slice <= sram)
            a_stream = np.where(reuse_a, 1.0, foldNt) * M * K * bi
            b_bytes = np.where(reuse_b, 1.0, foldM) * K * N * bi
            o_bytes = M * N * ba
            vlink_bytes, vlink_cycles = _stream_vlink(a_stream)
            return dict(
                dram_bytes=a_stream + b_bytes + o_bytes,
                vlink_bytes=vlink_bytes,
                vlink_cycles=vlink_cycles,
                sram_need_bytes=base,
            )

    if dataflow == "ws":
        base = R * Cc * bi + 2.0 * (R * ba + Cc * bi)
        stationary_bytes = K * N * bi  # weights, loaded once
        if fold == "k":
            # dOS-style contraction split: partial-sum planes down the pile.
            Kt = _ceil(K, L)
            foldN = _ceil(N, R)
            foldKt = _ceil(Kt, Cc)
            a_resident = M * Kt * bi  # per-tier K slice, full temporal M
            reuse_a = base + a_resident <= sram
            a_bytes = np.where(reuse_a, 1.0, foldN) * M * K * bi
            o_tile = M * R * ba
            o_fits = base + np.where(reuse_a, a_resident, 0.0) + o_tile <= sram
            o_bytes = np.where(o_fits, 1.0, 2.0 * foldKt - 1.0) * M * N * ba
            folds = foldN * foldKt
            vlink_bytes = np.where(L > 1.0, (L - 1.0) * folds * R * Cc * ba, 0.0)
            with np.errstate(divide="ignore"):
                per_boundary_bw = R * Cc * vbits / 8.0
                vlink_cycles = np.where(
                    L > 1.0, folds * R * Cc * ba / per_boundary_bw, 0.0
                )
            return dict(
                dram_bytes=stationary_bytes + a_bytes + o_bytes,
                vlink_bytes=vlink_bytes,
                vlink_cycles=vlink_cycles,
                sram_need_bytes=base,
            )
        if fold == "n":
            Nt = _ceil(N, L)
            foldNt = _ceil(Nt, R)
            foldK = _ceil(K, Cc)
            a_resident = M * K * bi  # every tier consumes all of A
            reuse_a = base + a_resident <= sram
            a_stream = np.where(reuse_a, 1.0, foldNt) * M * K * bi
            o_tile = M * R * ba
            o_fits = base + np.where(reuse_a, a_resident, 0.0) + o_tile <= sram
            o_bytes = np.where(o_fits, 1.0, 2.0 * foldK - 1.0) * M * N * ba
            vlink_bytes, vlink_cycles = _stream_vlink(a_stream)
            return dict(
                dram_bytes=stationary_bytes + a_stream + o_bytes,
                vlink_bytes=vlink_bytes,
                vlink_cycles=vlink_cycles,
                sram_need_bytes=base,
            )

    if dataflow == "is":
        base = R * Cc * bi + 2.0 * (R * ba + Cc * bi)
        stationary_bytes = M * K * bi  # inputs, loaded once
        if fold == "k":
            Kt = _ceil(K, L)
            foldM = _ceil(M, R)
            foldKt = _ceil(Kt, Cc)
            b_resident = N * Kt * bi
            reuse_b = base + b_resident <= sram
            b_bytes = np.where(reuse_b, 1.0, foldM) * K * N * bi
            o_tile = N * R * ba
            o_fits = base + np.where(reuse_b, b_resident, 0.0) + o_tile <= sram
            o_bytes = np.where(o_fits, 1.0, 2.0 * foldKt - 1.0) * M * N * ba
            folds = foldM * foldKt
            vlink_bytes = np.where(L > 1.0, (L - 1.0) * folds * R * Cc * ba, 0.0)
            with np.errstate(divide="ignore"):
                per_boundary_bw = R * Cc * vbits / 8.0
                vlink_cycles = np.where(
                    L > 1.0, folds * R * Cc * ba / per_boundary_bw, 0.0
                )
            return dict(
                dram_bytes=stationary_bytes + b_bytes + o_bytes,
                vlink_bytes=vlink_bytes,
                vlink_cycles=vlink_cycles,
                sram_need_bytes=base,
            )
        if fold == "m":
            Mt = _ceil(M, L)
            foldMt = _ceil(Mt, R)
            foldK = _ceil(K, Cc)
            b_resident = N * K * bi  # every tier consumes all of B
            reuse_b = base + b_resident <= sram
            b_stream = np.where(reuse_b, 1.0, foldMt) * K * N * bi
            o_tile = N * R * ba
            o_fits = base + np.where(reuse_b, b_resident, 0.0) + o_tile <= sram
            o_bytes = np.where(o_fits, 1.0, 2.0 * foldK - 1.0) * M * N * ba
            vlink_bytes, vlink_cycles = _stream_vlink(b_stream)
            return dict(
                dram_bytes=stationary_bytes + b_stream + o_bytes,
                vlink_bytes=vlink_bytes,
                vlink_cycles=vlink_cycles,
                sram_need_bytes=base,
            )

    raise ValueError(f"unknown fold {fold!r} for dataflow {dataflow!r}")


def roofline_cycles(compute_cycles, mem_cycles, vlink_cycles):
    """Overlapped three-term roofline [cycles].

    Returns ``(total, stall, bound_idx)``: ``total = max(compute,
    memory, vlink)`` (the three engines run concurrently; the slowest
    paces the GEMM), ``stall = total - compute`` (extra cycles the MAC
    array waits), ``bound_idx`` indexes ``BOUND_NAMES`` with ties
    breaking toward compute — an unbounded spec therefore reports
    'compute' everywhere with exactly zero stall.
    """
    compute = np.asarray(compute_cycles, dtype=np.float64)
    mem = np.asarray(mem_cycles, dtype=np.float64)
    vlink = np.asarray(vlink_cycles, dtype=np.float64)
    total = np.maximum(compute, np.maximum(mem, vlink))
    stall = total - compute
    bound_idx = np.where(
        vlink > np.maximum(compute, mem),
        2,
        np.where(mem > compute, 1, 0),
    )
    return total, stall, bound_idx


def bound_names(bound_idx) -> np.ndarray:
    """Index array -> ('compute'|'memory'|'vlink') str array."""
    return np.asarray(BOUND_NAMES)[np.asarray(bound_idx, dtype=np.int64)]
