"""Content-addressed result cache: resumable large-scale Study runs.

A ``Study`` spec is canonically hashable (sorted-key strict JSON of
everything except the cosmetic ``name``), and the engine's evaluation
is exactly decomposable into independent sub-grid chunks (the (R, C)
search is rowwise independent — see ``DesignGrid.subset``). Together
those give bit-for-bit resumability: ``Study.run(cache=...)`` stores
each evaluated chunk under

    <root>/<spec-hash>/spec.json            the spec (for --resume)
    <root>/<spec-hash>/chunks/<key>.json    one evaluated sub-grid
    <root>/<spec-hash>/result.json          the finished artifact

and a re-run (or ``python -m repro run --resume <dir>``) loads every
chunk that already exists and computes only the missing ones.
**Invalidation rule**: the directory name IS the spec hash — any change
to the workload/space/constraints/analysis content lands in a fresh
directory; nothing is ever reused across differing specs. Fields that
provably cannot change a result bit (``name``, and the
backend/chunk/shard execution knobs) are excluded, so an interrupted
sweep resumes across executor settings. Chunk files
are written atomically (tmp + rename), so a killed run never leaves a
truncated chunk behind.
"""

from __future__ import annotations

import hashlib
import json
import os
import pathlib

__all__ = ["DEFAULT_CACHE_DIR", "ResultCache", "study_hash"]

#: conventional cache root (what the CLI uses when none is given).
DEFAULT_CACHE_DIR = ".repro_cache"

#: target result cells (workloads x points) per cached chunk — small
#: enough that an interrupted million-point sweep resumes at fine
#: granularity, large enough to amortize the engine's per-call setup.
DEFAULT_BLOCK_CELLS = 1 << 16


#: spec fields that cannot change a result bit and therefore do not key
#: the cache: ``name`` is cosmetic; backend ("identical integers"),
#: chunk ("results are independent of it"), shard (rowwise-
#: independent search) and workers (the work queue's chunk payloads
#: are bit-identical across process counts) are execution knobs — an
#: interrupted unsharded single-process sweep can resume sharded with
#: eight workers without recomputing anything.
_NON_CONTENT_TOP = ("name",)
_NON_CONTENT_ANALYSIS = ("backend", "chunk", "shard", "workers")


def study_hash(study) -> str:
    """Canonical content hash of a Study spec (16 hex chars).

    Hashes the sorted-key strict-JSON spec dict minus the
    result-invariant fields above; ``version`` bumps invalidate
    implicitly because the version is part of the dict.
    """
    d = dict(study.to_dict())
    for k in _NON_CONTENT_TOP:
        d.pop(k, None)
    if isinstance(d.get("analysis"), dict):
        d["analysis"] = {
            k: v for k, v in d["analysis"].items() if k not in _NON_CONTENT_ANALYSIS
        }
    canon = json.dumps(d, sort_keys=True, separators=(",", ":"), allow_nan=False)
    return hashlib.sha256(canon.encode()).hexdigest()[:16]


class ResultCache:
    """Spec-hash-keyed chunk store with hit/miss accounting.

    ``block_cells`` sets the chunking granularity Study uses when
    splitting a grid (the chunk *key* embeds the exact index range, so
    differently-sized chunks never alias — they just miss).
    """

    def __init__(self, root, block_cells: int = DEFAULT_BLOCK_CELLS):
        self.root = pathlib.Path(root)
        self.block_cells = int(block_cells)
        self.hits = 0
        self.misses = 0

    # -- layout -------------------------------------------------------------

    def study_dir(self, study) -> pathlib.Path:
        return self.root / study_hash(study)

    def prepare(self, study) -> pathlib.Path:
        """Create the study directory and persist the spec for --resume."""
        d = self.study_dir(study)
        (d / "chunks").mkdir(parents=True, exist_ok=True)
        spec = d / "spec.json"
        if not spec.exists():
            _atomic_write(spec, study.to_json() + "\n")
        return d

    # -- chunks -------------------------------------------------------------

    def load_chunk(self, study, key: str) -> dict | None:
        """The chunk's JSON payload, or None (counted as hit / miss)."""
        path = self.study_dir(study) / "chunks" / f"{key}.json"
        if path.exists():
            try:
                d = json.loads(path.read_text())
            except (OSError, json.JSONDecodeError):
                d = None  # unreadable -> recompute (atomic writes make this rare)
            if d is not None:
                self.hits += 1
                return d
        self.misses += 1
        return None

    def peek_chunk(self, study, key: str) -> dict | None:
        """``load_chunk`` without touching the hit/miss counters — how
        the work-queue parent collects chunks its workers just wrote
        (counting those as hits would mask real resume accounting)."""
        path = self.study_dir(study) / "chunks" / f"{key}.json"
        try:
            return json.loads(path.read_text())
        except (OSError, json.JSONDecodeError):
            return None

    def store_chunk(self, study, key: str, payload: dict) -> pathlib.Path:
        path = self.study_dir(study) / "chunks" / f"{key}.json"
        path.parent.mkdir(parents=True, exist_ok=True)
        _atomic_write(path, json.dumps(payload, allow_nan=False))
        return path

    def chunk_keys(self, study) -> list[str]:
        d = self.study_dir(study) / "chunks"
        return sorted(p.stem for p in d.glob("*.json")) if d.is_dir() else []

    # -- results ------------------------------------------------------------

    def store_result(self, study, result) -> pathlib.Path:
        path = self.study_dir(study) / "result.json"
        _atomic_write(path, result.to_json() + "\n")
        return path

    def stats(self) -> dict:
        return {
            "hits": self.hits,
            "misses": self.misses,
            "chunks": self.hits + self.misses,
            "root": str(self.root),
        }


def _atomic_write(path: pathlib.Path, text: str) -> None:
    tmp = path.with_name(path.name + ".tmp")
    tmp.write_text(text)
    os.replace(tmp, path)
