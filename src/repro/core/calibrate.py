"""Calibration harness: fit the roofline model to measured kernels.

The analytical model (``core.bandwidth``, ``analysis.roofline``) prices
every Study with *assumed* peak rates; the repo also ships real
``dos_matmul`` / ``flash_attention`` / ``ssm_scan`` kernels that are
never measured against it. This module closes that loop, the
measured-vs-modeled methodology of the fine-grain 3D-stack
characterization literature (arxiv 2409.10539):

1. **Sweep** the three kernel families over a shape grid
   (``shape_grid``): GEMM M/K/N including skewed tall/wide shapes,
   attention B/S/H/D in prefill (causal, GQA) and decode (KV-cache)
   modes, and SSM B/S/H/P/N chunked scans.
2. **Measure** each shape (``measure_row``): inputs are seeded, the
   jitted wrapper is built once per family (``_kernel_fn`` — a cached
   factory, so repeated calls never re-dispatch through Python), the
   call is AOT-compiled (``jit(f).lower(*args).compile()``) and timed
   dispatch-free, median-of-reps after explicit warmup — the MaxText
   microbenchmark recipe. Each row reports achieved FLOP/s and GB/s.
3. **Fit** (``fit_rows``): alternating least squares against
   ``analysis.roofline.roofline_terms_batched`` — every row is
   assigned to its binding term (compute vs memory) under the current
   parameters, then each parameter is re-fit in closed form from its
   assigned rows (relative-error-weighted LSQ), iterated to a fixed
   point. Fitted parameters: one effective compute rate per family
   (reported as an efficiency factor vs the nominal peak — the GEMM
   family's factor calibrates the GEMM dataflows dos/ws/is directly),
   one DRAM bandwidth (a ``BandwidthSpec.dram_gbs``), and a
   per-family launch overhead riding the combiner's additive
   ``collective_s`` slot (without it, every small shape reads as an
   impossibly slow rate).
4. **Report** model-vs-measured relative error per shape bucket, on
   the fit rows and on held-out rows (every ``holdout_every``-th shape
   never enters the fit), next to the error of the *uncalibrated*
   nominal constants — the gap is the point of calibrating.

The result is a ``CalibratedBandwidth`` artifact: a fitted
``BandwidthSpec`` plus per-family efficiency factors and fit
diagnostics. It is JSON-round-trippable and loadable back into any
Study via ``AnalysisSpec(bandwidth=...)`` (the spec layer unwraps it
to its embedded ``BandwidthSpec``, so a re-loaded artifact reproduces
bit-identical results).

Wall-clock numbers here are *backend* numbers (CPU in this container,
TPU on real hardware) — the harness calibrates whatever backend it
runs on, which is exactly what makes the model defensible there.
"""

from __future__ import annotations

import dataclasses
import functools
import math
import time

import numpy as np

from .bandwidth import BandwidthSpec
from .params import validate_option
from .ppa import constants as HW

__all__ = [
    "CALIBRATE_FAMILIES",
    "CALIBRATE_PRESETS",
    "CalibrateSpec",
    "CalibratedBandwidth",
    "fit_rows",
    "measure_row",
    "run_calibration",
    "shape_grid",
]

CALIBRATE_FAMILIES = ("gemm", "attention", "ssm")
CALIBRATE_PRESETS = ("smoke", "default", "full")

#: SSM chunk the CPU path auto-picks (see ``kernels.ssm_scan.ops``);
#: the analytic FLOP count of a chunked scan depends on it.
_SSM_CHUNK = 32

_F32 = 4  # bytes per f32 word (attention/SSM operand dtype)
_BF16 = 2  # bytes per bf16 word (GEMM operand dtype)


# ---------------------------------------------------------------------------
# Spec
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CalibrateSpec:
    """What to calibrate and how carefully.

    - ``families``: kernel families to sweep (subset of
      ``CALIBRATE_FAMILIES``).
    - ``preset``: shape-grid size — ``'smoke'`` (a few small shapes,
      CI-sized), ``'default'`` (the calibration grid), ``'full'``
      (adds large shapes; minutes on CPU).
    - ``reps`` / ``warmup``: timed repetitions (median is reported)
      after untimed warmup calls.
    - ``holdout_every``: every N-th shape is excluded from the fit and
      used only to score generalization (0 disables holdout).
    - ``seed``: input-data seed (timings are data-independent for
      these kernels; the seed keeps rows reproducible anyway).
    """

    families: tuple[str, ...] = CALIBRATE_FAMILIES
    preset: str = "default"
    reps: int = 5
    warmup: int = 2
    holdout_every: int = 4
    seed: int = 0

    def __post_init__(self):
        fams = self.families
        if isinstance(fams, str):
            fams = (fams,)
        fams = tuple(str(f) for f in fams)
        for f in fams:
            validate_option("calibrate family", f, CALIBRATE_FAMILIES)
        if not fams:
            raise ValueError("families must name at least one kernel family")
        object.__setattr__(self, "families", fams)
        validate_option("calibrate preset", self.preset, CALIBRATE_PRESETS)
        for name, lo in (("reps", 1), ("warmup", 0), ("holdout_every", 0),
                         ("seed", 0)):
            v = int(getattr(self, name))
            if v < lo:
                raise ValueError(f"{name} must be >= {lo}, got {v}")
            object.__setattr__(self, name, v)
        if self.holdout_every == 1:
            raise ValueError(
                "holdout_every=1 would hold out every shape; use 0 to "
                "disable holdout or >= 2 to keep fit rows"
            )

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "CalibrateSpec":
        return cls(**d)


# ---------------------------------------------------------------------------
# Shape grids
# ---------------------------------------------------------------------------

def _gemm_shapes(preset: str):
    smoke = [(256, 256, 256), (128, 1024, 256)]
    default = smoke + [
        (512, 512, 512),
        (512, 2048, 512),
        (1024, 1024, 256),
        (2048, 512, 128),   # tall
        (128, 512, 2048),   # wide
        # thin: low arithmetic intensity (memory-assigned). True
        # matvecs (m=1) are deliberately absent: a bf16 GEMV on CPU
        # times dtype conversion, not bandwidth, and poisons the fit.
        (16, 2048, 2048),
        (16, 4096, 1024),
    ]
    full = default + [(1024, 1024, 1024), (4096, 1024, 128), (16, 8192, 2048)]
    return {"smoke": smoke, "default": default, "full": full}[preset]


def _attention_shapes(preset: str):
    # (mode, b, s, h, kvh, d): prefill = causal flash over s; decode =
    # one token against an s-slot KV cache.
    smoke = [("prefill", 1, 256, 8, 2, 64), ("decode", 4, 1024, 8, 2, 64)]
    default = smoke + [
        ("prefill", 1, 512, 8, 8, 64),    # MHA (h == kvh)
        ("prefill", 1, 1024, 8, 2, 64),   # GQA g=4
        ("prefill", 2, 512, 16, 4, 64),
        ("prefill", 1, 1024, 16, 1, 64),  # MQA (h >> kvh)
        ("decode", 8, 4096, 16, 4, 64),
        ("decode", 16, 1024, 16, 2, 64),
        ("decode", 4, 8192, 8, 8, 64),    # big cache: memory-bound
    ]
    full = default + [
        ("prefill", 1, 2048, 8, 2, 64),
        ("decode", 32, 4096, 32, 8, 128),
    ]
    return {"smoke": smoke, "default": default, "full": full}[preset]


def _ssm_shapes(preset: str):
    # (b, s, h, p, n)
    smoke = [(1, 256, 8, 64, 64)]
    default = smoke + [
        (2, 1024, 8, 64, 64),
        (1, 512, 8, 64, 64),
        (4, 512, 4, 32, 64),
        (2, 2048, 4, 64, 32),
    ]
    full = default + [(4, 2048, 8, 64, 64), (1, 4096, 16, 64, 64)]
    return {"smoke": smoke, "default": default, "full": full}[preset]


def _gemm_row(m, k, n):
    return {
        "family": "gemm",
        "label": f"gemm_{m}x{k}x{n}",
        "params": {"m": m, "k": k, "n": n},
        "flops": 2.0 * m * k * n,
        "bytes": float(_BF16 * (m * k + k * n + m * n)),
    }


def _attention_row(mode, b, s, h, kvh, d):
    if mode == "prefill":
        flops = 4.0 * b * h * s * s * d * 0.5  # causal: half the mask
        byts = float(_F32 * (2 * b * s * h * d + 2 * b * s * kvh * d))
    else:  # decode: 1 query token vs an s-slot cache
        flops = 4.0 * b * h * s * d
        byts = float(_F32 * (2 * b * s * kvh * d + 2 * b * h * d))
    return {
        "family": "attention",
        "label": f"attn_{mode}_b{b}_s{s}_h{h}x{kvh}_d{d}",
        "params": {"mode": mode, "b": b, "s": s, "h": h, "kvh": kvh, "d": d},
        "flops": flops,
        "bytes": byts,
    }


def _ssm_row(b, s, h, p, n):
    t = min(_SSM_CHUNK, s)
    flops = 4.0 * b * s * h * n * p + 2.0 * b * s * t * h * (n + p)
    byts = float(_F32 * (2 * b * s * h * p + 2 * b * s * h * n + b * s * h))
    return {
        "family": "ssm",
        "label": f"ssm_b{b}_s{s}_h{h}_p{p}_n{n}",
        "params": {"b": b, "s": s, "h": h, "p": p, "n": n},
        "flops": flops,
        "bytes": byts,
    }


def shape_grid(spec: CalibrateSpec) -> list[dict]:
    """The calibration rows for a spec: one dict per (family, shape)
    with the analytic FLOP / byte counts and the holdout flag (every
    ``holdout_every``-th row *within each family* is held out, so all
    families contribute to both fit and holdout sets)."""
    rows: list[dict] = []
    for family in spec.families:
        if family == "gemm":
            fam_rows = [_gemm_row(*s) for s in _gemm_shapes(spec.preset)]
        elif family == "attention":
            fam_rows = [_attention_row(*s) for s in _attention_shapes(spec.preset)]
        else:
            fam_rows = [_ssm_row(*s) for s in _ssm_shapes(spec.preset)]
        for i, row in enumerate(fam_rows):
            row["holdout"] = bool(
                spec.holdout_every and (i % spec.holdout_every
                                        == spec.holdout_every - 1)
            )
            rows.append(row)
    return rows


# ---------------------------------------------------------------------------
# Measurement
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _kernel_fn(family: str, mode: str = ""):
    """Cached jitted wrapper per (family, mode) — built once, reused by
    every shape, so repeated measurement calls never re-trace or
    re-dispatch through the Python op layer."""
    import jax
    import jax.numpy as jnp

    from ..kernels.dos_matmul import dos_matmul
    from ..kernels.flash_attention import decode_attention
    from ..kernels.flash_attention.ops import flash_attention_jnp
    from ..kernels.ssm_scan import ssm_scan

    if family == "gemm":
        return jax.jit(lambda a, b: dos_matmul(a, b))
    if family == "attention" and mode == "prefill":
        return jax.jit(
            lambda q, k, v: flash_attention_jnp(q, k, v, causal=True)
        )
    if family == "attention":
        return jax.jit(
            lambda q, kc, vc, length: decode_attention(q, kc, vc, length=length)
        )
    if family == "ssm":
        return jax.jit(lambda u, ld, B, C: ssm_scan(u, ld, B, C)[0])
    raise ValueError(f"unknown kernel family {family!r}")


def _build_inputs(row: dict, seed: int):
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    p = row["params"]
    family = row["family"]
    if family == "gemm":
        a = jnp.asarray(rng.normal(size=(p["m"], p["k"])), jnp.bfloat16)
        b = jnp.asarray(rng.normal(size=(p["k"], p["n"])), jnp.bfloat16)
        return (a, b)
    if family == "attention" and p["mode"] == "prefill":
        q = jnp.asarray(rng.normal(size=(p["b"], p["s"], p["h"], p["d"])), jnp.float32)
        k = jnp.asarray(rng.normal(size=(p["b"], p["s"], p["kvh"], p["d"])), jnp.float32)
        v = jnp.asarray(rng.normal(size=(p["b"], p["s"], p["kvh"], p["d"])), jnp.float32)
        return (q, k, v)
    if family == "attention":
        q = jnp.asarray(rng.normal(size=(p["b"], 1, p["h"], p["d"])), jnp.float32)
        kc = jnp.asarray(rng.normal(size=(p["b"], p["s"], p["kvh"], p["d"])), jnp.float32)
        vc = jnp.asarray(rng.normal(size=(p["b"], p["s"], p["kvh"], p["d"])), jnp.float32)
        return (q, kc, vc, jnp.int32(p["s"]))
    u = jnp.asarray(rng.normal(size=(p["b"], p["s"], p["h"], p["p"])), jnp.float32)
    ld = jnp.asarray(-rng.uniform(0.01, 0.2, size=(p["b"], p["s"], p["h"])), jnp.float32)
    B = jnp.asarray(rng.normal(size=(p["b"], p["s"], p["h"], p["n"])), jnp.float32)
    C = jnp.asarray(rng.normal(size=(p["b"], p["s"], p["h"], p["n"])), jnp.float32)
    return (u, ld, B, C)


def measure_row(row: dict, *, reps: int = 5, warmup: int = 2,
                seed: int = 0) -> dict:
    """Measure one calibration row: AOT-compile the cached jitted
    wrapper for the row's shapes, run ``warmup`` untimed calls, then
    ``reps`` individually-timed calls. Returns a JSON-safe record with
    the median time and achieved FLOP/s / GB/s."""
    import jax

    args = _build_inputs(row, seed)
    fn = _kernel_fn(row["family"], row["params"].get("mode", ""))
    compiled = fn.lower(*args).compile()  # dispatch-free timed call
    for _ in range(max(warmup, 1)):
        jax.block_until_ready(compiled(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(compiled(*args))
        ts.append(time.perf_counter() - t0)
    t_s = float(np.median(ts))
    out = dict(row)
    out.update(
        t_s=t_s,
        spread_s=float(max(ts) - min(ts)),
        reps=int(reps),
        achieved_gflops=row["flops"] / t_s / 1e9,
        achieved_gbs=row["bytes"] / t_s / 1e9,
    )
    return out


# ---------------------------------------------------------------------------
# Fit
# ---------------------------------------------------------------------------

def _lsq_rate(work: np.ndarray, t: np.ndarray) -> float:
    """Closed-form relative-error-weighted LSQ for t ~ work / rate:
    minimize sum(((t_i - work_i/rate) / t_i)^2) over 1/rate."""
    x = float(np.sum(work / t) / np.sum((work / t) ** 2) )
    return 1.0 / x if x > 0 else math.inf


def _predict(rows_f, rows_b, rates: dict, bw: float, overhead: dict,
             families) -> np.ndarray:
    """Predicted step time per row via ``roofline_terms_batched``."""
    from ..analysis.roofline import roofline_terms_batched

    rate = np.asarray([rates[f] for f in families], dtype=np.float64)
    over = np.asarray([overhead.get(f, 0.0) for f in families],
                      dtype=np.float64)
    terms = roofline_terms_batched(rows_f / rate, rows_b / bw, over)
    return np.asarray(terms["step_s"], dtype=np.float64)


def fit_rows(measured: list[dict], spec: CalibrateSpec,
             iters: int = 40) -> dict:
    """Alternating least-squares roofline fit over measured rows.

    Three fitted parameter groups, all slotting into the combiner's
    existing terms: per-family effective compute rates, one shared
    DRAM bandwidth, and a per-family *launch overhead* riding the
    additive ``collective_s`` slot (per-call dispatch cost — without
    it every small shape reads as an impossibly slow rate, the classic
    roofline-fitting trap). Each iteration assigns every fit row to
    its binding term (compute vs memory) under the current parameters
    — via ``roofline_terms_batched``, the same combiner every report
    uses — then re-fits each group in closed form from its assigned
    rows (relative-error-weighted LSQ on the overhead-stripped
    residual). Returns the payload dict (fit + per-bucket errors + the
    ``CalibratedBandwidth`` artifact).
    """
    from ..analysis.roofline import roofline_terms_batched

    fams = tuple(sorted({r["family"] for r in measured}))
    F = np.asarray([r["flops"] for r in measured], dtype=np.float64)
    B = np.asarray([r["bytes"] for r in measured], dtype=np.float64)
    t = np.asarray([r["t_s"] for r in measured], dtype=np.float64)
    fam = np.asarray([r["family"] for r in measured])
    hold = np.asarray([bool(r.get("holdout")) for r in measured])
    fit = ~hold

    # init: the achieved-rate ceilings (no row can beat its own rate)
    rates = {
        f: float(np.max((F / t)[fit & (fam == f)], initial=1e9)) for f in fams
    }
    bw = float(np.max((B / t)[fit], initial=1e9))
    over = {f: 0.0 for f in fams}
    for _ in range(iters):
        over_vec = np.asarray([over[x] for x in fam], dtype=np.float64)
        tr = np.maximum(t - over_vec, 1e-9)  # overhead-stripped residual
        rate_vec = np.asarray([rates[x] for x in fam], dtype=np.float64)
        dom = roofline_terms_batched(F / rate_vec, B / bw, 0.0)["dominant"]
        new_rates = dict(rates)
        for f in fams:
            m = fit & (fam == f) & (dom == "compute")
            if m.any():
                new_rates[f] = _lsq_rate(F[m], tr[m])
        mmem = fit & (dom == "memory")
        new_bw = _lsq_rate(B[mmem], tr[mmem]) if mmem.any() else bw
        # overhead: weighted LSQ of the leftover t - max(F/r, B/bw),
        # clipped at 0 (an overhead cannot be negative)
        rate_vec = np.asarray([new_rates[x] for x in fam], dtype=np.float64)
        step = np.maximum(F / rate_vec, B / new_bw)
        new_over = {}
        for f in fams:
            m = fit & (fam == f)
            if m.any():
                w2 = 1.0 / t[m] ** 2
                new_over[f] = max(
                    0.0, float(np.sum((t[m] - step[m]) * w2) / np.sum(w2))
                )
            else:
                new_over[f] = over[f]
        if new_rates == rates and new_bw == bw and new_over == over:
            break
        rates, bw, over = new_rates, new_bw, new_over

    pred = _predict(F, B, rates, bw, over, fam)
    rel = np.abs(pred - t) / t
    # the uncalibrated model: nominal peak FLOP/s and HBM bandwidth
    nominal = {f: float(HW.TPU_PEAK_FLOPS_BF16) for f in fams}
    pred0 = _predict(F, B, nominal, float(HW.TPU_HBM_BW), {}, fam)
    rel0 = np.abs(pred0 - t) / t

    def _med(mask) -> float:
        return float(np.median(rel[mask])) if mask.any() else math.nan

    errors = {
        "fit_median_rel_err": _med(fit),
        "holdout_median_rel_err": _med(hold) if hold.any() else _med(fit),
        "uncalibrated_holdout_median_rel_err": float(
            np.median(rel0[hold if hold.any() else fit])
        ),
        "per_family_median_rel_err": {f: _med(fam == f) for f in fams},
    }
    efficiency = {f: rates[f] / float(HW.TPU_PEAK_FLOPS_BF16) for f in fams}
    artifact = CalibratedBandwidth(
        bandwidth=BandwidthSpec(dram_gbs=bw / 1e9),
        efficiency=efficiency,
        peak_flops=float(HW.TPU_PEAK_FLOPS_BF16),
        diagnostics=dict(
            errors, n_rows=len(measured), n_holdout=int(hold.sum()),
            families=list(fams), preset=spec.preset,
            overhead_s={f: over[f] for f in fams},
        ),
    )
    for r, p_, e_, e0 in zip(measured, pred, rel, rel0):
        r["pred_s"] = float(p_)
        r["rel_err"] = float(e_)
        r["rel_err_uncalibrated"] = float(e0)
    return {
        "rows": measured,
        "rates_flops": {f: rates[f] for f in fams},
        "dram_gbs_fitted": bw / 1e9,
        "efficiency": efficiency,
        "overhead_s": {f: over[f] for f in fams},
        "errors": errors,
        "artifact": artifact,
    }


# ---------------------------------------------------------------------------
# Artifact
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CalibratedBandwidth:
    """A fitted memory-system + efficiency artifact.

    - ``bandwidth``: the fitted ``BandwidthSpec`` (measured DRAM
      bandwidth; SRAM/vlink stay unbounded — they are not observable
      from single-chip wall time). This is what
      ``AnalysisSpec(bandwidth=...)`` consumes: passing the artifact
      (or its dict form) to any Study unwraps to this spec, so a
      JSON-round-tripped artifact reproduces bit-identical results.
    - ``efficiency``: per-family effective compute rate as a fraction
      of ``peak_flops``. The ``'gemm'`` entry calibrates the GEMM
      dataflows (dos/ws/is map the same MACs; ``dos_matmul`` is the
      dOS kernel) — ``efficiency_for`` exposes that mapping.
    - ``diagnostics``: fit/holdout error summary and provenance.
    """

    bandwidth: BandwidthSpec
    efficiency: dict
    peak_flops: float
    diagnostics: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if isinstance(self.bandwidth, dict):
            object.__setattr__(
                self, "bandwidth", BandwidthSpec.from_dict(self.bandwidth)
            )
        object.__setattr__(
            self, "efficiency",
            {str(k): float(v) for k, v in dict(self.efficiency).items()},
        )
        object.__setattr__(self, "peak_flops", float(self.peak_flops))

    def efficiency_for(self, dataflow: str) -> float:
        """Effective-compute factor for a GEMM dataflow (dos/os/ws/is
        all map MACs onto the same array; the measured GEMM efficiency
        calibrates them jointly). Falls back to 1.0 (nominal)."""
        if dataflow in self.efficiency:
            return self.efficiency[dataflow]
        return self.efficiency.get("gemm", 1.0)

    def to_dict(self) -> dict:
        return {
            "calibrated": True,
            "bandwidth": self.bandwidth.to_dict(),
            "efficiency": dict(self.efficiency),
            "peak_flops": self.peak_flops,
            "diagnostics": self.diagnostics,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "CalibratedBandwidth":
        return cls(
            bandwidth=BandwidthSpec.from_dict(d["bandwidth"]),
            efficiency=d.get("efficiency", {}),
            peak_flops=d.get("peak_flops", HW.TPU_PEAK_FLOPS_BF16),
            diagnostics=d.get("diagnostics", {}),
        )


# ---------------------------------------------------------------------------
# Front door
# ---------------------------------------------------------------------------

def run_calibration(spec: CalibrateSpec | None = None, *,
                    measured: list[dict] | None = None) -> dict:
    """Sweep + measure + fit in one call (the benchmark / direct-use
    path; ``Study`` kind='calibrate' drives the same pieces with
    per-shape chunk caching). ``measured`` (pre-recorded rows) skips
    measurement — the fit is then deterministic."""
    spec = spec or CalibrateSpec()
    if measured is None:
        measured = [
            measure_row(row, reps=spec.reps, warmup=spec.warmup,
                        seed=spec.seed)
            for row in shape_grid(spec)
        ]
    return fit_rows(measured, spec)
