"""Dataflow descriptors for 2D/3D systolic arrays (paper Sec. III-C).

The paper discusses four dataflows for mapping a GEMM ``A(MxK) @ B(KxN)``
onto a systolic array:

- OS  (output stationary):  M,N spatial; K temporal. Outputs accumulate
  in-place; A streams from the left, B from the top.
- WS  (weight stationary):  N,K spatial; M temporal. B pre-loaded.
- IS  (input stationary):   M,K spatial; N temporal. A pre-loaded.
- dOS (distributed output stationary, the paper's contribution): M,N
  spatial in-tier, **K spatial across tiers** (K/l per tier) plus an
  (l-1)-cycle cross-tier accumulation. WS/IS extended to 3D need no
  inter-tier traffic (they degenerate to model parallelism), which is
  why the paper focuses on dOS.

Besides the mapping descriptors, this module derives the *switching
activities* of MACs, horizontal links and vertical (TSV/MIV) links that
the dynamic power model (core.ppa.power) consumes — the paper found a
static analysis insufficient precisely because these activities differ
between the horizontal and vertical links (Sec. IV-B).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .analytical import _ceil_div, fold_dims, native_fold, tau_is, tau_ws

__all__ = [
    "Dataflow",
    "OS",
    "WS",
    "IS",
    "DOS",
    "DATAFLOWS",
    "Activity",
    "dos_activity",
    "activity_batched",
]


@dataclasses.dataclass(frozen=True)
class Dataflow:
    name: str
    spatial: tuple  # GEMM dims mapped onto array axes (in-tier)
    temporal: tuple  # GEMM dims mapped onto time
    tier_dim: str | None  # GEMM dim mapped across tiers (3D only)
    stationary: str  # which operand (or 'output') stays in place
    cross_tier_traffic: bool  # does the 3D variant need vertical links?

    def describe(self) -> str:
        t = f", {self.tier_dim} across tiers" if self.tier_dim else ""
        return (
            f"{self.name}: {'/'.join(self.spatial)} spatial, "
            f"{'/'.join(self.temporal)} temporal{t}; {self.stationary} stationary"
        )


OS = Dataflow("OS", ("M", "N"), ("K",), None, "output", False)
WS = Dataflow("WS", ("N", "K"), ("M",), None, "B", False)
IS = Dataflow("IS", ("M", "K"), ("N",), None, "A", False)
#: The paper's contribution: K split across tiers with cross-tier reduction.
DOS = Dataflow("dOS", ("M", "N"), ("K/l",), "K", "output", True)

#: Engine-facing registry: lower-case key -> descriptor.
DATAFLOWS = {"os": OS, "ws": WS, "is": IS, "dos": DOS}


@dataclasses.dataclass(frozen=True)
class Activity:
    """Average switching activities over a workload's runtime.

    All activities are per-unit-per-cycle event rates in [0, 1]:
    ``mac`` — fraction of MACs doing useful work in an average cycle;
    ``hlink`` — word-transfers per horizontal link per cycle;
    ``vlink`` — word-transfers per vertical (TSV/MIV) link per cycle;
    ``cycles`` — total runtime (denominator).
    """

    mac: float
    hlink: float
    vlink: float
    cycles: float
    hlink_hops_total: float
    vlink_hops_total: float
    mac_ops_total: float


def activity_batched(M, K, N, R, C, tiers, dataflow: str = "dos",
                     fold: str | None = None) -> Activity:
    """Batched activity factors for one dataflow over arrays of designs.

    All arguments broadcast; the returned ``Activity`` carries float64
    arrays of the broadcast shape (the scalar ``dos_activity`` is the
    batch-of-one special case). Derivation for dOS (per fold of full
    tiles, averaged over all folds):

    - MAC-ops: every output element needs K multiply-accumulates, spread
      over ``l`` tiers; per fold the tile does R*C*ceil(K/l) ops *per
      tier*.
    - Horizontal hops: an element of A traverses up to C PEs rightward,
      an element of B traverses up to R PEs downward (in-plane). Every
      useful MAC-op implies one A-hop and one B-hop arriving at that PE,
      so in-plane word-hops ~= 2 * mac_ops over ~2*R*C*l links.
    - Vertical hops: only the partial-sum accumulation uses the TSV/MIV
      pile: each of the R*C piles moves one word across each of its
      (l-1) interfaces per fold -> R*C*(l-1) word-hops over R*C*(l-1)
      vertical links => per-link activity 1/tau_fold. This is the
      asymmetry that makes the paper's dynamic power analysis matter.

    WS and IS keep the same operand-delivery hop model (2 hops per
    useful MAC) but have **zero** vertical activity: extended to 3D they
    split their temporal dimension across tiers with no cross-tier
    traffic (Sec. III-C), which is why the paper focuses on dOS.

    ``fold`` selects a non-native tier fold (``analytical.fold_dims``):
    cycles come from the fold's (D1, D2, T) triple; vertical hops are a
    dOS-style R*C*(L-1) plane per fold when the contraction dim is
    split, or the shared operand's compulsory multicast — (L-1) copies
    of its K*N (fold-m) / M*K (fold-n) words — when an output dim is.
    The fold's in-plane delivery keeps the generic 2-hops-per-MAC
    model. ``fold=None`` or the dataflow's native fold is the existing
    model, bit-for-bit.
    """
    M, K, N, R, C, L = np.broadcast_arrays(
        *(np.asarray(x, dtype=np.int64) for x in (M, K, N, R, C, tiers))
    )
    if fold is not None and fold != native_fold(dataflow):
        D1, D2, Tser = fold_dims(fold, dataflow, M, K, N, L)
        folds = _ceil_div(D1, R) * _ceil_div(D2, C)
        cycles = ((2 * R + C + Tser - 2) * folds).astype(np.float64)
        if fold == "k":  # ws/is contraction split: partial-sum planes
            v_hops = np.where(L > 1, R * C * (L - 1) * folds, 0).astype(np.float64)
        else:  # output-dim split: multicast the shared operand once
            shared_words = K * N if fold == "m" else M * K
            v_hops = np.where(L > 1, (L - 1) * shared_words, 0).astype(np.float64)
        with np.errstate(invalid="ignore", divide="ignore"):
            v_act = np.where(
                L > 1, v_hops / (cycles * R * C * np.maximum(L - 1, 1)), 0.0
            )
    elif dataflow in ("os", "dos"):
        kl = _ceil_div(K, L)
        folds = _ceil_div(M, R) * _ceil_div(N, C)
        tau_fold = 2 * R + C + kl + L - 3  # == 2R + C + K - 2 at l = 1
        cycles = (tau_fold * folds).astype(np.float64)
        v_hops = np.where(L > 1, R * C * (L - 1) * folds, 0).astype(np.float64)
        with np.errstate(invalid="ignore", divide="ignore"):
            v_act = np.where(
                L > 1, v_hops / (cycles * R * C * np.maximum(L - 1, 1)), 0.0
            )
    elif dataflow == "ws":
        cycles = tau_ws(M, K, N, R, C, L).astype(np.float64)
        v_hops = np.zeros_like(cycles)
        v_act = np.zeros_like(cycles)
    elif dataflow == "is":
        cycles = tau_is(M, K, N, R, C, L).astype(np.float64)
        v_hops = np.zeros_like(cycles)
        v_act = np.zeros_like(cycles)
    else:
        raise ValueError(f"unknown dataflow {dataflow!r}")

    # Useful ops honour ragged edges (average active tile = M*N/folds).
    mac_ops = (M * N * K).astype(np.float64)  # total useful MACs across tiers
    mac_act = mac_ops / (cycles * R * C * L)
    h_hops = 2.0 * mac_ops
    n_hlinks = 2.0 * R * C * L
    h_act = h_hops / (cycles * n_hlinks)

    return Activity(
        mac=mac_act,
        hlink=h_act,
        vlink=v_act,
        cycles=cycles,
        hlink_hops_total=h_hops,
        vlink_hops_total=v_hops,
        mac_ops_total=mac_ops,
    )


def dos_activity(M, K, N, R, C, tiers) -> Activity:
    """Scalar dOS activity factors (batch-of-one of ``activity_batched``).

    For tiers == 1 this is plain OS on a 2D array.
    """
    a = activity_batched(
        np.array([M]), np.array([K]), np.array([N]),
        np.array([R]), np.array([C]), np.array([tiers]), "dos",
    )
    return Activity(*(float(np.asarray(f)[0]) for f in dataclasses.astuple(a)))
