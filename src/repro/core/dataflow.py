"""Dataflow descriptors for 2D/3D systolic arrays (paper Sec. III-C).

The paper discusses four dataflows for mapping a GEMM ``A(MxK) @ B(KxN)``
onto a systolic array:

- OS  (output stationary):  M,N spatial; K temporal. Outputs accumulate
  in-place; A streams from the left, B from the top.
- WS  (weight stationary):  N,K spatial; M temporal. B pre-loaded.
- IS  (input stationary):   M,K spatial; N temporal. A pre-loaded.
- dOS (distributed output stationary, the paper's contribution): M,N
  spatial in-tier, **K spatial across tiers** (K/l per tier) plus an
  (l-1)-cycle cross-tier accumulation. WS/IS extended to 3D need no
  inter-tier traffic (they degenerate to model parallelism), which is
  why the paper focuses on dOS.

Besides the mapping descriptors, this module derives the *switching
activities* of MACs, horizontal links and vertical (TSV/MIV) links that
the dynamic power model (core.ppa.power) consumes — the paper found a
static analysis insufficient precisely because these activities differ
between the horizontal and vertical links (Sec. IV-B).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .analytical import _ceil_div

__all__ = ["Dataflow", "OS", "WS", "IS", "DOS", "Activity", "dos_activity"]


@dataclasses.dataclass(frozen=True)
class Dataflow:
    name: str
    spatial: tuple  # GEMM dims mapped onto array axes (in-tier)
    temporal: tuple  # GEMM dims mapped onto time
    tier_dim: str | None  # GEMM dim mapped across tiers (3D only)
    stationary: str  # which operand (or 'output') stays in place
    cross_tier_traffic: bool  # does the 3D variant need vertical links?

    def describe(self) -> str:
        t = f", {self.tier_dim} across tiers" if self.tier_dim else ""
        return (
            f"{self.name}: {'/'.join(self.spatial)} spatial, "
            f"{'/'.join(self.temporal)} temporal{t}; {self.stationary} stationary"
        )


OS = Dataflow("OS", ("M", "N"), ("K",), None, "output", False)
WS = Dataflow("WS", ("N", "K"), ("M",), None, "B", False)
IS = Dataflow("IS", ("M", "K"), ("N",), None, "A", False)
#: The paper's contribution: K split across tiers with cross-tier reduction.
DOS = Dataflow("dOS", ("M", "N"), ("K/l",), "K", "output", True)


@dataclasses.dataclass(frozen=True)
class Activity:
    """Average switching activities over a workload's runtime.

    All activities are per-unit-per-cycle event rates in [0, 1]:
    ``mac`` — fraction of MACs doing useful work in an average cycle;
    ``hlink`` — word-transfers per horizontal link per cycle;
    ``vlink`` — word-transfers per vertical (TSV/MIV) link per cycle;
    ``cycles`` — total runtime (denominator).
    """

    mac: float
    hlink: float
    vlink: float
    cycles: float
    hlink_hops_total: float
    vlink_hops_total: float
    mac_ops_total: float


def dos_activity(M, K, N, R, C, tiers) -> Activity:
    """Activity factors for dOS on an l-tier (R x C)-per-tier array.

    For tiers == 1 this is plain OS on a 2D array. Derivation (per fold
    of full tiles, averaged over all folds):

    - MAC-ops: every output element needs K multiply-accumulates, spread
      over ``l`` tiers; per fold the tile does R*C*ceil(K/l) ops *per
      tier*.
    - Horizontal hops: an element of A traverses up to C PEs rightward,
      an element of B traverses up to R PEs downward (in-plane). Per
      fold per tier: R*Kl elements x C hops + Kl*C elements x R hops
      = 2*R*C*Kl word-hops over ~2*R*C in-plane links.
    - Vertical hops: only the partial-sum accumulation uses the TSV/MIV
      pile: each of the R*C piles moves one word across each of its
      (l-1) interfaces per fold -> R*C*(l-1) word-hops over R*C*(l-1)
      vertical links => per-link activity 1/tau_fold. This is the
      asymmetry that makes the paper's dynamic power analysis matter.
    """
    M, K, N, R, C, L = (int(x) for x in (M, K, N, R, C, tiers))
    kl = -(-K // L)
    folds = int(_ceil_div(M, R)) * int(_ceil_div(N, C))
    tau_fold = 2 * R + C + kl + L - 3 if L > 1 else 2 * R + C + K - 2
    cycles = float(tau_fold * folds)

    # Useful ops honour ragged edges (average active tile = M*N/folds).
    mac_ops = float(M * N * K)  # total useful MACs across tiers
    mac_act = mac_ops / (cycles * R * C * L)

    # Every useful MAC-op implies one A-hop and one B-hop arriving at
    # that PE, so in-plane word-hops ~= 2 * mac_ops.
    h_hops = 2.0 * mac_ops
    n_hlinks = 2.0 * R * C * L
    h_act = h_hops / (cycles * n_hlinks)

    if L > 1:
        v_hops = float(R * C * (L - 1) * folds)
        n_vlinks = float(R * C * (L - 1))
        v_act = v_hops / (cycles * n_vlinks)
    else:
        v_hops, v_act = 0.0, 0.0

    return Activity(
        mac=mac_act,
        hlink=h_act,
        vlink=v_act,
        cycles=cycles,
        hlink_hops_total=h_hops,
        vlink_hops_total=v_hops,
        mac_ops_total=mac_ops,
    )
