"""Design-space exploration sweeps (paper Figs. 5, 6, 7 and Sec. IV-A).

These are the paper's workload/architecture studies. Each sweep is a
thin wrapper over the batched evaluation engine (``core.engine``): it
builds one ``DesignGrid`` spanning every (workload, MAC budget, tier)
combination, makes a **single** ``evaluate()`` call, and reshapes the
stacked result into the figure's layout — no per-point Python loops.
Regression tests pin the outputs bit-for-bit to the original per-point
loop implementations.

- Fig. 5: 3D-vs-2D speedup over tier count, for several MAC budgets and
  several K (M = 64, N = 147 fixed — ResNet50's RN0 M/N).
- Fig. 6: speedup over MAC budget at 4 tiers (M = 64), for several N and
  K; the threshold N_min = M*N below which 3D cannot win.
- Fig. 7: scatter of the *optimal* tier count for 300 random workloads
  drawn around ResNet50-like layer dimensions, for three MAC budgets;
  the optimal-tier distribution shifts right as the budget grows.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .analytical import mac_threshold
from .engine import DesignGrid, evaluate, optimal_tiers_batched

__all__ = [
    "fig5_sweep",
    "fig6_sweep",
    "fig7_scatter",
    "random_workloads",
    "PAPER_WORKLOADS",
]

# Table I: exemplary layers from current DNN workloads mapped to M, K, N.
PAPER_WORKLOADS = {
    "RN0": (64, 12100, 147),  # ResNet50
    "RN1": (512, 784, 128),
    "GNMT0": (128, 4096, 2048),  # Google NMT
    "GNMT1": (320, 4096, 3072),
    "DB0": (1024, 50000, 16),  # DeepBench
    "DB1": (35, 2560, 4096),
    "TF0": (31999, 84, 1024),  # Transformer
    "TF1": (84, 4096, 1024),
}


def fig5_sweep(
    mac_budgets=(2**12, 2**14, 2**16, 2**18),
    ks=(255, 2560, 12100),
    tiers=tuple(range(1, 17)),
    M=64,
    N=147,
    mode="opt",
    backend="numpy",
):
    """Speedup vs tier count for each (MAC budget, K). Returns
    {(n_macs, K): [speedup per tier count]} — one engine call."""
    workloads = [(M, k, N) for k in ks]
    grid = DesignGrid.product(workloads, mac_budgets, tiers, mode=mode)
    res = evaluate(grid, backend=backend, metrics=("perf",))
    s = res.speedup.reshape(len(ks), len(mac_budgets), len(tiers))
    out = {}
    for bi, n in enumerate(mac_budgets):
        for ki, k in enumerate(ks):
            out[(n, k)] = [float(v) for v in s[ki, bi]]
    return tiers, out


def fig6_sweep(
    mac_budgets=tuple(2**p for p in range(10, 19)),
    ns=(147, 1024),
    ks=(784, 4096),
    M=64,
    tiers=4,
    mode="opt",
    backend="numpy",
):
    """Speedup vs MAC budget at fixed tier count. Returns
    {(N, K): [speedup per budget]} plus the N_min threshold per N —
    one engine call."""
    workloads = [(M, k, n_dim) for n_dim in ns for k in ks]
    grid = DesignGrid.product(workloads, mac_budgets, [tiers], mode=mode)
    res = evaluate(grid, backend=backend, metrics=("perf",))
    s = res.speedup.reshape(len(ns), len(ks), len(mac_budgets))
    out = {}
    thresholds = {}
    for ni, n_dim in enumerate(ns):
        thresholds[n_dim] = mac_threshold(M, n_dim)
        for ki, k in enumerate(ks):
            out[(n_dim, k)] = [float(v) for v in s[ni, ki]]
    return mac_budgets, out, thresholds


@dataclasses.dataclass(frozen=True)
class Fig7Result:
    mac_budget: int
    optimal_tiers: np.ndarray  # per workload
    median: float


def random_workloads(n: int = 300, seed: int = 0):
    """Random workloads 'based on ResNet50 parameters' (Sec. IV-A.2):
    M, N sampled from conv-layer output/channel ranges, K from the
    unrolled reduction range of ResNet50 layers."""
    rng = np.random.default_rng(seed)
    M = rng.integers(16, 512, size=n)
    N = 2 ** rng.integers(4, 12, size=n)  # 16..2048 channels-ish
    K = rng.integers(64, 12100, size=n)
    return np.stack([M, K, N], axis=1)


def fig7_scatter(
    mac_budgets=(2**14, 2**16, 2**18),
    n_workloads=300,
    seed=0,
    max_tiers=16,
    mode="opt",
    backend="numpy",
):
    """Optimal tier count per workload x budget — one engine call over
    the full (workloads x budgets x tiers) grid."""
    wl = random_workloads(n_workloads, seed)
    best, _ = optimal_tiers_batched(
        wl, mac_budgets, max_tiers=max_tiers, mode=mode, backend=backend
    )
    return [
        Fig7Result(
            mac_budget=b,
            optimal_tiers=best[:, bi].astype(np.int64),
            median=float(np.median(best[:, bi])),
        )
        for bi, b in enumerate(mac_budgets)
    ]
