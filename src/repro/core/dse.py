"""Design-space exploration sweeps (paper Figs. 5, 6, 7 and Sec. IV-A).

These are the paper's workload/architecture studies, reproduced from the
analytical model:

- Fig. 5: 3D-vs-2D speedup over tier count, for several MAC budgets and
  several K (M = 64, N = 147 fixed — ResNet50's RN0 M/N).
- Fig. 6: speedup over MAC budget at 4 tiers (M = 64), for several N and
  K; the threshold N_min = M*N below which 3D cannot win.
- Fig. 7: scatter of the *optimal* tier count for 300 random workloads
  drawn around ResNet50-like layer dimensions, for three MAC budgets;
  the optimal-tier distribution shifts right as the budget grows.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .analytical import mac_threshold, optimal_tiers, speedup_3d

__all__ = [
    "fig5_sweep",
    "fig6_sweep",
    "fig7_scatter",
    "random_workloads",
    "PAPER_WORKLOADS",
]

# Table I: exemplary layers from current DNN workloads mapped to M, K, N.
PAPER_WORKLOADS = {
    "RN0": (64, 12100, 147),  # ResNet50
    "RN1": (512, 784, 128),
    "GNMT0": (128, 4096, 2048),  # Google NMT
    "GNMT1": (320, 4096, 3072),
    "DB0": (1024, 50000, 16),  # DeepBench
    "DB1": (35, 2560, 4096),
    "TF0": (31999, 84, 1024),  # Transformer
    "TF1": (84, 4096, 1024),
}


def fig5_sweep(
    mac_budgets=(2**12, 2**14, 2**16, 2**18),
    ks=(255, 2560, 12100),
    tiers=tuple(range(1, 17)),
    M=64,
    N=147,
    mode="opt",
):
    """Speedup vs tier count for each (MAC budget, K). Returns
    {(n_macs, K): [speedup per tier count]}."""
    out = {}
    for n in mac_budgets:
        for k in ks:
            out[(n, k)] = [speedup_3d(M, k, N, n, l, mode) for l in tiers]
    return tiers, out


def fig6_sweep(
    mac_budgets=tuple(2**p for p in range(10, 19)),
    ns=(147, 1024),
    ks=(784, 4096),
    M=64,
    tiers=4,
    mode="opt",
):
    """Speedup vs MAC budget at fixed tier count. Returns
    {(N, K): [speedup per budget]} plus the N_min threshold per N."""
    out = {}
    thresholds = {}
    for n_dim in ns:
        thresholds[n_dim] = mac_threshold(M, n_dim)
        for k in ks:
            out[(n_dim, k)] = [speedup_3d(M, k, n_dim, b, tiers, mode) for b in mac_budgets]
    return mac_budgets, out, thresholds


@dataclasses.dataclass(frozen=True)
class Fig7Result:
    mac_budget: int
    optimal_tiers: np.ndarray  # per workload
    median: float


def random_workloads(n: int = 300, seed: int = 0):
    """Random workloads 'based on ResNet50 parameters' (Sec. IV-A.2):
    M, N sampled from conv-layer output/channel ranges, K from the
    unrolled reduction range of ResNet50 layers."""
    rng = np.random.default_rng(seed)
    M = rng.integers(16, 512, size=n)
    N = 2 ** rng.integers(4, 12, size=n)  # 16..2048 channels-ish
    K = rng.integers(64, 12100, size=n)
    return np.stack([M, K, N], axis=1)


def fig7_scatter(mac_budgets=(2**14, 2**16, 2**18), n_workloads=300, seed=0, max_tiers=16, mode="opt"):
    wl = random_workloads(n_workloads, seed)
    results = []
    for b in mac_budgets:
        opt = np.array([optimal_tiers(m, k, n, b, max_tiers, mode)[0] for m, k, n in wl])
        results.append(Fig7Result(mac_budget=b, optimal_tiers=opt, median=float(np.median(opt))))
    return results
