"""Design-space exploration sweeps (paper Figs. 5, 6, 7 and Sec. IV-A).

These are the paper's workload/architecture studies, expressed as
declarative ``Study`` specs (``core.study``): each ``fig*_study``
builder returns the spec whose ``run()`` makes a **single** batched
engine call over every (workload, MAC budget, tier) combination — no
per-point Python loops. Regression tests pin the outputs bit-for-bit
to the original per-point loop implementations.

The classic call-style entry points (``fig5_sweep``/``fig6_sweep``/
``fig7_scatter``) remain as thin shims over the same specs: they run
the Study, reshape the payload into the historical return format, and
emit a ``DeprecationWarning`` pointing at the spec equivalent.

- Fig. 5: 3D-vs-2D speedup over tier count, for several MAC budgets and
  several K (M = 64, N = 147 fixed — ResNet50's RN0 M/N).
- Fig. 6: speedup over MAC budget at 4 tiers (M = 64), for several N and
  K; the threshold N_min = M*N below which 3D cannot win.
- Fig. 7: scatter of the *optimal* tier count for 300 random workloads
  drawn around ResNet50-like layer dimensions, for three MAC budgets;
  the optimal-tier distribution shifts right as the budget grows.
"""

from __future__ import annotations

import dataclasses
import warnings

import numpy as np

from .analytical import mac_threshold
from .study import AnalysisSpec, SpaceSpec, Study, WorkloadSpec

__all__ = [
    "fig5_study",
    "fig5_sweep",
    "fig6_study",
    "fig6_sweep",
    "fig7_study",
    "fig7_scatter",
    "random_workloads",
    "PAPER_WORKLOADS",
]

# Table I: exemplary layers from current DNN workloads mapped to M, K, N.
PAPER_WORKLOADS = {
    "RN0": (64, 12100, 147),  # ResNet50
    "RN1": (512, 784, 128),
    "GNMT0": (128, 4096, 2048),  # Google NMT
    "GNMT1": (320, 4096, 3072),
    "DB0": (1024, 50000, 16),  # DeepBench
    "DB1": (35, 2560, 4096),
    "TF0": (31999, 84, 1024),  # Transformer
    "TF1": (84, 4096, 1024),
}


def _deprecated(old: str, new: str):
    warnings.warn(
        f"{old} is deprecated; build the declarative equivalent with "
        f"{new} (core.study) and call .run() — same engine, same bits, "
        f"plus a serializable StudyResult artifact.",
        DeprecationWarning,
        stacklevel=3,
    )


def fig5_study(
    mac_budgets=(2**12, 2**14, 2**16, 2**18),
    ks=(255, 2560, 12100),
    tiers=tuple(range(1, 17)),
    M=64,
    N=147,
    mode="opt",
    backend="numpy",
) -> Study:
    """The Fig.-5 sweep as a Study: speedup vs tier count for each
    (MAC budget, K); payload ``speedup`` is (K, budget, tier)."""
    return Study(
        name="fig5",
        workload=WorkloadSpec(kind="gemms", gemms=tuple((M, k, N) for k in ks)),
        space=SpaceSpec(mac_budgets=mac_budgets, tiers=tiers, mode=mode),
        analysis=AnalysisSpec(kind="sweep", figure="fig5", backend=backend),
    )


def fig5_sweep(
    mac_budgets=(2**12, 2**14, 2**16, 2**18),
    ks=(255, 2560, 12100),
    tiers=tuple(range(1, 17)),
    M=64,
    N=147,
    mode="opt",
    backend="numpy",
):
    """DEPRECATED shim over ``fig5_study``. Returns the historical
    ``(tiers, {(n_macs, K): [speedup per tier count]})`` format."""
    _deprecated("fig5_sweep(...)", "fig5_study(...)")
    res = fig5_study(mac_budgets, ks, tiers, M, N, mode, backend).run()
    s = np.asarray(res.payload["speedup"])
    out = {}
    for bi, n in enumerate(mac_budgets):
        for ki, k in enumerate(ks):
            out[(n, k)] = [float(v) for v in s[ki, bi]]
    return tiers, out


def fig6_study(
    mac_budgets=tuple(2**p for p in range(10, 19)),
    ns=(147, 1024),
    ks=(784, 4096),
    M=64,
    tiers=4,
    mode="opt",
    backend="numpy",
) -> Study:
    """The Fig.-6 sweep as a Study: speedup vs MAC budget at a fixed
    tier count; payload ``speedup`` is (N x K, budget, 1), workload
    rows ordered N-major like the figure."""
    return Study(
        name="fig6",
        workload=WorkloadSpec(
            kind="gemms",
            gemms=tuple((M, k, n_dim) for n_dim in ns for k in ks),
        ),
        space=SpaceSpec(mac_budgets=mac_budgets, tiers=(tiers,), mode=mode),
        analysis=AnalysisSpec(kind="sweep", figure="fig6", backend=backend),
    )


def fig6_sweep(
    mac_budgets=tuple(2**p for p in range(10, 19)),
    ns=(147, 1024),
    ks=(784, 4096),
    M=64,
    tiers=4,
    mode="opt",
    backend="numpy",
):
    """DEPRECATED shim over ``fig6_study``. Returns the historical
    ``(mac_budgets, {(N, K): [speedup per budget]}, {N: N_min})``."""
    _deprecated("fig6_sweep(...)", "fig6_study(...)")
    res = fig6_study(mac_budgets, ns, ks, M, tiers, mode, backend).run()
    s = np.asarray(res.payload["speedup"]).reshape(len(ns), len(ks), len(mac_budgets))
    out = {}
    thresholds = {}
    for ni, n_dim in enumerate(ns):
        thresholds[n_dim] = mac_threshold(M, n_dim)
        for ki, k in enumerate(ks):
            out[(n_dim, k)] = [float(v) for v in s[ni, ki]]
    return mac_budgets, out, thresholds


@dataclasses.dataclass(frozen=True)
class Fig7Result:
    mac_budget: int
    optimal_tiers: np.ndarray  # per workload
    median: float


def random_workloads(n: int = 300, seed: int = 0):
    """Random workloads 'based on ResNet50 parameters' (Sec. IV-A.2):
    M, N sampled from conv-layer output/channel ranges, K from the
    unrolled reduction range of ResNet50 layers."""
    rng = np.random.default_rng(seed)
    M = rng.integers(16, 512, size=n)
    N = 2 ** rng.integers(4, 12, size=n)  # 16..2048 channels-ish
    K = rng.integers(64, 12100, size=n)
    return np.stack([M, K, N], axis=1)


def fig7_study(
    mac_budgets=(2**14, 2**16, 2**18),
    n_workloads=300,
    seed=0,
    max_tiers=16,
    mode="opt",
    backend="numpy",
) -> Study:
    """The Fig.-7 scatter as a Study: optimal tier count per (random
    workload, budget); payload ``optimal_tiers`` is (workload, budget)."""
    return Study(
        name="fig7",
        workload=WorkloadSpec(kind="random", n=n_workloads, seed=seed),
        space=SpaceSpec(mac_budgets=mac_budgets,
                        tiers=tuple(range(1, max_tiers + 1)), mode=mode),
        analysis=AnalysisSpec(kind="sweep", figure="fig7", backend=backend),
    )


def fig7_scatter(
    mac_budgets=(2**14, 2**16, 2**18),
    n_workloads=300,
    seed=0,
    max_tiers=16,
    mode="opt",
    backend="numpy",
):
    """DEPRECATED shim over ``fig7_study``. Returns the historical
    ``[Fig7Result per budget]`` list."""
    _deprecated("fig7_scatter(...)", "fig7_study(...)")
    res = fig7_study(mac_budgets, n_workloads, seed, max_tiers, mode, backend).run()
    best = np.asarray(res.payload["optimal_tiers"], dtype=np.int64)
    return [
        Fig7Result(
            mac_budget=b,
            optimal_tiers=best[:, bi],
            median=float(res.payload["medians"][bi]),
        )
        for bi, b in enumerate(mac_budgets)
    ]
