"""Unified batched design-space evaluation engine (perf + PPA sweeps).

The paper's headline results (Figs. 5-7, 9; up to 9.14x 3D-vs-2D
speedup) come from sweeping thousands of (workload x array x tier)
design points through the runtime, power and thermal models. This
module evaluates such sweeps in **one vectorized pass**:

    grid = DesignGrid.product(
        workloads=[(64, 12100, 147)],          # (M, K, N) rows
        mac_budgets=[2**14, 2**16, 2**18],
        tiers=range(1, 17),
    )
    res = evaluate(grid)                       # every metric, (W, P) arrays
    res.speedup, res.power_w, res.t_max_c, ...

For every (workload, design point) pair the engine finds the optimal
per-tier (R, C) under the MAC budget (or takes explicit rows/cols),
then derives in one shot: cycles (Eq. 1/2 and the WS/IS analogues),
switching activities, silicon area, dynamic+static power, energy,
steady-state tier temperatures (lumped model), utilization, and the
3D-vs-2D speedup against the budget-matched optimized 2D baseline.

Backends: ``backend='numpy'`` (default) runs the batched search with
numpy; ``backend='jax'`` jit-compiles the same search kernel
(``analytical._search_rc``) with ``jax.numpy`` under a scoped x64
context (cycle counts overflow int32). Both return identical integers;
derived metrics are always finished in numpy so the two backends share
every formula downstream of the search.

The scalar optimizers in ``core.analytical`` are batch-of-one wrappers
over the same kernel, so per-point and grid results can never drift —
the regression tests pin ``fig5_sweep``/``fig6_sweep``/``fig7_scatter``
to the legacy loop implementations bit-for-bit.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import numpy as np

from .analytical import (
    FOLD_NAMES,
    INVALID_CYCLES,
    _search_rc,
    _square_rc,
    fold_dims,
    native_fold,
)
from .bandwidth import (
    BOUND_NAMES,
    BandwidthSpec,
    bound_names,
    fold_traffic_batched,
    gemm_traffic_batched,
    roofline_cycles,
)
from .dataflow import activity_batched
from .params import (
    VALID_BACKENDS,
    VALID_DATAFLOWS,
    VALID_FOLDS,
    VALID_METRICS,
    VALID_MODES,
    VALID_SCHEDULE_POLICIES,
    VALID_TECHS,
    VALID_THERMAL_MODES,
    validate_option,
    validate_options,
)
from .ppa import constants as C
from .ppa.area import array_area_um2_batched
from .ppa.power import array_power_batched
from .ppa.thermal import ThermalState, lumped_tier_temps, step_temps
from .pricing import (
    DvfsSpec,
    dram_bytes_per_cycle,
    governed_run,
    governor_step,
    price_steps,
    scale_power,
)

__all__ = [
    "BandwidthSpec",
    "DesignGrid",
    "DvfsSpec",
    "EvalResult",
    "NetworkReport",
    "PolicyResult",
    "candidate_fixed_designs",
    "evaluate",
    "schedule",
    "thermal_feasible",
    "optimal_tiers_batched",
    "pareto_frontier",
    "pareto_mask_batched",
    "score_mesh_strategies",
    "MESH_STRATEGIES",
    "ICI_HOP_LATENCY_S",
]

_DEFAULT_CHUNK = 2048
_ALL_METRICS = ("perf", "area", "power", "thermal")
#: evaluate() streams point-blocks once the (W, P) result matrix would
#: exceed this many cells — bounds peak memory at any grid size.
_AUTO_STREAM_CELLS = 1 << 22


def _resolve_shards(shard, backend: str) -> int:
    """Shard request -> device count (deferred import: jax is lazy here).

    Only the jax backend has a device axis. ``'auto'`` is best-effort
    and portable: it means "all available parallelism", which on the
    numpy backend is none (1). An *explicit* count, by contrast, is a
    hard request — it errors on the numpy backend everywhere rather
    than silently no-opping on hosts that happen to have devices.
    """
    if shard is None or shard == "none" or shard == 1:
        return 1
    if backend != "jax":
        if shard == "auto":
            return 1
        raise ValueError(
            f"shard={shard!r} requires backend='jax' (the numpy search has "
            "no device axis); use shard='auto' for best-effort portability"
        )
    from ..parallel.shard_eval import resolve_shards

    return resolve_shards(shard)


def _as_1d_int(x) -> np.ndarray:
    return np.atleast_1d(np.asarray(x, dtype=np.int64))


@dataclasses.dataclass(frozen=True)
class DesignGrid:
    """A batch of GEMM workloads crossed with a batch of design points.

    ``workloads`` is (W, 3) int64 — rows of (M, K, N), the GEMM
    ``A(M x K) @ B(K x N)`` dimensions [elements]. Design points are
    parallel (P,) arrays: either ``mac_budgets`` [MAC units] (the
    engine optimizes the per-tier (R, C) shape under ``mac_budgets //
    tiers``, the paper's Sec. IV-A rounding) or explicit
    ``rows``/``cols`` [MACs per tier edge].
    ``dataflow`` is 'os' | 'ws' | 'is' | 'dos' — one string for the
    whole grid or a (P,) array ('os' is dOS at any tier count's l=1
    formulaic limit; at tiers > 1 'os' is treated as dOS). ``tech`` is
    '2d' | 'tsv' | 'miv', scalar or (P,).

    ``dram_gbs`` / ``sram_kib`` (optional, scalar or (P,) float) make
    the memory system itself a search axis: per-point DRAM bandwidth
    [GB/s] and per-tier SRAM capacity [KiB]. They only take effect when
    ``evaluate()`` runs with a ``BandwidthSpec`` — the per-point values
    override the spec's scalar ``dram_gbs`` / ``sram_kib_per_tier`` —
    and are ignored (with the spec's scalars used grid-wide) otherwise.

    ``fold`` (optional, 'm' | 'k' | 'n', scalar or (P,)) makes the
    per-layer tier fold a design axis (``analytical.fold_dims``): which
    GEMM dimension the l tiers partition. ``None`` (default) is the
    dataflow's native fold everywhere — the paper's tier split,
    bit-identical to the pre-fold engine.
    """

    workloads: np.ndarray
    tiers: np.ndarray
    mac_budgets: np.ndarray | None = None
    rows: np.ndarray | None = None
    cols: np.ndarray | None = None
    dataflow: str | np.ndarray = "dos"
    tech: str | np.ndarray = "tsv"
    mode: str = "opt"
    dram_gbs: np.ndarray | None = None
    sram_kib: np.ndarray | None = None
    fold: str | np.ndarray | None = None

    def __post_init__(self):
        validate_options("dataflow", self.dataflow, VALID_DATAFLOWS)
        validate_options("tech", self.tech, VALID_TECHS)
        validate_option("mode", self.mode, VALID_MODES)
        if self.fold is not None:
            validate_options("fold", self.fold, VALID_FOLDS)
        wl = np.atleast_2d(np.asarray(self.workloads, dtype=np.int64))
        if wl.ndim != 2 or wl.shape[1] != 3:
            raise ValueError(f"workloads must be (W, 3) of (M, K, N), got {wl.shape}")
        object.__setattr__(self, "workloads", wl)
        if self.mac_budgets is None and (self.rows is None or self.cols is None):
            raise ValueError("need either mac_budgets or explicit rows+cols")
        # The point count P is the common broadcast length of every
        # per-point field, so e.g. scalar tiers + vector budgets works.
        per_point = {"tiers": _as_1d_int(self.tiers)}
        for name in ("mac_budgets", "rows", "cols"):
            v = getattr(self, name)
            if v is not None:
                per_point[name] = _as_1d_int(v)
        for name in ("dram_gbs", "sram_kib"):
            v = getattr(self, name)
            if v is not None:
                arr = np.atleast_1d(np.asarray(v, dtype=np.float64))
                if not np.all(arr > 0):
                    raise ValueError(f"{name} values must be > 0")
                per_point[name] = arr
        for name in ("dataflow", "tech", "fold"):
            v = getattr(self, name)
            if v is not None and not isinstance(v, str):
                per_point[name] = np.atleast_1d(np.asarray(v))
        try:
            P = np.broadcast_shapes(*(a.shape for a in per_point.values()))[0]
        except ValueError:
            lens = {k: a.shape[0] for k, a in per_point.items()}
            raise ValueError(
                f"design-point arrays have incompatible lengths: {lens}"
            ) from None
        for name, v in per_point.items():
            object.__setattr__(self, name, np.broadcast_to(v, (P,)))

    @property
    def n_workloads(self) -> int:
        return self.workloads.shape[0]

    @property
    def n_points(self) -> int:
        return self.tiers.shape[0]

    @classmethod
    def product(
        cls,
        workloads,
        mac_budgets: Sequence[int],
        tiers: Sequence[int],
        **kw,
    ) -> "DesignGrid":
        """Cartesian product of budgets x tiers (budget-major ordering:
        point index p = i_budget * len(tiers) + i_tier)."""
        b = _as_1d_int(mac_budgets)
        t = _as_1d_int(tiers)
        bb = np.repeat(b, t.shape[0])
        tt = np.tile(t, b.shape[0])
        return cls(workloads=workloads, tiers=tt, mac_budgets=bb, **kw)

    @classmethod
    def explicit(cls, workloads, rows, cols, tiers, **kw) -> "DesignGrid":
        """Design points with fixed per-tier (rows, cols) — no search."""
        return cls(workloads=workloads, tiers=tiers, rows=rows, cols=cols, **kw)

    def subset(self, lo: int, hi: int) -> "DesignGrid":
        """The sub-grid of design points [lo, hi) (same workloads).

        The engine's search is rowwise independent, so evaluating a
        subset and slicing the full evaluation give identical bits —
        this is what makes streaming and chunk caching exact.
        """
        kw: dict = {"workloads": self.workloads, "tiers": self.tiers[lo:hi],
                    "mode": self.mode}
        for name in ("mac_budgets", "rows", "cols", "dram_gbs", "sram_kib"):
            v = getattr(self, name)
            if v is not None:
                kw[name] = v[lo:hi]
        for name in ("dataflow", "tech", "fold"):
            v = getattr(self, name)
            if name == "fold" and v is None:
                continue
            kw[name] = v if isinstance(v, str) else v[lo:hi]
        return DesignGrid(**kw)

    def to_dict(self) -> dict:
        """JSON-compatible form; ``from_dict`` is the exact inverse."""
        out: dict = {"workloads": self.workloads.tolist()}
        for name in ("tiers", "mac_budgets", "rows", "cols", "dram_gbs", "sram_kib"):
            v = getattr(self, name)
            out[name] = None if v is None else np.asarray(v).tolist()
        for name in ("dataflow", "tech", "fold"):
            v = getattr(self, name)
            if name == "fold" and v is None:
                out[name] = None
                continue
            out[name] = v if isinstance(v, str) else [str(x) for x in v]
        out["mode"] = self.mode
        return out

    @classmethod
    def from_dict(cls, d: dict) -> "DesignGrid":
        kw = {"workloads": d["workloads"], "tiers": d["tiers"], "mode": d.get("mode", "opt")}
        for name in ("mac_budgets", "rows", "cols", "dram_gbs", "sram_kib"):
            if d.get(name) is not None:
                kw[name] = d[name]
        for name in ("dataflow", "tech", "fold"):
            v = d.get(name)
            if v is not None:
                kw[name] = v if isinstance(v, str) else np.asarray(v)
        return cls(**kw)


@dataclasses.dataclass(frozen=True)
class EvalResult:
    """Stacked evaluation results; every array is (W, P) float64/int64.

    Units, per field: ``rows``/``cols`` are per-tier array dimensions
    [MACs]; ``cycles``/``cycles_2d``/``stall_cycles``/``mem_cycles``/
    ``vlink_cycles`` are clock cycles at the model's 1 GHz
    (``ppa.constants.FREQ_HZ``); ``area_um2``/``footprint_um2`` are
    silicon area [um^2]; ``power_w`` family is watts [W]; ``energy_j``
    is joules [J]; ``edp_js`` is the energy-delay product [J*s];
    ``t_max_c`` is the hottest tier's steady-state temperature [degC];
    ``dram_bytes``/``vlink_bytes``/``sram_need_bytes`` are bytes;
    ``speedup``/``utilization``/activity fields are dimensionless.

    ``cycles`` / ``cycles_2d`` are float64 (np.inf marks invalid design
    points, e.g. per-tier budget < 1); ``speedup = cycles_2d / cycles``
    against the budget-matched optimized 2D baseline of the same
    dataflow family. Metric groups not requested from ``evaluate()``
    are None.

    The bandwidth group (``stall_cycles`` ... ``within_sram_capacity``)
    is present iff ``evaluate()`` ran with a ``bandwidth=`` spec; then
    ``cycles``/``cycles_2d`` are the bandwidth-aware roofline totals
    (``cycles = compute + stall_cycles``) and ``bound`` classifies each
    point as ``'compute' | 'memory' | 'vlink'``. With an unbounded spec
    the group is all-zero/'compute' and every other field is bit-for-bit
    identical to the bandwidth-oblivious result.
    """

    grid: DesignGrid
    rows: np.ndarray
    cols: np.ndarray
    cycles: np.ndarray
    cycles_2d: np.ndarray
    speedup: np.ndarray
    utilization: np.ndarray
    valid: np.ndarray
    mac_act: np.ndarray | None = None
    hlink_act: np.ndarray | None = None
    vlink_act: np.ndarray | None = None
    area_um2: np.ndarray | None = None
    footprint_um2: np.ndarray | None = None
    area_norm_speedup: np.ndarray | None = None
    power_w: np.ndarray | None = None
    peak_power_w: np.ndarray | None = None
    static_power_w: np.ndarray | None = None
    dynamic_power_w: np.ndarray | None = None
    energy_j: np.ndarray | None = None
    edp_js: np.ndarray | None = None
    t_max_c: np.ndarray | None = None
    within_thermal_budget: np.ndarray | None = None
    #: bandwidth group — set iff evaluate() ran with a bandwidth spec.
    stall_cycles: np.ndarray | None = None
    bound: np.ndarray | None = None
    mem_cycles: np.ndarray | None = None
    vlink_cycles: np.ndarray | None = None
    dram_bytes: np.ndarray | None = None
    vlink_bytes: np.ndarray | None = None
    sram_need_bytes: np.ndarray | None = None
    within_sram_capacity: np.ndarray | None = None
    #: sustained-performance group — set iff evaluate() ran with
    #: thermal='transient': DVFS-governed steps/s over the settled half
    #: of the run, the cold top-state rate, their ratio, the governed
    #: hottest-tier excursion [degC], and the (W, P, n_states) fraction
    #: of governed steps spent in each DVFS state. In this mode
    #: ``within_thermal_budget`` reflects the governed excursion.
    sustained_per_s: np.ndarray | None = None
    peak_per_s: np.ndarray | None = None
    peak_vs_sustained: np.ndarray | None = None
    t_max_transient_c: np.ndarray | None = None
    dvfs_residency: np.ndarray | None = None

    @property
    def feasible(self) -> np.ndarray:
        """(W, P) bool — valid AND within every evaluated capacity.

        The first-class feasibility mask: optima (``pareto_mask``,
        ``schedule``, the advisor's design ranking) exclude points that
        are structurally invalid, would exceed the junction limit
        [degC], or whose minimal SRAM working set [bytes] does not fit
        the per-tier capacity (bandwidth-aware runs). Masks that were
        not evaluated are skipped.
        """
        m = self.valid
        if self.within_thermal_budget is not None:
            m = m & self.within_thermal_budget
        if self.within_sram_capacity is not None:
            m = m & self.within_sram_capacity
        return m

    #: dtypes restored by ``from_dict`` (everything else is float64).
    _INT_FIELDS = ("rows", "cols")
    _BOOL_FIELDS = ("valid", "within_thermal_budget", "within_sram_capacity")
    _STR_FIELDS = ("bound",)

    def to_dict(self) -> dict:
        """Array fields as a plain dict (None entries dropped), plus the
        originating grid under ``'grid'`` (already JSON-compatible).
        ``from_dict`` completes this into a lossless round-trip."""
        out = {"grid": self.grid.to_dict()}
        for f in dataclasses.fields(self):
            if f.name == "grid":
                continue
            v = getattr(self, f.name)
            if v is not None:
                out[f.name] = v
        return out

    @classmethod
    def from_dict(cls, d: dict) -> "EvalResult":
        """Inverse of ``to_dict``; accepts arrays or (JSON) nested lists
        and restores the exact per-field dtypes."""
        grid = d["grid"]
        kw = {"grid": grid if isinstance(grid, DesignGrid) else DesignGrid.from_dict(grid)}
        for f in dataclasses.fields(cls):
            if f.name == "grid" or d.get(f.name) is None:
                continue
            if f.name in cls._INT_FIELDS:
                dt = np.int64
            elif f.name in cls._BOOL_FIELDS:
                dt = bool
            elif f.name in cls._STR_FIELDS:
                dt = np.str_
            else:
                dt = np.float64
            kw[f.name] = np.asarray(d[f.name], dtype=dt)
        return cls(**kw)

    @classmethod
    def concat(cls, grid: DesignGrid, parts: Sequence["EvalResult"]) -> "EvalResult":
        """Stitch point-block results back into one (W, P) result.

        ``parts`` are evaluations of consecutive ``grid.subset`` blocks
        (all with the same metric groups); arrays concatenate along the
        point axis. The inverse of streaming: bit-for-bit equal to one
        unstreamed ``evaluate(grid)``.
        """
        if len(parts) == 1:
            return dataclasses.replace(parts[0], grid=grid)
        kw: dict = {"grid": grid}
        for f in dataclasses.fields(cls):
            if f.name == "grid":
                continue
            vs = [getattr(p, f.name) for p in parts]
            kw[f.name] = None if vs[0] is None else np.concatenate(vs, axis=1)
        return cls(**kw)

    def pareto_mask(
        self,
        objectives: Sequence[str] = ("cycles", "area_um2", "power_w"),
        feasible_only: bool = True,
    ) -> np.ndarray:
        """(W, P) bool — per-workload Pareto frontier over the named
        (minimized) metric columns (paper Sec. IV-C/D trade-offs).

        ``feasible_only`` (default) restricts the frontier to
        thermally feasible points: a design that dominates on
        latency/area/power but overshoots the junction limit is not a
        usable optimum. Pass False for the unconstrained frontier.
        """
        cols = []
        for name in objectives:
            v = getattr(self, name)
            if v is None:
                raise ValueError(f"metric {name!r} was not evaluated")
            cols.append(np.asarray(v, dtype=np.float64))
        stacked = np.stack(cols, axis=-1)  # (W, P, n_obj)
        if feasible_only:
            # Infeasible points neither appear on nor dominate the
            # frontier: blank them out before the scan (pareto_frontier
            # ignores non-finite rows entirely).
            stacked = np.where(self.feasible[..., None], stacked, np.inf)
        return pareto_mask_batched(stacked)


# ---------------------------------------------------------------------------
# Search backends
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=32)
def _jax_search_fn(r_max_total: int):
    import jax
    import jax.numpy as jnp

    def run(D1, D2, Tser, budget):
        return _search_rc(jnp, D1, D2, Tser, budget, r_max_total)

    return jax.jit(run)


def _search_batch(D1, D2, Tser, budget, backend: str, chunk: int, n_shards: int = 1):
    """Chunked dispatch of the (R, C) search. Returns (r, c, tau) int64.

    ``n_shards`` > 1 (jax backend) splits each chunk across the local
    JAX devices via ``parallel.shard_eval`` — same kernel, same static
    search width, so results match the unsharded path bit-for-bit. The
    numpy backend has no device axis and ignores ``n_shards``.
    """
    B = D1.shape[0]
    r_out = np.empty(B, dtype=np.int64)
    c_out = np.empty(B, dtype=np.int64)
    t_out = np.empty(B, dtype=np.int64)
    if B == 0:
        return r_out, c_out, t_out
    if backend == "jax":
        from jax.experimental import enable_x64

        # One static r_max (rounded up to a power of two to bound
        # recompiles) for the whole batch keeps a single jit cache entry.
        r_max = int(np.max(np.minimum(D1, budget)))
        r_max = 1 << max(int(np.ceil(np.log2(max(r_max, 1)))), 0)
        with enable_x64():
            if n_shards > 1:
                from ..parallel.shard_eval import sharded_search

                step = chunk * n_shards  # ~chunk rows per device
                for lo in range(0, B, step):
                    hi = min(lo + step, B)
                    r, c, t = sharded_search(
                        D1[lo:hi], D2[lo:hi], Tser[lo:hi], budget[lo:hi],
                        r_max, n_shards,
                    )
                    r_out[lo:hi], c_out[lo:hi], t_out[lo:hi] = r, c, t
                return r_out, c_out, t_out
            fn = _jax_search_fn(r_max)
            for lo in range(0, B, chunk):
                hi = min(lo + chunk, B)
                r, c, t = fn(D1[lo:hi], D2[lo:hi], Tser[lo:hi], budget[lo:hi])
                r_out[lo:hi], c_out[lo:hi], t_out[lo:hi] = (
                    np.asarray(r), np.asarray(c), np.asarray(t),
                )
        return r_out, c_out, t_out
    if backend != "numpy":
        raise ValueError(f"unknown backend {backend!r}")
    # Sort by each point's own search width so every chunk gets a tight
    # r_max — mixing one wide point into a chunk would otherwise charge
    # the whole chunk its width. Pure reordering; results are scattered
    # back, so the output is unchanged.
    widths = np.minimum(D1, budget)
    order = np.argsort(widths, kind="stable")
    tables = _factored_tables(D1, D2, budget, int(widths[order[-1]]))
    for lo in range(0, B, chunk):
        sel = order[lo : lo + chunk]
        r_max = int(widths[sel[-1]])
        r = c = t = None
        if tables is not None:
            out = _search_from_tables(tables, sel, Tser, r_max)
            if out is not None:
                r, c, t = out
        if r is None:
            r, c, t = _search_rc(
                np, D1[sel], D2[sel], Tser[sel], budget[sel], r_max
            )
        r_out[sel], c_out[sel], t_out[sel] = r, c, t
    return r_out, c_out, t_out


def _factored_tables(D1, D2, budget, r_max_total: int):
    """Precompute the Tser-independent parts of the (R, C) search.

    Per candidate R the tightened pair only depends on D1 (row folds)
    and on (D2, budget) (column folds): tau = (2*R2 + C2 + Tser - 2) *
    foldM * f. Design grids repeat the same workloads across many tier
    counts/budgets, so computing those chains once per *unique* D1 and
    per unique (D2, budget) pair and gathering rows afterwards removes
    nearly all of the division work. The search-space bound R <=
    min(D1, budget) is baked into the tables as inf entries, so invalid
    candidates cost nothing per chunk. Returns None when the grid has
    too little repetition (or is too wide for exact float64) to pay
    off.
    """
    if r_max_total < 1 or max(
        int(D1.max(initial=0)), int(D2.max(initial=0)), int(budget.max(initial=0))
    ) >= 2**52:
        return None
    uD1, invD1 = np.unique(D1, return_inverse=True)
    pair = np.stack([D2, budget], axis=1)
    upair, invP = np.unique(pair, axis=0, return_inverse=True)
    if (uD1.shape[0] + upair.shape[0]) * 2 > D1.shape[0]:
        return None  # not enough repetition to amortize the tables
    Rf = np.arange(1.0, r_max_total + 1.0)[None, :]
    D1f = uD1.astype(np.float64)[:, None]
    foldM = np.floor((D1f + Rf - 1.0) / Rf)
    R2 = np.floor((D1f + foldM - 1.0) / foldM)  # tightened, same folds
    D2f = upair[:, 0].astype(np.float64)[:, None]
    bf = upair[:, 1].astype(np.float64)[:, None]
    C1 = np.minimum(np.maximum(np.floor(bf / Rf), 1.0), D2f)
    f = np.floor((D2f + C1 - 1.0) / C1)
    C2 = np.floor((D2f + f - 1.0) / f)  # tightened: same folds, smaller C
    # Exact-arithmetic bound pieces: tau <= (fill_base + Tser - 2) *
    # prod_max. Chunks whose bound stays under 2^53 skip any overflow
    # guard (the common case).
    fill_base = 2.0 * R2.max() + C2.max()
    prod_max = foldM.max() * f.max()
    # Bake the R <= D1 / R <= budget pruning in as inf (fill > 0, so
    # inf propagates through tau and argmin never picks these).
    foldM[Rf > D1f] = np.inf
    f[Rf > bf] = np.inf
    # Table entries < 2^23 are exact in float32 — halves the gather
    # bandwidth of the chunk stage; tau itself is still formed in f64.
    dt = (
        np.float32
        if int(uD1.max(initial=0)) < 2**22 and int(upair[:, 0].max(initial=0)) < 2**23
        else np.float64
    )
    return (
        invD1,
        invP,
        foldM.astype(dt),
        (2.0 * R2).astype(dt),
        f.astype(dt),
        C2.astype(dt),
        (fill_base, prod_max),
    )


def _search_from_tables(tables, sel, Tser, r_max: int):
    """Finish the search for one chunk from the factored f64 tables.

    Returns None on (rare) potential tau overflow past 2^53; the caller
    reruns the chunk through the exact int64 kernel.
    """
    invD1, invP, foldM_u, twoR2_u, f_u, C2_u, (fill_base, prod_max) = tables
    Tsf = Tser[sel].astype(np.float64)
    if (fill_base + float(Tsf.max(initial=0.0)) - 2.0) * prod_max >= 2.0**53:
        return None
    g1 = invD1[sel]
    g2 = invP[sel]
    C2 = C2_u[:, :r_max][g2]
    folds = np.multiply(
        foldM_u[:, :r_max][g1], f_u[:, :r_max][g2], dtype=np.float64
    )
    taus = np.add(twoR2_u[:, :r_max][g1], C2, dtype=np.float64)
    taus += (Tsf - 2.0)[:, None]
    np.multiply(taus, folds, out=taus)
    i = np.argmin(taus, axis=1)
    rows = np.arange(sel.shape[0])
    t = taus[rows, i]
    r = (twoR2_u[g1, i] * 0.5).astype(np.int64)
    c = C2[rows, i].astype(np.int64)
    return r, c, np.where(np.isfinite(t), t, INVALID_CYCLES).astype(np.int64)


def _optimize_flat(M, K, N, n_macs, tiers, dataflow, mode, backend, chunk,
                   n_shards: int = 1, fold: str | None = None):
    """Batched shape optimization (flat arrays) honoring invalid budgets."""
    budget = n_macs // tiers
    ok = budget >= 1
    bsafe = np.maximum(budget, 1)
    D1, D2, Tser = fold_dims(fold, dataflow, M, K, N, tiers)
    if mode == "square":
        r, c, t = _square_rc(np, D1, D2, Tser, bsafe)
    else:
        r, c, t = _search_batch(D1, D2, Tser, bsafe, backend, chunk, n_shards)
    t = np.where(ok, t, INVALID_CYCLES)
    return r, c, t


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------

def evaluate(
    grid: DesignGrid,
    backend: str = "numpy",
    metrics: Sequence[str] = _ALL_METRICS,
    chunk: int = _DEFAULT_CHUNK,
    thermal_limit: float = C.THERMAL_BUDGET_C,
    shard: int | str | None = None,
    stream: int | None = None,
    bandwidth: BandwidthSpec | dict | None = None,
    freq_hz: float = C.FREQ_HZ,
    vdd_v: float = C.VDD,
    thermal: str = "steady",
    dvfs: DvfsSpec | dict | None = None,
) -> EvalResult:
    """Evaluate every (workload, design point) pair of the grid at once.

    ``metrics`` selects result groups: 'perf' (always computed),
    'area', 'power', 'thermal' (thermal implies power implies area).
    ``chunk`` bounds the working-set of the (B, R_max) search
    intermediates; results are independent of it. ``thermal_limit``
    sets the junction temperature [degC] behind
    ``within_thermal_budget`` / ``feasible``.

    ``bandwidth`` (a ``core.bandwidth.BandwidthSpec`` or its dict form)
    turns on the bandwidth-aware runtime model: DRAM traffic [bytes]
    under the SRAM-capacity reuse model, vertical-link (TSV vs MIV)
    service time [cycles], and the overlapped roofline ``cycles =
    max(compute, memory, vlink)`` — with ``stall_cycles``, the
    ``bound`` classification and the SRAM feasibility mask added to
    the result (see ``EvalResult``). The 2D baseline behind
    ``speedup`` is bandwidth-adjusted with the same spec (its own
    searched shape, tech '2d': no vertical links). ``None`` (default)
    and an unbounded spec are bit-for-bit identical to the plain
    evaluation. The (R, C) shape search itself stays compute-optimal —
    stalls are charged to the chosen design, not re-searched.

    ``shard``: ``'auto'`` splits the (R, C) search across the host's
    JAX devices (jax backend; ``parallel.shard_eval``); an int requests
    that many device shards; ``None``/``'none'`` stays single-device.
    ``stream`` caps how many design points are evaluated per pass —
    blocks are evaluated consecutively and stitched with
    ``EvalResult.concat`` so peak memory stays bounded at any grid
    size. By default grids past ~4M result cells stream automatically.
    Neither knob changes a single result bit (the search is rowwise
    independent; regression-pinned); both compose with ``bandwidth``.

    ``freq_hz`` / ``vdd_v`` move the whole evaluation to another
    operating point (``core.pricing`` scaling conventions: memory
    cycles and power follow the clock/supply; compute cycles do not).
    The defaults are the reference point and are bit-for-bit identical
    to the historical fixed-1-GHz results.

    ``thermal='transient'`` (requires the 'thermal' metric group)
    additionally runs the DVFS-governed transient model per design
    point: the per-workload step is executed ``dvfs.sim_steps`` times
    against the lumped RC stack (``ppa.thermal.ThermalState``) with the
    governor (``dvfs``, a ``pricing.DvfsSpec``; defaults to
    ``DvfsSpec()``) throttling on tier over-temperature. The result
    gains the sustained-performance group (``sustained_per_s`` ...
    ``dvfs_residency``) and — the semantic flip —
    ``within_thermal_budget`` becomes "the *governed* excursion stays
    under ``thermal_limit``", so a design the steady-state model
    rejects can be feasible at a lower sustained clock.
    """
    validate_option("backend", backend, VALID_BACKENDS)
    validate_option("thermal", thermal, VALID_THERMAL_MODES)
    metrics = {validate_option("metric", m, VALID_METRICS) for m in metrics}
    if thermal == "transient":
        if "thermal" not in metrics:
            raise ValueError(
                "thermal='transient' needs the 'thermal' metric group"
            )
        if dvfs is None:
            dvfs = DvfsSpec()
        elif not isinstance(dvfs, DvfsSpec):
            dvfs = DvfsSpec.from_dict(dvfs)
    elif dvfs is not None:
        raise ValueError("dvfs requires thermal='transient'")
    if "thermal" in metrics:
        metrics.add("power")
    if "power" in metrics:
        metrics.add("area")
    n_shards = _resolve_shards(shard, backend)
    if bandwidth is not None and not isinstance(bandwidth, BandwidthSpec):
        bandwidth = BandwidthSpec.from_dict(bandwidth)

    W, P = grid.n_workloads, grid.n_points
    if stream is None:
        block = P if W * P <= _AUTO_STREAM_CELLS else max(
            1, _AUTO_STREAM_CELLS // max(W, 1)
        )
    else:
        block = max(1, int(stream))
    if block < P:
        parts = [
            _evaluate_block(
                grid.subset(lo, min(lo + block, P)), backend, metrics, chunk,
                thermal_limit, n_shards, bandwidth, freq_hz, vdd_v,
                thermal, dvfs,
            )
            for lo in range(0, P, block)
        ]
        return EvalResult.concat(grid, parts)
    return _evaluate_block(
        grid, backend, metrics, chunk, thermal_limit, n_shards, bandwidth,
        freq_hz, vdd_v, thermal, dvfs,
    )


def _evaluate_block(
    grid: DesignGrid,
    backend: str,
    metrics: set,
    chunk: int,
    thermal_limit: float,
    n_shards: int = 1,
    bandwidth: BandwidthSpec | None = None,
    freq_hz: float = C.FREQ_HZ,
    vdd_v: float = C.VDD,
    thermal: str = "steady",
    dvfs: DvfsSpec | None = None,
) -> EvalResult:
    """One unstreamed evaluation pass (metrics already resolved)."""
    W, P = grid.n_workloads, grid.n_points
    # Flatten workload-major: flat index = w * P + p  -> reshape to (W, P).
    Mf = np.repeat(grid.workloads[:, 0], P)
    Kf = np.repeat(grid.workloads[:, 1], P)
    Nf = np.repeat(grid.workloads[:, 2], P)
    Lf = np.tile(grid.tiers, W)
    tech_p = (
        np.full(P, grid.tech) if isinstance(grid.tech, str) else grid.tech
    )
    techf = np.tile(tech_p, W)
    if grid.mac_budgets is not None:
        budgetf = np.tile(grid.mac_budgets, W)
    else:
        budgetf = np.tile(grid.rows * grid.cols * grid.tiers, W)

    df_p = (
        np.full(P, grid.dataflow)
        if isinstance(grid.dataflow, str)
        else np.asarray(grid.dataflow)
    )
    dff = np.tile(df_p, W)

    # Group the flat batch by (dataflow, fold): every model below is
    # uniform within a group. With no fold axis the groups are exactly
    # the historical per-dataflow groups (fold=None -> native mapping).
    if grid.fold is None:
        groups = [
            (str(df), None, np.nonzero(dff == df)[0]) for df in np.unique(dff)
        ]
    else:
        fold_p = (
            np.full(P, grid.fold)
            if isinstance(grid.fold, str)
            else np.asarray(grid.fold)
        )
        foldf = np.tile(fold_p, W)
        key = np.char.add(np.char.add(dff.astype("U8"), ":"), foldf.astype("U8"))
        groups = []
        for kk in np.unique(key):
            df, fo = str(kk).split(":")
            groups.append((df, fo, np.nonzero(key == kk)[0]))

    rows = np.empty(W * P, dtype=np.int64)
    cols = np.empty(W * P, dtype=np.int64)
    cyc = np.full(W * P, INVALID_CYCLES, dtype=np.int64)
    cyc2d = np.full(W * P, INVALID_CYCLES, dtype=np.int64)
    rows2d = np.ones(W * P, dtype=np.int64)
    cols2d = np.ones(W * P, dtype=np.int64)

    for df, fo, sel in groups:
        M_, K_, N_, L_, b_ = Mf[sel], Kf[sel], Nf[sel], Lf[sel], budgetf[sel]
        if grid.rows is not None:
            rows[sel] = np.tile(grid.rows, W)[sel]
            cols[sel] = np.tile(grid.cols, W)[sel]
            D1, D2, Tser = fold_dims(fo, df, M_, K_, N_, L_)
            r_, c_ = rows[sel], cols[sel]
            cyc[sel] = (2 * r_ + c_ + Tser - 2) * (-(-D1 // r_)) * (-(-D2 // c_))
        else:
            r_, c_, t_ = _optimize_flat(
                M_, K_, N_, b_, L_, df, grid.mode, backend, chunk, n_shards,
                fold=fo,
            )
            rows[sel], cols[sel], cyc[sel] = r_, c_, t_
        # Budget-matched optimized 2D baseline of the same dataflow
        # family (native mapping: every fold degenerates to it on one
        # tier). Dedupe (workload, budget): within `sel` the baseline
        # is constant across tier counts.
        wkey = np.stack([M_, K_, N_, b_], axis=1)
        uniq, inv = np.unique(wkey, axis=0, return_inverse=True)
        r2, c2, t2 = _optimize_flat(
            uniq[:, 0], uniq[:, 1], uniq[:, 2], uniq[:, 3],
            np.ones(len(uniq), dtype=np.int64), df, grid.mode,
            backend, chunk, n_shards,
        )
        cyc2d[sel] = t2[inv]
        rows2d[sel], cols2d[sel] = r2[inv], c2[inv]

    valid = cyc != INVALID_CYCLES
    cycles = np.where(valid, cyc, 0).astype(np.float64)
    cycles[~valid] = np.inf
    cycles_2d = np.where(cyc2d != INVALID_CYCLES, cyc2d, 0).astype(np.float64)
    cycles_2d[cyc2d == INVALID_CYCLES] = np.inf

    # --- bandwidth-aware roofline (tentpole): DRAM / SRAM / vlink -----
    # Applied to the compute-optimal shapes found above; an unbounded
    # spec yields zero stalls and leaves every downstream value
    # bit-for-bit unchanged (max(compute, 0, 0) == compute; + 0.0 is
    # exact), which is what makes bandwidth=None and an uncapped spec
    # regression-identical.
    bw_fields: dict = {}
    stall_flat = None
    if bandwidth is not None:
        mem_cyc = np.zeros(W * P)
        vl_cyc = np.zeros(W * P)
        dram_b = np.zeros(W * P)
        vl_b = np.zeros(W * P)
        sram_need = np.zeros(W * P)
        mem_cyc2 = np.zeros(W * P)
        # Per-point grid overrides (guided search over memory systems):
        # scalars stay the scalar fast path, bit-identical to before.
        if grid.dram_gbs is not None:
            bpc = np.tile(grid.dram_gbs, W) * 1e9 / freq_hz
        else:
            bpc = dram_bytes_per_cycle(bandwidth, freq_hz)
        if grid.sram_kib is not None:
            sram_cap = np.tile(grid.sram_kib, W) * 1024.0
        else:
            sram_cap = bandwidth.sram_bytes
        tech2d = np.full(W * P, "2d")
        ones = np.ones(W * P, dtype=np.int64)
        for df, fo, sel in groups:
            sram_sel = None if grid.sram_kib is None else sram_cap[sel]
            bpc_sel = bpc if np.isscalar(bpc) else bpc[sel]
            tr = fold_traffic_batched(
                fo, df, Mf[sel], Kf[sel], Nf[sel],
                rows[sel], cols[sel], Lf[sel], techf[sel], bandwidth,
                sram_bytes=sram_sel,
            )
            dram_b[sel] = tr["dram_bytes"]
            vl_b[sel] = tr["vlink_bytes"]
            vl_cyc[sel] = tr["vlink_cycles"]
            sram_need[sel] = tr["sram_need_bytes"]
            mem_cyc[sel] = tr["dram_bytes"] / bpc_sel
            # Budget-matched 2D baseline under the same memory system
            # (its own searched shape; tech '2d' has no vertical links).
            tr2 = gemm_traffic_batched(
                df, Mf[sel], Kf[sel], Nf[sel],
                rows2d[sel], cols2d[sel], ones[sel], tech2d[sel], bandwidth,
                sram_bytes=sram_sel,
            )
            mem_cyc2[sel] = tr2["dram_bytes"] / bpc_sel
        compute_flat = cycles  # pre-roofline array-busy cycles
        cycles, stall_flat, bidx = roofline_cycles(cycles, mem_cyc, vl_cyc)
        stall_flat = np.where(valid, stall_flat, np.nan)
        cycles_2d = np.maximum(cycles_2d, mem_cyc2)
        bw_fields = dict(
            stall_cycles=stall_flat.reshape(W, P),
            bound=bound_names(bidx).reshape(W, P),
            mem_cycles=mem_cyc.reshape(W, P),
            vlink_cycles=vl_cyc.reshape(W, P),
            dram_bytes=dram_b.reshape(W, P),
            vlink_bytes=vl_b.reshape(W, P),
            sram_need_bytes=sram_need.reshape(W, P),
            within_sram_capacity=(sram_need <= sram_cap).reshape(W, P),
        )

    with np.errstate(invalid="ignore", divide="ignore"):
        speedup = np.where(valid, cycles_2d / cycles, np.nan)
        n_used = rows * cols * Lf
        utilization = np.where(
            valid, (Mf * Kf * Nf).astype(np.float64) / (n_used * cycles), np.nan
        )

    res = dict(
        rows=rows.reshape(W, P),
        cols=cols.reshape(W, P),
        cycles=cycles.reshape(W, P),
        cycles_2d=cycles_2d.reshape(W, P),
        speedup=speedup.reshape(W, P),
        utilization=utilization.reshape(W, P),
        valid=valid.reshape(W, P),
        **bw_fields,
    )

    act = None
    if "power" in metrics or "area" in metrics:
        # Activities are cheap; compute per dataflow group.
        mac_a = np.zeros(W * P)
        hl_a = np.zeros(W * P)
        vl_a = np.zeros(W * P)
        for df, fo, sel in groups:
            a = activity_batched(
                Mf[sel], Kf[sel], Nf[sel], rows[sel], cols[sel], Lf[sel], df,
                fold=fo,
            )
            mac_a[sel], hl_a[sel], vl_a[sel] = a.mac, a.hlink, a.vlink
        res.update(
            mac_act=mac_a.reshape(W, P),
            hlink_act=hl_a.reshape(W, P),
            vlink_act=vl_a.reshape(W, P),
        )

    if "area" in metrics:
        # The paper's fixed-budget comparison charges the provisioned
        # array ((budget // l) * l MACs), not just the mapped sub-array.
        prov = (budgetf // Lf) * Lf
        a3, fp3, _ = array_area_um2_batched(prov, Lf, techf)
        a2, _, _ = array_area_um2_batched(budgetf, np.ones_like(Lf), "2d")
        with np.errstate(invalid="ignore", divide="ignore"):
            ans = speedup * (a2 / a3)
        res.update(
            area_um2=a3.reshape(W, P),
            footprint_um2=fp3.reshape(W, P),
            area_norm_speedup=ans.reshape(W, P),
        )

    if "power" in metrics:
        pw = {}
        for df, fo, sel in groups:
            p = array_power_batched(
                Mf[sel], Kf[sel], Nf[sel], rows[sel], cols[sel], Lf[sel],
                techf[sel], df, fold=fo,
            )
            for k, v in p.items():
                pw.setdefault(k, np.zeros(W * P))[sel] = v
        pw_ref = pw  # reference-point power (the transient model rescales)
        pw = scale_power(pw, freq_hz, vdd_v)  # identity at the default point
        t_s = np.where(valid, pw["cycles"] / freq_hz, np.nan)
        energy = pw["total_w"] * t_s
        t_total = t_s
        power_avg = pw["total_w"]
        if stall_flat is not None:
            # Stall cycles burn static power only (the MAC/link activity
            # waits with the array); energy = full power over the
            # compute phase + static power over the stall. Exact when
            # stall == 0: + static * 0.0 adds nothing, preserving the
            # uncapped bit-identity.
            t_stall = np.where(valid, stall_flat, 0.0) / freq_hz
            energy = energy + pw["static_w"] * t_stall
            t_total = t_s + t_stall
            with np.errstate(invalid="ignore", divide="ignore"):
                power_avg = np.where(t_stall > 0, energy / t_total, pw["total_w"])
        res.update(
            power_w=np.where(valid, power_avg, np.nan).reshape(W, P),
            peak_power_w=np.where(valid, pw["peak_w"], np.nan).reshape(W, P),
            static_power_w=np.where(valid, pw["static_w"], np.nan).reshape(W, P),
            dynamic_power_w=np.where(valid, pw["dynamic_w"], np.nan).reshape(W, P),
            energy_j=energy.reshape(W, P),
            edp_js=(energy * t_total).reshape(W, P),
        )

    if "thermal" in metrics:
        # Heat flux from the compute-phase power (full activity), not
        # the stall-averaged power: bandwidth stalls only cool the
        # stack, so masking on the active-phase temperature is the
        # conservative (and uncapped-identical) choice.
        lmax = int(np.max(Lf))
        idx = np.arange(lmax)[None, :]
        alive = idx < Lf[:, None]
        with np.errstate(invalid="ignore"):
            q = np.where(
                alive, (np.where(valid, pw["total_w"], 0.0) / Lf)[:, None], 0.0
            )
        fp_mm2 = res["footprint_um2"].reshape(-1) * 1e-6
        T = lumped_tier_temps(q, fp_mm2, Lf, techf, rows * cols)
        t_max = np.where(valid, np.max(np.where(alive, T, -np.inf), axis=1), np.nan)
        res.update(
            t_max_c=t_max.reshape(W, P),
            within_thermal_budget=(t_max < thermal_limit).reshape(W, P),
        )

        if thermal == "transient":
            # DVFS-governed transient run of each (workload, point)
            # step: compute/vlink cycle counts are clock-invariant,
            # memory cycles rescale with the governed clock, power is
            # rescaled per state from the reference report. Feasibility
            # flips to the governed excursion.
            if stall_flat is not None:
                mem_flat, vl_flat = mem_cyc, vl_cyc
            else:
                compute_flat = cycles
                mem_flat = np.zeros(W * P)
                vl_flat = np.zeros(W * P)
            gov = governed_run(
                compute_flat, mem_flat, vl_flat,
                pw_ref["static_w"], pw_ref["dynamic_w"], valid,
                Lf, techf, fp_mm2, rows * cols,
                dvfs, thermal_limit, freq_hz,
            )
            res.update(
                sustained_per_s=gov["sustained_per_s"].reshape(W, P),
                peak_per_s=gov["peak_per_s"].reshape(W, P),
                peak_vs_sustained=gov["peak_vs_sustained"].reshape(W, P),
                t_max_transient_c=gov["t_max_transient_c"].reshape(W, P),
                dvfs_residency=gov["residency"].reshape(W, P, dvfs.n_states),
                within_thermal_budget=gov["within_limit"].reshape(W, P),
            )

    return EvalResult(grid=grid, **res)


def optimal_tiers_batched(
    workloads,
    mac_budgets,
    max_tiers: int = 16,
    mode: str = "opt",
    backend: str = "numpy",
    chunk: int = _DEFAULT_CHUNK,
    shard: int | str | None = None,
    tech: str = "tsv",
    bandwidth: BandwidthSpec | dict | None = None,
):
    """Batched Fig.-7 argmin over tier count for every (workload, budget).

    Returns ``(best_tiers, best_cycles)`` int64/float64 arrays of shape
    (W, B) — cycles at the model's 1 GHz clock. Ties break toward fewer
    tiers, matching the scalar ``analytical.optimal_tiers`` loop
    exactly. With ``bandwidth`` set, the argmin runs over the
    bandwidth-aware roofline cycles (``tech`` selects the vertical-link
    technology for the derived vlink width) — the paper's Fig.-7 tier
    optimum under a finite memory system instead of peak compute.
    """
    wl = np.atleast_2d(np.asarray(workloads, dtype=np.int64))
    budgets = _as_1d_int(mac_budgets)
    W, B, T = wl.shape[0], budgets.shape[0], int(max_tiers)
    if bandwidth is not None and not isinstance(bandwidth, BandwidthSpec):
        bandwidth = BandwidthSpec.from_dict(bandwidth)
    # Direct search over the flattened (W x B x T) grid: unlike a full
    # evaluate() this skips the 2D-baseline pass Fig. 7 never uses.
    Mf = np.repeat(wl[:, 0], B * T)
    Kf = np.repeat(wl[:, 1], B * T)
    Nf = np.repeat(wl[:, 2], B * T)
    Lf = np.tile(np.arange(1, T + 1, dtype=np.int64), W * B)
    nm = np.tile(np.repeat(budgets, T), W)
    r, c, t = _optimize_flat(
        Mf, Kf, Nf, nm, Lf, "dos", mode, backend, chunk,
        _resolve_shards(shard, backend),
    )
    cyc = np.where(t != INVALID_CYCLES, t, 0).astype(np.float64)
    cyc[t == INVALID_CYCLES] = np.inf
    if bandwidth is not None:
        validate_option("tech", tech, VALID_TECHS)
        tr = gemm_traffic_batched(
            "dos", Mf, Kf, Nf, r, c, Lf, np.full(Lf.shape, tech), bandwidth
        )
        cyc, _, _ = roofline_cycles(
            cyc, tr["dram_bytes"] / bandwidth.dram_bytes_per_cycle,
            tr["vlink_cycles"],
        )
    cyc = cyc.reshape(W, B, T)
    best = np.argmin(cyc, axis=2)
    best_cycles = np.take_along_axis(cyc, best[:, :, None], axis=2)[:, :, 0]
    return best + 1, best_cycles


# ---------------------------------------------------------------------------
# Network-level scheduling (zoo -> lowering -> schedule -> report)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PolicyResult:
    """Network-level reduction of one mapping policy.

    ``per_layer``: every layer runs on its own best feasible array
    design (the DSE upper bound). ``fixed``: ONE array design (rows x
    cols x tiers) serves every layer — the physically buildable case.
    ``total_cycles`` [cycles at 1 GHz] is inf when no feasible design
    exists; ``time_s`` [s], ``energy_j`` [J], ``edp_js`` [J*s],
    ``t_max_c`` [degC]. ``stall_cycles``/``bound`` summarize the
    bandwidth-aware run (count-weighted stall total and the bound
    class carrying the largest share of runtime); they stay at their
    compute-bound defaults when ``schedule`` ran without a bandwidth
    spec.
    """

    policy: str
    total_cycles: float
    time_s: float
    energy_j: float
    edp_js: float
    total_cycles_2d: float
    speedup_vs_2d: float
    t_max_c: float
    utilization: float
    feasible: bool
    #: per-layer: (n_gemms, 3) int array of (rows, cols, tiers) per
    #: layer; fixed: the single (rows, cols, tiers) chosen.
    design: np.ndarray
    stall_cycles: float = 0.0
    bound: str = "compute"

    _FLOAT_FIELDS = (
        "total_cycles", "time_s", "energy_j", "edp_js", "total_cycles_2d",
        "speedup_vs_2d", "t_max_c", "utilization", "stall_cycles",
    )

    @classmethod
    def from_dict(cls, d: dict) -> "PolicyResult":
        kw = dict(d)
        kw["design"] = np.asarray(d["design"], dtype=np.int64)
        for name in cls._FLOAT_FIELDS:
            # float() also decodes the strict-JSON "Infinity"/"NaN"
            # encoding of non-finite values (see study._jsonify);
            # pre-bandwidth artifacts lack stall_cycles/bound and take
            # the compute-bound defaults.
            if name in kw:
                kw[name] = float(kw[name])
        return cls(**kw)


@dataclasses.dataclass(frozen=True)
class NetworkReport:
    """End-to-end evaluation of one lowered network stream."""

    arch: str
    shape: str
    mode: str
    n_gemms: int
    n_gemm_invocations: int
    total_macs: int
    per_layer: PolicyResult
    fixed: PolicyResult
    #: candidate fixed designs considered / excluded purely by thermal
    n_candidates: int
    n_thermally_masked: int
    thermal_limit: float
    #: DVFS-governed transient replay of the fixed design (None on
    #: steady-state runs / pre-transient artifacts): states, residency,
    #: peak vs sustained pass time, governed excursion, feasibility.
    dvfs: dict | None = None
    #: fine-grain tier-folded policy (None unless schedule ran with
    #: 'tier_fold' in ``policies``): one fixed array, but each layer
    #: picks its best per-tier partition (m/k/n fold) on it.
    tier_fold: PolicyResult | None = None
    #: tier_fold bookkeeping: {'by_layer': [fold name per layer],
    #: 'residency': {fold: count-weighted cycle share}}.
    fold: dict | None = None

    def to_dict(self) -> dict:
        out = dataclasses.asdict(self)
        for pol in ("per_layer", "fixed", "tier_fold"):
            if out.get(pol) is not None:
                out[pol]["design"] = np.asarray(out[pol]["design"]).tolist()
        return out

    @classmethod
    def from_dict(cls, d: dict) -> "NetworkReport":
        """Inverse of ``to_dict`` (lossless up to JSON float text);
        pre-fold artifacts restore with ``tier_fold``/``fold`` None."""
        kw = dict(d)
        for pol in ("per_layer", "fixed", "tier_fold"):
            v = d.get(pol)
            if v is None:
                continue
            kw[pol] = v if isinstance(v, PolicyResult) else PolicyResult.from_dict(v)
        return cls(**kw)


def _adaptive_chunk(workloads, mac_budgets) -> int:
    """Bound the (chunk, r_max) search working set to ~2^23 elements.

    Network streams carry token-sized dims (M up to tens of
    thousands), so the default 2048-wide chunks would allocate
    multi-GB tau intermediates. Results are chunk-independent."""
    wl = np.atleast_2d(np.asarray(workloads, dtype=np.int64))
    d1_max = int(wl.max())  # upper bound on D1 for any dataflow
    r_max = min(d1_max, int(np.max(mac_budgets)))
    return int(np.clip((1 << 23) // max(r_max, 1), 64, _DEFAULT_CHUNK))


def thermal_feasible(
    workloads,
    mac_budgets,
    tiers,
    dataflow: str = "dos",
    tech: str = "tsv",
    thermal_limit: float = C.THERMAL_BUDGET_C,
    backend: str = "numpy",
) -> np.ndarray:
    """(W, P) bool — can each (workload, design point) run within the
    junction limit? The advisor uses this to strike 3D-stacked
    candidates whose steady-state stack temperature overshoots."""
    wl = np.atleast_2d(np.asarray(workloads, dtype=np.int64))
    grid = DesignGrid(
        workloads=wl, tiers=_as_1d_int(tiers), mac_budgets=_as_1d_int(mac_budgets),
        dataflow=dataflow, tech=tech,
    )
    res = evaluate(
        grid, backend=backend, metrics=("thermal",),
        chunk=_adaptive_chunk(wl, grid.mac_budgets),
        thermal_limit=thermal_limit,
    )
    return res.feasible


def candidate_fixed_designs(res: EvalResult, tiers, per_point: bool = False):
    """Fixed-array candidate designs from a per-layer-optimum pass.

    The shared first half of the two-pass selection ``schedule`` and
    ``core.serve`` both run: the valid per-layer (rows, cols) optima of
    ``res`` form the candidate set for the explicit re-evaluation pass
    (scoring stays with each caller).

    Pooled (default, ``schedule``): the distinct (rows, cols, tiers)
    triples over every valid (layer, point) cell — (n_cand, 3) int64.

    ``per_point=True`` (``core.serve``): per design point j, the sorted
    distinct (rows, cols) pairs of its own valid cells, with a (1, 1)
    fallback for structurally invalid points — returns
    ``(cand_rows, cand_cols, owner)`` int64 arrays, ``owner[i]`` the
    original point index candidate i belongs to.
    """
    v = res.valid
    if not per_point:
        return np.unique(
            np.stack(
                [res.rows[v], res.cols[v], np.broadcast_to(tiers, v.shape)[v]],
                axis=1,
            ),
            axis=0,
        )
    cand_rows, cand_cols, owner = [], [], []
    for j in range(v.shape[1]):
        vj = v[:, j]
        pairs = sorted(set(zip(res.rows[vj, j].tolist(), res.cols[vj, j].tolist())))
        if not pairs:
            pairs = [(1, 1)]  # structurally invalid point (budget < tiers)
        for r, c in pairs:
            cand_rows.append(r)
            cand_cols.append(c)
            owner.append(j)
    return (
        np.asarray(cand_rows, dtype=np.int64),
        np.asarray(cand_cols, dtype=np.int64),
        np.asarray(owner, dtype=np.int64),
    )


def _reduce_policy(
    policy, counts, cycles, energy, t_max, util_den, cycles_2d, design, freq_hz,
    stall_cycles: float = 0.0, bound: str = "compute",
):
    """Totals for one policy given the per-layer chosen columns."""
    total_cycles = float(np.sum(counts * cycles))
    time_s = total_cycles / freq_hz
    energy_j = float(np.sum(counts * energy))
    total_2d = float(np.sum(counts * cycles_2d))
    with np.errstate(invalid="ignore", divide="ignore"):
        speedup = total_2d / total_cycles if total_cycles > 0 else np.nan
    feasible = bool(np.isfinite(total_cycles))
    t_max = np.asarray(t_max, dtype=np.float64)
    hot = float(np.nanmax(t_max)) if np.any(np.isfinite(t_max)) else float("nan")
    return PolicyResult(
        policy=policy,
        total_cycles=total_cycles,
        time_s=time_s,
        energy_j=energy_j,
        edp_js=energy_j * time_s,
        total_cycles_2d=total_2d,
        speedup_vs_2d=float(speedup),
        t_max_c=hot,
        utilization=float(util_den) if feasible else float("nan"),
        feasible=feasible,
        design=design,
        stall_cycles=stall_cycles,
        bound=bound,
    )


def _governed_layer_replay(
    res2: EvalResult, c_star: int, counts, dvfs: DvfsSpec, thermal_limit: float
) -> dict:
    """Replay the fixed design's layer stream under the DVFS governor.

    One pass = the whole network (every layer, count-weighted) on the
    chosen fixed array; ``dvfs.sim_steps`` passes integrate the lumped
    RC stack with a governor decision after every layer. Returns the
    report's ``dvfs`` dict — sustained (last, thermally settled) vs
    peak (cold, top-state) pass time and the governed verdict.
    """
    W = res2.cycles.shape[0]
    fx = res2.cycles[:, c_star]
    out = {
        "freqs_ghz": list(dvfs.freqs_ghz),
        "vdds_v": list(dvfs.vdds_v),
        "sim_passes": dvfs.sim_steps,
    }
    if not np.all(np.isfinite(fx)):
        out.update(feasible_transient=False, within_thermal_budget=False)
        return out
    stall = (
        np.nan_to_num(res2.stall_cycles[:, c_star])
        if res2.stall_cycles is not None
        else np.zeros(W)
    )
    compute = fx - stall
    mem = (
        res2.mem_cycles[:, c_star]
        if res2.mem_cycles is not None
        else np.zeros(W)
    )
    vl = (
        res2.vlink_cycles[:, c_star]
        if res2.vlink_cycles is not None
        else np.zeros(W)
    )
    static = res2.static_power_w[:, c_star]
    dyn = res2.dynamic_power_w[:, c_star]
    grid2 = res2.grid
    L = int(grid2.tiers[c_star])
    tech = (
        grid2.tech if isinstance(grid2.tech, str) else str(grid2.tech[c_star])
    )
    fp_mm2 = float(res2.footprint_um2[0, c_star]) * 1e-6
    macs = float(grid2.rows[c_star] * grid2.cols[c_star])
    freqs = dvfs.freqs_hz()
    sd, ss = dvfs.scales()
    tstate = ThermalState.init(
        np.array([fp_mm2]), np.array([L]), np.array([tech]), np.array([macs])
    )
    state = dvfs.n_states - 1
    resid = np.zeros(dvfs.n_states)
    t_hot = -np.inf
    pass_s = 0.0
    counts = np.asarray(counts, dtype=np.float64)
    with np.errstate(invalid="ignore", divide="ignore"):
        for _ in range(dvfs.sim_steps):
            pass_s = 0.0
            for i in range(W):
                f = float(freqs[state])
                tot = max(compute[i], mem[i] * (f / C.FREQ_HZ), vl[i])
                dt = counts[i] * tot / f
                pwr = static[i] * ss[state] + dyn[i] * sd[state]
                tstate = step_temps(
                    tstate, np.full((1, L), pwr / L), np.array([dt])
                )
                tmax = float(tstate.t_max_c[0])
                t_hot = max(t_hot, tmax)
                resid[state] += 1.0
                pass_s += dt
                state = int(
                    governor_step(
                        np.array([state]), np.array([tmax]), thermal_limit, dvfs
                    )[0]
                )
        f_top = float(freqs[-1])
        peak_s = float(np.sum(
            counts
            * np.maximum(compute, np.maximum(mem * (f_top / C.FREQ_HZ), vl))
            / f_top
        ))
    out.update(
        residency=(resid / resid.sum()).tolist(),
        peak_pass_s=peak_s,
        sustained_pass_s=pass_s,
        peak_vs_sustained=pass_s / peak_s if peak_s > 0 else float("nan"),
        t_max_transient_c=t_hot,
        within_thermal_budget=bool(t_hot < thermal_limit),
        feasible_transient=bool(np.isfinite(pass_s) and t_hot < thermal_limit),
    )
    return out


def schedule(
    stream,
    mac_budgets=(2**14, 2**16, 2**18),
    tiers=tuple(range(1, 17)),
    dataflow: str = "dos",
    tech: str = "tsv",
    backend: str = "numpy",
    thermal_limit: float = C.THERMAL_BUDGET_C,
    require_feasible: bool = True,
    chunk: int | None = None,
    shard: int | str | None = None,
    bandwidth: BandwidthSpec | dict | None = None,
    thermal: str = "steady",
    dvfs: DvfsSpec | dict | None = None,
    policies: Sequence[str] = ("per_layer", "fixed"),
) -> NetworkReport:
    """Evaluate a whole lowered network stream on the design grid.

    ``stream`` is a ``core.network.WorkloadStream`` (anything with
    ``.workloads`` (n, 3), ``.counts`` (n,) and the naming attributes
    works). The engine evaluates the stream batched over the (budget x
    tier) grid once, derives the candidate fixed-array designs from the
    per-layer optima, re-evaluates those shared designs explicitly, and
    reduces to network-level totals (cycles at 1 GHz, seconds, joules,
    J*s, degC) under two policies:

    - ``per_layer``: each GEMM on its own best feasible design — the
      DSE upper bound (what per-layer papers report).
    - ``fixed``: one (rows x cols x tiers) array serves every layer —
      the buildable accelerator. Its candidate set contains every
      layer's optimum, so ``fixed.total_cycles >=
      per_layer.total_cycles`` by construction.
    - ``tier_fold`` (opt-in via ``policies``): one fixed array, but
      each layer additionally picks its best per-tier partition of the
      GEMM — fold-m / fold-k / fold-n (``analytical.fold_dims``) —
      with the cross-tier reduction / operand-multicast traffic priced
      on the vertical links (``bandwidth.fold_traffic_batched``, via
      ``pricing.price_steps``). The native fold is always a candidate
      and prices identically to the fixed policy's cycles, so
      ``tier_fold.total_cycles <= fixed.total_cycles`` by construction
      (equality at one tier, where every fold degenerates to native).
      Per-fold SRAM working sets join the feasibility mask; the
      thermal verdict is inherited from the design's native-mapping
      evaluation (folds redistribute the same work across the same
      stack).

    ``policies`` must contain 'per_layer' and 'fixed' (the report's
    backbone); add 'tier_fold' for the folded policy + the report's
    ``fold`` residency dict.

    Thermal feasibility is first-class: designs whose lumped stack
    temperature reaches ``thermal_limit`` [degC] are excluded from both
    optima (``require_feasible=False`` disables the mask, for
    ablations). Speedups are against the budget-matched optimized 2D
    baseline of the same dataflow family, reduced with the same
    per-layer counts.

    ``bandwidth`` (a ``core.bandwidth.BandwidthSpec``) makes the whole
    reduction bandwidth-aware: candidate designs are still the
    compute-optimal per-layer shapes (the search is not re-run under
    stalls), but their per-layer cycles/energy include DRAM and
    vertical-link stalls, SRAM capacity joins the feasibility mask,
    and both policy optima are taken over the stalled totals — which
    can (and does; regression-pinned) flip the winning fixed design
    under a DRAM cap. Uncapped/None is bit-identical to the plain
    schedule.

    ``thermal='transient'`` reports *sustained* instead of gated-peak
    performance: the steady-state thermal mask is dropped from the
    candidate selection (structural validity and SRAM capacity still
    apply), and the winning fixed design's layer stream is replayed
    ``dvfs.sim_steps`` times under the DVFS governor against the
    transient RC stack — the report's ``dvfs`` dict carries the
    governed residency, peak-vs-sustained pass time and the governed
    excursion's own feasibility verdict.
    """
    validate_option("dataflow", dataflow, VALID_DATAFLOWS)
    validate_option("tech", tech, VALID_TECHS)
    validate_option("backend", backend, VALID_BACKENDS)
    validate_option("thermal", thermal, VALID_THERMAL_MODES)
    policies = tuple(
        validate_option("policy", p, VALID_SCHEDULE_POLICIES) for p in policies
    )
    for need in ("per_layer", "fixed"):
        if need not in policies:
            raise ValueError(
                f"policies must include {need!r} (got {policies!r}); "
                "'tier_fold' is the opt-in extra"
            )
    if thermal == "transient":
        if dvfs is None:
            dvfs = DvfsSpec()
        elif not isinstance(dvfs, DvfsSpec):
            dvfs = DvfsSpec.from_dict(dvfs)
    elif dvfs is not None:
        raise ValueError("dvfs requires thermal='transient'")
    wl = np.atleast_2d(np.asarray(stream.workloads, dtype=np.int64))
    counts = np.asarray(stream.counts, dtype=np.float64)
    W = wl.shape[0]
    if counts.shape != (W,):
        raise ValueError(f"counts shape {counts.shape} != ({W},)")
    if chunk is None:
        chunk = _adaptive_chunk(wl, mac_budgets)

    # Pass 1: per-layer optimal shapes over the (budget x tier) grid —
    # only the searched (rows, cols) feed the candidate set, so skip
    # the PPA metric groups here; feasibility is applied in pass 2.
    grid = DesignGrid.product(wl, mac_budgets, tiers, dataflow=dataflow, tech=tech)
    res1 = evaluate(grid, backend=backend, metrics=("perf",), chunk=chunk, shard=shard)

    # Candidate fixed designs: every distinct per-layer optimum. The
    # per-layer policy minimizes over the same candidate columns, which
    # is what makes fixed >= per_layer a theorem rather than a trend.
    cand = candidate_fixed_designs(res1, grid.tiers)
    if cand.shape[0] == 0:
        raise ValueError(f"{stream.arch}/{stream.shape}: no valid design point")

    # Pass 2: every layer on every shared candidate design (no search —
    # explicit shapes), with power/thermal for the feasibility mask.
    grid2 = DesignGrid.explicit(
        wl, rows=cand[:, 0], cols=cand[:, 1], tiers=cand[:, 2],
        dataflow=dataflow, tech=tech,
    )
    res2 = evaluate(
        grid2, backend=backend, chunk=chunk, thermal_limit=thermal_limit,
        shard=shard, bandwidth=bandwidth,
    )
    if thermal == "transient" and require_feasible:
        # sustained mode: thermal gating moves to the governed replay —
        # structural validity and SRAM capacity still mask candidates
        feas = res2.valid
        if res2.within_sram_capacity is not None:
            feas = feas & res2.within_sram_capacity
    else:
        feas = res2.feasible if require_feasible else res2.valid
    # counted from the thermal mask alone — under a bandwidth spec,
    # feasible also carries the SRAM-capacity mask, which must not be
    # misattributed to overheating in the report
    thermal_ok = res2.valid & res2.within_thermal_budget
    n_thermal_masked = int(np.sum(np.all(res2.valid, axis=0) & ~np.all(thermal_ok, axis=0)))

    cyc = np.where(feas, res2.cycles, np.inf)
    energy = np.where(feas, res2.energy_j, np.inf)
    freq = C.FREQ_HZ
    workload_macs = (wl[:, 0] * wl[:, 1] * wl[:, 2]).astype(np.float64)
    n_macs_used = (cand[:, 0] * cand[:, 1] * cand[:, 2]).astype(np.float64)

    def util(chosen_cycles, chosen_cols):
        # Useful MAC-ops per provisioned MAC-cycle over the whole run.
        den = np.sum(counts * n_macs_used[chosen_cols] * chosen_cycles)
        return np.sum(counts * workload_macs) / den if den > 0 else np.nan

    def bw_summary(chosen_cycles, layer_rows, layer_cols):
        """Count-weighted stall total [cycles] + dominant bound class."""
        if res2.stall_cycles is None:
            return 0.0, "compute"
        fin = np.isfinite(chosen_cycles)
        stall = float(np.sum(
            counts * np.where(fin, res2.stall_cycles[layer_rows, layer_cols], 0.0)
        ))
        weight = counts * np.where(fin, chosen_cycles, 0.0)
        b = res2.bound[layer_rows, layer_cols]
        shares = {n: float(np.sum(weight[b == n])) for n in BOUND_NAMES}
        return stall, max(BOUND_NAMES, key=lambda n: shares[n])

    # --- per-layer-optimal policy -------------------------------------
    best = np.argmin(cyc, axis=1)  # (W,)
    rows_w = np.arange(W)
    pl_cyc = cyc[rows_w, best]
    pl_stall, pl_bound = bw_summary(pl_cyc, rows_w, best)
    per_layer = _reduce_policy(
        "per_layer", counts, pl_cyc,
        energy[rows_w, best],
        np.where(np.isfinite(pl_cyc), res2.t_max_c[rows_w, best], np.nan),
        util(pl_cyc, best),
        np.where(np.isfinite(pl_cyc), res2.cycles_2d[rows_w, best], np.inf),
        cand[best], freq, pl_stall, pl_bound,
    )

    # --- fixed-design policy ------------------------------------------
    # inf propagation: any infeasible layer poisons the whole column.
    tot = np.sum(counts[:, None] * cyc, axis=0)
    c_star = int(np.argmin(tot))
    fx_cyc = cyc[:, c_star]
    fx_cols = np.full(W, c_star)
    fx_stall, fx_bound = bw_summary(fx_cyc, rows_w, fx_cols)
    fixed = _reduce_policy(
        "fixed", counts, fx_cyc,
        energy[:, c_star],
        np.where(np.isfinite(fx_cyc), res2.t_max_c[:, c_star], np.nan),
        util(fx_cyc, fx_cols),
        np.where(np.isfinite(fx_cyc), res2.cycles_2d[:, c_star], np.inf),
        cand[c_star], freq, fx_stall, fx_bound,
    )

    # --- tier-folded policy (opt-in) ----------------------------------
    # One fixed array like `fixed`, but each layer picks its best tier
    # fold on it. All three folds are priced through price_steps (the
    # native fold reproduces the engine's cycles bit-for-bit), so the
    # argmin can only improve on `fixed`; ties break toward native.
    tier_fold_pol = None
    fold_info = None
    if "tier_fold" in policies:
        spec_bw = bandwidth if bandwidth is not None else BandwidthSpec()
        nat = native_fold(dataflow)
        fold_order = [nat] + [f for f in FOLD_NAMES if f != nat]
        Mw, Kw, Nw = (wl[:, i][:, None] for i in range(3))
        r_c, c_c, l_c = (cand[:, i][None, :] for i in range(3))
        priced = [
            price_steps(dataflow, Mw, Kw, Nw, r_c, c_c, l_c, tech, spec_bw,
                        fold=f)
            for f in fold_order
        ]
        cyc_f = np.stack([p["total_cycles"] for p in priced])  # (3, W, n_cand)
        if require_feasible:
            ok_f = np.stack(
                [p["sram_need_bytes"] <= spec_bw.sram_bytes for p in priced]
            )
            cyc_fm = np.where(feas[None] & ok_f, cyc_f, np.inf)
        else:
            cyc_fm = np.where(feas[None], cyc_f, np.inf)
        fi = np.argmin(cyc_fm, axis=0)  # first minimum -> native on ties
        cell = np.take_along_axis(cyc_fm, fi[None], axis=0)[0]
        en_f = np.stack([p["energy_j"] for p in priced])
        cell_en = np.where(
            np.isfinite(cell),
            np.take_along_axis(en_f, fi[None], axis=0)[0],
            np.inf,
        )
        tot_f = np.sum(counts[:, None] * cell, axis=0)
        c_fold = int(np.argmin(tot_f))
        tf_cyc = cell[:, c_fold]
        fin = np.isfinite(tf_cyc)
        st_f = np.stack([p["stall_cycles"] for p in priced])
        bi_f = np.stack([p["bound_idx"] for p in priced])
        cell_st = np.take_along_axis(st_f, fi[None], axis=0)[0][:, c_fold]
        cell_bi = np.take_along_axis(bi_f, fi[None], axis=0)[0][:, c_fold]
        tf_stall = float(np.sum(counts * np.where(fin, cell_st, 0.0)))
        weight = counts * np.where(fin, tf_cyc, 0.0)
        b_names = bound_names(cell_bi)
        shares = {n: float(np.sum(weight[b_names == n])) for n in BOUND_NAMES}
        tf_bound = max(BOUND_NAMES, key=lambda n: shares[n])
        tier_fold_pol = _reduce_policy(
            "tier_fold", counts, tf_cyc, cell_en[:, c_fold],
            np.where(fin, res2.t_max_c[:, c_fold], np.nan),
            util(tf_cyc, np.full(W, c_fold)),
            np.where(fin, res2.cycles_2d[:, c_fold], np.inf),
            cand[c_fold], freq, tf_stall, tf_bound,
        )
        li = fi[:, c_fold]
        wsum = float(weight.sum())
        fold_info = {
            "by_layer": [fold_order[int(i)] for i in li],
            "residency": {
                f: (float(np.sum(weight[li == i])) / wsum if wsum > 0 else 0.0)
                for i, f in enumerate(fold_order)
            },
        }

    dvfs_report = None
    if thermal == "transient":
        dvfs_report = _governed_layer_replay(
            res2, c_star, counts, dvfs, thermal_limit
        )

    return NetworkReport(
        arch=stream.arch,
        shape=stream.shape,
        mode=str(stream.mode),
        n_gemms=W,
        n_gemm_invocations=int(counts.sum()),
        total_macs=int(np.sum(counts * workload_macs)),
        per_layer=per_layer,
        fixed=fixed,
        n_candidates=int(cand.shape[0]),
        n_thermally_masked=n_thermal_masked,
        thermal_limit=thermal_limit,
        dvfs=dvfs_report,
        tier_fold=tier_fold_pol,
        fold=fold_info,
    )


# ---------------------------------------------------------------------------
# Pareto utility (paper Sec. IV-C/D: latency-area-power trade-offs)
# ---------------------------------------------------------------------------

def pareto_frontier(points, chunk: int = 2048) -> np.ndarray:
    """Boolean mask of Pareto-optimal rows (all objectives minimized).

    ``points`` is (n, d); a row is on the frontier iff no other row is
    <= in every objective and < in at least one. Rows with non-finite
    entries are never on the frontier. The 2-objective case runs the
    sort-based O(n log n) sweep; otherwise O(n^2) in ``chunk``-sized
    blocks. Both paths are the single-workload case of
    ``pareto_mask_batched`` (regression-pinned bit-identical to the
    pre-vectorized scan by ``tests/test_engine.py``).
    """
    pts = np.atleast_2d(np.asarray(points, dtype=np.float64))
    return pareto_mask_batched(pts[None, :, :], chunk=chunk)[0]


def _pareto_mask_2obj(pts: np.ndarray) -> np.ndarray:
    """(W, n, 2) -> (W, n) frontier masks via per-row lexicographic
    sort + prefix-min sweep — O(W n log n), no pairwise matrix.

    A point is dominated iff (a) some point with strictly smaller x has
    y <= its y (prefix min over earlier x-groups), or (b) a point with
    the same x has strictly smaller y (within a group, sorted by y, the
    group head holds the minimum). Ties on both coordinates keep every
    copy, matching the pairwise scan's strict-< requirement.
    """
    W, n = pts.shape[:2]
    finite = np.isfinite(pts).all(axis=-1)
    q = np.where(finite[..., None], pts, np.inf)
    x, y = q[..., 0], q[..., 1]
    # Stable two-pass argsort == per-row lexsort by (x asc, then y asc).
    o1 = np.argsort(y, axis=1, kind="stable")
    o2 = np.argsort(np.take_along_axis(x, o1, axis=1), axis=1, kind="stable")
    order = np.take_along_axis(o1, o2, axis=1)
    X = np.take_along_axis(x, order, axis=1)
    Y = np.take_along_axis(y, order, axis=1)
    F = np.take_along_axis(finite, order, axis=1)
    idx = np.arange(n)[None, :]
    new_group = np.ones((W, n), dtype=bool)
    new_group[:, 1:] = X[:, 1:] != X[:, :-1]
    group_start = np.maximum.accumulate(np.where(new_group, idx, 0), axis=1)
    # Exclusive prefix-min of Y, then snapped back to each group's
    # start: the best y among points with strictly smaller x.
    prev_min = np.full((W, n), np.inf)
    if n > 1:
        prev_min[:, 1:] = np.minimum.accumulate(Y, axis=1)[:, :-1]
    best_before = np.take_along_axis(prev_min, group_start, axis=1)
    y_head = np.take_along_axis(Y, group_start, axis=1)
    dominated = (best_before <= Y) | ((idx > group_start) & (Y > y_head)) | ~F
    mask = np.zeros((W, n), dtype=bool)
    np.put_along_axis(mask, order, ~dominated, axis=1)
    return mask


def pareto_mask_batched(points, chunk: int | None = None) -> np.ndarray:
    """(W, n, d) -> (W, n) bool: per-workload Pareto frontiers in one
    vectorized pass (all objectives minimized).

    Rows with any non-finite entry are never on a frontier and never
    dominate (they are lifted to +inf, and +inf <= finite is False).
    d == 2 takes the O(n log n) sort sweep; the general case is the
    chunked O(n^2) dominance scan with the workload axis batched in,
    ``chunk`` bounding the (W, chunk, n) block size.
    """
    pts = np.asarray(points, dtype=np.float64)
    if pts.ndim != 3:
        raise ValueError(f"points must be (W, n, d), got shape {pts.shape}")
    W, n, d = pts.shape
    if n == 0:
        return np.zeros((W, 0), dtype=bool)
    if d == 2:
        return _pareto_mask_2obj(pts)
    finite = np.isfinite(pts).all(axis=-1)
    q = np.where(finite[..., None], pts, np.inf)
    if chunk is None:
        chunk = 2048
    b = max(1, min(chunk, _AUTO_STREAM_CELLS // max(W * n, 1) + 1))
    dominated = np.zeros((W, n), dtype=bool)
    for lo in range(0, n, b):
        hi = min(lo + b, n)
        blk = q[:, lo:hi, None, :]  # (W, b, 1, d)
        allq = q[:, None, :, :]  # (W, 1, n, d)
        dom = (allq <= blk).all(-1) & (allq < blk).any(-1)  # (W, b, n)
        dominated[:, lo:hi] = dom.any(-1)
    return finite & ~dominated


# ---------------------------------------------------------------------------
# Batched TPU-mesh strategy scoring (what core.advisor ranks with)
# ---------------------------------------------------------------------------

_BF16 = 2  # bytes
#: per-hop ICI latency. This is where the paper's (l-1) *serial* adder
#: term survives on a mesh: a ring collective over an axis of size l
#: costs ~2(l-1) latency hops regardless of payload, so the dOS total is
#: convex in l exactly like Eq. 2.
ICI_HOP_LATENCY_S = 1e-6

MESH_STRATEGIES = ("replicate", "shard_M", "shard_N", "shard_K")


def score_mesh_strategies(
    M,
    K,
    N,
    axis,
    bytes_per_el: int = _BF16,
    flops_per_s: float = C.TPU_PEAK_FLOPS_BF16,
    hbm_bw: float = C.TPU_HBM_BW,
    ici_bw: float = C.TPU_ICI_BW_PER_LINK,
    mxu_tile: int = 128,
):
    """Batched advisor scoring: cost every GEMM x every mesh strategy.

    Vectorized over broadcastable ``M, K, N, axis``. Returns a dict
    ``{strategy: {'compute_s', 'memory_s', 'collective_s', 'total_s'}}``
    of float64 arrays. The compute term includes the paper's
    fill/quantization effect: a per-device output tile smaller than the
    MXU tile wastes the systolic array exactly like the paper's
    ceil(M/R)ceil(N/C) rounding — this is how N_macs > M*N re-emerges
    at chip level. ``core.advisor.score_strategies`` is the
    batch-of-one wrapper.
    """
    Mi, Ki, Ni, L = np.broadcast_arrays(
        *(np.asarray(x, dtype=np.int64) for x in (M, K, N, axis))
    )
    # Dimension products (M*N*K and friends) overflow int64 for very
    # large GEMMs; float64 keeps them finite like the old Python-int
    # scalar scoring did, and is exact below 2^53.
    M, K, N = (a.astype(np.float64) for a in (Mi, Ki, Ni))
    b = bytes_per_el

    def eff(m, n, k):
        um = -(-m // mxu_tile) * mxu_tile
        un = -(-n // mxu_tile) * mxu_tile
        uk = -(-k // 8) * 8
        return (m * n * k) / (um * un * uk)

    def compute_t(m, n, k):
        e = np.maximum(eff(m, n, k), 1e-6)
        return 2.0 * m * n * k / (flops_per_s * e) / 1.0

    def memory_t(m, n, k):
        return b * (m * k + k * n + m * n) / hbm_bw

    def ring_allreduce(nbytes):
        return 2.0 * (L - 1) / L * nbytes / ici_bw + 2 * (L - 1) * ICI_HOP_LATENCY_S

    def ring_allgather(nbytes_shard):
        return (L - 1) * nbytes_shard / ici_bw + (L - 1) * ICI_HOP_LATENCY_S

    zeros = np.zeros(np.broadcast_shapes(M.shape), dtype=np.float64)
    mL = (-(-Mi // L)).astype(np.float64)
    nL = (-(-Ni // L)).astype(np.float64)
    kL = (-(-Ki // L)).astype(np.float64)
    out = {
        "replicate": (compute_t(M, N, K), memory_t(M, N, K), zeros),
        "shard_M": (compute_t(mL, N, K), memory_t(mL, N, K), zeros),
        "shard_N": (
            compute_t(M, nL, K),
            memory_t(M, nL, K),
            ring_allgather(b * M * nL),
        ),
        "shard_K": (
            compute_t(M, N, kL),
            memory_t(M, N, kL),
            ring_allreduce(b * M * N),
        ),
    }
    return {
        name: {
            "compute_s": comp,
            "memory_s": mem,
            "collective_s": coll,
            # Compute and memory overlap on TPU; the collective is
            # serialized (paper-faithful: sequential adder pile).
            "total_s": np.maximum(comp, mem) + coll,
        }
        for name, (comp, mem, coll) in out.items()
    }
