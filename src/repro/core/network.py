"""Network-level lowering: model-zoo configs -> per-layer GEMM streams.

The paper evaluates 3D-vs-2D trade-offs on isolated GEMM layers
(Table I), but its architectural claims are about whole networks
running on one accelerator. This module closes that gap: it walks any
``ArchConfig`` from ``repro.configs`` and emits the complete per-layer
GEMM workload stream for a ``ShapeConfig`` — every weight GEMM the
network executes, with its multiplicity — so the batched evaluation
engine (``core.engine.schedule``) can reduce a whole network to
end-to-end cycles/energy/EDP under a thermal feasibility constraint.

Lowering conventions (documented per family in the ``_lower_*``
helpers):

- The stream describes ONE network execution: a full forward over the
  global batch for ``train``/``prefill`` shapes (per-sequence GEMMs
  with ``count`` multiplied by the batch), and one batched decode step
  (M = global_batch) for ``decode`` shapes.
- Only *matrix-multiply* work is lowered — exactly what Eqs. 1/2
  model: attention q/k/v/o projections, MLP up/gate/down, MoE routers
  + routed/shared experts (with expected routed token counts), SSM
  in/out projections and the depthwise conv as an im2col GEMM, and
  the logits/unembedding GEMM. Embedding lookups (gathers), softmax,
  norms and the SSM recurrence itself (outer-product state updates,
  K = 1 per step) are not GEMMs and are excluded. Attention
  score/value products (activation x activation) are likewise outside
  the paper's weight-GEMM model and excluded.
- Identical (M, K, N) GEMMs are merged with summed counts, so the
  stream stays compact (one entry per unique shape) while the engine
  weights totals by ``count``.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..config import ArchConfig, Mode, ShapeConfig

__all__ = [
    "LayerGemm",
    "WorkloadStream",
    "lower_network",
    "lower_zoo",
    "CONV_WIDTH",
]

#: depthwise-conv kernel taps lowered as the K dim of an im2col GEMM.
CONV_WIDTH = 4


@dataclasses.dataclass(frozen=True)
class LayerGemm:
    """One GEMM shape in a network stream with its multiplicity."""

    name: str
    M: int
    K: int
    N: int
    #: how many times this GEMM runs in one network execution
    count: int

    @property
    def macs(self) -> int:
        return self.M * self.K * self.N

    @property
    def total_macs(self) -> int:
        return self.macs * self.count


@dataclasses.dataclass(frozen=True)
class WorkloadStream:
    """The full per-layer GEMM stream of one (arch, shape) cell.

    ``workloads`` / ``counts`` are the arrays ``core.engine.schedule``
    consumes; ``gemms`` keeps the named per-entry breakdown for
    reports. Entries are unique (M, K, N) shapes (merged on lowering).
    ``layer_names`` aligns with ``workloads`` rows — reports that
    attach per-layer decisions (e.g. the schedule's ``tier_fold``
    fold-per-layer assignment) key on it.
    """

    arch: str
    shape: str
    mode: Mode
    gemms: tuple[LayerGemm, ...]

    @property
    def workloads(self) -> np.ndarray:
        """(n, 3) int64 of unique (M, K, N) rows."""
        return np.array([[g.M, g.K, g.N] for g in self.gemms], dtype=np.int64)

    @property
    def counts(self) -> np.ndarray:
        """(n,) int64 multiplicity per unique GEMM."""
        return np.array([g.count for g in self.gemms], dtype=np.int64)

    @property
    def layer_names(self) -> tuple[str, ...]:
        """Per-entry names, aligned with ``workloads`` / ``counts``."""
        return tuple(g.name for g in self.gemms)

    @property
    def total_macs(self) -> int:
        return int(sum(g.total_macs for g in self.gemms))

    @property
    def n_gemm_invocations(self) -> int:
        return int(self.counts.sum())

    def compulsory_bytes(self, bytes_in: int = 1, bytes_acc: int = 2) -> int:
        """Count-weighted compulsory DRAM traffic [bytes] of one run.

        Each GEMM reads A (M*K) and B (K*N) once at ``bytes_in`` and
        writes its output (M*N) once at ``bytes_acc`` — the floor no
        SRAM capacity can beat; the engine's bandwidth model
        (``core.bandwidth``) converges to exactly this with unbounded
        per-tier SRAM.
        """
        return int(
            sum(
                g.count * ((g.M * g.K + g.K * g.N) * bytes_in
                           + g.M * g.N * bytes_acc)
                for g in self.gemms
            )
        )

    def arithmetic_intensity(self, bytes_in: int = 1, bytes_acc: int = 2) -> float:
        """MAC-ops per compulsory DRAM byte [ops/byte].

        The stream-level roofline knee: against a DRAM interface of
        ``B`` bytes/cycle, streams below ``B`` ops/byte per MAC are
        memory-bound even with perfect reuse — decode streams sit far
        below train/prefill ones (the bandwidth model's headline
        effect on the model zoo).
        """
        b = self.compulsory_bytes(bytes_in, bytes_acc)
        return self.total_macs / b if b else float("nan")


def _merge(arch: str, shape: str, mode: Mode, items) -> WorkloadStream:
    """Merge identical (M, K, N) shapes, keeping the first name."""
    by_shape: dict[tuple[int, int, int], list] = {}
    order: list[tuple[int, int, int]] = []
    for g in items:
        if g.count <= 0 or min(g.M, g.K, g.N) <= 0:
            continue
        key = (g.M, g.K, g.N)
        if key not in by_shape:
            by_shape[key] = [g.name, 0]
            order.append(key)
        by_shape[key][1] += g.count
    gemms = tuple(
        LayerGemm(name=by_shape[k][0], M=k[0], K=k[1], N=k[2], count=by_shape[k][1])
        for k in order
    )
    if not gemms:
        raise ValueError(f"{arch}/{shape}: lowering produced an empty stream")
    return WorkloadStream(arch=arch, shape=shape, mode=mode, gemms=gemms)


def _tokens(shape: ShapeConfig) -> tuple[int, int]:
    """(M dim per GEMM, per-network count multiplier) for the mode.

    train/prefill: the array streams one sequence at a time (M =
    seq_len); the global batch multiplies every count. decode: one
    batched decode step (M = global_batch) — the paper's small-M
    regime where the 3D/2D trade-off inverts.
    """
    if shape.mode == "decode":
        return shape.global_batch, 1
    return shape.seq_len, shape.global_batch


def _attention(cfg: ArchConfig, t: int, n_layers: int, prefix: str = ""):
    """q/k/v/o projection GEMMs for ``n_layers`` attention layers."""
    d, hd = cfg.d_model, cfg.head_dim_
    q_out = cfg.n_heads * hd
    kv_out = cfg.n_kv_heads * hd
    return [
        LayerGemm(f"{prefix}attn.q", t, d, q_out, n_layers),
        LayerGemm(f"{prefix}attn.kv", t, d, kv_out, 2 * n_layers),
        LayerGemm(f"{prefix}attn.o", t, q_out, d, n_layers),
    ]


def _mlp(cfg: ArchConfig, t: int, n_layers: int, d_ff: int | None = None,
         prefix: str = ""):
    """MLP GEMMs: gated (silu -> gate+up+down) or classic (up+down)."""
    d = cfg.d_model
    ff = cfg.d_ff if d_ff is None else d_ff
    if ff <= 0 or n_layers <= 0:
        return []
    n_in = 2 * n_layers if cfg.act == "silu" else n_layers
    return [
        LayerGemm(f"{prefix}mlp.in", t, d, ff, n_in),
        LayerGemm(f"{prefix}mlp.out", t, ff, d, n_layers),
    ]


def _logits(cfg: ArchConfig, t: int):
    return [LayerGemm("logits", t, cfg.d_model, cfg.vocab, 1)]


def _lower_dense(cfg: ArchConfig, t: int):
    return (
        _attention(cfg, t, cfg.n_layers)
        + _mlp(cfg, t, cfg.n_layers)
        + _logits(cfg, t)
    )


def _lower_moe(cfg: ArchConfig, t: int):
    """MoE: attention as dense; FFN = router + routed + shared experts.

    Routed expert GEMMs use the *expected* per-expert token count under
    uniform top-k routing, ceil(t * top_k / n_experts) — the quantity
    the paper's M dim sees per expert array pass.
    """
    d = cfg.d_model
    routed_t = max(1, -(-t * cfg.top_k // cfg.n_experts))
    ff = cfg.expert_d_ff
    out = _attention(cfg, t, cfg.n_layers)
    out.append(LayerGemm("moe.router", t, d, cfg.n_experts, cfg.n_layers))
    n_in = 2 if cfg.act == "silu" else 1
    out += [
        LayerGemm("moe.expert.in", routed_t, d, ff,
                  n_in * cfg.n_experts * cfg.n_layers),
        LayerGemm("moe.expert.out", routed_t, ff, d,
                  cfg.n_experts * cfg.n_layers),
    ]
    if cfg.n_shared_experts:
        out += [
            LayerGemm("moe.shared.in", t, d, ff,
                      n_in * cfg.n_shared_experts * cfg.n_layers),
            LayerGemm("moe.shared.out", t, ff, d,
                      cfg.n_shared_experts * cfg.n_layers),
        ]
    return out + _logits(cfg, t)


def _mamba_block(cfg: ArchConfig, t: int, n_layers: int):
    """Mamba2-style block: in_proj, depthwise conv (im2col), out_proj.

    The selective-scan recurrence itself is an outer-product state
    update (K = 1 per step) — not a GEMM — and is excluded; the paper's
    runtime model has nothing to say about it.
    """
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    n_ssm_heads = max(1, d_in // cfg.ssm_head_dim)
    in_out = 2 * d_in + 2 * cfg.ssm_state + n_ssm_heads
    return [
        LayerGemm("ssm.in_proj", t, d, in_out, n_layers),
        # depthwise conv1d over the x/B/C streams as one im2col GEMM:
        # K = kernel taps, N = conv channels.
        LayerGemm("ssm.conv", t, CONV_WIDTH, d_in + 2 * cfg.ssm_state, n_layers),
        LayerGemm("ssm.out_proj", t, d_in, d, n_layers),
    ]


def _lower_ssm(cfg: ArchConfig, t: int):
    """SSM family: xLSTM-style blocks (q/k/v/o projections around the
    matrix-memory recurrence) when ``slstm_at``/``d_ff == 0`` says so,
    otherwise pure Mamba blocks."""
    if cfg.d_ff == 0:
        # xLSTM: 4 d x d projections per block (q/k/v + out); the
        # mLSTM recurrence is outer-product (K = 1), not lowered.
        d = cfg.d_model
        out = [
            LayerGemm("xlstm.qkv", t, d, d, 3 * cfg.n_layers),
            LayerGemm("xlstm.out", t, d, d, cfg.n_layers),
        ]
        return out + _logits(cfg, t)
    return _mamba_block(cfg, t, cfg.n_layers) + _logits(cfg, t)


def _lower_hybrid(cfg: ArchConfig, t: int):
    """Hybrid (zamba2): Mamba backbone + the weight-shared attention
    block applied after every ``attn_every``-th layer."""
    out = _mamba_block(cfg, t, cfg.n_layers)
    n_attn = cfg.n_layers // cfg.attn_every if cfg.attn_every else 0
    if n_attn:
        out += _attention(cfg, t, n_attn, prefix="shared.")
        out += _mlp(cfg, t, n_attn, prefix="shared.")
    return out + _logits(cfg, t)


def _lower_encdec(cfg: ArchConfig, t: int, mode: Mode):
    """Encoder-decoder (whisper): encoder runs only when new frames are
    ingested (train/prefill); decode steps reuse the encoder output and
    the cross-attention k/v cache."""
    out = []
    if mode != "decode":
        et = cfg.enc_seq
        out += _attention(cfg, et, cfg.n_enc_layers, prefix="enc.")
        out += _mlp(cfg, et, cfg.n_enc_layers, prefix="enc.")
        # cross-attention k/v over encoder states, computed once
        kv_out = cfg.n_kv_heads * cfg.head_dim_
        out.append(
            LayerGemm("dec.cross.kv", et, cfg.d_model, kv_out, 2 * cfg.n_layers)
        )
    out += _attention(cfg, t, cfg.n_layers, prefix="dec.")
    # cross-attention q and o per decoder layer
    q_out = cfg.n_heads * cfg.head_dim_
    out += [
        LayerGemm("dec.cross.q", t, cfg.d_model, q_out, cfg.n_layers),
        LayerGemm("dec.cross.o", t, q_out, cfg.d_model, cfg.n_layers),
    ]
    out += _mlp(cfg, t, cfg.n_layers, prefix="dec.")
    return out + _logits(cfg, t)


def _lower_vlm(cfg: ArchConfig, t: int, mode: Mode):
    """VLM (llama-3.2-vision): dense self-attention layers plus
    cross-attention layers over precomputed image-patch embeddings.
    Image k/v are cached after prefill, so decode skips them."""
    n_cross = cfg.n_layers // cfg.cross_every if cfg.cross_every else 0
    n_self = cfg.n_layers - n_cross
    out = _attention(cfg, t, n_self)
    out += _mlp(cfg, t, cfg.n_layers)
    q_out = cfg.n_heads * cfg.head_dim_
    kv_out = cfg.n_kv_heads * cfg.head_dim_
    out += [
        LayerGemm("cross.q", t, cfg.d_model, q_out, n_cross),
        LayerGemm("cross.o", t, q_out, cfg.d_model, n_cross),
    ]
    if mode != "decode" and n_cross:
        out.append(
            LayerGemm("cross.kv", cfg.n_image_tokens, cfg.d_model, kv_out,
                      2 * n_cross)
        )
    return out + _logits(cfg, t)


_LOWERERS = {
    "dense": lambda cfg, t, mode: _lower_dense(cfg, t),
    "moe": lambda cfg, t, mode: _lower_moe(cfg, t),
    "ssm": lambda cfg, t, mode: _lower_ssm(cfg, t),
    "hybrid": lambda cfg, t, mode: _lower_hybrid(cfg, t),
    "encdec": _lower_encdec,
    "vlm": _lower_vlm,
}


def lower_network(cfg: ArchConfig, shape: ShapeConfig) -> WorkloadStream:
    """Lower one (arch, shape) cell to its GEMM workload stream."""
    if cfg.family not in _LOWERERS:
        raise ValueError(f"no lowerer for family {cfg.family!r} ({cfg.name})")
    t, mult = _tokens(shape)
    items = _LOWERERS[cfg.family](cfg, t, shape.mode)
    items = [dataclasses.replace(g, count=g.count * mult) for g in items]
    return _merge(cfg.name, shape.name, shape.mode, items)


def lower_zoo(shapes=None, archs=None) -> list[WorkloadStream]:
    """Lower every live (arch, shape) cell of the registry.

    ``shapes``/``archs`` filter by name; the arch-applicability rules
    of ``repro.configs.cells`` apply (no full attention at 500k)."""
    from ..configs import REGISTRY, SHAPES, cells

    live, _ = cells()
    out = []
    for arch_name, shape_name in live:
        if shapes is not None and shape_name not in shapes:
            continue
        if archs is not None and arch_name not in archs:
            continue
        out.append(lower_network(REGISTRY[arch_name], SHAPES[shape_name]))
    return out
