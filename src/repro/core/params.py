"""Shared option validation for the DSE stack.

Every layer of the stack — ``DesignGrid``, ``evaluate``, ``schedule``,
the declarative ``study`` specs and the CLI — accepts the same small
string vocabularies (dataflow, vertical-interconnect tech, metric
groups, search backends, shape-search modes). Before this module each
consumer either re-validated its own subset or let an invalid string
die deep in the PPA tables with a bare ``KeyError``/silent miv
fallback. This is the one place those vocabularies live; everything
else calls ``validate_option``/``validate_options`` at its API
boundary and fails fast with the full list of valid choices.
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "VALID_BACKENDS",
    "VALID_DATAFLOWS",
    "VALID_FOLDS",
    "VALID_LENGTH_DISTS",
    "VALID_METRICS",
    "VALID_MODES",
    "VALID_OBJECTIVES",
    "VALID_SCHEDULE_POLICIES",
    "VALID_SERVE_MAPPINGS",
    "VALID_SERVE_POLICIES",
    "VALID_TECHS",
    "VALID_THERMAL_MODES",
    "validate_option",
    "validate_options",
]

#: 'os' is dOS at the l = 1 formulaic limit (see DesignGrid docs).
VALID_DATAFLOWS = ("os", "dos", "ws", "is")
#: vertical-interconnect technology ('2d' = no stacking).
VALID_TECHS = ("2d", "tsv", "miv")
#: result groups of ``engine.evaluate`` (thermal implies power implies
#: area — the implication is applied by ``evaluate``, not here).
VALID_METRICS = ("perf", "area", "power", "thermal")
#: search backends of the batched (R, C) kernel.
VALID_BACKENDS = ("numpy", "jax")
#: shape-search modes: full rectangular search vs square arrays.
VALID_MODES = ("opt", "square")
#: thermal analysis modes: 'steady' gates on the worst-case lumped
#: steady state at a fixed clock; 'transient' time-steps the same RC
#: stack under a DVFS governor and gates on the governed excursion.
VALID_THERMAL_MODES = ("steady", "transient")
#: per-layer tier folds: which GEMM dimension a stack of L tiers
#: partitions. Every dataflow has a *native* fold (its paper tier
#: split: 'k' for os/dos, 'm' for ws, 'n' for is) plus two non-native
#: folds priced by ``bandwidth.fold_traffic_batched``.
VALID_FOLDS = ("m", "k", "n")
#: scheduling policies of ``engine.schedule``: 'per_layer' re-shapes
#: the array per layer, 'fixed' commits one array for the stream,
#: 'tier_fold' commits one array but picks the best per-layer tier
#: fold (m/k/n) on it.
VALID_SCHEDULE_POLICIES = ("per_layer", "fixed", "tier_fold")
#: serving step-mapping: 'native' prices each step under the
#: dataflow's paper tier split; 'tier_fold' prices all folds and takes
#: the per-step elementwise best.
VALID_SERVE_MAPPINGS = ("native", "tier_fold")
#: serving batch policies (``core.serve.TrafficSpec``): 'continuous'
#: admits into free slots every step, 'static' drains each batch fully
#: before admitting the next.
VALID_SERVE_POLICIES = ("continuous", "static")
#: request length distributions of the serving traffic sampler.
VALID_LENGTH_DISTS = ("fixed", "uniform", "lognormal")
#: minimizable ``EvalResult`` metric columns (Pareto objectives).
#: ``stall_cycles`` is populated only by bandwidth-aware runs.
VALID_OBJECTIVES = (
    "cycles",
    "cycles_2d",
    "utilization",
    "mac_act",
    "hlink_act",
    "vlink_act",
    "area_um2",
    "footprint_um2",
    "power_w",
    "peak_power_w",
    "static_power_w",
    "dynamic_power_w",
    "energy_j",
    "edp_js",
    "t_max_c",
    "stall_cycles",
)


def validate_option(name: str, value, valid) -> str:
    """Check one scalar option; raise ValueError listing valid choices."""
    if isinstance(value, np.str_):
        value = str(value)
    if not isinstance(value, str) or value not in valid:
        raise ValueError(
            f"invalid {name} {value!r}; valid options: "
            + ", ".join(repr(v) for v in valid)
        )
    return value


def validate_options(name: str, value, valid):
    """Check a scalar-or-array option (e.g. a per-point ``tech`` array).

    Returns ``value`` unchanged so call sites can validate inline.
    """
    if isinstance(value, (str, np.str_)):
        validate_option(name, value, valid)
        return value
    arr = np.asarray(value)
    for v in np.unique(arr):
        validate_option(name, v, valid)
    return value
