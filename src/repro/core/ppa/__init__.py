"""Power / area / thermal models for 2D and 3D systolic arrays."""

from . import constants
from .area import AreaReport, area_normalized_speedup, array_area_um2
from .power import PowerReport, array_power, table2_setup
from .thermal import ThermalReport, thermal_report

__all__ = [
    "constants",
    "AreaReport",
    "area_normalized_speedup",
    "array_area_um2",
    "PowerReport",
    "array_power",
    "table2_setup",
    "ThermalReport",
    "thermal_report",
]
