"""Power / area / thermal models for 2D and 3D systolic arrays.

Every model has a batched entry point (``*_batched`` /
``lumped_tier_temps``) that evaluates whole design grids in one
vectorized pass — this is what ``core.engine`` calls — plus scalar
report wrappers for interactive use.
"""

from . import constants
from .area import (
    AreaReport,
    area_normalized_speedup,
    array_area_um2,
    array_area_um2_batched,
)
from .power import PowerReport, array_power, array_power_batched, table2_setup
from .thermal import ThermalReport, lumped_tier_temps, thermal_report

__all__ = [
    "constants",
    "AreaReport",
    "area_normalized_speedup",
    "array_area_um2",
    "array_area_um2_batched",
    "PowerReport",
    "array_power",
    "array_power_batched",
    "table2_setup",
    "ThermalReport",
    "lumped_tier_temps",
    "thermal_report",
]
