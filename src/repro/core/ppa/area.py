"""Area model and area-normalized performance (paper Sec. IV-D, Fig. 9).

2D: every MAC occupies A_MAC. 3D-TSV: each MAC additionally hosts a
dedicated vertical-link array (the paper deliberately over-provisions a
TSV array between every vertically adjacent MAC pair as a worst case);
TSVs carry a keep-out-zone, MIVs are ~3 orders of magnitude smaller
("monolithic integration only adds a few percent").

Fig. 9 plots runtime-per-total-silicon-area of the 3D array normalized
to the 2D array: ratio = speedup(l) / (1 + vlink_overhead(l)), where
the overhead scales with (l-1)/l (the bottom tier has no downward
links).

All entry points are batched (arrays broadcast); the scalar
``array_area_um2`` / ``area_normalized_speedup`` wrappers are the
batch-of-one special cases kept for interactive use.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..analytical import optimize_array_2d, optimize_array_3d
from . import constants as C

__all__ = [
    "AreaReport",
    "array_area_um2",
    "array_area_um2_batched",
    "area_normalized_speedup",
]


@dataclasses.dataclass(frozen=True)
class AreaReport:
    tech: str
    total_um2: float  # total silicon area (sum over tiers)
    footprint_um2: float  # per-tier footprint (the stacked outline)
    vlink_overhead: float  # vertical-link area / MAC area (per affected MAC)


def array_area_um2_batched(n_macs_total, tiers, tech):
    """Batched area model. ``tech`` is a str or array of '2d'|'tsv'|'miv'.

    Returns ``(total_um2, footprint_um2, vlink_overhead)`` float64
    arrays of the broadcast shape. Matches the scalar model exactly:
    the bottom tier carries no downward vias, so the per-MAC vertical
    overhead scales with (tiers-1)/tiers. '2d' entries add no via area
    but still split ``n_macs_total`` per tier when ``tiers`` > 1 (like
    the scalar model; query 2D dies with ``tiers == 1``).
    """
    n_macs_total, tiers = np.broadcast_arrays(
        *(np.asarray(x, dtype=np.int64) for x in (n_macs_total, tiers))
    )
    tech = np.broadcast_to(np.asarray(tech), n_macs_total.shape)
    per_tier = np.where(tiers > 1, n_macs_total // np.maximum(tiers, 1), n_macs_total)
    a_per_via = np.where(tech == "tsv", C.A_TSV_UM2, C.A_MIV_UM2)
    a_v = np.where(tech == "2d", 0.0, C.VLINK_BITS * a_per_via)
    frac = (tiers - 1) / np.maximum(tiers, 1)
    overhead = a_v * frac / C.A_MAC_UM2
    footprint = per_tier * (C.A_MAC_UM2 + a_v * frac)
    total = np.where(tech == "2d", footprint, footprint * tiers)
    return total, footprint, overhead


def array_area_um2(n_macs_total: int, tiers: int, tech: str) -> AreaReport:
    """Scalar wrapper over ``array_area_um2_batched`` (batch of one)."""
    total, footprint, overhead = array_area_um2_batched(
        np.array([n_macs_total]), np.array([tiers]), np.array([tech])
    )
    return AreaReport(tech, float(total[0]), float(footprint[0]), float(overhead[0]))


def area_normalized_speedup(M, K, N, n_macs, tiers, tech, mode="opt") -> float:
    """Fig. 9's y-axis: (perf/area of 3D) / (perf/area of 2D).

    Both chips are charged their full *provisioned* silicon area (the
    manufactured array), even when the optimizer maps the workload onto
    a sub-array — matching the paper's fixed-MAC-budget comparison.
    """
    t2 = optimize_array_2d(M, K, N, n_macs, mode)
    t3 = optimize_array_3d(M, K, N, n_macs, tiers, mode)
    a2 = array_area_um2(int(n_macs), 1, "2d").total_um2
    a3 = array_area_um2((int(n_macs) // tiers) * tiers, tiers, tech).total_um2
    return float((t2.cycles / t3.cycles) * (a2 / a3))
