"""Area model and area-normalized performance (paper Sec. IV-D, Fig. 9).

2D: every MAC occupies A_MAC. 3D-TSV: each MAC additionally hosts a
dedicated vertical-link array (the paper deliberately over-provisions a
TSV array between every vertically adjacent MAC pair as a worst case);
TSVs carry a keep-out-zone, MIVs are ~3 orders of magnitude smaller
("monolithic integration only adds a few percent").

Fig. 9 plots runtime-per-total-silicon-area of the 3D array normalized
to the 2D array: ratio = speedup(l) / (1 + vlink_overhead(l)), where
the overhead scales with (l-1)/l (the bottom tier has no downward
links).
"""

from __future__ import annotations

import dataclasses

from ..analytical import optimize_array_2d, optimize_array_3d
from . import constants as C

__all__ = ["AreaReport", "array_area_um2", "area_normalized_speedup"]


@dataclasses.dataclass(frozen=True)
class AreaReport:
    tech: str
    total_um2: float  # total silicon area (sum over tiers)
    footprint_um2: float  # per-tier footprint (the stacked outline)
    vlink_overhead: float  # vertical-link area / MAC area (per affected MAC)


def array_area_um2(n_macs_total: int, tiers: int, tech: str) -> AreaReport:
    per_tier = n_macs_total // tiers if tiers > 1 else n_macs_total
    if tech == "2d":
        a = per_tier * C.A_MAC_UM2
        return AreaReport("2d", a, a, 0.0)
    a_v = C.VLINK_BITS * (C.A_TSV_UM2 if tech == "tsv" else C.A_MIV_UM2)
    frac = (tiers - 1) / tiers  # bottom tier carries no downward vias
    per_mac = C.A_MAC_UM2 + a_v * frac
    footprint = per_tier * per_mac
    return AreaReport(tech, footprint * tiers, footprint, a_v * frac / C.A_MAC_UM2)


def area_normalized_speedup(M, K, N, n_macs, tiers, tech, mode="opt") -> float:
    """Fig. 9's y-axis: (perf/area of 3D) / (perf/area of 2D).

    Both chips are charged their full *provisioned* silicon area (the
    manufactured array), even when the optimizer maps the workload onto
    a sub-array — matching the paper's fixed-MAC-budget comparison.
    """
    t2 = optimize_array_2d(M, K, N, n_macs, mode)
    t3 = optimize_array_3d(M, K, N, n_macs, tiers, mode)
    a2 = array_area_um2(int(n_macs), 1, "2d").total_um2
    a3 = array_area_um2((int(n_macs) // tiers) * tiers, tiers, tech).total_um2
    return float((t2.cycles / t3.cycles) * (a2 / a3))
