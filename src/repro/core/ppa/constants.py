"""Physical constants for the PPA models (15 nm nangate node, 1 GHz).

Literature-sourced constants come straight from the paper's references:
TSV capacitance ~10 fF [20], MIV capacitance ~0.2 fF [21]. The remaining
constants are *calibrated* so the structural power/area/thermal models
reproduce the paper's reported numbers (Table II watts, Fig. 9
crossovers, Fig. 8 trends); every calibrated value is annotated with its
target and stays within physically plausible ranges for a 15 nm node.

Calibration procedure (reproducible): solve the linear system formed by
Table II's three average-power rows for (P_CLK_LEAK_PER_MAC,
P_WIRE_PER_MAC_UM, ALPHA_V) given first-principles dynamic terms; then
fit E_MAC_PEAK to the three peak-power rows. See
``benchmarks/tab2_power.py`` for the closed loop.
"""

from __future__ import annotations

# --- Technology / operating point -----------------------------------------
VDD = 0.8  # V
FREQ_HZ = 1.0e9  # paper: 1 GHz clock
THERMAL_BUDGET_C = 105.0  # junction limit used for "not thermally limited"

# --- Vertical interconnect (paper-sourced) ---------------------------------
C_TSV_F = 10e-15  # [20] ~10 fF per TSV
C_MIV_F = 0.2e-15  # [21] ~0.2 fF per MIV
VLINK_BITS = 17  # 16b partial-sum bus + accumulate-control per MAC pile

# --- Area (calibrated to Fig. 9 bands; plausible 15 nm values) -------------
A_MAC_UM2 = 400.0  # 8b x 8b MAC + 16b acc + pipeline regs
A_TSV_UM2 = 30.0  # TSV + keep-out-zone, per via ([20]-scale)
A_MIV_UM2 = 0.05  # per MIV ([22]-scale); "few percent overhead"

# --- Power (calibrated to Table II; see module docstring) -------------------
# Per-MAC clock-tree + leakage power. 81 uW/MAC ~ a few dozen FFs at 1 GHz.
P_CLK_LEAK_PER_MAC_W = 8.088264759124456e-05
# Die-size-dependent wiring overhead (clock spine / distribution): grows
# with die side. This is the term that makes the monolithic-footprint 2D
# die (4.44 mm side) burn more than a 2.56 mm 3D tier - the physical
# mechanism behind Table II's "3D draws slightly less".
P_WIRE_PER_MAC_PER_UM_W = 9.256300411858144e-09
# Average dynamic energy per useful MAC-op (operand regs included).
E_MAC_OP_J = 100e-15
# Energy per word-hop on an in-plane neighbour link (wire + register).
E_HOP_J = 5e-15
# Vertical-net switching activity (bit-level, per cycle). Calibrated to
# the TSV-MIV split of Table II. NOTE: ~40x larger than the idealized
# dOS accumulate-only activity (1/tau_fold); the paper's RTL evidently
# toggles vertical nets beyond the minimal dataflow requirement,
# consistent with its stated worst-case TSV over-provisioning.
ALPHA_V = 0.07441636322497748
# Peak (single-cycle) dynamic energy per MAC when the streaming path is
# fully active; fits Table II's peak rows within ~2%.
E_MAC_PEAK_J = 165e-15

# --- Thermal (calibrated to Fig. 8 trends) ----------------------------------
K_SI_W_MK = 130.0  # silicon lateral conductivity
T_TIER_SI_UM = 20.0  # thinned tier silicon thickness (3D)
T_2D_SI_UM = 300.0  # full-thickness 2D die
T_ILD_UM = 1.0  # inter-tier dielectric thickness
K_ILD_W_MK = 1.4  # SiO2-ish
K_CU_W_MK = 400.0  # copper (TSV fill)
# Heatsink: package + spreader resistance from the die face to ambient,
# normalized per mm^2 of die area.
R_HEATSINK_KMM2_W = 40.0
T_AMBIENT_C = 45.0  # in-server ambient at the package
# Volumetric heat capacity of silicon — gives each tier a thermal mass
# (footprint x silicon thickness) for the transient RC stepping.
C_SI_J_M3K = 1.63e6  # J/(m^3 K)
# Lateral spreading from die edges into the package substrate. Smaller
# dies have a higher perimeter/area ratio, so they shed relatively more
# heat sideways — this produces the paper's "hotter with more MACs"
# trend (Fig. 8).
G_EDGE_PER_MM_W_K = 0.02

# --- Roofline hardware model (TPU v5e target) --------------------------------
TPU_PEAK_FLOPS_BF16 = 197e12  # per chip
TPU_HBM_BW = 819e9  # bytes/s per chip
TPU_ICI_BW_PER_LINK = 50e9  # bytes/s per link
