"""Dynamic power model for 2D / 3D-TSV / 3D-MIV systolic arrays.

Reproduces the paper's Table II (Sec. IV-B). The paper's central
observation is that a *static* analysis is insufficient: the dOS
dataflow drives horizontal links hard while vertical (TSV/MIV) links
only carry partial-sum accumulation, so the two link classes have very
different switching activities. This model therefore builds power from
per-component switched energies x activity rates derived from the
dataflow (``core.dataflow.dos_activity``):

    P = P_clk+leak(n_macs)                 (clocked every cycle)
      + P_wire(n_macs, die_side)           (die-size-dependent overhead)
      + P_mac_dyn(useful MAC-op rate)
      + P_hlink(in-plane word-hop rate)
      + P_vlink(vertical net activity; C_TSV vs C_MIV)

Peak power adds the fully-active streaming path on top of the idle
baseline (paper reports PrimeTime peak).
"""

from __future__ import annotations

import dataclasses
import math

from ..dataflow import dos_activity
from . import constants as C

__all__ = ["PowerReport", "array_power", "table2_setup"]


@dataclasses.dataclass(frozen=True)
class PowerReport:
    tech: str  # '2d' | 'tsv' | 'miv'
    total_w: float
    peak_w: float
    components: dict
    runtime_cycles: float


def _die_side_um(n_macs_per_tier: int, tech: str) -> float:
    # Active-wiring extent only: TSV keep-out zones enlarge the die but
    # carry no clocked wiring, so they do not add to the clock spine.
    del tech
    return math.sqrt(n_macs_per_tier * C.A_MAC_UM2)


def array_power(
    M: int,
    K: int,
    N: int,
    rows: int,
    cols: int,
    tiers: int,
    tech: str,
) -> PowerReport:
    """Average + peak power of an array running the (M,K,N) GEMM.

    ``rows, cols`` are per-tier dimensions; ``tech`` selects the
    vertical-interconnect technology ('2d' forces tiers == 1).
    """
    if tech == "2d":
        assert tiers == 1, "2D array cannot have tiers"
    act = dos_activity(M, K, N, rows, cols, tiers)
    n_per_tier = rows * cols
    n_total = n_per_tier * tiers
    t_s = act.cycles / C.FREQ_HZ

    # Baseline: clock tree + leakage on every MAC + die-size wiring term.
    side = _die_side_um(n_per_tier, tech)
    p_base = n_total * (C.P_CLK_LEAK_PER_MAC_W + C.P_WIRE_PER_MAC_PER_UM_W * side)

    # Useful compute.
    p_mac = act.mac_ops_total * C.E_MAC_OP_J / t_s

    # In-plane streaming: operands traverse the *full* array width/height
    # (systolic shifting does not stop at the useful region) - this is
    # the 2D array's hidden cost when R,C exceed the active M,N tile.
    kl = -(-K // tiers)
    a_hops = min(M, rows) * kl * cols * (-(-M // rows)) * (-(-N // cols)) * tiers
    b_hops = kl * min(N, cols) * rows * (-(-M // rows)) * (-(-N // cols)) * tiers
    p_hop = (a_hops + b_hops) * C.E_HOP_J / t_s

    # Vertical nets (3D only): bit-level activity x per-bit cap energy.
    p_v = 0.0
    if tiers > 1:
        cap = C.C_TSV_F if tech == "tsv" else C.C_MIV_F
        n_vbits = n_per_tier * (tiers - 1) * C.VLINK_BITS
        e_bit = 0.5 * cap * C.VDD**2
        p_v = C.ALPHA_V * n_vbits * C.FREQ_HZ * e_bit

    total = p_base + p_mac + p_hop + p_v
    peak = total + n_total * C.E_MAC_PEAK_J * C.FREQ_HZ
    return PowerReport(
        tech=tech,
        total_w=total,
        peak_w=peak,
        components={
            "clk_leak_w": n_total * C.P_CLK_LEAK_PER_MAC_W,
            "die_wire_w": p_base - n_total * C.P_CLK_LEAK_PER_MAC_W,
            "mac_dyn_w": p_mac,
            "hlink_w": p_hop,
            "vlink_w": p_v,
        },
        runtime_cycles=act.cycles,
    )


def table2_setup():
    """The paper's Table II configurations: workload M,N=128, K=300;
    3D = 3 tiers x 16384 MACs (128x128); 2D = 49284 MACs (222x222)."""
    return {
        "2d": dict(M=128, K=300, N=128, rows=222, cols=222, tiers=1, tech="2d"),
        "tsv": dict(M=128, K=300, N=128, rows=128, cols=128, tiers=3, tech="tsv"),
        "miv": dict(M=128, K=300, N=128, rows=128, cols=128, tiers=3, tech="miv"),
    }
