"""Dynamic power model for 2D / 3D-TSV / 3D-MIV systolic arrays.

Reproduces the paper's Table II (Sec. IV-B). The paper's central
observation is that a *static* analysis is insufficient: the dOS
dataflow drives horizontal links hard while vertical (TSV/MIV) links
only carry partial-sum accumulation, so the two link classes have very
different switching activities. This model therefore builds power from
per-component switched energies x activity rates derived from the
dataflow (``core.dataflow.activity_batched``):

    P = P_clk+leak(n_macs)                 (clocked every cycle)
      + P_wire(n_macs, die_side)           (die-size-dependent overhead)
      + P_mac_dyn(useful MAC-op rate)
      + P_hlink(in-plane word-hop rate)
      + P_vlink(vertical net activity; C_TSV vs C_MIV)

Peak power adds the fully-active streaming path on top of the idle
baseline (paper reports PrimeTime peak).

``array_power_batched`` evaluates whole design grids at once (what the
engine calls); the scalar ``array_power`` wrapper is the batch-of-one
special case kept for interactive use and Table II.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..analytical import _ceil_div, native_fold
from ..dataflow import activity_batched
from . import constants as C

__all__ = ["PowerReport", "array_power", "array_power_batched", "table2_setup"]


@dataclasses.dataclass(frozen=True)
class PowerReport:
    tech: str  # '2d' | 'tsv' | 'miv'
    total_w: float
    peak_w: float
    components: dict
    runtime_cycles: float


def array_power_batched(M, K, N, rows, cols, tiers, tech, dataflow: str = "dos",
                        fold: str | None = None):
    """Batched power model: all arguments broadcast; ``tech`` is a str or
    array of '2d'|'tsv'|'miv'. Returns a dict of float64 arrays:

    ``total_w, peak_w, static_w, dynamic_w, clk_leak_w, die_wire_w,
    mac_dyn_w, hlink_w, vlink_w, cycles``.

    The in-plane hop count for OS/dOS charges the *full* array
    width/height (systolic shifting does not stop at the useful region)
    — the 2D array's hidden cost when R, C exceed the active M, N tile.
    WS/IS (no cross-tier traffic) are charged the operand-delivery hops
    from their activity model instead.

    ``fold`` (a non-native tier fold, see ``analytical.fold_dims``)
    reprices cycles and vertical activity through the folded activity
    model; non-native folds charge the generic operand-delivery hop
    model in-plane. ``None``/native is the existing model bit-for-bit.
    """
    M, K, N, R, Cc, L = np.broadcast_arrays(
        *(np.asarray(x, dtype=np.int64) for x in (M, K, N, rows, cols, tiers))
    )
    native = fold is None or fold == native_fold(dataflow)
    tech = np.broadcast_to(np.asarray(tech), M.shape)
    act = activity_batched(M, K, N, R, Cc, L, dataflow, fold=None if native else fold)
    n_per_tier = R * Cc
    n_total = n_per_tier * L
    t_s = act.cycles / C.FREQ_HZ

    # Baseline: clock tree + leakage on every MAC + die-size wiring term.
    # Active-wiring extent only: TSV keep-out zones enlarge the die but
    # carry no clocked wiring, so they do not add to the clock spine.
    side = np.sqrt(n_per_tier * C.A_MAC_UM2)
    p_base = n_total * (C.P_CLK_LEAK_PER_MAC_W + C.P_WIRE_PER_MAC_PER_UM_W * side)

    # Useful compute.
    p_mac = act.mac_ops_total * C.E_MAC_OP_J / t_s

    # In-plane streaming.
    if dataflow in ("os", "dos") and native:
        kl = _ceil_div(K, L)
        folds = _ceil_div(M, R) * _ceil_div(N, Cc)
        a_hops = np.minimum(M, R) * kl * Cc * folds * L
        b_hops = kl * np.minimum(N, Cc) * R * folds * L
        p_hop = (a_hops + b_hops) * C.E_HOP_J / t_s
    else:
        p_hop = act.hlink_hops_total * C.E_HOP_J / t_s

    # Vertical nets (3D only): bit-level activity x per-bit cap energy.
    cap = np.where(tech == "tsv", C.C_TSV_F, C.C_MIV_F)
    e_bit = 0.5 * cap * C.VDD**2
    n_vbits = n_per_tier * (L - 1) * C.VLINK_BITS
    p_v = np.where(
        (L > 1) & (tech != "2d") & (act.vlink_hops_total > 0),
        C.ALPHA_V * n_vbits * C.FREQ_HZ * e_bit,
        0.0,
    )

    total = p_base + p_mac + p_hop + p_v
    peak = total + n_total * C.E_MAC_PEAK_J * C.FREQ_HZ
    clk_leak = n_total * C.P_CLK_LEAK_PER_MAC_W
    return {
        "total_w": total,
        "peak_w": peak,
        "static_w": p_base,
        "dynamic_w": p_mac + p_hop + p_v,
        "clk_leak_w": clk_leak,
        "die_wire_w": p_base - clk_leak,
        "mac_dyn_w": p_mac,
        "hlink_w": p_hop,
        "vlink_w": p_v,
        "cycles": act.cycles,
    }


def array_power(
    M: int,
    K: int,
    N: int,
    rows: int,
    cols: int,
    tiers: int,
    tech: str,
) -> PowerReport:
    """Average + peak power of an array running the (M,K,N) GEMM.

    ``rows, cols`` are per-tier dimensions; ``tech`` selects the
    vertical-interconnect technology ('2d' forces tiers == 1). Scalar
    wrapper over ``array_power_batched`` (batch of one).
    """
    if tech == "2d":
        assert tiers == 1, "2D array cannot have tiers"
    r = array_power_batched(
        np.array([M]), np.array([K]), np.array([N]),
        np.array([rows]), np.array([cols]), np.array([tiers]), np.array([tech]),
    )
    return PowerReport(
        tech=tech,
        total_w=float(r["total_w"][0]),
        peak_w=float(r["peak_w"][0]),
        components={
            k: float(r[k][0])
            for k in ("clk_leak_w", "die_wire_w", "mac_dyn_w", "hlink_w", "vlink_w")
        },
        runtime_cycles=float(r["cycles"][0]),
    )


def table2_setup():
    """The paper's Table II configurations: workload M,N=128, K=300;
    3D = 3 tiers x 16384 MACs (128x128); 2D = 49284 MACs (222x222)."""
    return {
        "2d": dict(M=128, K=300, N=128, rows=222, cols=222, tiers=1, tech="2d"),
        "tsv": dict(M=128, K=300, N=128, rows=128, cols=128, tiers=3, tech="tsv"),
        "miv": dict(M=128, K=300, N=128, rows=128, cols=128, tiers=3, tech="miv"),
    }
