"""Thermal model of 2D / 3D stacked arrays (paper Sec. IV-C).

Our HotSpot-6.0 analogue: the die stack is discretized into a
(tiers x g x g) grid of thermal cells. Steady state solves

    sum_j G_ij (T_j - T_i) + q_i = 0        for every cell i,

with lateral silicon conduction within a tier, vertical conduction
between tiers (ILD + TSV copper in parallel for the TSV flavour), and a
package/heatsink path from the *bottom* tier to ambient (the paper
splits results into "bottom" = near heatsink and "middle" = the rest).

The sparse system is solved with damped Jacobi iterations inside
``jax.lax.while_loop`` - a pure-JAX stencil relaxation. The power map
comes from the power model: cells inside the active M x N region carry
dynamic power, every cell carries clock+leakage; border cells end up
cooler purely through conduction, reproducing the paper's observed
in-die variability.

Reproduced qualitative findings (Fig. 8): 3D hotter than 2D; hotter
with more MACs; MIV hotter than TSV (TSVs add area -> lower power
density -> better heat spreading); all within the thermal budget.

Besides the steady state, the batched lumped model also exposes a
*transient* form (``ThermalState`` + ``step_temps``): each tier gets a
thermal mass (footprint x silicon thickness x volumetric heat capacity
of Si) and the same conductance stack is time-stepped with backward
Euler, so the steady-state solution is the exact fixed point under
constant power. This is what the DVFS governor integrates against.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..params import VALID_TECHS, validate_option, validate_options
from .power import array_power
from . import constants as C

__all__ = [
    "ThermalReport",
    "ThermalState",
    "solve_stack",
    "step_temps",
    "thermal_report",
    "lumped_tier_temps",
]

_GRID = 24  # cells per die side


@dataclasses.dataclass(frozen=True)
class ThermalReport:
    tech: str
    macs_per_tier: int
    tiers: int
    t_max_c: float
    # five-number summaries (min, q1, median, q3, max) per region
    bottom: tuple
    middle: tuple | None
    within_budget: bool


def solve_stack(q_w, cell_area_mm2, tiers: int, tech: str):
    """Damped-Jacobi steady-state solve. q_w: (tiers, g, g) power map [W]."""
    validate_option("tech", tech, VALID_TECHS)
    tiers = int(tiers)
    if tiers < 1:
        raise ValueError(f"tiers must be >= 1, got {tiers}")
    return _solve_stack_jit(q_w, cell_area_mm2, tiers, tech)


@functools.partial(jax.jit, static_argnums=(2, 3))
def _solve_stack_jit(q_w, cell_area_mm2, tiers: int, tech: str):
    g = q_w.shape[-1]
    cell_side_m = jnp.sqrt(cell_area_mm2) * 1e-3

    t_si = (C.T_2D_SI_UM if tiers == 1 else C.T_TIER_SI_UM) * 1e-6
    # Lateral conductance between neighbouring cells (same tier).
    g_lat = C.K_SI_W_MK * t_si  # * (cell_side / cell_side)
    # Vertical conductance between stacked cells: ILD film + via metal.
    a_cell_m2 = cell_area_mm2 * 1e-6
    g_ild = C.K_ILD_W_MK * a_cell_m2 / (C.T_ILD_UM * 1e-6)
    if tech == "tsv":
        # TSV copper in parallel with the ILD. Per-cell via share
        # assumption: every thermal cell column carries the vertical
        # partial-sum bus of ~one MAC pile (VLINK_BITS vias), and a
        # quarter of each via's drawn area (A_TSV_UM2 includes the
        # keep-out zone) is conductive copper core. The lumped model
        # (lumped_tier_temps) charges the same share per MAC, so both
        # models see consistent vertical conductance densities.
        n_vias_cell = C.VLINK_BITS
        a_cu = n_vias_cell * (C.A_TSV_UM2 * 0.25) * 1e-12  # conductive core
        g_via = C.K_CU_W_MK * a_cu / (C.T_TIER_SI_UM * 1e-6)
        g_vert = g_ild + g_via
    else:
        g_vert = g_ild
    # Heatsink path from the bottom tier.
    g_sink = a_cell_m2 * 1e6 / C.R_HEATSINK_KMM2_W  # W/K per cell
    # Lateral edge spreading into the package (per boundary cell).
    g_edge = C.G_EDGE_PER_MM_W_K * (cell_side_m * 1e3)

    edge_mask = jnp.zeros((g, g))
    edge_mask = edge_mask.at[0, :].set(1.0).at[-1, :].set(1.0)
    edge_mask = edge_mask.at[:, 0].set(1.0).at[:, -1].set(1.0)

    def neighbor_sum(T):
        s = jnp.zeros_like(T)
        w = jnp.zeros_like(T)
        # lateral (4-neighbourhood)
        s = s.at[:, 1:, :].add(g_lat * T[:, :-1, :])
        w = w.at[:, 1:, :].add(g_lat)
        s = s.at[:, :-1, :].add(g_lat * T[:, 1:, :])
        w = w.at[:, :-1, :].add(g_lat)
        s = s.at[:, :, 1:].add(g_lat * T[:, :, :-1])
        w = w.at[:, :, 1:].add(g_lat)
        s = s.at[:, :, :-1].add(g_lat * T[:, :, 1:])
        w = w.at[:, :, :-1].add(g_lat)
        if tiers > 1:
            # vertical between tiers (tier 0 = bottom, near heatsink)
            s = s.at[1:].add(g_vert * T[:-1])
            w = w.at[1:].add(g_vert)
            s = s.at[:-1].add(g_vert * T[1:])
            w = w.at[:-1].add(g_vert)
        # heatsink from bottom tier
        s = s.at[0].add(g_sink * C.T_AMBIENT_C)
        w = w.at[0].add(g_sink)
        # edge spreading (every tier's boundary cells)
        s = s + g_edge * edge_mask * C.T_AMBIENT_C
        w = w + g_edge * edge_mask
        return s, w

    T0 = jnp.full_like(q_w, C.T_AMBIENT_C + 20.0)

    def cond(state):
        T, dT, it = state
        return (dT > 1e-5) & (it < 200_000)

    def body(state):
        T, _, it = state
        s, w = neighbor_sum(T)
        T_new = (s + q_w) / w
        T_new = T + 0.9 * (T_new - T)  # light damping
        return T_new, jnp.max(jnp.abs(T_new - T)), it + 1

    T, _, _ = jax.lax.while_loop(cond, body, (T0, jnp.inf, 0))
    return T


def lumped_tier_temps(q_tiers_w, footprint_mm2, tiers, tech, macs_per_tier):
    """Batched steady-state *lumped* tier temperatures (one node per tier).

    The engine's vectorized thermal path: where ``solve_stack`` resolves
    in-die gradients on a (tiers, g, g) grid for one design,
    this collapses each tier to a single thermal node and solves the
    whole batch of tier chains in one tridiagonal sweep — the same
    physics (vertical ILD+TSV conduction, bottom-tier heatsink, edge
    spreading scaling with perimeter) at die granularity.

    Args (broadcast over the batch dim B):
      q_tiers_w:     (B, Lmax) per-tier power [W]; entries beyond a
                     design's tier count are ignored.
      footprint_mm2: (B,) per-tier die footprint.
      tiers:         (B,) int tier counts (1..Lmax).
      tech:          (B,) str array ('2d'|'tsv'|'miv') — 'tsv' adds the
                     via copper to the vertical path.
      macs_per_tier: (B,) int — sizes the per-die TSV copper share.

    Returns (B, Lmax) float64 temperatures [C]; padded tiers read
    ambient. Tier 0 is the bottom (heatsink-side) tier.
    """
    q = np.asarray(q_tiers_w, dtype=np.float64)
    B, Lmax = q.shape
    footprint_mm2 = np.broadcast_to(np.asarray(footprint_mm2, np.float64), (B,))
    tiers = np.broadcast_to(np.asarray(tiers, np.int64), (B,))
    tech = np.broadcast_to(np.asarray(tech), (B,))
    macs_per_tier = np.broadcast_to(np.asarray(macs_per_tier, np.float64), (B,))
    diag, sub, sup, rhs, _ = _lumped_system(
        q, footprint_mm2, tiers, tech, macs_per_tier
    )
    return _thomas(diag, sub, sup, rhs)


def _lumped_system(q, footprint_mm2, tiers, tech, macs_per_tier):
    """Assemble the batched lumped tridiagonal system (already broadcast).

    Returns ``(diag, sub, sup, rhs, alive)`` with padded rows pinned to
    identity x ambient. ``rhs`` includes the per-tier power injection
    ``q``; pass zeros to get the q-independent part (the transient
    stepping adds its own source term per step).
    """
    validate_options("tech", tech, VALID_TECHS)
    if np.any(tiers < 1):
        raise ValueError(
            f"tiers must be >= 1 everywhere, got min {int(np.min(tiers))}"
        )
    Lmax = q.shape[1]
    a_m2 = footprint_mm2 * 1e-6
    g_ild = C.K_ILD_W_MK * a_m2 / (C.T_ILD_UM * 1e-6)
    # Per-MAC TSV copper share: each MAC pile carries VLINK_BITS vias,
    # of which a quarter of the drawn area (A_TSV_UM2 includes the
    # keep-out zone) is conductive core — the same per-cell share
    # solve_stack assumes, so grid and lumped vertical paths agree.
    a_cu = macs_per_tier * C.VLINK_BITS * (C.A_TSV_UM2 * 0.25) * 1e-12
    g_via = C.K_CU_W_MK * a_cu / (C.T_TIER_SI_UM * 1e-6)
    g_vert = np.where(tech == "tsv", g_ild + g_via, g_ild)
    g_sink = footprint_mm2 / C.R_HEATSINK_KMM2_W
    g_edge = C.G_EDGE_PER_MM_W_K * 4.0 * np.sqrt(footprint_mm2)

    idx = np.arange(Lmax)[None, :]
    alive = idx < tiers[:, None]
    has_below = alive & (idx > 0)
    has_above = idx < (tiers[:, None] - 1)

    # Tridiagonal system: diag * T_i - g_vert * (T_below + T_above) = rhs.
    diag = (
        g_edge[:, None] * alive
        + g_sink[:, None] * (idx == 0)
        + g_vert[:, None] * has_below
        + g_vert[:, None] * has_above
    )
    sub = np.where(has_below, -g_vert[:, None], 0.0)
    sup = np.where(has_above, -g_vert[:, None], 0.0)
    rhs = (
        np.where(alive, q, 0.0)
        + g_edge[:, None] * alive * C.T_AMBIENT_C
        + g_sink[:, None] * (idx == 0) * C.T_AMBIENT_C
    )
    # Padded nodes: identity rows pinned to ambient.
    diag = np.where(alive, diag, 1.0)
    rhs = np.where(alive, rhs, C.T_AMBIENT_C)
    return diag, sub, sup, rhs, alive


def _thomas(diag, sub, sup, rhs):
    """Vectorized Thomas algorithm over the batch (Lmax <= 16 is tiny).

    Degenerate rows (zero-area design points) divide 0/0 and yield
    NaN, which callers mask via their validity arrays.
    """
    Lmax = rhs.shape[1]
    with np.errstate(invalid="ignore", divide="ignore"):
        cp = np.zeros_like(rhs)
        dp = np.zeros_like(rhs)
        cp[:, 0] = sup[:, 0] / diag[:, 0]
        dp[:, 0] = rhs[:, 0] / diag[:, 0]
        for i in range(1, Lmax):
            denom = diag[:, i] - sub[:, i] * cp[:, i - 1]
            cp[:, i] = sup[:, i] / denom
            dp[:, i] = (rhs[:, i] - sub[:, i] * dp[:, i - 1]) / denom
        T = np.empty_like(rhs)
        T[:, -1] = dp[:, -1]
        for i in range(Lmax - 2, -1, -1):
            T[:, i] = dp[:, i] - cp[:, i] * T[:, i + 1]
    return T


@dataclasses.dataclass(frozen=True)
class ThermalState:
    """Batched transient state of the lumped tier stack.

    Holds the assembled (q-independent) conductance system plus each
    tier's heat capacity and current temperature; advance it with
    ``step_temps``. Build via ``ThermalState.init``.
    """

    temps_c: np.ndarray  # (B, Lmax) current tier temperatures [C]
    alive: np.ndarray  # (B, Lmax) bool, False on padded tiers
    diag: np.ndarray  # steady-state diagonal (padded rows = 1)
    sub: np.ndarray
    sup: np.ndarray
    rhs0: np.ndarray  # q-independent rhs (padded rows = ambient)
    cap_j_k: np.ndarray  # (B, Lmax) per-tier heat capacity [J/K], 0 padded

    @classmethod
    def init(
        cls,
        footprint_mm2,
        tiers,
        tech,
        macs_per_tier,
        t0_c: float = C.T_AMBIENT_C,
    ) -> "ThermalState":
        """Assemble a stack batch at a uniform start temperature.

        Args broadcast over the batch dim B exactly as in
        ``lumped_tier_temps``; the tier heat capacity is the silicon
        volume (footprint x tier thickness; full-thickness die for
        single-tier designs) times ``C_SI_J_M3K``.
        """
        tiers_b = np.atleast_1d(np.asarray(tiers, np.int64))
        B = tiers_b.shape[0]
        Lmax = int(np.max(tiers_b)) if B else 1
        footprint_mm2 = np.broadcast_to(
            np.asarray(footprint_mm2, np.float64), (B,)
        )
        tech_b = np.broadcast_to(np.asarray(tech), (B,))
        macs_b = np.broadcast_to(np.asarray(macs_per_tier, np.float64), (B,))
        q0 = np.zeros((B, Lmax), dtype=np.float64)
        diag, sub, sup, rhs0, alive = _lumped_system(
            q0, footprint_mm2, tiers_b, tech_b, macs_b
        )
        t_si_m = np.where(tiers_b == 1, C.T_2D_SI_UM, C.T_TIER_SI_UM) * 1e-6
        cap = footprint_mm2 * 1e-6 * t_si_m * C.C_SI_J_M3K  # J/K per tier
        cap_j_k = np.where(alive, cap[:, None], 0.0)
        temps = np.full((B, Lmax), float(t0_c), dtype=np.float64)
        return cls(
            temps_c=temps, alive=alive, diag=diag, sub=sub, sup=sup,
            rhs0=rhs0, cap_j_k=cap_j_k,
        )

    @property
    def t_max_c(self) -> np.ndarray:
        """(B,) hottest live tier per design."""
        return np.max(np.where(self.alive, self.temps_c, -np.inf), axis=1)


def step_temps(state: ThermalState, q_tiers_w, dt_s) -> ThermalState:
    """One backward-Euler step of the lumped stack: hold per-tier power
    ``q_tiers_w`` (B, Lmax) [W] for ``dt_s`` (scalar or (B,)) [s].

    Solves ``(C/dt + A) T' = (C/dt) T + rhs(q)`` with the same Thomas
    sweep as the steady solve, so it is unconditionally stable and the
    steady-state ``lumped_tier_temps`` solution is its exact fixed
    point under constant power. ``dt_s`` must be > 0 (use the caller's
    validity mask to skip degenerate points — NaN temperatures there
    are masked, exactly as in the steady path).
    """
    q = np.asarray(q_tiers_w, dtype=np.float64)
    dt = np.asarray(dt_s, dtype=np.float64)
    if dt.ndim == 1:
        dt = dt[:, None]
    with np.errstate(invalid="ignore", divide="ignore"):
        cdt = np.where(state.alive, state.cap_j_k / dt, 0.0)
        diag = state.diag + cdt
        rhs = state.rhs0 + np.where(
            state.alive, q + cdt * state.temps_c, 0.0
        )
        T = _thomas(diag, state.sub, state.sup, rhs)
    return dataclasses.replace(state, temps_c=T)


def _power_map(M, K, N, rows, cols, tiers, tech, g=_GRID):
    """Distribute the power report onto a (tiers, g, g) grid."""
    rep = array_power(M, K, N, rows, cols, tiers, tech)
    n_total = rows * cols * tiers
    base = rep.components["clk_leak_w"] + rep.components["die_wire_w"]
    dyn = rep.total_w - base
    q = np.full((tiers, g, g), base / (tiers * g * g), dtype=np.float64)
    # Active streaming region: rows x cols that actually carry operands.
    fr = min(M, rows) / rows
    fc = min(N, cols) / cols
    gr, gc = max(1, round(g * fr)), max(1, round(g * fc))
    q[:, :gr, :gc] += dyn / (tiers * gr * gc)
    return jnp.asarray(q), rep


def thermal_report(macs_per_tier: int, tiers: int, tech: str, M=128, K=300, N=128):
    """Fig. 8 setup: per-layer temperature stats for a given config."""
    validate_option("tech", tech, VALID_TECHS)
    if int(tiers) < 1:
        raise ValueError(f"tiers must be >= 1, got {tiers}")
    side = int(np.sqrt(macs_per_tier))
    rows = cols = side
    q, rep = _power_map(M, K, N, rows, cols, tiers, tech)
    a_mac = C.A_MAC_UM2
    if tech == "tsv":
        a_mac = a_mac + C.VLINK_BITS * C.A_TSV_UM2 * (tiers - 1) / max(tiers, 1)
    elif tech == "miv":
        a_mac = a_mac + C.VLINK_BITS * C.A_MIV_UM2 * (tiers - 1) / max(tiers, 1)
    cell_area_mm2 = (macs_per_tier * a_mac * 1e-6) / (_GRID * _GRID)
    T = np.asarray(solve_stack(q, cell_area_mm2, tiers, tech))

    def stats(x):
        return tuple(float(v) for v in np.percentile(x, [0, 25, 50, 75, 100]))

    bottom = stats(T[0])
    middle = stats(T[1:]) if tiers > 1 else None
    t_max = float(T.max())
    return ThermalReport(
        tech=tech,
        macs_per_tier=macs_per_tier,
        tiers=tiers,
        t_max_c=t_max,
        bottom=bottom,
        middle=middle,
        within_budget=t_max < C.THERMAL_BUDGET_C,
    )
