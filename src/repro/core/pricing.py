"""Frequency-aware step pricing + the DVFS governor.

One shared pricer for the ``dataflow_dims`` + ``gemm_traffic_batched``
+ ``roofline_cycles`` + power-charging sequence that ``core.engine``
and ``core.serve`` used to spell out independently. Everything here is
parameterized on an explicit clock ``freq_hz`` (and supply ``vdd_v``)
instead of baking in ``constants.FREQ_HZ`` — at the default
(1 GHz, VDD) every expression reduces to the exact op sequence the
call sites had before, so steady-state results stay bit-for-bit
identical (regression-pinned in ``tests/test_transient_thermal.py``).

Frequency/voltage conventions (standard CMOS first-order scaling,
relative to the reference operating point F0 = ``C.FREQ_HZ``,
V0 = ``C.VDD``):

- compute cycles and vertical-link cycles are clock-invariant counts;
- DRAM service is a wall-clock rate, so memory *cycles* scale with f
  (``dram_bytes_per_cycle`` = bytes/s / f);
- dynamic power scales with f * V^2, static (leakage + clock-tree
  bias) with V^2;
- seconds = cycles / f.

``DvfsSpec`` + ``governor_step`` + ``governed_run`` implement the
discrete-state governor: throttle one state down when the hottest tier
crosses ``limit - throttle_margin_c``, step back up only after it
cools below an additional ``hysteresis_c`` band. ``governed_run``
time-steps the lumped RC stack (``ppa.thermal.ThermalState``) over
repeated executions of a fixed work quantum and reports *sustained*
throughput next to the peak the steady-state model advertises.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .analytical import fold_dims
from .bandwidth import BandwidthSpec, fold_traffic_batched, roofline_cycles
from .ppa import constants as C
from .ppa.power import array_power_batched
from .ppa.thermal import ThermalState, step_temps

__all__ = [
    "DvfsSpec",
    "dram_bytes_per_cycle",
    "governed_run",
    "governor_step",
    "power_scales",
    "price_steps",
    "scale_power",
]


def dram_bytes_per_cycle(bandwidth: BandwidthSpec, freq_hz=C.FREQ_HZ):
    """DRAM service rate [bytes/cycle] at an explicit clock.

    Identical expression to ``BandwidthSpec.dram_bytes_per_cycle`` at
    the default clock (bit-for-bit), but a faster clock fits fewer
    bytes into each cycle — memory-bound regions do not speed up.
    """
    return bandwidth.dram_gbs * 1e9 / freq_hz


def power_scales(freq_hz=C.FREQ_HZ, vdd_v=C.VDD):
    """(dynamic, static) power multipliers vs the (F0, V0) reference.

    dynamic ∝ f * V^2, static ∝ V^2. Scalars in, scalars out; arrays
    broadcast.
    """
    return (
        (freq_hz / C.FREQ_HZ) * (vdd_v / C.VDD) ** 2,
        (vdd_v / C.VDD) ** 2,
    )


def scale_power(pw: dict, freq_hz=C.FREQ_HZ, vdd_v=C.VDD) -> dict:
    """Rescale an ``array_power_batched`` report to an operating point.

    At exactly the reference point the input dict is returned
    *unchanged* (same object) — the identity fast path that keeps the
    default-clock results bit-identical. Activity counts ("cycles" et
    al.) are clock-invariant and pass through untouched.
    """
    if (
        np.isscalar(freq_hz)
        and np.isscalar(vdd_v)
        and freq_hz == C.FREQ_HZ
        and vdd_v == C.VDD
    ):
        return pw
    sd, ss = power_scales(freq_hz, vdd_v)
    out = dict(pw)
    out["static_w"] = pw["static_w"] * ss
    out["dynamic_w"] = pw["dynamic_w"] * sd
    out["total_w"] = out["static_w"] + out["dynamic_w"]
    if "peak_w" in pw:
        # peak = total + headroom; the headroom is all-dynamic.
        out["peak_w"] = out["total_w"] + (pw["peak_w"] - pw["total_w"]) * sd
    return out


def price_steps(
    dataflow: str,
    M,
    K,
    N,
    rows,
    cols,
    tiers,
    tech,
    bandwidth: BandwidthSpec,
    freq_hz=C.FREQ_HZ,
    vdd_v=C.VDD,
    fold: str | None = None,
) -> dict:
    """Price one batch of GEMM steps on fixed arrays, in one call.

    The shared kernel behind ``engine.evaluate``'s explicit-design path
    and ``core.serve``'s queue stepping: dataflow fold geometry ->
    roofline'd cycles -> scaled power -> seconds / energy / per-tier
    watts. All array arguments broadcast together (the serve pricer
    passes (layers, points) matrices); ``dataflow``/``bandwidth``/
    ``fold`` and the operating point are uniform per call.

    ``fold`` selects a per-layer tier fold (``analytical.fold_dims``)
    for the ``tier_fold`` policy and the serve mapping knob; ``None``
    (or the dataflow's native fold) is the paper's tier split and
    reproduces the pre-fold pricing bit-for-bit.

    Returns a dict of broadcast arrays:
      ``compute_cycles``  array-busy cycles (clock-invariant count)
      ``mem_cycles``      DRAM service cycles at ``freq_hz``
      ``vlink_cycles``    serialized vertical-link cycles
      ``total_cycles``    rooflined max, ``stall_cycles`` its stall part
      ``dram_bytes``, ``vlink_bytes``, ``sram_need_bytes``  traffic
      ``total_w``/``static_w``/``dynamic_w``/``peak_w``  scaled power
      ``tier_w``          total_w / tiers (the thermal injection)
      ``seconds``         total_cycles / freq_hz
      ``energy_j``        active power over compute + static over stall
    """
    D1, D2, T = fold_dims(fold, dataflow, M, K, N, tiers)
    folds = -(-D1 // rows) * -(-D2 // cols)
    compute = (2 * rows + cols + T - 2).astype(np.float64) * folds
    tr = fold_traffic_batched(
        fold, dataflow, M, K, N, rows, cols, tiers, tech, bandwidth
    )
    bpc = dram_bytes_per_cycle(bandwidth, freq_hz)
    with np.errstate(invalid="ignore"):
        mem = tr["dram_bytes"] / bpc
    total, stall, bidx = roofline_cycles(compute, mem, tr["vlink_cycles"])
    pw = array_power_batched(M, K, N, rows, cols, tiers, tech, dataflow, fold=fold)
    pw = scale_power(pw, freq_hz, vdd_v)
    with np.errstate(invalid="ignore", divide="ignore"):
        seconds = total / freq_hz
        energy = (
            pw["total_w"] * compute + pw["static_w"] * stall
        ) / freq_hz
        tier_w = pw["total_w"] / tiers
    return {
        "compute_cycles": compute,
        "mem_cycles": mem,
        "vlink_cycles": tr["vlink_cycles"],
        "total_cycles": total,
        "stall_cycles": stall,
        "bound_idx": bidx,
        "dram_bytes": tr["dram_bytes"],
        "vlink_bytes": tr["vlink_bytes"],
        "sram_need_bytes": tr["sram_need_bytes"],
        "total_w": pw["total_w"],
        "static_w": pw["static_w"],
        "dynamic_w": pw["dynamic_w"],
        "peak_w": pw["peak_w"],
        "tier_w": tier_w,
        "seconds": seconds,
        "energy_j": energy,
    }


@dataclasses.dataclass(frozen=True)
class DvfsSpec:
    """Discrete DVFS operating states + governor policy (JSON-stable).

    States are listed slowest-first; the governor starts at (and cools
    back up toward) the top state. ``vdds_v`` defaults to a linear
    voltage ramp ending exactly at ``constants.VDD`` for the top state,
    so a top state at the reference 1 GHz reproduces the steady model's
    power bit-for-bit.

    ``throttle_margin_c`` backs the trip point off the thermal limit
    (trip at ``limit - margin``); ``hysteresis_c`` is the extra cooling
    band required before stepping back up — prevents limit cycling.
    ``sim_steps`` is the number of governed work quanta integrated by
    ``governed_run`` (sustained throughput is measured over the second
    half, after the thermal transient).
    """

    freqs_ghz: tuple = (0.5, 0.75, 1.0)
    vdds_v: tuple | None = None
    throttle_margin_c: float = 3.0
    hysteresis_c: float = 5.0
    sim_steps: int = 64

    def __post_init__(self):
        freqs = tuple(float(f) for f in self.freqs_ghz)
        if not freqs or any(f <= 0 for f in freqs):
            raise ValueError(
                f"freqs_ghz must be positive frequencies, got {freqs}"
            )
        if any(b <= a for a, b in zip(freqs, freqs[1:])):
            raise ValueError(
                f"freqs_ghz must be strictly ascending, got {freqs}"
            )
        object.__setattr__(self, "freqs_ghz", freqs)
        if self.vdds_v is None:
            top = freqs[-1]
            vdds = tuple(C.VDD * (0.6 + 0.4 * (f / top)) for f in freqs)
        else:
            vdds = tuple(float(v) for v in self.vdds_v)
            if len(vdds) != len(freqs):
                raise ValueError(
                    f"vdds_v must match freqs_ghz ({len(freqs)} states), "
                    f"got {len(vdds)}"
                )
            if any(v <= 0 for v in vdds):
                raise ValueError(f"vdds_v must be positive, got {vdds}")
            if any(b < a for a, b in zip(vdds, vdds[1:])):
                raise ValueError(
                    f"vdds_v must be non-decreasing, got {vdds}"
                )
        object.__setattr__(self, "vdds_v", vdds)
        for name in ("throttle_margin_c", "hysteresis_c"):
            v = float(getattr(self, name))
            if not np.isfinite(v) or v < 0:
                raise ValueError(f"{name} must be finite and >= 0, got {v}")
            object.__setattr__(self, name, v)
        steps = int(self.sim_steps)
        if steps < 2:
            raise ValueError(f"sim_steps must be >= 2, got {steps}")
        object.__setattr__(self, "sim_steps", steps)

    @property
    def n_states(self) -> int:
        return len(self.freqs_ghz)

    def freqs_hz(self) -> np.ndarray:
        return np.asarray(self.freqs_ghz, dtype=np.float64) * 1e9

    def scales(self) -> tuple:
        """Per-state (dynamic, static) power multipliers vs (F0, V0)."""
        return power_scales(
            self.freqs_hz(), np.asarray(self.vdds_v, dtype=np.float64)
        )

    def to_dict(self) -> dict:
        return {
            "freqs_ghz": list(self.freqs_ghz),
            "vdds_v": list(self.vdds_v),
            "throttle_margin_c": self.throttle_margin_c,
            "hysteresis_c": self.hysteresis_c,
            "sim_steps": self.sim_steps,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "DvfsSpec":
        return cls(**d)


def governor_step(state_idx, t_max_c, limit_c: float, spec: DvfsSpec):
    """One governor decision per design: new state indices.

    Throttle down one state when the hottest tier is within
    ``throttle_margin_c`` of the limit; step up one state only after it
    cools a further ``hysteresis_c`` below the trip point. NaN
    temperatures (invalid designs) hold their state.
    """
    t = np.asarray(t_max_c, dtype=np.float64)
    trip = limit_c - spec.throttle_margin_c
    down = t >= trip
    up = t < (trip - spec.hysteresis_c)
    new = np.where(down, state_idx - 1, np.where(up, state_idx + 1, state_idx))
    return np.clip(new, 0, spec.n_states - 1)


def governed_run(
    compute_cycles,
    mem_cycles,
    vlink_cycles,
    static_w,
    dynamic_w,
    valid,
    tiers,
    tech,
    footprint_mm2,
    macs_per_tier,
    dvfs: DvfsSpec,
    limit_c: float,
    freq_hz: float = C.FREQ_HZ,
) -> dict:
    """DVFS-governed transient execution of one fixed work quantum.

    All per-design inputs are flat (B,) float64 arrays priced at the
    reference clock ``freq_hz``: the quantum's compute / memory /
    vertical-link cycles and its static / dynamic power draw. The run
    repeats the quantum ``dvfs.sim_steps`` times, at each step
    re-roofing the cycle count at the governed frequency (memory
    cycles scale with f, compute and vlink counts do not), stepping
    the lumped RC stack by the quantum's wall-clock duration, and
    letting the governor react to the hottest tier.

    Returns a dict of (B,) arrays (``residency`` is (B, n_states)):
      ``sustained_per_s``    quanta/s over the second half of the run
      ``peak_per_s``         quanta/s at the top state, cold
      ``peak_vs_sustained``  their ratio (>= 1 when throttling binds)
      ``t_max_transient_c``  hottest excursion over the whole run
      ``residency``          fraction of steps spent in each state
      ``within_limit``       governed excursion stayed under the limit
    """
    compute = np.asarray(compute_cycles, dtype=np.float64)
    mem = np.asarray(mem_cycles, dtype=np.float64)
    vlink = np.asarray(vlink_cycles, dtype=np.float64)
    B = compute.shape[0]
    S = dvfs.n_states
    freqs = dvfs.freqs_hz()
    sd, ss = dvfs.scales()

    state = np.full(B, S - 1, dtype=np.int64)
    tstate = ThermalState.init(footprint_mm2, tiers, tech, macs_per_tier)
    tiers_f = np.maximum(np.asarray(tiers, dtype=np.float64), 1.0)
    residency = np.zeros((B, S), dtype=np.float64)
    t_hot = np.full(B, -np.inf)
    rows_b = np.arange(B)
    half = dvfs.sim_steps // 2
    n_meas = dvfs.sim_steps - half
    time_meas = np.zeros(B, dtype=np.float64)

    with np.errstate(invalid="ignore", divide="ignore", over="ignore"):
        for k in range(dvfs.sim_steps):
            f = freqs[state]
            total = np.maximum(
                compute, np.maximum(mem * (f / freq_hz), vlink)
            )
            dt = np.where(valid, total / f, 1.0)
            p = static_w * ss[state] + dynamic_w * sd[state]
            q = np.where(
                tstate.alive,
                (np.where(valid, p, 0.0) / tiers_f)[:, None],
                0.0,
            )
            tstate = step_temps(tstate, q, dt)
            tmax = tstate.t_max_c
            t_hot = np.fmax(t_hot, tmax)
            residency[rows_b, state] += 1.0
            if k >= half:
                time_meas += np.where(valid, dt, 0.0)
            state = governor_step(state, tmax, limit_c, dvfs)

        sustained = np.where(
            valid & (time_meas > 0), n_meas / time_meas, np.nan
        )
        f_top = freqs[-1]
        total_top = np.maximum(
            compute, np.maximum(mem * (f_top / freq_hz), vlink)
        )
        peak = np.where(valid, f_top / total_top, np.nan)
        ratio = peak / sustained

    return {
        "sustained_per_s": sustained,
        "peak_per_s": peak,
        "peak_vs_sustained": ratio,
        "t_max_transient_c": np.where(valid, t_hot, np.nan),
        "residency": residency / dvfs.sim_steps,
        "within_limit": valid & (t_hot < limit_c),
    }
