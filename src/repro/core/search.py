"""Guided Pareto search: a stable frontier out of a billion-point space.

The batched engine prices ~10^5 design points per second, but the full
space it can express — MAC budget x tiers x dataflow x vertical-link
tech x DRAM bandwidth x SRAM capacity — is billions of points
(``benchmarks/search_bench.py`` pins an effective ~1e9-point space).
Exhaustive sweeps stop being an option well before that; this module is
the ROADMAP's "guided search over combinatorially large spaces" item:

- **One batch per generation.** Candidates are index tuples into the
  per-axis value lists, and every generation is exactly one vectorized
  ``engine.evaluate`` call over the proposed batch (the per-point
  ``DesignGrid`` axes — including the PR-6 ``dram_gbs``/``sram_kib``
  memory-system axes — carry heterogeneous candidates in a single
  grid). No per-candidate Python loop ever touches the engine.
- **Successive halving over a coarse-to-fine lattice.** Generation g
  samples the axis lattice at stride ``refine[g]`` (a halving schedule
  like (8, 8, 4, 4, 2, 2, 1, 1)); early generations scan the whole
  space cheaply, later ones resolve fine structure around survivors.
- **Evolutionary proposals.** A fraction of each generation mutates /
  crossbreeds survivors of the running *feasible-only* Pareto archive
  (the frontier of every feasible point evaluated so far), the rest
  keeps exploring the lattice. Proposals are deduped against the
  evaluated-point set, so no point is ever priced twice.
- **Deterministic and resumable.** The PRNG is a single seeded
  ``np.random.default_rng`` threaded explicitly through the proposal
  step; proposals are a pure function of (seed, results so far), so
  identical seeds give bit-identical ``StudyResult`` payloads — also
  across ``--resume`` (each generation's batch is a content-addressed
  cache chunk; replayed chunks reproduce the evaluation bits exactly,
  so the PRNG trajectory re-derives identically) and across any worker
  count (``parallel.work_queue`` farms missing blocks to N processes
  over the same chunk protocol).

On small spaces the proposal step switches to exhaustive enumeration of
the not-yet-seen remainder whenever the whole space fits in the
remaining evaluation budget — the property ``tests/test_search.py``
pins: with budget >= space size the guided frontier *equals* the
exhaustive feasible frontier.
"""

from __future__ import annotations

import dataclasses
import math
import tempfile

import numpy as np

from .cache import ResultCache
from .engine import DesignGrid, evaluate, pareto_mask_batched
from .params import VALID_FOLDS, VALID_OBJECTIVES, validate_option

__all__ = [
    "SearchSpec",
    "evaluate_candidates",
    "chunk_payload",
    "exhaustive_frontier",
    "hypervolume",
    "resolve_axes",
    "run_search",
]


@dataclasses.dataclass(frozen=True)
class SearchSpec:
    """The guided-search configuration (JSON-round-trippable).

    - ``objectives``: minimized ``EvalResult`` metric columns; a design
      point's objective value is the workload-count-weighted sum over
      the study's workloads (one scalar per objective per point).
    - ``generations`` x ``population``: the evaluation budget — each
      generation proposes up to ``population`` unseen candidates and
      prices them in one engine batch.
    - ``refine``: per-generation lattice stride (successive halving);
      shorter than ``generations`` repeats its last entry.
    - ``mutation`` / ``crossover``: fractions of each generation bred
      from the running feasible-only Pareto archive (the remainder
      keeps sampling the stride lattice). Both 0 disables evolution.
    - ``seed``: the explicit PRNG seed — identical seeds give
      bit-identical results (also across ``--resume`` / worker counts).
    - ``dram_gbs`` / ``sram_kib``: optional memory-system axes [GB/s,
      KiB per tier]; they require ``AnalysisSpec.bandwidth`` and ride
      the grid's per-point overrides.
    - ``folds``: optional tier-fold axis ('m'|'k'|'n' — see
      ``analytical.fold_dims``); each candidate commits every layer to
      one fold, riding the grid's per-point ``fold`` override. A
      dataflow's native fold prices identically to no fold at all.
    - ``ref_point``: hypervolume reference (one value per objective);
      ``None`` derives it from the evaluated feasible set (nadir * 1.1).
    """

    objectives: tuple[str, ...] = ("cycles", "energy_j")
    generations: int = 8
    population: int = 256
    refine: tuple[int, ...] = (8, 8, 4, 4, 2, 2, 1, 1)
    mutation: float = 0.4
    crossover: float = 0.3
    seed: int = 0
    dram_gbs: tuple[float, ...] | None = None
    sram_kib: tuple[float, ...] | None = None
    folds: tuple[str, ...] | None = None
    ref_point: tuple[float, ...] | None = None

    def __post_init__(self):
        object.__setattr__(
            self, "objectives",
            tuple(validate_option("objective", o, VALID_OBJECTIVES)
                  for o in self.objectives),
        )
        for name in ("generations", "population", "seed"):
            object.__setattr__(self, name, int(getattr(self, name)))
        if self.generations < 1:
            raise ValueError(f"generations must be >= 1, got {self.generations}")
        if self.population < 1:
            raise ValueError(f"population must be >= 1, got {self.population}")
        if self.seed < 0:
            raise ValueError(f"seed must be >= 0, got {self.seed}")
        refine = tuple(int(s) for s in self.refine)
        if not refine or any(s < 1 for s in refine):
            raise ValueError(f"refine must be positive strides, got {self.refine}")
        object.__setattr__(self, "refine", refine)
        for name in ("mutation", "crossover"):
            object.__setattr__(self, name, float(getattr(self, name)))
        if not (0.0 <= self.mutation <= 1.0 and 0.0 <= self.crossover <= 1.0
                and self.mutation + self.crossover <= 1.0):
            raise ValueError(
                f"mutation ({self.mutation}) and crossover ({self.crossover}) "
                "must be fractions with mutation + crossover <= 1"
            )
        for name in ("dram_gbs", "sram_kib"):
            v = getattr(self, name)
            if v is None:
                continue
            vals = tuple(float(x) for x in v)
            if not vals or any(not math.isfinite(x) or x <= 0 for x in vals):
                raise ValueError(f"{name} axis needs positive finite values, got {v}")
            object.__setattr__(self, name, vals)
        if self.folds is not None:
            object.__setattr__(
                self, "folds",
                tuple(validate_option("fold", f, VALID_FOLDS) for f in self.folds),
            )
        if self.ref_point is not None:
            rp = tuple(float(x) for x in self.ref_point)
            if len(rp) != len(self.objectives) or any(not math.isfinite(x) for x in rp):
                raise ValueError(
                    f"ref_point needs one finite value per objective "
                    f"({len(self.objectives)}), got {self.ref_point}"
                )
            object.__setattr__(self, "ref_point", rp)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "SearchSpec":
        return cls(**d)


# ---------------------------------------------------------------------------
# The search space: named axes of values, candidates as index tuples
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _Axis:
    name: str
    values: np.ndarray  # 1-D; int64 / float64 / str


def resolve_axes(study) -> list[_Axis]:
    """The study's search axes, in canonical order.

    ``SpaceSpec`` contributes mac_budgets / tiers / dataflow / tech
    (strings become single-value axes); ``SearchSpec`` contributes the
    optional memory-system axes. The effective space is their product —
    candidates are index tuples into these value lists.
    """
    space, spec = study.space, study.analysis.search
    if spec is None:
        raise ValueError("kind='search' needs an AnalysisSpec.search SearchSpec")
    if space.rows is not None:
        raise ValueError(
            "search optimizes over MAC budgets (the engine finds per-tier "
            "shapes); drop the explicit rows/cols"
        )
    if space.mac_budgets is None:
        raise ValueError("search needs SpaceSpec.mac_budgets as an axis")
    if space.layout != "product":
        raise ValueError("search crosses its axes itself; use layout='product'")
    axes = [
        _Axis("mac_budgets", np.asarray(space.mac_budgets, dtype=np.int64)),
        _Axis("tiers", np.asarray(space.tiers, dtype=np.int64)),
    ]
    for name in ("dataflow", "tech"):
        v = getattr(space, name)
        axes.append(_Axis(name, np.asarray([v] if isinstance(v, str) else list(v))))
    for name in ("dram_gbs", "sram_kib"):
        v = getattr(spec, name)
        if v is not None:
            axes.append(_Axis(name, np.asarray(v, dtype=np.float64)))
    if spec.folds is not None:
        axes.append(_Axis("fold", np.asarray(list(spec.folds))))
    for ax in axes:
        if len(np.unique(ax.values)) != ax.values.shape[0]:
            raise ValueError(
                f"search axis {ax.name!r} has duplicate values — the space "
                "product would double-count points"
            )
    return axes


def _candidate_grid(study, stream, axes: list[_Axis], cands: np.ndarray) -> DesignGrid:
    """Index rows -> ONE heterogeneous DesignGrid (a single engine batch)."""
    vals = {ax.name: ax.values[cands[:, i]] for i, ax in enumerate(axes)}
    kw: dict = {
        "workloads": stream.workloads,
        "tiers": vals["tiers"],
        "mac_budgets": vals["mac_budgets"],
        "dataflow": vals["dataflow"],
        "tech": vals["tech"],
        "mode": study.space.mode,
    }
    for name in ("dram_gbs", "sram_kib", "fold"):
        if name in vals:
            kw[name] = vals[name]
    return DesignGrid(**kw)


def evaluate_candidates(study, cands, stream=None, axes=None):
    """Price one candidate batch: one vectorized ``engine.evaluate``.

    Returns ``(objectives, feasible)`` — (n, n_obj) float64 of
    count-weighted objective sums and (n,) bool of all-workloads
    feasibility under the study's constraints. This is the work unit
    the multi-process queue farms out; it is deterministic, so chunk
    payloads are bit-identical across processes and worker counts.
    """
    a = study.analysis
    spec = a.search
    if stream is None:
        stream = study.workload.resolve()
    if axes is None:
        axes = resolve_axes(study)
    cands = np.asarray(cands, dtype=np.int64)
    grid = _candidate_grid(study, stream, axes, cands)
    res = evaluate(
        grid,
        metrics=a.metrics,
        backend=a.backend,
        thermal_limit=study.constraints.thermal_limit_c,
        shard=a.shard,
        bandwidth=a.bandwidth,
        **({"chunk": a.chunk} if a.chunk is not None else {}),
    )
    mask = study.constraints.mask(res)
    feasible = mask.all(axis=0)
    counts = np.asarray(stream.counts, dtype=np.float64)
    cols = []
    for name in spec.objectives:
        v = getattr(res, name)
        if v is None:
            raise ValueError(
                f"objective {name!r} was not evaluated — add its metric "
                "group to AnalysisSpec.metrics"
            )
        with np.errstate(invalid="ignore"):
            cols.append((counts[:, None] * np.asarray(v, dtype=np.float64)).sum(axis=0))
    return np.stack(cols, axis=1), feasible


def chunk_payload(cands: np.ndarray, objs: np.ndarray, feasible: np.ndarray) -> dict:
    """The JSON chunk form of one evaluated block (cache / wire format).

    Candidates are stored alongside the results and verified on load —
    a chunk whose candidate rows do not match the deterministic
    re-proposal is recomputed, never silently trusted.
    """
    from .study import _jsonify  # deferred: study imports this module

    return {
        "candidates": np.asarray(cands, dtype=np.int64).tolist(),
        "objectives": _jsonify(np.asarray(objs, dtype=np.float64)),
        "feasible": np.asarray(feasible, dtype=bool).tolist(),
    }


def _decode_chunk(d: dict):
    objs = np.asarray(d["objectives"], dtype=np.float64)
    feas = np.asarray(d["feasible"], dtype=bool)
    return objs, feas


# ---------------------------------------------------------------------------
# Proposals: lattice exploration + evolution over the Pareto archive
# ---------------------------------------------------------------------------

def _propose(rng, spec: SearchSpec, sizes, stride: int, archive_X, seen,
             remaining_budget: int) -> np.ndarray:
    """Up to ``population`` unseen candidate index rows for one generation.

    Pure function of (rng state, archive, seen): re-running a resumed
    search re-derives the identical proposal sequence. When the whole
    space fits in the remaining budget the proposal degrades to
    exhaustive enumeration of the unseen remainder (completeness on
    small spaces — the property tests' guarantee).
    """
    n_axes = len(sizes)
    total = math.prod(sizes)
    pop = spec.population
    unseen = total - len(seen)
    if unseen <= 0:
        return np.empty((0, n_axes), dtype=np.int64)
    if total <= remaining_budget or unseen <= pop:
        out = []
        for flat in range(total):
            c = tuple(int(x) for x in np.unravel_index(flat, sizes))
            if c not in seen:
                out.append(c)
                if len(out) == pop:
                    break
        return np.asarray(out, dtype=np.int64).reshape(len(out), n_axes)

    n_arch = archive_X.shape[0]
    n_mut = int(round(pop * spec.mutation)) if n_arch >= 1 else 0
    n_cross = int(round(pop * spec.crossover)) if n_arch >= 2 else 0
    n_explore = pop - n_mut - n_cross
    lattice = np.asarray([-(-s // stride) for s in sizes], dtype=np.int64)
    hi = np.asarray(sizes, dtype=np.int64) - 1

    chosen: dict[tuple, None] = {}
    for _ in range(12):  # bounded retry: dedupe may reject whole batches
        need = pop - len(chosen)
        if need <= 0:
            break
        parts = []
        if n_explore:
            parts.append(rng.integers(0, lattice, size=(n_explore, n_axes)) * stride)
        if n_mut:
            parents = archive_X[rng.integers(0, n_arch, size=n_mut)]
            step = rng.integers(-2, 3, size=(n_mut, n_axes)) * stride
            flip = rng.random((n_mut, n_axes)) < 0.5
            parts.append(np.clip(parents + np.where(flip, step, 0), 0, hi))
        if n_cross:
            pa = archive_X[rng.integers(0, n_arch, size=n_cross)]
            pb = archive_X[rng.integers(0, n_arch, size=n_cross)]
            mix = rng.random((n_cross, n_axes)) < 0.5
            parts.append(np.where(mix, pa, pb))
        batch = np.concatenate(parts, axis=0)
        for row in batch:
            t = tuple(int(x) for x in row)
            if t not in seen and t not in chosen:
                chosen[t] = None
                if len(chosen) == pop:
                    break
    return np.asarray(list(chosen), dtype=np.int64).reshape(len(chosen), n_axes)


# ---------------------------------------------------------------------------
# Hypervolume (minimization; exact)
# ---------------------------------------------------------------------------

def hypervolume(points, ref) -> float:
    """Dominated hypervolume of a minimized point set w.r.t. ``ref``.

    Exact: O(n log n) sweep for 2 objectives, recursive slicing over the
    first coordinate (HSO-style) for d >= 3. Points not strictly better
    than ``ref`` in every objective contribute nothing and are dropped;
    non-finite points never contribute.
    """
    pts = np.atleast_2d(np.asarray(points, dtype=np.float64))
    ref = np.asarray(ref, dtype=np.float64).reshape(-1)
    if pts.shape[0] == 0:
        return 0.0
    if pts.shape[1] != ref.shape[0]:
        raise ValueError(f"ref has {ref.shape[0]} coords for {pts.shape[1]}-d points")
    keep = np.isfinite(pts).all(axis=1) & (pts < ref).all(axis=1)
    pts = pts[keep]
    if pts.shape[0] == 0:
        return 0.0
    pts = pts[pareto_mask_batched(pts[None])[0]]
    return _hv(pts, ref)


def _hv(pts: np.ndarray, ref: np.ndarray) -> float:
    d = pts.shape[1]
    if d == 1:
        return float(ref[0] - pts.min())
    if d == 2:
        order = np.lexsort((pts[:, 1], pts[:, 0]))
        p = pts[order]
        hv, prev_y = 0.0, float(ref[1])
        for x, y in p:
            hv += (ref[0] - x) * (prev_y - y)
            prev_y = y
        return float(hv)
    order = np.argsort(pts[:, 0], kind="stable")
    p = pts[order]
    xs = p[:, 0]
    hv = 0.0
    for i in range(p.shape[0]):
        x_hi = xs[i + 1] if i + 1 < xs.shape[0] else ref[0]
        width = float(x_hi - xs[i])
        if width <= 0.0:
            continue
        sub = p[: i + 1, 1:]
        sub = sub[pareto_mask_batched(sub[None])[0]]
        hv += width * _hv(sub, ref[1:])
    return float(hv)


# ---------------------------------------------------------------------------
# The search loop
# ---------------------------------------------------------------------------

def run_search(study, stream, cache: ResultCache | None = None) -> dict:
    """Execute a ``kind='search'`` study; returns the payload dict.

    Cached execution chunks each generation's batch into cache blocks
    keyed ``search-gen####-lo-hi`` (worker-count-independent), so
    ``--resume`` replays finished generations with zero recomputation
    and an interrupted generation resumes at block granularity. With
    ``AnalysisSpec.workers > 1`` the missing blocks of each generation
    are farmed to worker processes over the same chunk protocol
    (``parallel.work_queue``); an ephemeral cache carries the chunks
    when the run itself is uncached.
    """
    a = study.analysis
    spec: SearchSpec = a.search
    axes = resolve_axes(study)
    sizes = [int(ax.values.shape[0]) for ax in axes]
    total = math.prod(sizes)
    rng = np.random.default_rng(spec.seed)
    workers = int(a.workers) if a.workers else 1
    W = int(np.atleast_2d(stream.workloads).shape[0])

    tmp = None
    if workers > 1 and cache is None:
        # the queue's transport is the chunk store; give it a scratch one
        tmp = tempfile.TemporaryDirectory(prefix="repro-workqueue-")
        cache = ResultCache(tmp.name)
        cache.prepare(study)
    try:
        seen: dict[tuple, None] = {}
        n_obj = len(spec.objectives)
        all_X: list[np.ndarray] = []
        all_F: list[np.ndarray] = []
        archive_X = np.empty((0, len(axes)), dtype=np.int64)
        archive_F = np.empty((0, n_obj), dtype=np.float64)
        n_feasible = 0
        history = []
        for g in range(spec.generations):
            stride = spec.refine[min(g, len(spec.refine) - 1)]
            remaining = spec.population * (spec.generations - g)
            cands = _propose(rng, spec, sizes, stride, archive_X, seen, remaining)
            if cands.shape[0]:
                objs, feas = _evaluate_generation(
                    study, stream, axes, cands, g, cache, workers, W
                )
                for row in cands:
                    seen[tuple(int(x) for x in row)] = None
                n_feasible += int(feas.sum())
                if feas.any():
                    all_X.append(cands[feas])
                    all_F.append(objs[feas])
                    ax_cat = np.concatenate([archive_X, cands[feas]])
                    af_cat = np.concatenate([archive_F, objs[feas]])
                    m = pareto_mask_batched(af_cat[None])[0]
                    archive_X, archive_F = ax_cat[m], af_cat[m]
            history.append({
                "generation": g,
                "stride": int(stride),
                "n_proposed": int(cands.shape[0]),
                "n_evaluated_total": len(seen),
                "n_feasible_total": n_feasible,
                "frontier_size": int(archive_X.shape[0]),
            })

        if spec.ref_point is not None:
            ref = np.asarray(spec.ref_point, dtype=np.float64)
        elif archive_F.shape[0]:
            feas_F = np.concatenate(all_F) if all_F else archive_F
            finite = feas_F[np.isfinite(feas_F).all(axis=1)]
            nad = finite.max(axis=0) if finite.shape[0] else archive_F.max(axis=0)
            ref = np.where(nad > 0, nad * 1.1, nad + 1.0)
        else:
            ref = None
        hv = hypervolume(archive_F, ref) if ref is not None else 0.0

        order = np.lexsort(archive_F.T[::-1]) if archive_F.shape[0] else np.empty(0, int)
        frontier_X, frontier_F = archive_X[order], archive_F[order]
        return {
            "objectives": list(spec.objectives),
            "axes": {ax.name: ax.values.tolist() for ax in axes},
            "axis_names": [ax.name for ax in axes],
            "space_size": int(total),
            "n_evaluated": len(seen),
            "frac_evaluated": len(seen) / total if total else 0.0,
            "n_feasible": n_feasible,
            "frontier_candidates": frontier_X,
            "frontier_objectives": frontier_F,
            "frontier_designs": {
                ax.name: ax.values[frontier_X[:, i]].tolist()
                for i, ax in enumerate(axes)
            },
            "hypervolume": float(hv),
            "ref_point": None if ref is None else [float(x) for x in ref],
            "generations": spec.generations,
            "history": history,
        }
    finally:
        if tmp is not None:
            tmp.cleanup()


def _evaluate_generation(study, stream, axes, cands, g: int, cache, workers: int,
                         W: int):
    """One generation's batch through the (cached, possibly multi-process)
    chunk protocol; merged results are block-layout-independent."""
    n = cands.shape[0]
    block = n if cache is None else max(1, cache.block_cells // max(W, 1))
    blocks = []
    jobs = []
    parts: dict[str, dict] = {}
    for lo in range(0, n, block):
        hi = min(lo + block, n)
        key = f"search-gen{g:04d}-{lo:08d}-{hi:08d}"
        blocks.append((key, lo, hi))
        if cache is not None:
            d = cache.load_chunk(study, key)
            if d is not None and d.get("candidates") == cands[lo:hi].tolist():
                parts[key] = d
                continue
        jobs.append((key, lo, hi))
    if jobs and workers > 1:
        from ..parallel.work_queue import run_blocks

        run_blocks(
            study.to_json(indent=None),
            str(cache.root),
            cache.block_cells,
            [(key, cands[lo:hi].tolist()) for key, lo, hi in jobs],
            workers=workers,
            start_method="spawn" if study.analysis.backend == "jax" else None,
        )
        for key, lo, hi in jobs:
            d = cache.peek_chunk(study, key)
            if d is None:
                raise RuntimeError(f"work queue produced no chunk for {key}")
            parts[key] = d
    elif jobs:
        for key, lo, hi in jobs:
            objs, feas = evaluate_candidates(
                study, cands[lo:hi], stream=stream, axes=axes
            )
            payload = chunk_payload(cands[lo:hi], objs, feas)
            if cache is not None:
                cache.store_chunk(study, key, payload)
            parts[key] = payload
    objs_parts, feas_parts = [], []
    for key, lo, hi in blocks:
        o, f = _decode_chunk(parts[key])
        objs_parts.append(o)
        feas_parts.append(f)
    return np.concatenate(objs_parts, axis=0), np.concatenate(feas_parts, axis=0)


# ---------------------------------------------------------------------------
# Exhaustive reference (validation subspaces, property tests, the bench)
# ---------------------------------------------------------------------------

def exhaustive_frontier(study, stream=None, block: int = 1 << 14) -> dict:
    """Price EVERY point of the study's search space (streamed in
    blocks); returns the exact feasible frontier and bookkeeping.

    The reference the guided search is validated against — tractable up
    to ~1e6-point subspaces at the engine's batch throughput.
    """
    if stream is None:
        stream = study.workload.resolve()
    axes = resolve_axes(study)
    sizes = [int(ax.values.shape[0]) for ax in axes]
    total = math.prod(sizes)
    feas_X: list[np.ndarray] = []
    feas_F: list[np.ndarray] = []
    n_feasible = 0
    for lo in range(0, total, block):
        hi = min(lo + block, total)
        flat = np.arange(lo, hi)
        cands = np.stack(np.unravel_index(flat, sizes), axis=1).astype(np.int64)
        objs, feas = evaluate_candidates(study, cands, stream=stream, axes=axes)
        n_feasible += int(feas.sum())
        if feas.any():
            # frontier-reduce incrementally: memory stays O(frontier)
            feas_X.append(cands[feas])
            feas_F.append(objs[feas])
            X = np.concatenate(feas_X)
            F = np.concatenate(feas_F)
            m = pareto_mask_batched(F[None])[0]
            feas_X, feas_F = [X[m]], [F[m]]
    X = feas_X[0] if feas_X else np.empty((0, len(axes)), dtype=np.int64)
    F = feas_F[0] if feas_F else np.empty((0, len(study.analysis.search.objectives)))
    order = np.lexsort(F.T[::-1]) if F.shape[0] else np.empty(0, int)
    return {
        "space_size": total,
        "n_feasible": n_feasible,
        "frontier_candidates": X[order],
        "frontier_objectives": F[order],
    }
