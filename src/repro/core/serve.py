"""Serving-traffic engine: price a request stream on every design point.

The paper's 9.14x 3D-vs-2D headline is a single-GEMM peak number; the
question production cares about is *sustained*: how many users does one
3D stack serve when the workload is a mix of compute-bound prefill
bursts and bandwidth-bound decode steps under continuous batching?
This module answers it with the pieces the repo already has:

- ``TrafficSpec``: a seeded, JSON-round-trippable request workload —
  Poisson arrivals at ``arrival_rps``, prompt/output length
  distributions (fixed | uniform | lognormal, truncated to
  ``[1, *_max]``), a ``max_batch`` admission cap, the batching
  ``policy`` ('continuous' | 'static') and a ``chunk_prefill`` token
  budget that interleaves long prompts with running decodes.
- ``ServeSpec``: ties the traffic to the study's model-zoo workload
  (the network is re-lowered per *step token*: one batched decode-step
  GEMM stream with M left symbolic) and to the simulator knobs
  (kv-cache word size, the representative step size the fixed-array
  design search uses, a step-count safety cap).
- ``run_serve``: the ``kind='serve'`` executor. Per design point of
  the study's ``SpaceSpec`` grid it (1) derives the fixed (R, C, L)
  array exactly like ``engine.schedule`` — per-layer optima at a
  representative step, candidates re-evaluated explicitly, the
  count-weighted-best feasible candidate wins — then (2) steps the
  batched request queue (admit -> chunked prefill -> interleaved
  decode -> retire), pricing every step with one vectorized call into
  the shared frequency-aware step pricer (``core.pricing.price_steps``
  over all layers x design points at once), and (3) reduces to
  tokens/s, p50/p99 TTFT, p50/p99 per-output-token latency,
  energy/token and tokens/s/W per design point.

Pricing conventions (documented modeling choices):

- A step with ``m`` total tokens (prefill-chunk tokens + one token per
  running decode) executes the per-token GEMM stream with M = m —
  continuous batching fuses prefill and decode tokens into one batched
  pass, which is exactly the decode-mode lowering of
  ``core.network`` with the batch replaced by the step composition.
- kv-cache traffic uses ``analysis.traffic``'s decode accounting: each
  decode request re-reads its full context
  (``kv_bytes_per_context_token`` x context length) and every new
  token writes one slot; SSM families pay the recurrent-state
  read+write per request (``state_bytes_per_request``). Attention
  score/value products are outside the weight-GEMM model (see
  ``core.network``), so the cache stream is charged as *serialized*
  memory time on the DRAM interface — the stand-in for the un-modeled
  attention kernel, and exactly zero under an unbounded
  ``BandwidthSpec`` (the compute-bound idealization).
- Energy charges each layer's active power over its compute cycles and
  the design's static power over every stalled or idle cycle
  (including arrival gaps), mirroring ``engine.evaluate``'s
  stall-aware energy; tokens/s/W therefore equals generated tokens per
  joule.

Feasibility (thermal + SRAM + the study's ``ConstraintSpec`` caps) is
evaluated on the chosen fixed design at the representative step, so
the usual masks strike serving points exactly like evaluate/pareto
points. Everything is deterministic given ``TrafficSpec.seed`` —
the trace sampler is one ``np.random.default_rng`` with a fixed draw
order — and the per-point state updates are elementwise, so chunking
the design grid (``--cache``/``--resume`` replays finished point
blocks) is bit-identical to one unchunked pass.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from .analytical import FOLD_NAMES, native_fold
from .bandwidth import BandwidthSpec
from .cache import ResultCache
from .engine import DesignGrid, candidate_fixed_designs, evaluate
from .params import (
    VALID_LENGTH_DISTS,
    VALID_SERVE_MAPPINGS,
    VALID_SERVE_POLICIES,
    validate_option,
)
from .ppa import constants as C
from .ppa.power import array_power_batched
from .ppa.thermal import ThermalState, step_temps
from .pricing import (
    DvfsSpec,
    dram_bytes_per_cycle,
    governor_step,
    power_scales,
    price_steps,
)

__all__ = [
    "ServeSpec",
    "TrafficSpec",
    "restore_points",
    "run_serve",
    "sample_trace",
]

#: fields of the per-point payload arrays and their restored dtypes.
_POINT_INT = ("rows", "cols", "tiers", "steps", "tokens_prefilled",
              "tokens_decoded")
_POINT_BOOL = ("valid", "feasible", "feasible_steady")
_POINT_STR = ("dataflow", "tech")
_POINT_FLOAT = (
    "t_max_c", "area_um2", "gen_tok_s", "total_tok_s", "ttft_p50_s",
    "ttft_p99_s", "tpot_p50_s", "tpot_p99_s", "energy_j",
    "energy_per_token_j", "avg_power_w", "tokens_per_s_per_w",
    "makespan_s", "stall_frac", "dram_bytes",
    # transient-mode (thermal='transient') extras; absent on steady runs
    "peak_tok_s", "peak_vs_sustained", "t_max_transient_c",
)
POINT_FIELDS = _POINT_INT + _POINT_BOOL + _POINT_STR + _POINT_FLOAT


@dataclasses.dataclass(frozen=True)
class TrafficSpec:
    """A seeded serving request stream (JSON-round-trippable).

    - ``arrival_rps``: request arrival rate [requests/s] — Poisson
      (exponential inter-arrival gaps).
    - ``n_requests``: trace length [requests].
    - ``prompt_dist``/``prompt_mean``/``prompt_max``: prompt length
      distribution ('fixed' | 'uniform' | 'lognormal'), its mean and
      the truncation bound [tokens]; sampled lengths land in
      ``[1, prompt_max]``. ``output_*``: same for generated lengths
      (the first token counts — a request produces ``output_len``
      tokens, the first at prefill completion).
    - ``sigma``: log-space spread of the lognormal distributions.
    - ``max_batch``: concurrent-request cap of the batching policy.
    - ``policy``: 'continuous' (admit into free slots every step) or
      'static' (drain each batch fully before admitting the next).
    - ``chunk_prefill``: prefill token budget per request per step
      (0 = whole prompt in one step) — chunked prefill interleaves
      long prompts with running decodes.
    - ``seed``: the one RNG seed behind arrivals and lengths.
    """

    arrival_rps: float = 256.0
    n_requests: int = 32
    prompt_dist: str = "lognormal"
    prompt_mean: int = 128
    prompt_max: int = 1024
    output_dist: str = "lognormal"
    output_mean: int = 32
    output_max: int = 256
    sigma: float = 0.6
    max_batch: int = 8
    policy: str = "continuous"
    chunk_prefill: int = 64
    seed: int = 0

    def __post_init__(self):
        validate_option("serve policy", self.policy, VALID_SERVE_POLICIES)
        for name in ("prompt_dist", "output_dist"):
            validate_option(name, getattr(self, name), VALID_LENGTH_DISTS)
        for name in ("arrival_rps", "sigma"):
            v = float(getattr(self, name))
            if not (math.isfinite(v) and v > 0):
                raise ValueError(f"{name} must be a positive finite rate, got {v}")
            object.__setattr__(self, name, v)
        for name in ("n_requests", "prompt_mean", "prompt_max", "output_mean",
                     "output_max", "max_batch"):
            v = int(getattr(self, name))
            if v < 1:
                raise ValueError(f"{name} must be >= 1, got {v}")
            object.__setattr__(self, name, v)
        for kind in ("prompt", "output"):
            mean, mx = getattr(self, f"{kind}_mean"), getattr(self, f"{kind}_max")
            if mean > mx:
                raise ValueError(
                    f"{kind}_mean {mean} exceeds the {kind}_max truncation "
                    f"bound {mx}"
                )
        v = int(self.chunk_prefill)
        if v < 0:
            raise ValueError(f"chunk_prefill must be >= 0 (0 = unchunked), got {v}")
        object.__setattr__(self, "chunk_prefill", v)
        object.__setattr__(self, "seed", int(self.seed))

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "TrafficSpec":
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class ServeSpec:
    """Simulator configuration for ``AnalysisSpec(kind='serve')``.

    The model-zoo workload and the design grid come from the study's
    ``WorkloadSpec`` (kind='network' required: arch + shape) and
    ``SpaceSpec``; this spec adds what serving needs on top:

    - ``traffic``: the ``TrafficSpec`` request stream.
    - ``bytes_kv``: kv-cache word size [bytes] (2 = bf16, matching
      ``analysis.traffic``'s decode accounting).
    - ``design_tokens``: the representative step token count the fixed
      (R, C) design search optimizes for (default:
      ``max_batch + chunk_prefill`` — the steady-state mixed step).
    - ``max_steps``: safety cap on simulation steps (default: derived
      from the trace; a bound no admissible schedule exceeds).
    - ``mapping``: ``'native'`` (default — each step priced at the
      dataflow's native tier mapping, bit-identical to studies written
      before the knob) or ``'tier_fold'`` — every step additionally
      prices the non-native tier folds (``analytical.fold_dims``) and
      takes, per layer and design point, the cheapest SRAM-feasible
      fold by total cycles, so serving rides the fine-grain tier-folded
      mapping exactly like ``engine.schedule``'s tier_fold policy.
    """

    traffic: TrafficSpec | dict = dataclasses.field(default_factory=TrafficSpec)
    bytes_kv: int = 2
    design_tokens: int | None = None
    max_steps: int | None = None
    mapping: str = "native"

    def __post_init__(self):
        if isinstance(self.traffic, dict):
            object.__setattr__(self, "traffic", TrafficSpec.from_dict(self.traffic))
        elif not isinstance(self.traffic, TrafficSpec):
            raise ValueError(
                f"traffic must be a TrafficSpec or dict, "
                f"got {type(self.traffic).__name__}"
            )
        v = int(self.bytes_kv)
        if v < 1:
            raise ValueError(f"bytes_kv must be >= 1 byte, got {v}")
        object.__setattr__(self, "bytes_kv", v)
        for name in ("design_tokens", "max_steps"):
            v = getattr(self, name)
            if v is not None:
                v = int(v)
                if v < 1:
                    raise ValueError(f"{name} must be >= 1, got {v}")
                object.__setattr__(self, name, v)
        validate_option("serve mapping", self.mapping, VALID_SERVE_MAPPINGS)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "ServeSpec":
        return cls(**d)


# ---------------------------------------------------------------------------
# Traffic sampling
# ---------------------------------------------------------------------------

def sample_trace(spec: TrafficSpec) -> dict:
    """Sample the request trace (deterministic given ``spec.seed``).

    Returns ``arrival_s`` (float64 seconds, sorted), ``prompt_lens``
    and ``output_lens`` (int64 tokens, truncated to ``[1, *_max]``).
    The draw order (arrivals, then prompts, then outputs) is part of
    the determinism contract — same seed, bit-identical trace.
    """
    rng = np.random.default_rng(spec.seed)
    arrival_s = np.cumsum(rng.exponential(1.0 / spec.arrival_rps, spec.n_requests))

    def lengths(dist: str, mean: int, bound: int) -> np.ndarray:
        if dist == "fixed":
            v = np.full(spec.n_requests, float(mean))
        elif dist == "uniform":
            v = rng.uniform(1.0, 2.0 * mean - 1.0, spec.n_requests)
        else:  # lognormal with the requested mean
            mu = math.log(mean) - 0.5 * spec.sigma**2
            v = rng.lognormal(mu, spec.sigma, spec.n_requests)
        return np.clip(np.rint(v), 1, bound).astype(np.int64)

    return {
        "arrival_s": arrival_s,
        "prompt_lens": lengths(spec.prompt_dist, spec.prompt_mean, spec.prompt_max),
        "output_lens": lengths(spec.output_dist, spec.output_mean, spec.output_max),
    }


# ---------------------------------------------------------------------------
# Fixed-design derivation (per design point, schedule-style)
# ---------------------------------------------------------------------------

def _eval_kw(study, bandwidth) -> dict:
    kw = dict(
        backend=study.analysis.backend,
        metrics=("perf", "area", "power", "thermal"),
        thermal_limit=study.constraints.thermal_limit_c,
        shard=study.analysis.shard,
        bandwidth=bandwidth,
    )
    if study.analysis.chunk is not None:
        kw["chunk"] = study.analysis.chunk
    return kw


def _per_point(value, n: int) -> np.ndarray:
    """A grid's dataflow/tech attribute as a per-point str array."""
    return np.full(n, value) if isinstance(value, str) else np.asarray(value)


def _derive_designs(
    study, sub: DesignGrid, counts: np.ndarray, bandwidth,
    thermal: str = "steady",
) -> dict:
    """One fixed (R, C, L) array per design point of ``sub``.

    Mirrors ``engine.schedule``'s two passes, per point: the per-layer
    (R, C) optima at the representative step are the candidate set
    (``engine.candidate_fixed_designs``, the shared enumeration);
    candidates are re-evaluated explicitly over all layers and the
    count-weighted-cheapest wins — restricted to candidates feasible
    on every layer when ``constraints.require_feasible`` (falling back
    to the unrestricted optimum, flagged infeasible, when none is).

    ``thermal='transient'`` drops the worst-case steady thermal gate
    from the *selection* mask — the governed simulation decides thermal
    feasibility — while ``feasible_steady`` keeps the steady verdict
    for the peak-vs-sustained comparison.
    """
    kw = _eval_kw(study, bandwidth)
    res = evaluate(sub, **kw)
    Pb = sub.n_points
    df_p = _per_point(sub.dataflow, Pb)
    tech_p = _per_point(sub.tech, Pb)

    cand_rows, cand_cols, owner = candidate_fixed_designs(
        res, sub.tiers, per_point=True
    )
    cand = DesignGrid.explicit(
        sub.workloads,
        rows=cand_rows,
        cols=cand_cols,
        tiers=sub.tiers[owner],
        dataflow=sub.dataflow if isinstance(sub.dataflow, str) else df_p[owner],
        tech=sub.tech if isinstance(sub.tech, str) else tech_p[owner],
    )
    res_c = evaluate(cand, **kw)
    w = counts[:, None].astype(np.float64)
    tot = np.sum(w * res_c.cycles, axis=0)
    valid_c = res_c.valid.all(axis=0)
    feas_steady = study.constraints.mask(res_c).all(axis=0)
    if thermal == "transient" and res_c.within_thermal_budget is not None:
        relaxed = dataclasses.replace(
            res_c,
            within_thermal_budget=np.ones_like(res_c.within_thermal_budget),
        )
        feas_c = study.constraints.mask(relaxed).all(axis=0)
    else:
        feas_c = feas_steady

    pick = np.zeros(Pb, dtype=np.int64)
    for j in range(Pb):
        (idx,) = np.nonzero(owner == j)
        score = np.where(valid_c[idx], tot[idx], np.inf)
        if study.constraints.require_feasible and feas_c[idx].any():
            score = np.where(feas_c[idx], score, np.inf)
        pick[j] = idx[int(np.argmin(score))]

    t_max = (
        np.nanmax(np.where(np.isnan(res_c.t_max_c), -np.inf, res_c.t_max_c), axis=0)
        if res_c.t_max_c is not None
        else np.full(len(owner), np.nan)
    )
    return {
        "rows": cand_rows[pick],
        "cols": cand_cols[pick],
        "tiers": np.asarray(sub.tiers, dtype=np.int64),
        "dataflow": df_p,
        "tech": tech_p,
        "valid": valid_c[pick],
        "feasible": feas_c[pick],
        "feasible_steady": feas_steady[pick],
        "t_max_c": np.asarray(t_max, dtype=np.float64)[pick],
        "area_um2": np.asarray(res_c.area_um2[0], dtype=np.float64)[pick],
        "footprint_um2": np.asarray(
            res_c.footprint_um2[0], dtype=np.float64
        )[pick],
    }


# ---------------------------------------------------------------------------
# Step pricing: one vectorized engine call per simulation step
# ---------------------------------------------------------------------------

class _StepPricer:
    """Prices a (layers x design points) serving step in one batch.

    Precomputes the per-dataflow point groups and the per-point static
    power; ``price(m_tokens, kv_bytes)`` returns the step's total
    cycles, stall cycles, energy [J] and DRAM bytes per design point —
    ``max(compute, memory, vlink)`` per layer (Eqs. 1/2 +
    ``bandwidth.roofline_cycles``), count-weighted over the stream,
    plus the serialized kv-cache service time.

    ``mapping='tier_fold'`` additionally prices every non-native tier
    fold per step and keeps, per (layer, point), the cheapest
    SRAM-feasible fold by total cycles (ties keep the native mapping,
    so tier_fold is never slower than native).
    """

    def __init__(self, designs: dict, K, N, counts, bandwidth: BandwidthSpec,
                 mapping: str = "native"):
        self.rows = designs["rows"]
        self.cols = designs["cols"]
        self.tiers = designs["tiers"]
        self.tech = designs["tech"]
        self.valid = designs["valid"]
        self.K = np.asarray(K, dtype=np.int64)
        self.N = np.asarray(N, dtype=np.int64)
        self.counts = np.asarray(counts, dtype=np.float64)
        self.bw = bandwidth
        self.mapping = mapping
        df = designs["dataflow"]
        self.groups = {
            str(d): np.nonzero(df == d)[0] for d in np.unique(df).tolist()
        }
        self.static_w = np.zeros(self.rows.size)
        for d, idx in self.groups.items():
            pw = array_power_batched(
                1, 1, 1, self.rows[idx], self.cols[idx], self.tiers[idx],
                self.tech[idx], d,
            )
            self.static_w[idx] = pw["static_w"]

    def _price_group(self, d, m, Kc, Nc, R, Cc, L, tech, f, v):
        """One dataflow group's per-(layer, point) step pricing; under
        ``mapping='tier_fold'`` the elementwise cheapest SRAM-feasible
        fold (by total cycles, native winning ties) is returned."""
        pr = price_steps(d, m, Kc, Nc, R, Cc, L, tech, self.bw, f, v)
        if self.mapping != "tier_fold":
            return pr
        keys = ("total_cycles", "compute_cycles", "stall_cycles",
                "total_w", "dram_bytes")
        best = {k: pr[k] for k in keys}
        for fold in FOLD_NAMES:
            if fold == native_fold(d):
                continue
            p = price_steps(d, m, Kc, Nc, R, Cc, L, tech, self.bw, f, v,
                            fold=fold)
            better = (p["total_cycles"] < best["total_cycles"]) & (
                p["sram_need_bytes"] <= self.bw.sram_bytes
            )
            best = {k: np.where(better, p[k], best[k]) for k in keys}
        return best

    def price(self, m_tokens: np.ndarray, kv_bytes: np.ndarray,
              freq_hz=C.FREQ_HZ, vdd_v=C.VDD):
        """Step cycles (at ``freq_hz``), stall cycles, energy [J] and
        DRAM bytes per design point. ``freq_hz``/``vdd_v`` accept
        per-point arrays (the DVFS governor's operating points); the
        scalar default reproduces the 1 GHz pricing bit-for-bit."""
        P = self.rows.size
        step = np.zeros(P)
        stall = np.zeros(P)
        energy = np.zeros(P)
        dram = np.zeros(P)
        act = m_tokens > 0
        cw = self.counts[:, None]
        f_scalar = np.isscalar(freq_hz)
        v_scalar = np.isscalar(vdd_v)
        for d, idx in self.groups.items():
            if not act[idx].any():
                continue
            R, Cc, L = self.rows[idx], self.cols[idx], self.tiers[idx]
            m = np.maximum(m_tokens[idx], 1)  # priced, then masked by act
            Kc, Nc = self.K[:, None], self.N[:, None]
            f = freq_hz if f_scalar else freq_hz[idx]
            v = vdd_v if v_scalar else vdd_v[idx]
            pr = self._price_group(
                d, m[None, :], Kc, Nc, R[None, :], Cc[None, :], L[None, :],
                np.broadcast_to(
                    self.tech[idx][None, :], (self.K.size, idx.size)
                ),
                f, v,
            )
            compute = pr["compute_cycles"]
            w_total = np.sum(cw * pr["total_cycles"], axis=0)
            w_compute = np.sum(cw * compute, axis=0)
            kv_cyc = kv_bytes[idx] / dram_bytes_per_cycle(self.bw, f)
            _, ss = power_scales(f, v)
            step_g = w_total + kv_cyc
            e_active = np.sum(cw * pr["total_w"] * compute, axis=0) / f
            e_stall = self.static_w[idx] * ss * (step_g - w_compute) / f
            a = act[idx]
            step[idx] = np.where(a, step_g, 0.0)
            stall[idx] = np.where(
                a, np.sum(cw * pr["stall_cycles"], axis=0) + kv_cyc, 0.0
            )
            energy[idx] = np.where(a, e_active + e_stall, 0.0)
            dram[idx] = np.where(
                a,
                np.sum(cw * pr["dram_bytes"], axis=0) + kv_bytes[idx],
                0.0,
            )
        # structurally invalid designs serve nothing in finite time
        bad = act & ~self.valid
        step[bad] = np.inf
        stall[bad] = np.inf
        energy[bad] = np.inf
        return step, stall, energy, dram


# ---------------------------------------------------------------------------
# The queue simulator
# ---------------------------------------------------------------------------

def _simulate(designs: dict, K, N, counts, trace: dict, spec: ServeSpec,
              bandwidth: BandwidthSpec, cfg, thermal: str = "steady",
              dvfs: DvfsSpec | None = None,
              thermal_limit: float = C.THERMAL_BUDGET_C) -> dict:
    """Step the batched request queue on every design point at once.

    All per-point state is elementwise (a design point never reads
    another's state), so simulating a subset of points and slicing a
    full run give identical bits — the property the chunk cache and
    ``--resume`` rely on.

    ``thermal='transient'`` threads the DVFS governor through the
    stepping: every step is priced at the per-point governed (f, V)
    operating point, converted back to reference 1 GHz cycles for the
    queue clock, and the lumped RC stack integrates the step's average
    power over its wall-clock duration; the governor reacts to the
    hottest tier after every step. The output then *is* sustained
    serving performance, with ``t_max_transient_c`` (governed
    excursion) and ``dvfs_residency`` (per-state step fractions,
    (P, n_states)) added.
    """
    # deferred: analysis.traffic imports core.ppa, whose package
    # __init__ loads this module — importing at module scope would
    # cycle when repro.analysis is the entry point
    from ..analysis.traffic import (
        kv_bytes_per_context_token,
        state_bytes_per_request,
    )

    tr = spec.traffic
    pricer = _StepPricer(designs, K, N, counts, bandwidth,
                         mapping=spec.mapping)
    P, n = designs["rows"].size, tr.n_requests
    arrival = trace["arrival_s"] * C.FREQ_HZ  # cycles
    prompt = trace["prompt_lens"]
    output = trace["output_lens"]
    kv_tok = kv_bytes_per_context_token(cfg, spec.bytes_kv)
    ssm_req = state_bytes_per_request(cfg)
    chunk = tr.chunk_prefill if tr.chunk_prefill else int(prompt.max())

    state = np.zeros((P, n), dtype=np.int8)  # 0 wait, 1 prefill, 2 decode, 3 done
    rem_pf = np.broadcast_to(prompt, (P, n)).copy()
    rem_out = np.broadcast_to(output, (P, n)).copy()
    t = np.zeros(P)
    t_first = np.full((P, n), np.inf)
    t_done = np.full((P, n), np.inf)
    tok_pf = np.zeros(P, dtype=np.int64)
    tok_dec = np.zeros(P, dtype=np.int64)
    steps = np.zeros(P, dtype=np.int64)
    total_cyc = np.zeros(P)
    stall_cyc = np.zeros(P)
    energy = np.zeros(P)
    dram = np.zeros(P)

    governed = thermal == "transient"
    if governed:
        if dvfs is None:
            dvfs = DvfsSpec()
        freqs = dvfs.freqs_hz()
        vdds = np.asarray(dvfs.vdds_v, dtype=np.float64)
        _, ss_states = dvfs.scales()
        gstate = np.full(P, dvfs.n_states - 1, dtype=np.int64)
        tstate = ThermalState.init(
            designs["footprint_um2"] * 1e-6,
            designs["tiers"],
            designs["tech"],
            (designs["rows"] * designs["cols"]).astype(np.float64),
        )
        tiers_f = designs["tiers"].astype(np.float64)
        resid = np.zeros((P, dvfs.n_states))
        n_ran = np.zeros(P)
        t_hot = np.full(P, -np.inf)
        rows_p = np.arange(P)

    cap = spec.max_steps or int(
        n * (-(-int(prompt.max()) // chunk) + int(output.max()) + 2) + 16
    )
    it = 0
    while (state < 3).any():
        it += 1
        if it > cap:
            raise RuntimeError(
                f"serve simulation exceeded {cap} steps — raise "
                f"ServeSpec.max_steps or check the traffic spec"
            )
        waiting = state == 0
        active = (state == 1) | (state == 2)
        has_act = active.any(axis=1)
        # Idle points jump to their next arrival (static power still burns).
        next_arr = np.min(np.where(waiting, arrival[None, :], np.inf), axis=1)
        gap = np.where(~has_act & (next_arr > t), next_arr - t, 0.0)
        with np.errstate(invalid="ignore"):
            static_now = (
                pricer.static_w * ss_states[gstate] if governed
                else pricer.static_w
            )
            e_gap = np.where(gap > 0, static_now * gap / C.FREQ_HZ, 0.0)
            energy += e_gap
        t = t + gap
        # Admission, in arrival order, into the policy's free slots.
        slots = tr.max_batch - active.sum(axis=1)
        if tr.policy == "static":
            slots = np.where(has_act, 0, tr.max_batch)
        elig = waiting & (arrival[None, :] <= t[:, None])
        admit = elig & (np.cumsum(elig, axis=1) <= slots[:, None])
        state = np.where(admit, np.int8(1), state)
        # Step composition: chunked prefill + one token per decode.
        pf = state == 1
        dec = state == 2
        pf_tok = np.where(pf, np.minimum(rem_pf, chunk), 0)
        n_pf = pf_tok.sum(axis=1)
        n_dec = dec.sum(axis=1)
        m = n_pf + n_dec
        ctx = np.where(dec, prompt[None, :] + (output[None, :] - rem_out), 0)
        kv_bytes = (ctx.sum(axis=1) + n_dec + n_pf) * kv_tok + n_dec * ssm_req
        if governed:
            f_cur = freqs[gstate]
            step, stl, e, db = pricer.price(m, kv_bytes, f_cur, vdds[gstate])
            # queue time is kept in reference 1 GHz cycles: a step at a
            # throttled clock costs proportionally more of them.
            scale = C.FREQ_HZ / f_cur
            step = step * scale
            stl = stl * scale
        else:
            step, stl, e, db = pricer.price(m, kv_bytes)
        t_new = t + step
        ran = m > 0
        if governed:
            with np.errstate(invalid="ignore", divide="ignore"):
                dt_s = (gap + np.where(ran, step, 0.0)) / C.FREQ_HZ
                e_iter = e_gap + np.where(ran, e, 0.0)
                upd = (dt_s > 0) & np.isfinite(dt_s)
                dt_safe = np.where(upd, dt_s, 1.0)
                p_avg = np.where(
                    upd & np.isfinite(e_iter), e_iter / dt_safe, 0.0
                )
                q = np.where(
                    tstate.alive, (p_avg / tiers_f)[:, None], 0.0
                )
                t_next = step_temps(tstate, q, dt_safe).temps_c
                tstate = dataclasses.replace(
                    tstate,
                    temps_c=np.where(upd[:, None], t_next, tstate.temps_c),
                )
            t_hot = np.fmax(t_hot, tstate.t_max_c)
            resid[rows_p[ran], gstate[ran]] += 1.0
            n_ran += ran
            gstate = governor_step(gstate, tstate.t_max_c, thermal_limit, dvfs)
        steps += ran
        total_cyc += np.where(ran, step, 0.0)
        stall_cyc += np.where(ran, stl, 0.0)
        energy += np.where(ran, e, 0.0)
        dram += np.where(ran, db, 0.0)
        tok_pf += n_pf
        tok_dec += n_dec
        # Progress: prefill completions emit their first token this step.
        rem_pf = rem_pf - pf_tok
        done_pf = pf & (rem_pf == 0)
        t_first = np.where(done_pf, t_new[:, None], t_first)
        rem_out = rem_out - (done_pf | dec)
        tok_dec += done_pf.sum(axis=1)
        state = np.where(done_pf, np.int8(2), state)
        finished = (state == 2) & (rem_out == 0)
        t_done = np.where(finished, t_new[:, None], t_done)
        state = np.where(finished, np.int8(3), state)
        t = t_new

    with np.errstate(invalid="ignore", divide="ignore"):
        makespan = t_done.max(axis=1) / C.FREQ_HZ
        ttft = (t_first - arrival[None, :]) / C.FREQ_HZ
        tokens_out = int(output.sum())
        tokens_in = int(prompt.sum())
        multi = output > 1
        if multi.any():
            tpot = (t_done[:, multi] - t_first[:, multi]) / (
                (output[multi] - 1)[None, :] * C.FREQ_HZ
            )
            tpot_p50 = np.percentile(tpot, 50, axis=1)
            tpot_p99 = np.percentile(tpot, 99, axis=1)
        else:
            tpot_p50 = np.full(P, np.nan)
            tpot_p99 = np.full(P, np.nan)
        gen_tok_s = tokens_out / makespan
        avg_power = energy / makespan
        out = {
            "gen_tok_s": gen_tok_s,
            "total_tok_s": (tokens_in + tokens_out) / makespan,
            "ttft_p50_s": np.percentile(ttft, 50, axis=1),
            "ttft_p99_s": np.percentile(ttft, 99, axis=1),
            "tpot_p50_s": tpot_p50,
            "tpot_p99_s": tpot_p99,
            "energy_j": energy,
            "energy_per_token_j": energy / tokens_out,
            "avg_power_w": avg_power,
            "tokens_per_s_per_w": gen_tok_s / avg_power,
            "makespan_s": makespan,
            "steps": steps,
            "stall_frac": stall_cyc / total_cyc,
            "dram_bytes": dram,
            "tokens_prefilled": tok_pf,
            "tokens_decoded": tok_dec,
        }
        if governed:
            out["t_max_transient_c"] = np.where(
                designs["valid"], t_hot, np.nan
            )
            out["dvfs_residency"] = resid / np.maximum(n_ran, 1.0)[:, None]
    return out


# ---------------------------------------------------------------------------
# Payload assembly / restore
# ---------------------------------------------------------------------------

def restore_points(d: dict) -> dict:
    """JSON-decoded per-point dict -> typed numpy arrays (the serve
    payload's analogue of ``EvalResult.from_dict``)."""
    out = {}
    for k, v in d.items():
        if isinstance(v, np.ndarray):
            out[k] = v
        elif k in _POINT_INT:
            out[k] = np.asarray(v, dtype=np.int64)
        elif k in _POINT_BOOL:
            out[k] = np.asarray(v, dtype=bool)
        elif k in _POINT_STR:
            out[k] = np.asarray(v)
        else:
            out[k] = np.asarray(v, dtype=np.float64)
    return out


def _summarize(points: dict, require_feasible: bool) -> dict:
    """Best-3D vs best-2D on tokens/s/W over the (feasible) points."""
    ok = points["feasible"] if require_feasible else points["valid"]
    is2d = (points["tiers"] == 1) | (points["tech"] == "2d")
    eff = np.where(ok, points["tokens_per_s_per_w"], -np.inf)

    def best(mask):
        e = np.where(mask, eff, -np.inf)
        if not np.isfinite(e.max()):
            return None
        i = int(np.argmax(e))
        return {
            "point": i,
            "design": [int(points["rows"][i]), int(points["cols"][i]),
                       int(points["tiers"][i])],
            "tech": str(points["tech"][i]),
            "tokens_per_s_per_w": float(points["tokens_per_s_per_w"][i]),
            "gen_tok_s": float(points["gen_tok_s"][i]),
            "ttft_p99_s": float(points["ttft_p99_s"][i]),
        }

    b3, b2 = best(~is2d), best(is2d)
    return {
        "n_feasible": int(points["feasible"].sum()),
        "best_3d": b3,
        "best_2d": b2,
        "win_3d_vs_2d": (
            b3["tokens_per_s_per_w"] / b2["tokens_per_s_per_w"]
            if b3 and b2 and b2["tokens_per_s_per_w"] > 0
            else None
        ),
    }


def run_serve(study, stream, cache: ResultCache | None = None) -> dict:
    """Execute a ``kind='serve'`` study; returns the payload dict.

    ``stream`` is the study's resolved workload (its arch/shape naming
    is the contract; serving re-lowers the network per step token).
    With a cache, consecutive design-point blocks are the chunk unit
    (``points-<lo>-<hi>``, like ``Study._evaluate``): each block
    derives its fixed designs and simulates independently, so
    ``--resume`` recomputes exactly the missing points and the stitched
    payload is bit-identical to an uncached run.
    """
    from .study import _jsonify  # deferred: study imports this module

    spec: ServeSpec = study.analysis.serve
    tr = spec.traffic
    if study.workload.kind != "network":
        raise ValueError(
            "kind='serve' needs a kind='network' workload (a model-zoo arch "
            "+ shape) — the traffic simulator prices that network's per-step "
            "GEMM stream"
        )
    from ..configs import REGISTRY, SHAPES

    from .network import lower_network

    cfg = REGISTRY[study.workload.arch]
    # Per-token GEMM structure: one decode step at batch 1 — M becomes
    # the step's token count, counts/K/N are the per-step stream.
    step_shape = dataclasses.replace(
        SHAPES[study.workload.shape], global_batch=1, mode="decode"
    )
    per_tok = lower_network(cfg, step_shape)
    K = per_tok.workloads[:, 1]
    N = per_tok.workloads[:, 2]
    counts = per_tok.counts

    bandwidth = study.analysis.bandwidth or BandwidthSpec()
    thermal = study.analysis.thermal
    dvfs = study.analysis.dvfs
    if thermal == "transient" and dvfs is None:
        dvfs = DvfsSpec()
    m_rep = spec.design_tokens or (tr.max_batch + tr.chunk_prefill)
    wl_rep = np.column_stack(
        [np.full(K.size, m_rep, dtype=np.int64), K, N]
    )
    grid = study.space.to_grid(wl_rep)
    trace = sample_trace(tr)
    P = grid.n_points

    block = P if cache is None else max(1, cache.block_cells // max(tr.n_requests, 1))
    parts = []
    for lo in range(0, P, max(block, 1)):
        hi = min(lo + block, P)
        key = f"points-{lo:010d}-{hi:010d}"
        d = cache.load_chunk(study, key) if cache is not None else None
        if d is None:
            sub = grid.subset(lo, hi)
            designs = _derive_designs(study, sub, counts, bandwidth, thermal)
            metrics = _simulate(designs, K, N, counts, trace, spec, bandwidth, cfg)
            d = {k: designs[k] for k in
                 ("rows", "cols", "tiers", "dataflow", "tech", "valid",
                  "feasible", "t_max_c", "area_um2")}
            if thermal == "transient":
                gov = _simulate(
                    designs, K, N, counts, trace, spec, bandwidth, cfg,
                    thermal="transient", dvfs=dvfs,
                    thermal_limit=study.constraints.thermal_limit_c,
                )
                d["feasible_steady"] = designs["feasible_steady"]
                d["peak_tok_s"] = metrics["gen_tok_s"]
                d.update(gov)
                with np.errstate(invalid="ignore", divide="ignore"):
                    d["peak_vs_sustained"] = (
                        d["peak_tok_s"] / gov["gen_tok_s"]
                    )
                # governed verdict replaces the worst-case steady gate
                d["feasible"] = (
                    designs["feasible"]
                    & np.isfinite(d["t_max_transient_c"])
                    & (d["t_max_transient_c"]
                       < study.constraints.thermal_limit_c)
                )
            else:
                d.update(metrics)
            if cache is not None:
                cache.store_chunk(study, key, _jsonify(d))
        parts.append(restore_points(d))
    points = {
        k: np.concatenate([p[k] for p in parts]) for k in parts[0]
    }
    extra = (
        {"thermal": "transient", "dvfs": dvfs.to_dict()}
        if thermal == "transient"
        else {}
    )
    return {
        "arch": study.workload.arch,
        "shape": study.workload.shape,
        **extra,
        "n_points": P,
        "n_gemm_layers": int(K.size),
        "design_tokens": int(m_rep),
        "trace": {
            "n_requests": tr.n_requests,
            "tokens_in": int(trace["prompt_lens"].sum()),
            "tokens_out": int(trace["output_lens"].sum()),
            "prompt_min": int(trace["prompt_lens"].min()),
            "prompt_max": int(trace["prompt_lens"].max()),
            "output_min": int(trace["output_lens"].min()),
            "output_max": int(trace["output_lens"].max()),
            "last_arrival_s": float(trace["arrival_s"][-1]),
        },
        "points": points,
        "summary": _summarize(points, study.constraints.require_feasible),
    }
