"""One front door over the DSE stack: declarative, serializable studies.

The paper's results (Figs. 5-8, Table I) are joint sweeps over
workload x (MAC budget, tiers, dataflow, tech) under a thermal
constraint. This module makes such a sweep a *first-class artifact*: a
``Study`` is four small JSON-round-trippable specs —

- ``WorkloadSpec``: what runs — a raw GEMM list, a model-zoo network
  lowered via ``core.network.lower_network``, or the Fig.-7 random
  workload generator (``core.dse.random_workloads``);
- ``SpaceSpec``: the design space — MAC budgets x tiers (product or
  parallel explicit points), optional fixed rows/cols, dataflow, tech;
- ``ConstraintSpec``: thermal junction limit, optional area / power /
  MAC-budget caps, and whether optima must be feasible;
- ``AnalysisSpec``: which question to ask — ``evaluate`` | ``schedule``
  | ``pareto`` | ``advise`` | ``sweep`` (the paper figures);

— compiled by ``Study.run()`` into **one** pass through the existing
batched engine (``core.engine``) and returned as a versioned
``StudyResult`` that echoes the inputs and serializes to JSON
(``save``/``load``/``to_json``/``from_json``). The legacy entry points
(``dse.fig5_sweep``/``fig6_sweep``/``fig7_scatter``,
``advisor.rank_candidates``, the report generator, the examples and
benchmarks) are thin wrappers over these specs, and ``python -m repro``
exposes the same studies from the shell:

    PYTHONPATH=src python -m repro example-spec evaluate > spec.json
    PYTHONPATH=src python -m repro run spec.json --out artifact.json

In-memory, ``StudyResult.payload`` keeps the engine's typed objects
(``EvalResult`` / ``NetworkReport`` / numpy arrays) so the facade adds
no conversion cost over a direct engine call; JSON conversion happens
only in ``to_dict``/``to_json``.
"""

from __future__ import annotations

import dataclasses
import json
import math
import pathlib

import numpy as np

from . import calibrate as _calibrate
from .bandwidth import BOUND_NAMES, BandwidthSpec
from .cache import ResultCache
from .calibrate import CalibrateSpec, CalibratedBandwidth
from .engine import (
    MESH_STRATEGIES,
    DesignGrid,
    EvalResult,
    NetworkReport,
    _adaptive_chunk,
    evaluate,
    optimal_tiers_batched,
    schedule,
)
from .params import (
    VALID_BACKENDS,
    VALID_DATAFLOWS,
    VALID_METRICS,
    VALID_MODES,
    VALID_OBJECTIVES,
    VALID_SCHEDULE_POLICIES,
    VALID_TECHS,
    VALID_THERMAL_MODES,
    validate_option,
    validate_options,
)
from .ppa import constants as C
from .pricing import DvfsSpec
from .search import SearchSpec, run_search
from .serve import ServeSpec, TrafficSpec, restore_points, run_serve

__all__ = [
    "ANALYSIS_KINDS",
    "SPEC_VERSION",
    "SWEEP_FIGURES",
    "WORKLOAD_KINDS",
    "AnalysisSpec",
    "BandwidthSpec",
    "CalibrateSpec",
    "CalibratedBandwidth",
    "ConstraintSpec",
    "DvfsSpec",
    "SearchSpec",
    "ServeSpec",
    "SpaceSpec",
    "Study",
    "StudyResult",
    "TrafficSpec",
    "WorkloadSpec",
]

#: bumped whenever the spec/artifact schema changes incompatibly.
SPEC_VERSION = 1

WORKLOAD_KINDS = ("gemms", "network", "random")
ANALYSIS_KINDS = (
    "evaluate", "schedule", "pareto", "advise", "sweep", "roofline", "search",
    "calibrate", "serve",
)
SWEEP_FIGURES = ("fig5", "fig6", "fig7")


# ---------------------------------------------------------------------------
# Normalization / JSON helpers
# ---------------------------------------------------------------------------

def _int_tuple(name: str, v) -> tuple[int, ...] | None:
    if v is None:
        return None
    try:
        return tuple(int(x) for x in np.atleast_1d(np.asarray(v)).tolist())
    except (TypeError, ValueError):
        raise ValueError(f"{name} must be an int sequence, got {v!r}") from None


def _str_or_tuple(v):
    return v if isinstance(v, str) else tuple(str(x) for x in v)


def _jsonify(v):
    """Engine objects / numpy -> JSON-compatible plain Python.

    Non-finite floats become the strings ``"Infinity"`` / ``"-Infinity"``
    / ``"NaN"`` so artifacts are *strict* JSON (parseable by jq /
    JavaScript, not just Python); ``float(...)`` and
    ``np.asarray(..., dtype=float)`` on the decode paths restore them
    exactly. ``to_json`` serializes with ``allow_nan=False`` so a raw
    token can never slip through.
    """
    if isinstance(v, (EvalResult, NetworkReport, DesignGrid)):
        return _jsonify(v.to_dict())
    if dataclasses.is_dataclass(v) and not isinstance(v, type):
        return _jsonify(dataclasses.asdict(v))
    if isinstance(v, dict):
        return {str(k): _jsonify(x) for k, x in v.items()}
    if isinstance(v, np.ndarray):
        if np.issubdtype(v.dtype, np.floating) and not np.isfinite(v).all():
            return _jsonify(v.tolist())
        return v.tolist()
    if isinstance(v, (list, tuple)):
        return [_jsonify(x) for x in v]
    if isinstance(v, np.generic):
        return _jsonify(v.item())
    if isinstance(v, float) and not math.isfinite(v):
        return "NaN" if math.isnan(v) else ("Infinity" if v > 0 else "-Infinity")
    return v


# ---------------------------------------------------------------------------
# Spec layer
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _ResolvedWorkload:
    """The stream-shaped object every analysis consumes (duck-typed to
    ``core.network.WorkloadStream`` for ``engine.schedule``)."""

    workloads: np.ndarray
    counts: np.ndarray
    arch: str
    shape: str
    mode: str = "gemm"


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """What runs. ``kind``:

    - ``'gemms'``: explicit ``gemms`` = ((M, K, N), ...) rows with
      optional per-row ``counts`` (multiplicities for ``schedule``);
    - ``'network'``: the model-zoo config ``arch`` lowered for shape
      ``shape`` via ``core.network.lower_network``;
    - ``'random'``: ``n`` Fig.-7-style random workloads from
      ``core.dse.random_workloads(n, seed)``.
    """

    kind: str = "gemms"
    gemms: tuple[tuple[int, int, int], ...] = ()
    counts: tuple[int, ...] | None = None
    arch: str | None = None
    shape: str | None = None
    n: int = 300
    seed: int = 0

    def __post_init__(self):
        validate_option("workload kind", self.kind, WORKLOAD_KINDS)
        gemms = ()
        if len(self.gemms):
            arr = np.atleast_2d(np.asarray(self.gemms, dtype=np.int64))
            if arr.ndim != 2 or arr.shape[1] != 3:
                raise ValueError(
                    f"gemms must be (M, K, N) rows, got shape {arr.shape}"
                )
            gemms = tuple(tuple(int(x) for x in row) for row in arr.tolist())
        object.__setattr__(self, "gemms", gemms)
        object.__setattr__(self, "counts", _int_tuple("counts", self.counts))
        object.__setattr__(self, "n", int(self.n))
        object.__setattr__(self, "seed", int(self.seed))
        if self.kind == "gemms":
            if not self.gemms:
                raise ValueError("kind='gemms' needs gemms = ((M, K, N), ...) rows")
            if self.counts is not None and len(self.counts) != len(self.gemms):
                raise ValueError(
                    f"counts length {len(self.counts)} != {len(self.gemms)} gemms"
                )
        elif self.kind == "network":
            from ..configs import REGISTRY, SHAPES  # deferred: registry import

            validate_option("arch", self.arch, tuple(sorted(REGISTRY)))
            validate_option("shape", self.shape, tuple(sorted(SHAPES)))
        elif self.n < 1:
            raise ValueError(f"kind='random' needs n >= 1, got {self.n}")

    def resolve(self):
        """-> a stream (``workloads``/``counts``/naming attributes)."""
        if self.kind == "network":
            from ..configs import REGISTRY, SHAPES
            from .network import lower_network

            return lower_network(REGISTRY[self.arch], SHAPES[self.shape])
        if self.kind == "random":
            from .dse import random_workloads

            wl = random_workloads(self.n, self.seed)
            return _ResolvedWorkload(
                workloads=wl,
                counts=np.ones(wl.shape[0], dtype=np.int64),
                arch=f"random-{self.n}",
                shape=f"seed-{self.seed}",
            )
        wl = np.asarray(self.gemms, dtype=np.int64)
        counts = (
            np.asarray(self.counts, dtype=np.int64)
            if self.counts is not None
            else np.ones(wl.shape[0], dtype=np.int64)
        )
        return _ResolvedWorkload(
            workloads=wl, counts=counts, arch="gemms", shape=f"{wl.shape[0]}x3"
        )

    def to_dict(self) -> dict:
        return _jsonify(dataclasses.asdict(self))

    @classmethod
    def from_dict(cls, d: dict) -> "WorkloadSpec":
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class SpaceSpec:
    """The design space. ``layout='product'`` crosses ``mac_budgets`` x
    ``tiers`` (budget-major, like ``DesignGrid.product``);
    ``layout='explicit'`` zips the per-point arrays in parallel. Fixed
    per-tier shapes (``rows``/``cols``) skip the (R, C) search."""

    mac_budgets: tuple[int, ...] | None = (2**14, 2**16, 2**18)
    tiers: tuple[int, ...] = tuple(range(1, 17))
    rows: tuple[int, ...] | None = None
    cols: tuple[int, ...] | None = None
    dataflow: str | tuple[str, ...] = "dos"
    tech: str | tuple[str, ...] = "tsv"
    mode: str = "opt"
    layout: str = "product"

    def __post_init__(self):
        for name in ("mac_budgets", "tiers", "rows", "cols"):
            object.__setattr__(self, name, _int_tuple(name, getattr(self, name)))
        for name in ("dataflow", "tech"):
            object.__setattr__(self, name, _str_or_tuple(getattr(self, name)))
        validate_options("dataflow", self.dataflow, VALID_DATAFLOWS)
        validate_options("tech", self.tech, VALID_TECHS)
        validate_option("mode", self.mode, VALID_MODES)
        validate_option("layout", self.layout, ("product", "explicit"))
        if (self.rows is None) != (self.cols is None):
            raise ValueError("rows and cols must be given together")
        if self.rows is None and self.mac_budgets is None:
            raise ValueError("need either mac_budgets or explicit rows+cols")

    def _df_tech(self) -> dict:
        return {
            name: (v if isinstance(v, str) else np.asarray(v))
            for name, v in (("dataflow", self.dataflow), ("tech", self.tech))
        }

    def to_grid(self, workloads) -> DesignGrid:
        kw = dict(self._df_tech(), mode=self.mode)
        if self.rows is not None:
            return DesignGrid.explicit(
                workloads, rows=self.rows, cols=self.cols, tiers=self.tiers, **kw
            )
        if self.layout == "product":
            return DesignGrid.product(
                workloads, mac_budgets=self.mac_budgets, tiers=self.tiers, **kw
            )
        return DesignGrid(
            workloads=workloads, tiers=self.tiers, mac_budgets=self.mac_budgets, **kw
        )

    def to_dict(self) -> dict:
        return _jsonify(dataclasses.asdict(self))

    @classmethod
    def from_dict(cls, d: dict) -> "SpaceSpec":
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class ConstraintSpec:
    """Feasibility constraints. The thermal limit [degC] feeds the
    engine's first-class mask; the optional caps additionally strike
    design points whose provisioned MAC budget [MACs] / silicon area
    [um^2] / average power [W] / minimal SRAM working set [KiB per
    tier] overshoot (reported as ``constraint_mask`` in the payload).
    ``max_sram_kib_per_tier`` is the capacity cap: it needs the
    bandwidth model active (``AnalysisSpec.bandwidth``) so
    ``sram_need_bytes`` exists to compare against.
    ``require_feasible=False`` lets optima/frontiers ignore the mask
    (ablations)."""

    thermal_limit_c: float = C.THERMAL_BUDGET_C
    max_mac_budget: int | None = None
    max_area_um2: float | None = None
    max_power_w: float | None = None
    max_sram_kib_per_tier: float | None = None
    require_feasible: bool = True

    def __post_init__(self):
        object.__setattr__(self, "thermal_limit_c", float(self.thermal_limit_c))
        if self.max_mac_budget is not None:
            object.__setattr__(self, "max_mac_budget", int(self.max_mac_budget))
        for name in ("max_area_um2", "max_power_w", "max_sram_kib_per_tier"):
            v = getattr(self, name)
            if v is not None:
                object.__setattr__(self, name, float(v))
        object.__setattr__(self, "require_feasible", bool(self.require_feasible))

    @property
    def has_caps(self) -> bool:
        return any(
            v is not None
            for v in (self.max_mac_budget, self.max_area_um2, self.max_power_w,
                      self.max_sram_kib_per_tier)
        )

    def mask(self, res: EvalResult) -> np.ndarray:
        """(W, P) bool: engine feasibility AND every requested cap."""
        m = res.feasible
        grid = res.grid
        if self.max_mac_budget is not None:
            b = (
                grid.mac_budgets
                if grid.mac_budgets is not None
                else grid.rows * grid.cols * grid.tiers
            )
            m = m & (b <= self.max_mac_budget)[None, :]
        if self.max_sram_kib_per_tier is not None:
            if res.sram_need_bytes is None:
                raise ValueError(
                    "max_sram_kib_per_tier needs the bandwidth model active "
                    "(set AnalysisSpec.bandwidth) so sram_need_bytes exists"
                )
            m = m & (res.sram_need_bytes <= self.max_sram_kib_per_tier * 1024.0)
        for cap, metric in (
            (self.max_area_um2, "area_um2"),
            (self.max_power_w, "power_w"),
        ):
            if cap is None:
                continue
            v = getattr(res, metric)
            if v is None:
                raise ValueError(
                    f"constraint on {metric} needs that metric evaluated "
                    f"(add the matching group to AnalysisSpec.metrics)"
                )
            with np.errstate(invalid="ignore"):
                m = m & (np.nan_to_num(v, nan=np.inf) <= cap)
        return m

    def to_dict(self) -> dict:
        return _jsonify(dataclasses.asdict(self))

    @classmethod
    def from_dict(cls, d: dict) -> "ConstraintSpec":
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class AnalysisSpec:
    """Which question the study asks.

    - ``'evaluate'``: every (workload, design point) metric group in
      ``metrics`` (one batched ``engine.evaluate``).
    - ``'pareto'``: evaluate + the per-workload Pareto frontier over
      ``objectives`` (minimized), feasibility-restricted.
    - ``'schedule'``: the workload as ONE network stream through
      ``engine.schedule`` (per-layer-optimal vs fixed-design policies).
    - ``'advise'``: the TPU-mesh advisor — rank the four sharding
      strategies for every GEMM on a mesh axis of size ``axis``; with
      ``mac_budget`` set, ``shard_K`` (the 3D-stacked dOS mapping) is
      thermally struck when infeasible. Extra roofline knobs go in
      ``params``.
    - ``'sweep'``: a paper figure (``figure`` in fig5|fig6|fig7) over
      the study's space.
    - ``'roofline'``: evaluate under the (required) ``bandwidth``
      memory system and classify every design point as compute- /
      memory- / vlink-bound, with the stall breakdown in the payload.
    - ``'search'``: guided Pareto search (``core.search``) over the
      space's axes (plus the ``search`` spec's optional memory-system
      axes) — successive halving + evolutionary proposals, one engine
      batch per generation; needs a ``search`` ``SearchSpec``.
      ``workers`` (an execution knob, like backend/chunk/shard: never
      part of the cache key) farms each generation's missing cache
      blocks to N worker processes (``parallel.work_queue``).
    - ``'calibrate'``: measure the real kernels over ``calibrate``'s
      (a ``core.calibrate.CalibrateSpec``, defaulted when omitted)
      shape grid and fit the roofline model to the timings; the
      payload's ``artifact`` is a ``CalibratedBandwidth`` any other
      study accepts via ``bandwidth=``. The workload spec is ignored
      (the "workload" IS the calibration grid); each measured shape is
      one cache chunk, so ``--resume`` replays finished shapes.
    - ``'serve'``: the serving-traffic simulator (``core.serve``,
      defaulted ``serve`` ``ServeSpec`` when omitted) — step a seeded
      batched request queue (admit -> chunked prefill -> interleaved
      decode -> retire) on every design point of the space, pricing
      each step through the bandwidth-aware engine, and reduce to
      tokens/s, p50/p99 TTFT + per-output-token latency, energy/token
      and tokens/s/W per point. Needs a ``kind='network'`` workload;
      design-point blocks are the cache chunks (``--resume`` replays
      finished points bit-for-bit). A ``CalibratedBandwidth`` artifact
      passed as ``bandwidth=`` prices traffic on fitted constants.

    ``bandwidth`` (a ``core.bandwidth.BandwidthSpec`` or its dict
    form) attaches the bandwidth-aware runtime model to ANY kind:
    evaluate/pareto/sweep results gain ``stall_cycles``/``bound`` and
    the SRAM feasibility mask, schedule reduces over stalled cycles,
    and advise maps a finite ``dram_gbs`` [GB/s] onto the mesh
    advisor's HBM term. ``None`` (default) keeps the compute-bound
    model bit-for-bit.

    ``thermal`` selects the thermal model: ``'steady'`` (default) gates
    on the worst-case lumped steady state at the fixed 1 GHz clock —
    bit-identical to studies written before the knob existed — while
    ``'transient'`` time-steps the same RC stack under a discrete DVFS
    governor (``dvfs``, a ``core.pricing.DvfsSpec`` or its dict form,
    defaulted when omitted) and reports *sustained* performance:
    evaluate/pareto/roofline points gain ``sustained_per_s`` /
    ``peak_vs_sustained`` / ``t_max_transient_c`` / ``dvfs_residency``,
    schedule reports the governed replay of its fixed design, and
    serve's queue stepping is governed end-to-end (tokens/s *is*
    sustained). ``dvfs`` without ``thermal='transient'`` is an error.

    ``policies`` (schedule studies only) selects which scheduling
    policies ``engine.schedule`` reports. ``None`` (default) keeps the
    engine default — ``('per_layer', 'fixed')``, bit-identical to
    studies written before the knob existed; add ``'tier_fold'`` to
    also price the fine-grain tier-folded mapping (each layer's GEMM
    partitioned across tiers along its best dimension, vlink-priced).

    ``chunk=None`` uses the engine default, except for network
    workloads where the adaptive bound kicks in (token-sized M dims).
    ``shard`` is the engine's device-sharding knob (``'auto'`` = split
    the search over all local JAX devices; results are unchanged).
    """

    kind: str = "evaluate"
    metrics: tuple[str, ...] = ("perf", "area", "power", "thermal")
    backend: str = "numpy"
    chunk: int | None = None
    shard: int | str | None = None
    objectives: tuple[str, ...] = ("cycles", "area_um2", "power_w")
    axis: int = 16
    mac_budget: int | None = None
    figure: str | None = None
    bandwidth: BandwidthSpec | dict | None = None
    search: SearchSpec | dict | None = None
    calibrate: CalibrateSpec | dict | None = None
    serve: ServeSpec | dict | None = None
    thermal: str = "steady"
    dvfs: DvfsSpec | dict | None = None
    policies: tuple[str, ...] | None = None
    workers: int | None = None
    params: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        validate_option("analysis kind", self.kind, ANALYSIS_KINDS)
        validate_option("backend", self.backend, VALID_BACKENDS)
        if self.search is not None and not isinstance(self.search, SearchSpec):
            if not isinstance(self.search, dict):
                raise ValueError(
                    f"search must be a SearchSpec or dict, "
                    f"got {type(self.search).__name__}"
                )
            object.__setattr__(self, "search", SearchSpec.from_dict(self.search))
        if self.kind == "search":
            if self.search is None:
                raise ValueError(
                    "kind='search' needs a search= SearchSpec (objectives, "
                    "generations, population, refinement schedule, seed)"
                )
            if self.bandwidth is None and (
                self.search.dram_gbs is not None or self.search.sram_kib is not None
            ):
                raise ValueError(
                    "the search's dram_gbs/sram_kib memory-system axes need "
                    "a bandwidth= spec (the model they parameterize)"
                )
        if self.calibrate is not None and not isinstance(self.calibrate, CalibrateSpec):
            if not isinstance(self.calibrate, dict):
                raise ValueError(
                    f"calibrate must be a CalibrateSpec or dict, "
                    f"got {type(self.calibrate).__name__}"
                )
            object.__setattr__(
                self, "calibrate", CalibrateSpec.from_dict(self.calibrate)
            )
        if self.kind == "calibrate" and self.calibrate is None:
            object.__setattr__(self, "calibrate", CalibrateSpec())
        if self.serve is not None and not isinstance(self.serve, ServeSpec):
            if not isinstance(self.serve, dict):
                raise ValueError(
                    f"serve must be a ServeSpec or dict, "
                    f"got {type(self.serve).__name__}"
                )
            object.__setattr__(self, "serve", ServeSpec.from_dict(self.serve))
        if self.kind == "serve" and self.serve is None:
            object.__setattr__(self, "serve", ServeSpec())
        validate_option("thermal", self.thermal, VALID_THERMAL_MODES)
        if self.dvfs is not None and not isinstance(self.dvfs, DvfsSpec):
            if not isinstance(self.dvfs, dict):
                raise ValueError(
                    f"dvfs must be a DvfsSpec or dict, "
                    f"got {type(self.dvfs).__name__}"
                )
            object.__setattr__(self, "dvfs", DvfsSpec.from_dict(self.dvfs))
        if self.thermal == "transient":
            if self.kind not in (
                "evaluate", "pareto", "roofline", "schedule", "serve"
            ):
                raise ValueError(
                    f"thermal='transient' applies to evaluate/pareto/"
                    f"roofline/schedule/serve studies, not kind="
                    f"{self.kind!r}"
                )
            if (
                self.kind in ("evaluate", "pareto", "roofline")
                and "thermal" not in self.metrics
            ):
                raise ValueError(
                    "thermal='transient' needs the 'thermal' metric group "
                    "in metrics= (the governor integrates the RC stack)"
                )
            if self.dvfs is None:
                object.__setattr__(self, "dvfs", DvfsSpec())
        elif self.dvfs is not None:
            raise ValueError(
                "dvfs= needs thermal='transient' (the governor only runs "
                "in the transient model)"
            )
        if self.policies is not None:
            if self.kind != "schedule":
                raise ValueError(
                    "policies= applies to schedule studies only "
                    f"(got kind={self.kind!r})"
                )
            pols = tuple(
                validate_option("policy", p, VALID_SCHEDULE_POLICIES)
                for p in self.policies
            )
            if "per_layer" not in pols or "fixed" not in pols:
                raise ValueError(
                    "policies must include 'per_layer' and 'fixed' (the "
                    "baselines every schedule report is anchored on)"
                )
            object.__setattr__(self, "policies", pols)
        if self.workers is not None:
            n = int(self.workers)
            if n < 1:
                raise ValueError(f"workers must be >= 1, got {self.workers}")
            object.__setattr__(self, "workers", n)
        if self.bandwidth is not None and not isinstance(self.bandwidth, BandwidthSpec):
            # A CalibratedBandwidth (or its dict form — recognizable by
            # the embedded spec + efficiency/marker keys) unwraps to its
            # fitted BandwidthSpec here, so a measured artifact plugs
            # into any study exactly where an assumed spec would go —
            # and reloading the spec from JSON normalizes identically.
            bw = self.bandwidth
            if isinstance(bw, dict) and ("calibrated" in bw or
                                         ("bandwidth" in bw and "efficiency" in bw)):
                bw = CalibratedBandwidth.from_dict(bw)
            if isinstance(bw, CalibratedBandwidth):
                object.__setattr__(self, "bandwidth", bw.bandwidth)
            elif not isinstance(bw, dict):
                raise ValueError(
                    f"bandwidth must be a BandwidthSpec, CalibratedBandwidth "
                    f"or dict, got {type(bw).__name__}"
                )
            else:
                object.__setattr__(self, "bandwidth", BandwidthSpec.from_dict(bw))
        if self.kind == "roofline" and self.bandwidth is None:
            raise ValueError(
                "kind='roofline' needs a bandwidth= spec — the memory system "
                "whose bounds it classifies (e.g. BandwidthSpec.paper_default())"
            )
        if self.shard is not None and self.shard not in ("auto", "none"):
            try:
                n = int(self.shard)
            except (TypeError, ValueError):
                raise ValueError(
                    f"shard must be None, 'auto', 'none' or a positive int, "
                    f"got {self.shard!r}"
                ) from None
            if n < 1:
                raise ValueError(f"shard must be >= 1, got {n}")
            object.__setattr__(self, "shard", n)
        object.__setattr__(
            self, "metrics", tuple(validate_option("metric", m, VALID_METRICS)
                                   for m in self.metrics)
        )
        object.__setattr__(
            self, "objectives",
            tuple(validate_option("objective", o, VALID_OBJECTIVES)
                  for o in self.objectives),
        )
        object.__setattr__(self, "axis", int(self.axis))
        if self.chunk is not None:
            object.__setattr__(self, "chunk", int(self.chunk))
        if self.mac_budget is not None:
            object.__setattr__(self, "mac_budget", int(self.mac_budget))
        if self.kind == "sweep":
            validate_option("sweep figure", self.figure, SWEEP_FIGURES)
        if not isinstance(self.params, dict):
            raise ValueError(f"params must be a dict, got {type(self.params).__name__}")

    def to_dict(self) -> dict:
        return _jsonify(dataclasses.asdict(self))

    @classmethod
    def from_dict(cls, d: dict) -> "AnalysisSpec":
        return cls(**d)


# ---------------------------------------------------------------------------
# The study itself
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Study:
    """A declarative, reproducible DSE study (the one front door).

    ``run()`` compiles the four specs into the batched engine and
    returns a ``StudyResult``. The whole object round-trips through
    JSON, so a study can be checked in, re-run, and diffed.
    """

    workload: WorkloadSpec
    space: SpaceSpec = dataclasses.field(default_factory=SpaceSpec)
    constraints: ConstraintSpec = dataclasses.field(default_factory=ConstraintSpec)
    analysis: AnalysisSpec = dataclasses.field(default_factory=AnalysisSpec)
    name: str = ""

    def __post_init__(self):
        for name, typ in (
            ("workload", WorkloadSpec),
            ("space", SpaceSpec),
            ("constraints", ConstraintSpec),
            ("analysis", AnalysisSpec),
        ):
            v = getattr(self, name)
            if isinstance(v, dict):
                object.__setattr__(self, name, typ.from_dict(v))
            elif not isinstance(v, typ):
                raise ValueError(f"{name} must be a {typ.__name__} (or dict)")

    # -- serialization ------------------------------------------------------

    def to_dict(self) -> dict:
        return {
            "version": SPEC_VERSION,
            "name": self.name,
            "workload": self.workload.to_dict(),
            "space": self.space.to_dict(),
            "constraints": self.constraints.to_dict(),
            "analysis": self.analysis.to_dict(),
        }

    @classmethod
    def from_dict(cls, d: dict) -> "Study":
        version = int(d.get("version", SPEC_VERSION))
        if version > SPEC_VERSION:
            raise ValueError(
                f"spec version {version} is newer than supported {SPEC_VERSION}"
            )
        if "workload" not in d:
            raise ValueError("a study spec needs at least a 'workload' section")
        kw = {"workload": WorkloadSpec.from_dict(d["workload"]),
              "name": str(d.get("name", ""))}
        for name, typ in (
            ("space", SpaceSpec),
            ("constraints", ConstraintSpec),
            ("analysis", AnalysisSpec),
        ):
            if d.get(name) is not None:
                kw[name] = typ.from_dict(d[name])
        return cls(**kw)

    def to_json(self, indent: int | None = 1) -> str:
        return json.dumps(self.to_dict(), indent=indent, allow_nan=False)

    @classmethod
    def from_json(cls, s: str) -> "Study":
        return cls.from_dict(json.loads(s))

    def save(self, path) -> pathlib.Path:
        path = pathlib.Path(path)
        path.write_text(self.to_json() + "\n")
        return path

    @classmethod
    def load(cls, path) -> "Study":
        return cls.from_json(pathlib.Path(path).read_text())

    # -- execution ----------------------------------------------------------

    def run(self, cache=None) -> "StudyResult":
        """Compile the specs into the engine and return the artifact.

        The payload's units follow ``engine.EvalResult`` /
        ``engine.PolicyResult``: cycles at the model's 1 GHz clock,
        bytes, watts, joules, J*s, um^2, degC; bandwidth knobs are
        GB/s (DRAM) and KiB (SRAM per tier).

        ``cache`` (a path or ``core.cache.ResultCache``) turns on
        content-addressed chunk caching: the grid is split into
        sub-grid chunks keyed by the canonical spec hash + index range,
        already-cached chunks are loaded instead of recomputed
        (bit-for-bit — chunking never changes results), and freshly
        computed chunks are stored so an interrupted run resumes where
        it left off (``python -m repro run --resume``). The returned
        ``StudyResult.cache`` carries the hit/miss counters.
        """
        if cache is not None and not isinstance(cache, ResultCache):
            cache = ResultCache(cache)
        stream = self.workload.resolve()
        runner = getattr(self, f"_run_{self.analysis.kind}")
        if cache is None:
            payload = runner(stream)
            return StudyResult(study=self, kind=self.analysis.kind, payload=payload)
        cache.prepare(self)
        h0, m0 = cache.hits, cache.misses  # shared caches: report this run only
        payload = runner(stream, cache=cache)
        stats = dict(cache.stats())
        stats["hits"] -= h0
        stats["misses"] -= m0
        stats["chunks"] = stats["hits"] + stats["misses"]
        result = StudyResult(
            study=self, kind=self.analysis.kind, payload=payload,
            cache=stats,
        )
        cache.store_result(self, result)
        return result

    def _chunk_for(self, workloads) -> int | None:
        a = self.analysis
        if a.chunk is not None:
            return a.chunk
        if self.workload.kind == "network" and self.space.mac_budgets is not None:
            # token-sized M dims: bound the search working set like
            # engine.schedule does (results are chunk-independent).
            return _adaptive_chunk(workloads, self.space.mac_budgets)
        return None

    def _evaluate(self, stream, metrics=None, cache: ResultCache | None = None) -> EvalResult:
        grid = self.space.to_grid(stream.workloads)
        kw = {}
        chunk = self._chunk_for(stream.workloads)
        if chunk is not None:
            kw["chunk"] = chunk
        kw["backend"] = self.analysis.backend
        kw["metrics"] = self.analysis.metrics if metrics is None else metrics
        kw["thermal_limit"] = self.constraints.thermal_limit_c
        kw["shard"] = self.analysis.shard
        kw["bandwidth"] = self.analysis.bandwidth
        if self.analysis.thermal == "transient" and "thermal" in kw["metrics"]:
            kw["thermal"] = "transient"
            kw["dvfs"] = self.analysis.dvfs
        if cache is None:
            return evaluate(grid, **kw)
        # Chunked, cached execution: consecutive point-blocks, each
        # independently evaluated (or loaded) and stitched — identical
        # bits to the one-pass evaluate by rowwise independence.
        W, P = grid.n_workloads, grid.n_points
        block = max(1, cache.block_cells // max(W, 1))
        parts = []
        for lo in range(0, P, block):
            hi = min(lo + block, P)
            key = f"points-{lo:010d}-{hi:010d}"
            d = cache.load_chunk(self, key)
            if d is not None:
                part = EvalResult.from_dict(d)
            else:
                part = evaluate(grid.subset(lo, hi), **kw)
                cache.store_chunk(self, key, _jsonify(part.to_dict()))
            parts.append(part)
        return EvalResult.concat(grid, parts)

    def _run_evaluate(self, stream, cache: ResultCache | None = None) -> dict:
        res = self._evaluate(stream, cache=cache)
        mask = self.constraints.mask(res)
        return {
            "result": res,
            "constraint_mask": mask,
            "n_valid": int(res.valid.sum()),
            "n_feasible": int(mask.sum()),
        }

    def _run_roofline(self, stream, cache: ResultCache | None = None) -> dict:
        """Bandwidth-aware evaluate + per-point bound classification.

        Same engine pass (and the same chunked/cached/sharded execution
        paths) as ``'evaluate'`` — the bandwidth spec is mandatory, so
        the payload additionally carries the bound histogram over valid
        points and the aggregate stall share of total runtime."""
        payload = self._run_evaluate(stream, cache=cache)
        res = payload["result"]
        v = res.valid
        payload["bound_counts"] = {
            name: int(np.sum(v & (np.asarray(res.bound) == name)))
            for name in BOUND_NAMES
        }
        cycles_total = float(np.sum(res.cycles[v]))
        stall_total = float(np.sum(np.where(v, res.stall_cycles, 0.0)))
        payload["stall_cycles_total"] = stall_total
        payload["stall_frac"] = stall_total / cycles_total if cycles_total else 0.0
        return payload

    def _run_search(self, stream, cache: ResultCache | None = None) -> dict:
        """Guided Pareto search (see ``core.search``): each generation is
        one vectorized engine batch and one set of cache chunks, so
        ``--resume`` replays finished generations bit-for-bit and
        ``analysis.workers`` farms missing blocks to N processes."""
        return run_search(self, stream, cache=cache)

    def _run_calibrate(self, stream, cache: ResultCache | None = None) -> dict:
        """Measure + fit (see ``core.calibrate``). The workload stream
        is unused — the calibration grid is the workload. Each measured
        shape is one cache chunk (keyed by index + label), so an
        interrupted sweep resumes at the first unmeasured shape; the
        fit is deterministic given the measured rows, so a fully-cached
        re-run reproduces the artifact bit-for-bit."""
        del stream
        spec = self.analysis.calibrate
        measured = []
        for i, row in enumerate(_calibrate.shape_grid(spec)):
            key = f"shape-{i:04d}-{row['label']}"
            d = cache.load_chunk(self, key) if cache is not None else None
            if d is None:
                d = _calibrate.measure_row(
                    row, reps=spec.reps, warmup=spec.warmup, seed=spec.seed
                )
                if cache is not None:
                    cache.store_chunk(self, key, _jsonify(d))
            measured.append(d)
        return _calibrate.fit_rows(measured, spec)

    def _run_serve(self, stream, cache: ResultCache | None = None) -> dict:
        """Serving-traffic simulation (see ``core.serve``): per design
        point, derive the fixed array and step the seeded request queue,
        pricing every step through the bandwidth-aware engine. Point
        blocks are the cache chunks — per-point state is elementwise,
        so ``--resume`` recomputes exactly the missing points with a
        bit-identical stitched payload."""
        return run_serve(self, stream, cache=cache)

    def _run_pareto(self, stream, cache: ResultCache | None = None) -> dict:
        payload = self._run_evaluate(stream, cache=cache)
        res, mask = payload["result"], payload["constraint_mask"]
        res_f = (
            dataclasses.replace(res, within_thermal_budget=mask)
            if self.constraints.has_caps
            else res
        )
        payload["pareto_mask"] = res_f.pareto_mask(
            self.analysis.objectives,
            feasible_only=self.constraints.require_feasible,
        )
        payload["objectives"] = list(self.analysis.objectives)
        return payload

    def _run_schedule(self, stream, cache: ResultCache | None = None) -> dict:
        if self.space.rows is not None:
            raise ValueError("schedule searches array shapes; drop rows/cols")
        if self.constraints.has_caps:
            raise ValueError(
                "schedule supports the thermal constraint only; drop the caps"
            )
        for name in ("dataflow", "tech"):
            if not isinstance(getattr(self.space, name), str):
                raise ValueError(f"schedule needs a single {name}, not a per-point array")
        # schedule's two passes couple all layers (the candidate set is
        # derived from every per-layer optimum), so it caches as one unit.
        if cache is not None:
            d = cache.load_chunk(self, "schedule")
            if d is not None:
                return _restore_payload("schedule", d)
        kw = {}
        if self.analysis.chunk is not None:
            kw["chunk"] = self.analysis.chunk
        if self.analysis.policies is not None:
            kw["policies"] = self.analysis.policies
        rep = schedule(
            stream,
            mac_budgets=self.space.mac_budgets,
            tiers=self.space.tiers,
            dataflow=self.space.dataflow,
            tech=self.space.tech,
            backend=self.analysis.backend,
            thermal_limit=self.constraints.thermal_limit_c,
            require_feasible=self.constraints.require_feasible,
            shard=self.analysis.shard,
            bandwidth=self.analysis.bandwidth,
            thermal=self.analysis.thermal,
            dvfs=self.analysis.dvfs,
            **kw,
        )
        payload = {"report": rep}
        if cache is not None:
            cache.store_chunk(self, "schedule", _jsonify(payload))
        return payload

    def _run_advise(self, stream, cache: ResultCache | None = None) -> dict:
        from .advisor import _rank  # deferred: advisor's shim imports Study

        if self.constraints.has_caps:
            raise ValueError(
                "advise supports the thermal constraint only; drop the caps"
            )
        if not isinstance(self.space.tech, str):
            raise ValueError("advise needs a single tech, not a per-point array")
        if cache is not None:
            d = cache.load_chunk(self, "advise")
            if d is not None:
                return _restore_payload("advise", d)
        params = dict(self.analysis.params)
        bw = self.analysis.bandwidth
        if bw is not None and math.isfinite(bw.dram_gbs):
            # The mesh advisor's memory term is its HBM model [bytes/s];
            # a finite DRAM cap maps straight onto it (an explicit
            # params['hbm_bw'] still wins).
            params.setdefault("hbm_bw", bw.dram_gbs * 1e9)
        names, totals = _rank(
            stream.workloads,
            self.analysis.axis,
            mac_budget=self.analysis.mac_budget,
            tech=self.space.tech,
            thermal_limit=self.constraints.thermal_limit_c,
            **params,
        )
        payload = {
            "strategies": list(MESH_STRATEGIES),
            "names": names,
            "totals": totals,
            "axis": self.analysis.axis,
        }
        if cache is not None:
            cache.store_chunk(self, "advise", _jsonify(payload))
        return payload

    def _run_sweep(self, stream, cache: ResultCache | None = None) -> dict:
        fig = self.analysis.figure
        budgets, tiers = self.space.mac_budgets, self.space.tiers
        if budgets is None or self.space.rows is not None or self.space.layout != "product":
            raise ValueError(
                "sweep figures need a product space (mac_budgets x tiers, "
                "no explicit rows/cols)"
            )
        if self.constraints != ConstraintSpec():
            raise ValueError(
                "sweep figures reproduce the paper's unconstrained sweeps; "
                "drop the non-default constraints (use kind='evaluate' or "
                "'pareto' for constrained studies)"
            )
        if fig == "fig7":
            if self.space.dataflow != "dos":
                raise ValueError(
                    "the fig7 optimal-tier search is defined for the dOS "
                    "dataflow only"
                )
            max_tiers = max(tiers)
            if tiers != tuple(range(1, max_tiers + 1)):
                raise ValueError("fig7 sweeps tiers 1..max; use tiers=range(1, T+1)")
            best, best_cycles = self._fig7_tiers(stream, budgets, max_tiers, cache)
            return {
                "mac_budgets": list(budgets),
                "max_tiers": max_tiers,
                "optimal_tiers": best,
                "best_cycles": best_cycles,
                "medians": [float(np.median(best[:, bi])) for bi in range(len(budgets))],
            }
        # fig5/fig6: one perf-only evaluate over the product grid,
        # reshaped (workload, budget, tier) — budget-major point order.
        res = self._evaluate(stream, metrics=("perf",), cache=cache)
        W = stream.workloads.shape[0]
        speedup = res.speedup.reshape(W, len(budgets), len(tiers))
        return {
            "mac_budgets": list(budgets),
            "tiers": list(tiers),
            "workloads": stream.workloads.tolist(),
            "speedup": speedup,
        }

    def _fig7_tiers(self, stream, budgets, max_tiers: int, cache: ResultCache | None):
        """The fig7 optimal-tier search, chunked over *workloads*.

        Each workload's argmin is independent of every other workload,
        so workload-blocks are the natural cache/stream unit for the
        Fig-7-style million-point sweeps (``benchmarks/scale_bench.py``).
        """
        kw = dict(max_tiers=max_tiers, mode=self.space.mode,
                  backend=self.analysis.backend, shard=self.analysis.shard)
        if self.analysis.bandwidth is not None:
            if not isinstance(self.space.tech, str):
                raise ValueError(
                    "a bandwidth-aware fig7 sweep needs a single tech "
                    "(the derived vertical-link width is per-technology)"
                )
            kw.update(bandwidth=self.analysis.bandwidth, tech=self.space.tech)
        wl = np.atleast_2d(np.asarray(stream.workloads, dtype=np.int64))
        if cache is None:
            return optimal_tiers_batched(wl, budgets, **kw)
        W = wl.shape[0]
        width = max(1, len(budgets) * max_tiers)
        block = max(1, cache.block_cells // width)
        bs, cs = [], []
        for lo in range(0, W, block):
            hi = min(lo + block, W)
            key = f"workloads-{lo:010d}-{hi:010d}"
            d = cache.load_chunk(self, key)
            if d is None:
                b_, c_ = optimal_tiers_batched(wl[lo:hi], budgets, **kw)
                cache.store_chunk(
                    self, key,
                    _jsonify({"optimal_tiers": b_, "best_cycles": c_}),
                )
            else:
                b_ = np.asarray(d["optimal_tiers"], dtype=np.int64)
                c_ = np.asarray(d["best_cycles"], dtype=np.float64)
            bs.append(b_)
            cs.append(c_)
        return np.concatenate(bs, axis=0), np.concatenate(cs, axis=0)

    # -- convenience --------------------------------------------------------

    @classmethod
    def example(cls, kind: str = "evaluate") -> "Study":
        """A small runnable template spec per analysis kind (the CLI's
        ``example-spec`` source — each finishes in seconds)."""
        validate_option("analysis kind", kind, ANALYSIS_KINDS)
        gemms = ((64, 12100, 147), (512, 784, 128))
        space = SpaceSpec(mac_budgets=(2**14, 2**16), tiers=tuple(range(1, 9)))
        if kind == "schedule":
            return cls(
                name="example-schedule",
                workload=WorkloadSpec(kind="network", arch="smollm-135m",
                                      shape="decode_32k"),
                space=space,
                analysis=AnalysisSpec(kind="schedule"),
            )
        if kind == "advise":
            return cls(
                name="example-advise",
                workload=WorkloadSpec(kind="gemms", gemms=gemms),
                analysis=AnalysisSpec(kind="advise", axis=16, mac_budget=2**16),
            )
        if kind == "sweep":
            return cls(
                name="example-sweep-fig5",
                workload=WorkloadSpec(kind="gemms",
                                      gemms=((64, 255, 147), (64, 12100, 147))),
                space=space,
                analysis=AnalysisSpec(kind="sweep", figure="fig5"),
            )
        if kind == "roofline":
            return cls(
                name="example-roofline",
                workload=WorkloadSpec(kind="gemms", gemms=gemms),
                space=space,
                analysis=AnalysisSpec(
                    kind="roofline", bandwidth=BandwidthSpec.paper_default()
                ),
            )
        if kind == "calibrate":
            # the workload is a placeholder (calibrate ignores it —
            # the shape grid is the workload); smoke preset + low reps
            # keep the example in CI-seconds territory.
            return cls(
                name="example-calibrate",
                workload=WorkloadSpec(kind="gemms", gemms=gemms),
                analysis=AnalysisSpec(
                    kind="calibrate",
                    calibrate=CalibrateSpec(preset="smoke", reps=2, warmup=1),
                ),
            )
        if kind == "serve":
            return cls(
                name="example-serve",
                workload=WorkloadSpec(kind="network", arch="smollm-135m",
                                      shape="decode_32k"),
                space=SpaceSpec(mac_budgets=(2**14, 2**16), tiers=(1, 4, 8)),
                analysis=AnalysisSpec(
                    kind="serve",
                    bandwidth=BandwidthSpec.paper_default(),
                    serve=ServeSpec(
                        traffic=TrafficSpec(
                            arrival_rps=2048.0,
                            n_requests=8,
                            prompt_mean=64,
                            prompt_max=256,
                            output_mean=8,
                            output_max=32,
                            max_batch=4,
                            chunk_prefill=32,
                            seed=0,
                        )
                    ),
                ),
            )
        if kind == "search":
            return cls(
                name="example-search",
                workload=WorkloadSpec(kind="gemms", gemms=gemms),
                space=SpaceSpec(
                    mac_budgets=tuple(2**k for k in range(10, 19)),
                    tiers=tuple(range(1, 9)),
                    dataflow=("dos", "ws"),
                    tech=("tsv", "miv"),
                ),
                analysis=AnalysisSpec(
                    kind="search",
                    bandwidth=BandwidthSpec.paper_default(),
                    search=SearchSpec(
                        objectives=("cycles", "energy_j"),
                        generations=4,
                        population=64,
                        refine=(4, 2, 1),
                        seed=0,
                        dram_gbs=(64.0, 128.0, 256.0, 512.0),
                        sram_kib=(256.0, 512.0, 1024.0),
                    ),
                ),
            )
        return cls(
            name=f"example-{kind}",
            workload=WorkloadSpec(kind="gemms", gemms=gemms),
            space=space,
            analysis=AnalysisSpec(kind=kind),
        )


# ---------------------------------------------------------------------------
# The artifact
# ---------------------------------------------------------------------------

def _restore_payload(kind: str, payload: dict) -> dict:
    """Re-type a JSON-decoded payload (inverse of ``_jsonify``)."""
    out = dict(payload)
    if "result" in out and not isinstance(out["result"], EvalResult):
        out["result"] = EvalResult.from_dict(out["result"])
    if "report" in out and not isinstance(out["report"], NetworkReport):
        out["report"] = NetworkReport.from_dict(out["report"])
    for key, dt in (
        ("constraint_mask", bool),
        ("pareto_mask", bool),
        ("totals", np.float64),
        ("speedup", np.float64),
        ("best_cycles", np.float64),
        ("optimal_tiers", np.int64),
        ("frontier_candidates", np.int64),
        ("frontier_objectives", np.float64),
    ):
        if key in out and not isinstance(out[key], np.ndarray):
            out[key] = np.asarray(out[key], dtype=dt)
    if kind == "advise" and not isinstance(out.get("names"), np.ndarray):
        out["names"] = np.asarray(out["names"])
    if kind == "calibrate" and isinstance(out.get("artifact"), dict):
        out["artifact"] = CalibratedBandwidth.from_dict(out["artifact"])
    if kind == "serve" and isinstance(out.get("points"), dict):
        out["points"] = restore_points(out["points"])
    return out


@dataclasses.dataclass(frozen=True)
class StudyResult:
    """Versioned, serializable result artifact: inputs echoed + payload.

    ``payload`` is kind-specific and array-backed in memory (see the
    module docstring); ``to_dict``/``to_json`` give the JSON form and
    ``from_dict``/``from_json``/``load`` restore the typed objects.
    """

    study: Study
    kind: str
    payload: dict
    version: int = SPEC_VERSION
    #: cache hit/miss counters when the run was cache-backed (else None).
    cache: dict | None = None

    # typed accessors ------------------------------------------------------
    @property
    def result(self) -> EvalResult | None:
        """The batched ``EvalResult`` (evaluate/pareto kinds)."""
        return self.payload.get("result")

    @property
    def report(self) -> NetworkReport | None:
        """The ``NetworkReport`` (schedule kind)."""
        return self.payload.get("report")

    def to_dict(self) -> dict:
        out = {
            "version": self.version,
            "kind": self.kind,
            "study": self.study.to_dict(),
            "payload": _jsonify(self.payload),
        }
        if self.cache is not None:
            out["cache"] = _jsonify(self.cache)
        return out

    @classmethod
    def from_dict(cls, d: dict) -> "StudyResult":
        version = int(d.get("version", SPEC_VERSION))
        if version > SPEC_VERSION:
            raise ValueError(
                f"artifact version {version} is newer than supported {SPEC_VERSION}"
            )
        kind = str(d["kind"])
        return cls(
            study=Study.from_dict(d["study"]),
            kind=kind,
            payload=_restore_payload(kind, d["payload"]),
            version=version,
            cache=d.get("cache"),
        )

    def to_json(self, indent: int | None = 1) -> str:
        # allow_nan=False: artifacts are strict JSON; non-finite values
        # travel as the _jsonify string encoding instead
        return json.dumps(self.to_dict(), indent=indent, allow_nan=False)

    @classmethod
    def from_json(cls, s: str) -> "StudyResult":
        return cls.from_dict(json.loads(s))

    def save(self, path) -> pathlib.Path:
        path = pathlib.Path(path)
        path.write_text(self.to_json() + "\n")
        return path

    @classmethod
    def load(cls, path) -> "StudyResult":
        return cls.from_json(pathlib.Path(path).read_text())

    def describe(self) -> str:
        """One-line human summary (what the CLI prints)."""
        name = self.study.name or "<unnamed>"
        if self.kind == "search":
            p = self.payload
            return (
                f"{name}: search {p['n_evaluated']:,}/{p['space_size']:,} "
                f"points ({p['frac_evaluated']:.3%}) over "
                f"{p['generations']} generations — "
                f"{len(p['frontier_objectives'])} on the feasible frontier, "
                f"hypervolume {p['hypervolume']:.4e}"
            )
        if self.kind == "calibrate":
            p = self.payload
            e = p["errors"]
            eff = ", ".join(
                f"{k}: {v:.2%}" for k, v in sorted(p["efficiency"].items())
            )
            return (
                f"{name}: calibrate {len(p['rows'])} shapes — "
                f"dram {p['dram_gbs_fitted']:.2f} GB/s, efficiency {eff}; "
                f"holdout err {e['holdout_median_rel_err']:.1%} "
                f"(uncalibrated {e['uncalibrated_holdout_median_rel_err']:.1%})"
            )
        if self.kind == "serve":
            p = self.payload
            s = p["summary"]
            best = s["best_3d"] or s["best_2d"]
            head = (
                f"{name}: serve {p['trace']['n_requests']} requests x "
                f"{p['n_points']} design points on {p['arch']} — "
                f"{s['n_feasible']} feasible"
            )
            if best is None:
                return head + ", no servable design"
            d = best["design"]
            head += (
                f"; best {d[0]}x{d[1]}x{d[2]}/{best['tech']} at "
                f"{best['gen_tok_s']:.3e} tok/s, "
                f"{best['tokens_per_s_per_w']:.3e} tok/s/W"
            )
            if s["win_3d_vs_2d"] is not None:
                head += f" ({s['win_3d_vs_2d']:.2f}x 3D-vs-2D on tok/s/W)"
            return head
        if self.kind == "roofline":
            W, P = self.result.valid.shape
            bc = self.payload["bound_counts"]
            mix = ", ".join(f"{k}: {v}" for k, v in bc.items())
            return (
                f"{name}: roofline {W} workloads x {P} design points — "
                f"bounds {mix}; stalls {self.payload['stall_frac']:.1%} of "
                f"total cycles"
            )
        if self.kind in ("evaluate", "pareto"):
            res = self.result
            W, P = res.valid.shape
            extra = (
                f", {int(self.payload['pareto_mask'].sum())} on the frontier"
                if "pareto_mask" in self.payload
                else ""
            )
            return (
                f"{name}: {self.kind} {W} workloads x {P} design points — "
                f"{self.payload['n_feasible']}/{self.payload['n_valid']} "
                f"valid points feasible{extra}"
            )
        if self.kind == "schedule":
            rep = self.report
            fx = rep.fixed
            d = np.asarray(fx.design).reshape(-1)
            line = (
                f"{name}: schedule {rep.arch}/{rep.shape} — fixed "
                f"{int(d[0])}x{int(d[1])}x{int(d[2])} at {fx.total_cycles:.3e} "
                f"cycles, {fx.speedup_vs_2d:.2f}x vs 2D"
            )
            tf = getattr(rep, "tier_fold", None)
            if tf is not None:
                gain = fx.total_cycles / tf.total_cycles if tf.total_cycles else 1.0
                line += (
                    f"; tier_fold {tf.total_cycles:.3e} cycles "
                    f"({gain:.2f}x vs fixed)"
                )
            return line
        if self.kind == "advise":
            names = np.asarray(self.payload["names"])
            u, c = np.unique(names, return_counts=True)
            mix = ", ".join(f"{n}: {k}" for n, k in zip(u.tolist(), c.tolist()))
            return f"{name}: advise axis={self.payload['axis']} — winners {mix}"
        fig = self.study.analysis.figure
        if fig == "fig7":
            med = ", ".join(f"{m:g}" for m in self.payload["medians"])
            return f"{name}: sweep {fig} — median optimal tiers [{med}]"
        s = np.asarray(self.payload["speedup"], dtype=np.float64)
        with np.errstate(invalid="ignore"):
            peak = float(np.nanmax(s))
        return f"{name}: sweep {fig} — peak 3D-vs-2D speedup {peak:.2f}x"
