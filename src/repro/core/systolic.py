"""Cycle-level functional simulator of the paper's 2D/3D systolic arrays.

This is the executable form of the paper's Figs. 1-4: it simulates the
output-stationary (OS) dataflow on a 2D R x C MAC array cycle by cycle,
and the distributed-output-stationary (dOS) dataflow on an l-tier 3D
array (per-tier OS on a K/l slice + sequential partial-sum accumulation
down the tier pile). It serves two purposes:

1. **Correctness of the dataflow**: the simulated array must produce
   exactly ``A @ B`` (property-tested over random shapes).
2. **Validation of the analytical model**: the simulated cycle counts
   must equal Eq. 1 / Eq. 2 of ``core.analytical`` exactly.

The simulation itself is pure JAX (``lax.scan`` over cycles), so it
vectorizes over tiers with ``vmap`` — i.e. we simulate the 3D array the
same way the hardware would run it: all tiers in lockstep, then the
(l-1)-add accumulation.

Mechanics of one OS tile (r, c are PE coordinates):
  - A enters column 0 skewed by row:   PE(r, 0) receives A[r, t-r] at cycle t
  - B enters row 0 skewed by column:   PE(0, c) receives B[t-c, c] at cycle t
  - per cycle: operands shift right/down one PE; each PE multiplies its
    current pair and accumulates locally.
  - PE(r, c) therefore sees (A[r, k], B[k, c]) together at cycle r+c+k,
    accumulating the exact dot product. Compute finishes at cycle
    R+C+K-2; draining the outputs costs another R cycles, giving
    Eq. 1's per-fold term (2R + C + K - 2).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from .analytical import tau_2d, tau_3d

__all__ = ["SimResult", "simulate_os_2d", "simulate_dos_3d"]


@dataclasses.dataclass
class SimResult:
    out: jax.Array  # the computed M x N product
    cycles: int  # simulated runtime in cycles (incl. fill + drain + reduce)
    folds: int  # number of serialization steps
    tiers: int


def _injection_schedules(A_tile, B_tile, R, C, K):
    """Skewed operand injection: Ainj[t, r] = A[r, t-r], Binj[t, c] = B[t-c, c]."""
    T = R + C + K - 2  # last useful cycle index is (R-1)+(C-1)+(K-1)
    t = jnp.arange(T)[:, None]
    r = jnp.arange(R)[None, :]
    c = jnp.arange(C)[None, :]
    ka = t - r  # (T, R) index into K for A
    kb = t - c  # (T, C) index into K for B
    a_valid = (ka >= 0) & (ka < K)
    b_valid = (kb >= 0) & (kb < K)
    Ainj = jnp.where(a_valid, A_tile[r, jnp.clip(ka, 0, K - 1)], 0.0)
    Binj = jnp.where(b_valid, B_tile[jnp.clip(kb, 0, K - 1), c], 0.0)
    return Ainj, Binj, T


@functools.partial(jax.jit, static_argnums=(2, 3))
def _simulate_tile(A_tile, B_tile, R: int, C: int):
    """Simulate one OS fold on an R x C array. A_tile: (R, K), B_tile: (K, C)."""
    K = A_tile.shape[1]
    Ainj, Binj, _T = _injection_schedules(A_tile, B_tile, R, C, K)

    def cycle(carry, inj):
        a_reg, b_reg, acc = carry
        a_in, b_in = inj
        # operands march right / down by one PE per cycle
        a_reg = jnp.concatenate([a_in[:, None], a_reg[:, :-1]], axis=1)
        b_reg = jnp.concatenate([b_in[None, :], b_reg[:-1, :]], axis=0)
        acc = acc + a_reg * b_reg
        return (a_reg, b_reg, acc), None

    z = jnp.zeros((R, C), A_tile.dtype)
    (_, _, acc), _ = jax.lax.scan(cycle, (z, z, z), (Ainj, Binj))
    return acc


def simulate_os_2d(A, B, R: int, C: int) -> SimResult:
    """OS dataflow on a 2D R x C array, with M/N fold serialization.

    Simulated cycles match Eq. 1: (2R + C + K - 2) * ceil(M/R) * ceil(N/C).
    """
    A = jnp.asarray(A, jnp.float32)
    B = jnp.asarray(B, jnp.float32)
    M, K = A.shape
    K2, N = B.shape
    assert K == K2, (A.shape, B.shape)
    m_folds = -(-M // R)
    n_folds = -(-N // C)
    # Pad to full fold tiles; ragged edges are computed with zero padding
    # (hardware would gate those PEs off; runtime is unchanged).
    Ap = jnp.pad(A, ((0, m_folds * R - M), (0, 0)))
    Bp = jnp.pad(B, ((0, 0), (0, n_folds * C - N)))
    A_tiles = Ap.reshape(m_folds, R, K)
    B_tiles = Bp.reshape(K, n_folds, C).transpose(1, 0, 2)
    # vmap over fold tiles = serial steps in hardware, identical math.
    sim = jax.vmap(jax.vmap(_simulate_tile, (None, 0, None, None)), (0, None, None, None))
    tiles = sim(A_tiles, B_tiles, R, C)  # (m_folds, n_folds, R, C)
    out = tiles.transpose(0, 2, 1, 3).reshape(m_folds * R, n_folds * C)[:M, :N]
    cycles = int(tau_2d(M, K, N, R, C))
    return SimResult(out=out, cycles=cycles, folds=m_folds * n_folds, tiers=1)


def simulate_dos_3d(A, B, R: int, C: int, tiers: int) -> SimResult:
    """dOS dataflow on an l-tier 3D array of R x C tiles (paper Figs. 3-4).

    K is split into ceil(K/l) slices; every tier runs OS on its slice in
    lockstep (vmap); then each output pile accumulates its l partial
    sums with l-1 sequential cross-tier adds (the TSV/MIV traffic).
    Simulated cycles match Eq. 2.
    """
    A = jnp.asarray(A, jnp.float32)
    B = jnp.asarray(B, jnp.float32)
    M, K = A.shape
    _, N = B.shape
    L = int(tiers)
    kl = -(-K // L)
    # Pad K so every tier gets a full slice (zeros contribute nothing).
    Ap = jnp.pad(A, ((0, 0), (0, kl * L - K)))
    Bp = jnp.pad(B, ((0, kl * L - K), (0, 0)))
    A_sl = Ap.reshape(M, L, kl).transpose(1, 0, 2)  # (L, M, kl)
    B_sl = Bp.reshape(L, kl, N)  # (L, kl, N)

    m_folds = -(-M // R)
    n_folds = -(-N // C)
    Apad = jnp.pad(A_sl, ((0, 0), (0, m_folds * R - M), (0, 0)))
    Bpad = jnp.pad(B_sl, ((0, 0), (0, 0), (0, n_folds * C - N)))
    A_tiles = Apad.reshape(L, m_folds, R, kl)
    B_tiles = Bpad.reshape(L, kl, n_folds, C).transpose(0, 2, 1, 3)

    sim_tile = jax.vmap(_simulate_tile, (0, 0, None, None))  # over tiers
    sim_nf = jax.vmap(sim_tile, (None, 1, None, None))  # over n folds
    sim_mf = jax.vmap(sim_nf, (1, None, None, None))  # over m folds
    partial = sim_mf(A_tiles, B_tiles, R, C)  # (m_folds, n_folds, L, R, C)

    # Cross-tier accumulation pile: l-1 strictly sequential adds, exactly
    # as the partial sums ripple down the TSV/MIV pile to the bottom tier.
    def add_down(acc, tier_partial):
        return acc + tier_partial, None

    init = partial[:, :, 0]
    stacked = partial[:, :, 1:].transpose(2, 0, 1, 3, 4)  # (L-1, mf, nf, R, C)
    acc, _ = jax.lax.scan(add_down, init, stacked)
    out = acc.transpose(0, 2, 1, 3).reshape(m_folds * R, n_folds * C)[:M, :N]
    cycles = int(tau_3d(M, K, N, R, C, L))
    return SimResult(out=out, cycles=cycles, folds=m_folds * n_folds, tiers=L)
