"""Deterministic synthetic LM data pipeline.

Generates a learnable token stream (noisy affine next-token process) so
training-loss curves are meaningful without external data. Host-sharded:
every process generates only its slice of the global batch, keyed by
(seed, step, process_index) — restart-safe and order-independent, which
is what elastic restarts need.
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np

__all__ = ["DataConfig", "SyntheticLM"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    noise: float = 0.05  # fraction of random tokens


class SyntheticLM:
    """next = (5*cur + 17) % vocab with `noise` random replacements."""

    def __init__(self, cfg: DataConfig, process_index: int | None = None,
                 process_count: int | None = None):
        self.cfg = cfg
        self.pi = jax.process_index() if process_index is None else process_index
        self.pc = jax.process_count() if process_count is None else process_count
        assert cfg.global_batch % self.pc == 0
        self.local_batch = cfg.global_batch // self.pc

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, self.pi])
        )
        b, s = self.local_batch, cfg.seq_len
        toks = np.empty((b, s + 1), np.int32)
        toks[:, 0] = rng.integers(0, cfg.vocab, size=b)
        for t in range(1, s + 1):
            toks[:, t] = (5 * toks[:, t - 1] + 17) % cfg.vocab
        mask = rng.random((b, s + 1)) < cfg.noise
        toks[mask] = rng.integers(0, cfg.vocab, size=int(mask.sum()))
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch(step)
            step += 1
