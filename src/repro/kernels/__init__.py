"""Pallas TPU kernels (validated on CPU via interpret mode).

Each kernel package ships kernel.py (pl.pallas_call + BlockSpec VMEM
tiling), ops.py (jit'd public wrapper with CPU fallback) and ref.py
(pure-jnp oracle).
"""

from .dos_matmul import dos_matmul
from .flash_attention import decode_attention, flash_attention
from .ssm_scan import ssm_scan

__all__ = ["dos_matmul", "flash_attention", "decode_attention", "ssm_scan"]
