from .kernel import dos_matmul_pallas
from .ops import dos_matmul, pick_blocks
from .ref import dos_matmul_ref, matmul_ref

__all__ = ["dos_matmul", "dos_matmul_pallas", "dos_matmul_ref", "matmul_ref", "pick_blocks"]
