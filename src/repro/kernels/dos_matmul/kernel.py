"""Pallas TPU kernel: dOS (distributed-output-stationary) tiled matmul.

The paper's dOS dataflow adapted to the TPU memory hierarchy:

- The MXU plays the role of one 2D systolic tier (it literally is one).
- The contraction dimension K is tiled across the **pallas grid's
  innermost (sequential) dimension** — K-blocks are the "tiers",
  executed temporally on one chip, exactly like Eq. 2's K/ℓ slices.
- The output tile stays **stationary in a VMEM f32 scratch accumulator**
  across all K-steps (the "output stationary" part); partial sums are
  accumulated in-register/VMEM instead of over TSVs.
- The cross-*chip* tier dimension (the paper's physical stacking) is
  provided by ``repro.parallel``: K is additionally sharded over the
  mesh's model axis and the adder pile becomes an all-reduce.

Block shapes are chosen MXU-aligned (multiples of 128 in M/N, K-block a
multiple of the dtype's packing); the VMEM working set is
bm*bk + bk*bn (operands) + bm*bn (f32 acc) elements.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..._jax_compat import pallas_tpu_compiler_params

_CompilerParams = pallas_tpu_compiler_params()

__all__ = ["dos_matmul_kernel", "dos_matmul_pallas"]


def dos_matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k_tiers: int, out_dtype):
    """One (i, j, k) grid step: accumulate a K-tier into the stationary
    output tile; emit on the last tier."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k_tiers - 1)
    def _emit():
        o_ref[...] = acc_ref[...].astype(out_dtype)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "out_dtype", "interpret")
)
def dos_matmul_pallas(
    a: jax.Array,
    b: jax.Array,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """``a(M,K) @ b(K,N)`` with dOS K-tiering. Shapes must divide blocks
    (the ops.py wrapper pads); K-tier count = K // bk."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"shape ({m},{k})x({k2},{n}) must divide blocks ({bm},{bn},{bk})"
    )
    out_dtype = out_dtype or a.dtype
    n_k = k // bk

    grid = (m // bm, n // bn, n_k)
    kernel = functools.partial(
        dos_matmul_kernel, n_k_tiers=n_k, out_dtype=out_dtype
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a, b)
