"""Public op: dOS matmul with padding, block selection and CPU fallback.

``dos_matmul`` is the layer-facing entry point used by the model zoo.
On TPU it calls the Pallas kernel; on CPU (this container) it uses the
pure-jnp reference so smoke tests and the multi-pod dry-run lower plain
XLA HLO. ``interpret=True`` forces the Pallas kernel in interpret mode
(used by the kernel test-suite to validate the kernel body on CPU).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import dos_matmul_pallas
from .ref import dos_matmul_ref, matmul_ref

__all__ = ["dos_matmul", "pick_blocks"]


# Minimum Pallas tile (sublane, lane) for f32; shapes below this are
# dominated by zero padding and dispatch to the reference GEMM instead.
MIN_TILE_M = 8
MIN_TILE_N = 128
MIN_TILE_K = 128


def pick_blocks(m: int, n: int, k: int, vmem_budget_bytes: int = 8 * 2**20):
    """MXU-aligned block sizes fitting the VMEM budget.

    Working set (bf16 operands + f32 acc): 2(bm*bk + bk*bn) + 4*bm*bn.
    Prefers 128-aligned bm/bn and a deep K block (dOS wants as much of
    the contraction resident as possible: fewer "tier" iterations).
    Skewed (tall/wide) GEMMs get rectangular tiles: when one output dim
    is small, its freed VMEM goes to the other dim — fewer grid rows
    and better reuse of the small operand — instead of sitting idle.
    """

    def fits(bm_, bn_, bk_):
        return 2 * (bm_ * bk_ + bk_ * bn_) + 4 * bm_ * bn_ <= vmem_budget_bytes

    bm = min(128, _round_up(m, MIN_TILE_M))
    bn = min(128, _round_up(n, MIN_TILE_N))
    if n <= 128 < m:  # tall: grow bm while the min-depth K block fits
        while bm < 512 and bm < _round_up(m, MIN_TILE_M) and fits(2 * bm, bn, MIN_TILE_K):
            bm *= 2
        bm = min(bm, _round_up(m, MIN_TILE_M))
    elif m <= 128 < n:  # wide: grow bn symmetrically
        while bn < 512 and bn < _round_up(n, MIN_TILE_N) and fits(bm, 2 * bn, MIN_TILE_K):
            bn *= 2
        bn = min(bn, _round_up(n, MIN_TILE_N))
    bk = 512
    while not fits(bm, bn, bk) and bk > MIN_TILE_K:
        bk //= 2
    return bm, bn, min(bk, _round_up(k, MIN_TILE_K))


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


@functools.partial(
    jax.jit, static_argnames=("out_dtype", "blocks", "interpret", "force_ref")
)
def dos_matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    out_dtype=None,
    blocks: tuple | None = None,
    interpret: bool | None = None,
    force_ref: bool = False,
) -> jax.Array:
    """``a(..., M, K) @ b(K, N)`` via the dOS Pallas kernel.

    Leading batch dims of ``a`` are flattened into M. Inputs are padded
    up to block multiples and the result is sliced back.

    Dispatch: on TPU -> Pallas kernel; on CPU -> jnp reference (so smoke
    tests and the dry-run lower plain XLA HLO). Pass ``interpret=True``
    to force the kernel body in interpret mode (kernel test-suite).
    """
    out_dtype = out_dtype or a.dtype
    if interpret is None:
        if force_ref or jax.default_backend() != "tpu":
            return matmul_ref(a, b, out_dtype)
        interpret = False
    elif force_ref:
        return matmul_ref(a, b, out_dtype)

    lead = a.shape[:-1]
    m = 1
    for d in lead:
        m *= d
    k = a.shape[-1]
    n = b.shape[-1]

    # Degenerate shapes (any dim below the minimum tile): the padded
    # kernel would spend most of its FLOPs on zeros — use the reference
    # GEMM, which XLA handles without padding waste.
    if m < MIN_TILE_M or n < MIN_TILE_N or k < MIN_TILE_K:
        return matmul_ref(a, b, out_dtype)

    a2 = a.reshape(m, k)

    bm, bn, bk = blocks or pick_blocks(m, n, k)
    mp, np_, kp = _round_up(m, bm), _round_up(n, bn), _round_up(k, bk)
    if (mp, kp) != (m, k):
        a2 = jnp.pad(a2, ((0, mp - m), (0, kp - k)))
    b2 = b
    if (kp, np_) != (k, n):
        b2 = jnp.pad(b, ((0, kp - k), (0, np_ - n)))
    out = dos_matmul_pallas(
        a2, b2, bm=bm, bn=bn, bk=bk, out_dtype=out_dtype, interpret=interpret
    )
    return out[:m, :n].reshape(*lead, n)
