"""Pure-jnp oracle for the dOS matmul kernel.

``dos_matmul_ref`` reproduces the kernel's *exact* accumulation order:
K is split into ``n_tiers`` contiguous slices ("tiers"); each tier
produces a partial sum in f32; partial sums are added sequentially down
the pile (paper Fig. 3). For well-conditioned inputs this equals
``a @ b`` up to f32 rounding, which the property tests assert.
"""

from __future__ import annotations

import jax.numpy as jnp


def matmul_ref(a, b, out_dtype=None):
    """Plain f32-accumulated matmul (the mathematical ground truth)."""
    out_dtype = out_dtype or a.dtype
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(out_dtype)


def dos_matmul_ref(a, b, n_tiers: int = 1, out_dtype=None):
    """Tier-split matmul with the kernel's accumulation order."""
    out_dtype = out_dtype or a.dtype
    k = a.shape[-1]
    assert b.shape[0] == k, (a.shape, b.shape)
    assert k % n_tiers == 0, f"K={k} must divide into {n_tiers} tiers"
    kl = k // n_tiers
    acc = jnp.zeros((a.shape[0], b.shape[1]), jnp.float32)
    for t in range(n_tiers):  # sequential adder pile
        sl = slice(t * kl, (t + 1) * kl)
        acc = acc + jnp.dot(a[:, sl], b[sl, :], preferred_element_type=jnp.float32)
    return acc.astype(out_dtype)
