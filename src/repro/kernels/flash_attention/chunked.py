"""Chunked flash attention in pure jnp with a flash-2 custom VJP.

This is the CPU/dry-run twin of the Pallas kernel: the same online-
softmax chunking (KV blocks = sequential "tiers", output/m/l stationary)
expressed as a ``lax.scan`` so the lowered HLO has O(S*d) residency —
the dry-run's memory_analysis and roofline then reflect the kernel's
true behaviour instead of a naive S x S materialization.

Forward saves only (q, k, v, o, lse); the backward recomputes p per
block (flash-2):

    D_i  = rowsum(dO * O)
    p_ij = exp(q_i k_j^T * scale - lse_i)
    dV_j = p^T dO
    dS   = p * (dO V_j^T - D_i) * scale
    dQ_i += dS K_j ;  dK_j += dS^T Q_i

Layout is grouped for GQA: q is (B, KVH, G, Sq, D) and k/v are
(B, KVH, Skv, D), so each KV head is contracted against its G query
heads directly inside the einsums — no ``jnp.repeat`` materializing g×
copies of K/V per chunk. The backward's dK/dV einsums sum over G, which
is exactly the group-gradient reduction the repeat VJP used to do.
``window`` is a traced f32 scalar (+inf = global) so per-layer scanned
metadata works; its cotangent is zero.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .ref import NEG_INF

__all__ = ["flash_core"]


def _mask(qi, kj, causal: bool, window):
    qi_ = qi[:, None]
    kj_ = kj[None, :]
    ok = jnp.ones((qi.shape[0], kj.shape[0]), bool)
    if causal:
        ok = ok & (kj_ <= qi_)
    ok = ok & (kj_ > qi_ - window)
    return ok


def _chunk_kv(k, chunk):
    """(B,KVH,Skv,D) -> (nkv, B,KVH,chunk,D) with zero tail padding."""
    b, kvh, skv, d = k.shape
    nkv = -(-skv // chunk)
    pad = nkv * chunk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
    return k.reshape(b, kvh, nkv, chunk, d).transpose(2, 0, 1, 3, 4)


def _fwd_impl(q, k, v, window, causal, scale, q_offset, chunk, unroll=False):
    b, kvh, g, sq, d = q.shape
    skv = k.shape[2]
    kc = _chunk_kv(k, chunk)
    vc = _chunk_kv(v, chunk)

    qf = q.astype(jnp.float32) * scale
    qi = jnp.arange(sq) + q_offset

    def step(carry, inp):
        m, l, acc, j = carry
        k_j, v_j = inp
        s = jnp.einsum("bkgqd,bkcd->bkgqc", qf, k_j.astype(jnp.float32))
        kj = j * chunk + jnp.arange(chunk)
        ok = _mask(qi, kj, causal, window) & (kj < skv)[None, :]
        s = jnp.where(ok[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bkgqc,bkcd->bkgqd", p, v_j.astype(jnp.float32)
        )
        return (m_new, l, acc, j + 1), None

    # init carries derived from q so their varying-axes match inside
    # shard_map bodies (pipeline parallelism traces this under manual
    # collectives; constants would be non-varying and scan would reject).
    zq = jnp.zeros_like(qf)
    m0 = zq[..., 0] + NEG_INF
    l0 = zq[..., 0]
    a0 = zq
    (m, l, acc, _), _ = jax.lax.scan(step, (m0, l0, a0, jnp.int32(0)), (kc, vc), unroll=unroll)
    l_safe = jnp.where(l == 0.0, 1.0, l)
    o = (acc / l_safe[..., None]).astype(q.dtype)
    lse = m + jnp.log(l_safe)
    return o, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8))
def flash_core(q, k, v, window, causal: bool, scale: float, q_offset: int,
               chunk: int, unroll: bool = False):
    """q: (B,KVH,G,Sq,D); k, v: (B,KVH,Skv,D); window: f32 scalar
    (inf=global). Returns o: (B,KVH,G,Sq,D)."""
    o, _ = _fwd_impl(q, k, v, window, causal, scale, q_offset, chunk, unroll)
    return o


def _fwd_rule(q, k, v, window, causal, scale, q_offset, chunk, unroll=False):
    o, lse = _fwd_impl(q, k, v, window, causal, scale, q_offset, chunk, unroll)
    return o, (q, k, v, window, o, lse)


def _bwd_rule(causal, scale, q_offset, chunk, unroll, res, do):
    q, k, v, window, o, lse = res
    b, kvh, g, sq, d = q.shape
    skv = k.shape[2]
    nkv = -(-skv // chunk)
    kc = _chunk_kv(k, chunk)
    vc = _chunk_kv(v, chunk)

    qf = q.astype(jnp.float32)
    dof = do.astype(jnp.float32)
    qi = jnp.arange(sq) + q_offset
    D = jnp.sum(dof * o.astype(jnp.float32), axis=-1)  # (b,kvh,g,sq)

    def step(dq, inp):
        k_j, v_j, j = inp
        kjf = k_j.astype(jnp.float32)
        s = jnp.einsum("bkgqd,bkcd->bkgqc", qf * scale, kjf)
        kj = j * chunk + jnp.arange(chunk)
        ok = _mask(qi, kj, causal, window) & (kj < skv)[None, :]
        s = jnp.where(ok[None, None, None], s, NEG_INF)
        p = jnp.exp(s - lse[..., None])  # (b,kvh,g,q,c)
        # dV/dK contract over g as well: the per-group gradient sum that
        # jnp.repeat's VJP used to perform.
        dv_j = jnp.einsum("bkgqc,bkgqd->bkcd", p, dof)
        dp = jnp.einsum("bkgqd,bkcd->bkgqc", dof, v_j.astype(jnp.float32))
        ds = p * (dp - D[..., None]) * scale
        dq = dq + jnp.einsum("bkgqc,bkcd->bkgqd", ds, kjf)
        dk_j = jnp.einsum("bkgqc,bkgqd->bkcd", ds, qf)
        return dq, (dk_j, dv_j)

    dq0 = jnp.zeros_like(qf)
    js = jnp.arange(nkv, dtype=jnp.int32)
    dq, (dks, dvs) = jax.lax.scan(step, dq0, (kc, vc, js), unroll=unroll)
    dk = dks.transpose(1, 2, 0, 3, 4).reshape(b, kvh, nkv * chunk, d)[:, :, :skv]
    dv = dvs.transpose(1, 2, 0, 3, 4).reshape(b, kvh, nkv * chunk, d)[:, :, :skv]
    return (
        dq.astype(q.dtype),
        dk.astype(k.dtype),
        dv.astype(v.dtype),
        jnp.zeros_like(window),
    )


flash_core.defvjp(_fwd_rule, _bwd_rule)
