"""Pallas TPU kernel: blockwise online-softmax (flash) attention.

dOS structure, applied to attention: the KV sequence is the contraction
dimension. KV blocks play the "tiers" (innermost sequential grid dim);
the output tile (bq x D), the running max m and the running normalizer l
stay **stationary in VMEM** across KV steps — the attention analogue of
the paper's stationary partial-sum pile, with the softmax rescaling as
the tier-to-tier accumulation rule.

Supports causal masking, sliding-window (local) masking, GQA head
grouping and cross-attention (no mask), so it serves every attention
flavour in the model zoo (gemma3 local:global, whisper cross-attn,
llama vision cross-attn, ...).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..._jax_compat import pallas_tpu_compiler_params

_CompilerParams = pallas_tpu_compiler_params()

from .ref import NEG_INF

__all__ = ["flash_attention_pallas"]

_LANES = 128  # TPU vector lane width for the m/l scratch


def _attn_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    acc_ref,
    m_ref,
    l_ref,
    *,
    n_kv: int,
    bq: int,
    bk: int,
    causal: bool,
    window: int | None,
    scale: float,
    q_offset: int,
    out_dtype,
):
    kv_step = pl.program_id(2)

    @pl.when(kv_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32) * scale  # (bq, d)
    k = k_ref[0].astype(jnp.float32)  # (bk, d)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (bq, bk)

    q_idx = pl.program_id(1) * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    q_idx = q_idx + q_offset
    k_idx = kv_step * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), dtype=jnp.bool_)
    if causal:
        mask = mask & (k_idx <= q_idx)
    if window is not None:
        mask = mask & (k_idx > q_idx - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[:, :1]  # (bq, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)  # (bq, bk)
    corr = jnp.exp(m_prev - m_new)  # (bq, 1)

    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    v = v_ref[0].astype(jnp.float32)  # (bk, d)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)

    @pl.when(kv_step == n_kv - 1)
    def _emit():
        l = l_ref[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows stay zero
        o_ref[0, ...] = (acc_ref[...] / l).astype(out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "scale", "q_offset", "bq", "bk", "group", "heads",
        "interpret",
    ),
)
def flash_attention_pallas(
    q: jax.Array,  # (BH, Sq, D)   flattened batch*heads
    k: jax.Array,  # (BKVH, Skv, D)
    v: jax.Array,
    *,
    group: int,  # q heads per kv head (GQA)
    heads: int | None = None,  # q heads per batch (for kv index math)
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    q_offset: int = 0,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    bh, sq, d = q.shape
    bkvh, skv, _ = k.shape
    h = heads if heads is not None else bh  # q heads per batch row
    kvh = h // group
    assert sq % bq == 0 and skv % bk == 0, (sq, bq, skv, bk)
    if scale is None:
        scale = 1.0 / (d**0.5)
    n_kv = skv // bk
    grid = (bh, sq // bq, n_kv)

    def q_map(bhi, i, j):
        return (bhi, i, 0)

    def kv_map(bhi, i, j):
        b = bhi // h
        hh = bhi % h
        return (b * kvh + hh // group, j, 0)

    kernel = functools.partial(
        _attn_kernel,
        n_kv=n_kv,
        bq=bq,
        bk=bk,
        causal=causal,
        window=window,
        scale=scale,
        q_offset=q_offset,
        out_dtype=q.dtype,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), q_map),
            pl.BlockSpec((1, bk, d), kv_map),
            pl.BlockSpec((1, bk, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq, d), q_map),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, _LANES), jnp.float32),
            pltpu.VMEM((bq, _LANES), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
