"""Public attention ops used by the model zoo.

``flash_attention``: training/prefill attention over full sequences.
On TPU it dispatches to the Pallas kernel; on CPU to the jnp reference
(clean HLO for smoke tests and the multi-pod dry-run).

``decode_attention``: single-token attention against a KV cache. This
is a bandwidth-bound matvec (no flash tiling needed); implemented as
einsum so XLA shards it freely across the mesh.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...parallel.axes import shard
from .chunked import flash_core
from .kernel import flash_attention_pallas
from .ref import attention_ref

__all__ = ["flash_attention", "decode_attention", "flash_attention_jnp"]


def flash_attention_jnp(q, k, v, *, causal=True, window=None, scale=None,
                        q_offset=0, chunk=512, unroll=False):
    """Chunked flash attention (custom-VJP lax.scan) on (B,S,H,D)
    layouts — the CPU/dry-run path with kernel-equivalent memory
    behaviour. ``window`` may be a traced scalar."""
    b, sq, h, d = q.shape
    _, skv, kvh, _ = k.shape
    g = h // kvh
    scale = scale if scale is not None else 1.0 / (d**0.5)
    # grouped GQA layout: q (B,KVH,G,Sq,D), k/v (B,KVH,Skv,D) — the core
    # contracts each KV head against its G query heads directly instead
    # of materializing g× repeated K/V copies.
    qt = q.reshape(b, sq, kvh, g, d).transpose(0, 2, 3, 1, 4)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    win = jnp.float32(jnp.inf) if window is None else jnp.asarray(window, jnp.float32)
    chunk = min(chunk, skv)
    o = flash_core(qt, kt, vt, win, causal, float(scale), int(q_offset), chunk, unroll)
    return o.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, d)


def flash_attention(
    q: jax.Array,  # (B, Sq, H, D)
    k: jax.Array,  # (B, Skv, KVH, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    q_offset: int = 0,
    interpret: bool | None = None,
    force_ref: bool = False,
    unroll: bool = False,
) -> jax.Array:
    """Dispatching wrapper (plain function — the surrounding model jit
    traces it; keeping it un-jitted preserves python ints as static
    tiling params for the Pallas path)."""
    if force_ref:
        return attention_ref(
            q, k, v, causal=causal, window=window, scale=scale, q_offset=q_offset
        )
    if interpret is None:
        if jax.default_backend() != "tpu":
            return flash_attention_jnp(
                q, k, v, causal=causal, window=window, scale=scale,
                q_offset=q_offset, unroll=unroll,
            )
        interpret = False

    # Pallas path: tiling parameters must be static Python values.
    assert window is None or isinstance(window, int), (
        "traced `window` is only supported on the jnp reference path"
    )
    assert q_offset is None or isinstance(q_offset, int)
    b, sq, h, d = q.shape
    _, skv, kvh, _ = k.shape
    group = h // kvh
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * kvh, skv, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * kvh, skv, d)
    bq = min(128, sq)
    bk = min(128, skv)
    o = flash_attention_pallas(
        qf,
        kf,
        vf,
        group=group,
        heads=h,
        causal=causal,
        window=window,
        scale=scale,
        q_offset=q_offset,
        bq=bq,
        bk=bk,
        interpret=interpret,
    )
    return o.reshape(b, h, sq, d).transpose(0, 2, 1, 3)


def decode_attention(
    q: jax.Array,  # (B, 1, H, D)
    k_cache: jax.Array,  # (B, S, KVH, D)
    v_cache: jax.Array,
    *,
    length: jax.Array | int,  # valid cache length (scalar or per-batch)
    window: int | None = None,
    scale: float | None = None,
) -> jax.Array:
    """One decode step: q attends to the first ``length`` cache slots
    (and at most the trailing ``window`` of them, if sliding)."""
    b, _, h, d = q.shape
    _, s, kvh, _ = k_cache.shape
    g = h // kvh
    scale = scale if scale is not None else 1.0 / (d**0.5)

    qf = (q.astype(jnp.float32) * scale).astype(q.dtype)
    qf = qf.reshape(b, kvh, g, d)
    # match the cache layout (KVH-sharded when divisible) so the logits
    # einsum partitions by head instead of all-gathering the cache.
    qf = shard(qf, "decode_q_kvh")
    # Transpose the cache to (B, KVH, S, D) — in its storage dtype, so
    # no f32 second copy is materialized (f32 accumulation comes from
    # preferred_element_type). With KVH leading, both contractions lower
    # as plain batched GEMV over S instead of a strided 5-D einsum with
    # a dummy q axis, which is markedly faster on CPU.
    kc = k_cache.transpose(0, 2, 1, 3)
    vc = v_cache.transpose(0, 2, 1, 3)
    logits = jnp.einsum(
        "bkgd,bksd->bkgs", qf, kc,
        preferred_element_type=jnp.float32,
    )  # (b, kvh, g, s)

    pos = jnp.arange(s)
    lengths = jnp.broadcast_to(jnp.asarray(length), (b,))[:, None]
    valid = pos[None, :] < lengths
    if window is not None:
        # window includes the newest position (index length-1)
        valid = valid & (pos[None, :] >= lengths - window)
    neg = jnp.finfo(jnp.float32).min * 0.7
    vmask = valid[:, None, None, :]
    logits = jnp.where(vmask, logits, neg)
    m = jnp.max(logits, axis=-1, keepdims=True)
    # Zero the masked slots explicitly: when NO slot is valid (length=0,
    # or a window that excludes everything) the max trick would yield a
    # uniform softmax over garbage — the output must be exact zeros.
    p = jnp.where(vmask, jnp.exp(logits - m), 0.0)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    p = p / jnp.where(denom > 0.0, denom, 1.0)
    o = jnp.einsum(
        "bkgs,bksd->bkgd", p.astype(v_cache.dtype), vc,
        preferred_element_type=jnp.float32,
    )
    return o.reshape(b, 1, h, d).astype(q.dtype)
