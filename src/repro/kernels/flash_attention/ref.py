"""Pure-jnp oracle for blockwise (flash) attention.

Layout convention: q (B, Sq, H, D); k, v (B, Skv, KVH, D) with
H = G * KVH (GQA groups). Masks: causal, sliding-window (attend to the
last ``window`` positions incl. self), or full (cross-attention).
All math in f32.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

__all__ = ["attention_ref", "NEG_INF"]

NEG_INF = -0.7 * float(np.finfo(np.float32).max)


def attention_ref(
    q,
    k,
    v,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    q_offset: int = 0,
):
    """Reference attention. ``q_offset`` places the query block at
    absolute positions [q_offset, q_offset+Sq) relative to the keys
    (used for decode: Sq=1, q_offset=cache_len-1)."""
    b, sq, h, d = q.shape
    _, skv, kvh, _ = k.shape
    assert h % kvh == 0, (h, kvh)
    g = h // kvh
    scale = scale if scale is not None else 1.0 / np.sqrt(d)

    qf = q.astype(jnp.float32) * scale
    kf = jnp.repeat(k.astype(jnp.float32), g, axis=2)
    vf = jnp.repeat(v.astype(jnp.float32), g, axis=2)

    s = jnp.einsum("bqhd,bkhd->bhqk", qf, kf)
    qi = jnp.arange(sq)[:, None] + q_offset
    kj = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask = mask & (kj <= qi)
    if window is not None:
        mask = mask & (kj > qi - window)
    s = jnp.where(mask[None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - m)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, vf)
    return o.astype(q.dtype)
