from .kernel import ssm_scan_pallas
from .ops import ssm_scan, ssm_scan_chunked_jnp
from .ref import ssm_scan_ref, ssm_step_ref

__all__ = ["ssm_scan", "ssm_scan_pallas", "ssm_scan_chunked_jnp", "ssm_scan_ref", "ssm_step_ref"]
