"""Pallas TPU kernel: chunked SSD (Mamba2 / mLSTM) scan.

dOS structure applied to a recurrence: time is the contraction
dimension. The sequence is tiled into chunks (the innermost sequential
grid dim — the "tiers"); the inter-chunk SSM state (N x P) stays
**stationary in a VMEM f32 scratch** across chunk steps, exactly like
the dOS partial-sum pile. Within a chunk, the recurrence is rewritten
as dense MXU matmuls (the SSD "matrix transform" form):

  per chunk of length T, with la_i = cumsum(ld_i) (log-decay):
    L_ij    = exp(la_i - la_j)  for j <= i else 0     (T x T)
    y_intra = ((C B^T) * L) @ U                        (T x P)
    y_inter = exp(la_i) * (C_i @ S_prev)               (T x P)
    S_new   = exp(la_T) S_prev + (exp(la_T - la_j) B_j)^T @ U

All accumulation in f32. Grid: (batch*heads, n_chunks); the chunk dim
is sequential ('arbitrary') so the state scratch carries across chunks
of the same (b, h) row. The final state is emitted as a second output
(prefill hands it to the decode loop).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..._jax_compat import pallas_tpu_compiler_params

_CompilerParams = pallas_tpu_compiler_params()

__all__ = ["ssm_scan_pallas"]


def _ssd_kernel(u_ref, ld_ref, b_ref, c_ref, y_ref, sout_ref, s_ref, *, chunk: int, n_chunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    u = u_ref[0].astype(jnp.float32)  # (T, P)
    ld = ld_ref[0].astype(jnp.float32)  # (T, 1)
    bmat = b_ref[0].astype(jnp.float32)  # (T, N)
    cmat = c_ref[0].astype(jnp.float32)  # (T, N)

    la = jnp.cumsum(ld[:, 0])  # (T,) log cumulative decay

    # Intra-chunk: ((C B^T) * L) @ U with L the decay-masked lower tri.
    cb = jnp.dot(cmat, bmat.T, preferred_element_type=jnp.float32)  # (T, T)
    li = la[:, None] - la[None, :]  # la_i - la_j
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    lmat = jnp.exp(jnp.where(jj <= ii, li, -1e30))  # mask before exp
    y = jnp.dot(cb * lmat, u, preferred_element_type=jnp.float32)  # (T, P)

    # Inter-chunk: previous state decayed to each position.
    s_prev = s_ref[...]  # (N, P)
    decay_i = jnp.exp(la)[:, None]  # (T, 1)
    y = y + decay_i * jnp.dot(cmat, s_prev, preferred_element_type=jnp.float32)

    # State update for the next chunk.
    decay_tot = jnp.exp(la[-1])
    bdec = bmat * jnp.exp(la[-1] - la)[:, None]  # (T, N)
    s_new = decay_tot * s_prev + jnp.dot(
        bdec.T, u, preferred_element_type=jnp.float32
    )
    s_ref[...] = s_new

    y_ref[0, ...] = y.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _emit_state():
        sout_ref[0, ...] = s_new


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssm_scan_pallas(
    u: jax.Array,  # (BH, S, P) flattened batch*heads
    ld: jax.Array,  # (BH, S, 1) log-decay
    B: jax.Array,  # (BH, S, N)
    C: jax.Array,  # (BH, S, N)
    *,
    chunk: int = 128,
    interpret: bool = False,
):
    """Returns (y: (BH, S, P), final_state: (BH, N, P) f32)."""
    bh, s, p = u.shape
    n = B.shape[-1]
    assert s % chunk == 0, (s, chunk)
    n_chunks = s // chunk
    grid = (bh, n_chunks)

    def seq_map(i, j):
        return (i, j, 0)

    def row_map(i, j):
        return (i, 0, 0)

    kernel = functools.partial(_ssd_kernel, chunk=chunk, n_chunks=n_chunks)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, p), seq_map),
            pl.BlockSpec((1, chunk, 1), seq_map),
            pl.BlockSpec((1, chunk, n), seq_map),
            pl.BlockSpec((1, chunk, n), seq_map),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, p), seq_map),
            pl.BlockSpec((1, n, p), row_map),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, p), u.dtype),
            jax.ShapeDtypeStruct((bh, n, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(u, ld, B, C)
