"""Public op: chunked SSD scan with CPU fallback.

``ssm_scan(u, ld, B, C)`` with model-facing layout
u: (Bt, S, H, P), ld: (Bt, S, H), B/C: (Bt, S, H, N).
Returns (y: (Bt, S, H, P), final_state: (Bt, H, N, P) f32).

On TPU dispatches to the Pallas chunked kernel (per-(batch, head)
rows); on CPU uses a *chunked jnp implementation with identical math*
(so the dry-run HLO reflects the real matmul structure, not a length-S
scan). The step-by-step reference remains the validation oracle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import ssm_scan_pallas
from .ref import ssm_scan_ref

__all__ = ["ssm_scan", "ssm_scan_chunked_jnp"]


def ssm_scan_chunked_jnp(u, ld, B, C, chunk: int = 128, unroll: bool = False):
    """Chunked SSD in plain jnp — the same math as the Pallas kernel,
    vectorized over (batch, head); used on CPU and for the dry-run."""
    bt, s, h, p = u.shape
    n = B.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    uf = u.astype(jnp.float32).reshape(bt, nc, chunk, h, p)
    ldf = ld.astype(jnp.float32).reshape(bt, nc, chunk, h)
    Bf = B.astype(jnp.float32).reshape(bt, nc, chunk, h, n)
    Cf = C.astype(jnp.float32).reshape(bt, nc, chunk, h, n)

    la = jnp.cumsum(ldf, axis=2)  # (bt,nc,T,h)

    # Intra-chunk (batched over bt, nc, h).
    cb = jnp.einsum("bcihn,bcjhn->bcijh", Cf, Bf)  # (bt,nc,T,T,h)
    li = la[:, :, :, None, :] - la[:, :, None, :, :]  # (bt,nc,T,T,h)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    # mask BEFORE exp: the j>i entries are positive log-decays whose exp
    # overflows; where() after exp leaks inf*0=NaN into the backward.
    li = jnp.where(tri[None, None, :, :, None], li, -1e30)
    lmat = jnp.exp(li)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", cb * lmat, uf)

    # Cross-chunk state: sequential scan over chunks (nc steps). The
    # scan carries ONLY the state — each step is an elementwise
    # decay-and-add on (bt,h,n,p) and emits the state *entering* the
    # chunk; the C-contraction is hoisted out into one batched einsum
    # over all chunks below, cutting per-step dispatch overhead.
    decay_tot = jnp.exp(la[:, :, -1, :])  # (bt,nc,h)
    dec = jnp.exp(la[:, :, -1:, :] - la)  # (bt,nc,T,h)
    s_inc = jnp.einsum("bcjhn,bcjh,bcjhp->bchnp", Bf, dec, uf)

    def chunk_step(state, inp):
        d_tot, inc = inp
        prev = state
        state = d_tot[:, :, None, None] * state + inc
        return state, prev

    inputs = (
        decay_tot.transpose(1, 0, 2),
        s_inc.transpose(1, 0, 2, 3, 4),
    )
    s0 = jnp.zeros((bt, h, n, p), jnp.float32)
    final, prevs = jax.lax.scan(chunk_step, s0, inputs, unroll=unroll)
    states = prevs.transpose(1, 0, 2, 3, 4)  # (bt,nc,h,n,p)
    y_inter = jnp.einsum("bcihn,bchnp,bcih->bcihp", Cf, states, jnp.exp(la))

    y = (y_intra + y_inter).reshape(bt, s, h, p)
    return y.astype(u.dtype), final


@functools.partial(jax.jit, static_argnames=("chunk", "interpret", "force_ref", "unroll"))
def ssm_scan(
    u: jax.Array,
    ld: jax.Array,
    B: jax.Array,
    C: jax.Array,
    *,
    chunk: int | None = None,
    interpret: bool | None = None,
    force_ref: bool = False,
    unroll: bool = False,
):
    """Chunked SSD scan; returns (y (Bt,S,H,P), state (Bt,H,N,P)).

    ``chunk=None`` auto-picks: 128 on TPU (MXU-sized tiles for the
    Pallas kernel) but 32 on CPU, where the O(S*T) intra-chunk T×T
    decay matrix dominates and smaller chunks win despite more scan
    steps (the batched cross-chunk step keeps scan overhead flat)."""
    if force_ref:
        return ssm_scan_ref(u, ld, B, C)
    if chunk is None:
        chunk = 128 if jax.default_backend() == "tpu" else 32
    s_orig = u.shape[1]
    chunk = min(chunk, s_orig)
    if s_orig % chunk:
        # Pad with identity steps: ld=0 (decay 1), u=0, B=0 leave the
        # state untouched; the padded outputs are sliced away.
        pad = chunk - s_orig % chunk
        padw = ((0, 0), (0, pad), (0, 0), (0, 0))
        u = jnp.pad(u, padw)
        B = jnp.pad(B, padw)
        C = jnp.pad(C, padw)
        ld = jnp.pad(ld, ((0, 0), (0, pad), (0, 0)))
        y, state = ssm_scan(
            u, ld, B, C, chunk=chunk, interpret=interpret, force_ref=force_ref,
            unroll=unroll,
        )
        return y[:, :s_orig], state
    if interpret is None:
        if jax.default_backend() != "tpu":
            return ssm_scan_chunked_jnp(u, ld, B, C, chunk=chunk, unroll=unroll)
        interpret = False

    bt, s, h, p = u.shape
    n = B.shape[-1]
    ur = u.transpose(0, 2, 1, 3).reshape(bt * h, s, p)
    ldr = ld.transpose(0, 2, 1).reshape(bt * h, s, 1)
    Br = B.transpose(0, 2, 1, 3).reshape(bt * h, s, n)
    Cr = C.transpose(0, 2, 1, 3).reshape(bt * h, s, n)
    y, state = ssm_scan_pallas(ur, ldr, Br, Cr, chunk=chunk, interpret=interpret)
    return (
        y.reshape(bt, h, s, p).transpose(0, 2, 1, 3),
        state.reshape(bt, h, n, p),
    )
