"""Pure-jnp oracle for the chunked SSD scan.

Generalized linear-recurrence (SSD) form, per batch b and head h:

    s_t = a_t * s_{t-1} + B_t u_t^T          s in R^{N x P}
    y_t = s_t^T C_t                          y in R^P

where a_t = exp(ld_t) is a scalar-per-(step, head) decay given as
log-decay ld_t <= 0, and u_t in R^P is the (already-scaled) input.

This covers both users in the zoo:
- Mamba2:  ld_t = dt_t * A_h (A_h < 0), u_t = dt_t * x_t
- mLSTM:   ld_t = log f_t (forget gate), u_t = v_t, B_t = i_t * k_t,
           C_t = q_t (plus a P=1 normalizer scan)

The reference materializes the recurrence step by step with
``lax.scan`` — the ground truth the chunked kernel must match.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["ssm_scan_ref", "ssm_step_ref"]


def ssm_scan_ref(u, ld, B, C, s0=None):
    """u: (Bt, S, H, P), ld: (Bt, S, H), B/C: (Bt, S, H, N).

    Returns y: (Bt, S, H, P) and the final state (Bt, H, N, P).
    """
    bt, s, h, p = u.shape
    n = B.shape[-1]
    uf = u.astype(jnp.float32)
    af = jnp.exp(ld.astype(jnp.float32))
    Bf = B.astype(jnp.float32)
    Cf = C.astype(jnp.float32)
    if s0 is None:
        s0 = jnp.zeros((bt, h, n, p), jnp.float32)

    def step(state, inp):
        u_t, a_t, b_t, c_t = inp  # (bt,h,p), (bt,h), (bt,h,n), (bt,h,n)
        state = (
            a_t[:, :, None, None] * state
            + b_t[:, :, :, None] * u_t[:, :, None, :]
        )
        y_t = jnp.einsum("bhnp,bhn->bhp", state, c_t)
        return state, y_t

    inputs = (
        uf.transpose(1, 0, 2, 3),
        af.transpose(1, 0, 2),
        Bf.transpose(1, 0, 2, 3),
        Cf.transpose(1, 0, 2, 3),
    )
    final, ys = jax.lax.scan(step, s0, inputs)
    y = ys.transpose(1, 0, 2, 3)  # (bt, s, h, p)
    return y.astype(u.dtype), final


def ssm_step_ref(state, u_t, ld_t, B_t, C_t):
    """Single decode step. state: (Bt,H,N,P); u_t: (Bt,H,P);
    ld_t: (Bt,H); B_t/C_t: (Bt,H,N). Returns (y_t, new_state)."""
    a_t = jnp.exp(ld_t.astype(jnp.float32))
    state = (
        a_t[:, :, None, None] * state
        + B_t.astype(jnp.float32)[:, :, :, None] * u_t.astype(jnp.float32)[:, :, None, :]
    )
    y_t = jnp.einsum("bhnp,bhn->bhp", state, C_t.astype(jnp.float32))
    return y_t.astype(u_t.dtype), state
