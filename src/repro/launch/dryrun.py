import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: for each cell we build the production mesh from placeholder
host devices, jit the right step function with full NamedShardings,
``.lower().compile()`` it, and record

  - ``compiled.memory_analysis()``  (fits-per-chip evidence)
  - ``compiled.cost_analysis()``    (per-device FLOPs / bytes)
  - the collective schedule parsed from the compiled HLO

into a JSON artifact under experiments/dryrun/. EXPERIMENTS.md §Dry-run
and §Roofline are generated from these artifacts (benchmarks/roofline).

NOTE the XLA_FLAGS line above must execute before ANY other import —
jax locks the device count at first init. Do not set that flag globally:
smoke tests and benches must see 1 device.
"""

import argparse
import dataclasses
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp

from .._jax_compat import unwrap_cost_analysis
from ..analysis.roofline import parse_collectives, roofline_from_artifact
from ..config import SHAPES, RunConfig
from ..configs import REGISTRY, cells, get_config
from ..models import build
from ..models.params import ParamDef, tree_size
from ..optim import OptConfig
from ..parallel.axes import ShardingRules, use_rules
from ..parallel.plan import make_plan
from .mesh import make_production_mesh
from .steps import make_prefill_step, make_serve_step, make_train_step

ART_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def model_flops_for(model, shape) -> float:
    """MODEL_FLOPS = 6*N*D (train) or 2*N*D (inference), N = active
    params minus the embedding gather table, D = tokens processed."""
    cfg = model.cfg
    n = model.n_params
    if cfg.family == "moe":
        routed = tree_size(
            {
                k: v
                for k, v in model.defs["layers"]["ffn"].items()
                if k in ("wi_gate", "wi_up", "wo")
            }
        )
        n -= routed * (1.0 - cfg.top_k / cfg.n_experts)
    n -= cfg.vocab * cfg.d_model  # embedding gather does no matmul flops
    if shape.mode == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.mode == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def microbatch_policy(cfg, shape) -> int:
    """Gradient-accumulation factor for train cells: activation
    transients shrink by this factor so the biggest models fit HBM."""
    if shape.mode != "train":
        return 1
    n = cfg.n_params
    if n > 40e9:
        return 8
    if n > 5e9:
        return 4
    return 1


def variant_cfg(cfg, k: int):
    """A k-unit fully-unrolled copy of the arch for exact cost
    accounting (cost_analysis counts loop bodies once; the unrolled
    1-unit and 2-unit variants give base + per-unit costs exactly)."""
    kw = dict(scan_layers=False, unroll_inner=True)
    fam = cfg.family
    if fam in ("dense", "moe"):
        kw["n_layers"] = k
    elif fam == "vlm":
        kw["n_layers"] = k * cfg.cross_every
    elif fam == "hybrid":
        kw["n_layers"] = k * cfg.attn_every
    elif fam == "ssm":
        kw["n_layers"] = k
        kw["slstm_at"] = ()  # sLSTM counted as mLSTM-equivalent (noted)
    elif fam == "encdec":
        kw["n_layers"] = k
        kw["n_enc_layers"] = k
    return dataclasses.replace(cfg, **kw)


def n_units(cfg) -> int:
    if cfg.family == "vlm":
        return cfg.n_layers // cfg.cross_every
    if cfg.family == "hybrid":
        return cfg.n_layers // cfg.attn_every
    return cfg.n_layers  # dense/moe/ssm layers; encdec (enc, dec) pairs


def lower_cell(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    strategy: str = "dos",
    fsdp: bool = True,
    remat: bool = True,
    donate: bool = True,
    cfg_override=None,
    microbatches: int | None = None,
    unroll_mb: bool = False,
):
    """Lower + compile one cell; returns (artifact dict, compiled)."""
    cfg = cfg_override if cfg_override is not None else get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    n_chips = mesh.size
    model = build(cfg)
    rules = ShardingRules(
        mesh, strategy=strategy, fsdp=fsdp and shape.mode == "train"
    )
    plan = make_plan(model, shape, rules)
    mb = microbatches if microbatches is not None else microbatch_policy(cfg, shape)

    if shape.mode == "train":
        step = make_train_step(model, OptConfig(), remat=remat,
                               microbatches=mb, unroll_mb=unroll_mb)
        donate_argnums = (0, 1) if donate else ()
    elif shape.mode == "prefill":
        step = make_prefill_step(model, max_len=shape.seq_len)
        donate_argnums = ()
    else:
        step = make_serve_step(model)
        donate_argnums = (1,) if donate else ()

    t0 = time.time()
    with use_rules(rules), mesh:
        lowered = jax.jit(
            step,
            in_shardings=plan.in_shardings,
            out_shardings=plan.out_shardings,
            donate_argnums=donate_argnums,
        ).lower(*plan.abstract)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = unwrap_cost_analysis(compiled.cost_analysis())
    coll = parse_collectives(compiled.as_text())
    rf = roofline_from_artifact(
        arch=arch,
        shape=shape_name,
        mesh_name=mesh_name,
        n_chips=n_chips,
        cost=cost,
        coll=coll,
        model_flops=model_flops_for(model, shape),
    )

    artifact = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "strategy": strategy,
        "fsdp": bool(fsdp and shape.mode == "train"),
        "n_chips": n_chips,
        "mode": shape.mode,
        "microbatches": mb,
        "n_params": model.n_params,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_per_device_gb": round(
                (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                 + mem.output_size_in_bytes - mem.alias_size_in_bytes)
                / 2**30, 3,
            ),
        },
        "cost": {k: v for k, v in cost.items() if k in ("flops", "bytes accessed")},
        "collectives": {
            "counts": coll.counts,
            "wire_bytes": coll.wire_bytes,
            "by_op_bytes": coll.by_op_bytes,
        },
        "roofline": rf.to_dict(),
    }
    return artifact, compiled


def measure_cost_corrected(arch, shape_name, *, multi_pod, strategy, fsdp,
                           remat, microbatches=None):
    """Exact per-step cost via unrolled 1-unit / 2-unit variants:
    total(metric) = cost(1) + (units - 1) * (cost(2) - cost(1))."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mb = microbatches if microbatches is not None else microbatch_policy(cfg, shape)
    outs = []
    for k in (1, 2):
        vcfg = variant_cfg(cfg, k)
        art, compiled = lower_cell(
            arch, shape_name, multi_pod=multi_pod, strategy=strategy,
            fsdp=fsdp, remat=remat, cfg_override=vcfg,
            microbatches=mb, unroll_mb=True,
        )
        coll = parse_collectives(compiled.as_text())
        outs.append((art["cost"], coll))
    (c1, coll1), (c2, coll2) = outs
    units = n_units(cfg)

    def comb(a, b):
        return a + (units - 1) * (b - a)

    cost = {
        "flops": comb(c1.get("flops", 0.0), c2.get("flops", 0.0)),
        "bytes accessed": comb(
            c1.get("bytes accessed", 0.0), c2.get("bytes accessed", 0.0)
        ),
    }
    wire = comb(coll1.wire_bytes, coll2.wire_bytes)
    by_op = {
        op: comb(coll1.by_op_bytes.get(op, 0.0), coll2.by_op_bytes.get(op, 0.0))
        for op in set(coll1.by_op_bytes) | set(coll2.by_op_bytes)
    }
    counts = {
        op: int(comb(coll1.counts.get(op, 0), coll2.counts.get(op, 0)))
        for op in set(coll1.counts) | set(coll2.counts)
    }
    from ..analysis.roofline import CollectiveStats

    coll = CollectiveStats(
        wire_bytes=wire, result_bytes=0.0, counts=counts, by_op_bytes=by_op
    )
    return cost, coll


def cell_key(arch, shape, mesh_name, strategy):
    return f"{arch}__{shape}__{mesh_name}__{strategy}"


def run_and_save(arch, shape_name, *, multi_pod, strategy="dos", force=False,
                 verbose=True, **kw):
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    ART_DIR.mkdir(parents=True, exist_ok=True)
    out = ART_DIR / (cell_key(arch, shape_name, mesh_name, strategy) + ".json")
    if out.exists() and not force:
        if verbose:
            print(f"[skip] {out.name} (cached)")
        return json.loads(out.read_text())
    try:
        artifact, compiled = lower_cell(
            arch, shape_name, multi_pod=multi_pod, strategy=strategy, **kw
        )
        # Exact cost accounting (single-pod roofline only — the
        # multi-pod pass proves compilation/sharding).
        if not multi_pod:
            cfg = get_config(arch)
            shape = SHAPES[shape_name]
            model = build(cfg)
            cost_c, coll_c = measure_cost_corrected(
                arch, shape_name, multi_pod=multi_pod, strategy=strategy,
                fsdp=kw.get("fsdp", True), remat=kw.get("remat", True),
            )
            from ..analysis.traffic import traffic_bytes_per_device

            kbytes = traffic_bytes_per_device(
                cfg, shape, model.n_params,
                n_chips=artifact["n_chips"],
                microbatches=artifact.get("microbatches", 1),
            )
            rf = roofline_from_artifact(
                arch=arch, shape=shape_name,
                mesh_name=artifact["mesh"], n_chips=artifact["n_chips"],
                cost=cost_c, coll=coll_c,
                model_flops=model_flops_for(model, shape),
                kernel_bytes=kbytes,
            )
            artifact["cost_corrected"] = cost_c
            artifact["collectives_corrected"] = {
                "counts": coll_c.counts,
                "wire_bytes": coll_c.wire_bytes,
                "by_op_bytes": coll_c.by_op_bytes,
            }
            artifact["roofline"] = rf.to_dict()
    except Exception as e:  # record failures — they are bugs to fix
        artifact = {
            "arch": arch, "shape": shape_name, "mesh": mesh_name,
            "strategy": strategy, "error": f"{type(e).__name__}: {e}",
            "traceback": traceback.format_exc()[-4000:],
        }
        out.write_text(json.dumps(artifact, indent=1))
        if verbose:
            print(f"[FAIL] {out.name}: {artifact['error']}")
        return artifact
    out.write_text(json.dumps(artifact, indent=1))
    if verbose:
        r = artifact["roofline"]
        print(
            f"[ok] {out.name}: mem/dev={artifact['memory']['peak_per_device_gb']}GB "
            f"flops/dev={artifact['cost'].get('flops', 0):.3e} "
            f"dominant={r['dominant']} step~{r['step_s']*1e3:.2f}ms "
            f"(compile {artifact['compile_s']}s)"
        )
    return artifact


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="one arch (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--strategy", default="dos", choices=["dos", "megatron", "zero", "auto"])
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--remat-policy", default=None,
                    help="'save_gathered' keeps FSDP gathers across bwd")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    live, skipped = cells()
    if args.list:
        for a, s in live:
            print(f"{a} {s}")
        for a, s, why in skipped:
            print(f"# SKIP {a} {s}: {why}")
        return

    todo = [
        (a, s)
        for a, s in live
        if (args.arch is None or a == args.arch)
        and (args.shape is None or s == args.shape)
    ]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    n_fail = 0
    for a, s in todo:
        for mp in meshes:
            remat = (args.remat_policy or True) if not args.no_remat else False
            art = run_and_save(
                a, s, multi_pod=mp, strategy=args.strategy,
                fsdp=not args.no_fsdp, remat=remat,
                force=args.force,
            )
            n_fail += 1 if "error" in art else 0
    print(f"done: {len(todo) * len(meshes)} cells, {n_fail} failures")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
