"""Production mesh construction.

Defined as functions (not module-level constants) so importing never
touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 BEFORE first jax
use, and smoke tests must keep seeing 1 device.
"""

from __future__ import annotations

from repro._jax_compat import make_mesh as _make_mesh

__all__ = ["make_production_mesh", "make_test_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model). Multi-pod: 2 pods
    = 512 chips (pod, data, model); the pod axis carries DP by default
    or pipeline stages with --pipeline."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_test_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many devices the test environment has."""
    return _make_mesh((data, model), ("data", "model"))
