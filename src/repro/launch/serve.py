"""Serving launcher: batched prefill + decode loop.

Drives the same prefill/serve steps the dry-run lowers, on real
devices. Measures prefill latency, aggregate decode throughput and
per-token decode latency percentiles (each step synchronized, so the
median/p99 spread is visible, not averaged away); the examples use it
with reduced configs and ``--json`` emits the machine-readable summary
CI smoke checks parse.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from ..configs import get_config, reduced
from ..models import build
from ..parallel.axes import ShardingRules, param_sharding, use_rules
from .mesh import make_test_mesh


def serve_loop(
    cfg,
    *,
    batch: int = 4,
    prompt_len: int = 64,
    gen_tokens: int = 32,
    strategy: str = "dos",
    mesh_shape=(1, 1),
    seed: int = 0,
    greedy: bool = True,
):
    mesh = make_test_mesh(*mesh_shape)
    rules = ShardingRules(mesh, strategy=strategy, fsdp=False)
    model = build(cfg)
    max_len = prompt_len + gen_tokens

    with use_rules(rules), mesh:
        ps = param_sharding(model.defs, rules)
        params = jax.device_put(model.init(jax.random.PRNGKey(seed)), ps)

        rng = jax.random.PRNGKey(seed + 1)
        prompts = jax.random.randint(rng, (batch, prompt_len), 0, cfg.vocab)
        pf_batch = {"tokens": prompts}
        if cfg.family == "vlm":
            pf_batch["image_embeds"] = jax.random.normal(
                rng, (batch, cfg.n_image_tokens, cfg.d_model),
                dtype=jnp.dtype(cfg.compute_dtype),
            )
        if cfg.family == "encdec":
            pf_batch["enc_frames"] = jax.random.normal(
                rng, (batch, cfg.enc_seq, cfg.d_model),
                dtype=jnp.dtype(cfg.compute_dtype),
            )

        prefill = jax.jit(lambda p, b: model.prefill(p, b, max_len=max_len))
        decode = jax.jit(model.decode)

        t0 = time.time()
        logits, cache = prefill(params, pf_batch)
        logits.block_until_ready()
        t_prefill = time.time() - t0

        tok = jnp.argmax(logits[:, -1:], axis=-1)
        out_tokens = [tok]
        # Per-step timing: synchronize every decode step so the
        # percentiles measure real step latency (the first step carries
        # the jit compile; it is kept — p99 reports it honestly, the
        # median ignores it).
        step_s = []
        t0 = time.time()
        for _ in range(gen_tokens - 1):
            ts = time.time()
            logits, cache = decode(params, cache, {"token": tok})
            tok = jnp.argmax(logits, axis=-1)
            tok.block_until_ready()
            step_s.append(time.time() - ts)
            out_tokens.append(tok)
        t_decode = time.time() - t0

    gen = jnp.concatenate(out_tokens, axis=1)
    steps = jnp.asarray(step_s) if step_s else jnp.zeros(1)
    return {
        "generated": gen,
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        "decode_tok_s": batch * (gen_tokens - 1) / max(t_decode, 1e-9),
        "step_p50_s": float(jnp.percentile(steps, 50)),
        "step_p99_s": float(jnp.percentile(steps, 99)),
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen-tokens", type=int, default=32)
    ap.add_argument("--strategy", default="dos")
    ap.add_argument("--json", action="store_true",
                    help="emit the machine-readable summary (CI smoke)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    r = serve_loop(
        cfg, batch=args.batch, prompt_len=args.prompt_len,
        gen_tokens=args.gen_tokens, strategy=args.strategy,
    )
    if args.json:
        print(json.dumps({
            "arch": args.arch,
            "batch": args.batch,
            "prompt_len": args.prompt_len,
            "gen_tokens": args.gen_tokens,
            "prefill_s": r["prefill_s"],
            "decode_tok_s": r["decode_tok_s"],
            "step_p50_s": r["step_p50_s"],
            "step_p99_s": r["step_p99_s"],
        }, indent=1))
        return
    print(
        f"prefill {r['prefill_s']*1e3:.1f}ms; decode {r['decode_tok_s']:.1f} tok/s "
        f"(step p50 {r['step_p50_s']*1e3:.2f}ms, p99 {r['step_p99_s']*1e3:.2f}ms); "
        f"sample: {r['generated'][0, :16].tolist()}"
    )


if __name__ == "__main__":
    main()
