"""Serving launcher: batched prefill + decode loop.

Drives the same prefill/serve steps the dry-run lowers, on real
devices. Measures prefill latency and decode throughput; the examples
use it with reduced configs.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import get_config, reduced
from ..models import build
from ..parallel.axes import ShardingRules, param_sharding, use_rules
from .mesh import make_test_mesh


def serve_loop(
    cfg,
    *,
    batch: int = 4,
    prompt_len: int = 64,
    gen_tokens: int = 32,
    strategy: str = "dos",
    mesh_shape=(1, 1),
    seed: int = 0,
    greedy: bool = True,
):
    mesh = make_test_mesh(*mesh_shape)
    rules = ShardingRules(mesh, strategy=strategy, fsdp=False)
    model = build(cfg)
    max_len = prompt_len + gen_tokens

    with use_rules(rules), mesh:
        ps = param_sharding(model.defs, rules)
        params = jax.device_put(model.init(jax.random.PRNGKey(seed)), ps)

        rng = jax.random.PRNGKey(seed + 1)
        prompts = jax.random.randint(rng, (batch, prompt_len), 0, cfg.vocab)
        pf_batch = {"tokens": prompts}
        if cfg.family == "vlm":
            pf_batch["image_embeds"] = jax.random.normal(
                rng, (batch, cfg.n_image_tokens, cfg.d_model),
                dtype=jnp.dtype(cfg.compute_dtype),
            )
        if cfg.family == "encdec":
            pf_batch["enc_frames"] = jax.random.normal(
                rng, (batch, cfg.enc_seq, cfg.d_model),
                dtype=jnp.dtype(cfg.compute_dtype),
            )

        prefill = jax.jit(lambda p, b: model.prefill(p, b, max_len=max_len))
        decode = jax.jit(model.decode)

        t0 = time.time()
        logits, cache = prefill(params, pf_batch)
        logits.block_until_ready()
        t_prefill = time.time() - t0

        tok = jnp.argmax(logits[:, -1:], axis=-1)
        out_tokens = [tok]
        t0 = time.time()
        for _ in range(gen_tokens - 1):
            logits, cache = decode(params, cache, {"token": tok})
            tok = jnp.argmax(logits, axis=-1)
            out_tokens.append(tok)
        tok.block_until_ready()
        t_decode = time.time() - t0

    gen = jnp.concatenate(out_tokens, axis=1)
    return {
        "generated": gen,
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        "decode_tok_s": batch * (gen_tokens - 1) / max(t_decode, 1e-9),
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen-tokens", type=int, default=32)
    ap.add_argument("--strategy", default="dos")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    r = serve_loop(
        cfg, batch=args.batch, prompt_len=args.prompt_len,
        gen_tokens=args.gen_tokens, strategy=args.strategy,
    )
    print(
        f"prefill {r['prefill_s']*1e3:.1f}ms; decode {r['decode_tok_s']:.1f} tok/s; "
        f"sample: {r['generated'][0, :16].tolist()}"
    )


if __name__ == "__main__":
    main()
