"""Step factories: train_step / prefill_step / serve_step.

These are the functions the launcher jits and the dry-run lowers. They
close over the model and config; all distribution enters through the
sharding rules context + the in/out shardings from ``parallel.plan``.

``microbatches > 1`` turns the train step into gradient accumulation:
the global batch is split along its leading dim and scanned, grads
accumulate in f32 at the parameter sharding (ZeRO layout), and one
optimizer update runs at the end. This is the standard memory lever for
the biggest cells (activation transients shrink by the microbatch
factor) and is also where DP comm can overlap the last microbatch's
compute on real hardware.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..optim import OptConfig, adamw_update

__all__ = ["make_train_step", "make_prefill_step", "make_serve_step"]


def make_train_step(model, opt_cfg: OptConfig, *, remat: bool = True,
                    microbatches: int = 1, unroll_mb: bool = False):
    def loss_fn(p, batch):
        return model.loss(p, batch, remat=remat)

    if microbatches == 1:
        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            new_params, new_opt, _ = adamw_update(params, grads, opt_state, opt_cfg)
            return new_params, new_opt, loss

        return train_step

    def train_step(params, opt_state, batch):
        mb = microbatches

        def split(x):
            b = x.shape[0]
            assert b % mb == 0, (b, mb)
            return x.reshape(mb, b // mb, *x.shape[1:])

        batches = jax.tree.map(split, batch)

        def accum(carry, mb_batch):
            gsum, lsum = carry
            loss, grads = jax.value_and_grad(loss_fn)(params, mb_batch)
            gsum = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), gsum, grads
            )
            return (gsum, lsum + loss), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (gsum, lsum), _ = jax.lax.scan(
            accum, (g0, jnp.float32(0)), batches, unroll=unroll_mb
        )
        grads = jax.tree.map(lambda g: g / mb, gsum)
        loss = lsum / mb
        new_params, new_opt, _ = adamw_update(params, grads, opt_state, opt_cfg)
        return new_params, new_opt, loss

    return train_step


def make_prefill_step(model, *, max_len: int = 0):
    def prefill_step(params, batch):
        return model.prefill(params, batch, max_len=max_len)

    return prefill_step


def make_serve_step(model):
    def serve_step(params, cache, batch):
        return model.decode(params, cache, batch)

    return serve_step
