"""Training launcher: real devices, fault-tolerant loop, checkpointing.

On this CPU container it drives reduced configs end-to-end (the
examples use it); on a TPU pod the same code path runs the production
mesh — the mesh/sharding logic is identical to the dry-run's.

Features exercised here (the large-scale story in miniature):
  - sharded params/opt via the same ShardingRules as the dry-run
  - async checkpointing every --ckpt-every steps + restart-on-failure
  - elastic restore (checkpoints are mesh-independent full arrays)
  - straggler watchdog, per-step metrics
  - optional int8+error-feedback gradient compression (--compress)
  - deterministic restart-safe data pipeline
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import checkpointer
from ..config import SHAPES, ShapeConfig
from ..configs import get_config, reduced
from ..data import DataConfig, SyntheticLM
from ..models import build
from ..optim import OptConfig, init_opt_state
from ..parallel.axes import ShardingRules, param_sharding, use_rules
from ..parallel.plan import batch_sharding
from ..runtime import FaultInjector, StragglerWatchdog, run_with_restarts
from .mesh import make_test_mesh
from .steps import make_train_step


def train_loop(
    cfg,
    *,
    steps: int = 100,
    global_batch: int = 8,
    seq_len: int = 128,
    strategy: str = "dos",
    mesh_shape=(1, 1),
    ckpt_dir: str | None = None,
    ckpt_every: int = 20,
    microbatches: int = 1,
    fault_injector: FaultInjector | None = None,
    log_every: int = 10,
    opt_cfg: OptConfig | None = None,
    seed: int = 0,
):
    mesh = make_test_mesh(*mesh_shape)
    rules = ShardingRules(mesh, strategy=strategy, fsdp=True)
    model = build(cfg)
    opt_cfg = opt_cfg or OptConfig(lr=1e-3, warmup_steps=20, total_steps=steps)
    data = SyntheticLM(DataConfig(cfg.vocab, seq_len, global_batch, seed=seed))
    step_fn = make_train_step(model, opt_cfg, remat=True, microbatches=microbatches)

    ps = param_sharding(model.defs, rules)
    oss = {"m": ps, "v": ps, "step": jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())}
    bspec = {
        "tokens": jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32),
        "labels": jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32),
    }
    bs = batch_sharding(rules, bspec)

    jit_step = jax.jit(
        step_fn, in_shardings=(ps, oss, bs), out_shardings=(ps, oss, None),
        donate_argnums=(0, 1),
    )

    losses = []
    watchdog = StragglerWatchdog()

    def make_state(resume_step):
        with use_rules(rules), mesh:
            params = jax.device_put(model.init(jax.random.PRNGKey(seed)), ps)
            opt = jax.device_put(init_opt_state(params), oss)
        if resume_step is not None and ckpt_dir:
            like = {"params": params, "opt": opt}
            host = checkpointer.restore(ckpt_dir, resume_step, like)
            params = jax.device_put(host["params"], ps)
            opt = jax.device_put(host["opt"], oss)
        return {"params": params, "opt": opt}

    def run(state, start_step):
        params, opt = state["params"], state["opt"]
        with use_rules(rules), mesh:
            for step in range(start_step, steps):
                if fault_injector is not None:
                    fault_injector.maybe_fail(step)
                watchdog.start_step()
                batch = jax.tree.map(jnp.asarray, data.batch(step))
                params, opt, loss = jit_step(params, opt, batch)
                watchdog.end_step(step)
                losses.append(float(loss))
                if step % log_every == 0:
                    print(f"step {step:5d} loss {float(loss):.4f}")
                if ckpt_dir and step > 0 and step % ckpt_every == 0:
                    checkpointer.save_async(
                        ckpt_dir, step, {"params": params, "opt": opt}
                    )
        if ckpt_dir:
            checkpointer.save(ckpt_dir, steps, {"params": params, "opt": opt})
        return {"params": params, "opt": opt}

    if ckpt_dir:
        state = run_with_restarts(make_state, run, ckpt_dir=ckpt_dir)
        checkpointer.wait_for_saves()
    else:
        state = run(make_state(None), 0)
    return state, losses, watchdog


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--strategy", default="dos")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    t0 = time.time()
    _, losses, wd = train_loop(
        cfg, steps=args.steps, global_batch=args.batch, seq_len=args.seq,
        strategy=args.strategy, ckpt_dir=args.ckpt_dir,
        microbatches=args.microbatches,
        opt_cfg=OptConfig(lr=args.lr, warmup_steps=20, total_steps=args.steps),
    )
    dt = time.time() - t0
    toks = args.steps * args.batch * args.seq
    print(
        f"done: {args.steps} steps in {dt:.1f}s ({toks/dt:.0f} tok/s); "
        f"loss {losses[0]:.3f} -> {losses[-1]:.3f}; "
        f"slow steps: {len(wd.slow_steps)}"
    )


if __name__ == "__main__":
    main()
