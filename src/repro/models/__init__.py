"""Model zoo: pure-functional JAX implementations of the assigned archs."""

from .zoo import Model, build

__all__ = ["Model", "build"]
