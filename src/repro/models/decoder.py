"""Unified decoder LM covering the assigned architecture families.

One parameterized decoder serves: dense (llama/smollm/qwen), local:global
patterns (gemma3), MoE FFNs (deepseek-moe, llama4-scout), vision
cross-attention interleave (llama-3.2-vision), Mamba2+shared-attention
hybrid (zamba2) and xLSTM stacks (mLSTM/sLSTM).

Layer stacking: homogeneous runs of layers are stacked and executed
with ``lax.scan`` (compile time O(1) in depth — required for
qwen2-72b's 80 layers); per-layer attention metadata (sliding window,
rope theta) rides along as scanned arrays so heterogeneous attention
patterns (gemma3's 5:1) still scan. Heterogeneous *structures* (vision
cross-attn every 5th, zamba2's shared block every 6th) use grouped
scans.

Modes: ``train`` (full seq, loss-ready logits), ``prefill`` (returns KV
caches / SSM states), ``decode`` (one token; caches advance).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..config import ArchConfig
from .layers import (
    attention, attn_defs, compute_cross_kv, embed_defs, embed_tokens,
    mlp, mlp_defs, rmsnorm, rmsnorm_def, unembed,
)
from .moe import moe_block, moe_defs
from .params import ParamDef, stack_defs
from .ssm import mamba_block, mamba_defs, mamba_init_state
from .xlstm import (
    mlstm_block, mlstm_defs, mlstm_init_state,
    slstm_block, slstm_defs, slstm_init_state,
)

__all__ = ["decoder_defs", "decoder_forward", "init_cache", "layer_metadata"]

_GLOBAL_WINDOW = 2**30  # "window" larger than any sequence = global attn


# --------------------------------------------------------------------------
# Parameter trees
# --------------------------------------------------------------------------


def _block_defs(cfg: ArchConfig):
    d = {
        "ln1": rmsnorm_def(cfg.d_model),
        "attn": attn_defs(cfg),
        "ln2": rmsnorm_def(cfg.d_model),
        "ffn": moe_defs(cfg) if cfg.family == "moe" else mlp_defs(cfg),
    }
    return d


def decoder_defs(cfg: ArchConfig):
    defs = {
        "embed": embed_defs(cfg),
        "final_norm": rmsnorm_def(cfg.d_model),
    }
    fam = cfg.family
    if fam in ("dense", "moe"):
        defs["layers"] = stack_defs(_block_defs(cfg), cfg.n_layers)
    elif fam == "vlm":
        period = cfg.cross_every  # every Nth layer is a cross layer
        n_groups = cfg.n_layers // period
        n_self = period - 1
        self_defs = stack_defs(stack_defs(_block_defs(cfg), n_self), n_groups)
        cross = {
            "ln1": rmsnorm_def(cfg.d_model),
            "attn": attn_defs(cfg),
            "gate": ParamDef((1,), ("one",), init="zeros"),
            "ln2": rmsnorm_def(cfg.d_model),
            "ffn": mlp_defs(cfg),
        }
        defs["layers"] = self_defs
        defs["cross_layers"] = stack_defs(cross, n_groups)
    elif fam == "hybrid":
        period = cfg.attn_every
        n_groups = cfg.n_layers // period
        defs["layers"] = stack_defs(stack_defs(mamba_defs(cfg), period), n_groups)
        defs["shared_attn"] = {  # ONE set of weights, applied every period
            "ln1": rmsnorm_def(cfg.d_model),
            "attn": attn_defs(cfg),
            "ln2": rmsnorm_def(cfg.d_model),
            "ffn": mlp_defs(cfg),
        }
    elif fam == "ssm":  # xLSTM
        blocks = []
        for i in range(cfg.n_layers):
            kind = "slstm" if i in cfg.slstm_at else "mlstm"
            sub = slstm_defs(cfg) if kind == "slstm" else mlstm_defs(cfg)
            blocks.append({"kind_" + kind: sub, "ln": rmsnorm_def(cfg.d_model)})
        defs["blocks"] = blocks
    else:
        raise ValueError(f"decoder does not handle family {fam}")
    return defs


def layer_metadata(cfg: ArchConfig, n: int | None = None):
    """Per-layer (window, theta) arrays for scanned attention layers."""
    n = n or cfg.n_layers
    wins, thetas = [], []
    for i in range(n):
        is_global = cfg.global_every and ((i + 1) % cfg.global_every == 0)
        if cfg.sliding_window and not is_global:
            wins.append(cfg.sliding_window)
        else:
            wins.append(_GLOBAL_WINDOW)
        if is_global and cfg.global_rope_theta:
            thetas.append(cfg.global_rope_theta)
        else:
            thetas.append(cfg.rope_theta)
    return jnp.asarray(wins, jnp.int32), jnp.asarray(thetas, jnp.float32)


# --------------------------------------------------------------------------
# KV / state cache construction
# --------------------------------------------------------------------------


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Decode-ready cache pytree for the whole model."""
    kvh, hd = cfg.n_kv_heads, cfg.head_dim_

    def kv(b=batch, s=max_len):
        return {
            "k": jnp.zeros((b, s, kvh, hd), dtype),
            "v": jnp.zeros((b, s, kvh, hd), dtype),
            "length": jnp.int32(0),
        }

    fam = cfg.family
    if fam in ("dense", "moe"):
        return {
            "layers": jax.tree.map(
                lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape),
                kv(),
            )
        }
    if fam == "vlm":
        period = cfg.cross_every
        n_groups = cfg.n_layers // period
        n_self = period - 1
        self_kv = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_groups, n_self) + x.shape), kv()
        )
        cross = {
            "k": jnp.zeros((n_groups, batch, cfg.n_image_tokens, kvh, hd), dtype),
            "v": jnp.zeros((n_groups, batch, cfg.n_image_tokens, kvh, hd), dtype),
        }
        return {"layers": self_kv, "cross": cross}
    if fam == "hybrid":
        period = cfg.attn_every
        n_groups = cfg.n_layers // period
        m = mamba_init_state(cfg, batch, dtype)
        mamba_stack = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_groups, period) + x.shape), m
        )
        attn_stack = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_groups,) + x.shape), kv()
        )
        return {"mamba": mamba_stack, "attn": attn_stack}
    if fam == "ssm":
        states = []
        for i in range(cfg.n_layers):
            if i in cfg.slstm_at:
                states.append(slstm_init_state(cfg, batch))
            else:
                states.append(mlstm_init_state(cfg, batch))
        return {"blocks": states}
    raise ValueError(fam)


# --------------------------------------------------------------------------
# Forward
# --------------------------------------------------------------------------


def _attn_mlp_block(lp, x, cfg, *, mode, cache, window, theta, cross_kv=None):
    h, new_cache = attention(
        lp["attn"],
        rmsnorm(x, lp["ln1"], cfg.norm_eps),
        cfg,
        mode=mode,
        cache=cache,
        window=window,
        theta=theta,
        cross_kv=cross_kv,
    )
    if "gate" in lp:  # gated cross-attn (llama-3.2-vision)
        h = jnp.tanh(lp["gate"].astype(jnp.float32)).astype(h.dtype) * h
    x = x + h
    y = rmsnorm(x, lp["ln2"], cfg.norm_eps)
    if "router" in lp["ffn"]:
        y = moe_block(lp["ffn"], y, cfg)
    else:
        y = mlp(lp["ffn"], y, cfg.act)
    return x + y, new_cache


def _scan_blocks(stacked_params, x, cfg, *, mode, caches, metas, remat=False):
    """lax.scan over a homogeneous stack of attn+ffn blocks.
    ``cfg.scan_layers=False`` fully unrolls (used by the dry-run's cost
    variants so cost_analysis counts every layer)."""
    win_arr, theta_arr = metas

    def body(carry, xs):
        lp, w, th, cache_l = xs
        y, new_cache = _attn_mlp_block(
            lp, carry, cfg, mode=mode, cache=cache_l, window=w, theta=th
        )
        return y, new_cache

    if remat:
        policy = (
            jax.checkpoint_policies.save_only_these_names("gathered_w")
            if remat == "save_gathered" else None
        )
        body = jax.checkpoint(body, policy=policy)
    x, new_caches = jax.lax.scan(
        body, x, (stacked_params, win_arr, theta_arr, caches),
        unroll=not cfg.scan_layers,
    )
    return x, new_caches


def decoder_forward(
    params,
    tokens,  # (B, S) int32
    cfg: ArchConfig,
    *,
    mode: str,
    cache=None,
    image_embeds=None,  # (B, n_img, E) for vlm
    max_len: int = 0,  # decode capacity for prefill-produced caches
    remat: bool = False,
):
    """Returns (logits, new_cache)."""
    compute_dtype = jnp.dtype(cfg.compute_dtype)
    x = embed_tokens(params["embed"], tokens, compute_dtype)
    b, s, e = x.shape
    fam = cfg.family
    new_cache = None

    if fam in ("dense", "moe"):
        metas = layer_metadata(cfg)
        caches = cache["layers"] if cache is not None else None
        if caches is None and mode != "train":
            caches = None
        x, ncache = _scan_blocks(
            params["layers"], x, cfg, mode=mode, caches=caches, metas=metas,
            remat=(remat if mode == "train" else False),
        )
        if mode != "train":
            new_cache = {"layers": ncache}

    elif fam == "vlm":
        period = cfg.cross_every
        n_groups = cfg.n_layers // period
        n_self = period - 1
        win_all, theta_all = layer_metadata(cfg, n_groups * n_self)
        win_g = win_all.reshape(n_groups, n_self)
        theta_g = theta_all.reshape(n_groups, n_self)
        self_caches = cache["layers"] if cache is not None else None
        cross_cache = cache["cross"] if cache is not None else None
        new_self, new_cross = [], []
        for g in range(n_groups):
            sp = jax.tree.map(lambda a: a[g], params["layers"])
            cp = jax.tree.map(lambda a: a[g], params["cross_layers"])
            cg = (
                jax.tree.map(lambda a: a[g], self_caches)
                if self_caches is not None
                else None
            )
            x, nc = _scan_blocks(
                sp, x, cfg, mode=mode, caches=cg, metas=(win_g[g], theta_g[g]),
                remat=(remat if mode == "train" else False),
            )
            if mode == "decode":
                ckv = (cross_cache["k"][g], cross_cache["v"][g])
            else:
                ckv = compute_cross_kv(cp["attn"], image_embeds, cfg)
            x, _ = _attn_mlp_block(
                cp, x, cfg, mode=mode, cache=None, window=None, theta=None,
                cross_kv=ckv,
            )
            if mode != "train":
                new_self.append(nc)
                new_cross.append(ckv)
        if mode != "train":
            new_cache = {
                "layers": jax.tree.map(lambda *xs: jnp.stack(xs), *new_self),
                "cross": {
                    "k": jnp.stack([kv[0] for kv in new_cross]),
                    "v": jnp.stack([kv[1] for kv in new_cross]),
                },
            }

    elif fam == "hybrid":
        period = cfg.attn_every
        n_groups = cfg.n_layers // period
        mamba_caches = cache["mamba"] if cache is not None else None
        attn_caches = cache["attn"] if cache is not None else None
        shared = params["shared_attn"]
        new_mamba, new_attn = [], []
        for g in range(n_groups):
            gp = jax.tree.map(lambda a: a[g], params["layers"])
            gc = (
                jax.tree.map(lambda a: a[g], mamba_caches)
                if mamba_caches is not None
                else None
            )

            def mbody(carry, xs):
                lp, st = xs
                y, new_st = mamba_block(lp, carry, cfg, mode=mode, state=st)
                return carry + y, new_st

            if gc is None:
                gc_in = jax.tree.map(
                    lambda x_: jnp.broadcast_to(x_, (period,) + x_.shape),
                    mamba_init_state(cfg, b, compute_dtype),
                )
            else:
                gc_in = gc
            mb = jax.checkpoint(mbody) if (remat and mode == "train") else mbody
            x, nst = jax.lax.scan(mb, x, (gp, gc_in), unroll=not cfg.scan_layers)
            ac = (
                jax.tree.map(lambda a: a[g], attn_caches)
                if attn_caches is not None
                else None
            )
            x, nac = _attn_mlp_block(
                shared, x, cfg, mode=mode, cache=ac,
                window=None, theta=cfg.rope_theta,
            )
            if mode != "train":
                new_mamba.append(nst)
                new_attn.append(nac)
        if mode != "train":
            new_cache = {
                "mamba": jax.tree.map(lambda *xs: jnp.stack(xs), *new_mamba),
                "attn": jax.tree.map(lambda *xs: jnp.stack(xs), *new_attn),
            }

    elif fam == "ssm":
        states = cache["blocks"] if cache is not None else [None] * cfg.n_layers
        new_states = []
        for i, bp in enumerate(params["blocks"]):
            block = slstm_block if i in cfg.slstm_at else mlstm_block
            sub = bp["kind_slstm"] if i in cfg.slstm_at else bp["kind_mlstm"]
            y, nst = block(sub, rmsnorm(x, bp["ln"], cfg.norm_eps), cfg,
                           mode=mode, state=states[i])
            x = x + y
            new_states.append(nst)
        if mode != "train":
            new_cache = {"blocks": new_states}

    else:
        raise ValueError(fam)

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(params["embed"], x, cfg.tie_embeddings)

    if mode == "prefill" and max_len and new_cache is not None:
        new_cache = _pad_cache_tree(new_cache, max_len)
    return logits, new_cache


def _pad_cache_tree(cache, max_len):
    """Pad every kv buffer (dim -3 = seq) up to max_len."""

    def rec(node):
        if isinstance(node, dict) and "k" in node and "length" in node:
            s = node["k"].shape[-3]
            if s >= max_len:
                return node
            padw = [(0, 0)] * node["k"].ndim
            padw[-3] = (0, max_len - s)
            return {
                "k": jnp.pad(node["k"], padw),
                "v": jnp.pad(node["v"], padw),
                "length": node["length"],
            }
        if isinstance(node, dict):
            return {k: rec(v) for k, v in node.items()}
        if isinstance(node, list):
            return [rec(v) for v in node]
        return node

    return rec(cache)
