"""Whisper-style encoder-decoder backbone.

Per the assignment, the conv/mel frontend is a STUB: ``input_specs``
supplies precomputed frame embeddings (B, enc_seq, E). The encoder is a
bidirectional transformer over frames; the decoder is causal self-attn
+ cross-attn to the encoder output. Positions are sinusoidal (keeps
parameter shapes independent of the benchmark sequence lengths).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..config import ArchConfig
from .layers import (
    attention, attn_defs, compute_cross_kv, embed_defs, embed_tokens,
    mlp, mlp_defs, rmsnorm, rmsnorm_def, unembed,
)
from .params import stack_defs

__all__ = ["encdec_defs", "encode", "encdec_forward", "encdec_init_cache"]


def _sinusoid(seq, dim, offset=0):
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None] + offset
    half = dim // 2
    freq = jnp.exp(-jnp.arange(half, dtype=jnp.float32) * (jnp.log(10_000.0) / max(half - 1, 1)))
    ang = pos * freq[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _enc_block_defs(cfg):
    return {
        "ln1": rmsnorm_def(cfg.d_model),
        "attn": attn_defs(cfg),
        "ln2": rmsnorm_def(cfg.d_model),
        "ffn": mlp_defs(cfg, act="gelu"),
    }


def _dec_block_defs(cfg):
    return {
        "ln1": rmsnorm_def(cfg.d_model),
        "attn": attn_defs(cfg),
        "lnx": rmsnorm_def(cfg.d_model),
        "xattn": attn_defs(cfg),
        "ln2": rmsnorm_def(cfg.d_model),
        "ffn": mlp_defs(cfg, act="gelu"),
    }


def encdec_defs(cfg: ArchConfig):
    return {
        "embed": embed_defs(cfg),
        "enc_layers": stack_defs(_enc_block_defs(cfg), cfg.n_enc_layers),
        "enc_norm": rmsnorm_def(cfg.d_model),
        "dec_layers": stack_defs(_dec_block_defs(cfg), cfg.n_layers),
        "final_norm": rmsnorm_def(cfg.d_model),
    }


def encode(params, frames, cfg: ArchConfig):
    """frames: (B, enc_seq, E) precomputed stub embeddings."""
    x = frames.astype(jnp.dtype(cfg.compute_dtype))
    x = x + _sinusoid(x.shape[1], x.shape[2]).astype(x.dtype)[None]

    def body(carry, lp):
        h, _ = attention(
            lp["attn"], rmsnorm(carry, lp["ln1"], cfg.norm_eps), cfg,
            mode="train", causal=False, theta=None,
        )
        y = carry + h
        y = y + mlp(lp["ffn"], rmsnorm(y, lp["ln2"], cfg.norm_eps), "gelu")
        return y, None

    x, _ = jax.lax.scan(body, x, params["enc_layers"], unroll=not cfg.scan_layers)
    return rmsnorm(x, params["enc_norm"], cfg.norm_eps)


def encdec_init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    kvh, hd = cfg.n_kv_heads, cfg.head_dim_
    L = cfg.n_layers
    kv = {
        "k": jnp.zeros((L, batch, max_len, kvh, hd), dtype),
        "v": jnp.zeros((L, batch, max_len, kvh, hd), dtype),
        "length": jnp.zeros((L,), jnp.int32),
    }
    cross = {
        "k": jnp.zeros((L, batch, cfg.enc_seq, kvh, hd), dtype),
        "v": jnp.zeros((L, batch, cfg.enc_seq, kvh, hd), dtype),
    }
    return {"self": kv, "cross": cross}


def encdec_forward(
    params,
    tokens,  # (B, S) decoder tokens
    cfg: ArchConfig,
    *,
    mode: str,
    enc_frames=None,  # (B, enc_seq, E); required for train/prefill
    cache=None,
    max_len: int = 0,
    remat: bool = False,
):
    compute_dtype = jnp.dtype(cfg.compute_dtype)
    x = embed_tokens(params["embed"], tokens, compute_dtype)
    b, s, e = x.shape

    if mode == "decode":
        offset = cache["self"]["length"][0]  # same for all layers
    else:
        offset = 0
        enc_out = encode(params, enc_frames, cfg)
    x = x + _sinusoid(s, e, offset=offset).astype(x.dtype)[None]

    self_caches = cache["self"] if cache is not None else None
    cross_caches = cache["cross"] if cache is not None else None

    def body(carry, xs):
        lp, sc, cc = xs
        h, new_sc = attention(
            lp["attn"], rmsnorm(carry, lp["ln1"], cfg.norm_eps), cfg,
            mode=mode, cache=sc, theta=None,
        )
        y = carry + h
        if mode == "decode":
            ckv = (cc["k"], cc["v"])
        else:
            ckv = compute_cross_kv(lp["xattn"], enc_out, cfg)
        hx, _ = attention(
            lp["xattn"], rmsnorm(y, lp["lnx"], cfg.norm_eps), cfg,
            mode=mode, cross_kv=ckv,
        )
        y = y + hx
        y = y + mlp(lp["ffn"], rmsnorm(y, lp["ln2"], cfg.norm_eps), "gelu")
        new_cc = None if mode == "train" else {"k": ckv[0], "v": ckv[1]}
        return y, (new_sc, new_cc)

    if remat and mode == "train":
        body = jax.checkpoint(body)
    x, (new_self, new_cross) = jax.lax.scan(
        body, x, (params["dec_layers"], self_caches, cross_caches),
        unroll=not cfg.scan_layers,
    )

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(params["embed"], x, cfg.tie_embeddings)

    new_cache = None
    if mode != "train":
        new_cache = {"self": new_self, "cross": new_cross}
        if mode == "prefill" and max_len:
            padw = max_len - new_cache["self"]["k"].shape[-3]
            if padw > 0:
                pw = [(0, 0)] * new_cache["self"]["k"].ndim
                pw[-3] = (0, padw)
                new_cache["self"] = {
                    "k": jnp.pad(new_cache["self"]["k"], pw),
                    "v": jnp.pad(new_cache["self"]["v"], pw),
                    "length": new_cache["self"]["length"],
                }
    return logits, new_cache
