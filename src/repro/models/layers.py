"""Shared transformer layers: norms, RoPE, attention, MLP, embeddings.

Pure-functional: every block is ``(params, x, ...) -> y`` with params
described by ParamDef trees. All GEMMs route through ``proj`` which
dispatches to the dOS Pallas kernel on TPU and plain jnp elsewhere.
Activations carry logical sharding constraints (``parallel.axes.shard``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from ..kernels.dos_matmul import dos_matmul
from ..kernels.flash_attention import decode_attention, flash_attention
from ..parallel.axes import shard
from .params import ParamDef

__all__ = [
    "proj", "rmsnorm", "rmsnorm_def", "rope", "embed_defs", "embed_tokens",
    "unembed", "attn_defs", "attention", "mlp_defs", "mlp",
]


def proj(x, w, b=None):
    """x (..., K) @ w (K, N) in compute dtype, f32 accumulation.

    The cast weight is checkpoint-named so the `save_gathered` remat
    policy can keep FSDP/ZeRO all-gather results across the backward
    pass instead of re-gathering (§Perf A3)."""
    w_c = checkpoint_name(w.astype(x.dtype), "gathered_w")
    y = dos_matmul(x, w_c, out_dtype=x.dtype)
    if b is not None:
        y = y + b.astype(x.dtype)
    return y


# --- norms ---------------------------------------------------------------


def rmsnorm_def(dim: int, axes=("embed",)):
    return ParamDef((dim,), axes, init="ones" if len(axes) else "ones")


def rmsnorm(x, scale, eps: float = 1e-6, plus_one: bool = False):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    s = scale.astype(jnp.float32)
    if plus_one:
        s = 1.0 + s
    return (y * s).astype(x.dtype)


# --- rotary embeddings -----------------------------------------------------


def rope(x, positions, theta):
    """x: (..., S, H, D); positions: (S,) or scalar; theta may be traced
    (per-layer theta arrays inside scanned layers)."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(
        -jnp.log(jnp.asarray(theta, jnp.float32))
        * (jnp.arange(half, dtype=jnp.float32) / half)
    )
    ang = jnp.asarray(positions, jnp.float32)[..., None] * freqs  # (S, half)
    cos = jnp.cos(ang)[..., None, :]  # (S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --- embeddings -------------------------------------------------------------


def embed_defs(cfg):
    defs = {"tok": ParamDef((cfg.vocab, cfg.d_model), ("vocab", "embed"), scale=0.02)}
    if not cfg.tie_embeddings:
        defs["head"] = ParamDef(
            (cfg.d_model, cfg.vocab), ("embed", "vocab"), contract=0, out=1
        )
    return defs


def embed_tokens(p, tokens, compute_dtype):
    x = jnp.take(p["tok"], tokens, axis=0).astype(compute_dtype)
    return shard(x, "residual")


def unembed(p, x, tie: bool):
    w = p["tok"].T if tie else p["head"]
    logits = proj(x.astype(jnp.bfloat16) if x.dtype == jnp.bfloat16 else x, w)
    return shard(logits.astype(jnp.float32), "logits")


# --- attention ---------------------------------------------------------------


def attn_defs(cfg, cross: bool = False):
    e, h, kvh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    defs = {
        "wq": ParamDef((e, h * hd), ("embed", "heads_flat"), contract=0, out=1),
        "wk": ParamDef((e, kvh * hd), ("embed", "heads_flat"), contract=0, out=1),
        "wv": ParamDef((e, kvh * hd), ("embed", "heads_flat"), contract=0, out=1),
        "wo": ParamDef((h * hd, e), ("heads_flat", "embed"), contract=0, out=1),
    }
    if cfg.qkv_bias:
        defs["bq"] = ParamDef((h * hd,), ("heads_flat",), init="zeros")
        defs["bk"] = ParamDef((kvh * hd,), ("heads_flat",), init="zeros")
        defs["bv"] = ParamDef((kvh * hd,), ("heads_flat",), init="zeros")
    if cfg.qk_norm:
        defs["q_norm"] = rmsnorm_def(hd, ("head_dim",))
        defs["k_norm"] = rmsnorm_def(hd, ("head_dim",))
    return defs


def compute_cross_kv(p, kv_src, cfg):
    """Project a cross-attention source (image embeds / encoder output)
    to (k, v) once — cached at prefill, reused every decode step."""
    b, skv, _ = kv_src.shape
    kvh, hd = cfg.n_kv_heads, cfg.head_dim_
    k = proj(kv_src, p["wk"], p.get("bk")).reshape(b, skv, kvh, hd)
    v = proj(kv_src, p["wv"], p.get("bv")).reshape(b, skv, kvh, hd)
    return shard(k, "kv_cache"), shard(v, "kv_cache")


def attention(
    p,
    x,
    cfg,
    *,
    mode: str,  # train | prefill | decode
    positions=None,  # rope positions for x
    window=None,  # None/0 = global; traced scalar OK (jnp mask path)
    theta=None,  # rope theta (traced OK); None -> no rope (whisper sin)
    cache=None,  # dict(k, v, length) for decode / filled by prefill
    cross_kv=None,  # precomputed (k, v) -> cross-attention, no cache update
    causal: bool = True,
):
    """The universal attention block. Returns (y, new_cache)."""
    b, s, e = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_

    q = proj(x, p["wq"], p.get("bq")).reshape(b, s, h, hd)
    if cross_kv is None:
        k = proj(x, p["wk"], p.get("bk")).reshape(b, s, kvh, hd)
        v = proj(x, p["wv"], p.get("bv")).reshape(b, s, kvh, hd)
    else:
        k, v = cross_kv

    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        if cross_kv is None:
            k = rmsnorm(k, p["k_norm"], cfg.norm_eps)

    win = window  # may be a traced scalar; the jnp mask path handles it

    new_cache = None
    if cross_kv is not None:
        q = shard(q, "attn_heads")
        skv = k.shape[1]
        if mode == "decode":
            o = decode_attention(q, k, v, length=skv, window=None)
        else:
            o = flash_attention(
                q, k, v, causal=False, window=None, unroll=cfg.unroll_inner
            )
    elif mode == "decode":
        assert cache is not None and s == 1
        length = cache["length"]
        if theta is not None:
            q = rope(q, length, theta)
            k = rope(k, length, theta)
        q = shard(q, "attn_heads")
        kc = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, length, 0, 0)
        )
        vc = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, length, 0, 0)
        )
        kc = shard(kc, "kv_cache")
        vc = shard(vc, "kv_cache")
        o = decode_attention(q, kc, vc, length=length + 1, window=win)
        new_cache = {"k": kc, "v": vc, "length": length + 1}
    else:
        if theta is not None:
            if positions is None:
                positions = jnp.arange(s)
            q = rope(q, positions, theta)
            k = rope(k, positions, theta)
        q = shard(q, "attn_heads")
        k = shard(k, "kv_cache")
        v = shard(v, "kv_cache")
        o = flash_attention(
            q, k, v, causal=causal, window=win, unroll=cfg.unroll_inner
        )
        if mode == "prefill":
            new_cache = {"k": k, "v": v, "length": jnp.int32(s)}

    o = shard(o, "attn_heads")
    y = proj(o.reshape(b, s, h * hd), p["wo"])
    return shard(y, "residual"), new_cache


# --- MLP -----------------------------------------------------------------------


def mlp_defs(cfg, d_ff=None, act=None):
    e = cfg.d_model
    f = d_ff or cfg.d_ff
    act = act or cfg.act
    if act == "silu":  # gated (llama family)
        return {
            "wi_gate": ParamDef((e, f), ("embed", "mlp"), contract=0, out=1),
            "wi_up": ParamDef((e, f), ("embed", "mlp"), contract=0, out=1),
            "wo": ParamDef((f, e), ("mlp", "embed"), contract=0, out=1),
        }
    return {  # plain 2-layer (whisper)
        "wi": ParamDef((e, f), ("embed", "mlp"), contract=0, out=1),
        "bi": ParamDef((f,), ("mlp",), init="zeros"),
        "wo": ParamDef((f, e), ("mlp", "embed"), contract=0, out=1),
        "bo": ParamDef((e,), ("embed",), init="zeros"),
    }


def mlp(p, x, act: str = "silu"):
    if "wi_gate" in p:
        g = proj(x, p["wi_gate"])
        u = proj(x, p["wi_up"])
        hidden = shard(jax.nn.silu(g) * u, "mlp_hidden")
        y = proj(hidden, p["wo"])
    else:
        hidden = shard(jax.nn.gelu(proj(x, p["wi"], p["bi"])), "mlp_hidden")
        y = proj(hidden, p["wo"], p["bo"])
    return shard(y, "residual")
