"""Mixture-of-Experts FFN (deepseek-moe fine-grained; llama4-scout).

Routing: softmax router -> top-k experts per token -> tokens sorted by
expert id -> ``jax.lax.ragged_dot`` over expert groups (dense MXU
per-group GEMMs, no capacity-dropping) -> unsort, weight, combine.
Shared experts (deepseek's always-on experts) run as a plain gated MLP.

Sharding: expert FFN weights are TP-sharded under both strategies
(dOS: contraction dim; megatron: expert_ff dim). An expert-parallel
shard_map path with all_to_all dispatch lives in ``parallel.moe_ep``
(beyond-paper optimization).

Paper connection: each routed expert GEMM has K = expert_d_ff (tiny for
fine-grained MoE). The advisor (core.advisor) correctly scores dOS as
unattractive here — the paper's small-K finding (Fig. 5, green curves).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..parallel.axes import shard
from .layers import proj
from .params import ParamDef

__all__ = ["moe_defs", "moe_block"]


def moe_defs(cfg):
    e = cfg.d_model
    f = cfg.expert_d_ff
    ne = cfg.n_experts
    defs = {
        "router": ParamDef((e, ne), ("embed", "experts"), contract=0, out=1),
        "wi_gate": ParamDef((ne, e, f), ("experts", "embed", "expert_ff"), contract=1, out=2),
        "wi_up": ParamDef((ne, e, f), ("experts", "embed", "expert_ff"), contract=1, out=2),
        "wo": ParamDef((ne, f, e), ("experts", "expert_ff", "embed"), contract=1, out=2),
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        defs["shared"] = {
            "wi_gate": ParamDef((e, fs), ("embed", "mlp"), contract=0, out=1),
            "wi_up": ParamDef((e, fs), ("embed", "mlp"), contract=0, out=1),
            "wo": ParamDef((fs, e), ("mlp", "embed"), contract=0, out=1),
        }
    return defs


def moe_block(p, x, cfg):
    """x: (B, S, E) -> (B, S, E)."""
    b, s, e = x.shape
    t = b * s
    k = cfg.top_k
    ne = cfg.n_experts
    xt = x.reshape(t, e)

    # --- routing (f32 for numerics) ---------------------------------------
    logits = proj(xt.astype(jnp.float32), p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)  # (T, NE)
    topk_p, topk_i = jax.lax.top_k(probs, k)  # (T, K)
    topk_p = topk_p / jnp.sum(topk_p, axis=-1, keepdims=True)

    # --- sort-by-expert dispatch ------------------------------------------
    flat_expert = topk_i.reshape(-1)  # (T*K,)
    order = jnp.argsort(flat_expert)  # stable
    token_of = jnp.arange(t * k, dtype=jnp.int32) // k
    xs = xt[token_of[order]]  # (T*K, E) sorted by expert
    group_sizes = jnp.bincount(flat_expert, length=ne).astype(jnp.int32)

    # --- expert GEMMs (ragged over groups) ----------------------------------
    g = jax.lax.ragged_dot(xs, p["wi_gate"].astype(xs.dtype), group_sizes)
    u = jax.lax.ragged_dot(xs, p["wi_up"].astype(xs.dtype), group_sizes)
    h = jax.nn.silu(g) * u  # (T*K, F)
    h = shard(h, "mlp_hidden")
    y_sorted = jax.lax.ragged_dot(h, p["wo"].astype(h.dtype), group_sizes)

    # --- unsort & combine ------------------------------------------------------
    inv = jnp.argsort(order)
    y = y_sorted[inv]  # (T*K, E) in (token, k) order
    y = y.reshape(t, k, e) * topk_p[..., None].astype(y.dtype)
    y = jnp.sum(y, axis=1)  # (T, E)

    if "shared" in p:
        sp = p["shared"]
        sg = proj(xt, sp["wi_gate"])
        su = proj(xt, sp["wi_up"])
        y = y + proj(jax.nn.silu(sg) * su, sp["wo"])

    return shard(y.reshape(b, s, e).astype(x.dtype), "residual")


def aux_load_balance_loss(p, x, cfg):
    """Switch-style load-balance auxiliary loss (used by train_step)."""
    b, s, e = x.shape
    xt = x.reshape(b * s, e)
    logits = proj(xt.astype(jnp.float32), p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top1 = jnp.argmax(probs, axis=-1)
    frac_tokens = jnp.mean(
        jax.nn.one_hot(top1, cfg.n_experts, dtype=jnp.float32), axis=0
    )
    frac_probs = jnp.mean(probs, axis=0)
    return cfg.n_experts * jnp.sum(frac_tokens * frac_probs)
