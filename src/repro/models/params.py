"""Parameter definition trees.

A model is described by a pytree of ``ParamDef`` leaves (shape, logical
axes, GEMM contraction/output axis indices, initializer). From one tree
we derive: real parameters (``materialize``), ShapeDtypeStructs for the
dry-run (``abstract``), and NamedShardings (``parallel.axes``).
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

__all__ = ["ParamDef", "materialize", "abstract", "tree_size", "stack_defs"]


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple
    axes: tuple  # logical axis names, len == len(shape)
    contract: int | None = None  # GEMM contraction axis index (sharded under dOS)
    out: int | None = None  # GEMM output axis index (sharded under megatron-col)
    init: str = "normal"  # normal | zeros | ones
    scale: float | None = None  # None -> 1/sqrt(fan_in)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_def(x):
    return isinstance(x, ParamDef)


def materialize(defs, rng: jax.Array, dtype=jnp.float32):
    """Initialize real parameters for a ParamDef tree."""
    leaves, treedef = jax.tree.flatten(defs, is_leaf=_is_def)
    rngs = jax.random.split(rng, len(leaves))

    def one(d: ParamDef, key):
        if d.init == "zeros":
            return jnp.zeros(d.shape, dtype)
        if d.init == "ones":
            return jnp.ones(d.shape, dtype)
        if d.init == "neg_linspace":  # mamba A: -[1..H], broadcast over stacking
            h = d.shape[-1]
            v = -jnp.linspace(1.0, float(h), h).astype(dtype)
            return jnp.broadcast_to(v, d.shape)
        fan_in = d.shape[d.contract] if d.contract is not None else d.shape[0]
        scale = d.scale if d.scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, d.shape) * scale).astype(dtype)

    return jax.tree.unflatten(treedef, [one(d, k) for d, k in zip(leaves, rngs)])


def abstract(defs, dtype=jnp.float32):
    """ShapeDtypeStruct tree (no allocation) for .lower()."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype), defs, is_leaf=_is_def
    )


def tree_size(defs) -> int:
    """Total parameter count of a ParamDef tree."""
    return sum(
        math.prod(d.shape) for d in jax.tree.leaves(defs, is_leaf=_is_def)
    )


def stack_defs(defs, n: int, axis_name: str = "layers"):
    """Prepend a stacked-layers dimension to every leaf (for lax.scan)."""

    def one(d: ParamDef):
        return ParamDef(
            shape=(n,) + d.shape,
            axes=(axis_name,) + d.axes,
            contract=None if d.contract is None else d.contract + 1,
            out=None if d.out is None else d.out + 1,
            init=d.init,
            scale=d.scale,
        )

    return jax.tree.map(one, defs, is_leaf=_is_def)
