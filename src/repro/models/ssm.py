"""Mamba2 (SSD) block — the zamba2 backbone.

Structure (simplified from the Mamba2 paper; conv applies to the x
branch only, single B/C group):

  x -> in-projections: x_in, z (gate), B, C, dt
  x_in -> causal depthwise conv(width 4) -> silu
  y  = SSD-scan(u = dt*x_in, log-decay = dt*A_h, B, C) + D*x_in
  out = W_o (rmsnorm(y) * silu(z))

Train/prefill run the chunked kernel; decode advances the recurrence
one step carrying (ssm_state, conv_state).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels.ssm_scan import ssm_scan
from ..kernels.ssm_scan.ref import ssm_step_ref
from ..parallel.axes import shard
from .layers import proj, rmsnorm
from .params import ParamDef

__all__ = ["mamba_defs", "mamba_block", "mamba_init_state"]

_CONV_W = 4


def mamba_defs(cfg):
    e = cfg.d_model
    di = cfg.ssm_expand * e
    n = cfg.ssm_state
    h = di // cfg.ssm_head_dim
    return {
        "wx": ParamDef((e, di), ("embed", "ssm_inner"), contract=0, out=1),
        "wz": ParamDef((e, di), ("embed", "ssm_inner"), contract=0, out=1),
        "wB": ParamDef((e, n), ("embed", "state"), contract=0, out=1),
        "wC": ParamDef((e, n), ("embed", "state"), contract=0, out=1),
        "wdt": ParamDef((e, h), ("embed", "ssm_heads"), contract=0, out=1),
        "dt_bias": ParamDef((h,), ("ssm_heads",), init="zeros"),
        "A": ParamDef((h,), ("ssm_heads",), init="neg_linspace"),
        "D": ParamDef((h,), ("ssm_heads",), init="ones"),
        "conv_w": ParamDef((_CONV_W, di), ("conv", "ssm_inner"), init="normal", scale=0.5),
        "norm": ParamDef((di,), ("ssm_inner",), init="ones"),
        "wo": ParamDef((di, e), ("ssm_inner", "embed"), contract=0, out=1),
    }


def _causal_conv(x, w, state=None):
    """Depthwise causal conv, width 4. x: (B, S, Di); state: (B, 3, Di)
    carries the last 3 inputs for decode. Returns (y, new_state)."""
    b, s, di = x.shape
    pad = state if state is not None else jnp.zeros((b, _CONV_W - 1, di), x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (B, S+3, Di)
    y = sum(
        xp[:, i : i + s, :] * w[i][None, None, :].astype(x.dtype)
        for i in range(_CONV_W)
    )
    new_state = xp[:, -(_CONV_W - 1) :, :]
    return y, new_state


def mamba_init_state(cfg, batch, dtype=jnp.float32):
    di = cfg.ssm_expand * cfg.d_model
    h = di // cfg.ssm_head_dim
    return {
        "ssm": jnp.zeros((batch, h, cfg.ssm_state, cfg.ssm_head_dim), jnp.float32),
        "conv": jnp.zeros((batch, _CONV_W - 1, di), dtype),
    }


def mamba_block(p, x, cfg, *, mode: str, state=None):
    """Returns (y, new_state). state is required for decode; prefill
    returns the state for the decode loop."""
    b, s, e = x.shape
    di = cfg.ssm_expand * e
    hd = cfg.ssm_head_dim
    h = di // hd

    x_in = proj(x, p["wx"])  # (B, S, Di)
    z = proj(x, p["wz"])
    Bm = proj(x, p["wB"])  # (B, S, N)
    Cm = proj(x, p["wC"])
    dt = jax.nn.softplus(
        proj(x, p["wdt"]).astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
    )  # (B, S, H)

    conv_state = state["conv"] if state is not None else None
    x_c, new_conv = _causal_conv(x_in, p["conv_w"], conv_state)
    x_c = jax.nn.silu(x_c)
    xh = x_c.reshape(b, s, h, hd)
    xh = shard(xh, "attn_heads")

    A = p["A"].astype(jnp.float32)
    u = (dt[..., None] * xh.astype(jnp.float32)).astype(x.dtype)
    ld = dt * A[None, None, :]  # (B, S, H) log-decay
    Bh = jnp.broadcast_to(Bm[:, :, None, :], (b, s, h, cfg.ssm_state))
    Ch = jnp.broadcast_to(Cm[:, :, None, :], (b, s, h, cfg.ssm_state))

    if mode == "decode":
        assert state is not None and s == 1
        y1, new_ssm = ssm_step_ref(
            state["ssm"], u[:, 0], ld[:, 0], Bh[:, 0], Ch[:, 0]
        )
        y = y1[:, None]  # (B, 1, H, hd)
    else:
        y, new_ssm = ssm_scan(u, ld, Bh, Ch, unroll=cfg.unroll_inner)
    new_ssm = shard(new_ssm, "ssm_state")

    y = y + p["D"].astype(x.dtype)[None, None, :, None] * xh
    y = y.reshape(b, s, di)
    y = rmsnorm(y, p["norm"], cfg.norm_eps) * jax.nn.silu(z)
    out = proj(y.astype(x.dtype), p["wo"])
    new_state = {"ssm": new_ssm, "conv": new_conv}
    return shard(out, "residual"), new_state
