"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

mLSTM is expressed as two SSD scans (reusing the chunked kernel — the
same dOS "stationary state over sequential chunk-tiers" structure):

  C_t = f_t C_{t-1} + (i_t k_t) v_t^T       -> ssm_scan(u=v, ld=log f, B=i*k, C=q)
  n_t = f_t n_{t-1} + (i_t k_t)             -> ssm_scan(u=1, ...) with P=1
  y_t = (C_t^T q_t) / max(|n_t^T q_t|, 1)

The paper's technique (dOS / K-dim sharding) does NOT apply to the
recurrence itself — the memory update is an outer product (K = 1); it
applies only to the q/k/v/out projections. Recorded in DESIGN.md
§Arch-applicability.

sLSTM keeps per-head scalar state with a plain lax.scan (inherently
sequential, as the xLSTM paper states).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels.ssm_scan import ssm_scan
from ..kernels.ssm_scan.ref import ssm_step_ref
from ..parallel.axes import shard
from .layers import proj, rmsnorm
from .params import ParamDef

__all__ = [
    "mlstm_defs", "mlstm_block", "mlstm_init_state",
    "slstm_defs", "slstm_block", "slstm_init_state",
]


# --- mLSTM -------------------------------------------------------------------


def mlstm_defs(cfg):
    e = cfg.d_model
    h = cfg.n_heads
    n = cfg.ssm_state  # key/query dim per head
    p_ = cfg.ssm_head_dim  # value dim per head
    return {
        "wq": ParamDef((e, h * n), ("embed", "heads_flat"), contract=0, out=1),
        "wk": ParamDef((e, h * n), ("embed", "heads_flat"), contract=0, out=1),
        "wv": ParamDef((e, h * p_), ("embed", "heads_flat"), contract=0, out=1),
        "wi": ParamDef((e, h), ("embed", "ssm_heads"), contract=0, out=1),
        "wf": ParamDef((e, h), ("embed", "ssm_heads"), contract=0, out=1),
        "bf": ParamDef((h,), ("ssm_heads",), init="ones"),
        "wo_gate": ParamDef((e, h * p_), ("embed", "heads_flat"), contract=0, out=1),
        "norm": ParamDef((h * p_,), ("heads_flat",), init="ones"),
        "wo": ParamDef((h * p_, e), ("heads_flat", "embed"), contract=0, out=1),
    }


def mlstm_init_state(cfg, batch):
    h, n, p_ = cfg.n_heads, cfg.ssm_state, cfg.ssm_head_dim
    return {
        "C": jnp.zeros((batch, h, n, p_), jnp.float32),
        "n": jnp.zeros((batch, h, n, 1), jnp.float32),
    }


def mlstm_block(p, x, cfg, *, mode: str, state=None):
    b, s, e = x.shape
    h, n, p_ = cfg.n_heads, cfg.ssm_state, cfg.ssm_head_dim

    q = proj(x, p["wq"]).reshape(b, s, h, n)
    k = proj(x, p["wk"]).reshape(b, s, h, n) / (n**0.5)
    v = proj(x, p["wv"]).reshape(b, s, h, p_)
    q = shard(q, "attn_heads")
    i_pre = proj(x, p["wi"]).astype(jnp.float32)  # (B,S,H)
    f_pre = proj(x, p["wf"]).astype(jnp.float32) + p["bf"].astype(jnp.float32)

    # Stabilized exponential gating (xLSTM Sec. 2): fold the input gate
    # into B and keep log f as the decay.
    ld = jax.nn.log_sigmoid(f_pre)  # (B,S,H)
    i_gate = jnp.exp(jnp.minimum(i_pre, 10.0))  # clipped exp input gate
    Bk = (k.astype(jnp.float32) * i_gate[..., None]).astype(x.dtype)  # i_t * k_t

    ones = jnp.ones((b, s, h, 1), x.dtype)
    if mode == "decode":
        assert state is not None and s == 1
        yc, newC = ssm_step_ref(state["C"], v[:, 0], ld[:, 0], Bk[:, 0], q[:, 0])
        yn, newn = ssm_step_ref(state["n"], ones[:, 0], ld[:, 0], Bk[:, 0], q[:, 0])
        yc, yn = yc[:, None], yn[:, None]
        new_state = {"C": newC, "n": newn}
    else:
        yc, newC = ssm_scan(v, ld, Bk, q, unroll=cfg.unroll_inner)  # (B,S,H,P)
        yn, newn = ssm_scan(ones, ld, Bk, q, unroll=cfg.unroll_inner)  # (B,S,H,1)
        new_state = {"C": newC, "n": newn}

    denom = jnp.maximum(jnp.abs(yn.astype(jnp.float32)), 1.0)
    y = yc.astype(jnp.float32) / denom  # (B,S,H,P)
    y = y.reshape(b, s, h * p_)
    y = rmsnorm(y.astype(x.dtype), p["norm"], cfg.norm_eps)
    o_gate = jax.nn.sigmoid(proj(x, p["wo_gate"]).astype(jnp.float32))
    y = (y.astype(jnp.float32) * o_gate).astype(x.dtype)
    return shard(proj(y, p["wo"]), "residual"), new_state


# --- sLSTM --------------------------------------------------------------------


def slstm_defs(cfg):
    e = cfg.d_model
    h = cfg.n_heads
    d_h = e // h
    # recurrent weights are per-head block-diagonal (xLSTM's heads)
    return {
        "wz": ParamDef((e, e), ("embed", "heads_flat"), contract=0, out=1),
        "wi": ParamDef((e, h), ("embed", "ssm_heads"), contract=0, out=1),
        "wf": ParamDef((e, h), ("embed", "ssm_heads"), contract=0, out=1),
        "wo_gate": ParamDef((e, e), ("embed", "heads_flat"), contract=0, out=1),
        "bf": ParamDef((h,), ("ssm_heads",), init="ones"),
        "r": ParamDef((h, d_h, d_h), ("heads", "head_dim", "head_dim"), scale=0.1),
        "norm": ParamDef((e,), ("embed",), init="ones"),
        "wo": ParamDef((e, e), ("heads_flat", "embed"), contract=0, out=1),
    }


def slstm_init_state(cfg, batch):
    e = cfg.d_model
    h = cfg.n_heads
    return {
        "c": jnp.zeros((batch, e), jnp.float32),
        "n": jnp.zeros((batch, h), jnp.float32),
        "h": jnp.zeros((batch, e), jnp.float32),
    }


def slstm_block(p, x, cfg, *, mode: str, state=None):
    """Scalar-memory LSTM with a recurrent (previous-output) term.
    Sequential over time by construction."""
    b, s, e = x.shape
    h = cfg.n_heads
    d_h = e // h

    z_in = proj(x, p["wz"]).astype(jnp.float32)
    i_in = proj(x, p["wi"]).astype(jnp.float32)
    f_in = proj(x, p["wf"]).astype(jnp.float32) + p["bf"].astype(jnp.float32)
    o_in = proj(x, p["wo_gate"]).astype(jnp.float32)
    r = p["r"].astype(jnp.float32)

    if state is None:
        state = slstm_init_state(cfg, b)

    def step(carry, inp):
        c, nrm, h_prev = carry
        z_t, i_t, f_t, o_t = inp
        # recurrent contribution from h_{t-1} (per-head block diagonal)
        hp = h_prev.reshape(b, h, d_h)
        rec = jnp.einsum("bhd,hde->bhe", hp, r).reshape(b, e)
        z = jnp.tanh(z_t + rec)
        i_g = jnp.exp(jnp.minimum(i_t, 10.0))  # (b, h)
        f_g = jax.nn.sigmoid(f_t)
        c_new = (
            jnp.repeat(f_g, d_h, axis=-1) * c + jnp.repeat(i_g, d_h, axis=-1) * z
        )
        n_new = f_g * nrm + i_g
        h_head = c_new.reshape(b, h, d_h) / jnp.maximum(n_new, 1.0)[..., None]
        o_g = jax.nn.sigmoid(o_t)
        h_new = (o_g * h_head.reshape(b, e))
        return (c_new, n_new, h_new), h_new

    inputs = (
        z_in.transpose(1, 0, 2),
        i_in.transpose(1, 0, 2),
        f_in.transpose(1, 0, 2),
        o_in.transpose(1, 0, 2),
    )
    (c, nrm, h_last), ys = jax.lax.scan(
        step, (state["c"], state["n"], state["h"]), inputs
    )
    y = ys.transpose(1, 0, 2).astype(x.dtype)  # (B,S,E)
    y = rmsnorm(y, p["norm"], cfg.norm_eps)
    out = proj(y, p["wo"])
    return shard(out, "residual"), {"c": c, "n": nrm, "h": h_last}
