"""Model zoo facade: one `Model` object per architecture config.

Gives the launcher, dry-run, tests and examples a uniform surface:

    model = build(cfg)
    params = model.init(rng)
    loss   = model.loss(params, batch)            # train shapes
    logits, cache = model.prefill(params, batch)  # prefill shapes
    logits, cache = model.decode(params, cache, batch)  # serve_step
    specs  = model.input_specs(shape)             # ShapeDtypeStructs
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..config import ArchConfig, ShapeConfig
from .decoder import decoder_defs, decoder_forward, init_cache
from .encdec import encdec_defs, encdec_forward, encdec_init_cache
from .moe import aux_load_balance_loss
from .params import abstract, materialize, tree_size

__all__ = ["Model", "build"]


def softmax_xent(logits, labels):
    """Mean next-token cross-entropy; logits f32 (B, S, V)."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    defs: Any  # ParamDef tree

    # ---- parameters -----------------------------------------------------
    def init(self, rng, dtype=None):
        dtype = dtype or jnp.dtype(self.cfg.param_dtype)
        return materialize(self.defs, rng, dtype)

    def abstract_params(self, dtype=None):
        dtype = dtype or jnp.dtype(self.cfg.param_dtype)
        return abstract(self.defs, dtype)

    @property
    def n_params(self) -> int:
        return tree_size(self.defs)

    # ---- forward ------------------------------------------------------------
    def _forward(self, params, tokens, *, mode, cache=None, batch=None,
                 max_len=0, remat=False):
        cfg = self.cfg
        batch = batch or {}
        if cfg.family == "encdec":
            return encdec_forward(
                params, tokens, cfg, mode=mode,
                enc_frames=batch.get("enc_frames"), cache=cache,
                max_len=max_len, remat=remat,
            )
        return decoder_forward(
            params, tokens, cfg, mode=mode, cache=cache,
            image_embeds=batch.get("image_embeds"), max_len=max_len,
            remat=remat,
        )

    def loss(self, params, batch, *, remat: bool = False):
        logits, _ = self._forward(
            params, batch["tokens"], mode="train", batch=batch, remat=remat
        )
        loss = softmax_xent(logits, batch["labels"])
        if self.cfg.family == "moe":
            # load-balance aux on the first layer's router (cheap proxy)
            first = jax.tree.map(lambda a: a[0], params["layers"])
            x = jnp.take(params["embed"]["tok"], batch["tokens"], axis=0)
            loss = loss + 0.01 * aux_load_balance_loss(
                first["ffn"], x.astype(jnp.float32), self.cfg
            )
        return loss

    def prefill(self, params, batch, *, max_len: int = 0):
        return self._forward(
            params, batch["tokens"], mode="prefill", batch=batch,
            max_len=max_len,
        )

    def decode(self, params, cache, batch):
        """One serve step: batch["token"] (B, 1) -> logits (B, 1, V)."""
        return self._forward(params, batch["token"], mode="decode", cache=cache)

    # ---- caches -----------------------------------------------------------
    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        if self.cfg.family == "encdec":
            return encdec_init_cache(self.cfg, batch, max_len, dtype)
        return init_cache(self.cfg, batch, max_len, dtype)

    def abstract_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        return jax.eval_shape(
            lambda: self.init_cache(batch, max_len, dtype)
        )

    # ---- dry-run input specs ---------------------------------------------------
    def input_specs(self, shape: ShapeConfig) -> dict:
        """ShapeDtypeStruct stand-ins for every model input (no alloc)."""
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        if shape.mode == "train":
            specs = {
                "tokens": jax.ShapeDtypeStruct((b, s), i32),
                "labels": jax.ShapeDtypeStruct((b, s), i32),
            }
        elif shape.mode == "prefill":
            specs = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        else:  # decode: one new token against a seq_len cache
            specs = {"token": jax.ShapeDtypeStruct((b, 1), i32)}
        if cfg.family == "vlm" and shape.mode != "decode":
            specs["image_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.n_image_tokens, cfg.d_model), jnp.dtype(cfg.compute_dtype)
            )
        if cfg.family == "encdec" and shape.mode != "decode":
            specs["enc_frames"] = jax.ShapeDtypeStruct(
                (b, cfg.enc_seq, cfg.d_model), jnp.dtype(cfg.compute_dtype)
            )
        return specs


def build(cfg: ArchConfig) -> Model:
    if cfg.family == "encdec":
        defs = encdec_defs(cfg)
    else:
        defs = decoder_defs(cfg)
    return Model(cfg=cfg, defs=defs)
