from .adamw import OptConfig, abstract_opt_state, adamw_update, init_opt_state, schedule

__all__ = ["OptConfig", "abstract_opt_state", "adamw_update", "init_opt_state", "schedule"]
