"""AdamW with global-norm clipping and ZeRO-style sharded state.

Optimizer state mirrors the parameter pytree, so the same NamedShardings
apply — with FSDP rules the m/v moments are sharded over the data axis
(ZeRO), which is what lets qwen2-72b training fit 16 GB/chip.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["OptConfig", "init_opt_state", "adamw_update", "abstract_opt_state"]


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: OptConfig, step):
    """Linear warmup + cosine decay."""
    step = jnp.asarray(step, jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    t = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1
    )
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params):
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p), params)
    return {
        "m": zeros,
        "v": jax.tree.map(lambda p: jnp.zeros_like(p), params),
        "step": jnp.zeros((), jnp.int32),
    }


def abstract_opt_state(abstract_params):
    return {
        "m": abstract_params,
        "v": abstract_params,
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def adamw_update(params, grads, state, cfg: OptConfig):
    step = state["step"] + 1
    lr = schedule(cfg, step)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads)

    b1, b2 = cfg.b1, cfg.b2
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m_, v_):
        u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + cfg.eps)
        u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "step": step}, gnorm
