"""Distribution: logical-axis sharding, pipeline, MoE-EP, compression."""

from .axes import ShardingRules, current_rules, param_sharding, shard, use_rules

__all__ = ["ShardingRules", "current_rules", "param_sharding", "shard", "use_rules"]
