"""Distribution: logical-axis sharding, pipeline, MoE-EP, compression,
and device-sharded design-space search dispatch (``shard_eval``)."""

from .axes import ShardingRules, current_rules, param_sharding, shard, use_rules

__all__ = ["ShardingRules", "current_rules", "param_sharding", "shard", "use_rules"]
