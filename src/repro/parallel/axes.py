"""Logical-axis sharding: how the paper's dataflows become mesh rules.

Parameters and activations are annotated with *logical* axis names
("embed", "heads", "mlp", ...). A ``ShardingRules`` object maps logical
names to physical mesh axes according to the chosen dataflow strategy:

- ``dos`` (paper-faithful): every weight is sharded along its GEMM
  **contraction** axis over ``model`` — the mesh-level dOS. Each device
  computes a K/ℓ partial sum; XLA materializes the paper's adder pile
  as an all-reduce (or reduce-scatter when the next layer consumes a
  sharded layout — the "optimized pile").
- ``megatron`` (the WS/IS-in-3D analogue): column-parallel in-projs
  (output axis sharded), row-parallel out-projs (contraction sharded) —
  the classic pairing with one collective per block.
- ``auto``: per-GEMM choice delegated to ``core.advisor``.

FSDP ("zero") additionally shards every weight's largest remaining axis
over ``data`` for training, so optimizer state and master weights scale
with the full mesh.

Activation constraints go through ``shard(x, kind)`` with a small
vocabulary of activation kinds; when no rules are active this is a
no-op so single-device tests run unchanged.
"""

from __future__ import annotations

import contextlib
import contextvars
import dataclasses

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["ShardingRules", "use_rules", "current_rules", "shard", "param_sharding"]

_RULES: contextvars.ContextVar = contextvars.ContextVar("sharding_rules", default=None)

# Mesh axes that carry the batch (data-parallel) dimension.
BATCH_AXES = ("pod", "data")


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    mesh: Mesh
    strategy: str = "dos"  # dos | megatron | zero | auto
    fsdp: bool = True

    def batch_axes(self):
        """Mesh axes carrying the batch. The 'zero' strategy (pure
        ZeRO-3 data parallelism — params live sharded over every axis
        and are gathered per layer) spreads the batch over the WHOLE
        mesh; dOS/megatron keep 'model' for tensor sharding."""
        if self.strategy == "zero":
            return tuple(self.mesh.axis_names)
        return tuple(a for a in BATCH_AXES if a in self.mesh.axis_names)

    def axis_size(self, name: str) -> int:
        return self.mesh.shape[name] if name in self.mesh.axis_names else 1

    # ---- activations ------------------------------------------------------
    def act_spec(self, kind: str) -> P:
        """dOS chains reduce-scatters: every GEMM's output lands sharded
        on the *next* GEMM's contraction dim (residual on E, attention
        internals on heads, MLP hidden on F) — each partial-sum pile is
        scattered instead of fully replicated, which is both the
        memory-lean form of the paper's adder pile and what keeps
        per-device activations bounded. Megatron replicates the residual
        and shards the block-internal dims (classic col/row pairing)."""
        b = self.batch_axes() or None
        model = "model" if "model" in self.mesh.axis_names else None
        if self.strategy == "zero":
            model = None  # activations purely batch-sharded
        dos = self.strategy == "dos"
        table = {
            # residual stream (B, S, E): dOS keeps E sharded (the
            # reduce-scattered adder-pile output); megatron replicates.
            "residual": P(b, None, model if dos else None),
            # attention activations (B, S, H, D): heads sharded in both
            # (dOS: heads are the o-proj contraction dim).
            "attn_heads": P(b, None, model, None),
            # mlp hidden (B, S, F): F is the down-proj contraction dim.
            "mlp_hidden": P(b, None, model),
            # logits (B, S, V): vocab sharded in both strategies
            "logits": P(b, None, model),
            # kv cache (B, S, KVH, D)
            "kv_cache": P(b, None, model, None),
            # decode residual (B, 1, E)
            "decode_residual": P(b, None, model if dos else None),
            # ssm state (B, H, N, P)
            "ssm_state": P(b, model, None, None),
            # decode attention internals: q regrouped (B, KVH, G, D).
            # The KVH entry mirrors the cache layout so the batched
            # per-head contraction stays partitioned instead of forcing
            # a cache all-gather; the shard() divisibility guard drops
            # the axis when KVH doesn't divide.
            "decode_q_kvh": P(b, model, None, None),
            "none": P(),
        }
        return table[kind]

    # ---- parameters ---------------------------------------------------------
    def param_spec(self, axes: tuple, contract: int | None, out: int | None) -> P:
        """PartitionSpec for a weight with the given logical axes.

        ``contract``/``out`` are the GEMM contraction / output axis
        indices (None for non-GEMM params such as norms and biases).
        """
        model = "model" if "model" in self.mesh.axis_names else None
        if self.strategy == "zero":
            model = None  # no tensor sharding; fsdp below shards storage
        spec: list = [None] * len(axes)
        if model is not None and contract is not None:
            if self.strategy == "dos":
                shard_idx = contract
            elif self.strategy == "megatron":
                # col for in-projections (role encoded by axis name), row
                # for out-projections: out-proj contraction axes are
                # "heads"/"mlp"/"experts_ff" style inner axes.
                shard_idx = contract if axes[contract] in _INNER_AXES else out
            else:  # auto: resolved upstream, defaults to dos here
                shard_idx = contract
            if shard_idx is not None:
                spec[shard_idx] = model
        # vocab embedding tables: shard vocab over model
        if contract is None and "vocab" in axes and model is not None:
            spec[axes.index("vocab")] = model
        if self.fsdp:
            data_axes = self.batch_axes()
            if data_axes:
                # biggest remaining axis gets the data shards (ZeRO-3)
                free = [i for i in range(len(axes)) if spec[i] is None and axes[i] != "layers"]
                if free:
                    spec_idx = max(free, key=lambda i: _AXIS_WEIGHT.get(axes[i], 1))
                    spec[spec_idx] = data_axes if len(data_axes) > 1 else data_axes[0]
        return P(*spec)


# Axes that are GEMM-inner ("row-parallel") in the megatron pairing.
_INNER_AXES = {"heads_flat", "mlp", "expert_ff", "ssm_inner"}
# Relative size hints for picking the FSDP axis.
_AXIS_WEIGHT = {
    "vocab": 100, "mlp": 50, "expert_ff": 50, "embed": 40, "heads_flat": 30,
    "ssm_inner": 30, "experts": 20, "heads": 10, "kv_heads": 5, "head_dim": 2,
    "state": 2,
}


@contextlib.contextmanager
def use_rules(rules: ShardingRules | None):
    token = _RULES.set(rules)
    try:
        yield rules
    finally:
        _RULES.reset(token)


def current_rules() -> ShardingRules | None:
    return _RULES.get()


def shard(x, kind: str):
    """Constrain an activation's sharding (no-op without active rules).

    Axes whose shard count does not divide the dimension are dropped
    (replicated) — this keeps one rule table valid across full-size and
    smoke-test shapes.
    """
    rules = current_rules()
    if rules is None:
        return x
    spec = rules.act_spec(kind)
    nd = x.ndim
    parts = list(spec)
    if len(parts) < nd:
        parts = parts + [None] * (nd - len(parts))
    elif len(parts) > nd:
        parts = parts[:nd]
    for i, part in enumerate(parts):
        if part is None:
            continue
        axes_ = part if isinstance(part, tuple) else (part,)
        size = 1
        for a in axes_:
            size *= rules.axis_size(a)
        if size == 0 or x.shape[i] % size != 0:
            parts[i] = None
            # kv caches: when the head-count axis cannot take the model
            # shards (e.g. qwen2-72b kvh=8 < 16), fall back to context-
            # sharding the cache SEQUENCE dim — a replicated constraint
            # here would force XLA to all-gather the whole cache every
            # decode step, and head_dim sharding does not compose with
            # the GQA-grouped decode einsum under GSPMD.
            if kind == "kv_cache" and i == nd - 2 and nd >= 3:
                msize = rules.axis_size("model")
                if (part == "model" and parts[nd - 3] is None
                        and x.shape[nd - 3] % msize == 0):
                    parts[nd - 3] = "model"
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, P(*parts))
    )


def param_sharding(defs, rules: ShardingRules):
    """Map a ParamDef pytree to NamedShardings."""
    from ..models.params import ParamDef  # local import to avoid cycle

    def one(d: ParamDef):
        if not _divisible(d, rules):
            # fall back to replicated if the shard doesn't divide
            return NamedSharding(rules.mesh, P())
        return NamedSharding(rules.mesh, rules.param_spec(d.axes, d.contract, d.out))

    return jax.tree.map(one, defs, is_leaf=lambda x: isinstance(x, ParamDef))


def _divisible(d, rules: ShardingRules) -> bool:
    spec = rules.param_spec(d.axes, d.contract, d.out)
    for dim, part in zip(d.shape, tuple(spec) + (None,) * (len(d.shape) - len(spec))):
        if part is None:
            continue
        axes = part if isinstance(part, tuple) else (part,)
        size = 1
        for a in axes:
            size *= rules.axis_size(a)
        if dim % size != 0:
            return False
    return True
