"""int8 + error-feedback gradient compression (beyond-paper opt).

Cross-replica gradient sync is the data-parallel analogue of the
paper's cross-tier partial-sum reduction: per-device partial gradients
are "piled up" over the data axis. This module compresses that pile:
each device quantizes its local gradient to int8 with a per-tensor
scale, all-reduces the int8 payload (4x fewer wire bytes than f32,
2x vs bf16), dequantizes, and keeps the quantization residual as
error-feedback state added to the next step's gradient — the standard
EF-SGD construction that keeps convergence unbiased in the long run.

Implemented with ``shard_map`` over the data axis so the quantize /
psum / dequantize pipeline is explicit (pjit's implicit grad psum
cannot be intercepted). Params must be replicated over ``data`` for
this path (compression targets cross-replica sync; FSDP's gathered
shards already move int-sized payloads), so it composes with model
sharding but not with ZeRO — documented trade-off.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .._jax_compat import shard_map as _shard_map

from jax.sharding import PartitionSpec as P

__all__ = ["init_error_state", "compressed_psum_grads"]


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quantize(x):
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_psum_grads(grads, error_state, mesh, axis: str = "data"):
    """All-reduce ``grads`` over ``axis`` in int8 with error feedback.

    Returns (synced_grads_f32, new_error_state). Call inside the train
    step on the *local* (per-replica mean) gradients.
    """

    def one_sync(g, err):
        g = g.astype(jnp.float32) + err
        q, scale = _quantize(g)
        # int8 payloads sum without overflow in int32; scales are tiny.
        qsum = jax.lax.psum(q.astype(jnp.int32), axis)
        ssum = jax.lax.psum(scale, axis)
        n = jax.lax.psum(1, axis)
        # each replica contributed q*scale; approximate with mean scale
        g_hat_local = q.astype(jnp.float32) * scale
        g_hat = qsum.astype(jnp.float32) * (ssum / n) / n
        new_err = g - g_hat_local  # local quantization residual
        return g_hat, new_err

    def leaf_sync(g, err):
        @functools.partial(
            _shard_map, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()),
        )
        def f(g_, e_):
            return one_sync(g_, e_)

        return f(g, err)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error_state)
    out_g, out_e = [], []
    for g, e in zip(flat_g, flat_e):
        gh, ne = leaf_sync(g, e)
        out_g.append(gh)
        out_e.append(ne)
    return jax.tree.unflatten(treedef, out_g), jax.tree.unflatten(treedef, out_e)
