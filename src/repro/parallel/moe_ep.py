"""Expert-parallel MoE dispatch (shard_map) — §Perf Cell C2.

Fine-grained MoE (deepseek: 64 experts, per-expert K=1408) sits in the
paper's *small-K loses* regime (Fig. 5): no tensor axis wants a slice
of an expert. The right mapping keeps experts **whole but distributed**
— 64/16 = 4 experts per device over the ``model`` axis — and moves
*tokens* to experts instead of gathering weights:

  - every device routes its local tokens (router weights replicated);
  - tokens pick top-k experts; picks for non-local experts are masked
    into a zero-weight overflow bucket;
  - a ragged_dot over the 4 local experts computes local contributions;
  - a psum over ``model`` combines (each token's k experts live
    somewhere, every device contributes what it owns).

Wire cost per layer ≈ one psum of the token activations (tokens x E),
independent of expert-parameter size — vs. the ZeRO mapping's
per-layer gather of the full expert set (measured: 120 TB/step,
EXPERIMENTS.md §Perf C1, refuted).

This module is validated against the replicated ``moe_block`` oracle in
tests/test_sharding_multidevice.py (smoke scale).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .._jax_compat import shard_map as _shard_map

from jax.sharding import PartitionSpec as P

from ..models.layers import proj

__all__ = ["moe_block_ep"]


def moe_block_ep(p, x, cfg, mesh, *, axis: str = "model", batch_axis: str | None = "data"):
    """Expert-parallel MoE FFN. p: the moe_defs tree with expert weights
    sharded over ``axis`` on their expert dim; x: (B, S, E) sharded over
    ``batch_axis``. Returns (B, S, E)."""
    ne = cfg.n_experts
    ax_size = mesh.shape[axis]
    assert ne % ax_size == 0, (ne, ax_size)
    ne_local = ne // ax_size
    k = cfg.top_k

    in_specs = (
        {  # params
            "router": P(),
            "wi_gate": P(axis),
            "wi_up": P(axis),
            "wo": P(axis),
            **({"shared": P()} if "shared" in p else {}),
        },
        P(batch_axis),  # x
    )

    @functools.partial(
        _shard_map, mesh=mesh, in_specs=in_specs, out_specs=P(batch_axis),
    )
    def run(pl, xl):
        b, s, e = xl.shape
        t = b * s
        xt = xl.reshape(t, e)
        my = jax.lax.axis_index(axis)

        logits = xt.astype(jnp.float32) @ pl["router"].astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        topk_p, topk_i = jax.lax.top_k(probs, k)
        topk_p = topk_p / jnp.sum(topk_p, axis=-1, keepdims=True)

        # local expert ids in [0, ne_local); non-local -> overflow bucket
        local_i = topk_i - my * ne_local
        is_local = (local_i >= 0) & (local_i < ne_local)
        local_i = jnp.where(is_local, local_i, ne_local)
        w_local = jnp.where(is_local, topk_p, 0.0)

        flat_e = local_i.reshape(-1)
        order = jnp.argsort(flat_e)
        token_of = jnp.arange(t * k, dtype=jnp.int32) // k
        xs = xt[token_of[order]]
        group_sizes = jnp.bincount(flat_e, length=ne_local + 1).astype(jnp.int32)

        # zero-expert overflow row keeps ragged_dot shapes static
        def padded(w):  # (ne_local, a, b) -> (ne_local + 1, a, b)
            return jnp.concatenate([w, jnp.zeros_like(w[:1])], axis=0)

        g = jax.lax.ragged_dot(xs, padded(pl["wi_gate"]).astype(xs.dtype), group_sizes)
        u = jax.lax.ragged_dot(xs, padded(pl["wi_up"]).astype(xs.dtype), group_sizes)
        h = jax.nn.silu(g) * u
        y_sorted = jax.lax.ragged_dot(h, padded(pl["wo"]).astype(h.dtype), group_sizes)

        inv = jnp.argsort(order)
        y = y_sorted[inv].reshape(t, k, e)
        y = jnp.sum(y * w_local[..., None].astype(y.dtype), axis=1)
        # combine across expert shards: each device contributed the
        # experts it owns — the psum is the paper's adder pile applied
        # to the *expert* axis.
        y = jax.lax.psum(y, axis)

        if "shared" in pl:
            sp = pl["shared"]
            sg = proj(xt, sp["wi_gate"])
            su = proj(xt, sp["wi_up"])
            y = y + proj(jax.nn.silu(sg) * su, sp["wo"]).astype(y.dtype)
        return y.reshape(b, s, e).astype(xl.dtype)

    pl_in = {kk: p[kk] for kk in ("router", "wi_gate", "wi_up", "wo")}
    if "shared" in p:
        pl_in["shared"] = p["shared"]
    return run(pl_in, x)
