"""GPipe-style pipeline parallelism over the ``pod`` mesh axis.

The multi-pod mesh (2, 16, 16) can drive its pod axis either as extra
data parallelism (default) or as pipeline stages (--pipeline). Here the
layer stack is split into ``n_stages`` contiguous stages; microbatches
flow through a ``shard_map`` loop of ``n_mb + n_stages - 1`` ticks with
``ppermute`` handoffs — the classic GPipe schedule, expressed so that
jax.grad differentiates straight through it (ppermute's transpose is
the reverse permute, giving the backward pipeline for free).

Embedding runs on stage 0, the LM head + loss on the last stage. The
loop is written version-agnostically so it runs on jax 0.4 and >= 0.7
alike: every value carried through the shard_map body has rank >= 1
(jax 0.4's linearization names shard_map residuals ``{0: axes}``,
which a rank-0 carry cannot satisfy, breaking the backward pass), and
the loss leaves the body as a per-stage ``P(stage_axis)`` output
summed *outside* — only the last stage contributes a nonzero partial,
so no in-body psum/broadcast collective is needed at all. Bubble
fraction is (n_stages - 1) / (n_mb + n_stages - 1) — the §Perf log
reasons about it explicitly.

This path implements the dense family (llama/qwen/gemma-style blocks);
it exists to prove the schedule and to give the dry-run a pipelined
multi-pod cell, not to replace the default DP-over-pod mapping.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .._jax_compat import pcast as _pcast
from .._jax_compat import shard_map as _shard_map

from jax.sharding import PartitionSpec as P

from ..models.decoder import _attn_mlp_block, layer_metadata
from ..models.layers import embed_tokens, rmsnorm, unembed
from ..models.zoo import softmax_xent

__all__ = ["make_gpipe_loss"]


def make_gpipe_loss(cfg, mesh, *, n_stages: int, n_microbatches: int,
                    stage_axis: str = "pod", remat: bool = True):
    """Returns loss_fn(params, batch) running the GPipe schedule.

    params: the normal dense decoder tree (layers stacked (L, ...)).
    batch: {"tokens": (B, S), "labels": (B, S)}; B % n_microbatches == 0.
    The caller shards params' layer stacks over ``stage_axis`` via
    stage_param_sharding (stage dim = leading layer dim grouped).
    """
    assert cfg.family in ("dense",), "pipeline path implements dense archs"
    L = cfg.n_layers
    assert L % n_stages == 0, (L, n_stages)
    per_stage = L // n_stages
    win_all, theta_all = layer_metadata(cfg)

    def stage_fwd(stage_params, x, wins, thetas):
        def body(carry, xs):
            lp, w, th = xs
            y, _ = _attn_mlp_block(
                lp, carry, cfg, mode="train", cache=None, window=w, theta=th
            )
            return y, None

        b = jax.checkpoint(body) if remat else body
        x, _ = jax.lax.scan(b, x, (stage_params, wins, thetas))
        return x

    def loss_fn(params, batch):
        n_mb = n_microbatches
        tokens, labels = batch["tokens"], batch["labels"]
        b, s = tokens.shape
        assert b % n_mb == 0
        mb = b // n_mb
        tokens_mb = tokens.reshape(n_mb, mb, s)
        labels_mb = labels.reshape(n_mb, mb, s)

        # reshape layer stacks to (stages, per_stage, ...)
        layers = jax.tree.map(
            lambda a: a.reshape(n_stages, per_stage, *a.shape[1:]),
            params["layers"],
        )
        wins = win_all.reshape(n_stages, per_stage)
        thetas = theta_all.reshape(n_stages, per_stage)

        other_axes = tuple(a for a in mesh.axis_names if a != stage_axis)

        @functools.partial(
            _shard_map,
            mesh=mesh,
            in_specs=(
                P(stage_axis),  # layers: stage dim sharded
                P(stage_axis),  # wins
                P(stage_axis),  # thetas
                P(),  # embed/head/final norm: replicated
                P(None, None, None),  # tokens_mb
                P(None, None, None),  # labels_mb
            ),
            # per-stage loss partials; only the last stage's is nonzero
            out_specs=P(stage_axis),
        )
        def run(layers_s, wins_s, thetas_s, shared, toks, labs):
            my = jax.lax.axis_index(stage_axis)
            lp = jax.tree.map(lambda a: a[0], layers_s)  # local stage params
            w_l, t_l = wins_s[0], thetas_s[0]
            emb, fin = shared["embed"], shared["final_norm"]

            n_ticks = n_mb + n_stages - 1
            compute_dtype = jnp.dtype(cfg.compute_dtype)
            act0 = jnp.zeros((mb, s, cfg.d_model), compute_dtype)
            # rank >= 1 keeps jax 0.4's residual naming representable
            loss0 = jnp.zeros((1,), jnp.float32)
            fwd_perm = [(i, i + 1) for i in range(n_stages - 1)]

            def tick(carry, t):
                act, loss_sum = carry
                # stage 0 ingests microbatch t (if valid)
                mb_idx = jnp.clip(t, 0, n_mb - 1)
                x_in = embed_tokens(emb, toks[mb_idx], compute_dtype)
                x = jnp.where(my == 0, x_in, act)
                y = stage_fwd(lp, x, w_l, t_l)
                # last stage: loss for microbatch t - (n_stages - 1)
                out_idx = t - (n_stages - 1)
                valid_out = (out_idx >= 0) & (out_idx < n_mb)
                lab = labs[jnp.clip(out_idx, 0, n_mb - 1)]
                z = rmsnorm(y, fin, cfg.norm_eps)
                logits = unembed(emb, z, cfg.tie_embeddings)
                mb_loss = softmax_xent(logits, lab)
                is_last = my == n_stages - 1
                loss_sum = loss_sum + jnp.where(
                    is_last & valid_out, mb_loss, 0.0
                )[None]
                # hand activations forward
                act_next = jax.lax.ppermute(y, stage_axis, fwd_perm)
                return (act_next, loss_sum), None

            # carries become stage-varying after my-dependent selects
            act0_v = _pcast(act0, (stage_axis,), to="varying")
            loss0_v = _pcast(loss0, (stage_axis,), to="varying")
            (_, loss_sum), _ = jax.lax.scan(
                tick, (act0_v, loss0_v), jnp.arange(n_ticks)
            )
            return loss_sum

        shared = {"embed": params["embed"], "final_norm": params["final_norm"]}
        partials = run(layers, wins, thetas, shared, tokens_mb, labels_mb)
        # sum of per-stage partials == the last stage's loss; no
        # collective needed (stages other than the last contribute 0)
        return jnp.sum(partials) / n_mb

    return loss_fn
