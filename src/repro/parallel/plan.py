"""Sharding plans: map every training/serving input to a NamedSharding.

``make_plan`` assembles, for a (model, shape, rules) triple, the
abstract inputs and in/out shardings that ``jax.jit`` needs — for
train_step (params, opt_state, batch), prefill_step and serve_step
(params, cache, token batch). This is where decode caches get their
placement: batch over the data axes and one head/feature dim over
``model`` (with per-dim divisibility fallbacks, so gemma3's single KV
head falls back to head_dim sharding, and long_500k's batch=1 falls
back to context sharding over the sequence dim).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..config import ShapeConfig
from ..models.params import ParamDef
from ..optim import abstract_opt_state
from .axes import ShardingRules, param_sharding

__all__ = ["Plan", "make_plan"]


@dataclasses.dataclass
class Plan:
    rules: ShardingRules
    abstract: tuple  # positional abstract inputs for .lower()
    in_shardings: tuple
    out_shardings: Any


def _ns(rules, *parts):
    return NamedSharding(rules.mesh, P(*parts))


def _fit(rules: ShardingRules, shape, parts):
    """Drop spec entries that do not divide the dim."""
    parts = list(parts) + [None] * (len(shape) - len(parts))
    for i, part in enumerate(parts):
        if part is None:
            continue
        axes = part if isinstance(part, tuple) else (part,)
        size = 1
        for a in axes:
            size *= rules.axis_size(a)
        if shape[i] % size != 0:
            parts[i] = None
    return parts


def batch_sharding(rules: ShardingRules, spec_tree):
    """Token/label/frame inputs: leading dim over the data axes."""
    b = rules.batch_axes() or None

    def one(s: jax.ShapeDtypeStruct):
        parts = _fit(rules, s.shape, [b])
        return _ns(rules, *parts)

    return jax.tree.map(one, spec_tree)


def cache_shardings(rules: ShardingRules, abstract_cache, batch: int):
    """Decode/prefill cache placement with divisibility fallbacks."""
    b = rules.batch_axes() or None
    model = "model" if "model" in rules.mesh.axis_names else None
    dsize = 1
    for a in rules.batch_axes():
        dsize *= rules.axis_size(a)
    msize = rules.axis_size("model") if model else 1
    long_ctx = batch % max(dsize, 1) != 0  # e.g. batch == 1 at 500k

    def one(path, s):
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        shape = s.shape
        nd = len(shape)
        parts = [None] * nd
        if name in ("length", "step") or nd <= 1:
            return _ns(rules, *parts)
        if name in ("k", "v") and nd >= 4:
            # (..., B, S, KVH, HD)
            bdim, sdim, hdim, ddim = nd - 4, nd - 3, nd - 2, nd - 1
            if not long_ctx:
                parts[bdim] = b
            elif shape[sdim] % (dsize or 1) == 0:
                parts[sdim] = b  # context-shard the cache sequence
            if model:
                if shape[hdim] % msize == 0:
                    parts[hdim] = model
                elif parts[sdim] is None and shape[sdim] % msize == 0:
                    # context-shard the cache sequence (ring decode):
                    # composes with GQA einsums where head_dim cannot.
                    parts[sdim] = model
            return _ns(rules, *_fit(rules, shape, parts))
        # state leaves (ssm/conv/mlstm/slstm): batch dim is the first
        # dim of size `batch` scanning from the left; shard the largest
        # remaining dim over model.
        bdim = None
        for i, d in enumerate(shape):
            if d == batch:
                bdim = i
                break
        if bdim is not None and not long_ctx:
            parts[bdim] = b
        if model:
            cands = [
                i for i in range(nd)
                if i != bdim and shape[i] % msize == 0 and shape[i] >= msize
            ]
            if cands:
                parts[max(cands, key=lambda i: shape[i])] = model
        return _ns(rules, *_fit(rules, shape, parts))

    return jax.tree_util.tree_map_with_path(one, abstract_cache)


def make_plan(
    model,
    shape: ShapeConfig,
    rules: ShardingRules,
    *,
    mode: str | None = None,
) -> Plan:
    """Abstract inputs + shardings for the step implied by ``shape``."""
    mode = mode or shape.mode
    cfg = model.cfg
    if mode == "train":
        ap = model.abstract_params(jnp.dtype(cfg.param_dtype))
        ps = param_sharding(model.defs, rules)
        aos = abstract_opt_state(ap)
        oss = {"m": ps, "v": ps, "step": _ns(rules)}
        specs = model.input_specs(shape)
        bs = batch_sharding(rules, specs)
        return Plan(
            rules=rules,
            abstract=(ap, aos, specs),
            in_shardings=(ps, oss, bs),
            out_shardings=(ps, oss, _ns(rules)),  # params, opt, loss
        )

    serve_dtype = jnp.bfloat16
    ap = model.abstract_params(serve_dtype)
    serve_rules = dataclasses.replace(rules, fsdp=False)
    ps = param_sharding(model.defs, serve_rules)
    specs = model.input_specs(shape)
    bs = batch_sharding(rules, specs)
    b = shape.global_batch

    if mode == "prefill":
        # logits + cache out
        ac = model.abstract_cache(b, shape.seq_len, serve_dtype)
        cs = cache_shardings(rules, ac, b)
        logits_shape = (b, shape.seq_len, cfg.vocab)
        logits_s = _ns(rules, *_fit(
            rules, logits_shape, [rules.batch_axes() or None, None, "model"]
        ))
        return Plan(
            rules=rules,
            abstract=(ap, specs),
            in_shardings=(ps, bs),
            out_shardings=(logits_s, cs),
        )

    # decode / long-context decode
    ac = model.abstract_cache(b, shape.seq_len, serve_dtype)
    cs = cache_shardings(rules, ac, b)
    long_ctx = b == 1
    logits_parts = _fit(
        rules, (b, 1, cfg.vocab),
        [None if long_ctx else (rules.batch_axes() or None), None, "model"],
    )
    logits_s = _ns(rules, *logits_parts)
    return Plan(
        rules=rules,
        abstract=(ap, ac, specs),
        in_shardings=(ps, cs, bs),
        out_shardings=(logits_s, cs),
    )
