"""Device-sharded dispatch of the batched (R, C) design-space search.

The engine's hot kernel (``core.analytical._search_rc``) is rowwise
independent: every design point's search reads only its own
(D1, D2, Tser, budget) row. That makes data-parallel execution across
the host's JAX devices exact — this module splits the flat point batch
over a 1-D device mesh with ``shard_map`` and runs the *same* jitted
kernel per shard, so sharded and unsharded results are bit-for-bit
identical (regression-pinned by ``tests/test_scale.py``).

On a plain CPU host there is one device and ``shard='auto'`` degrades
to the single-device path; multi-device CPU testing uses
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (see
``tests/conftest.run_multidevice``).
"""

from __future__ import annotations

import functools

import numpy as np

__all__ = ["resolve_shards", "sharded_search"]


def resolve_shards(shard) -> int:
    """Normalize an ``evaluate(shard=...)`` request to a shard count.

    ``None``/``'none'``/``1`` -> 1 (unsharded). ``'auto'`` -> the number
    of local JAX devices. An explicit int must not exceed the local
    device count (``shard_map`` places one sub-batch per device).
    """
    if shard is None or shard == "none" or shard == 1:
        return 1
    import jax

    n_dev = jax.local_device_count()
    if shard == "auto":
        return max(n_dev, 1)
    try:
        n = int(shard)
    except (TypeError, ValueError):
        raise ValueError(
            f"shard must be None, 'none', 'auto' or a positive int, got {shard!r}"
        ) from None
    if n < 1:
        raise ValueError(f"shard must be >= 1, got {n}")
    if n > n_dev:
        raise ValueError(
            f"shard={n} exceeds the {n_dev} local JAX device(s); "
            "set XLA_FLAGS=--xla_force_host_platform_device_count for CPU testing"
        )
    return n


@functools.lru_cache(maxsize=32)
def _sharded_search_fn(n_shards: int, r_max_total: int):
    """jit(shard_map(_search_rc)) over a 1-D ('shard',) device mesh.

    Cached per (shard count, static search width) like the engine's
    single-device ``_jax_search_fn`` — one compile per width class.
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from .._jax_compat import make_mesh, shard_map
    from ..core.analytical import _search_rc

    mesh = make_mesh((n_shards,), ("shard",))

    def search(D1, D2, Tser, budget):
        return _search_rc(jnp, D1, D2, Tser, budget, r_max_total)

    fn = shard_map(
        search,
        mesh=mesh,
        in_specs=(P("shard"),) * 4,
        out_specs=(P("shard"),) * 3,
    )
    return jax.jit(fn)


def sharded_search(D1, D2, Tser, budget, r_max_total: int, n_shards: int):
    """Run one search batch split across ``n_shards`` devices.

    Inputs are (B,) int64 numpy arrays; B need not divide the shard
    count — the batch is padded with trivial rows (all-ones searches)
    and sliced back, so degenerate batches (B < n_shards, B == 1) are
    exact. Caller is expected to hold jax's ``enable_x64`` scope, like
    the engine's unsharded jax path.
    """
    B = D1.shape[0]
    pad = (-B) % n_shards
    if pad:
        one = np.ones(pad, dtype=np.int64)
        D1, D2, Tser, budget = (
            np.concatenate([a, one]) for a in (D1, D2, Tser, budget)
        )
    fn = _sharded_search_fn(n_shards, r_max_total)
    r, c, t = fn(D1, D2, Tser, budget)
    return (
        np.asarray(r)[:B],
        np.asarray(c)[:B],
        np.asarray(t)[:B],
    )
