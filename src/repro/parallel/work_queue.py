"""Multi-process work queue over the chunk-cache protocol.

The guided search (``core.search``) decomposes every generation into
content-addressed cache blocks. This module farms the *missing* blocks
of a generation to N worker processes: each worker rebuilds the Study
from its JSON spec, prices its candidate block through the same
``search.evaluate_candidates`` path as the in-process runner, and
atomically stores the chunk file. The parent collects the chunks — the
cache IS the transport, so there is no result pickling, a killed worker
leaves no partial state (atomic writes), and a crashed run resumes
exactly like a single-process one.

Chunk payloads are bit-identical across worker counts (the evaluation
is deterministic and JSON float64 round-trips are exact), which is why
``AnalysisSpec.workers`` is an execution knob excluded from the spec
hash — a sweep started with one worker resumes with eight.

Inside each worker the engine's own parallelism still applies: a
``shard='auto'`` study shards its (R, C) search over the worker's local
JAX devices (``parallel.shard_eval``), composing process-level and
device-level parallelism.

Start method: ``fork`` where available (cheap, inherits sys.path), else
``spawn``. Callers using the jax backend should pass
``start_method='spawn'`` — forking a process after jax initializes its
thread pools is unsafe.
"""

from __future__ import annotations

import concurrent.futures
import multiprocessing
import os
import pathlib

__all__ = ["run_blocks"]


def _eval_store(study_json: str, cache_root: str, block_cells: int, key: str,
                cands) -> str:
    """Worker body: price one candidate block, store its chunk, return key."""
    import numpy as np

    from ..core.cache import ResultCache
    from ..core.search import chunk_payload, evaluate_candidates
    from ..core.study import Study

    study = Study.from_json(study_json)
    cache = ResultCache(cache_root, block_cells=block_cells)
    c = np.asarray(cands, dtype=np.int64)
    objs, feas = evaluate_candidates(study, c)
    cache.store_chunk(study, key, chunk_payload(c, objs, feas))
    return key


def _ensure_importable() -> None:
    """Make sure spawn children can ``import repro`` (they re-import this
    module by qualified name; sys.path does not inherit, PYTHONPATH does)."""
    root = str(pathlib.Path(__file__).resolve().parents[2])
    pp = os.environ.get("PYTHONPATH", "")
    if root not in pp.split(os.pathsep):
        os.environ["PYTHONPATH"] = os.pathsep.join(p for p in (root, pp) if p)


def run_blocks(study_json: str, cache_root: str, block_cells: int, jobs,
               workers: int, start_method: str | None = None) -> list[str]:
    """Farm ``jobs`` = [(chunk_key, candidate_rows), ...] to N processes.

    Blocks until every chunk is stored (or re-raises the first worker
    failure). Returns the completed keys in submission order.
    """
    jobs = list(jobs)
    if not jobs:
        return []
    if start_method is None:
        methods = multiprocessing.get_all_start_methods()
        start_method = "fork" if "fork" in methods else "spawn"
    if start_method == "spawn":
        _ensure_importable()
    ctx = multiprocessing.get_context(start_method)
    n = max(1, min(int(workers), len(jobs)))
    with concurrent.futures.ProcessPoolExecutor(max_workers=n, mp_context=ctx) as ex:
        futs = [
            ex.submit(_eval_store, study_json, cache_root, block_cells, key, cands)
            for key, cands in jobs
        ]
        return [f.result() for f in futs]
