from .fault_tolerance import FaultInjector, StragglerWatchdog, elastic_restore, run_with_restarts

__all__ = ["FaultInjector", "StragglerWatchdog", "elastic_restore", "run_with_restarts"]
