"""Fault tolerance: restarts, elastic resharding, straggler mitigation.

Designed for thousands of nodes, exercised here single-process:

- ``run_with_restarts`` — supervises a training function; on failure it
  restores the latest checkpoint and re-enters. ``max_restarts`` bounds
  crash loops. Failures are injectable for tests (``FaultInjector``).
- ``elastic_restore`` — re-shards a checkpoint onto the *current* mesh
  (checkpoints store full arrays, so any divisible mesh works: losing a
  pod means restarting data-parallel width 16 instead of 32 with the
  same model shards).
- ``StragglerWatchdog`` — per-step deadline from a robust moving
  estimate of step time; slow steps are counted and surfaced so the
  scheduler can evict/replace the slow host (on TPU pods, gang-scheduled
  steps make the slowest chip the global step time — mitigation is
  detect-and-replace, plus keeping per-step work balanced, which the
  sharding rules guarantee by construction).
"""

from __future__ import annotations

import dataclasses
import logging
import time

import jax

from ..checkpoint import checkpointer

log = logging.getLogger("repro.runtime")

__all__ = ["run_with_restarts", "elastic_restore", "StragglerWatchdog", "FaultInjector"]


@dataclasses.dataclass
class FaultInjector:
    """Deterministic fault injection for tests: raises at given steps."""

    fail_at_steps: tuple = ()
    fired: set = dataclasses.field(default_factory=set)

    def maybe_fail(self, step: int):
        if step in self.fail_at_steps and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"injected node failure at step {step}")


class StragglerWatchdog:
    def __init__(self, factor: float = 3.0, warmup: int = 5):
        self.factor = factor
        self.warmup = warmup
        self.times: list[float] = []
        self.slow_steps: list[int] = []
        self._t0 = None

    def start_step(self):
        self._t0 = time.monotonic()

    def end_step(self, step: int) -> bool:
        dt = time.monotonic() - self._t0
        slow = False
        if len(self.times) >= self.warmup:
            med = sorted(self.times)[len(self.times) // 2]
            if dt > self.factor * med:
                self.slow_steps.append(step)
                log.warning("straggler: step %d took %.3fs (median %.3fs)", step, dt, med)
                slow = True
        self.times.append(dt)
        if len(self.times) > 100:
            self.times.pop(0)
        return slow


def elastic_restore(ckpt_dir, step, like, shardings):
    """Restore a checkpoint and place it with the current mesh's
    shardings (elastic: the saving mesh may have differed)."""
    host = checkpointer.restore(ckpt_dir, step, like)
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s), host, shardings
    )


def run_with_restarts(make_state, train_steps, *, ckpt_dir, max_restarts: int = 3):
    """Supervise ``train_steps(state, start_step) -> state``.

    ``make_state(resume_step | None)`` builds (or restores) training
    state; on an exception the latest checkpoint is picked up and the
    loop re-enters. Returns the final state.
    """
    restarts = 0
    while True:
        resume = checkpointer.latest_step(ckpt_dir)
        state = make_state(resume)
        try:
            return train_steps(state, 0 if resume is None else resume)
        except Exception as e:  # noqa: BLE001 - supervision boundary
            restarts += 1
            log.warning("restart %d/%d after failure: %s", restarts, max_restarts, e)
            if restarts > max_restarts:
                raise
