"""Hypothesis, or graceful stand-ins when it isn't installed.

``from _hyp import given, settings, st`` gives test modules the real
hypothesis API when available; otherwise ``@given(...)`` marks just the
property-based tests as skipped, so the deterministic tests in the same
module still collect and run under the tier-1 ``pytest -x -q`` command.
"""

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAS_HYPOTHESIS = True
except ImportError:
    import pytest

    HAS_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        return lambda f: pytest.mark.skip(reason="hypothesis not installed")(f)

    def settings(*_args, **_kwargs):
        return lambda f: f

    class _Strategies:
        """Accepts any strategy construction and returns inert objects."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _Strategies()
