"""Hypothesis, or graceful stand-ins when it isn't installed.

``from _hyp import given, settings, st`` gives test modules the real
hypothesis API when available; otherwise ``@given(...)`` marks just the
property-based tests as skipped, so the deterministic tests in the same
module still collect and run under the tier-1 ``pytest -x -q`` command.

CI must never silently lose the property tests: with
``REPRO_REQUIRE_HYPOTHESIS=1`` in the environment (set by the CI
workflow, which installs hypothesis via the ``[test]`` extra) a missing
hypothesis is a hard collection error instead of 7 quiet skips.
"""

import os

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAS_HYPOTHESIS = True
except ImportError:
    if os.environ.get("REPRO_REQUIRE_HYPOTHESIS"):
        raise ImportError(
            "REPRO_REQUIRE_HYPOTHESIS is set but hypothesis is not "
            "installed — `pip install hypothesis` (or `pip install -e "
            ".[test]`) so the property tests run instead of skipping"
        ) from None

    import pytest

    HAS_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        return lambda f: pytest.mark.skip(reason="hypothesis not installed")(f)

    def settings(*_args, **_kwargs):
        return lambda f: f

    class _Strategies:
        """Accepts any strategy construction and returns inert objects."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _Strategies()
