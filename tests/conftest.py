"""Shared test helpers.

IMPORTANT: no XLA_FLAGS here — unit/smoke tests must see the real
single-device environment. Tests that need a multi-device mesh spawn a
subprocess with --xla_force_host_platform_device_count (see
run_multidevice).
"""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_multidevice(code: str, n_devices: int = 8, timeout: int = 900):
    """Run `code` in a fresh python with N fake CPU devices; returns stdout.
    The snippet should print results; raise/assert inside it for failure."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env, cwd=REPO,
    )
    if r.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-4000:]}"
        )
    return r.stdout


@pytest.fixture(scope="session")
def rng():
    import numpy as np

    return np.random.default_rng(0)
