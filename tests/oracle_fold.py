"""Scalar fold-pricing oracle: slow, explicit, obviously correct.

The vectorized fold pricing path (``analytical.fold_dims`` ->
``bandwidth.fold_traffic_batched`` -> ``pricing.price_steps``) is a
pile of broadcast ``np.where`` algebra — fast, but hard to eyeball.
This module reprices ONE (dataflow, fold, workload, design) point at a
time with nothing but Python integers, explicit per-tier / per-fold /
per-boundary loops, and if/else per model rule, so every charged byte
and cycle can be traced back to the sentence in the model docstrings
that mandates it. ``tests/test_fold.py`` runs the two implementations
over a dense grid and asserts **bit-for-bit** float equality.

Bit-for-bit is achievable because the vectorized model is exact-integer
float64 arithmetic (all counts < 2^53) plus a small number of true
float divisions; the oracle accumulates every count as an arbitrary-
precision Python int (loops, not closed forms) and then applies the
same final float ops in the same association order (one rounding per
division — e.g. ``vlink_cycles = bytes / per_boundary_bw`` — matches
exactly when both sides feed it identical operand bits).

Everything here is deliberately O(folds * tiers): correctness over
speed. Keep test workloads modest.
"""

import math

from repro.core.bandwidth import TSV_VLINK_SHARE, BandwidthSpec
from repro.core.ppa import constants as C


def ceil_div(a: int, b: int) -> int:
    """ceil(a/b) by counting how many size-b chunks cover a."""
    assert a >= 0 and b >= 1
    n = 0
    while n * b < a:
        n += 1
    return n


def count_folds(D1: int, D2: int, R: int, Cc: int) -> int:
    """Number of (R x C) array passes over a (D1 x D2) spatial map,
    counted by literally walking the tile grid."""
    folds = 0
    for _i in range(0, D1, R):
        for _j in range(0, D2, Cc):
            folds += 1
    return folds


def native_fold(dataflow: str) -> str:
    if dataflow in ("os", "dos"):
        return "k"
    if dataflow == "ws":
        return "m"
    if dataflow == "is":
        return "n"
    raise ValueError(dataflow)


def fold_geometry(dataflow: str, fold, M: int, K: int, N: int, L: int):
    """(D1, D2, T_serial) of the dataflow under the chosen fold.

    Spelled out case by case (no shared helper with the production
    code): each tier runs the dataflow's own 2D schedule on its slice;
    splitting the contraction dim pays L - 1 serial cross-tier adds.
    """
    if fold is None:
        fold = native_fold(dataflow)
    if dataflow in ("os", "dos"):
        if fold == "k":  # native: K split across tiers + serial adds
            return M, N, ceil_div(K, L) + L - 1
        if fold == "m":  # rows split: independent sub-GEMMs, full K
            return ceil_div(M, L), N, K
        if fold == "n":
            return M, ceil_div(N, L), K
    elif dataflow == "ws":
        if fold == "m":  # native: temporal M split, no vlink traffic
            return N, K, ceil_div(M, L)
        if fold == "k":  # contraction split: dOS-style serial adds
            return N, ceil_div(K, L), M + L - 1
        if fold == "n":
            return ceil_div(N, L), K, M
    elif dataflow == "is":
        if fold == "n":  # native: temporal N split
            return M, K, ceil_div(N, L)
        if fold == "k":
            return M, ceil_div(K, L), N + L - 1
        if fold == "m":
            return ceil_div(M, L), K, N
    raise ValueError(f"unknown fold {fold!r} for dataflow {dataflow!r}")


def per_tier_macs(dataflow: str, fold, M: int, K: int, N: int, L: int):
    """Useful multiply-accumulates each tier performs, from its actual
    (unpadded) slice of the split dimension. Conservation — the sum is
    exactly M*K*N for EVERY fold — is a property test's assertion."""
    if fold is None:
        fold = native_fold(dataflow)
    dim = {"m": M, "k": K, "n": N}[fold]
    chunk = ceil_div(dim, L)
    out = []
    for tier in range(L):
        lo = tier * chunk
        hi = min(lo + chunk, dim)
        span = max(0, hi - lo)
        if fold == "m":
            out.append(span * K * N)
        elif fold == "k":
            out.append(M * span * N)
        else:
            out.append(M * K * span)
    return out


def resolve_vbits(spec: BandwidthSpec, tech: str) -> float:
    """Per-pile vertical bus width [bits/cycle]; '2d' has no links."""
    if tech == "2d":
        return math.inf
    if spec.vlink_bits_per_mac == "derived":
        if tech == "miv":
            return float(C.VLINK_BITS)
        return C.VLINK_BITS / TSV_VLINK_SHARE  # shared TSV bus
    return float(spec.vlink_bits_per_mac)


def _plane_vlink(folds: int, R: int, Cc: int, L: int, ba: int, vbits: float):
    """Partial-sum accumulation down the pile (dOS-style contraction
    split): every fold pushes one R x C accumulator plane across each
    of the L - 1 tier boundaries. Boundaries run concurrently, so the
    service time is ONE boundary's bytes over one boundary's bandwidth.
    """
    if L <= 1:
        return 0.0, 0.0
    total_bytes = 0
    per_boundary_bytes = 0
    for _fold in range(folds):
        for boundary in range(L - 1):
            plane = R * Cc * ba  # one accumulator plane
            total_bytes += plane
            if boundary == 0:  # any one boundary; all carry the same
                per_boundary_bytes += plane
    per_boundary_bw = float(R * Cc) * vbits / 8.0
    return float(total_bytes), float(per_boundary_bytes) / per_boundary_bw


def _stream_vlink(stream_bytes: int, R: int, Cc: int, L: int, vbits: float):
    """Multicast of a shared operand's DRAM stream down the pile
    (output-dim fold): each of the L - 1 boundaries carries one copy
    of the stream; service time is the stream over one boundary."""
    if L <= 1:
        return 0.0, 0.0
    total_bytes = 0
    for _boundary in range(L - 1):
        total_bytes += stream_bytes
    per_boundary_bw = float(R * Cc) * vbits / 8.0
    return float(total_bytes), float(stream_bytes) / per_boundary_bw


def _repeat_bytes(times: int, tensor_bytes: int) -> int:
    """Stream a tensor ``times`` times — charged read by read."""
    total = 0
    for _pass in range(times):
        total += tensor_bytes
    return total


def oracle_traffic(dataflow: str, fold, M, K, N, R, Cc, L, tech: str,
                   spec: BandwidthSpec) -> dict:
    """DRAM bytes, vlink bytes/cycles and SRAM working set of one GEMM
    under one fold — every branch of ``fold_traffic_batched`` (and the
    native ``gemm_traffic_batched``) re-derived with explicit loops."""
    if fold is None:
        fold = native_fold(dataflow)
    bi, ba = spec.bytes_in, spec.bytes_acc
    sram = spec.sram_bytes  # float; may be inf
    vbits = resolve_vbits(spec, tech)

    if dataflow in ("os", "dos"):
        # outputs stationary: accumulators + edge stream buffers resident
        base = R * Cc * ba + 2 * (R + Cc) * bi
        if fold == "k":  # native tier split: per-tier K slice
            Kt = ceil_div(K, L)
            foldM = ceil_div(M, R)
            foldN = ceil_div(N, Cc)
            a_tile = R * Kt * bi  # one fold-row's per-tier A slice
            b_slice = Kt * N * bi  # full per-tier B slice
            reuse_a = float(base + a_tile) <= sram
            reuse_b = reuse_a and float(base + a_tile + b_slice) <= sram
            a_bytes = _repeat_bytes(1 if reuse_a else foldN, M * K * bi)
            b_bytes = _repeat_bytes(1 if reuse_b else foldM, K * N * bi)
            o_bytes = M * N * ba  # written once; accumulation on-chip
            folds = count_folds(M, N, R, Cc)
            v_bytes, v_cycles = _plane_vlink(folds, R, Cc, L, ba, vbits)
            dram = a_bytes + b_bytes + o_bytes
        else:
            a_tile = R * K * bi  # the fold keeps K whole
            if fold == "m":
                Mt = ceil_div(M, L)
                foldMt = ceil_div(Mt, R)  # per-tier row folds (shrunk ~L)
                foldN = ceil_div(N, Cc)
                b_slice = K * N * bi  # B shared whole across tiers
                reuse_a = float(base + a_tile) <= sram
                reuse_b = reuse_a and float(base + a_tile + b_slice) <= sram
                a_bytes = _repeat_bytes(1 if reuse_a else foldN, M * K * bi)
                b_stream = _repeat_bytes(1 if reuse_b else foldMt, K * N * bi)
                o_bytes = M * N * ba
                v_bytes, v_cycles = _stream_vlink(b_stream, R, Cc, L, vbits)
                dram = a_bytes + b_stream + o_bytes
            else:  # fold == "n"
                Nt = ceil_div(N, L)
                foldM = ceil_div(M, R)
                foldNt = ceil_div(Nt, Cc)
                b_slice = K * Nt * bi  # per-tier column slice of B
                reuse_a = float(base + a_tile) <= sram
                reuse_b = reuse_a and float(base + a_tile + b_slice) <= sram
                a_stream = _repeat_bytes(1 if reuse_a else foldNt, M * K * bi)
                b_bytes = _repeat_bytes(1 if reuse_b else foldM, K * N * bi)
                o_bytes = M * N * ba
                v_bytes, v_cycles = _stream_vlink(a_stream, R, Cc, L, vbits)
                dram = a_stream + b_bytes + o_bytes
        return dict(dram_bytes=float(dram), vlink_bytes=v_bytes,
                    vlink_cycles=v_cycles, sram_need_bytes=float(base))

    if dataflow in ("ws", "is"):
        # ws: weights (K x N) stationary, A streams, O accumulates over
        # the ceil(K/C) contraction folds. is: mirror with A <-> B.
        base = R * Cc * bi + 2 * (R * ba + Cc * bi)
        stationary = (K * N if dataflow == "ws" else M * K) * bi
        # the streamed operand is A for ws, B for is; its tensor bytes:
        moving = M * K * bi if dataflow == "ws" else K * N * bi
        if fold == "k":  # contraction split: dOS-style planes
            Kt = ceil_div(K, L)
            foldKt = ceil_div(Kt, Cc)
            if dataflow == "ws":
                fold_sp = ceil_div(N, R)  # spatial folds over rows
                resident = M * Kt * bi  # per-tier K slice of A
                o_tile = M * R * ba
            else:
                fold_sp = ceil_div(M, R)
                resident = N * Kt * bi
                o_tile = N * R * ba
            reuse = float(base + resident) <= sram
            m_bytes = _repeat_bytes(1 if reuse else fold_sp, moving)
            o_fits = float(base + (resident if reuse else 0) + o_tile) <= sram
            o_passes = 1 if o_fits else 2 * foldKt - 1
            o_bytes = _repeat_bytes(o_passes, M * N * ba)
            folds = fold_sp * foldKt
            v_bytes, v_cycles = _plane_vlink(folds, R, Cc, L, ba, vbits)
        elif (dataflow == "ws" and fold == "n") or (
                dataflow == "is" and fold == "m"):
            # output-dim fold: tiers share the WHOLE moving operand
            foldK = ceil_div(K, Cc)
            if dataflow == "ws":
                Nt = ceil_div(N, L)
                fold_sp = ceil_div(Nt, R)  # per-tier spatial folds
                resident = M * K * bi  # every tier consumes all of A
                o_tile = M * R * ba
            else:
                Mt = ceil_div(M, L)
                fold_sp = ceil_div(Mt, R)
                resident = N * K * bi
                o_tile = N * R * ba
            reuse = float(base + resident) <= sram
            m_stream = _repeat_bytes(1 if reuse else fold_sp, moving)
            o_fits = float(base + (resident if reuse else 0) + o_tile) <= sram
            o_passes = 1 if o_fits else 2 * foldK - 1
            o_bytes = _repeat_bytes(o_passes, M * N * ba)
            v_bytes, v_cycles = _stream_vlink(m_stream, R, Cc, L, vbits)
            m_bytes = m_stream
        else:  # native temporal split (ws fold-m / is fold-n)
            foldK = ceil_div(K, Cc)
            if dataflow == "ws":
                Mt = ceil_div(M, L)
                fold_sp = ceil_div(N, R)
                resident = Mt * K * bi
                o_tile = Mt * R * ba
            else:
                Nt = ceil_div(N, L)
                fold_sp = ceil_div(M, R)
                resident = Nt * K * bi
                o_tile = Nt * R * ba
            reuse = float(base + resident) <= sram
            m_bytes = _repeat_bytes(1 if reuse else fold_sp, moving)
            o_fits = float(base + (resident if reuse else 0) + o_tile) <= sram
            o_passes = 1 if o_fits else 2 * foldK - 1
            o_bytes = _repeat_bytes(o_passes, M * N * ba)
            v_bytes, v_cycles = 0.0, 0.0
        return dict(dram_bytes=float(stationary + m_bytes + o_bytes),
                    vlink_bytes=v_bytes, vlink_cycles=v_cycles,
                    sram_need_bytes=float(base))

    raise ValueError(f"unknown dataflow {dataflow!r}")


def oracle_activity(dataflow: str, fold, M, K, N, R, Cc, L):
    """(cycles, mac_ops, h_hops, v_hops) of the power model's activity
    accounting — native dataflows verbatim, non-native folds by the
    fold convention (partial-sum planes vs shared-operand multicast)."""
    nat = fold is None or fold == native_fold(dataflow)
    if not nat:
        D1, D2, T = fold_geometry(dataflow, fold, M, K, N, L)
        folds = count_folds(D1, D2, R, Cc)
        cycles = float((2 * R + Cc + T - 2) * folds)
        if fold == "k":
            v_hops = 0
            for _fold in range(folds):
                for _boundary in range(L - 1):
                    v_hops += R * Cc  # one word plane per boundary
            v_hops = float(v_hops) if L > 1 else 0.0
        else:
            shared_words = K * N if fold == "m" else M * K
            v_hops = 0
            for _boundary in range(L - 1):
                v_hops += shared_words  # one multicast copy
            v_hops = float(v_hops) if L > 1 else 0.0
    elif dataflow in ("os", "dos"):
        kl = ceil_div(K, L)
        folds = count_folds(M, N, R, Cc)
        cycles = float((2 * R + Cc + kl + L - 3) * folds)
        v_hops = float(R * Cc * (L - 1) * folds) if L > 1 else 0.0
    elif dataflow == "ws":
        cycles = float(
            (2 * R + Cc + ceil_div(M, L) - 2) * count_folds(N, K, R, Cc)
        )
        v_hops = 0.0
    else:  # is
        cycles = float(
            (2 * R + Cc + ceil_div(N, L) - 2) * count_folds(M, K, R, Cc)
        )
        v_hops = 0.0
    mac_ops = float(M * N * K)
    return cycles, mac_ops, 2.0 * mac_ops, v_hops


def oracle_power(dataflow: str, fold, M, K, N, R, Cc, L, tech: str) -> dict:
    """Scalar re-derivation of ``array_power_batched`` at (1 GHz, VDD).

    Op-for-op: each component repeats the vectorized association order
    so the floats agree bit-for-bit.
    """
    nat = fold is None or fold == native_fold(dataflow)
    cycles, mac_ops, h_hops, v_hops = oracle_activity(
        dataflow, None if nat else fold, M, K, N, R, Cc, L
    )
    n_per_tier = R * Cc
    n_total = n_per_tier * L
    t_s = cycles / C.FREQ_HZ
    side = math.sqrt(n_per_tier * C.A_MAC_UM2)
    p_base = n_total * (C.P_CLK_LEAK_PER_MAC_W
                        + C.P_WIRE_PER_MAC_PER_UM_W * side)
    p_mac = mac_ops * C.E_MAC_OP_J / t_s
    if dataflow in ("os", "dos") and nat:
        # full-array systolic shift charge (shifting never stops early)
        kl = ceil_div(K, L)
        folds = count_folds(M, N, R, Cc)
        a_hops = min(M, R) * kl * Cc * folds * L
        b_hops = kl * min(N, Cc) * R * folds * L
        p_hop = (a_hops + b_hops) * C.E_HOP_J / t_s
    else:
        p_hop = h_hops * C.E_HOP_J / t_s
    cap = C.C_TSV_F if tech == "tsv" else C.C_MIV_F
    e_bit = 0.5 * cap * C.VDD**2
    n_vbits = n_per_tier * (L - 1) * C.VLINK_BITS
    if L > 1 and tech != "2d" and v_hops > 0:
        p_v = C.ALPHA_V * n_vbits * C.FREQ_HZ * e_bit
    else:
        p_v = 0.0
    total = p_base + p_mac + p_hop + p_v
    peak = total + n_total * C.E_MAC_PEAK_J * C.FREQ_HZ
    return dict(total_w=total, peak_w=peak, static_w=p_base,
                dynamic_w=p_mac + p_hop + p_v, cycles=cycles)


def oracle_price(dataflow: str, M, K, N, R, Cc, L, tech: str,
                 spec: BandwidthSpec, freq_hz=C.FREQ_HZ, vdd_v=C.VDD,
                 fold=None) -> dict:
    """Scalar twin of ``pricing.price_steps`` for one design point."""
    M, K, N, R, Cc, L = (int(x) for x in (M, K, N, R, Cc, L))
    D1, D2, T = fold_geometry(dataflow, fold, M, K, N, L)
    folds = count_folds(D1, D2, R, Cc)
    compute = float(2 * R + Cc + T - 2) * float(folds)
    tr = oracle_traffic(dataflow, fold, M, K, N, R, Cc, L, tech, spec)
    bpc = spec.dram_gbs * 1e9 / freq_hz
    mem = tr["dram_bytes"] / bpc
    total = max(compute, mem, tr["vlink_cycles"])
    stall = total - compute
    if tr["vlink_cycles"] > max(compute, mem):
        bidx = 2
    elif mem > compute:
        bidx = 1
    else:
        bidx = 0
    pw = oracle_power(dataflow, fold, M, K, N, R, Cc, L, tech)
    if not (freq_hz == C.FREQ_HZ and vdd_v == C.VDD):
        sd = (freq_hz / C.FREQ_HZ) * (vdd_v / C.VDD) ** 2
        ss = (vdd_v / C.VDD) ** 2
        static = pw["static_w"] * ss
        dynamic = pw["dynamic_w"] * sd
        total_w = static + dynamic
        peak_w = total_w + (pw["peak_w"] - pw["total_w"]) * sd
        pw = dict(pw, static_w=static, dynamic_w=dynamic,
                  total_w=total_w, peak_w=peak_w)
    energy = (pw["total_w"] * compute + pw["static_w"] * stall) / freq_hz
    return {
        "compute_cycles": compute,
        "mem_cycles": mem,
        "vlink_cycles": tr["vlink_cycles"],
        "total_cycles": total,
        "stall_cycles": stall,
        "bound_idx": bidx,
        "dram_bytes": tr["dram_bytes"],
        "vlink_bytes": tr["vlink_bytes"],
        "sram_need_bytes": tr["sram_need_bytes"],
        "total_w": pw["total_w"],
        "static_w": pw["static_w"],
        "dynamic_w": pw["dynamic_w"],
        "peak_w": pw["peak_w"],
        "tier_w": pw["total_w"] / L,
        "seconds": total / freq_hz,
        "energy_j": energy,
    }
