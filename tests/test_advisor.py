"""The DSE-to-mesh advisor: paper regimes re-emerge at chip level."""

from repro.core.advisor import GemmShard, choose_sharding, score_strategies


def test_decode_large_k_prefers_k_or_n_sharding():
    """Decode GEMMs (tiny M) must not replicate: sharding wins."""
    g = GemmShard(M=8, K=8192, N=8192, axis=16)
    best = choose_sharding(g)
    assert best.name in ("shard_K", "shard_N")
    scores = {s.name: s.total_s for s in score_strategies(g)}
    assert scores[best.name] < scores["replicate"]


def test_train_large_m_prefers_m_sharding():
    g = GemmShard(M=1 << 20, K=4096, N=4096, axis=16)
    assert choose_sharding(g).name == "shard_M"


def test_small_k_disfavors_shard_k():
    """Paper Fig. 5 small-K regime: fine-grained MoE experts (K=1408)
    should not be contraction-sharded 16 ways."""
    g = GemmShard(M=256, K=1408 // 16 * 16, N=2048, axis=16)
    scores = {s.name: s.total_s for s in score_strategies(g)}
    assert scores["shard_K"] >= min(scores["shard_M"], scores["shard_N"])


def test_collective_term_convex_in_axis():
    """Eq. 2's l-term convexity: the dOS collective grows with the axis
    while compute shrinks — there is an interior optimum."""
    times = []
    for ax in (2, 4, 8, 16, 64, 256):
        g = GemmShard(M=64, K=1 << 20, N=64, axis=ax)
        s = {x.name: x for x in score_strategies(g)}["shard_K"]
        times.append(s.total_s)
    # decreasing early (compute-bound), flattening/rising late (collective)
    assert times[1] < times[0]
    assert times[-1] > min(times)


def test_chain_scoring_matches_measured_hillclimb():
    """§Perf closed loop: the chain-aware model must reproduce the
    MEASURED strategy ordering from EXPERIMENTS.md:
      - train shapes:  zero > megatron > dos   (Cell A: 1.71s/6.87s/27.9s)
      - decode shapes: megatron > dos          (Cell B3: 20.9ms vs 27.7ms)
    """
    from repro.core.advisor import score_block_chain

    trn = {s.name: s.total_s for s in score_block_chain(1 << 20, 2048, 11008, 16, 128, 16)}
    assert trn["zero"] < trn["megatron"] < trn["dos"]

    dec = {s.name: s.total_s for s in score_block_chain(128, 8192, 29568, 64, 128, 16)}
    assert dec["megatron"] < dec["dos"] < dec["zero"]
