"""Paper Eqs. 1-2 and the array-shape/tier optimizers (property-based)."""

import numpy as np
import pytest  # noqa: F401

from _hyp import given, settings, st  # property tests skip w/o hypothesis

from repro.core.analytical import (
    mac_threshold, optimal_tiers, optimize_array_2d, optimize_array_3d,
    speedup_3d, tau_2d, tau_3d,
)

dims = st.integers(min_value=1, max_value=2048)
small = st.integers(min_value=1, max_value=64)


def test_eq1_literal():
    # (2R + C + K - 2) * ceil(M/R) * ceil(N/C)
    assert tau_2d(64, 300, 128, 16, 8) == (32 + 8 + 300 - 2) * 4 * 16


def test_eq2_literal():
    assert tau_3d(64, 300, 128, 16, 8, 3) == (32 + 8 + (100 + 2) - 2) * 4 * 16


@given(M=dims, K=dims, N=dims, R=small, C=small)
@settings(max_examples=200, deadline=None)
def test_one_tier_recovers_2d(M, K, N, R, C):
    assert tau_3d(M, K, N, R, C, 1) == tau_2d(M, K, N, R, C)


@given(M=dims, K=dims, N=dims, R=small, C=small, l=st.integers(2, 16))
@settings(max_examples=200, deadline=None)
def test_tau_monotonic_in_k(M, K, N, R, C, l):
    assert tau_3d(M, K + 64, N, R, C, l) >= tau_3d(M, K, N, R, C, l)


@given(M=dims, K=dims, N=dims, n=st.sampled_from([2**10, 2**14, 2**18]),
       l=st.integers(1, 12))
@settings(max_examples=60, deadline=None)
def test_optimizer_respects_budget(M, K, N, n, l):
    plan = optimize_array_3d(M, K, N, n, l)
    assert plan.n_macs_used <= n
    assert plan.tiers == l
    # optimizer never beats the brute tau at its own (R, C)
    assert plan.cycles == tau_3d(M, K, N, plan.rows, plan.cols, l)


def test_paper_headline_speedups():
    """Fig. 5: up to ~9.16x at 12 tiers / 2^18 MACs / K=12100; ~1.93x at
    2 tiers. Our optimizer finds slightly better 2D baselines, so we
    accept a band around the paper's numbers."""
    s12 = speedup_3d(64, 12100, 147, 2**18, 12)
    s2 = speedup_3d(64, 12100, 147, 2**18, 2)
    assert 8.5 <= s12 <= 10.5, s12
    assert 1.8 <= s2 <= 2.1, s2


def test_small_k_small_macs_loses():
    """Paper Sec. IV-A: K=255 with 2^12 MACs -> ~51% performance LOSS."""
    s = speedup_3d(64, 255, 147, 2**12, 12)
    assert s < 0.75, s


@given(M=st.integers(2, 16), N=st.integers(2, 16))
@settings(max_examples=50, deadline=None)
def test_threshold_matches_paper(M, N):
    """3D cannot win when the MAC budget is below M*N (N_min = M*N),
    for large-K workloads (paper Fig. 6). The paper's threshold is an
    empirical statement over smooth sweeps: hypothesis found that for
    *unaligned* M, N (e.g. 9x9, 33x...) 2D fold quantization lets a
    sub-threshold 3D array win by up to ~1.3x — a real refinement of
    the paper's claim, recorded here by testing the aligned regime
    (multiples of 16, as plotted) strictly and documenting the ragged
    exception in EXPERIMENTS.md §Paper."""
    M, N = 16 * M, 16 * N
    n_macs = mac_threshold(M, N) // 2
    s = speedup_3d(M, 8192, N, n_macs, 4)
    assert s <= 1.0 + 1e-9, (M, N, s)


def test_optimal_tiers_grow_with_budget():
    """Fig. 7: larger MAC budgets favor more tiers (median shift)."""
    wl = [(64, 12100, 147), (128, 4096, 2048), (320, 4096, 3072)]
    med = []
    for budget in (2**14, 2**18):
        med.append(np.median([optimal_tiers(m, k, n, budget)[0] for m, k, n in wl]))
    assert med[1] >= med[0]
