"""Bandwidth-aware runtime model: identity, bounds, masks, flips.

The contract under test (ISSUE 5 acceptance criteria):

- uncapped identity: ``evaluate``/``schedule`` with ``bandwidth=None``
  or an unbounded ``BandwidthSpec()`` are bit-for-bit the seed results;
- a pinned memory-bound scenario collapses the 3D-vs-2D speedup below
  the compute-bound prediction (the paper's 9.14x regime);
- the TSV-vs-MIV technology choice is a *bandwidth* distinction on the
  vertical links, not only a capacitance one;
- SRAM capacity joins thermal as a first-class feasibility mask;
- a DRAM cap flips a schedule fixed-design winner AND an advisor
  strategy winner (both pinned);
- the batched artifact roofline (``analysis.roofline``) agrees with
  the scalar properties on its existing fixtures;
- streaming / chunk-caching compose with the bandwidth model without
  changing a bit.
"""

import dataclasses
import json

import numpy as np
import pytest

from repro.core.bandwidth import (
    BOUND_NAMES,
    BandwidthSpec,
    gemm_traffic_batched,
    resolve_vlink_bits,
    roofline_cycles,
)
from repro.core.engine import DesignGrid, PolicyResult, evaluate, optimal_tiers_batched, schedule
from repro.core.network import lower_network
from repro.configs import REGISTRY, SHAPES
from repro.core.study import (
    AnalysisSpec,
    ConstraintSpec,
    SpaceSpec,
    Study,
    StudyResult,
    WorkloadSpec,
)

RN0 = (64, 12100, 147)  # ResNet50 RN0 (Table I) — the paper's headline GEMM
WL = [RN0, (512, 784, 128)]
GRID = DesignGrid.product(WL, (2**14, 2**16, 2**18), range(1, 17))


def _assert_eval_equal(a, b):
    for f in dataclasses.fields(type(a)):
        if f.name == "grid":
            continue
        va, vb = getattr(a, f.name), getattr(b, f.name)
        if va is None and vb is None:
            continue
        assert va is not None and vb is not None, f.name
        np.testing.assert_array_equal(va, vb, err_msg=f.name)


# ---------------------------------------------------------------------------
# Uncapped identity (the seed contract)
# ---------------------------------------------------------------------------

def test_uncapped_spec_is_bit_identical_to_plain_evaluate():
    plain = evaluate(GRID)
    unb = evaluate(GRID, bandwidth=BandwidthSpec())
    for f in ("rows", "cols", "cycles", "cycles_2d", "speedup", "utilization",
              "valid", "area_um2", "power_w", "energy_j", "edp_js", "t_max_c",
              "within_thermal_budget"):
        np.testing.assert_array_equal(
            getattr(plain, f), getattr(unb, f), err_msg=f
        )
    v = unb.valid
    assert np.all(unb.stall_cycles[v] == 0.0)
    assert np.all(unb.bound[v] == "compute")
    assert unb.within_sram_capacity.all()
    np.testing.assert_array_equal(plain.feasible, unb.feasible)


def test_uncapped_schedule_is_bit_identical():
    stream = lower_network(REGISTRY["smollm-135m"], SHAPES["decode_32k"])
    rep0 = schedule(stream, mac_budgets=(2**14, 2**16), tiers=range(1, 9))
    rep1 = schedule(stream, mac_budgets=(2**14, 2**16), tiers=range(1, 9),
                    bandwidth=BandwidthSpec())
    assert rep0.to_dict() == rep1.to_dict()
    assert rep1.fixed.stall_cycles == 0.0
    assert rep1.fixed.bound == "compute"


def test_compute_bound_points_unchanged_under_generous_cap():
    # A finite but generous memory system: every point that stays
    # compute-bound must carry exactly the seed cycles, and — where the
    # 2D baseline is compute-bound too — exactly the seed speedup.
    plain = evaluate(GRID, metrics=("perf",))
    res = evaluate(
        GRID, metrics=("perf",),
        bandwidth=BandwidthSpec(dram_gbs=4096.0, sram_kib_per_tier=1 << 20),
    )
    cb = res.valid & (res.bound == "compute")
    assert cb.any()
    np.testing.assert_array_equal(res.cycles[cb], plain.cycles[cb])
    both = cb & (res.cycles_2d == plain.cycles_2d)
    assert both.any()
    np.testing.assert_array_equal(res.speedup[both], plain.speedup[both])


# ---------------------------------------------------------------------------
# Memory-bound collapse (pinned)
# ---------------------------------------------------------------------------

def test_memory_bound_speedup_collapse_pinned():
    grid = DesignGrid.product([RN0], (2**18,), range(1, 17))
    comp = evaluate(grid, metrics=("perf",))
    # The paper's compute-bound regime: ~9x+ at 2^18 MACs (Fig. 5).
    assert float(np.nanmax(comp.speedup)) > 9.0
    res = evaluate(grid, bandwidth=BandwidthSpec(dram_gbs=8.0,
                                                 sram_kib_per_tier=256.0,
                                                 vlink_bits_per_mac="derived"))
    v = res.valid
    assert np.all(res.bound[v] == "memory")
    # Memory-bound both sides of the 2D/3D comparison: the DRAM floor
    # is (near-)common, so the 9x+ speedup collapses to ~1x.
    assert float(np.nanmax(res.speedup)) <= 1.01
    # cycles are the roofline total: the memory term itself.
    np.testing.assert_allclose(res.cycles[v], res.mem_cycles[v])
    assert np.all(res.stall_cycles[v] > 0)


# ---------------------------------------------------------------------------
# Vertical links: TSV vs MIV is a bandwidth distinction
# ---------------------------------------------------------------------------

def test_vlink_bound_tsv_vs_miv_pinned():
    spec = BandwidthSpec(vlink_bits_per_mac="derived")
    kw = dict(rows=[2], cols=[2], tiers=[4])
    tsv = evaluate(DesignGrid.explicit([(64, 8, 64)], tech="tsv", **kw),
                   bandwidth=spec)
    miv = evaluate(DesignGrid.explicit([(64, 8, 64)], tech="miv", **kw),
                   bandwidth=spec)
    # tau = (2*2 + 2 + (ceil(8/4) + 4 - 1) - 2) * 32 * 32 = 9216 cycles;
    # TSV shared bus: 1024 folds * 16 B / (4 MACs * 17/16 bits / 8)
    assert miv.bound[0, 0] == "compute"
    assert miv.cycles[0, 0] == 9216.0
    assert tsv.bound[0, 0] == "vlink"
    np.testing.assert_allclose(tsv.cycles[0, 0], 1024 * 16 * 16 / 17)
    assert tsv.cycles[0, 0] > miv.cycles[0, 0]


def test_vlink_binds_through_array_search_pinned():
    """The vlink bound survives the engine's own (R, C) search.

    Narrow-TSV/high-tier regime: a 64-MAC budget spread over 8 tiers
    forces tiny per-tier arrays, and the short contraction (K = 8,
    Kt = 1) leaves each dOS fold only ~12 compute cycles against the
    shared TSV bus's ~15-cycle partial-sum drain — the best design the
    search can find is vlink-bound. Same budget on MIV (full-width bus
    per pile) is compute-bound at the same (2, 4) shape, pinning that
    the technology choice alone flips the binding resource.
    """
    spec = BandwidthSpec.paper_default()
    tsv = evaluate(
        DesignGrid.product([(64, 8, 64)], (64,), (8,), dataflow="dos", tech="tsv"),
        bandwidth=spec,
    )
    miv = evaluate(
        DesignGrid.product([(64, 8, 64)], (64,), (8,), dataflow="dos", tech="miv"),
        bandwidth=spec,
    )
    assert tsv.valid[0, 0] and miv.valid[0, 0]
    assert tsv.bound[0, 0] == "vlink"
    assert (int(tsv.rows[0, 0]), int(tsv.cols[0, 0])) == (2, 4)
    # ceil(64/2) * ceil(64/4) = 512 folds x 16 B plane / (8 MACs * 17/16 b / 8)
    np.testing.assert_allclose(tsv.cycles[0, 0], 512 * 16 * 16 / 17)
    assert tsv.stall_cycles[0, 0] == pytest.approx(512 * 16 * 16 / 17 - 7168.0)
    assert miv.bound[0, 0] == "compute"
    assert miv.cycles[0, 0] == 7168.0
    assert float(np.nansum(miv.stall_cycles)) == 0.0


def test_vlink_bound_counts_in_roofline_study():
    """`bound_counts.vlink > 0` end-to-end: the kind='roofline' payload
    (the BENCH_roofline vlink-scenario row) reports vlink-bound points
    under the same narrow-budget/high-tier space."""
    study = Study(
        workload=WorkloadSpec(kind="gemms", gemms=((64, 8, 64), (128, 16, 128))),
        space=SpaceSpec(mac_budgets=(64, 256), tiers=(8, 16),
                        dataflow=("dos",), tech=("tsv",)),
        analysis=AnalysisSpec(kind="roofline", bandwidth=BandwidthSpec.paper_default()),
    )
    counts = study.run().payload["bound_counts"]
    assert counts["vlink"] > 0
    assert counts["compute"] > 0  # regime boundary inside the space


def test_resolve_vlink_bits_derived():
    spec = BandwidthSpec(vlink_bits_per_mac="derived")
    bits = resolve_vlink_bits(spec, np.array(["2d", "tsv", "miv"]))
    assert np.isinf(bits[0])
    assert bits[1] == pytest.approx(17 / 16)
    assert bits[2] == 17.0


# ---------------------------------------------------------------------------
# SRAM capacity: feasibility mask + constraint cap
# ---------------------------------------------------------------------------

def test_sram_capacity_feasibility_mask():
    grid = DesignGrid.explicit([(256, 300, 256)], rows=[16, 64],
                               cols=[16, 64], tiers=[2, 2])
    res = evaluate(grid, bandwidth=BandwidthSpec(sram_kib_per_tier=1.0))
    # 16x16: 512 B plane + 128 B streams fits 1 KiB; 64x64 does not.
    np.testing.assert_array_equal(res.within_sram_capacity[0], [True, False])
    np.testing.assert_array_equal(res.feasible[0], [True, False])
    # and the frontier respects it
    mask = res.pareto_mask(("cycles",))
    assert not mask[0, 1]


def test_constraint_capacity_cap_requires_bandwidth():
    study = Study(
        workload=WorkloadSpec(kind="gemms", gemms=(RN0,)),
        space=SpaceSpec(mac_budgets=(2**16,), tiers=(1, 4)),
        constraints=ConstraintSpec(max_sram_kib_per_tier=16.0),
    )
    with pytest.raises(ValueError, match="bandwidth"):
        study.run()
    ok = dataclasses.replace(
        study, analysis=AnalysisSpec(bandwidth=BandwidthSpec(dram_gbs=256.0))
    )
    payload = ok.run().payload
    assert payload["constraint_mask"].shape == (1, 2)
    need = ok.run().result.sram_need_bytes
    np.testing.assert_array_equal(
        payload["constraint_mask"][0], (need[0] <= 16 * 1024)
    )


# ---------------------------------------------------------------------------
# Pinned winner flips under a DRAM cap
# ---------------------------------------------------------------------------

def test_schedule_fixed_design_flips_under_dram_cap():
    stream = lower_network(REGISTRY["smollm-135m"], SHAPES["decode_32k"])
    kw = dict(mac_budgets=(2**14, 2**16), tiers=range(1, 9))
    rep0 = schedule(stream, **kw)
    repc = schedule(stream, bandwidth=BandwidthSpec(
        dram_gbs=16.0, sram_kib_per_tier=64.0, vlink_bits_per_mac="derived",
    ), **kw)
    np.testing.assert_array_equal(rep0.fixed.design, [128, 256, 2])
    np.testing.assert_array_equal(repc.fixed.design, [128, 64, 8])
    assert repc.fixed.bound == "memory"
    assert repc.fixed.stall_cycles > 0
    # the structural guarantee survives the bandwidth model
    assert repc.fixed.total_cycles >= repc.per_layer.total_cycles


def test_advisor_winner_flips_under_dram_cap():
    def run(bw):
        return Study(
            workload=WorkloadSpec(kind="gemms", gemms=((8, 32768, 1024),)),
            analysis=AnalysisSpec(kind="advise", axis=16, bandwidth=bw),
        ).run().payload["names"]

    assert run(None)[0] == "shard_N"
    assert run(BandwidthSpec(dram_gbs=20.0))[0] == "shard_K"


def test_fig7_tier_optimum_flips_under_dram_cap():
    plain_t, _ = optimal_tiers_batched([RN0], [2**16])
    capped_t, _ = optimal_tiers_batched(
        [RN0], [2**16], bandwidth=BandwidthSpec(dram_gbs=4.0)
    )
    assert plain_t[0, 0] == 13
    assert capped_t[0, 0] == 1


# ---------------------------------------------------------------------------
# Batched == scalar on the legacy artifact-roofline fixtures
# ---------------------------------------------------------------------------

def test_artifact_roofline_batched_matches_scalar_fixtures():
    from repro.analysis.roofline import (
        CollectiveStats,
        roofline_from_artifact,
        roofline_terms_batched,
    )

    # the fixture grid from tests/test_roofline_parse.py, extended with
    # kernel-adjusted and tie cases
    cases = [
        dict(cost={"flops": 197e12, "bytes accessed": 819e9}, wire=50e9, kb=0.0),
        dict(cost={"flops": 98.5e12, "bytes accessed": 2 * 819e9}, wire=1e9, kb=0.0),
        dict(cost={"flops": 197e12, "bytes accessed": 3 * 819e9}, wire=0.0, kb=819e9),
        dict(cost={"flops": 0.0, "bytes accessed": 0.0}, wire=200e9, kb=0.0),
    ]
    rooflines = [
        roofline_from_artifact(
            arch="a", shape="s", mesh_name="m", n_chips=16,
            cost=c["cost"],
            coll=CollectiveStats(wire_bytes=c["wire"], result_bytes=0.0,
                                 counts={}, by_op_bytes={}),
            model_flops=1e15, kernel_bytes=c["kb"],
        )
        for c in cases
    ]
    batched = roofline_terms_batched(
        [r.compute_s for r in rooflines],
        [r.memory_s for r in rooflines],
        [r.collective_s for r in rooflines],
        [r.memory_s_kernel for r in rooflines],
    )
    for i, r in enumerate(rooflines):
        assert batched["dominant"][i] == r.dominant
        assert batched["step_s"][i] == r.step_s


# ---------------------------------------------------------------------------
# Streaming / caching / serialization compose with the bandwidth model
# ---------------------------------------------------------------------------

def test_streamed_bandwidth_evaluate_bit_identical():
    spec = BandwidthSpec.paper_default()
    one = evaluate(GRID, bandwidth=spec)
    streamed = evaluate(GRID, bandwidth=spec, stream=5)
    _assert_eval_equal(one, streamed)


def test_cached_roofline_study_resumes_bit_identical(tmp_path):
    study = Study(
        name="bw-cache",
        workload=WorkloadSpec(kind="gemms", gemms=WL),
        space=SpaceSpec(mac_budgets=(2**14, 2**16), tiers=tuple(range(1, 9))),
        analysis=AnalysisSpec(kind="roofline",
                              bandwidth=BandwidthSpec.paper_default(),
                              chunk=None),
    )
    cold = study.run(cache=tmp_path)
    warm = study.run(cache=tmp_path)
    assert cold.cache["misses"] > 0 and warm.cache["misses"] == 0
    _assert_eval_equal(cold.result, warm.result)
    assert cold.payload["bound_counts"] == warm.payload["bound_counts"]
    # and the artifact round-trips losslessly (bound strings included)
    art = StudyResult.from_json(cold.to_json())
    _assert_eval_equal(art.result, cold.result)


def test_bandwidth_spec_json_roundtrip_and_validation():
    for spec in (BandwidthSpec(), BandwidthSpec.paper_default(),
                 BandwidthSpec(dram_gbs=8.0, vlink_bits_per_mac=4.25)):
        rt = BandwidthSpec.from_dict(json.loads(json.dumps(spec.to_dict())))
        assert rt == spec
    assert BandwidthSpec().unbounded
    assert not BandwidthSpec.paper_default().unbounded
    with pytest.raises(ValueError, match="dram_gbs"):
        BandwidthSpec(dram_gbs=0)
    with pytest.raises(ValueError, match="vlink"):
        BandwidthSpec(vlink_bits_per_mac="huge")
    spec = Study.example("roofline")
    assert Study.from_json(spec.to_json()) == spec
    with pytest.raises(ValueError, match="bandwidth"):
        AnalysisSpec(kind="roofline")


def test_policy_result_backward_compatible_from_dict():
    d = dict(policy="fixed", total_cycles=1.0, time_s=1e-9, energy_j=1.0,
             edp_js=1e-9, total_cycles_2d=2.0, speedup_vs_2d=2.0,
             t_max_c=50.0, utilization=0.5, feasible=True, design=[1, 1, 1])
    p = PolicyResult.from_dict(d)  # pre-bandwidth artifact: defaults apply
    assert p.stall_cycles == 0.0 and p.bound == "compute"


# ---------------------------------------------------------------------------
# Traffic-model internals
# ---------------------------------------------------------------------------

def test_traffic_reuse_levels_monotone_in_sram():
    # more SRAM can only reduce DRAM traffic (reuse is monotone)
    last = None
    for kib in (1e-3, 8, 64, 1024, np.inf):
        tr = gemm_traffic_batched(
            "dos", [512], [4096], [512], [64], [64], [4],
            np.asarray(["tsv"]), BandwidthSpec(sram_kib_per_tier=kib),
        )
        if last is not None:
            assert tr["dram_bytes"][0] <= last
        last = float(tr["dram_bytes"][0])
    # unbounded SRAM -> compulsory traffic only: A + B + 2-byte output
    assert last == 512 * 4096 + 4096 * 512 + 512 * 512 * 2


def test_roofline_cycles_combiner():
    total, stall, idx = roofline_cycles([100.0, 100.0, 100.0],
                                        [50.0, 200.0, 100.0],
                                        [60.0, 150.0, 300.0])
    np.testing.assert_array_equal(total, [100.0, 200.0, 300.0])
    np.testing.assert_array_equal(stall, [0.0, 100.0, 200.0])
    assert [BOUND_NAMES[i] for i in idx] == ["compute", "memory", "vlink"]


def test_vlink_tech_flips_the_best_fold():
    """Pinned TSV-vs-MIV fold flip (ISSUE 10, satellite 2).

    (M, K, N) = (12, 7000, 12) on an os 4x4 array folded across 3
    tiers under the paper-default memory system. Folding the output
    rows (fold-m) trims compute from 21114 to 21030 cycles but emits
    two partial-sum planes per fold. MIV vlinks (17 bits/MAC) drain
    them for free -> fold-m wins; the shared TSV bus (17/16 bits/MAC)
    turns the identical mapping vlink-bound at ~39529 cycles -> the
    native fold-K keeps the win. Same silicon, same workload: the
    bonding technology alone decides the best intra-layer mapping.
    """
    from repro.core.pricing import price_steps

    spec = BandwidthSpec.paper_default()
    args = ("os", np.array([12]), np.array([7000]), np.array([12]),
            np.array([4]), np.array([4]), np.array([3]))

    def cycles(tech, fold):
        pr = price_steps(*args, np.array([tech]), spec, fold=fold)
        return float(pr["total_cycles"][0]), int(pr["bound_idx"][0])

    tsv_native, tsv_nb = cycles("tsv", None)
    tsv_m, tsv_mb = cycles("tsv", "m")
    miv_native, _ = cycles("miv", None)
    miv_m, miv_mb = cycles("miv", "m")

    # pinned absolute cycle counts (bit-exact regression values)
    assert tsv_native == 21114.0 and miv_native == 21114.0
    assert miv_m == 21030.0
    assert tsv_m == pytest.approx(39529.41176470588)
    # the flip itself: strict winners on both technologies
    assert miv_m < miv_native, "MIV must prefer fold-m"
    assert tsv_m > tsv_native, "TSV must keep the native fold-K"
    # and the mechanism: fold-m is vlink-bound on TSV, compute-bound on MIV
    assert tsv_mb == BOUND_NAMES.index("vlink")
    assert miv_mb == BOUND_NAMES.index("compute")
    assert tsv_nb == BOUND_NAMES.index("compute")
