"""Calibration harness: spec round-trips, fit recovery, Study wiring.

Measurement itself (wall-clock) is covered by one tiny smoke row; the
fit and all Study/cache plumbing run on synthetic or monkeypatched
rows so the suite stays timing-independent.
"""

import json

import numpy as np
import pytest

from repro.core.calibrate import (
    CalibrateSpec,
    CalibratedBandwidth,
    fit_rows,
    measure_row,
    run_calibration,
    shape_grid,
)
from repro.core.bandwidth import BandwidthSpec
from repro.core.cache import ResultCache
from repro.core.study import AnalysisSpec, Study, StudyResult, WorkloadSpec


# ---------------------------------------------------------------------------
# Spec
# ---------------------------------------------------------------------------

def test_spec_roundtrip_and_defaults():
    spec = CalibrateSpec(families=("gemm",), preset="smoke", reps=3,
                         warmup=1, holdout_every=3, seed=7)
    assert CalibrateSpec.from_dict(json.loads(json.dumps(spec.to_dict()))) == spec
    assert CalibrateSpec().families == ("gemm", "attention", "ssm")
    # a single family as a bare string normalizes to a tuple
    assert CalibrateSpec(families="ssm").families == ("ssm",)


@pytest.mark.parametrize(
    "kw",
    [
        dict(families=("gemm", "nope")),
        dict(families=()),
        dict(preset="huge"),
        dict(reps=0),
        dict(warmup=-1),
        dict(holdout_every=1),
    ],
)
def test_spec_validation(kw):
    with pytest.raises(ValueError):
        CalibrateSpec(**kw)


def test_shape_grid_holdout_per_family():
    spec = CalibrateSpec(preset="default", holdout_every=4)
    rows = shape_grid(spec)
    for fam in spec.families:
        flags = [r["holdout"] for r in rows if r["family"] == fam]
        assert flags[:4] == [False, False, False, True]
    assert all(r["flops"] > 0 and r["bytes"] > 0 for r in rows)
    # holdout_every=0 disables holdout entirely
    assert not any(r["holdout"] for r in shape_grid(
        CalibrateSpec(preset="default", holdout_every=0)))


# ---------------------------------------------------------------------------
# Fit
# ---------------------------------------------------------------------------

def _synthetic_rows(rates, bw, overhead, noise=0.0, seed=0):
    """Grid rows with t generated from the model itself."""
    spec = CalibrateSpec(preset="default")
    rng = np.random.default_rng(seed)
    rows = []
    for r in shape_grid(spec):
        f = r["family"]
        t = max(r["flops"] / rates[f], r["bytes"] / bw) + overhead[f]
        t *= 1.0 + noise * rng.uniform(-1.0, 1.0)
        d = dict(r)
        d.update(t_s=t, spread_s=0.0, reps=1,
                 achieved_gflops=r["flops"] / t / 1e9,
                 achieved_gbs=r["bytes"] / t / 1e9)
        rows.append(d)
    return spec, rows


def test_fit_recovers_synthetic_parameters():
    rates = {"gemm": 1e11, "attention": 2e10, "ssm": 4e10}
    bw, over = 3e9, {"gemm": 1e-4, "attention": 0.0, "ssm": 0.0}
    spec, rows = _synthetic_rows(rates, bw, over)
    p = fit_rows(rows, spec)
    # exact model in, exact model out: errors collapse
    assert p["errors"]["fit_median_rel_err"] < 0.02
    assert p["errors"]["holdout_median_rel_err"] < 0.05
    assert p["dram_gbs_fitted"] == pytest.approx(bw / 1e9, rel=0.1)
    for f, r in rates.items():
        assert p["rates_flops"][f] == pytest.approx(r, rel=0.1)
    assert p["overhead_s"]["gemm"] == pytest.approx(1e-4, rel=0.3)


def test_fit_beats_uncalibrated_under_noise():
    rates = {"gemm": 8e10, "attention": 3e10, "ssm": 5e10}
    spec, rows = _synthetic_rows(
        rates, 2.5e9, {f: 0.0 for f in rates}, noise=0.05, seed=3
    )
    e = fit_rows(rows, spec)["errors"]
    assert e["holdout_median_rel_err"] <= 0.15
    assert (e["uncalibrated_holdout_median_rel_err"]
            >= 2 * e["holdout_median_rel_err"])


def test_run_calibration_accepts_premeasured_rows():
    rates = {"gemm": 1e11, "attention": 2e10, "ssm": 4e10}
    spec, rows = _synthetic_rows(rates, 3e9, {f: 0.0 for f in rates})
    p1 = run_calibration(spec, measured=rows)
    p2 = run_calibration(spec, measured=rows)
    assert p1["artifact"].to_dict() == p2["artifact"].to_dict()  # deterministic


def test_measure_row_smoke():
    """One real (tiny) measurement: JSON-safe and self-consistent."""
    row = next(r for r in shape_grid(CalibrateSpec(preset="smoke"))
               if r["family"] == "gemm")
    d = measure_row(row, reps=1, warmup=1)
    json.dumps(d, allow_nan=False)  # strict-JSON safe
    assert d["t_s"] > 0 and d["achieved_gflops"] > 0
    assert d["achieved_gflops"] == pytest.approx(
        d["flops"] / d["t_s"] / 1e9)


# ---------------------------------------------------------------------------
# Artifact
# ---------------------------------------------------------------------------

def _artifact():
    return CalibratedBandwidth(
        bandwidth=BandwidthSpec(dram_gbs=2.5),
        efficiency={"gemm": 5e-4, "attention": 1e-4, "ssm": 2e-4},
        peak_flops=197e12,
        diagnostics={"holdout_median_rel_err": 0.1},
    )


def test_artifact_json_roundtrip_exact():
    art = _artifact()
    d = json.loads(json.dumps(art.to_dict()))
    art2 = CalibratedBandwidth.from_dict(d)
    assert art2 == art
    assert art2.to_dict() == art.to_dict()


def test_artifact_efficiency_for_dataflows():
    art = _artifact()
    for df in ("dos", "ws", "is", "os"):
        assert art.efficiency_for(df) == art.efficiency["gemm"]
    assert art.efficiency_for("attention") == art.efficiency["attention"]
    assert CalibratedBandwidth(
        bandwidth=BandwidthSpec(), efficiency={}, peak_flops=1.0
    ).efficiency_for("dos") == 1.0


def test_analysis_spec_unwraps_artifact():
    art = _artifact()
    for bw in (art, art.to_dict()):
        spec = AnalysisSpec(kind="roofline", bandwidth=bw)
        assert isinstance(spec.bandwidth, BandwidthSpec)
        assert spec.bandwidth == art.bandwidth
    # a plain BandwidthSpec dict still decodes as itself
    plain = AnalysisSpec(kind="roofline",
                         bandwidth=BandwidthSpec(dram_gbs=64.0).to_dict())
    assert plain.bandwidth == BandwidthSpec(dram_gbs=64.0)


def test_roofline_study_with_artifact_bit_identical():
    art = _artifact()
    study = Study(
        name="t-cal-roof",
        workload=WorkloadSpec(kind="gemms", gemms=((64, 255, 147),)),
        analysis=AnalysisSpec(kind="roofline", bandwidth=art),
    )
    j1 = study.run().to_json()
    # reload the spec from JSON (artifact already normalized away) and
    # separately re-wrap the artifact from its JSON dict: same bits
    assert Study.from_json(study.to_json()).run().to_json() == j1
    study2 = Study(
        name="t-cal-roof", workload=study.workload,
        analysis=AnalysisSpec(
            kind="roofline",
            bandwidth=json.loads(json.dumps(art.to_dict())),
        ),
    )
    assert study2.run().to_json() == j1


# ---------------------------------------------------------------------------
# Study kind='calibrate' (monkeypatched measurement)
# ---------------------------------------------------------------------------

def _fake_measure(row, *, reps=5, warmup=2, seed=0):
    """Deterministic pseudo-timing: model time for synthetic params."""
    rates = {"gemm": 1e11, "attention": 2e10, "ssm": 4e10}
    t = max(row["flops"] / rates[row["family"]], row["bytes"] / 2.5e9)
    d = dict(row)
    d.update(t_s=t, spread_s=0.0, reps=reps,
             achieved_gflops=row["flops"] / t / 1e9,
             achieved_gbs=row["bytes"] / t / 1e9)
    return d


def test_calibrate_study_end_to_end(monkeypatch, tmp_path):
    calls = []
    monkeypatch.setattr(
        "repro.core.calibrate.measure_row",
        lambda row, **kw: (calls.append(row["label"]), _fake_measure(row, **kw))[1],
    )
    study = Study.example("calibrate")
    assert Study.from_json(study.to_json()) == study  # example round-trips

    cache = ResultCache(tmp_path / "cache")
    res = study.run(cache=cache)
    n = len(calls)
    assert n == len(shape_grid(study.analysis.calibrate))
    assert res.cache["misses"] == n and res.cache["hits"] == 0
    assert isinstance(res.payload["artifact"], CalibratedBandwidth)
    assert "calibrate" in res.describe()

    # resume: all chunks hit, zero re-measurement, identical artifact
    res2 = study.run(cache=ResultCache(tmp_path / "cache"))
    assert len(calls) == n
    assert res2.cache["hits"] == n and res2.cache["misses"] == 0
    # identical artifact modulo the cache hit/miss counters
    assert res2.to_dict()["payload"] == res.to_dict()["payload"]

    # artifact survives the StudyResult JSON round-trip re-typed
    res3 = StudyResult.from_json(res.to_json())
    assert isinstance(res3.payload["artifact"], CalibratedBandwidth)
    assert res3.to_json() == res.to_json()

    # and the reloaded artifact drives a roofline study unchanged
    roof = Study(
        name="t-roof",
        workload=WorkloadSpec(kind="gemms", gemms=((64, 255, 147),)),
        analysis=AnalysisSpec(kind="roofline",
                              bandwidth=res3.payload["artifact"]),
    )
    assert roof.analysis.bandwidth == res.payload["artifact"].bandwidth


def test_calibrate_kind_defaults_spec():
    a = AnalysisSpec(kind="calibrate")
    assert a.calibrate == CalibrateSpec()
    b = AnalysisSpec(kind="calibrate", calibrate={"preset": "smoke"})
    assert b.calibrate.preset == "smoke"
    with pytest.raises(ValueError):
        AnalysisSpec(kind="calibrate", calibrate="smoke")
