"""Registry completeness + parameter-count sanity vs the named sizes."""

import pytest

from repro.configs import REGISTRY, cells, get_config
from repro.models import build

EXPECTED_B = {  # nameplate sizes (rough bands)
    "llama-3.2-vision-11b": (8.5, 11.5),   # text backbone of the 11B (vision stub)
    "smollm-135m": (0.11, 0.16),
    "qwen2.5-3b": (2.6, 3.5),
    "qwen2-72b": (65, 80),
    "gemma3-1b": (0.85, 1.3),
    "whisper-medium": (0.6, 1.0),          # our enc-dec variant
    "zamba2-2.7b": (2.2, 3.1),
    "deepseek-moe-16b": (14, 19),
    "llama4-scout-17b-a16e": (95, 115),    # 17B active / ~109B total
    "xlstm-125m": (0.05, 0.2),   # lean mLSTM blocks, d_ff=0 per assignment
}


def test_all_ten_archs_registered():
    assert len(REGISTRY) == 10


@pytest.mark.parametrize("name", sorted(REGISTRY))
def test_param_counts(name):
    model = build(get_config(name))
    lo, hi = EXPECTED_B[name]
    got = model.n_params / 1e9
    assert lo <= got <= hi, f"{name}: {got:.2f}B not in [{lo},{hi}]"


def test_cells_cover_assignment():
    live, skipped = cells()
    assert len(live) + len(skipped) == 40
    # long_500k runs only for sub-quadratic archs
    longs = [a for a, s in live if s == "long_500k"]
    assert set(longs) == {"gemma3-1b", "zamba2-2.7b", "xlstm-125m"}
    assert len(skipped) == 7
