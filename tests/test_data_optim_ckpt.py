"""Substrate tests: data determinism, optimizer, checkpoint roundtrip."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.data import DataConfig, SyntheticLM
from repro.optim import OptConfig, adamw_update, init_opt_state, schedule
from repro.checkpoint import checkpointer


def test_data_deterministic_and_restart_safe():
    cfg = DataConfig(vocab=101, seq_len=16, global_batch=4, seed=7)
    d1 = SyntheticLM(cfg, process_index=0, process_count=1)
    d2 = SyntheticLM(cfg, process_index=0, process_count=1)
    b1, b2 = d1.batch(5), d2.batch(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(d1.batch(5)["tokens"], d1.batch(6)["tokens"])


def test_data_host_sharding_disjoint():
    cfg = DataConfig(vocab=50, seq_len=8, global_batch=8, seed=1)
    p0 = SyntheticLM(cfg, process_index=0, process_count=2).batch(0)
    p1 = SyntheticLM(cfg, process_index=1, process_count=2).batch(0)
    assert p0["tokens"].shape == (4, 8)
    assert not np.array_equal(p0["tokens"], p1["tokens"])


def test_data_is_learnable_signal():
    cfg = DataConfig(vocab=101, seq_len=64, global_batch=4, seed=0, noise=0.0)
    b = SyntheticLM(cfg, 0, 1).batch(0)
    # labels follow the affine rule from tokens
    np.testing.assert_array_equal(
        b["labels"][:, 0], (5 * b["tokens"][:, 0] + 17) % 101
    )


def test_adamw_decreases_quadratic():
    p = {"w": jnp.ones((4,)) * 5.0}
    s = init_opt_state(p)
    cfg = OptConfig(lr=0.1, warmup_steps=0, total_steps=100, weight_decay=0.0)
    for _ in range(50):
        g = {"w": 2 * p["w"]}  # d/dw (w^2)
        p, s, _ = adamw_update(p, g, s, cfg)
    assert float(jnp.abs(p["w"]).max()) < 1.0


def test_schedule_shape():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    assert float(schedule(cfg, 0)) == 0.0
    assert abs(float(schedule(cfg, 10)) - 1.0) < 0.11
    assert float(schedule(cfg, 100)) <= 0.11


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": np.arange(6).reshape(2, 3), "b": {"c": np.float32(3.5)}}
    checkpointer.save(tmp_path, 7, tree)
    assert checkpointer.latest_step(tmp_path) == 7
    back = checkpointer.restore(tmp_path, 7, tree)
    np.testing.assert_array_equal(back["a"], tree["a"])
    assert back["b"]["c"] == tree["b"]["c"]


def test_checkpoint_retention_and_async(tmp_path):
    tree = {"x": np.ones((4,))}
    for s in (1, 2, 3, 4, 5):
        checkpointer.save(tmp_path, s, tree, keep=2)
    assert checkpointer.latest_step(tmp_path) == 5
    import pathlib
    steps = sorted(p.name for p in pathlib.Path(tmp_path).glob("step_*"))
    assert len(steps) == 2
    t = checkpointer.save_async(tmp_path, 6, tree)
    checkpointer.wait_for_saves()
    assert checkpointer.latest_step(tmp_path) == 6
