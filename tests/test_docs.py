"""Doc-sync check: every fenced ``json`` block in docs/ and README.md
must parse as a Study spec (``Study.from_json``).

This is what keeps the documentation executable: a field rename, a
removed analysis kind, or a changed default that invalidates a
documented spec fails the build here instead of rotting silently. The
convention (stated in docs/study_spec.md): JSON that is *not* a Study
spec uses a different fence language.
"""

import pathlib
import re

import pytest

from repro.core.study import Study

REPO = pathlib.Path(__file__).resolve().parents[1]
_FENCE = re.compile(r"```json\n(.*?)```", re.DOTALL)


def _doc_files():
    files = sorted((REPO / "docs").glob("*.md")) + [REPO / "README.md"]
    return [p for p in files if p.is_file()]


def _json_blocks():
    out = []
    for path in _doc_files():
        for i, m in enumerate(_FENCE.finditer(path.read_text())):
            out.append((f"{path.relative_to(REPO)}#{i}", m.group(1)))
    return out


BLOCKS = _json_blocks()


def test_docs_exist_and_carry_spec_examples():
    names = {p.name for p in _doc_files()}
    assert {"architecture.md", "paper_map.md", "study_spec.md",
            "README.md"} <= names
    # the reference doc must stay example-rich — a vacuous pass (no
    # blocks found, e.g. after a fence-style change) is a failure
    assert len(BLOCKS) >= 7, [b[0] for b in BLOCKS]


@pytest.mark.parametrize("where,text", BLOCKS, ids=[b[0] for b in BLOCKS])
def test_every_doc_json_block_is_a_valid_study_spec(where, text):
    study = Study.from_json(text)
    # and it re-serializes (catches fields that parse but cannot run
    # through the artifact path, e.g. non-JSON-able values)
    assert Study.from_json(study.to_json()) == study
