"""Batched evaluation engine: equivalence to the cycle-level simulator,
bit-for-bit regression against the legacy per-point DSE loops, PPA
batched-vs-scalar consistency, Pareto utility, and the JAX backend.

These tests deliberately avoid hypothesis so they always run under the
tier-1 ``pytest -x -q`` command.
"""

import numpy as np
import pytest

from repro.core.analytical import (
    ArrayPlan,
    mac_threshold,
    optimal_tiers,
    optimize_array_2d,
    optimize_array_3d,
    speedup_3d,
    tau_2d,
    tau_is,
    tau_ws,
)
from repro.core.dse import fig5_sweep, fig6_sweep, fig7_scatter, random_workloads
from repro.core.engine import (
    DesignGrid,
    evaluate,
    optimal_tiers_batched,
    pareto_frontier,
    pareto_mask_batched,
)

WORKLOADS = [(64, 12100, 147), (512, 784, 128), (35, 2560, 4096), (7, 33, 9)]


# ---------------------------------------------------------------------------
# Engine vs cycle-level simulator (ground truth for Eqs. 1-2)
# ---------------------------------------------------------------------------

def test_engine_cycles_match_simulator():
    from repro.core.systolic import simulate_dos_3d, simulate_os_2d

    rng = np.random.default_rng(0)
    cases = [(5, 9, 4, 2, 3, 1), (4, 12, 6, 3, 2, 3), (8, 7, 8, 4, 4, 2)]
    rows = np.array([c[3] for c in cases])
    cols = np.array([c[4] for c in cases])
    tiers = np.array([c[5] for c in cases])
    for i, (M, K, N, R, C, L) in enumerate(cases):
        grid = DesignGrid.explicit([(M, K, N)], rows[i], cols[i], tiers[i])
        res = evaluate(grid, metrics=("perf",))
        A = rng.normal(size=(M, K)).astype(np.float32)
        B = rng.normal(size=(K, N)).astype(np.float32)
        sim = (
            simulate_os_2d(A, B, R, C)
            if L == 1
            else simulate_dos_3d(A, B, R, C, L)
        )
        assert res.cycles[0, 0] == sim.cycles, (M, K, N, R, C, L)
        np.testing.assert_allclose(np.asarray(sim.out), A @ B, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Bit-for-bit regression: engine-backed sweeps == legacy per-point loops
# ---------------------------------------------------------------------------

def _legacy_fig5(mac_budgets, ks, tiers, M=64, N=147, mode="opt"):
    out = {}
    for n in mac_budgets:
        for k in ks:
            out[(n, k)] = [speedup_3d(M, k, N, n, l, mode) for l in tiers]
    return tiers, out


def _legacy_fig6(mac_budgets, ns, ks, M=64, tiers=4, mode="opt"):
    out, thresholds = {}, {}
    for n_dim in ns:
        thresholds[n_dim] = mac_threshold(M, n_dim)
        for k in ks:
            out[(n_dim, k)] = [
                speedup_3d(M, k, n_dim, b, tiers, mode) for b in mac_budgets
            ]
    return mac_budgets, out, thresholds


def test_fig5_matches_legacy_loop():
    budgets, ks, tiers = (2**12, 2**16), (255, 12100), tuple(range(1, 9))
    with pytest.warns(DeprecationWarning, match="fig5_sweep"):
        t_new, out_new = fig5_sweep(budgets, ks, tiers)
    t_old, out_old = _legacy_fig5(budgets, ks, tiers)
    assert t_new == t_old and out_new == out_old


def test_fig6_matches_legacy_loop():
    budgets, ns, ks = tuple(2**p for p in range(10, 15)), (147, 1024), (784,)
    with pytest.warns(DeprecationWarning, match="fig6_sweep"):
        b_new, out_new, th_new = fig6_sweep(budgets, ns, ks)
    b_old, out_old, th_old = _legacy_fig6(budgets, ns, ks)
    assert b_new == b_old and out_new == out_old and th_new == th_old


def test_fig7_matches_legacy_loop():
    budgets = (2**14, 2**16)
    with pytest.warns(DeprecationWarning, match="fig7_scatter"):
        res = fig7_scatter(budgets, n_workloads=40, seed=0, max_tiers=8)
    wl = random_workloads(40, 0)
    for fig7, b in zip(res, budgets):
        legacy = np.array([optimal_tiers(m, k, n, b, 8)[0] for m, k, n in wl])
        assert np.array_equal(fig7.optimal_tiers, legacy)
        assert fig7.median == float(np.median(legacy))


def test_engine_matches_scalar_optimizers():
    budgets, tiers = (2**12, 2**18), range(1, 9)
    grid = DesignGrid.product(WORKLOADS, budgets, tiers)
    res = evaluate(grid, metrics=("perf",))
    for wi, (m, k, n) in enumerate(WORKLOADS):
        for bi, b in enumerate(budgets):
            for ti, l in enumerate(tiers):
                p = bi * 8 + ti
                plan = optimize_array_3d(m, k, n, b, l)
                assert res.rows[wi, p] == plan.rows
                assert res.cols[wi, p] == plan.cols
                assert res.cycles[wi, p] == plan.cycles
                assert res.speedup[wi, p] == speedup_3d(m, k, n, b, l)


def test_optimal_tiers_batched_matches_scalar():
    budgets = (2**14, 2**18)
    best, cyc = optimal_tiers_batched(WORKLOADS, budgets, max_tiers=12)
    for wi, (m, k, n) in enumerate(WORKLOADS):
        for bi, b in enumerate(budgets):
            l, t = optimal_tiers(m, k, n, b, 12)
            assert best[wi, bi] == l and cyc[wi, bi] == t


def test_jax_backend_matches_numpy():
    grid = DesignGrid.product(WORKLOADS, (2**12, 2**16), range(1, 9))
    a = evaluate(grid, backend="numpy", metrics=("perf",))
    b = evaluate(grid, backend="jax", metrics=("perf",))
    assert np.array_equal(a.rows, b.rows)
    assert np.array_equal(a.cols, b.cols)
    assert np.array_equal(a.cycles, b.cycles)
    assert np.array_equal(a.speedup, b.speedup)


def test_chunking_does_not_change_results():
    grid = DesignGrid.product(WORKLOADS, (2**14,), range(1, 9))
    a = evaluate(grid, metrics=("perf",), chunk=3)
    b = evaluate(grid, metrics=("perf",), chunk=10_000)
    assert np.array_equal(a.cycles, b.cycles)
    assert np.array_equal(a.rows, b.rows)


# ---------------------------------------------------------------------------
# All four dataflows
# ---------------------------------------------------------------------------

def test_ws_is_runtime_models():
    # l = 1 literals: fill/drain + temporal dim, folds over spatial dims.
    assert tau_ws(64, 300, 128, 16, 8) == (32 + 8 + 64 - 2) * 8 * 38
    assert tau_is(64, 300, 128, 16, 8) == (32 + 8 + 128 - 2) * 4 * 38
    # Splitting the temporal dim across tiers shortens every fold.
    assert tau_ws(64, 300, 128, 16, 8, 4) < tau_ws(64, 300, 128, 16, 8, 1)
    assert tau_is(64, 300, 128, 16, 8, 4) < tau_is(64, 300, 128, 16, 8, 1)


@pytest.mark.parametrize("dataflow", ["os", "ws", "is", "dos"])
def test_engine_covers_all_dataflows(dataflow):
    grid = DesignGrid.product(
        WORKLOADS[:2], (2**12, 2**14), range(1, 5), dataflow=dataflow
    )
    res = evaluate(grid)
    assert np.all(res.valid)
    assert np.all(np.isfinite(res.cycles))
    assert np.all(res.power_w > 0)
    util = res.utilization
    assert np.all((util > 0) & (util <= 1.0 + 1e-12))
    if dataflow in ("ws", "is"):
        assert np.all(res.vlink_act == 0.0)  # no cross-tier traffic


# ---------------------------------------------------------------------------
# Utilization (ArrayPlan + engine agree)
# ---------------------------------------------------------------------------

def test_array_plan_utilization():
    M, K, N = 128, 300, 128
    plan = optimize_array_3d(M, K, N, 3 * 128 * 128, 3)
    want = (M * K * N) / (plan.n_macs_used * plan.cycles)
    assert plan.utilization == pytest.approx(want)
    assert 0 < plan.utilization <= 1
    # A perfectly filled array at l=1: util -> MN*K / (MN * (2R+C+K-2)).
    p2 = optimize_array_2d(8, 512, 8, 64)
    assert p2.utilization == pytest.approx(
        8 * 512 * 8 / (p2.n_macs_used * p2.cycles)
    )
    # Hand-built plans (no workload attached) stay NaN.
    assert np.isnan(ArrayPlan(8, 8, 1, 100.0, 64).utilization)


def test_engine_utilization_matches_plan():
    grid = DesignGrid.product([(64, 12100, 147)], (2**14,), (1, 4))
    res = evaluate(grid, metrics=("perf",))
    for p, l in enumerate((1, 4)):
        plan = optimize_array_3d(64, 12100, 147, 2**14, l)
        assert res.utilization[0, p] == pytest.approx(plan.utilization)


# ---------------------------------------------------------------------------
# PPA batched entry points == scalar reports; thermal sanity
# ---------------------------------------------------------------------------

def test_power_batched_matches_scalar():
    from repro.core.ppa import array_power, array_power_batched, table2_setup

    setups = list(table2_setup().values())
    batched = array_power_batched(
        np.array([s["M"] for s in setups]),
        np.array([s["K"] for s in setups]),
        np.array([s["N"] for s in setups]),
        np.array([s["rows"] for s in setups]),
        np.array([s["cols"] for s in setups]),
        np.array([s["tiers"] for s in setups]),
        np.array([s["tech"] for s in setups]),
    )
    for i, s in enumerate(setups):
        rep = array_power(**s)
        assert batched["total_w"][i] == rep.total_w
        assert batched["peak_w"][i] == rep.peak_w
        assert batched["cycles"][i] == rep.runtime_cycles


def test_area_batched_matches_scalar():
    from repro.core.ppa import array_area_um2, array_area_um2_batched

    n = np.array([2**14, 2**18, 2**18])
    l = np.array([1, 4, 12])
    tech = np.array(["2d", "tsv", "miv"])
    total, footprint, overhead = array_area_um2_batched(n, l, tech)
    for i in range(3):
        rep = array_area_um2(int(n[i]), int(l[i]), str(tech[i]))
        assert total[i] == rep.total_um2
        assert footprint[i] == rep.footprint_um2
        assert overhead[i] == rep.vlink_overhead


def test_lumped_thermal_trends():
    from repro.core.ppa import lumped_tier_temps
    from repro.core.ppa.constants import T_AMBIENT_C

    # Same total power: a 3-tier stack runs hotter than the 2D die, and
    # upper tiers (far from the heatsink) are hottest; padded = ambient.
    q3 = np.array([[3.0, 3.0, 3.0]])
    q1 = np.array([[9.0, 0.0, 0.0]])
    T3 = lumped_tier_temps(q3, [6.55], [3], ["tsv"], [16384])
    T1 = lumped_tier_temps(q1, [19.7], [1], ["2d"], [49284])
    assert T3[0, 2] >= T3[0, 1] >= T3[0, 0] > T_AMBIENT_C
    assert T3.max() > T1.max()
    assert T1[0, 1] == T1[0, 2] == T_AMBIENT_C  # padded tiers
    # MIV (no via copper) runs hotter than TSV at equal power.
    Tm = lumped_tier_temps(q3, [6.55], [3], ["miv"], [16384])
    assert Tm.max() >= T3.max()


def test_engine_full_metrics_sane():
    grid = DesignGrid.product(WORKLOADS[:2], (2**14, 2**16), range(1, 5))
    res = evaluate(grid)
    v = res.valid
    for name in ("power_w", "energy_j", "t_max_c", "area_um2"):
        arr = getattr(res, name)
        assert np.all(np.isfinite(arr[v])), name
        assert np.all(arr[v] > 0), name
    assert np.all(res.within_thermal_budget[v])
    # energy = power * time
    t_s = res.cycles / 1e9
    np.testing.assert_allclose(res.energy_j, res.power_w * t_s)


# ---------------------------------------------------------------------------
# Pareto utility
# ---------------------------------------------------------------------------

def test_pareto_frontier_basic():
    pts = np.array(
        [[1.0, 2.0], [2.0, 1.0], [2.0, 2.0], [3.0, 3.0], [1.0, 2.0], [np.inf, 0.0]]
    )
    mask = pareto_frontier(pts)
    assert mask.tolist() == [True, True, False, False, True, False]


def _pareto_reference(pts):
    """The pre-vectorization O(n^2) per-point scan — semantics oracle."""
    pts = np.asarray(pts, dtype=np.float64)
    n = len(pts)
    finite = np.isfinite(pts).all(axis=1)
    mask = np.zeros(n, dtype=bool)
    for i in range(n):
        if not finite[i]:
            continue
        dominated = False
        for j in range(n):
            if j == i or not finite[j]:
                continue
            if np.all(pts[j] <= pts[i]) and np.any(pts[j] < pts[i]):
                dominated = True
                break
        mask[i] = not dominated
    return mask


@pytest.mark.parametrize("d", [1, 2, 3, 4])
def test_pareto_mask_batched_matches_reference(d):
    """Bit-identity of the vectorized batched pass (and the sort-based
    2-objective fast path at d == 2) against the O(n^2) oracle, over
    clouds with ties, duplicate rows and non-finite values."""
    rng = np.random.default_rng(d)
    for trial in range(8):
        W, n = int(rng.integers(1, 4)), int(rng.integers(1, 120))
        # coarse integer grid => plenty of exact ties and duplicates
        pts = rng.integers(0, 6, size=(W, n, d)).astype(np.float64)
        if trial % 2:
            bad = rng.random((W, n)) < 0.15
            pts[bad, rng.integers(0, d)] = [np.inf, np.nan][trial % 4 == 1]
        got = pareto_mask_batched(pts)
        want = np.stack([_pareto_reference(pts[w]) for w in range(W)])
        np.testing.assert_array_equal(got, want, err_msg=f"trial {trial}")
        if d == 2:
            for w in range(W):
                np.testing.assert_array_equal(pareto_frontier(pts[w]), want[w])


def test_pareto_frontier_chunked_identical():
    rng = np.random.default_rng(0)
    pts = rng.normal(size=(2, 3000, 3))
    full = pareto_mask_batched(pts)
    np.testing.assert_array_equal(pareto_mask_batched(pts, chunk=17), full)


def test_pareto_2obj_fast_path_matches_general():
    """Lifting 2-obj points with a constant third column leaves the
    dominance relation unchanged, so the O(n log n) sweep must agree
    with the general O(n^2) scan on large tied clouds."""
    rng = np.random.default_rng(1)
    pts = np.round(rng.normal(size=(3, 4000, 2)), 1)  # heavy ties
    lifted = np.concatenate([pts, np.zeros_like(pts[..., :1])], axis=-1)
    np.testing.assert_array_equal(
        pareto_mask_batched(pts), pareto_mask_batched(lifted)
    )


def test_pareto_mask_on_grid():
    grid = DesignGrid.product([(64, 12100, 147)], (2**12, 2**14, 2**16), range(1, 9))
    res = evaluate(grid)
    mask = res.pareto_mask(("cycles", "area_um2", "power_w"))
    assert mask.shape == res.cycles.shape
    assert 0 < mask.sum() <= mask.size
    # every dominated point is beaten somewhere on all three axes
    front = np.stack(
        [res.cycles[mask], res.area_um2[mask], res.power_w[mask]], axis=1
    )
    dom = np.stack(
        [res.cycles[~mask], res.area_um2[~mask], res.power_w[~mask]], axis=1
    )
    for d in dom[np.isfinite(dom).all(1)]:
        assert np.any((front <= d).all(1) & (front < d).any(1))


# ---------------------------------------------------------------------------
# Advisor routes through the engine
# ---------------------------------------------------------------------------

def test_rank_candidates_matches_scalar_advisor():
    from repro.core.advisor import GemmShard, choose_sharding, rank_candidates

    wl = [(8, 8192, 8192), (1 << 20, 4096, 4096), (128, 256, 512), (64, 64, 64)]
    with pytest.warns(DeprecationWarning, match="rank_candidates"):
        names, totals = rank_candidates(wl, 16)
    assert totals.shape == (4, 4)
    for i, (m, k, n) in enumerate(wl):
        best = choose_sharding(GemmShard(M=m, K=k, N=n, axis=16))
        assert names[i] == best.name
        assert totals[i].min() == pytest.approx(best.total_s)


def test_optimize_rc_batched_matches_scalar():
    from repro.core.analytical import INVALID_CYCLES, optimize_rc_batched

    M = np.array([64, 512, 35, 8])
    K = np.array([12100, 784, 2560, 8])
    N = np.array([147, 128, 4096, 8])
    for b, l in [(2**14, 1), (2**16, 3), (2**18, 12)]:
        r, c, t = optimize_rc_batched(M, K, N, b, l)
        for i in range(4):
            plan = optimize_array_3d(int(M[i]), int(K[i]), int(N[i]), b, l)
            assert (r[i], c[i], float(t[i])) == (plan.rows, plan.cols, plan.cycles)
    # broadcasting + invalid budget sentinel
    r, c, t = optimize_rc_batched(8, 8, 8, np.array([4, 64]), np.array([8, 2]))
    assert t[0] == INVALID_CYCLES and t[1] != INVALID_CYCLES


def test_design_grid_broadcasts_point_fields():
    # scalar tiers x vector budgets (and the reverse) must both work.
    g = DesignGrid(workloads=[(64, 100, 64)], tiers=4, mac_budgets=[2**14, 2**16])
    assert g.n_points == 2 and g.tiers.tolist() == [4, 4]
    g2 = DesignGrid(workloads=[(64, 100, 64)], tiers=[1, 2, 4], mac_budgets=2**14)
    assert g2.n_points == 3 and g2.mac_budgets.tolist() == [2**14] * 3
    assert np.array_equal(
        evaluate(g2, metrics=("perf",)).cycles,
        evaluate(
            DesignGrid.product([(64, 100, 64)], [2**14], [1, 2, 4]),
            metrics=("perf",),
        ).cycles,
    )
    with pytest.raises(ValueError, match="incompatible lengths"):
        DesignGrid(workloads=[(1, 2, 3)], tiers=[1, 2], mac_budgets=[1, 2, 3])


def test_invalid_points_masked():
    # per-tier budget < 1 -> invalid, inf cycles, NaN downstream.
    grid = DesignGrid.product([(8, 8, 8)], (4,), (2, 8, 16))
    res = evaluate(grid)
    assert res.valid[0].tolist() == [True, False, False]
    assert np.isinf(res.cycles[0, 1]) and np.isnan(res.speedup[0, 2])
