"""Smoke-run every ``examples/*.py`` so the documented entry points
cannot rot (each with its fastest flags; a failing example is a doc
bug, not just an example bug — README and docs/ link to all of them).
"""

import pathlib
import subprocess
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parents[1]

#: every example and its CI-fast invocation. Adding an example without
#: registering it here fails test_all_examples_are_covered.
EXAMPLES = {
    "quickstart.py": ["--smoke"],
    "dse_explore.py": ["--m", "64", "--k", "2048", "--n", "147", "--pareto"],
    "network_explore.py": ["--arch", "smollm-135m", "--shape", "decode_32k"],
    "serve_decode.py": ["--arch", "smollm-135m", "--gen-tokens", "8"],
    "train_lm.py": ["--steps", "3", "--smoke"],
}


def _run(name, args):
    env = {"PYTHONPATH": str(REPO / "src")}
    import os

    env = {**os.environ, **env}
    return subprocess.run(
        [sys.executable, str(REPO / "examples" / name), *args],
        capture_output=True, text=True, timeout=600, env=env, cwd=REPO,
    )


def test_all_examples_are_covered():
    on_disk = {p.name for p in (REPO / "examples").glob("*.py")}
    assert on_disk == set(EXAMPLES), (
        "examples/ and the smoke registry drifted — register the new "
        "example (with fast flags) in tests/test_examples.py"
    )


@pytest.mark.parametrize("name,args", EXAMPLES.items(), ids=list(EXAMPLES))
def test_example_runs_clean(name, args):
    proc = _run(name, args)
    assert proc.returncode == 0, (
        f"{name} {' '.join(args)} failed:\n{proc.stdout[-2000:]}\n"
        f"{proc.stderr[-2000:]}"
    )
    assert proc.stdout.strip(), f"{name} printed nothing"


def test_network_explore_spec_flag_emits_runnable_spec():
    # --spec prints Study JSON; it must parse and round-trip (the same
    # contract the docs doc-sync check enforces for written specs)
    proc = _run("network_explore.py",
                ["--arch", "smollm-135m", "--shape", "decode_32k", "--spec"])
    assert proc.returncode == 0, proc.stderr[-2000:]
    sys.path.insert(0, str(REPO / "src"))
    from repro.core.study import Study

    study = Study.from_json(proc.stdout)
    assert study.workload.arch == "smollm-135m"
