"""Fault tolerance: injected failures, restart-resume, straggler watchdog."""

import numpy as np
import pytest

from repro.configs import REGISTRY, reduced
from repro.runtime import FaultInjector, StragglerWatchdog
from repro.launch.train import train_loop


def test_restart_resumes_from_checkpoint(tmp_path):
    cfg = reduced(REGISTRY["smollm-135m"])
    inj = FaultInjector(fail_at_steps=(12,))
    state, losses, _ = train_loop(
        cfg, steps=16, global_batch=2, seq_len=32,
        ckpt_dir=str(tmp_path), ckpt_every=5,
        fault_injector=inj, log_every=100,
    )
    # the injected failure fired and the loop still completed 16 steps
    assert 12 in inj.fired
    # steps 0..11 then resume from ckpt@10: 10..15 -> more than 16 recorded
    assert len(losses) >= 16
    assert np.isfinite(losses).all()


def test_loss_decreases_smoke():
    cfg = reduced(REGISTRY["smollm-135m"])
    _, losses, _ = train_loop(
        cfg, steps=40, global_batch=4, seq_len=64, log_every=100,
    )
    assert losses[-1] < losses[0] - 0.5, (losses[0], losses[-1])


def test_straggler_watchdog_flags_slow_step():
    import time

    wd = StragglerWatchdog(factor=3.0, warmup=3)
    for i in range(6):
        wd.start_step()
        time.sleep(0.01)
        wd.end_step(i)
    wd.start_step()
    time.sleep(0.2)
    assert wd.end_step(99) is True
    assert 99 in wd.slow_steps
