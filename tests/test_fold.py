"""Fine-grain tier-folded mapping: differential harness + properties.

The contract under test (ISSUE 10 acceptance criteria):

- **Differential**: the deliberately slow scalar oracle
  (``oracle_fold.py`` — explicit per-tier / per-fold / per-boundary
  loops, Python-int accounting) agrees **bit-for-bit** with the
  vectorized ``pricing.price_steps`` fold path on a dense grid of
  > 1k (workload, design, dataflow, fold, tech, spec) points, at the
  reference clock and at a DVFS-governed operating point.
- **tier_fold <= fixed** on every zoo cell: the fixed policy's native
  mapping is always in the fold candidate set, so the per-layer fold
  argmin can never lose to it (native wins ties).
- **L = 1 equality**: on single-tier grids every fold degenerates to
  the native 2D schedule — tier_fold == fixed exactly.
- **Conservation**: any fold partitions, never duplicates, the useful
  work (per-tier MAC sums == M*K*N) and leaves compulsory DRAM
  traffic untouched under unbounded SRAM.
- The schedule report carries the fold assignment (``by_layer`` +
  ``residency``) and round-trips through JSON.
"""

import numpy as np
import pytest
from _hyp import given, settings, st

from oracle_fold import oracle_price, per_tier_macs
from repro.core.analytical import FOLD_NAMES, fold_dims, native_fold
from repro.core.bandwidth import BandwidthSpec, fold_traffic_batched
from repro.core.engine import DesignGrid, NetworkReport, evaluate, schedule
from repro.core.network import lower_zoo
from repro.core.pricing import DvfsSpec, price_steps
from repro.core.ppa import constants as C

DATAFLOWS = ("os", "dos", "ws", "is")
FOLDS = (None,) + FOLD_NAMES

#: modest sizes — the oracle is deliberately O(folds * tiers) slow.
WORKLOADS = [(1, 64, 64), (7, 300, 13), (128, 300, 128),
             (33, 257, 65), (192, 1024, 96), (512, 129, 256)]
SHAPES_RC = [(8, 8), (16, 4), (32, 32), (4, 64)]
TIERS = [1, 2, 4, 8]

SPECS = [
    BandwidthSpec.paper_default(),
    # tight SRAM: exercises every spill branch of the reuse model
    BandwidthSpec(dram_gbs=64.0, sram_kib_per_tier=16.0,
                  vlink_bits_per_mac="derived"),
]

PRICE_KEYS = (
    "compute_cycles", "mem_cycles", "vlink_cycles", "total_cycles",
    "stall_cycles", "bound_idx", "dram_bytes", "vlink_bytes",
    "sram_need_bytes", "total_w", "static_w", "dynamic_w", "peak_w",
    "tier_w", "seconds", "energy_j",
)

_POINTS = [(M, K, N, R, Cc, L)
           for (M, K, N) in WORKLOADS
           for (R, Cc) in SHAPES_RC
           for L in TIERS]


def _assert_oracle_matches(spec, dataflow, fold, tech, freq_hz, vdd_v):
    arr = np.asarray(_POINTS, dtype=np.int64)
    pr = price_steps(
        dataflow, arr[:, 0], arr[:, 1], arr[:, 2], arr[:, 3], arr[:, 4],
        arr[:, 5], np.full(len(_POINTS), tech), spec, freq_hz, vdd_v,
        fold=fold,
    )
    for i, (M, K, N, R, Cc, L) in enumerate(_POINTS):
        o = oracle_price(dataflow, M, K, N, R, Cc, L, tech, spec,
                         freq_hz, vdd_v, fold=fold)
        for k in PRICE_KEYS:
            v = float(np.asarray(pr[k]).reshape(-1)[i])
            ok = o[k] == v or (np.isnan(o[k]) and np.isnan(v))
            assert ok, (
                f"{dataflow}/{fold}/{tech} {(M, K, N, R, Cc, L)} {k}: "
                f"oracle {o[k]!r} != vectorized {v!r}"
            )


# ---------------------------------------------------------------------------
# Differential: oracle vs vectorized, bit-for-bit (> 1k points per case)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", SPECS, ids=["paper", "tight-sram"])
@pytest.mark.parametrize("dataflow", DATAFLOWS)
@pytest.mark.parametrize("fold", FOLDS, ids=["native", "m", "k", "n"])
@pytest.mark.parametrize("tech", ["tsv", "miv"])
def test_oracle_differential(spec, dataflow, fold, tech):
    """96 points per case x 64 cases = 6144 bit-for-bit comparisons of
    every ``price_steps`` output key at the reference clock."""
    _assert_oracle_matches(spec, dataflow, fold, tech, C.FREQ_HZ, C.VDD)


@pytest.mark.parametrize("dataflow", DATAFLOWS)
@pytest.mark.parametrize("fold", FOLDS, ids=["native", "m", "k", "n"])
def test_oracle_differential_dvfs_point(dataflow, fold):
    """The same bit-identity holds at the governor's lowest (f, V)
    operating point — fold pricing and DVFS scaling compose."""
    d = DvfsSpec()
    _assert_oracle_matches(BandwidthSpec.paper_default(), dataflow, fold,
                           "tsv", float(d.freqs_hz()[0]), d.vdds_v[0])


def test_oracle_2d_unbounded_identity():
    """tech='2d' (no vertical links, L = 1) and the unbounded spec:
    stall-free, compute-bound, oracle still exact."""
    spec = BandwidthSpec()
    for df in DATAFLOWS:
        for (M, K, N) in WORKLOADS[:3]:
            pr = price_steps(df, np.array([M]), np.array([K]), np.array([N]),
                             np.array([16]), np.array([16]), np.array([1]),
                             np.array(["2d"]), spec)
            o = oracle_price(df, M, K, N, 16, 16, 1, "2d", spec)
            assert o["stall_cycles"] == 0.0 and o["bound_idx"] == 0
            for k in PRICE_KEYS:
                assert o[k] == float(np.asarray(pr[k]).reshape(-1)[0]), (df, k)


# ---------------------------------------------------------------------------
# Theorems: tier_fold <= fixed; equality at L = 1
# ---------------------------------------------------------------------------

ZOO = lower_zoo(shapes=("decode_32k", "train_4k"))
BW_CASES = [
    BandwidthSpec(dram_gbs=256.0, sram_kib_per_tier=1024.0),  # infinite vlink
    BandwidthSpec.paper_default(),
]


@pytest.mark.parametrize("bw", BW_CASES, ids=["inf-vlink", "paper"])
def test_tier_fold_never_loses_to_fixed_across_zoo(bw):
    """On EVERY zoo cell the tier_fold policy is at least as fast as
    fixed: the fixed design's native mapping is in the candidate set,
    so the per-layer argmin can only improve on it. Holds with
    unbounded vlinks (the ISSUE's stated property) and under the
    paper-default memory system alike."""
    for stream in ZOO:
        rep = schedule(stream, mac_budgets=(2**14,), tiers=range(1, 9),
                       bandwidth=bw,
                       policies=("per_layer", "fixed", "tier_fold"))
        assert rep.tier_fold is not None
        assert rep.tier_fold.total_cycles <= rep.fixed.total_cycles, (
            stream.arch, stream.shape)
        # the fold report aligns with the stream and sums to one
        assert len(rep.fold["by_layer"]) == len(stream.layer_names)
        assert set(rep.fold["by_layer"]) <= set(FOLD_NAMES)
        assert sum(rep.fold["residency"].values()) == pytest.approx(1.0)


def test_tier_fold_equals_fixed_on_single_tier_grid():
    """tiers == (1,): every fold degenerates to the native 2D schedule
    (fold_dims is the identity there), so tier_fold == fixed exactly
    and the winning design matches."""
    stream = ZOO[0]
    rep = schedule(stream, mac_budgets=(2**12, 2**14), tiers=(1,),
                   bandwidth=BandwidthSpec.paper_default(),
                   policies=("per_layer", "fixed", "tier_fold"))
    assert rep.tier_fold.total_cycles == rep.fixed.total_cycles
    assert np.array_equal(np.asarray(rep.tier_fold.design),
                          np.asarray(rep.fixed.design))
    # every layer reports the dataflow's native fold
    assert set(rep.fold["by_layer"]) == {native_fold("dos")}


def test_fold_dims_degenerate_at_one_tier():
    """fold_dims(fold, ..., tiers=1) == the native dims for all 12
    (dataflow, fold) combinations."""
    M, K, N = np.array([33]), np.array([257]), np.array([65])
    one = np.array([1])
    for df in DATAFLOWS:
        nat = fold_dims(None, df, M, K, N, one)
        for fold in FOLD_NAMES:
            got = fold_dims(fold, df, M, K, N, one)
            for a, b in zip(nat, got):
                assert np.array_equal(a, b), (df, fold)


# ---------------------------------------------------------------------------
# Conservation properties (hypothesis)
# ---------------------------------------------------------------------------

dims = st.integers(min_value=1, max_value=512)
tiers_st = st.integers(min_value=1, max_value=12)


@given(M=dims, K=dims, N=dims, L=tiers_st,
       df=st.sampled_from(DATAFLOWS), fold=st.sampled_from(FOLD_NAMES))
@settings(max_examples=60, deadline=None)
def test_fold_conserves_flops(M, K, N, L, df, fold):
    """Any fold partitions the GEMM: the per-tier useful-MAC slices
    (actual, unpadded spans) sum to exactly M*K*N."""
    assert sum(per_tier_macs(df, fold, M, K, N, L)) == M * K * N


@given(M=dims, K=dims, N=dims, L=tiers_st, R=st.integers(1, 64),
       Cc=st.integers(1, 64), df=st.sampled_from(DATAFLOWS),
       fold=st.sampled_from((None,) + FOLD_NAMES),
       tech=st.sampled_from(("tsv", "miv")))
@settings(max_examples=60, deadline=None)
def test_fold_conserves_compulsory_dram_bytes(M, K, N, L, R, Cc, df, fold,
                                              tech):
    """With unbounded SRAM every fold's DRAM traffic is exactly the
    compulsory floor — read A and B once, write O once. Folding moves
    traffic between the planar network and the vertical links; it
    never conjures DRAM bytes."""
    spec = BandwidthSpec()  # unbounded SRAM: perfect reuse everywhere
    tr = fold_traffic_batched(
        fold, df, np.array([M]), np.array([K]), np.array([N]),
        np.array([R]), np.array([Cc]), np.array([L]),
        np.array([tech]), spec,
    )
    compulsory = (M * K + K * N) * spec.bytes_in + M * N * spec.bytes_acc
    assert float(tr["dram_bytes"][0]) == float(compulsory)


@given(M=st.integers(1, 256), K=st.integers(1, 256), N=st.integers(1, 256),
       L=st.integers(2, 8))
@settings(max_examples=40, deadline=None)
def test_nonnative_fold_vlink_traffic_positive(M, K, N, L):
    """A non-native fold on a multi-tier stack always pays vertical
    traffic (partial-sum planes or operand multicast) — the cost the
    tier_fold policy trades against its fold-count win."""
    spec = BandwidthSpec.paper_default()
    for df in DATAFLOWS:
        for fold in FOLD_NAMES:
            if fold == native_fold(df):
                continue
            tr = fold_traffic_batched(
                fold, df, np.array([M]), np.array([K]), np.array([N]),
                np.array([8]), np.array([8]), np.array([L]),
                np.array(["tsv"]), spec,
            )
            assert float(tr["vlink_bytes"][0]) > 0, (df, fold)
            assert float(tr["vlink_cycles"][0]) > 0, (df, fold)


# ---------------------------------------------------------------------------
# Engine integration: fold as a DesignGrid axis; report round-trip
# ---------------------------------------------------------------------------

def test_fold_axis_at_native_is_identity_through_evaluate():
    """A grid pinned to each dataflow's native fold evaluates
    bit-identical to the unfolded grid."""
    wl = [(128, 300, 128), (7, 300, 13)]
    for df in DATAFLOWS:
        base = DesignGrid.product(wl, (2**12, 2**14), (1, 2, 4),
                                  dataflow=df, tech="tsv")
        folded = DesignGrid.product(wl, (2**12, 2**14), (1, 2, 4),
                                    dataflow=df, tech="tsv",
                                    fold=native_fold(df))
        bw = BandwidthSpec.paper_default()
        a = evaluate(base, bandwidth=bw)
        b = evaluate(folded, bandwidth=bw)
        np.testing.assert_array_equal(a.cycles, b.cycles, err_msg=df)
        np.testing.assert_array_equal(a.energy_j, b.energy_j, err_msg=df)
        np.testing.assert_array_equal(a.stall_cycles, b.stall_cycles,
                                      err_msg=df)


def test_schedule_rejects_unknown_policy_and_requires_baselines():
    stream = ZOO[0]
    with pytest.raises(ValueError, match="policy"):
        schedule(stream, mac_budgets=(2**12,), tiers=(1, 2),
                 policies=("per_layer", "fixed", "bogus"))
    with pytest.raises(ValueError, match="per_layer"):
        schedule(stream, mac_budgets=(2**12,), tiers=(1, 2),
                 policies=("fixed",))


def test_network_report_fold_roundtrip():
    """to_dict/from_dict keep the tier_fold policy + fold assignment;
    pre-fold dicts (no tier_fold key) still load."""
    stream = ZOO[0]
    rep = schedule(stream, mac_budgets=(2**14,), tiers=range(1, 5),
                   bandwidth=BandwidthSpec.paper_default(),
                   policies=("per_layer", "fixed", "tier_fold"))
    d = rep.to_dict()
    back = NetworkReport.from_dict(d)
    assert back.tier_fold.total_cycles == rep.tier_fold.total_cycles
    assert back.fold == rep.fold
    # backward compat: a pre-fold artifact lacks the keys entirely
    legacy = {k: v for k, v in d.items() if k not in ("tier_fold", "fold")}
    old = NetworkReport.from_dict(legacy)
    assert old.tier_fold is None and old.fold is None
    assert old.fixed.total_cycles == rep.fixed.total_cycles
