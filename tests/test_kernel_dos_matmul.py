"""dOS matmul Pallas kernel vs pure-jnp oracle (interpret mode)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.dos_matmul import dos_matmul, dos_matmul_ref, pick_blocks

SHAPES = [
    (128, 256, 128), (256, 512, 384), (100, 300, 77), (8, 8192, 128),
    (1, 512, 512), (384, 128, 1024),
]


@pytest.mark.parametrize("m,k,n", SHAPES)
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_matches_oracle(m, k, n, dtype):
    rng = np.random.default_rng(m * 1000 + k + n)
    a = jnp.asarray(rng.normal(size=(m, k)), dtype=dtype)
    b = jnp.asarray(rng.normal(size=(k, n)), dtype=dtype)
    ref = np.asarray(dos_matmul_ref(a, b, out_dtype="float32"))
    out = np.asarray(dos_matmul(a, b, interpret=True, out_dtype="float32"))
    tol = 2e-2 if dtype == "bfloat16" else 1e-4
    np.testing.assert_allclose(out, ref, rtol=tol, atol=tol * np.abs(ref).max())


def test_tier_accumulation_order():
    """Tier-split accumulation equals the monolithic product (f32)."""
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(64, 512)), dtype="float32")
    b = jnp.asarray(rng.normal(size=(512, 64)), dtype="float32")
    want = np.asarray(a) @ np.asarray(b)
    for tiers in (1, 2, 4, 8):
        out = np.asarray(dos_matmul_ref(a, b, n_tiers=tiers, out_dtype="float32"))
        # tier-split changes f32 summation order; tolerance scales with
        # the output magnitude (cancellation makes rtol misleading).
        np.testing.assert_allclose(out, want, rtol=1e-3, atol=1e-4 * np.abs(want).max())


def test_batched_lead_dims():
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.normal(size=(2, 16, 128)), dtype="float32")
    b = jnp.asarray(rng.normal(size=(128, 64)), dtype="float32")
    out = np.asarray(dos_matmul(a, b, interpret=True))
    ref = np.einsum("bik,kn->bin", np.asarray(a), np.asarray(b))
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


def test_pick_blocks_vmem_budget():
    bm, bn, bk = pick_blocks(4096, 4096, 8192)
    assert bm % 8 == 0 and bn % 128 == 0
    assert 2 * (bm * bk + bk * bn) + 4 * bm * bn <= 8 * 2**20


@pytest.mark.parametrize("m,n", [(4096, 128), (128, 4096)])
def test_pick_blocks_rectangular_for_skewed(m, n):
    """Tall/wide GEMMs get a rectangular tile: the long output dim's
    block grows past 128 while staying in the VMEM budget."""
    bm, bn, bk = pick_blocks(m, n, 4096)
    long_block = bm if m > n else bn
    assert long_block > 128
    assert bm % 8 == 0 and bn % 128 == 0 and bk % 128 == 0
    assert 2 * (bm * bk + bk * bn) + 4 * bm * bn <= 8 * 2**20
    # square stays square
    assert pick_blocks(4096, 4096, 4096)[:2] == (128, 128)


@pytest.mark.parametrize(
    "m,k,n",
    [
        (1, 17, 64),    # every dim degenerate
        (1, 512, 512),  # M=1: below the min sublane tile
        (64, 17, 256),  # K=17: below the min contraction tile
        (512, 512, 4),  # N below the min lane tile
    ],
)
def test_degenerate_shapes_dispatch_to_ref(m, k, n):
    """Dims below the minimum Pallas tile must take the reference path
    (even under interpret=True) and still match numpy — the padded
    kernel would be near-all zeros for these."""
    rng = np.random.default_rng(m + k + n)
    a = jnp.asarray(rng.normal(size=(m, k)), dtype="float32")
    b = jnp.asarray(rng.normal(size=(k, n)), dtype="float32")
    out = np.asarray(dos_matmul(a, b, interpret=True, out_dtype="float32"))
    want = np.asarray(a, np.float64) @ np.asarray(b, np.float64)
    np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-4 * max(1.0, np.abs(want).max()))
