"""Flash attention: Pallas kernel + chunked custom-VJP twin vs oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import attention_ref, decode_attention, flash_attention
from repro.kernels.flash_attention.ops import flash_attention_jnp

CASES = [
    # b, sq, skv, h, kvh, d, causal, window
    (2, 256, 256, 4, 2, 64, True, None),
    (1, 128, 128, 8, 1, 64, True, 128),
    (2, 256, 512, 4, 4, 32, False, None),  # cross
    (1, 384, 384, 2, 2, 128, True, 64),  # sliding window
]


def _mk(b, sq, skv, h, kvh, d, dtype, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, sq, h, d)), dtype=dtype)
    k = jnp.asarray(rng.normal(size=(b, skv, kvh, d)), dtype=dtype)
    v = jnp.asarray(rng.normal(size=(b, skv, kvh, d)), dtype=dtype)
    return q, k, v


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_pallas_kernel_interpret(case, dtype):
    b, sq, skv, h, kvh, d, causal, window = case
    q, k, v = _mk(b, sq, skv, h, kvh, d, dtype)
    ref = np.asarray(attention_ref(q, k, v, causal=causal, window=window), np.float32)
    out = np.asarray(
        flash_attention(q, k, v, causal=causal, window=window, interpret=True),
        np.float32,
    )
    tol = 3e-2 if dtype == "bfloat16" else 1e-4
    np.testing.assert_allclose(out, ref, rtol=tol, atol=tol)


@pytest.mark.parametrize("case", CASES)
def test_chunked_jnp_forward_and_grads(case):
    b, sq, skv, h, kvh, d, causal, window = case
    q, k, v = _mk(b, sq, skv, h, kvh, d, "float32", seed=3)
    ref = np.asarray(attention_ref(q, k, v, causal=causal, window=window))
    out = np.asarray(flash_attention_jnp(q, k, v, causal=causal, window=window, chunk=64))
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)

    def lc(q, k, v):
        return jnp.sum(flash_attention_jnp(q, k, v, causal=causal, window=window, chunk=64) ** 2)

    def lr(q, k, v):
        return jnp.sum(attention_ref(q, k, v, causal=causal, window=window) ** 2)

    g1 = jax.grad(lc, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(lr, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=5e-3, atol=5e-4)


@pytest.mark.parametrize("h,kvh", [(4, 4), (16, 1), (8, 2)])
@pytest.mark.parametrize("dtype,tol", [("float32", 2e-4), ("bfloat16", 3e-2)])
def test_gqa_grouping_extremes(h, kvh, dtype, tol):
    """The grouped-layout core (no jnp.repeat) across the GQA spectrum:
    MHA (h == kvh), MQA (h >> kvh), grouped — per-dtype tolerance
    bands (bf16 rounds the operands, not the algorithm)."""
    q, k, v = _mk(2, 128, 128, h, kvh, 64, dtype, seed=7)
    ref = np.asarray(attention_ref(q, k, v, causal=True), np.float32)
    out = np.asarray(flash_attention_jnp(q, k, v, causal=True, chunk=64), np.float32)
    np.testing.assert_allclose(out, ref, rtol=tol, atol=tol)


def test_decode_single_slot_cache():
    """seq_len=1 KV cache: one valid slot is a deterministic copy of v
    (softmax over one logit), exercising the batched-GEMV path's edge."""
    q, kc, vc = _mk_decode(b=2, s=1, h=4, kvh=2, d=32)
    out = np.asarray(decode_attention(q, kc, vc, length=1))
    want = np.repeat(np.asarray(vc)[:, 0], 2, axis=1).reshape(2, 1, 4, 32)
    np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-6)


def test_decode_bf16_cache_tolerance():
    """bf16 q/cache vs the f32 reference within the bf16 band — the
    restructured path must accumulate logits and o in f32."""
    q, kc, vc = _mk_decode(b=2, s=64, h=4, kvh=2, d=32)
    out32 = np.asarray(decode_attention(q, kc, vc, length=40))
    out16 = np.asarray(
        decode_attention(
            q.astype(jnp.bfloat16), kc.astype(jnp.bfloat16),
            vc.astype(jnp.bfloat16), length=40,
        ),
        np.float32,
    )
    np.testing.assert_allclose(out16, out32, rtol=3e-2, atol=3e-2)


def test_traced_window_matches_static():
    """Per-layer scanned metadata passes window as a traced scalar."""
    q, k, v = _mk(1, 128, 128, 2, 2, 32, "float32", seed=5)
    stat = flash_attention_jnp(q, k, v, causal=True, window=32)
    trac = jax.jit(
        lambda w: flash_attention_jnp(q, k, v, causal=True, window=w)
    )(jnp.int32(32))
    np.testing.assert_allclose(np.asarray(stat), np.asarray(trac), rtol=1e-4, atol=1e-5)


def _mk_decode(b=2, s=64, h=4, kvh=2, d=32, seed=2):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, 1, h, d)), "float32")
    kc = jnp.asarray(rng.normal(size=(b, s, kvh, d)), "float32")
    vc = jnp.asarray(rng.normal(size=(b, s, kvh, d)), "float32")
    return q, kc, vc


def test_decode_matches_ref():
    L = 40
    q, kc, vc = _mk_decode()
    for window in (None, 16):
        ref = attention_ref(q, kc[:, :L], vc[:, :L], causal=True, window=window, q_offset=L - 1)
        out = decode_attention(q, kc, vc, length=L, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_decode_fully_masked_is_zero():
    """length=0 (empty cache) and an everything-excluding window must
    give exact zeros, not a uniform softmax over garbage logits."""
    q, kc, vc = _mk_decode()
    out = np.asarray(decode_attention(q, kc, vc, length=0))
    assert np.all(out == 0.0)
    # window=0 excludes even the newest slot, for every batch row
    out = np.asarray(decode_attention(q, kc, vc, length=8, window=0))
    assert np.all(out == 0.0)
    # per-batch: row 0 empty -> zeros; row 1 live -> matches the ref
    out = np.asarray(decode_attention(q, kc, vc, length=jnp.array([0, 8])))
    assert np.all(out[0] == 0.0)
    ref = attention_ref(q[1:], kc[1:, :8], vc[1:, :8], causal=True, q_offset=7)
    np.testing.assert_allclose(out[1:], np.asarray(ref), rtol=1e-5, atol=1e-5)
    assert np.any(out[1] != 0.0)


def test_decode_per_batch_lengths_match_ref():
    lengths = (40, 17)
    q, kc, vc = _mk_decode()
    out = np.asarray(decode_attention(q, kc, vc, length=jnp.array(lengths)))
    for i, L in enumerate(lengths):
        ref = attention_ref(
            q[i : i + 1], kc[i : i + 1, :L], vc[i : i + 1, :L],
            causal=True, q_offset=L - 1,
        )
        np.testing.assert_allclose(out[i : i + 1], np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)


def test_decode_window_includes_newest_slot():
    """A sliding window always covers slot length-1 (the query's own
    position); window=1 attends to exactly that slot."""
    L = 40
    q, kc, vc = _mk_decode()
    out = np.asarray(decode_attention(q, kc, vc, length=L, window=1))
    # attention over a single slot: softmax == 1 -> output is v[L-1]
    b, _, h, d = q.shape
    kvh = kc.shape[2]
    # heads are kvh-major in the GQA grouping: head i reads kv head i // g
    want = np.repeat(np.asarray(vc)[:, L - 1], h // kvh, axis=1)
    want = want.reshape(b, 1, h, d)
    np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-6)
    # boundary inclusion/exclusion: window=w sees slots [L-w, L-1]
    w = 16
    outw = decode_attention(q, kc, vc, length=L, window=w)
    ref = attention_ref(q, kc[:, :L], vc[:, :L], causal=True, window=w,
                        q_offset=L - 1)
    np.testing.assert_allclose(np.asarray(outw), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    # and perturbing the newest in-window slot changes the output
    kc2 = kc.at[:, L - 1].add(1.0)
    out2 = decode_attention(q, kc2, vc, length=L, window=w)
    assert not np.allclose(np.asarray(outw), np.asarray(out2))
