"""Flash attention: Pallas kernel + chunked custom-VJP twin vs oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import attention_ref, decode_attention, flash_attention
from repro.kernels.flash_attention.ops import flash_attention_jnp

CASES = [
    # b, sq, skv, h, kvh, d, causal, window
    (2, 256, 256, 4, 2, 64, True, None),
    (1, 128, 128, 8, 1, 64, True, 128),
    (2, 256, 512, 4, 4, 32, False, None),  # cross
    (1, 384, 384, 2, 2, 128, True, 64),  # sliding window
]


def _mk(b, sq, skv, h, kvh, d, dtype, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, sq, h, d)), dtype=dtype)
    k = jnp.asarray(rng.normal(size=(b, skv, kvh, d)), dtype=dtype)
    v = jnp.asarray(rng.normal(size=(b, skv, kvh, d)), dtype=dtype)
    return q, k, v


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_pallas_kernel_interpret(case, dtype):
    b, sq, skv, h, kvh, d, causal, window = case
    q, k, v = _mk(b, sq, skv, h, kvh, d, dtype)
    ref = np.asarray(attention_ref(q, k, v, causal=causal, window=window), np.float32)
    out = np.asarray(
        flash_attention(q, k, v, causal=causal, window=window, interpret=True),
        np.float32,
    )
    tol = 3e-2 if dtype == "bfloat16" else 1e-4
    np.testing.assert_allclose(out, ref, rtol=tol, atol=tol)


@pytest.mark.parametrize("case", CASES)
def test_chunked_jnp_forward_and_grads(case):
    b, sq, skv, h, kvh, d, causal, window = case
    q, k, v = _mk(b, sq, skv, h, kvh, d, "float32", seed=3)
    ref = np.asarray(attention_ref(q, k, v, causal=causal, window=window))
    out = np.asarray(flash_attention_jnp(q, k, v, causal=causal, window=window, chunk=64))
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)

    def lc(q, k, v):
        return jnp.sum(flash_attention_jnp(q, k, v, causal=causal, window=window, chunk=64) ** 2)

    def lr(q, k, v):
        return jnp.sum(attention_ref(q, k, v, causal=causal, window=window) ** 2)

    g1 = jax.grad(lc, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(lr, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=5e-3, atol=5e-4)


def test_traced_window_matches_static():
    """Per-layer scanned metadata passes window as a traced scalar."""
    q, k, v = _mk(1, 128, 128, 2, 2, 32, "float32", seed=5)
    stat = flash_attention_jnp(q, k, v, causal=True, window=32)
    trac = jax.jit(
        lambda w: flash_attention_jnp(q, k, v, causal=True, window=w)
    )(jnp.int32(32))
    np.testing.assert_allclose(np.asarray(stat), np.asarray(trac), rtol=1e-4, atol=1e-5)


def test_decode_matches_ref():
    b, s, h, kvh, d, L = 2, 64, 4, 2, 32, 40
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.normal(size=(b, 1, h, d)), "float32")
    kc = jnp.asarray(rng.normal(size=(b, s, kvh, d)), "float32")
    vc = jnp.asarray(rng.normal(size=(b, s, kvh, d)), "float32")
    for window in (None, 16):
        ref = attention_ref(q, kc[:, :L], vc[:, :L], causal=True, window=window, q_offset=L - 1)
        out = decode_attention(q, kc, vc, length=L, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)
