"""Chunked SSD scan (Pallas + jnp twin) vs step-by-step recurrence."""

import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st  # property tests skip w/o hypothesis

from repro.kernels.ssm_scan import (
    ssm_scan, ssm_scan_chunked_jnp, ssm_scan_ref,
)
from repro.kernels.ssm_scan.ref import ssm_step_ref


def _mk(bt, s, h, p, n, seed=0):
    rng = np.random.default_rng(seed)
    u = jnp.asarray(rng.normal(size=(bt, s, h, p)), "float32")
    ld = jnp.asarray(-rng.uniform(0.001, 0.3, size=(bt, s, h)), "float32")
    B = jnp.asarray(rng.normal(size=(bt, s, h, n)), "float32")
    C = jnp.asarray(rng.normal(size=(bt, s, h, n)), "float32")
    return u, ld, B, C


@pytest.mark.parametrize("shape", [(2, 256, 3, 32, 16), (1, 128, 1, 64, 32), (3, 64, 2, 16, 8)])
@pytest.mark.parametrize("chunk", [32, 64, 128])
def test_chunked_matches_ref(shape, chunk):
    u, ld, B, C = _mk(*shape)
    ref_y, ref_s = ssm_scan_ref(u, ld, B, C)
    y, s = ssm_scan_chunked_jnp(u, ld, B, C, chunk=min(chunk, u.shape[1]))
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref_y), rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(ref_s), rtol=1e-3, atol=1e-4)


def test_pallas_interpret_matches_ref():
    u, ld, B, C = _mk(2, 256, 3, 32, 16, seed=9)
    ref_y, ref_s = ssm_scan_ref(u, ld, B, C)
    y, s = ssm_scan(u, ld, B, C, chunk=64, interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref_y), rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(ref_s), rtol=1e-3, atol=1e-4)


def test_ragged_seq_padding_path():
    """Non-chunk-divisible sequences pad with identity steps."""
    u, ld, B, C = _mk(1, 100, 2, 8, 4, seed=11)
    ref_y, ref_s = ssm_scan_ref(u, ld, B, C)
    y, s = ssm_scan(u, ld, B, C, chunk=32)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref_y), rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(ref_s), rtol=1e-3, atol=1e-4)


def test_auto_chunk_non_dividing_seq():
    """chunk=None auto-picks (32 on CPU); S=50 does not divide it, so
    the identity-step padding path must also engage under auto-chunk."""
    u, ld, B, C = _mk(2, 50, 2, 8, 4, seed=13)
    ref_y, ref_s = ssm_scan_ref(u, ld, B, C)
    y, s = ssm_scan(u, ld, B, C)
    assert y.shape == u.shape
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref_y), rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(ref_s), rtol=1e-3, atol=1e-4)


def test_bf16_tolerance_band():
    """bf16 inputs stay within the bf16 band of the f32 reference run
    on the SAME rounded operands (isolating algorithm error from input
    quantization); the chunked math accumulates in f32 and the state
    is returned in f32."""
    u, ld, B, C = _mk(1, 64, 2, 16, 8, seed=21)
    ub, ldb, Bb, Cb = (x.astype(jnp.bfloat16) for x in (u, ld, B, C))
    ref_y, ref_s = ssm_scan_ref(
        *(x.astype(jnp.float32) for x in (ub, ldb, Bb, Cb))
    )
    y, s = ssm_scan(ub, ldb, Bb, Cb, chunk=32)
    assert y.dtype == jnp.bfloat16
    assert s.dtype == jnp.float32
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(ref_y), rtol=3e-2, atol=3e-2
    )
    np.testing.assert_allclose(np.asarray(s), np.asarray(ref_s), rtol=3e-2, atol=3e-2)


@given(st.integers(1, 3), st.integers(1, 4), st.integers(1, 8), st.integers(1, 8))
@settings(max_examples=20, deadline=None)
def test_decode_step_consistency(bt, h, p, n):
    """Running the scan then one step == scanning S+1 steps."""
    u, ld, B, C = _mk(bt, 17, h, p, n, seed=p * 10 + n)
    y_all, s_all = ssm_scan_ref(u, ld, B, C)
    _, s_16 = ssm_scan_ref(u[:, :16], ld[:, :16], B[:, :16], C[:, :16])
    y1, s1 = ssm_step_ref(s_16, u[:, 16], ld[:, 16], B[:, 16], C[:, 16])
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s_all), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y_all[:, 16]), rtol=1e-4, atol=1e-5)
