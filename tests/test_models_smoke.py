"""Per-arch smoke tests: reduced config, forward + train step on CPU,
output shapes + finiteness; decode-vs-prefill cache consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY, reduced
from repro.models import build
from repro.optim import OptConfig
from repro.launch.steps import make_train_step
from repro.optim import init_opt_state

ARCHS = sorted(REGISTRY)


def _batch(cfg, B=2, S=32, seed=0):
    rng = jax.random.PRNGKey(seed)
    toks = jax.random.randint(rng, (B, S), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            rng, (B, cfg.n_image_tokens, cfg.d_model))
    if cfg.family == "encdec":
        batch["enc_frames"] = jax.random.normal(rng, (B, cfg.enc_seq, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step(arch):
    cfg = reduced(REGISTRY[arch])
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    step = make_train_step(model, OptConfig(lr=1e-3))
    batch = _batch(cfg)
    p2, o2, loss = jax.jit(step)(params, opt, batch)
    assert jnp.isfinite(loss), arch
    # params actually changed
    delta = sum(
        float(jnp.sum(jnp.abs(a - b)))
        for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(params))
    )
    assert delta > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_prefill(arch):
    """The strongest cache test: decode(token S) == prefill(S+1)[-1]."""
    cfg = reduced(REGISTRY[arch])
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(1))
    B, S = 2, 24
    batch = _batch(cfg, B, S + 1, seed=2)
    toks = batch["tokens"]
    extra = {k: v for k, v in batch.items() if k not in ("tokens", "labels")}
    full_logits, _ = model.prefill(params, {"tokens": toks, **extra}, max_len=S + 2)
    want = np.asarray(full_logits[:, S])
    _, cache = model.prefill(params, {"tokens": toks[:, :S], **extra}, max_len=S + 2)
    got_l, _ = model.decode(params, cache, {"token": toks[:, S:S + 1]})
    got = np.asarray(got_l[:, 0])
    err = np.abs(got - want).max() / (np.abs(want).max() + 1e-9)
    assert err < 5e-3, (arch, err)


@pytest.mark.parametrize("arch", ARCHS)
def test_microbatched_step_matches(arch):
    """Gradient accumulation = same loss value (mean over microbatches)."""
    cfg = reduced(REGISTRY[arch])
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(3))
    batch = _batch(cfg, B=4, S=16, seed=4)
    l1 = float(model.loss(params, batch))
    step = make_train_step(model, OptConfig(lr=0.0, weight_decay=0.0), microbatches=2)
    opt = init_opt_state(params)
    _, _, loss = jax.jit(step)(params, opt, batch)
    # mean of per-microbatch losses == full-batch loss for mean-xent
    assert abs(float(loss) - l1) < 5e-3, (arch, float(loss), l1)


def test_vocab_logit_shapes():
    for arch in ARCHS:
        cfg = reduced(REGISTRY[arch])
        model = build(cfg)
        params = model.init(jax.random.PRNGKey(0))
        batch = _batch(cfg, B=1, S=8)
        logits, _ = model.prefill(
            params, {k: v for k, v in batch.items() if k != "labels"}, max_len=16
        )
        assert logits.shape == (1, 8, cfg.vocab)
