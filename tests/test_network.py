"""Network-level mapping: zoo lowering, engine scheduling, and the
thermal feasibility mask as a first-class constraint.

Covers the acceptance criteria: every config lowers to a non-empty
stream and yields a finite network report in all three shape modes,
fixed-design latency >= per-layer-optimal latency, and thermal masking
changes advisor / Pareto / schedule outcomes in pinned scenarios.
"""

import numpy as np
import pytest

from repro.config import ShapeConfig
from repro.configs import REGISTRY, SHAPES
from repro.core.engine import DesignGrid, evaluate, schedule
from repro.core.network import CONV_WIDTH, lower_network, lower_zoo

# Reduced grid: same code paths, ~10x faster than the default sweep.
GRID_KW = dict(mac_budgets=(2**14, 2**16), tiers=range(1, 9))

MODES = ["train_4k", "prefill_32k", "decode_32k"]


# ---------------------------------------------------------------------------
# Lowering: every config x every mode -> non-empty, sane streams
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", sorted(REGISTRY))
@pytest.mark.parametrize("shape", MODES)
def test_every_config_lowers_nonempty(arch, shape):
    stream = lower_network(REGISTRY[arch], SHAPES[shape])
    wl = stream.workloads
    assert wl.shape[0] > 0 and wl.shape[1] == 3
    assert np.all(wl > 0)
    assert np.all(stream.counts > 0)
    assert stream.total_macs > 0
    # unique shapes only (merged on lowering)
    assert len({tuple(r) for r in wl.tolist()}) == wl.shape[0]


def test_token_conventions():
    """train/prefill streams carry M = seq_len; decode M = batch."""
    cfg = REGISTRY["qwen2.5-3b"]
    tr = lower_network(cfg, SHAPES["train_4k"])
    de = lower_network(cfg, SHAPES["decode_32k"])
    assert set(tr.workloads[:, 0]) == {SHAPES["train_4k"].seq_len}
    assert set(de.workloads[:, 0]) == {SHAPES["decode_32k"].global_batch}
    # the global batch multiplies counts instead for train/prefill.
    # gemma's q (d -> 1024) doesn't shape-merge with any other GEMM, so
    # its count is exactly n_layers x batch.
    g3 = REGISTRY["gemma3-1b"]
    tr3 = lower_network(g3, SHAPES["train_4k"])
    q = next(g for g in tr3.gemms if g.name == "attn.q")
    assert q.N == g3.n_heads * g3.head_dim_
    assert q.count == g3.n_layers * SHAPES["train_4k"].global_batch


def test_moe_routed_token_counts():
    """Routed experts see ceil(t * top_k / n_experts) tokens; shared
    experts and attention see all t tokens."""
    cfg = REGISTRY["deepseek-moe-16b"]
    shape = SHAPES["decode_32k"]
    stream = lower_network(cfg, shape)
    t = shape.global_batch
    routed_t = -(-t * cfg.top_k // cfg.n_experts)
    by_name = {g.name: g for g in stream.gemms}
    assert by_name["moe.expert.out"].M == routed_t
    assert by_name["moe.expert.out"].count == cfg.n_experts * cfg.n_layers
    assert by_name["moe.shared.in"].M == t
    assert by_name["moe.router"].N == cfg.n_experts
    assert by_name["attn.q"].M == t


def test_family_specific_layers():
    """Per-family lowering emits the structurally expected GEMMs.

    Shape-identical GEMMs merge (keeping the first name), so the
    checks are on shapes where names could collapse."""
    names = lambda s: {g.name for g in s.gemms}
    zb = REGISTRY["zamba2-2.7b"]
    ssm = lower_network(zb, SHAPES["train_4k"])
    assert {"ssm.in_proj", "ssm.conv", "ssm.out_proj", "shared.attn.q"} <= names(ssm)
    # conv lowered as im2col: K = kernel taps, N = conv channels
    conv = next(g for g in ssm.gemms if g.name == "ssm.conv")
    assert conv.K == CONV_WIDTH
    assert conv.N == zb.ssm_expand * zb.d_model + 2 * zb.ssm_state
    # xlstm: qkv and out projections are all (t, d, d) -> one merged
    # entry; its count covers all 4 projections per block
    xl = lower_network(REGISTRY["xlstm-125m"], SHAPES["train_4k"])
    assert {"xlstm.qkv", "logits"} <= names(xl)
    qkv = next(g for g in xl.gemms if g.name == "xlstm.qkv")
    assert qkv.count == (4 * REGISTRY["xlstm-125m"].n_layers
                         * SHAPES["train_4k"].global_batch)
    # whisper: encoder GEMMs (M = enc_seq) run in prefill, not decode
    wm = REGISTRY["whisper-medium"]
    enc = lower_network(wm, SHAPES["prefill_32k"])
    dec = lower_network(wm, SHAPES["decode_32k"])
    assert wm.enc_seq in set(enc.workloads[:, 0])
    assert wm.enc_seq not in set(dec.workloads[:, 0])
    # vlm: image-token k/v (M = n_image_tokens) is prefill-only too
    vl = REGISTRY["llama-3.2-vision-11b"]
    vl_p = lower_network(vl, SHAPES["prefill_32k"])
    vl_d = lower_network(vl, SHAPES["decode_32k"])
    assert vl.n_image_tokens in set(vl_p.workloads[:, 0])
    assert vl.n_image_tokens not in set(vl_d.workloads[:, 0])


def test_lower_zoo_covers_live_cells():
    from repro.configs import cells

    live, _ = cells()
    streams = lower_zoo()
    assert len(streams) == len(live)
    assert {(s.arch, s.shape) for s in streams} == set(live)


# ---------------------------------------------------------------------------
# schedule(): finite reports, policy ordering, reduction correctness
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", sorted(REGISTRY))
def test_schedule_finite_all_modes(arch):
    """Acceptance: finite network-level report in train, prefill and
    decode for every config, with fixed >= per-layer latency."""
    for shape in MODES:
        stream = lower_network(REGISTRY[arch], SHAPES[shape])
        rep = schedule(stream, **GRID_KW)
        for pol in (rep.per_layer, rep.fixed):
            assert pol.feasible, (arch, shape, pol.policy)
            for f in ("total_cycles", "time_s", "energy_j", "edp_js",
                      "total_cycles_2d", "speedup_vs_2d", "t_max_c",
                      "utilization"):
                assert np.isfinite(getattr(pol, f)), (arch, shape, pol.policy, f)
            assert pol.total_cycles > 0 and pol.energy_j > 0
            assert 0 < pol.utilization <= 1 + 1e-12
        assert rep.fixed.total_cycles >= rep.per_layer.total_cycles, (arch, shape)
        assert rep.mode == SHAPES[shape].mode


def test_schedule_reduction_matches_manual():
    """Per-layer totals == the count-weighted sum of each layer's best
    feasible candidate; fixed totals == the best single column."""
    stream = lower_network(REGISTRY["smollm-135m"], SHAPES["decode_32k"])
    rep = schedule(stream, **GRID_KW)
    wl, counts = stream.workloads, stream.counts

    # re-evaluate the chosen per-layer designs explicitly
    d = np.asarray(rep.per_layer.design)  # (W, 3) rows/cols/tiers
    g = DesignGrid.explicit(wl, rows=d[:, 0], cols=d[:, 1], tiers=d[:, 2])
    res = evaluate(g)
    per_layer_cyc = np.diag(res.cycles)
    assert rep.per_layer.total_cycles == pytest.approx(
        float(np.sum(counts * per_layer_cyc)))

    r, c, l = (int(x) for x in np.asarray(rep.fixed.design))
    g2 = DesignGrid.explicit(wl, rows=r, cols=c, tiers=l)
    res2 = evaluate(g2)
    assert rep.fixed.total_cycles == pytest.approx(
        float(np.sum(counts * res2.cycles[:, 0])))
    assert rep.fixed.energy_j == pytest.approx(
        float(np.sum(counts * res2.energy_j[:, 0])))


def test_schedule_count_weighting():
    """Doubling a layer's multiplicity moves the totals accordingly."""
    import dataclasses

    stream = lower_network(REGISTRY["smollm-135m"], SHAPES["decode_32k"])
    rep = schedule(stream, **GRID_KW)
    doubled = dataclasses.replace(
        stream,
        gemms=tuple(dataclasses.replace(g, count=2 * g.count) for g in stream.gemms),
    )
    rep2 = schedule(doubled, **GRID_KW)
    assert rep2.fixed.total_cycles == pytest.approx(2 * rep.fixed.total_cycles)
    assert rep2.per_layer.total_cycles == pytest.approx(
        2 * rep.per_layer.total_cycles)


def test_schedule_speedup_is_vs_2d_baseline():
    """speedup_vs_2d is the count-weighted 2D-total over the 3D-total."""
    stream = lower_network(REGISTRY["xlstm-125m"], SHAPES["decode_32k"])
    rep = schedule(stream, **GRID_KW)
    fx = rep.fixed
    assert fx.speedup_vs_2d == pytest.approx(fx.total_cycles_2d / fx.total_cycles)
    assert fx.speedup_vs_2d > 0


def test_schedule_report_roundtrip():
    stream = lower_network(REGISTRY["gemma3-1b"], SHAPES["decode_32k"])
    rep = schedule(stream, **GRID_KW)
    d = rep.to_dict()
    assert d["arch"] == "gemma3-1b" and d["fixed"]["policy"] == "fixed"
    assert len(d["per_layer"]["design"]) == rep.n_gemms


# ---------------------------------------------------------------------------
# Thermal feasibility as a first-class mask (regression-pinned scenarios)
# ---------------------------------------------------------------------------

def _advise(wl, axis, mac_budget=None, thermal_limit=None):
    """Rank mesh strategies through the non-deprecated Study front door
    (``rank_candidates`` is a deprecated shim over the same engine)."""
    from repro.core.study import AnalysisSpec, ConstraintSpec, Study, WorkloadSpec

    kw = {}
    if thermal_limit is not None:
        kw["constraints"] = ConstraintSpec(thermal_limit_c=thermal_limit)
    res = Study(
        workload=WorkloadSpec(kind="gemms", gemms=tuple(map(tuple, wl))),
        analysis=AnalysisSpec(kind="advise", axis=axis, mac_budget=mac_budget),
        **kw,
    ).run()
    return res.payload["names"], res.payload["totals"]


def test_thermal_mask_changes_advisor_outcome():
    """shard_K (the 3D-stacked dOS mapping) wins unconstrained for a
    huge-K decode GEMM, but gets struck when the 16-tier stack would
    exceed the thermal limit — the advisor falls back to scaled-out 2D."""
    from repro.core.engine import MESH_STRATEGIES

    wl = [(64, 1 << 20, 64)]
    names0, totals0 = _advise(wl, 16)
    assert names0[0] == "shard_K"
    # the 16-tier 2^18-MAC stack settles at ~47.7 C (lumped model);
    # a 47 C limit renders it infeasible
    names1, totals1 = _advise(wl, 16, mac_budget=2**18, thermal_limit=47.0)
    assert names1[0] != "shard_K"
    k = MESH_STRATEGIES.index("shard_K")
    assert np.isinf(totals1[0, k])
    # and with the real junction budget (105 C) nothing is masked
    names2, totals2 = _advise(wl, 16, mac_budget=2**18)
    assert names2[0] == "shard_K"
    assert np.array_equal(totals0, totals2)


def test_thermal_mask_changes_pareto_frontier():
    """At a 50 C limit, 3D points on the unconstrained latency/area/
    power frontier are excluded, and the constrained frontier differs
    (but never contains an infeasible point)."""
    grid = DesignGrid.product([(64, 12100, 147)], (2**14, 2**16, 2**18),
                              range(1, 17))
    res = evaluate(grid, thermal_limit=50.0)
    assert np.any(res.valid & ~res.feasible)  # the limit actually bites
    m_all = res.pareto_mask(feasible_only=False)
    m_feas = res.pareto_mask()
    assert np.any(m_all != m_feas)
    assert not np.any(m_feas & ~res.feasible)
    # feasible frontier points of the unconstrained mask survive
    assert np.all(m_feas[m_all & res.feasible])


def test_thermal_mask_changes_schedule_outcome():
    """Tightening the junction limit excludes candidate fixed designs
    and pushes the schedule onto a cooler (slower-or-equal) design."""
    stream = lower_network(REGISTRY["smollm-135m"], SHAPES["train_4k"])
    hot = schedule(stream, require_feasible=False, thermal_limit=50.0, **GRID_KW)
    cool = schedule(stream, thermal_limit=50.0, **GRID_KW)
    assert cool.n_thermally_masked > 0
    assert cool.fixed.t_max_c < 50.0
    assert cool.fixed.total_cycles >= hot.fixed.total_cycles
    assert not np.array_equal(
        np.asarray(cool.fixed.design), np.asarray(hot.fixed.design)
    ) or cool.fixed.total_cycles == hot.fixed.total_cycles


def test_feasible_property_falls_back_to_valid():
    grid = DesignGrid.product([(64, 300, 64)], (2**12,), (1, 2))
    res = evaluate(grid, metrics=("perf",))
    assert res.within_thermal_budget is None
    assert np.array_equal(res.feasible, res.valid)
