"""PPA models reproduce the paper's Table II, Fig. 8 trends, Fig. 9 bands."""

import pytest

from repro.core.ppa import (
    area_normalized_speedup, array_power, table2_setup, thermal_report,
)
from repro.core.ppa.constants import THERMAL_BUDGET_C

PAPER_TABLE2 = {"2d": (6.61, 14.99), "tsv": (6.39, 14.41), "miv": (6.26, 14.14)}


@pytest.mark.parametrize("name", ["2d", "tsv", "miv"])
def test_table2_total_power(name):
    r = array_power(**table2_setup()[name])
    want_total, want_peak = PAPER_TABLE2[name]
    assert abs(r.total_w - want_total) / want_total < 0.01, r.total_w
    assert abs(r.peak_w - want_peak) / want_peak < 0.03, r.peak_w


def test_power_ordering():
    rs = {n: array_power(**kw) for n, kw in table2_setup().items()}
    assert rs["2d"].total_w > rs["tsv"].total_w > rs["miv"].total_w
    # vertical links: TSV burns more than MIV (10fF vs 0.2fF)
    assert rs["tsv"].components["vlink_w"] > rs["miv"].components["vlink_w"]


def test_fig9_two_tier_band():
    """Paper: 2-tier face-to-face gives 1.19x-1.97x perf/area."""
    t = area_normalized_speedup(64, 12100, 147, 2**18, 2, "tsv")
    m = area_normalized_speedup(64, 12100, 147, 2**18, 2, "miv")
    assert 1.1 <= t <= 1.3, t
    assert 1.8 <= m <= 2.1, m


def test_fig9_small_macs_tsv_loses():
    """Paper: at 4096 MACs the TSV 3D-IC is WORSE per area than 2D."""
    assert area_normalized_speedup(64, 12100, 147, 4096, 4, "tsv") < 1.0


def test_fig9_miv_beats_tsv():
    for l in (2, 4, 8):
        assert area_normalized_speedup(64, 12100, 147, 2**18, l, "miv") > \
            area_normalized_speedup(64, 12100, 147, 2**18, l, "tsv")


def test_thermal_trends():
    """Fig. 8: 3D hotter than 2D; MIV hotter than TSV; hotter with more
    MACs; everything within the thermal budget."""
    t2 = thermal_report(16384, 1, "2d")
    tt = thermal_report(16384, 3, "tsv")
    tm = thermal_report(16384, 3, "miv")
    assert t2.t_max_c < tt.t_max_c < tm.t_max_c
    assert all(r.within_budget for r in (t2, tt, tm))
    small = thermal_report(4096, 3, "tsv")
    big = thermal_report(65536, 3, "tsv")
    assert small.t_max_c < big.t_max_c
    assert big.t_max_c < THERMAL_BUDGET_C
