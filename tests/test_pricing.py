"""Off-nominal ``pricing.price_steps`` coverage (ISSUE 10, satellite 3).

The 1 GHz / nominal-VDD identity path has long been regression-pinned
(roofline + serve suites). These tests pin the operating points those
suites never leave:

- the **lowest DVFS state** (first entry of ``DvfsSpec``): compute and
  vlink cycles are frequency-invariant, memory cycles scale with f
  (fewer wall-clock bytes/cycle at speed, more when slowed), power
  splits into the static (v/V0)^2 and dynamic (f/F0)(v/V0)^2 scalings;
- the **zero-M degenerate step**: a step that does no useful work
  still prices its compulsory weight traffic, and the power keys are
  NaN (0 compute seconds — there is no meaningful watts figure for a
  workless step; serve never emits one);
- a **vlink-bound step**: the vertical links, not compute or DRAM,
  set the critical path — total == vlink cycles, bound_idx == 2.
"""

import numpy as np
import pytest

from repro.core.bandwidth import BOUND_NAMES, BandwidthSpec
from repro.core.ppa import constants as C
from repro.core.pricing import DvfsSpec, price_steps


def _price(dataflow, M, K, N, R, Cc, L, tech, spec, *args, **kw):
    pr = price_steps(dataflow, np.array([M]), np.array([K]), np.array([N]),
                     np.array([R]), np.array([Cc]), np.array([L]),
                     np.array([tech]), spec, *args, **kw)
    return {k: float(np.asarray(v).reshape(-1)[0]) for k, v in pr.items()}


def test_price_steps_explicit_nominal_point_is_identity():
    """Passing (FREQ_HZ, VDD) explicitly must be bit-for-bit the
    default path — the scale_power fast-path contract."""
    spec = BandwidthSpec.paper_default()
    for df in ("os", "dos", "ws", "is"):
        a = _price(df, 128, 300, 128, 8, 8, 4, "tsv", spec)
        b = _price(df, 128, 300, 128, 8, 8, 4, "tsv", spec,
                   C.FREQ_HZ, C.VDD)
        assert a == b, df


@pytest.mark.parametrize("dataflow", ["os", "dos", "ws", "is"])
@pytest.mark.parametrize("tech", ["tsv", "miv"])
def test_price_steps_lowest_dvfs_state(dataflow, tech):
    d = DvfsSpec()
    f0, v0 = float(d.freqs_hz()[0]), float(d.vdds_v[0])
    assert f0 < C.FREQ_HZ and v0 < C.VDD  # genuinely off-nominal

    spec = BandwidthSpec.paper_default()
    nom = _price(dataflow, 128, 300, 128, 8, 8, 4, tech, spec)
    low = _price(dataflow, 128, 300, 128, 8, 8, 4, tech, spec, f0, v0)

    # cycle counts are clock-relative: compute and vlink don't move
    assert low["compute_cycles"] == nom["compute_cycles"]
    assert low["vlink_cycles"] == nom["vlink_cycles"]
    assert low["dram_bytes"] == nom["dram_bytes"]
    assert low["sram_need_bytes"] == nom["sram_need_bytes"]
    # DRAM delivers a fixed bytes/s, so its cycle cost scales with f
    assert low["mem_cycles"] == pytest.approx(
        nom["mem_cycles"] * f0 / C.FREQ_HZ, rel=1e-12)

    # the canonical DVFS power split
    sd = (f0 / C.FREQ_HZ) * (v0 / C.VDD) ** 2
    ss = (v0 / C.VDD) ** 2
    assert low["static_w"] == pytest.approx(nom["static_w"] * ss, rel=1e-12)
    assert low["dynamic_w"] == pytest.approx(nom["dynamic_w"] * sd, rel=1e-12)
    assert low["total_w"] == pytest.approx(
        nom["static_w"] * ss + nom["dynamic_w"] * sd, rel=1e-12)
    assert low["total_w"] < nom["total_w"]

    # wall clock stretches by the frequency ratio of the *total* cycles
    assert low["seconds"] == pytest.approx(
        low["total_cycles"] / f0, rel=1e-12)
    assert low["energy_j"] == pytest.approx(
        (low["total_w"] * low["compute_cycles"]
         + low["static_w"] * low["stall_cycles"]) / f0, rel=1e-12)


def test_price_steps_zero_m_degenerate_step():
    """M = 0: no MACs, no activations — but the weight panel still has
    to be fetched, so the step is pure memory stall. Power keys are
    NaN by design (watts over zero compute-seconds is undefined; the
    serving simulator never emits a zero-work step)."""
    spec = BandwidthSpec.paper_default()
    with np.errstate(invalid="ignore"):
        pr = _price("dos", 0, 64, 64, 8, 8, 2, "tsv", spec)

    assert pr["compute_cycles"] == 0.0
    assert pr["vlink_cycles"] == 0.0 and pr["vlink_bytes"] == 0.0
    # compulsory traffic: the K x N weight panel, nothing else
    assert pr["dram_bytes"] == 64 * 64 * spec.bytes_in
    assert pr["mem_cycles"] == pr["dram_bytes"] / spec.dram_bytes_per_cycle
    assert pr["total_cycles"] == pr["mem_cycles"]
    assert pr["stall_cycles"] == pr["total_cycles"]  # 100% stalled
    assert pr["bound_idx"] == BOUND_NAMES.index("memory")
    assert pr["seconds"] == pr["total_cycles"] / C.FREQ_HZ
    # static power is well-defined (leakage doesn't need work)...
    assert np.isfinite(pr["static_w"]) and pr["static_w"] > 0
    # ...but per-op power and energy are NaN, never a silent zero
    for k in ("total_w", "dynamic_w", "peak_w", "tier_w", "energy_j"):
        assert np.isnan(pr[k]), k


def test_price_steps_vlink_bound_step():
    """A short-contraction GEMM on a tall, narrow TSV stack: each fold
    carries only ~12 MAC cycles while the shared TSV bus needs ~15 to
    drain the partial-sum plane per boundary — the vertical links are
    the critical path."""
    spec = BandwidthSpec.paper_default()
    pr = _price("dos", 64, 8, 64, 2, 2, 8, "tsv", spec)

    assert pr["bound_idx"] == BOUND_NAMES.index("vlink")
    assert pr["vlink_cycles"] > pr["compute_cycles"]
    assert pr["vlink_cycles"] > pr["mem_cycles"]
    assert pr["total_cycles"] == pr["vlink_cycles"]
    assert pr["stall_cycles"] == pr["total_cycles"] - pr["compute_cycles"]
    assert pr["vlink_bytes"] > 0
    # MIV links at the same design point are wide enough to hide it
    miv = _price("dos", 64, 8, 64, 2, 2, 8, "miv", spec)
    assert miv["bound_idx"] != BOUND_NAMES.index("vlink")
    assert miv["total_cycles"] < pr["total_cycles"]
