"""HLO collective parsing + roofline arithmetic."""

from repro.analysis.roofline import parse_collectives, roofline_from_artifact, CollectiveStats

HLO = """
  %all-reduce.1 = f32[16,128]{1,0} all-reduce(%dot), replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add
  %ag = bf16[32,256]{1,0} all-gather(%x), replica_groups=[2,4]<=[8], dimensions={0}
  %rs = f32[8,64]{1,0} reduce-scatter(%y), replica_groups={{0,1,2,3}}, dimensions={0}, to_apply=%add
  %cp = bf16[4,4]{1,0} collective-permute(%z), source_target_pairs={{0,1}}
  %unrelated = f32[2,2]{1,0} add(%a, %b)
"""


def test_parse_collectives():
    c = parse_collectives(HLO)
    assert c.counts == {"all-reduce": 1, "all-gather": 1, "reduce-scatter": 1,
                        "collective-permute": 1}
    ar = 16 * 128 * 4 * 2 * 3 / 4        # bytes * 2(g-1)/g, g=4
    ag = 32 * 256 * 2 * 3 / 4            # bytes * (g-1)/g, g=4
    rs = 8 * 64 * 4 * 3                  # bytes * (g-1),   g=4
    cp = 4 * 4 * 2
    assert abs(c.wire_bytes - (ar + ag + rs + cp)) < 1e-6


def test_roofline_terms():
    coll = CollectiveStats(wire_bytes=50e9, result_bytes=0, counts={}, by_op_bytes={})
    r = roofline_from_artifact(
        arch="a", shape="s", mesh_name="m", n_chips=256,
        cost={"flops": 197e12, "bytes accessed": 819e9}, coll=coll,
        model_flops=197e12 * 256 * 0.5,
    )
    assert abs(r.compute_s - 1.0) < 1e-6
    assert abs(r.memory_s - 1.0) < 1e-6
    assert abs(r.collective_s - 1.0) < 1e-6
    assert r.dominant in ("compute", "memory", "collective")
    assert abs(r.useful_ratio - 0.5) < 1e-6
