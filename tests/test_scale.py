"""Large-scale execution: device sharding, streaming, cache, resume.

Covers the production-scale contract: ``evaluate(shard=..., stream=...)``
and the Study chunk cache change performance characteristics ONLY —
every result bit matches the plain single-pass path, including the
degenerate grids (1-point, smaller than the device count, not divisible
by the shard count).
"""

import dataclasses
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from conftest import REPO, run_multidevice
from repro.core.cache import ResultCache, study_hash
from repro.core.engine import DesignGrid, EvalResult, evaluate
from repro.core.study import Study, StudyResult

FIG7_GRID = dict(
    workloads=[(64, 12100, 147), (512, 784, 128), (35, 2560, 4096)],
    mac_budgets=(2**14, 2**16, 2**18),
    tiers=range(1, 17),
)


def _assert_results_equal(a: EvalResult, b: EvalResult, ctx=""):
    for f in dataclasses.fields(EvalResult):
        if f.name == "grid":
            continue
        va, vb = getattr(a, f.name), getattr(b, f.name)
        assert (va is None) == (vb is None), (ctx, f.name)
        if va is not None:
            assert np.array_equal(va, vb, equal_nan=True), (ctx, f.name)


# ---------------------------------------------------------------------------
# Streaming: point-blocks stitch back bit-for-bit
# ---------------------------------------------------------------------------

def test_stream_matches_unstreamed():
    grid = DesignGrid.product(**FIG7_GRID)
    full = evaluate(grid)
    for block in (1, 5, 7, 48, 1000):
        _assert_results_equal(full, evaluate(grid, stream=block), f"stream={block}")


def test_subset_concat_roundtrip():
    grid = DesignGrid.product(**FIG7_GRID)
    full = evaluate(grid)
    parts = [evaluate(grid.subset(lo, min(lo + 11, grid.n_points)))
             for lo in range(0, grid.n_points, 11)]
    _assert_results_equal(full, EvalResult.concat(grid, parts))


def test_subset_of_heterogeneous_grid():
    """Per-point dataflow/tech arrays slice with the points."""
    P = 8
    grid = DesignGrid(
        workloads=[(64, 300, 64)],
        tiers=np.arange(1, P + 1),
        mac_budgets=np.full(P, 2**14),
        dataflow=np.array(["dos", "ws"] * (P // 2)),
        tech=np.array(["tsv", "miv"] * (P // 2)),
    )
    full = evaluate(grid)
    _assert_results_equal(full, evaluate(grid, stream=3), "hetero")
    sub = grid.subset(2, 5)
    assert list(sub.dataflow) == list(grid.dataflow[2:5])
    assert sub.n_points == 3


# ---------------------------------------------------------------------------
# Device sharding (single-device semantics + validation in-process)
# ---------------------------------------------------------------------------

def test_shard_validation():
    grid = DesignGrid.product([(64, 300, 64)], (2**12,), (1, 2))
    _assert_results_equal(evaluate(grid), evaluate(grid, shard="none"))
    _assert_results_equal(evaluate(grid), evaluate(grid, shard=1))
    # 'auto' is best-effort and portable: on the numpy backend (no
    # device axis) it degrades to unsharded — never an error
    _assert_results_equal(evaluate(grid), evaluate(grid, shard="auto"))
    # an explicit count on the numpy backend is a hard error on EVERY
    # host (not a silent no-op on machines that happen to have devices)
    with pytest.raises(ValueError, match="backend='jax'"):
        evaluate(grid, shard=2)
    with pytest.raises(ValueError, match="shard"):
        evaluate(grid, backend="jax", shard=0)
    with pytest.raises(ValueError, match="shard"):
        evaluate(grid, backend="jax", shard="bogus")
    with pytest.raises(ValueError, match="device"):
        evaluate(grid, backend="jax", shard=10_000)


def test_sharded_matches_unsharded_multidevice():
    """The satellite contract, on 8 fake CPU devices: the Fig-7 grid and
    every degenerate shape (1-point, < device count, non-divisible)
    match the unsharded path bit-for-bit under shard='auto' and explicit
    shard counts."""
    run_multidevice(
        """
        import numpy as np, jax, dataclasses
        from repro.core.engine import DesignGrid, EvalResult, evaluate

        assert jax.local_device_count() == 8

        def check(grid, **kw):
            a = evaluate(grid, backend="jax")
            b = evaluate(grid, backend="jax", **kw)
            for f in dataclasses.fields(EvalResult):
                if f.name == "grid":
                    continue
                va, vb = getattr(a, f.name), getattr(b, f.name)
                assert (va is None) == (vb is None), f.name
                if va is not None:
                    assert np.array_equal(va, vb, equal_nan=True), (f.name, kw)

        # the Fig-7 grid (48 points = 6 per device)
        fig7 = DesignGrid.product(
            [(64, 12100, 147), (512, 784, 128)], (2**14, 2**16, 2**18),
            range(1, 17),
        )
        check(fig7, shard="auto")
        check(fig7, shard=3)           # 48 % 3 == 0 but != device count
        check(fig7, shard=5)           # 48 % 5 != 0 -> padded shards
        # degenerate grids
        one = DesignGrid.product([(64, 12100, 147)], (2**16,), (3,))
        check(one, shard="auto")       # 1 point on 8 devices
        small = DesignGrid.product([(64, 12100, 147)], (2**16,), (1, 2, 3))
        check(small, shard="auto")     # 3 points < 8 devices
        odd = DesignGrid.product([(35, 2560, 4096)], (2**14, 2**18), range(1, 8))
        check(odd, shard="auto")       # 14 points % 8 != 0
        check(odd, shard=8)
        # sharding composes with streaming
        check(fig7, shard="auto", stream=7)
        print("sharded-ok")
        """,
        n_devices=8,
    )


# ---------------------------------------------------------------------------
# Cache + resume
# ---------------------------------------------------------------------------

def _payload_json(res: StudyResult) -> str:
    return json.dumps(res.to_dict()["payload"], sort_keys=True)


@pytest.mark.parametrize(
    "kind", ["evaluate", "pareto", "schedule", "advise", "sweep", "search"]
)
def test_cached_run_is_bit_identical(kind, tmp_path):
    study = Study.example(kind)
    plain = study.run()
    cold = study.run(cache=ResultCache(tmp_path, block_cells=8))
    warm = study.run(cache=ResultCache(tmp_path, block_cells=8))
    assert cold.cache["hits"] == 0 and cold.cache["misses"] > 0
    assert warm.cache["misses"] == 0
    assert warm.cache["hits"] == cold.cache["misses"]
    assert _payload_json(plain) == _payload_json(cold) == _payload_json(warm)
    assert plain.cache is None  # uncached runs carry no counters


def test_resume_recomputes_only_missing_chunks(tmp_path):
    study = Study.example("evaluate")
    plain = study.run()
    cold = study.run(cache=ResultCache(tmp_path, block_cells=8))
    n = cold.cache["misses"]
    assert n >= 4  # the point of the test is multi-chunk resume
    chunks = sorted((ResultCache(tmp_path).study_dir(study) / "chunks").glob("*.json"))
    assert len(chunks) == n
    for p in chunks[::2]:
        p.unlink()
    resumed = study.run(cache=ResultCache(tmp_path, block_cells=8))
    assert resumed.cache["misses"] == len(chunks[::2])
    assert resumed.cache["hits"] == n - len(chunks[::2])
    assert _payload_json(plain) == _payload_json(resumed)


def test_fig7_cache_chunks_over_workloads(tmp_path):
    from repro.core.dse import fig7_study

    study = fig7_study(n_workloads=40)
    plain = study.run()
    # 48 cells per workload -> 10-workload chunks -> 4 chunks
    cold = study.run(cache=ResultCache(tmp_path, block_cells=480))
    assert cold.cache["misses"] == 4
    warm = study.run(cache=ResultCache(tmp_path, block_cells=480))
    assert warm.cache == {**warm.cache, "hits": 4, "misses": 0}
    assert _payload_json(plain) == _payload_json(cold) == _payload_json(warm)


def test_spec_hash_keys_the_cache(tmp_path):
    s1 = Study.example("evaluate")
    s2 = dataclasses.replace(s1, name="renamed")  # cosmetic -> same hash
    s3 = dataclasses.replace(
        s1, constraints=dataclasses.replace(s1.constraints, thermal_limit_c=50.0)
    )
    assert study_hash(s1) == study_hash(s2)
    assert study_hash(s1) != study_hash(s3)  # any real spec change invalidates
    # execution knobs are result-invariant and must NOT invalidate: an
    # interrupted unsharded numpy sweep can resume sharded on jax
    s4 = dataclasses.replace(
        s1, analysis=dataclasses.replace(s1.analysis, backend="jax",
                                         shard="auto", chunk=64),
    )
    assert study_hash(s1) == study_hash(s4)
    r1 = s1.run(cache=ResultCache(tmp_path))
    r2 = s2.run(cache=ResultCache(tmp_path))  # renamed: full cache hit
    assert r2.cache["misses"] == 0 and r2.cache["hits"] == r1.cache["misses"]
    r3 = s3.run(cache=ResultCache(tmp_path))  # changed: fresh directory
    assert r3.cache["hits"] == 0


def test_artifact_echoes_cache_stats(tmp_path):
    res = Study.example("evaluate").run(cache=ResultCache(tmp_path))
    d = res.to_dict()
    assert d["cache"]["misses"] >= 1
    back = StudyResult.from_dict(json.loads(res.to_json()))
    assert back.cache == res.cache
    # truncated chunk files are recomputed, not trusted
    study = Study.example("evaluate")
    chunk = next((ResultCache(tmp_path).study_dir(study) / "chunks").glob("*.json"))
    chunk.write_text("{not json")
    again = study.run(cache=ResultCache(tmp_path))
    assert again.cache["misses"] == 1
    assert _payload_json(again) == _payload_json(res)


def test_cli_cache_and_resume_roundtrip(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")

    def cli(*args, **kw):
        r = subprocess.run([sys.executable, "-m", "repro", *args],
                           capture_output=True, text=True, cwd=tmp_path,
                           env=env, **kw)
        assert r.returncode == 0, r.stderr
        return r

    spec = Study.example("evaluate").to_json()
    (tmp_path / "spec.json").write_text(spec)
    first = cli("run", "spec.json", "--cache", "cachedir", "--out", "a.json")
    assert "0 chunk(s) reused" in first.stderr
    resumed = cli("run", "--resume", "cachedir", "--out", "b.json")
    assert "0 computed" in resumed.stderr
    a = json.loads((tmp_path / "a.json").read_text())
    b = json.loads((tmp_path / "b.json").read_text())
    assert a["payload"] == b["payload"]
    # the cache directory layout is spec-hashed and self-describing
    study_dirs = [p for p in (tmp_path / "cachedir").iterdir() if p.is_dir()]
    assert len(study_dirs) == 1
    assert (study_dirs[0] / "spec.json").is_file()
    assert (study_dirs[0] / "result.json").is_file()
    assert list((study_dirs[0] / "chunks").glob("*.json"))
    # error paths: both spec and --resume / neither
    r = subprocess.run(
        [sys.executable, "-m", "repro", "run", "spec.json", "--resume", "cachedir"],
        capture_output=True, text=True, cwd=tmp_path, env=env,
    )
    assert r.returncode != 0 and "not both" in r.stderr
    r = subprocess.run(
        [sys.executable, "-m", "repro", "run", "--resume", "cachedir",
         "--cache", "other"],
        capture_output=True, text=True, cwd=tmp_path, env=env,
    )
    assert r.returncode != 0 and "drop --cache" in r.stderr
    r = subprocess.run(
        [sys.executable, "-m", "repro", "run"],
        capture_output=True, text=True, cwd=tmp_path, env=env,
    )
    assert r.returncode != 0


def test_scale_bench_smoke_api(tmp_path):
    """The benchmark's assertions (resume counters, bit-identity) run
    as part of the suite at a tiny size."""
    sys.path.insert(0, REPO)
    try:
        from benchmarks.scale_bench import run as bench_run
    finally:
        sys.path.pop(0)
    out = bench_run(points=2000, keep_cache=str(tmp_path / "bench-cache"))
    assert out["match"] and out["points"] >= 1900
    assert out["chunks"] >= 2
