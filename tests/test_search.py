"""Guided Pareto search (``core.search``): spec validation, exactness
on fully-covered spaces, determinism/resume bit-identity, worker-count
invariance, and the hypervolume metric.

Property-based tests use ``_hyp`` (real hypothesis when installed,
clean skips otherwise — CI sets REPRO_REQUIRE_HYPOTHESIS=1).
"""

import dataclasses
import json
import subprocess
import sys
import tempfile

import numpy as np
import pytest

from _hyp import given, settings, st
from repro.core.cache import ResultCache, study_hash
from repro.core.engine import pareto_mask_batched
from repro.core.search import (
    SearchSpec,
    evaluate_candidates,
    exhaustive_frontier,
    hypervolume,
    resolve_axes,
)
from repro.core.study import (
    AnalysisSpec,
    BandwidthSpec,
    SpaceSpec,
    Study,
    WorkloadSpec,
)


def _study(budgets=(2**10, 2**12), tiers=(1, 2, 4), dataflow=("dos", "ws"),
           tech=("tsv", "miv"), generations=2, population=16, refine=(2, 1),
           seed=0, workers=None, **search_kw) -> Study:
    return Study(
        name="search-test",
        workload=WorkloadSpec(kind="gemms", gemms=((64, 8, 64), (128, 16, 96))),
        space=SpaceSpec(mac_budgets=budgets, tiers=tiers, dataflow=dataflow,
                        tech=tech),
        analysis=AnalysisSpec(
            kind="search",
            bandwidth=BandwidthSpec.paper_default(),
            search=SearchSpec(objectives=("cycles", "energy_j"),
                              generations=generations, population=population,
                              refine=refine, seed=seed, **search_kw),
            workers=workers,
        ),
    )


def _frontier_set(payload_or_ex) -> set:
    return {tuple(c) for c in np.asarray(payload_or_ex["frontier_candidates"])}


# ---------------------------------------------------------------------------
# Spec validation + round-trip
# ---------------------------------------------------------------------------

def test_searchspec_validation():
    with pytest.raises(ValueError, match="objective"):
        SearchSpec(objectives=("cyclesss",))
    with pytest.raises(ValueError, match="generations"):
        SearchSpec(generations=0)
    with pytest.raises(ValueError, match="population"):
        SearchSpec(population=0)
    with pytest.raises(ValueError, match="refine"):
        SearchSpec(refine=(4, 0))
    with pytest.raises(ValueError, match="mutation"):
        SearchSpec(mutation=0.8, crossover=0.4)
    with pytest.raises(ValueError, match="ref_point"):
        SearchSpec(objectives=("cycles", "energy_j"), ref_point=(1.0,))
    with pytest.raises(ValueError, match="dram_gbs"):
        SearchSpec(dram_gbs=(0.0,))


def test_search_example_spec_roundtrip():
    s = Study.example("search")
    assert s.analysis.kind == "search"
    assert Study.from_json(s.to_json()).to_json() == s.to_json()
    # a dict-valued search field coerces to SearchSpec
    d = json.loads(s.to_json())
    assert isinstance(Study.from_dict(d).analysis.search, SearchSpec)


def test_workers_is_not_part_of_the_spec_hash():
    a, b = _study(workers=None), _study(workers=4)
    assert study_hash(a) == study_hash(b)


def test_search_requires_bandwidth_for_memory_axes():
    with pytest.raises(ValueError, match="bandwidth"):
        Study(
            workload=WorkloadSpec(kind="gemms", gemms=((64, 8, 64),)),
            space=SpaceSpec(mac_budgets=(2**10,), tiers=(1, 2)),
            analysis=AnalysisSpec(kind="search",
                                  search=SearchSpec(dram_gbs=(64.0, 256.0))),
        )


# ---------------------------------------------------------------------------
# Exactness: full coverage == exhaustive reference
# ---------------------------------------------------------------------------

def test_search_full_coverage_equals_exhaustive():
    study = _study()  # 24-point space, 2 x 16 budget => fully enumerated
    ex = exhaustive_frontier(study)
    res = study.run()
    p = res.payload
    assert p["space_size"] == 24
    assert p["n_evaluated"] == 24
    assert _frontier_set(p) == _frontier_set(ex)
    np.testing.assert_array_equal(
        p["frontier_objectives"], ex["frontier_objectives"]
    )
    ref = np.max(ex["frontier_objectives"], axis=0) + 1.0
    assert hypervolume(p["frontier_objectives"], ref) == pytest.approx(
        hypervolume(ex["frontier_objectives"], ref)
    )


def test_search_frontier_is_mutually_nondominated_and_feasible():
    study = _study(budgets=(2**10, 2**12, 2**14, 2**16), generations=3,
                   population=8, refine=(2, 1, 1))  # partial coverage
    p = study.run().payload
    assert 0 < p["n_evaluated"] < p["space_size"]
    F = p["frontier_objectives"]
    assert len(F) >= 1 and np.isfinite(F).all()
    assert pareto_mask_batched(F[None]).all()
    # frontier candidates index real axis values, and re-pricing them
    # reproduces the archived objectives exactly
    axes = resolve_axes(study)
    cands = np.asarray(p["frontier_candidates"])
    objs, feas = evaluate_candidates(study, cands, axes=axes)
    assert feas.all()
    np.testing.assert_array_equal(objs, F)


# ---------------------------------------------------------------------------
# Determinism, resume, worker invariance
# ---------------------------------------------------------------------------

def test_search_same_seed_bit_identical():
    a, b = _study().run(), _study().run()
    assert a.to_json() == b.to_json()


def test_search_resume_zero_recompute(tmp_path):
    study = _study()
    cold = study.run(cache=ResultCache(tmp_path))
    assert cold.cache["hits"] == 0 and cold.cache["misses"] > 0
    warm = study.run(cache=ResultCache(tmp_path))
    assert warm.cache["misses"] == 0
    assert warm.cache["hits"] == cold.cache["misses"]
    assert warm.to_dict()["payload"] == cold.to_dict()["payload"]


def test_search_cached_equals_uncached(tmp_path):
    study = _study()
    plain = study.run()
    cached = study.run(cache=ResultCache(tmp_path, block_cells=8))
    assert cached.to_dict()["payload"] == plain.to_dict()["payload"]


def test_search_workers_bit_identical(tmp_path):
    study = _study()
    one = study.run(cache=ResultCache(tmp_path / "w1", block_cells=8))
    two = dataclasses.replace(
        study, analysis=dataclasses.replace(study.analysis, workers=2)
    ).run(cache=ResultCache(tmp_path / "w2", block_cells=8))
    assert one.to_dict()["payload"] == two.to_dict()["payload"]


def test_search_cli_run_with_workers(tmp_path):
    spec = tmp_path / "spec.json"
    spec.write_text(_study().to_json())
    out = subprocess.run(
        [sys.executable, "-m", "repro", "run", str(spec), "--workers", "2",
         "--cache", str(tmp_path / "cache")],
        capture_output=True, text=True, check=True,
    )
    payload = json.loads(out.stdout)["payload"]
    assert payload["n_evaluated"] == 24
    direct = json.loads(_study().run().to_json())["payload"]
    assert payload == direct


# ---------------------------------------------------------------------------
# Hypervolume
# ---------------------------------------------------------------------------

def test_hypervolume_closed_forms():
    assert hypervolume(np.array([[0.0, 0.0]]), (1.0, 1.0)) == 1.0
    # staircase: 1*0.5 + 0.5*1 - overlap 0.5*0.5
    assert hypervolume(
        np.array([[0.0, 0.5], [0.5, 0.0]]), (1.0, 1.0)
    ) == pytest.approx(0.75)
    assert hypervolume(np.array([[0.0, 0.0, 0.0]]), (2.0, 2.0, 2.0)) == 8.0
    # dominated + out-of-reference points contribute nothing
    assert hypervolume(
        np.array([[0.0, 0.0], [0.5, 0.5], [2.0, -1.0], [np.nan, 0.0]]),
        (1.0, 1.0),
    ) == 1.0
    assert hypervolume(np.zeros((0, 2)), (1.0, 1.0)) == 0.0


def test_hypervolume_3d_matches_monte_carlo():
    rng = np.random.default_rng(0)
    pts = rng.random((32, 3))
    ref = (1.0, 1.0, 1.0)
    hv = hypervolume(pts, ref)
    samples = rng.random((200_000, 3))
    covered = (samples[:, None, :] >= pts[None, :, :]).all(-1).any(-1)
    assert hv == pytest.approx(covered.mean(), abs=5e-3)


# ---------------------------------------------------------------------------
# Properties (hypothesis)
# ---------------------------------------------------------------------------

@settings(max_examples=5, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_prop_full_coverage_frontier_equals_exhaustive(seed):
    study = _study(seed=seed)
    ex = exhaustive_frontier(study)
    p = study.run().payload
    assert p["n_evaluated"] == p["space_size"]
    assert _frontier_set(p) == _frontier_set(ex)
    ref = np.max(ex["frontier_objectives"], axis=0) + 1.0
    assert hypervolume(p["frontier_objectives"], ref) == pytest.approx(
        hypervolume(ex["frontier_objectives"], ref), rel=1e-12
    )


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_prop_partial_coverage_frontier_subset_of_exhaustive(seed):
    # partial budget (48-point space, 3 x 8 = 24 evaluated): the guided
    # frontier stays feasible and mutually nondominated for every seed,
    # its hv can only undershoot the exhaustive reference, and where it
    # overlaps the true frontier the objectives are bit-identical.
    study = _study(budgets=(2**10, 2**12, 2**14, 2**16), tiers=(1, 2, 4),
                   generations=3, population=8, refine=(2, 1, 1), seed=seed)
    ex = exhaustive_frontier(study)
    p = study.run().payload
    assert p["n_evaluated"] < p["space_size"]
    guided, exact = _frontier_set(p), _frontier_set(ex)
    covered = guided & exact
    # feasible, mutually nondominated, and hv-bounded regardless of seed
    assert pareto_mask_batched(np.asarray(p["frontier_objectives"])[None]).all()
    ref = np.max(ex["frontier_objectives"], axis=0) + 1.0
    hv_ex = hypervolume(ex["frontier_objectives"], ref)
    hv_g = hypervolume(p["frontier_objectives"], ref)
    assert hv_g <= hv_ex * (1 + 1e-12)
    # and the points it shares with the true frontier carry identical
    # objectives (bit-exact re-evaluation)
    if covered:
        ex_map = {
            tuple(c): tuple(o)
            for c, o in zip(ex["frontier_candidates"], ex["frontier_objectives"])
        }
        g_map = {
            tuple(c): tuple(o)
            for c, o in zip(p["frontier_candidates"], p["frontier_objectives"])
        }
        for c in covered:
            assert g_map[c] == ex_map[c]


@settings(max_examples=3, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_prop_same_seed_identical_including_resume(seed):
    study = _study(seed=seed)
    plain = study.run()
    assert study.run().to_json() == plain.to_json()
    with tempfile.TemporaryDirectory() as root:
        cold = study.run(cache=ResultCache(root, block_cells=8))
        warm = study.run(cache=ResultCache(root, block_cells=8))
        assert warm.cache["misses"] == 0
        assert cold.to_dict()["payload"] == plain.to_dict()["payload"]
        assert warm.to_dict()["payload"] == plain.to_dict()["payload"]
