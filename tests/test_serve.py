"""The serving-traffic subsystem (``core.serve``): sampler truncation
bounds, seeded determinism (bit-identical payloads, including across a
half-populated cache resume), token conservation through the queue, a
closed-form single-request trace checked against direct engine pricing,
and fail-fast spec validation.

These tests deliberately avoid hypothesis so they always run under the
tier-1 ``pytest -x -q`` command.
"""

import dataclasses
import json

import numpy as np
import pytest

from repro.analysis.traffic import (
    kv_bytes_per_context_token,
    state_bytes_per_request,
)
from repro.configs import REGISTRY, SHAPES
from repro.core.cache import ResultCache
from repro.core.engine import DesignGrid, evaluate
from repro.core.network import lower_network
from repro.core.ppa import constants as C
from repro.core.serve import ServeSpec, TrafficSpec, sample_trace
from repro.core.study import (
    AnalysisSpec,
    BandwidthSpec,
    ConstraintSpec,
    SpaceSpec,
    Study,
    StudyResult,
    WorkloadSpec,
)


def tiny_serve_study(**traffic_kw) -> Study:
    kw = dict(
        arrival_rps=4096.0,
        n_requests=6,
        prompt_mean=32,
        prompt_max=128,
        output_mean=6,
        output_max=24,
        max_batch=3,
        chunk_prefill=16,
        seed=0,
    )
    kw.update(traffic_kw)
    return Study(
        name="tiny-serve",
        workload=WorkloadSpec(kind="network", arch="smollm-135m",
                              shape="decode_32k"),
        space=SpaceSpec(mac_budgets=(2**14,), tiers=(1, 2, 4)),
        analysis=AnalysisSpec(
            kind="serve",
            bandwidth=BandwidthSpec.paper_default(),
            serve=ServeSpec(traffic=TrafficSpec(**kw)),
        ),
    )


# ---------------------------------------------------------------------------
# Fail-fast validation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize(
    "bad, fragment",
    [
        (lambda: TrafficSpec(policy="dynamic"), "'continuous'"),
        (lambda: TrafficSpec(prompt_dist="gaussian"), "'lognormal'"),
        (lambda: TrafficSpec(output_dist="zipf"), "'fixed'"),
        (lambda: TrafficSpec(arrival_rps=0.0), "positive"),
        (lambda: TrafficSpec(arrival_rps=-3.0), "positive"),
        (lambda: TrafficSpec(sigma=0.0), "positive"),
        (lambda: TrafficSpec(n_requests=0), ">= 1"),
        (lambda: TrafficSpec(max_batch=0), ">= 1"),
        (lambda: TrafficSpec(prompt_mean=512, prompt_max=128), "truncation"),
        (lambda: TrafficSpec(chunk_prefill=-1), ">= 0"),
        (lambda: ServeSpec(bytes_kv=0), ">= 1"),
        (lambda: ServeSpec(design_tokens=0), ">= 1"),
        (lambda: ServeSpec(traffic=3), "TrafficSpec"),
        (lambda: AnalysisSpec(kind="serve", serve="nope"), "ServeSpec"),
    ],
)
def test_spec_validation_lists_choices(bad, fragment):
    with pytest.raises(ValueError, match=".*"):
        try:
            bad()
        except ValueError as e:
            assert fragment in str(e), (fragment, str(e))
            raise


def test_serve_needs_network_workload():
    s = Study(
        workload=WorkloadSpec(kind="gemms", gemms=((64, 64, 64),)),
        analysis=AnalysisSpec(kind="serve"),
    )
    with pytest.raises(ValueError, match="network"):
        s.run()


def test_serve_kind_defaults_spec():
    a = AnalysisSpec(kind="serve")
    assert isinstance(a.serve, ServeSpec)
    assert isinstance(a.serve.traffic, TrafficSpec)


def test_spec_json_round_trip():
    s = tiny_serve_study()
    s2 = Study.from_json(s.to_json())
    assert s2 == s
    # dict traffic coerces like every other nested spec
    d = s.analysis.serve.to_dict()
    assert ServeSpec.from_dict(d) == s.analysis.serve


# ---------------------------------------------------------------------------
# Sampler: truncation bounds + determinism
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dist", ["fixed", "uniform", "lognormal"])
def test_sample_trace_truncation_bounds(dist):
    spec = TrafficSpec(
        n_requests=512, prompt_dist=dist, prompt_mean=64, prompt_max=96,
        output_dist=dist, output_mean=16, output_max=20, sigma=1.5, seed=3,
    )
    tr = sample_trace(spec)
    for key, bound in (("prompt_lens", 96), ("output_lens", 20)):
        v = tr[key]
        assert v.dtype == np.int64
        assert v.min() >= 1
        assert v.max() <= bound
    if dist == "fixed":
        assert (tr["prompt_lens"] == 64).all()
        assert (tr["output_lens"] == 16).all()
    assert (np.diff(tr["arrival_s"]) > 0).all()


def test_sample_trace_seeded():
    a = sample_trace(TrafficSpec(seed=7))
    b = sample_trace(TrafficSpec(seed=7))
    c = sample_trace(TrafficSpec(seed=8))
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])
    assert any(not np.array_equal(a[k], c[k]) for k in a)


# ---------------------------------------------------------------------------
# Simulator invariants
# ---------------------------------------------------------------------------

def test_conservation_and_determinism():
    s = tiny_serve_study()
    r1 = s.run()
    r2 = s.run()
    p = r1.payload
    pts = p["points"]
    # every admitted token retires, on every design point
    assert (pts["tokens_prefilled"] == p["trace"]["tokens_in"]).all()
    assert (pts["tokens_decoded"] == p["trace"]["tokens_out"]).all()
    # same seed -> bit-identical payload (strict JSON form)
    assert (
        json.dumps(r1.to_dict()["payload"], sort_keys=True)
        == json.dumps(r2.to_dict()["payload"], sort_keys=True)
    )
    # artifact JSON round-trip restores the typed arrays exactly
    r3 = StudyResult.from_json(r1.to_json())
    for k, v in pts.items():
        np.testing.assert_array_equal(v, r3.payload["points"][k], err_msg=k)
    # metrics are sane on this all-feasible grid
    assert pts["feasible"].all()
    assert (pts["gen_tok_s"] > 0).all()
    assert (pts["ttft_p99_s"] >= pts["ttft_p50_s"]).all()
    assert (pts["tpot_p99_s"] >= pts["tpot_p50_s"]).all()


def test_static_policy_and_unchunked_prefill():
    # static batching drains whole batches; chunk_prefill=0 prefills a
    # prompt in one step — both must conserve tokens all the same
    s = tiny_serve_study(policy="static", chunk_prefill=0)
    p = s.run().payload
    pts = p["points"]
    assert (pts["tokens_prefilled"] == p["trace"]["tokens_in"]).all()
    assert (pts["tokens_decoded"] == p["trace"]["tokens_out"]).all()
    # static batching can never beat continuous on makespan
    cont = tiny_serve_study(chunk_prefill=0).run().payload["points"]
    assert (pts["makespan_s"] >= cont["makespan_s"] - 1e-12).all()


def test_resume_bit_identical(tmp_path):
    s = tiny_serve_study()
    n = s.analysis.serve.traffic.n_requests
    cold = s.run(cache=ResultCache(tmp_path, block_cells=n))  # 1 point/chunk
    ref = json.dumps(cold.to_dict()["payload"], sort_keys=True)
    files = sorted(tmp_path.glob("*/chunks/points-*.json"))
    assert len(files) == 3
    for f in files[::2]:
        f.unlink()
    resumed = s.run(cache=ResultCache(tmp_path, block_cells=n))
    assert resumed.cache["misses"] == 2 and resumed.cache["hits"] == 1
    assert json.dumps(resumed.to_dict()["payload"], sort_keys=True) == ref
    warm = s.run(cache=ResultCache(tmp_path, block_cells=n))
    assert warm.cache["misses"] == 0
    assert json.dumps(warm.to_dict()["payload"], sort_keys=True) == ref


# ---------------------------------------------------------------------------
# Closed form: one request, fixed lengths, vs direct engine pricing
# ---------------------------------------------------------------------------

def test_single_request_matches_direct_engine_pricing():
    arch, shape_name = "smollm-135m", "decode_32k"
    prompt, output = 32, 2
    rows, cols, tiers = 16, 16, 2
    bw = BandwidthSpec.paper_default()
    s = Study(
        workload=WorkloadSpec(kind="network", arch=arch, shape=shape_name),
        space=SpaceSpec(rows=(rows,), cols=(cols,), tiers=(tiers,)),
        analysis=AnalysisSpec(
            kind="serve",
            bandwidth=bw,
            serve=ServeSpec(traffic=TrafficSpec(
                n_requests=1,
                prompt_dist="fixed", prompt_mean=prompt, prompt_max=prompt,
                output_dist="fixed", output_mean=output, output_max=output,
                max_batch=1, chunk_prefill=0, seed=0,
            )),
        ),
    )
    p = s.run().payload
    pts = p["points"]
    assert pts["steps"][0] == 2  # one prefill step + one decode step

    # direct engine pricing of the two steps: the per-token GEMM stream
    # at M=prompt (prefill) and M=1 (decode), plus the serialized
    # kv-cache service time
    cfg = REGISTRY[arch]
    step_shape = dataclasses.replace(
        SHAPES[shape_name], global_batch=1, mode="decode"
    )
    stream = lower_network(cfg, step_shape)
    K, N = stream.workloads[:, 1], stream.workloads[:, 2]
    counts = stream.counts.astype(np.float64)
    bpc = bw.dram_bytes_per_cycle
    kv_tok = kv_bytes_per_context_token(cfg)
    ssm = state_bytes_per_request(cfg)

    def step_cycles(m, kv_bytes):
        wl = np.column_stack([np.full(K.size, m, dtype=np.int64), K, N])
        grid = DesignGrid.explicit(wl, rows=(rows,), cols=(cols,),
                                   tiers=(tiers,))
        res = evaluate(grid, metrics=("perf",), bandwidth=bw)
        return float(np.sum(counts * res.cycles[:, 0])) + kv_bytes / bpc

    pf_cycles = step_cycles(prompt, prompt * kv_tok)
    # at the decode step the request has prompt + 1 tokens of context
    dec_cycles = step_cycles(1, (prompt + 1 + 1) * kv_tok + ssm)

    assert pts["ttft_p50_s"][0] == pytest.approx(
        pf_cycles / C.FREQ_HZ, rel=1e-12
    )
    # TPOT = decode step time per generated-after-first token
    assert pts["tpot_p50_s"][0] == pytest.approx(
        dec_cycles / C.FREQ_HZ, rel=1e-12
    )
    # makespan = arrival gap + both steps
    arrival = sample_trace(s.analysis.serve.traffic)["arrival_s"][0]
    assert pts["makespan_s"][0] == pytest.approx(
        arrival + (pf_cycles + dec_cycles) / C.FREQ_HZ, rel=1e-12
    )
