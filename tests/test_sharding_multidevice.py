"""Multi-device numerics: sharded == single-device, elastic restore,
pipeline parallelism, compression. Each case runs in a subprocess with
fake CPU devices (the main test process must keep 1 device)."""

import pytest

from conftest import run_multidevice


def test_loss_invariant_across_meshes_and_strategies():
    out = run_multidevice("""
        import jax, jax.numpy as jnp
        from repro.configs import REGISTRY, reduced
        from repro.models import build
        from repro.parallel.axes import ShardingRules, param_sharding, use_rules
        import numpy as np

        cfg = reduced(REGISTRY["qwen2.5-3b"])
        model = build(cfg)
        params = model.init(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab)
        batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
        ref = float(model.loss(params, batch))
        for (d, m) in [(2, 4), (4, 2), (8, 1), (1, 8)]:
            for strat in ("dos", "megatron"):
                mesh = jax.make_mesh((d, m), ("data", "model"))
                rules = ShardingRules(mesh, strategy=strat, fsdp=True)
                ps = param_sharding(model.defs, rules)
                with use_rules(rules), mesh:
                    p = jax.device_put(params, ps)
                    got = float(jax.jit(model.loss)(p, batch))
                assert abs(got - ref) < 5e-3, (d, m, strat, got, ref)
        print("MESH_NUMERICS_OK")
    """)
    assert "MESH_NUMERICS_OK" in out


def test_elastic_checkpoint_restore_across_meshes(tmp_path):
    out = run_multidevice(f"""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import REGISTRY, reduced
        from repro.models import build
        from repro.checkpoint import checkpointer
        from repro.runtime import elastic_restore
        from repro.parallel.axes import ShardingRules, param_sharding

        cfg = reduced(REGISTRY["smollm-135m"])
        model = build(cfg)
        params = model.init(jax.random.PRNGKey(0))
        # save on a (4, 2) mesh
        mesh_a = jax.make_mesh((4, 2), ("data", "model"))
        ps_a = param_sharding(model.defs, ShardingRules(mesh_a, "dos", fsdp=True))
        pa = jax.device_put(params, ps_a)
        checkpointer.save(r"{tmp_path}", 3, pa)
        # restore on a (2, 2) mesh — "lost a pod", half the devices
        mesh_b = jax.make_mesh((2, 2), ("data", "model"))
        ps_b = param_sharding(model.defs, ShardingRules(mesh_b, "dos", fsdp=True))
        pb = elastic_restore(r"{tmp_path}", 3, pa, ps_b)
        for x, y in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        print("ELASTIC_OK")
    """)
    assert "ELASTIC_OK" in out


def test_pipeline_matches_reference():
    out = run_multidevice("""
        import jax, jax.numpy as jnp, dataclasses
        from repro.configs import get_config, reduced
        from repro.models import build
        from repro.parallel.pipeline import make_gpipe_loss
        cfg = dataclasses.replace(reduced(get_config("smollm-135m")), n_layers=4)
        model = build(cfg)
        params = model.init(jax.random.PRNGKey(0))
        mesh = jax.make_mesh((4,), ("pod",))
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab)
        batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
        ref = float(model.loss(params, batch))
        loss_fn = make_gpipe_loss(cfg, mesh, n_stages=4, n_microbatches=4)
        with mesh:
            pl = float(jax.jit(loss_fn)(params, batch))
        assert abs(ref - pl) < 1e-4, (ref, pl)
        g = jax.jit(jax.grad(loss_fn))(params, batch)
        assert all(bool(jnp.all(jnp.isfinite(x))) for x in jax.tree.leaves(g))
        print("PIPELINE_OK")
    """, n_devices=4)
    assert "PIPELINE_OK" in out


def test_compressed_grad_sync():
    out = run_multidevice("""
        import jax, jax.numpy as jnp
        from repro.parallel.compression import compressed_psum_grads, init_error_state
        mesh = jax.make_mesh((8,), ("data",))
        g = {"w": jnp.linspace(-1, 1, 256).reshape(16, 16)}
        e = init_error_state(g)
        gh, ne = jax.jit(lambda g, e: compressed_psum_grads(g, e, mesh))(g, e)
        err = float(jnp.max(jnp.abs(gh["w"] - g["w"])))
        assert err < 1e-2, err           # int8 quantization error bound
        # error feedback: residual equals what the quantizer dropped
        assert float(jnp.max(jnp.abs(ne["w"]))) < 1e-2
        print("COMPRESS_OK")
    """)
    assert "COMPRESS_OK" in out


def test_dryrun_cell_mini_mesh():
    """End-to-end dry-run machinery on a small mesh-shaped problem:
    lower+compile one reduced arch with full shardings + roofline."""
    out = run_multidevice("""
        import jax, jax.numpy as jnp, dataclasses
        from repro.configs import REGISTRY, reduced
        from repro.config import ShapeConfig
        from repro.models import build
        from repro.parallel.axes import ShardingRules, use_rules
        from repro.parallel.plan import make_plan
        from repro.launch.steps import make_train_step, make_serve_step
        from repro.optim import OptConfig
        from repro.analysis.roofline import parse_collectives
        from repro._jax_compat import unwrap_cost_analysis

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = reduced(REGISTRY["gemma3-1b"])
        model = build(cfg)
        shape = ShapeConfig("t", 64, 4, "train")
        rules = ShardingRules(mesh, strategy="dos", fsdp=True)
        plan = make_plan(model, shape, rules)
        step = make_train_step(model, OptConfig())
        with use_rules(rules), mesh:
            lowered = jax.jit(step, in_shardings=plan.in_shardings,
                              out_shardings=plan.out_shardings).lower(*plan.abstract)
            compiled = lowered.compile()
        cost = unwrap_cost_analysis(compiled.cost_analysis())
        assert cost.get("flops", 0) > 0
        coll = parse_collectives(compiled.as_text())
        assert coll.wire_bytes > 0  # dOS must produce collectives
        mem = compiled.memory_analysis()
        assert mem.temp_size_in_bytes > 0
        # decode plan lowers too
        shape_d = ShapeConfig("d", 64, 4, "decode")
        plan_d = make_plan(model, shape_d, rules)
        serve = make_serve_step(model)
        with use_rules(rules), mesh:
            c2 = jax.jit(serve, in_shardings=plan_d.in_shardings,
                         out_shardings=plan_d.out_shardings).lower(*plan_d.abstract).compile()
        assert unwrap_cost_analysis(c2.cost_analysis()).get("flops", 0) > 0
        print("DRYRUN_MINI_OK")
    """)
    assert "DRYRUN_MINI_OK" in out


def test_moe_expert_parallel_matches_oracle():
    out = run_multidevice("""
        import jax, jax.numpy as jnp
        from repro.configs import REGISTRY, reduced
        from repro.models import build
        from repro.models.moe import moe_block
        from repro.parallel.moe_ep import moe_block_ep
        cfg = reduced(REGISTRY["deepseek-moe-16b"])
        model = build(cfg)
        params = model.init(jax.random.PRNGKey(0))
        lp = jax.tree.map(lambda a: a[0], params["layers"])["ffn"]
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))
        ref = moe_block(lp, x, cfg)
        with mesh:
            got = jax.jit(lambda p_, x_: moe_block_ep(p_, x_, cfg, mesh))(lp, x)
        err = float(jnp.max(jnp.abs(got - ref)))
        assert err < 1e-4, err
        print("MOE_EP_OK")
    """)
    assert "MOE_EP_OK" in out
