"""The Study front door: spec/artifact JSON round-trips, Study-vs-
direct-engine equivalence across every analysis kind, shared option
validation at the API boundary, the deprecation shims, and a CLI
smoke (``python -m repro run`` on a tiny spec).

These tests deliberately avoid hypothesis so they always run under the
tier-1 ``pytest -x -q`` command.
"""

import dataclasses
import json

import numpy as np
import pytest

from repro.configs import REGISTRY, SHAPES
from repro.core.engine import DesignGrid, EvalResult, NetworkReport, evaluate, schedule
from repro.core.network import lower_network
from repro.core.study import (
    ANALYSIS_KINDS,
    AnalysisSpec,
    ConstraintSpec,
    SpaceSpec,
    Study,
    StudyResult,
    WorkloadSpec,
    _jsonify,
)

WL = ((64, 12100, 147), (512, 784, 128), (35, 2560, 4096))
SPACE = SpaceSpec(mac_budgets=(2**14, 2**16), tiers=tuple(range(1, 9)))
TINY_SPACE = SpaceSpec(mac_budgets=(2**10, 2**12), tiers=(1, 2, 4))


def _assert_eval_equal(a: EvalResult, b: EvalResult):
    for f in dataclasses.fields(EvalResult):
        if f.name == "grid":
            continue
        va, vb = getattr(a, f.name), getattr(b, f.name)
        if va is None or vb is None:
            assert va is None and vb is None, f.name
        else:
            np.testing.assert_array_equal(va, vb, err_msg=f.name)


# ---------------------------------------------------------------------------
# Early validation at every API boundary (one shared validator)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize(
    "bad",
    [
        lambda: SpaceSpec(tech="tvs"),
        lambda: SpaceSpec(dataflow="wss"),
        lambda: SpaceSpec(mode="optt"),
        lambda: AnalysisSpec(kind="evaluatee"),
        lambda: AnalysisSpec(metrics=("perf", "powr")),
        lambda: AnalysisSpec(backend="torch"),
        lambda: AnalysisSpec(kind="sweep", figure="fig9"),
        lambda: WorkloadSpec(kind="network", arch="nope-7b", shape="train_4k"),
        lambda: WorkloadSpec(kind="network", arch="smollm-135m", shape="huge"),
        lambda: DesignGrid.product([(1, 2, 3)], [16], [1], tech="tvs"),
        lambda: DesignGrid.product([(1, 2, 3)], [16], [1], dataflow="wss"),
        lambda: DesignGrid.product(
            [(1, 2, 3)], [16], [1, 2], tech=np.array(["tsv", "miv2"])
        ),
    ],
)
def test_invalid_options_fail_fast_with_choices_listed(bad):
    with pytest.raises(ValueError, match="valid options"):
        bad()


def test_invalid_options_in_engine_calls():
    grid = DesignGrid.product([(8, 8, 8)], [64], [1])
    with pytest.raises(ValueError, match="valid options"):
        evaluate(grid, backend="torch")
    with pytest.raises(ValueError, match="valid options"):
        evaluate(grid, metrics=("perf", "powr"))
    stream = lower_network(REGISTRY["smollm-135m"], SHAPES["decode_32k"])
    with pytest.raises(ValueError, match="valid options"):
        schedule(stream, dataflow="wss")
    with pytest.raises(ValueError, match="valid options"):
        schedule(stream, tech="tvs")


def test_workload_spec_structural_validation():
    with pytest.raises(ValueError, match="gemms"):
        WorkloadSpec(kind="gemms")
    with pytest.raises(ValueError, match="counts"):
        WorkloadSpec(kind="gemms", gemms=WL, counts=(1, 2))
    with pytest.raises(ValueError, match="n >= 1"):
        WorkloadSpec(kind="random", n=0)


# ---------------------------------------------------------------------------
# Spec JSON round-trips (every analysis kind)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ANALYSIS_KINDS)
def test_example_spec_json_roundtrip(kind):
    study = Study.example(kind)
    assert Study.from_json(study.to_json()) == study


def test_custom_spec_json_roundtrip():
    study = Study(
        name="custom",
        workload=WorkloadSpec(kind="gemms", gemms=WL, counts=(3, 2, 1)),
        space=SpaceSpec(
            mac_budgets=(2**12, 2**14),
            tiers=(1, 4),
            dataflow=("dos", "ws"),
            tech=("tsv", "miv"),
            layout="explicit",
        ),
        constraints=ConstraintSpec(
            thermal_limit_c=60.0, max_area_um2=1e9, max_mac_budget=2**14
        ),
        analysis=AnalysisSpec(kind="pareto", objectives=("cycles", "power_w")),
    )
    rt = Study.from_json(study.to_json())
    assert rt == study
    # lists coming back from JSON normalize to the same tuples
    assert rt.space.dataflow == ("dos", "ws")
    assert rt.workload.counts == (3, 2, 1)


def test_explicit_rows_cols_spec_roundtrip_and_run():
    study = Study(
        workload=WorkloadSpec(kind="gemms", gemms=((64, 300, 64),)),
        space=SpaceSpec(
            mac_budgets=None, rows=(16, 32), cols=(16, 32), tiers=(2, 2)
        ),
        analysis=AnalysisSpec(metrics=("perf",)),
    )
    assert Study.from_json(study.to_json()) == study
    res = study.run().result
    direct = evaluate(
        DesignGrid.explicit([(64, 300, 64)], rows=(16, 32), cols=(16, 32), tiers=(2, 2)),
        metrics=("perf",),
    )
    _assert_eval_equal(res, direct)


# ---------------------------------------------------------------------------
# EvalResult / NetworkReport lossless to_dict <-> from_dict
# ---------------------------------------------------------------------------

def test_evalresult_json_roundtrip_lossless():
    grid = DesignGrid.product(WL, (2**12, 2**16), range(1, 5))
    res = evaluate(grid)
    d = json.loads(json.dumps(_jsonify(res.to_dict())))
    res2 = EvalResult.from_dict(d)
    _assert_eval_equal(res, res2)
    assert res2.rows.dtype == np.int64 and res2.cols.dtype == np.int64
    assert res2.valid.dtype == bool and res2.within_thermal_budget.dtype == bool
    g = res2.grid
    np.testing.assert_array_equal(g.workloads, grid.workloads)
    np.testing.assert_array_equal(g.tiers, grid.tiers)
    np.testing.assert_array_equal(g.mac_budgets, grid.mac_budgets)
    assert g.dataflow == grid.dataflow and g.tech == grid.tech


def test_networkreport_json_roundtrip_lossless():
    stream = lower_network(REGISTRY["gemma3-1b"], SHAPES["decode_32k"])
    rep = schedule(stream, mac_budgets=(2**14, 2**16), tiers=range(1, 9))
    rep2 = NetworkReport.from_dict(json.loads(json.dumps(rep.to_dict())))
    assert rep2.to_dict() == rep.to_dict()
    assert np.asarray(rep2.fixed.design).dtype == np.int64
    assert rep2.per_layer.design.shape == (rep.n_gemms, 3)


# ---------------------------------------------------------------------------
# Study.run == direct engine calls (all analysis kinds)
# ---------------------------------------------------------------------------

def test_study_evaluate_matches_direct_engine():
    study = Study(workload=WorkloadSpec(kind="gemms", gemms=WL), space=SPACE)
    res = study.run()
    direct = evaluate(DesignGrid.product(WL, SPACE.mac_budgets, SPACE.tiers))
    _assert_eval_equal(res.result, direct)
    assert res.payload["n_valid"] == int(direct.valid.sum())
    # artifact round-trip preserves the arrays bit-for-bit
    res2 = StudyResult.from_json(res.to_json())
    _assert_eval_equal(res2.result, direct)


def test_study_schedule_matches_direct_engine():
    arch, shape = "smollm-135m", "decode_32k"
    study = Study(
        workload=WorkloadSpec(kind="network", arch=arch, shape=shape),
        space=SPACE,
        analysis=AnalysisSpec(kind="schedule"),
    )
    rep = study.run().report
    direct = schedule(
        lower_network(REGISTRY[arch], SHAPES[shape]),
        mac_budgets=SPACE.mac_budgets,
        tiers=SPACE.tiers,
    )
    assert rep.to_dict() == direct.to_dict()


def test_study_pareto_matches_pareto_mask():
    study = Study(
        workload=WorkloadSpec(kind="gemms", gemms=WL),
        space=SPACE,
        analysis=AnalysisSpec(kind="pareto", objectives=("cycles", "power_w")),
    )
    out = study.run()
    direct = evaluate(DesignGrid.product(WL, SPACE.mac_budgets, SPACE.tiers))
    np.testing.assert_array_equal(
        out.payload["pareto_mask"], direct.pareto_mask(("cycles", "power_w"))
    )


def test_study_advise_matches_rank_impl():
    from repro.core.advisor import _rank

    wl = ((64, 1 << 20, 64), (4096, 512, 4096))
    study = Study(
        workload=WorkloadSpec(kind="gemms", gemms=wl),
        analysis=AnalysisSpec(kind="advise", axis=16, mac_budget=2**18),
    )
    out = study.run()
    names, totals = _rank(wl, 16, mac_budget=2**18)
    np.testing.assert_array_equal(out.payload["names"], names)
    np.testing.assert_array_equal(out.payload["totals"], totals)


def test_study_sweep_fig5_matches_direct_engine():
    from repro.core.dse import fig5_study

    budgets, ks, tiers = (2**12, 2**16), (255, 12100), tuple(range(1, 9))
    out = fig5_study(budgets, ks, tiers).run()
    wl = [(64, k, 147) for k in ks]
    direct = evaluate(DesignGrid.product(wl, budgets, tiers), metrics=("perf",))
    np.testing.assert_array_equal(
        np.asarray(out.payload["speedup"]).reshape(len(ks), -1), direct.speedup
    )


# ---------------------------------------------------------------------------
# Constraint caps (beyond the engine's thermal mask)
# ---------------------------------------------------------------------------

def test_constraint_caps_strike_points():
    study = Study(
        workload=WorkloadSpec(kind="gemms", gemms=WL),
        space=SPACE,
        constraints=ConstraintSpec(max_mac_budget=2**14),
    )
    out = study.run()
    mask = out.payload["constraint_mask"]
    res = out.result
    # every surviving point sits at the small budget; the mask is a
    # strict subset of the engine's own feasibility
    budgets = np.broadcast_to(res.grid.mac_budgets, mask.shape)
    assert mask.sum() > 0
    assert np.all(budgets[mask] <= 2**14)
    assert np.all(mask <= res.feasible)
    # power cap: a tiny limit should strike everything
    study2 = Study(
        workload=WorkloadSpec(kind="gemms", gemms=WL),
        space=SPACE,
        constraints=ConstraintSpec(max_power_w=1e-6),
    )
    assert study2.run().payload["n_feasible"] == 0


def test_constraint_cap_requires_metric():
    study = Study(
        workload=WorkloadSpec(kind="gemms", gemms=WL),
        space=TINY_SPACE,
        constraints=ConstraintSpec(max_power_w=1.0),
        analysis=AnalysisSpec(metrics=("perf",)),
    )
    with pytest.raises(ValueError, match="power_w"):
        study.run()


def test_analysis_kind_guards_reject_unsupported_specs():
    wl = WorkloadSpec(kind="gemms", gemms=((64, 255, 32),))
    with pytest.raises(ValueError, match="valid options"):
        AnalysisSpec(kind="pareto", objectives=("cyclesss",))
    with pytest.raises(ValueError, match="caps"):
        Study(workload=wl, constraints=ConstraintSpec(max_power_w=1.0),
              analysis=AnalysisSpec(kind="advise")).run()
    with pytest.raises(ValueError, match="constraints"):
        Study(workload=wl, space=TINY_SPACE,
              constraints=ConstraintSpec(thermal_limit_c=50.0),
              analysis=AnalysisSpec(kind="sweep", figure="fig5")).run()
    with pytest.raises(ValueError, match="dOS"):
        Study(workload=wl, space=SpaceSpec(mac_budgets=(2**10,), tiers=(1, 2),
                                           dataflow="ws"),
              analysis=AnalysisSpec(kind="sweep", figure="fig7")).run()
    with pytest.raises(ValueError, match="product space"):
        Study(workload=wl,
              space=SpaceSpec(mac_budgets=None, rows=(8,), cols=(8,), tiers=(2,)),
              analysis=AnalysisSpec(kind="sweep", figure="fig5")).run()


# ---------------------------------------------------------------------------
# Strict-JSON artifacts: non-finite values survive, raw tokens never leak
# ---------------------------------------------------------------------------

def _assert_strict_json(s: str):
    def _no_constants(tok):
        raise AssertionError(f"non-strict JSON token {tok!r} in artifact")

    json.loads(s, parse_constant=_no_constants)


def test_artifact_with_invalid_points_is_strict_json():
    # budget < tiers -> invalid points -> inf cycles / NaN speedup
    out = Study(
        workload=WorkloadSpec(kind="gemms", gemms=((8, 8, 8),)),
        space=SpaceSpec(mac_budgets=(4, 64), tiers=(1, 8)),
    ).run()
    assert not out.result.valid.all()  # the scenario really has inf/NaN
    s = out.to_json()
    _assert_strict_json(s)
    res2 = StudyResult.from_json(s).result
    _assert_eval_equal(out.result, res2)


def test_infeasible_schedule_artifact_is_strict_json():
    # a 0.1C junction limit leaves no feasible design: PolicyResult
    # carries inf cycles / NaN temps, which must still round-trip
    out = Study(
        workload=WorkloadSpec(kind="network", arch="smollm-135m",
                              shape="decode_32k"),
        space=SpaceSpec(mac_budgets=(2**14,), tiers=(1, 2)),
        constraints=ConstraintSpec(thermal_limit_c=0.1),
        analysis=AnalysisSpec(kind="schedule"),
    ).run()
    assert not out.report.fixed.feasible
    assert np.isinf(out.report.fixed.total_cycles)
    s = out.to_json()
    _assert_strict_json(s)
    rep2 = StudyResult.from_json(s).report
    # assert_equal, not ==: the infeasible policies carry NaN t_max
    np.testing.assert_equal(rep2.to_dict(), out.report.to_dict())
    assert np.isinf(rep2.fixed.total_cycles)


# ---------------------------------------------------------------------------
# Deprecation shims: warn AND stay bit-identical
# ---------------------------------------------------------------------------

def test_fig5_shim_warns_and_matches_study():
    from repro.core.dse import fig5_study, fig5_sweep

    budgets, ks, tiers = (2**12, 2**16), (255, 12100), tuple(range(1, 9))
    with pytest.warns(DeprecationWarning, match="fig5_study"):
        t, out = fig5_sweep(budgets, ks, tiers)
    s = np.asarray(fig5_study(budgets, ks, tiers).run().payload["speedup"])
    assert t == tiers
    for bi, n in enumerate(budgets):
        for ki, k in enumerate(ks):
            assert out[(n, k)] == [float(v) for v in s[ki, bi]]


def test_fig7_shim_warns_and_matches_study():
    from repro.core.dse import fig7_scatter, fig7_study

    budgets = (2**14, 2**16)
    with pytest.warns(DeprecationWarning, match="fig7_study"):
        res = fig7_scatter(budgets, n_workloads=25, seed=0, max_tiers=8)
    best = np.asarray(
        fig7_study(budgets, 25, 0, 8).run().payload["optimal_tiers"]
    )
    for bi, r in enumerate(res):
        np.testing.assert_array_equal(r.optimal_tiers, best[:, bi])
        assert r.median == float(np.median(best[:, bi]))


def test_rank_candidates_shim_warns_and_matches_impl():
    from repro.core.advisor import _rank, rank_candidates

    wl = [(64, 1 << 20, 64), (35, 2560, 4096)]
    with pytest.warns(DeprecationWarning, match="advise"):
        names, totals = rank_candidates(wl, 16, mac_budget=2**18,
                                        thermal_limit=47.0)
    n2, t2 = _rank(wl, 16, mac_budget=2**18, thermal_limit=47.0)
    np.testing.assert_array_equal(names, n2)
    np.testing.assert_array_equal(totals, t2)


# ---------------------------------------------------------------------------
# CLI smoke: python -m repro run on a tiny spec writes a valid artifact
# ---------------------------------------------------------------------------

def test_cli_run_writes_valid_artifact(tmp_path, capsys):
    from repro.cli import main

    spec = tmp_path / "spec.json"
    Study(
        name="cli-smoke",
        workload=WorkloadSpec(kind="gemms", gemms=((64, 255, 32),)),
        space=TINY_SPACE,
    ).save(spec)
    out = tmp_path / "artifact.json"
    assert main(["run", str(spec), "--out", str(out)]) == 0
    assert "cli-smoke" in capsys.readouterr().err
    art = StudyResult.load(out)
    assert art.kind == "evaluate" and art.study.name == "cli-smoke"
    assert art.result.valid.shape == (1, 6)
    # the artifact's echoed spec is runnable again, bit-for-bit
    _assert_eval_equal(art.study.run().result, art.result)


def test_cli_example_spec_and_stdin_run(tmp_path, capsys, monkeypatch):
    import io

    from repro.cli import main

    assert main(["example-spec", "advise"]) == 0
    spec_text = capsys.readouterr().out
    assert Study.from_json(spec_text).analysis.kind == "advise"
    monkeypatch.setattr("sys.stdin", io.StringIO(spec_text))
    assert main(["run", "-"]) == 0
    art = StudyResult.from_json(capsys.readouterr().out)
    assert art.kind == "advise"
    assert len(art.payload["names"]) == 2


def test_cli_rejects_bad_spec(tmp_path):
    from repro.cli import main

    bad = tmp_path / "bad.json"
    bad.write_text('{"space": {}}')
    with pytest.raises(SystemExit, match="workload"):
        main(["run", str(bad)])
    with pytest.raises(SystemExit, match="does not exist"):
        main(["run", str(tmp_path / "missing.json")])
    # misspelled field -> clean error, not a TypeError traceback
    typo = tmp_path / "typo.json"
    typo.write_text('{"workload": {"kind": "gemms", "gemm": [[64, 784, 128]]}}')
    with pytest.raises(SystemExit, match="invalid study spec"):
        main(["run", str(typo)])
