"""Cycle-level simulator: dOS computes exact GEMMs, cycles match Eqs."""

import numpy as np

from _hyp import given, settings, st  # property tests skip w/o hypothesis

from repro.core.analytical import tau_2d, tau_3d
from repro.core.systolic import simulate_dos_3d, simulate_os_2d

shapes = st.tuples(
    st.integers(1, 12), st.integers(1, 24), st.integers(1, 12),  # M K N
    st.integers(1, 6), st.integers(1, 6), st.integers(1, 4),  # R C L
)


@given(shapes)
@settings(max_examples=40, deadline=None)
def test_os_2d_exact(s):
    M, K, N, R, C, _ = s
    rng = np.random.default_rng(42)
    A = rng.normal(size=(M, K)).astype(np.float32)
    B = rng.normal(size=(K, N)).astype(np.float32)
    r = simulate_os_2d(A, B, R, C)
    np.testing.assert_allclose(np.asarray(r.out), A @ B, rtol=1e-4, atol=1e-4)
    assert r.cycles == int(tau_2d(M, K, N, R, C))


@given(shapes)
@settings(max_examples=40, deadline=None)
def test_dos_3d_exact(s):
    M, K, N, R, C, L = s
    rng = np.random.default_rng(7)
    A = rng.normal(size=(M, K)).astype(np.float32)
    B = rng.normal(size=(K, N)).astype(np.float32)
    r = simulate_dos_3d(A, B, R, C, L)
    np.testing.assert_allclose(np.asarray(r.out), A @ B, rtol=1e-4, atol=1e-4)
    assert r.cycles == int(tau_3d(M, K, N, R, C, L))
    assert r.tiers == L


def test_3d_faster_than_2d_when_k_large():
    """The simulated machine itself shows the paper's speedup."""
    A = np.ones((8, 96), np.float32)
    B = np.ones((96, 8), np.float32)
    r2 = simulate_os_2d(A, B, 8, 8)
    r3 = simulate_dos_3d(A, B, 8, 8, 4)
    assert r3.cycles < r2.cycles
