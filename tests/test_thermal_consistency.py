"""Thermal-model consistency: the engine's batched lumped model
(``lumped_tier_temps``) vs the HotSpot-analogue grid solver
(``solve_stack``) across the Fig. 8 configurations.

The lumped model collapses each tier to a single isothermal node —
it is the perfectly-spread *lower bound* of the grid model, which
resolves in-die gradients (weak lateral conduction through thinned
tiers leaves grid interiors hotter than the isothermal assumption).
Consistency therefore means: identical tier ordering, lumped <= grid
on max temperature, a bounded gap on the rise over ambient, and the
same monotonic trends (more tiers -> hotter, more MACs -> hotter).
"""

import numpy as np
import pytest

from repro.core.ppa import array_power, lumped_tier_temps
from repro.core.ppa import constants as C
from repro.core.ppa.thermal import _GRID, _power_map, solve_stack

FIG8_MACS = (4096, 16384, 65536)


def _both_models(macs_per_tier: int, tiers: int, tech: str):
    """(grid tier temps (tiers, g, g), lumped tier temps (tiers,)) for
    one Fig. 8 configuration, driven by the same power report."""
    side = int(np.sqrt(macs_per_tier))
    q, rep = _power_map(128, 300, 128, side, side, tiers, tech)
    a_mac = C.A_MAC_UM2
    if tech == "tsv":
        a_mac += C.VLINK_BITS * C.A_TSV_UM2 * (tiers - 1) / max(tiers, 1)
    elif tech == "miv":
        a_mac += C.VLINK_BITS * C.A_MIV_UM2 * (tiers - 1) / max(tiers, 1)
    cell_area_mm2 = (macs_per_tier * a_mac * 1e-6) / (_GRID * _GRID)
    T_grid = np.asarray(solve_stack(q, cell_area_mm2, tiers, tech))
    footprint_mm2 = macs_per_tier * a_mac * 1e-6
    q_lumped = np.full((1, tiers), rep.total_w / tiers)
    T_lumped = lumped_tier_temps(
        q_lumped, [footprint_mm2], [tiers], [tech], [macs_per_tier]
    )[0, :tiers]
    return T_grid, T_lumped


@pytest.mark.parametrize("macs", FIG8_MACS)
@pytest.mark.parametrize("tiers,tech", [(1, "2d"), (3, "tsv"), (3, "miv")])
def test_lumped_vs_grid_fig8_configs(macs, tiers, tech):
    T_grid, T_lumped = _both_models(macs, tiers, tech)
    grid_tier_means = T_grid.mean(axis=(1, 2))
    # identical tier ordering: temperature rises away from the heatsink
    assert np.all(np.diff(grid_tier_means) >= -1e-9)
    assert np.all(np.diff(T_lumped) >= -1e-9)
    # the isothermal lumped node never exceeds the grid's hotspot
    assert T_lumped.max() <= T_grid.max() + 1e-6
    # bounded gap on the rise over ambient: the lumped rise stays
    # within [25%, 100%] of the grid's max rise (2D, with thick
    # full-strength silicon, spreads almost perfectly and lands much
    # closer; thin 3D tiers spread worst)
    rise_g = T_grid.max() - C.T_AMBIENT_C
    rise_l = T_lumped.max() - C.T_AMBIENT_C
    assert rise_g > 0 and rise_l > 0
    lo = 0.70 if tiers == 1 else 0.25
    assert lo <= rise_l / rise_g <= 1.0 + 1e-9, (rise_l, rise_g)
    # and against the like-for-like quantity (the grid's per-tier
    # mean), the lumped nodes track within 55% of the rise
    rel = np.abs(T_lumped - grid_tier_means) / (grid_tier_means - C.T_AMBIENT_C)
    assert np.all(rel < 0.55), rel


def test_more_tiers_hotter_both_models():
    """Fig. 8 trend: deeper stacks run hotter.

    Grid model: the full Fig. 8 parametrization (same per-tier MACs,
    power model in the loop). Lumped model: the controlled stacking
    experiment — same per-tier power and footprint, more tiers — since
    the isothermal node cannot see the hotspot intensification that
    drives part of the grid trend (the power model's per-tier draw
    also dips slightly with depth, masking the residual effect)."""
    prev_g = -np.inf
    for tiers in (2, 3, 4, 5):
        T_grid, _ = _both_models(16384, tiers, "tsv")
        assert T_grid.max() > prev_g
        prev_g = T_grid.max()
    prev_l = -np.inf
    for tiers in (1, 2, 3, 4, 5, 6):
        q = np.zeros((1, 6))
        q[0, :tiers] = 2.0
        T = lumped_tier_temps(q, [6.5], [tiers], ["tsv"], [16384])
        t_max = float(np.max(T[0, :tiers]))
        assert t_max > prev_l
        prev_l = t_max


def test_more_macs_hotter_both_models():
    """Fig. 8 trend: bigger arrays run hotter (perimeter cooling does
    not keep up with the power of the larger die)."""
    prev_g = prev_l = -np.inf
    for macs in FIG8_MACS:
        T_grid, T_lumped = _both_models(macs, 3, "tsv")
        assert T_grid.max() > prev_g
        assert T_lumped.max() > prev_l
        prev_g, prev_l = T_grid.max(), T_lumped.max()


def test_lumped_miv_hotter_than_tsv():
    """No via copper in the vertical path (and a denser die) leaves
    MIV hotter than TSV in both models — the paper's Fig. 8 split."""
    Tg_tsv, Tl_tsv = _both_models(16384, 3, "tsv")
    Tg_miv, Tl_miv = _both_models(16384, 3, "miv")
    assert Tg_miv.max() > Tg_tsv.max()
    assert Tl_miv.max() > Tl_tsv.max()


def test_lumped_power_scaling_is_linear():
    """Steady-state linearity: doubling every tier's power doubles the
    rise over ambient (the tridiagonal solve is linear in q)."""
    q = np.array([[2.0, 2.0, 2.0]])
    T1 = lumped_tier_temps(q, [6.5], [3], ["tsv"], [16384])
    T2 = lumped_tier_temps(2 * q, [6.5], [3], ["tsv"], [16384])
    np.testing.assert_allclose(
        T2 - C.T_AMBIENT_C, 2 * (T1 - C.T_AMBIENT_C), rtol=1e-10
    )
