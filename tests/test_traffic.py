"""Kernel-aware HBM traffic model: mixed-family accounting consistency.

Regression tests for the hybrid split: zamba2-style hybrids run an SSM
backbone of ``n_layers`` blocks PLUS ``n_layers // attn_every``
weight-shared attention+MLP applications. The traffic model must charge
the SSM accounting for the backbone and the attention accounting (block
activations, kernel qkv/o, decode kv cache) for exactly the attention
applications — the seed model charged attention-kernel traffic for ALL
``n_layers`` while dropping the attention block/cache terms entirely.
"""

import dataclasses

import pytest

from repro.analysis.traffic import traffic_bytes_per_device
from repro.config import SHAPES
from repro.configs import REGISTRY

MODES = ["train_4k", "prefill_32k", "decode_32k"]
KW = dict(n_chips=256, model_ax=16, microbatches=4)
N_PARAMS = 1_000_000_000  # held fixed: weight traffic is an argument


@pytest.fixture(scope="module")
def hybrid():
    cfg = REGISTRY["zamba2-2.7b"]
    assert cfg.family == "hybrid" and cfg.attn_every > 0
    return cfg


@pytest.mark.parametrize("shape", MODES)
def test_hybrid_ssm_endpoint(hybrid, shape):
    """With no attention applications a hybrid is exactly an SSM."""
    hyb0 = dataclasses.replace(hybrid, attn_every=0)
    ssm = dataclasses.replace(hyb0, family="ssm")
    a = traffic_bytes_per_device(hyb0, SHAPES[shape], N_PARAMS, **KW)
    b = traffic_bytes_per_device(ssm, SHAPES[shape], N_PARAMS, **KW)
    assert a == pytest.approx(b, rel=1e-12)


@pytest.mark.parametrize("shape", MODES)
def test_hybrid_dense_endpoint(hybrid, shape):
    """The attention component of a hybrid equals the dense per-layer
    accounting: adding n_attn attention applications to the backbone
    moves the total by exactly what n_attn dense layers cost."""
    n_attn = hybrid.n_layers // hybrid.attn_every
    assert n_attn > 0
    hyb0 = dataclasses.replace(hybrid, attn_every=0)
    dense_kw = dict(family="dense", attn_every=0, ssm_state=0)
    dense_n = dataclasses.replace(hybrid, n_layers=n_attn, **dense_kw)
    dense_0 = dataclasses.replace(hybrid, n_layers=0, **dense_kw)
    sh = SHAPES[shape]
    d_hybrid = (
        traffic_bytes_per_device(hybrid, sh, N_PARAMS, **KW)
        - traffic_bytes_per_device(hyb0, sh, N_PARAMS, **KW)
    )
    d_dense = (
        traffic_bytes_per_device(dense_n, sh, N_PARAMS, **KW)
        - traffic_bytes_per_device(dense_0, sh, N_PARAMS, **KW)
    )
    assert d_hybrid == pytest.approx(d_dense, rel=1e-9)
    assert d_hybrid > 0  # the attention component actually counts


def test_hybrid_attention_scales_with_attn_every(hybrid):
    """More attention applications -> strictly more traffic, and the
    kernel component is proportional to n_layers // attn_every (the
    seed bug charged it for all n_layers regardless)."""
    sh = SHAPES["decode_32k"]
    t0 = traffic_bytes_per_device(
        dataclasses.replace(hybrid, attn_every=0), sh, N_PARAMS, **KW
    )
    t6 = traffic_bytes_per_device(
        dataclasses.replace(hybrid, attn_every=6), sh, N_PARAMS, **KW
    )
    t3 = traffic_bytes_per_device(
        dataclasses.replace(hybrid, attn_every=3), sh, N_PARAMS, **KW
    )
    assert t0 < t6 < t3
    n6 = hybrid.n_layers // 6
    n3 = hybrid.n_layers // 3
    assert (t3 - t0) / (t6 - t0) == pytest.approx(n3 / n6, rel=1e-9)


def test_non_hybrid_families_unchanged_structure():
    """Dense/MoE: attention accounting covers all layers; SSM: none.
    (Guards the refactored split against regressions for the families
    whose numbers the seed model already had right.)"""
    sh = SHAPES["decode_32k"]
    dense = REGISTRY["qwen2.5-3b"]
    # halving the layers halves the layer-proportional part
    half = dataclasses.replace(dense, n_layers=dense.n_layers // 2)
    t_full = traffic_bytes_per_device(dense, sh, N_PARAMS, **KW)
    t_half = traffic_bytes_per_device(half, sh, N_PARAMS, **KW)
    zero = dataclasses.replace(dense, n_layers=0)
    t_zero = traffic_bytes_per_device(zero, sh, N_PARAMS, **KW)
    assert (t_full - t_zero) == pytest.approx(2 * (t_half - t_zero), rel=1e-9)
    # xlstm (family ssm) must carry no attention-kernel/cache term:
    # the per-layer traffic is independent of the attention head count
    ssm = REGISTRY["xlstm-125m"]
    assert ssm.family == "ssm"
    more_heads = dataclasses.replace(ssm, n_kv_heads=ssm.n_heads)
    assert traffic_bytes_per_device(
        ssm, sh, N_PARAMS, **KW
    ) == pytest.approx(
        traffic_bytes_per_device(more_heads, sh, N_PARAMS, **KW), rel=1e-12
    )
