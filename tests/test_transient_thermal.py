"""Transient thermal/DVFS layer (``core.pricing`` + ``ppa.thermal``
time stepping): the steady lumped solve is the exact fixed point of
``step_temps``; the governor throttles down/steps up with hysteresis
and stays in range; governed sustained throughput never exceeds peak
and tightens monotonically with the thermal limit; the steady code
paths stay bit-identical when transient mode is off; and the pinned
steady-infeasible-3D-beats-2D feasibility flip from the thermal bench
holds through the full serve stack.
"""

import dataclasses
import json
import sys

import numpy as np
import pytest

sys.path.insert(0, str(__import__("pathlib").Path(__file__).parent))
from _hyp import given, settings, st

from repro.configs import REGISTRY, SHAPES
from repro.core.engine import DesignGrid, NetworkReport, evaluate, schedule
from repro.core.network import lower_network
from repro.core.ppa import constants as C
from repro.core.ppa.thermal import ThermalState, lumped_tier_temps, step_temps
from repro.core.pricing import DvfsSpec, governed_run, governor_step
from repro.core.study import (
    AnalysisSpec,
    BandwidthSpec,
    ConstraintSpec,
    ServeSpec,
    SpaceSpec,
    Study,
    TrafficSpec,
    WorkloadSpec,
)

WORKLOADS = [(64, 3072, 768), (256, 768, 768)]

BATCH = dict(
    footprint_mm2=np.array([4.2, 4.2, 30.0]),
    tiers=np.array([4, 8, 1]),
    tech=np.array(["tsv", "miv", "2d"]),
    macs_per_tier=np.array([4096.0, 4096.0, 65536.0]),
)


def _q(q_tier):
    L = int(BATCH["tiers"].max())
    return np.where(
        np.arange(L)[None, :] < BATCH["tiers"][:, None],
        np.asarray(q_tier)[:, None],
        0.0,
    )


# ---------------------------------------------------------------- thermal


def test_steady_state_is_exact_fixed_point():
    """One backward-Euler step from the steady solution stays there:
    the stepping reuses the steady assembly, so the fixed point is
    exact up to float64 roundoff, at any dt."""
    q = _q([1.5, 0.8, 6.0])
    steady = lumped_tier_temps(q, **BATCH)
    state = ThermalState.init(**BATCH)
    state = dataclasses.replace(state, temps_c=steady.copy())
    for dt in (1e-4, 0.1, 50.0):
        state = step_temps(state, q, np.full(3, dt))
        np.testing.assert_allclose(state.temps_c, steady, rtol=1e-9)


def test_transient_converges_to_steady():
    """Stepping from ambient under constant power converges to the
    one-shot steady solve, monotonically heating along the way."""
    q = _q([1.5, 0.8, 6.0])
    steady = lumped_tier_temps(q, **BATCH)
    state = ThermalState.init(**BATCH)
    t_prev = state.t_max_c.copy()
    for _ in range(400):
        state = step_temps(state, q, np.full(3, 0.05))
        assert np.all(state.t_max_c >= t_prev - 1e-9)
        t_prev = state.t_max_c.copy()
    alive = state.alive
    rel = np.abs(state.temps_c - steady)[alive] / np.abs(steady[alive])
    assert rel.max() < 1e-9
    # padded tiers stay pinned at ambient
    assert np.all(state.temps_c[~alive] == C.T_AMBIENT_C)


def test_transient_undershoots_steady_midway():
    """The whole point of the transient model: partway through the
    ramp the stack is strictly cooler than its steady state."""
    q = _q([1.5, 0.8, 6.0])
    steady = lumped_tier_temps(q, **BATCH)
    state = ThermalState.init(**BATCH)
    state = step_temps(state, q, np.full(3, 1e-3))
    alive = state.alive
    rise = state.temps_c[alive] - C.T_AMBIENT_C
    rise_steady = steady[alive] - C.T_AMBIENT_C
    assert np.all(rise > 0)
    assert np.all(rise < 0.7 * rise_steady)


# ------------------------------------------------------------------- spec


def test_dvfs_spec_defaults_round_trip():
    spec = DvfsSpec()
    again = DvfsSpec.from_dict(json.loads(json.dumps(spec.to_dict())))
    assert again == spec
    assert spec.n_states == 3
    # top state is the reference operating point: scale factors 1.0
    sd, ss = spec.scales()
    assert sd[-1] == 1.0 and ss[-1] == 1.0
    assert np.all(sd[:-1] < 1.0) and np.all(ss[:-1] < 1.0)


@pytest.mark.parametrize(
    "kw",
    [
        dict(freqs_ghz=()),
        dict(freqs_ghz=(1.0, 0.5)),
        dict(freqs_ghz=(-1.0, 1.0)),
        dict(vdds_v=(0.7,)),
        dict(vdds_v=(0.9, 0.8, 0.7)),
        dict(throttle_margin_c=-1.0),
        dict(hysteresis_c=float("nan")),
        dict(sim_steps=1),
    ],
)
def test_dvfs_spec_rejects(kw):
    with pytest.raises(ValueError):
        DvfsSpec(**kw)


def test_governor_step_policy():
    spec = DvfsSpec(freqs_ghz=(0.5, 0.75, 1.0), throttle_margin_c=3.0,
                    hysteresis_c=5.0)
    limit = 80.0  # trip at 77, step-up below 72
    state = np.array([2, 2, 1, 1, 0, 0])
    temps = np.array([78.0, 74.0, 71.0, np.nan, 77.0, 60.0])
    out = governor_step(state, temps, limit, spec)
    # hot -> down; in the hysteresis band -> hold; cool -> up;
    # NaN -> hold; bottom state saturates; cold bottom steps up
    assert out.tolist() == [1, 2, 2, 1, 0, 1]


@settings(max_examples=50, deadline=None)
@given(
    st.integers(min_value=2, max_value=5),
    st.lists(st.floats(min_value=-50.0, max_value=200.0),
             min_size=1, max_size=8),
)
def test_governor_state_always_in_range(n_states, temps):
    spec = DvfsSpec(freqs_ghz=tuple(0.5 + 0.1 * i for i in range(n_states)))
    state = np.arange(len(temps)) % n_states
    for _ in range(4):
        state = governor_step(state, np.array(temps), 85.0, spec)
        assert np.all((state >= 0) & (state <= n_states - 1))


@settings(max_examples=20, deadline=None)
@given(
    st.floats(min_value=1e5, max_value=1e8),
    st.floats(min_value=0.5, max_value=20.0),
    st.floats(min_value=50.0, max_value=95.0),
)
def test_governed_run_residency_is_distribution(cycles, power_w, limit_c):
    """Whatever the quantum and limit, residency rows are probability
    distributions, sustained <= peak, and the reported excursion is
    consistent with ``within_limit``."""
    b = np.ones(2)
    out = governed_run(
        compute_cycles=np.array([cycles, cycles / 3]),
        mem_cycles=np.array([cycles / 2, cycles]),
        vlink_cycles=np.zeros(2),
        static_w=b * power_w * 0.3,
        dynamic_w=b * power_w * 0.7,
        valid=np.array([True, True]),
        tiers=np.array([1, 4]),
        tech=np.array(["2d", "tsv"]),
        footprint_mm2=np.array([30.0, 8.0]),
        macs_per_tier=np.array([65536.0, 16384.0]),
        dvfs=DvfsSpec(sim_steps=16),
        limit_c=limit_c,
    )
    resid = out["residency"]
    assert np.all(resid >= 0) and np.all(resid <= 1)
    np.testing.assert_allclose(resid.sum(axis=1), 1.0)
    assert np.all(out["sustained_per_s"] <= out["peak_per_s"] * (1 + 1e-12))
    assert np.array_equal(
        out["within_limit"], out["t_max_transient_c"] < limit_c
    )


# -------------------------------------------------------------- evaluate


def _eval(thermal="steady", **kw):
    grid = DesignGrid.product(WORKLOADS, (2**14, 2**16), (1, 4, 8))
    return evaluate(grid, metrics=("perf", "area", "power", "thermal"),
                    thermal=thermal, **kw)


def test_steady_evaluate_bit_identical_with_explicit_mode():
    d0 = _eval().to_dict()
    d1 = _eval(thermal="steady").to_dict()
    assert d0.keys() == d1.keys()
    for k, v in d0.items():
        np.testing.assert_array_equal(v, d1[k], err_msg=k)


def test_transient_evaluate_sustained_group():
    res = _eval(thermal="transient", dvfs=DvfsSpec(sim_steps=8))
    ok = res.valid
    assert ok.any()
    np.testing.assert_allclose(res.dvfs_residency[ok].sum(axis=1), 1.0)
    assert np.all(
        res.peak_per_s[ok] >= res.sustained_per_s[ok] * (1 - 1e-9)
    )
    assert np.all(res.peak_vs_sustained[ok] >= 1.0 - 1e-9)
    assert np.all(np.isfinite(res.t_max_transient_c[ok]))
    # the governed excursion under a finite trace never exceeds the
    # infinite-horizon steady temperature
    assert np.all(
        res.t_max_transient_c[ok] <= res.t_max_c[ok] + 1e-9
    )


def test_transient_sustained_monotonic_in_limit():
    """Tightening the thermal limit can only reduce (never raise) the
    governed sustained throughput."""
    spec = DvfsSpec(sim_steps=16)
    hot = _eval(thermal="transient", dvfs=spec, thermal_limit=75.0)
    cold = _eval(thermal="transient", dvfs=spec, thermal_limit=48.0)
    ok = hot.valid & cold.valid
    assert ok.any()
    assert np.all(
        cold.sustained_per_s[ok] <= hot.sustained_per_s[ok] * (1 + 1e-12)
    )
    # and the top-state residency can only shrink
    assert np.all(
        cold.dvfs_residency[ok][:, -1] <= hot.dvfs_residency[ok][:, -1] + 1e-12
    )


# -------------------------------------------------------------- schedule


def test_schedule_transient_report_round_trips():
    stream = lower_network(REGISTRY["smollm-135m"], SHAPES["decode_32k"])
    rep = schedule(stream, mac_budgets=(2**14,), tiers=(1, 2, 4),
                   thermal="transient", dvfs=DvfsSpec(sim_steps=8))
    assert rep.dvfs is not None and rep.dvfs["feasible_transient"]
    np.testing.assert_allclose(np.sum(rep.dvfs["residency"]), 1.0)
    assert rep.dvfs["peak_vs_sustained"] >= 1.0 - 1e-12
    again = NetworkReport.from_dict(json.loads(json.dumps(rep.to_dict())))
    assert again.to_dict() == rep.to_dict()


def test_schedule_steady_identical_with_explicit_mode():
    stream = lower_network(REGISTRY["smollm-135m"], SHAPES["decode_32k"])
    r0 = schedule(stream, mac_budgets=(2**14,), tiers=(1, 2))
    r1 = schedule(stream, mac_budgets=(2**14,), tiers=(1, 2),
                  thermal="steady")
    assert r0.to_dict() == r1.to_dict()
    assert r0.dvfs is None


# ------------------------------------------------------ study spec gates


def test_analysis_spec_transient_validation():
    with pytest.raises(ValueError, match="thermal"):
        AnalysisSpec(kind="evaluate", thermal="bogus")
    with pytest.raises(ValueError, match="transient"):
        AnalysisSpec(kind="advise", thermal="transient")
    with pytest.raises(ValueError, match="thermal"):
        AnalysisSpec(kind="evaluate", thermal="transient",
                     metrics=("perf",))
    with pytest.raises(ValueError, match="transient"):
        AnalysisSpec(kind="evaluate", dvfs=DvfsSpec())
    spec = AnalysisSpec(kind="evaluate", thermal="transient")
    assert spec.dvfs == DvfsSpec()
    # dict coercion (the JSON path)
    spec2 = AnalysisSpec(kind="evaluate", thermal="transient",
                         dvfs={"freqs_ghz": [0.6, 1.0]})
    assert isinstance(spec2.dvfs, DvfsSpec)
    assert spec2.dvfs.freqs_ghz == (0.6, 1.0)


def test_transient_study_json_round_trip():
    study = Study(
        name="t",
        workload=WorkloadSpec(kind="gemms", gemms=tuple(WORKLOADS)),
        space=SpaceSpec(mac_budgets=(2**14,), tiers=(1, 4)),
        analysis=AnalysisSpec(kind="evaluate", thermal="transient",
                              dvfs=DvfsSpec(sim_steps=8)),
    )
    again = Study.from_json(study.to_json())
    assert again == study
    assert again.analysis.dvfs.sim_steps == 8


# ------------------------------------------------- serve: the pinned flip


def _flip_study(thermal):
    """The thermal bench scenario (see benchmarks/thermal_bench.py):
    per-tier-budget-matched grid where the 8-tier stack runs hotter
    than the small 2D die, under a limit between their steady temps."""
    traffic = TrafficSpec(
        arrival_rps=2048.0, n_requests=8, prompt_dist="lognormal",
        prompt_mean=128, prompt_max=512, output_dist="lognormal",
        output_mean=24, output_max=96, sigma=0.6, max_batch=4,
        policy="continuous", chunk_prefill=64, seed=0,
    )
    return Study(
        name=f"flip-{thermal}",
        workload=WorkloadSpec(kind="network", arch="qwen2.5-3b",
                              shape="decode_32k"),
        space=SpaceSpec(mac_budgets=(2**14, 2**18), tiers=(1, 8)),
        constraints=ConstraintSpec(thermal_limit_c=54.4),
        analysis=AnalysisSpec(
            kind="serve", thermal=thermal,
            bandwidth=BandwidthSpec.paper_default(),
            serve=ServeSpec(traffic=traffic),
        ),
    )


def test_serve_flip_steady_infeasible_3d_wins_sustained():
    steady = _flip_study("steady").run().payload["points"]
    pts = _flip_study("transient").run().payload["points"]
    np.testing.assert_array_equal(steady["feasible"], pts["feasible_steady"])
    ok = pts["valid"]
    np.testing.assert_allclose(pts["dvfs_residency"][ok].sum(axis=1), 1.0)
    assert np.all(pts["peak_vs_sustained"][ok] >= 1.0 - 1e-12)
    flip = pts["feasible"] & ~pts["feasible_steady"] & (pts["tiers"] > 1)
    base = pts["feasible_steady"] & (pts["tiers"] == 1)
    assert flip.any() and base.any()
    best3d = pts["gen_tok_s"][flip].max()
    best2d = pts["gen_tok_s"][base].max()
    # the steady gate threw away the fastest buildable design
    assert best3d > best2d
    assert np.all(pts["t_max_transient_c"][pts["feasible"]] < 54.4)


def test_serve_steady_payload_unchanged_by_mode_flag():
    """The steady serve payload carries no transient keys and is
    byte-identical whether thermal='steady' is defaulted or explicit."""
    pts = _flip_study("steady").run().payload["points"]
    assert "t_max_transient_c" not in pts
    assert "dvfs_residency" not in pts
    assert "peak_tok_s" not in pts
